#!/usr/bin/env python3
"""Validate an `ns-lbp serve-bench --trace` JSONL feed (see EXPERIMENTS.md).

Checks, in order:

1. every line parses as a flat JSON object with a known `kind`;
2. the ring dropped nothing (final `events_dropped` gauge is 0) — pass
   `--allow-drops` to relax the balance checks under deliberate overflow;
3. per-request lifecycle balance, keyed by (class, sensor_id, seq,
   model_id) — the model_id field is omitted from spans when 0, so
   single-model feeds key exactly as before:
   exactly one `submit` XOR one `reject`; every submitted request ends in
   exactly one terminal event (`complete` | `drop` | `expire` | `fail`);
   every completed request has exactly one `queue` span;
4. per-request timestamp sanity: the `queue` and `complete` spans anchor
   at the same enqueue instant, the `submit` instant is not before it,
   and the stage sum (queue wait + its batch's infer span) never exceeds
   the measured end-to-end latency beyond `--slack-ns`;
5. batch accounting: each `batch` span's member count equals the number
   of `queue` spans carrying its batch_id, and every completed request's
   batch has an `infer` span;
6. with `--report BENCH_serve.json`: per-class completed counts in the
   feed match the serve report (the feed belongs to the report's final
   run — with `--compare` the baseline's feed is overwritten);
7. with `--chrome FILE.trace.json`: the Chrome/Perfetto twin is one JSON
   array of well-formed trace events covering the same span counts.

Exit 0 on a valid feed, 1 with a diagnostic on the first violated check.
(Global file-order timestamp monotonicity is deliberately NOT checked:
spans are emitted at stage *end*, so records interleave across threads.)
"""

import argparse
import json
import sys
from collections import defaultdict

PER_REQUEST = {"submit", "reject", "queue", "complete", "drop", "expire",
               "fail"}
KINDS = PER_REQUEST | {"batch", "infer", "phase", "gauge"}
TERMINAL = {"complete", "drop", "expire", "fail"}


def fail(msg):
    print(f"trace check: FAIL: {msg}")
    sys.exit(1)


def load_feed(path):
    events = []
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as exc:
                fail(f"{path}:{lineno}: not JSON ({exc})")
            if not isinstance(ev, dict):
                fail(f"{path}:{lineno}: not an object")
            kind = ev.get("kind")
            if kind not in KINDS:
                fail(f"{path}:{lineno}: unknown kind {kind!r}")
            ev["_line"] = lineno
            events.append(ev)
    if not events:
        fail(f"{path}: empty feed")
    return events


def check_lifecycles(events, slack_ns):
    """Checks 3 + 4: balance and per-request timestamp sanity."""
    by_req = defaultdict(lambda: defaultdict(list))
    for ev in events:
        if ev["kind"] in PER_REQUEST:
            for field in ("class", "sensor_id", "seq", "ts_ns"):
                if field not in ev:
                    fail(f"line {ev['_line']}: {ev['kind']} record "
                         f"missing {field}")
            key = (ev["class"], ev["sensor_id"], ev["seq"],
                   ev.get("model_id", 0))
            by_req[key][ev["kind"]].append(ev)

    completed = defaultdict(int)
    for (cls, sensor, seq, model), evs in sorted(by_req.items()):
        at = f"{cls} sensor {sensor} seq {seq}" + (
            f" model {model}" if model else "")
        n_submit = len(evs["submit"])
        n_reject = len(evs["reject"])
        if n_submit + n_reject != 1:
            fail(f"{at}: {n_submit} submits + {n_reject} rejects "
                 "(want exactly one admission event)")
        if n_reject:
            extra = [k for k, v in evs.items() if k != "reject" and v]
            if extra:
                fail(f"{at}: rejected but also has {extra}")
            continue
        terms = [e for k in TERMINAL for e in evs[k]]
        if len(terms) != 1:
            fail(f"{at}: {len(terms)} terminal events "
                 f"({[t['kind'] for t in terms]}), want exactly one")
        term = terms[0]
        n_queue = len(evs["queue"])
        if term["kind"] == "complete":
            if n_queue != 1:
                fail(f"{at}: completed with {n_queue} queue spans")
            completed[cls] += 1
        elif n_queue > 1:
            fail(f"{at}: {n_queue} queue spans")

        # timestamp sanity: queue/complete anchor at the enqueue instant,
        # the submit instant is stamped just after it
        submit_ts = evs["submit"][0]["ts_ns"]
        for span in evs["queue"] + ([term] if term["kind"] == "complete"
                                    else []):
            # the enqueue instant is captured just *before* the submit
            # instant is stamped, so span anchors never follow it
            if span["ts_ns"] > submit_ts + slack_ns:
                fail(f"{at}: {span['kind']} anchor {span['ts_ns']} "
                     f"follows the submit instant {submit_ts}")
        if term["kind"] == "complete" and n_queue == 1:
            q, c = evs["queue"][0], term
            if abs(q["ts_ns"] - c["ts_ns"]) > slack_ns:
                fail(f"{at}: queue and complete spans anchor at "
                     f"different instants ({q['ts_ns']} vs {c['ts_ns']})")
            if q.get("dur_ns", 0) > c.get("dur_ns", 0) + slack_ns:
                fail(f"{at}: queue wait {q.get('dur_ns', 0)} ns exceeds "
                     f"e2e latency {c.get('dur_ns', 0)} ns")
    return by_req, completed


def check_batches(events, by_req, slack_ns):
    """Check 5: batch member counts and queue+infer <= e2e stage sums."""
    batch_spans = {}
    infer_spans = defaultdict(list)
    queue_members = defaultdict(int)
    for ev in events:
        if ev["kind"] == "batch":
            bid = ev.get("batch_id")
            if bid is None:
                fail(f"line {ev['_line']}: batch span without batch_id")
            if bid in batch_spans:
                fail(f"batch {bid}: duplicate batch span")
            batch_spans[bid] = ev
        elif ev["kind"] == "infer":
            bid = ev.get("batch_id")
            if bid is None:
                fail(f"line {ev['_line']}: infer span without batch_id")
            infer_spans[bid].append(ev)
        elif ev["kind"] == "queue":
            queue_members[ev.get("batch_id")] += 1

    for bid, span in sorted(batch_spans.items()):
        want = int(span.get("value", 0))
        got = queue_members.get(bid, 0)
        if want != got:
            fail(f"batch {bid}: span says {want} members, feed carries "
                 f"{got} queue spans")

    # stage sum: queue wait + the batch's infer time <= e2e latency
    for key, evs in by_req.items():
        if len(evs["complete"]) != 1 or len(evs["queue"]) != 1:
            continue
        q, c = evs["queue"][0], evs["complete"][0]
        bid = q.get("batch_id")
        infers = infer_spans.get(bid, [])
        if not infers:
            fail(f"{key}: completed via batch {bid} but the feed has no "
                 "infer span for it")
        stage_sum = q.get("dur_ns", 0) + min(i.get("dur_ns", 0)
                                             for i in infers)
        if stage_sum > c.get("dur_ns", 0) + slack_ns:
            fail(f"{key}: stage sum {stage_sum} ns exceeds e2e "
                 f"{c.get('dur_ns', 0)} ns (+{slack_ns} slack)")
    return len(batch_spans), sum(len(v) for v in infer_spans.values())


def check_report(report_path, completed):
    """Check 6: feed vs serve-bench --json per-class completed counts."""
    doc = json.load(open(report_path, encoding="utf-8"))
    # the feed belongs to the *final* run in the report
    rep = doc["results"][-1]["report"]
    for cls in rep.get("per_class", []):
        want = cls["completed"]
        got = completed.get(cls["class"], 0)
        if want != got:
            fail(f"report says {cls['class']} completed {want}, feed "
                 f"carries {got} complete spans")
    total = rep["completed"]
    if sum(completed.values()) != total:
        fail(f"report total completed {total} != feed "
             f"{sum(completed.values())}")
    print(f"trace check: report cross-check ok ({total} completions)")


def check_chrome(chrome_path, n_complete):
    """Check 7: the Chrome-trace twin is loadable and consistent."""
    doc = json.load(open(chrome_path, encoding="utf-8"))
    if not isinstance(doc, list) or not doc:
        fail(f"{chrome_path}: not a non-empty JSON array")
    complete_x = 0
    for i, ev in enumerate(doc):
        if not isinstance(ev, dict):
            fail(f"{chrome_path}[{i}]: not an object")
        for field in ("ph", "pid", "name"):
            if field not in ev:
                fail(f"{chrome_path}[{i}]: missing {field}")
        if ev["ph"] == "X":
            if "ts" not in ev or "dur" not in ev:
                fail(f"{chrome_path}[{i}]: X event without ts/dur")
            if ev["name"] == "complete":
                complete_x += 1
        elif ev["ph"] not in {"i", "C", "M"}:
            fail(f"{chrome_path}[{i}]: unexpected phase {ev['ph']!r}")
    if complete_x != n_complete:
        fail(f"{chrome_path}: {complete_x} complete X-events vs "
             f"{n_complete} in the feed")
    print(f"trace check: chrome twin ok ({len(doc)} records)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("feed", help="JSONL trace feed")
    ap.add_argument("--report", help="BENCH_serve.json to cross-check")
    ap.add_argument("--chrome", help="Chrome-trace twin to validate")
    ap.add_argument("--allow-drops", action="store_true",
                    help="tolerate ring overflow (skips balance checks)")
    ap.add_argument("--slack-ns", type=int, default=1_000_000,
                    help="timer slack for stage-sum checks (default 1 ms)")
    args = ap.parse_args()

    events = load_feed(args.feed)
    dropped = max((e.get("value", 0) for e in events
                   if e["kind"] == "gauge"
                   and e.get("label") == "events_dropped"), default=0)
    if dropped:
        msg = f"ring dropped {int(dropped)} events"
        if not args.allow_drops:
            fail(msg + " (pass --allow-drops for overflow runs)")
        print(f"trace check: {msg}; skipping balance checks")
        print(f"trace check: ok ({len(events)} events, overflow run)")
        return

    by_req, completed = check_lifecycles(events, args.slack_ns)
    n_batches, n_infers = check_batches(events, by_req, args.slack_ns)
    n_complete = sum(completed.values())
    if args.report:
        check_report(args.report, completed)
    if args.chrome:
        check_chrome(args.chrome, n_complete)
    print(f"trace check: ok — {len(events)} events, {len(by_req)} "
          f"requests, {n_complete} completed, {n_batches} batches, "
          f"{n_infers} infer spans, 0 ring drops")


if __name__ == "__main__":
    main()
