#!/usr/bin/env python3
"""Validate a `serve-bench --async --json` soak document
(see EXPERIMENTS.md §Async-serve).

Usage: soak_check.py BENCH_serve_async.json [--sensors N]
           [--max-spread K] [--p99-budget-ms MS] [--require-autoscale]

Checks, in order:

1. the document parses and carries the serve-bench schema
   (`frames`/`sensors`/`results`, each result a `report`);
2. lifecycle balance after drain, per result and per QoS class:
   accepted == completed + dropped + failed (nothing in flight, nothing
   double-counted), and something actually completed;
3. zero billed-frame loss: the billed class sheds nothing voluntarily
   or otherwise (dropped == failed == 0, completed == accepted);
4. correctness riders: no architectural/functional mismatches and no
   cross-check mismatches survived the soak;
5. fairness: per-sensor completed-frame spread (max - min across all
   offered streams) within `--max-spread` — the end-to-end deficit-
   round-robin bound;
6. p99 bounded: end-to-end p99 latency within `--p99-budget-ms` (a
   soak that completes by queueing unboundedly proves nothing);
7. with `--require-autoscale`: the async plane ran (`async` non-null),
   its worker pool is real (workers >= 1), the active shard count sits
   inside [min_shards, max_shards] with a consistent high water, and
   load actually grew the pool at least once (scale_up_events >= 1).

Exit 0 on a valid soak, 1 with a diagnostic on the first violated
check.  `--sensors N` additionally pins the document's stream fan-out
(CI runs the 100k-sensor soak with it).
"""

import argparse
import json
import sys


def fail(msg):
    print(f"soak check: FAIL: {msg}")
    sys.exit(1)


def class_counts(report, name):
    for c in report.get("per_class", []):
        if c["class"] == name:
            return c
    return None


def check_balance(tag, report):
    acc, comp = report["accepted"], report["completed"]
    drop, failed = report["dropped"], report["failed"]
    if acc != comp + drop + failed:
        fail(f"{tag}: lifecycle imbalance: accepted {acc} != "
             f"completed {comp} + dropped {drop} + failed {failed}")
    if comp == 0:
        fail(f"{tag}: nothing completed — the soak did no work")
    for c in report.get("per_class", []):
        if c["accepted"] != c["completed"] + c["dropped"] + c["failed"]:
            fail(f"{tag}: class {c['class']} imbalance: "
                 f"accepted {c['accepted']} != completed {c['completed']} "
                 f"+ dropped {c['dropped']} + failed {c['failed']}")


def check_billed_loss(tag, report):
    billed = class_counts(report, "billed")
    if billed is None or billed["accepted"] == 0:
        return  # the mix offered no billed traffic; nothing to lose
    if billed["dropped"] != 0 or billed["failed"] != 0:
        fail(f"{tag}: billed-frame loss: dropped {billed['dropped']}, "
             f"failed {billed['failed']} (must both be 0)")
    if billed["completed"] != billed["accepted"]:
        fail(f"{tag}: billed completions {billed['completed']} != "
             f"accepted {billed['accepted']}")


def check_async(tag, result, require):
    a = result.get("async")
    if a is None:
        if require:
            fail(f"{tag}: no async stats — the soak ran the threaded "
                 f"plane (pass --async to serve-bench)")
        return
    if a["workers"] < 1:
        fail(f"{tag}: async plane reports {a['workers']} workers")
    lo, hi = a["min_shards"], a["max_shards"]
    if not (1 <= lo <= hi):
        fail(f"{tag}: bad autoscale range [{lo}, {hi}]")
    if not (lo <= a["active_shards"] <= hi):
        fail(f"{tag}: active_shards {a['active_shards']} outside "
             f"[{lo}, {hi}]")
    if not (a["active_shards"] <= a["shards_high_water"] <= hi):
        fail(f"{tag}: shards_high_water {a['shards_high_water']} "
             f"inconsistent (active {a['active_shards']}, max {hi})")
    if require and hi > lo and a["scale_up_events"] < 1:
        fail(f"{tag}: no scale-up events under soak load "
             f"(range [{lo}, {hi}], high water {a['shards_high_water']})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("doc")
    ap.add_argument("--sensors", type=int, default=0,
                    help="require exactly this stream fan-out")
    ap.add_argument("--max-spread", type=int, default=4,
                    help="per-sensor completed-frame spread bound")
    ap.add_argument("--p99-budget-ms", type=float, default=5000.0,
                    help="end-to-end p99 latency budget [ms]")
    ap.add_argument("--require-autoscale", action="store_true",
                    help="fail unless the async plane ran and scaled up")
    args = ap.parse_args()

    try:
        with open(args.doc, "rb") as f:
            doc = json.load(f)
    except (OSError, ValueError) as exc:
        fail(f"{args.doc}: {exc}")
    for key in ("frames", "sensors", "results"):
        if key not in doc:
            fail(f"{args.doc}: not a serve-bench document (missing {key!r})")
    if args.sensors and doc["sensors"] != args.sensors:
        fail(f"sensors {doc['sensors']} != required {args.sensors}")
    if not doc["results"]:
        fail("document carries no results")

    for result in doc["results"]:
        report = result["report"]
        tag = f"shards={result['shards']}"
        check_balance(tag, report)
        check_billed_loss(tag, report)
        if report.get("arch_mismatches", 0) != 0:
            fail(f"{tag}: {report['arch_mismatches']} arch mismatches")
        if report.get("cross_check_mismatches", 0) != 0:
            fail(f"{tag}: {report['cross_check_mismatches']} cross-check "
                 f"mismatches")
        spread = result["fairness_spread"]
        if spread > args.max_spread:
            fail(f"{tag}: fairness spread {spread} > bound "
                 f"{args.max_spread}")
        p99 = report["latency_ms"]["p99"]
        if p99 > args.p99_budget_ms:
            fail(f"{tag}: p99 {p99:.1f} ms > budget "
                 f"{args.p99_budget_ms:.1f} ms")
        check_async(tag, result, args.require_autoscale)
        a = result.get("async")
        scaling = (f", shards {a['min_shards']}..{a['max_shards']} high "
                   f"water {a['shards_high_water']} (+{a['scale_up_events']}"
                   f"/-{a['scale_down_events']})" if a else "")
        print(f"soak check: {tag}: OK — {report['completed']} completed "
              f"over {doc['sensors']} sensors, spread {spread}, "
              f"p99 {p99:.1f} ms{scaling}")

    print(f"soak check: PASS ({args.doc})")


if __name__ == "__main__":
    main()
