#!/usr/bin/env python3
"""Bench-trajectory diff for CI — soft perf gate (see EXPERIMENTS.md).

Finds the most recent successful run on main that actually carries a
`bench-json` artifact (one artifact-less or expired run must not
disable the trajectory forever), downloads it, and prints per-metric
delta tables against the JSON files produced by the current run —
covering every trajectory artifact:

* BENCH_hotpath.json — bench_harness schema: per-case median ns,
* BENCH_serve.json   — serve-bench schema: per-shard-count throughput,
  p95 latency, energy per frame,
* BENCH_serve_async.json — same schema from the async-plane soak
  (EXPERIMENTS.md §Async-serve),
* BENCH_fleet.json   — fleet-bench schema: baseline/drill pass latency
  and completion counts,
* BENCH_chaos_*.json — chaos schema (EXPERIMENTS.md §Chaos): recovery
  p99 under injected faults and faulted-pass completion counts (the
  bitflip-sweep document carries physical rates, not perf — no series),
* AB_energy.json     — A/B harness schema: per-arm energy/time/TOPS-W.

A series absent from the previous run's artifact is a *first sighting*
(a newly introduced bench), not drift: it prints an informational line
and every metric shows as "new" — no warnings, no gate.

Gating policy: ordinary drift only annotates the table (runners are
noisy), but a *sustained* collapse — the current median more than 2x
worse than the previous run's — exits 1 and fails the step.  Everything
procedural (first run, expired artifact, API hiccup) still prints a
note and exits 0: only measured regressions gate, never plumbing.

Env: GITHUB_TOKEN, GITHUB_REPOSITORY, GITHUB_RUN_ID (standard in
Actions); GITHUB_API_URL optional.
"""

import io
import json
import os
import sys
import urllib.request
import zipfile

FLAG_THRESHOLD_PCT = 15.0  # deltas worse than this get a "regression?" mark
HARD_FACTOR = 2.0  # >2x worse than the previous median fails the step
ARTIFACT = "bench-json"


def api(url):
    req = urllib.request.Request(url, headers={
        "Authorization": f"Bearer {os.environ['GITHUB_TOKEN']}",
        "Accept": "application/vnd.github+json",
        "X-GitHub-Api-Version": "2022-11-28",
    })
    return urllib.request.urlopen(req, timeout=60)


def flatten(name, blob):
    """One file -> {metric: (value, higher_is_better)}."""
    doc = json.loads(blob)
    out = {}
    if "cases" in doc:  # bench_harness schema (BENCH_hotpath.json)
        for c in doc["cases"]:
            out[f"{c['name']} median_ns"] = (c["median_ns"], False)
    elif "results" in doc:  # serve-bench schema (BENCH_serve.json)
        for r in doc["results"]:
            rep = r["report"]
            tag = f"shards={r['shards']}"
            out[f"{tag} throughput_fps"] = (rep["throughput_fps"], True)
            out[f"{tag} p95_ms"] = (rep["latency_ms"]["p95"], False)
            out[f"{tag} energy_per_frame_uj"] = (
                rep["energy_per_frame_uj"], False)
    elif "scenario" in doc:  # chaos schema (BENCH_chaos_*.json)
        # NB: before the fleet branch — chaos docs also carry
        # "baseline"/"nodes", but with a different shape
        if doc["scenario"] != "bitflip-sweep":
            tag = doc["scenario"]
            out[f"{tag} recovery_p99_ms"] = (
                doc["gates"]["recovery_p99_ms"], False)
            out[f"{tag} completed"] = (
                doc["faulted"]["report"]["completed"], True)
    elif "baseline" in doc and "nodes" in doc:  # fleet-bench (BENCH_fleet.json)
        for phase in ("baseline", "drill"):
            sub = doc.get(phase)
            if not sub:
                continue
            rep = sub["report"]
            out[f"{phase} p95_ms"] = (rep["latency_ms"]["p95"], False)
            out[f"{phase} completed"] = (rep["completed"], True)
    elif "a" in doc and "b" in doc:  # A/B harness schema (AB_energy.json)
        for arm_key in ("a", "b"):
            arm = doc[arm_key]
            tag = f"{arm_key}:{arm.get('profile', '?')}"
            out[f"{tag} energy_uj_per_frame"] = (
                arm["energy_uj_per_frame"], False)
            out[f"{tag} time_us_per_frame"] = (arm["time_us_per_frame"],
                                               False)
            if "tops_per_watt" in arm:
                out[f"{tag} tops_per_watt"] = (arm["tops_per_watt"], True)
    else:
        print(f"{name}: unrecognized schema; skipping")
    return out


def previous_artifact_run(repo, base, current):
    """Newest successful run on main (excluding `current`) that still has
    an unexpired bench-json artifact, plus that artifact."""
    runs = json.load(api(
        f"{base}/repos/{repo}/actions/runs"
        "?branch=main&status=success&per_page=30"))["workflow_runs"]
    for run in runs:
        if str(run["id"]) == current:
            continue
        arts = json.load(api(
            f"{base}/repos/{repo}/actions/runs/{run['id']}/artifacts"
        ))["artifacts"]
        art = next((a for a in arts if a["name"] == ARTIFACT
                    and not a.get("expired")), None)
        if art is not None:
            return run, art
    return None, None


def hard_regressed(now, was, higher_better):
    """True when the current value is > HARD_FACTOR worse than `was`."""
    if higher_better:
        return now < was / HARD_FACTOR
    return now > was * HARD_FACTOR


def main():
    repo = os.environ["GITHUB_REPOSITORY"]
    base = os.environ.get("GITHUB_API_URL", "https://api.github.com")
    current = os.environ.get("GITHUB_RUN_ID", "")
    prev, art = previous_artifact_run(repo, base, current)
    if prev is None:
        print(f"bench delta: no previous successful run with a {ARTIFACT} "
              "artifact; skipping")
        return []
    zf = zipfile.ZipFile(io.BytesIO(api(art["archive_download_url"]).read()))

    hard = []
    for name in ("BENCH_hotpath.json", "BENCH_serve.json",
                 "BENCH_serve_async.json", "BENCH_fleet.json",
                 "BENCH_chaos_flaky.json", "BENCH_chaos_flap.json",
                 "AB_energy.json"):
        if name not in zf.namelist():
            if os.path.exists(name):
                # a newly introduced series: this run produced it but the
                # previous artifact predates it — first sighting, not drift
                print(f"bench delta: {name}: new series (first sighting; "
                      f"run {prev['id']} predates it) — recorded, no diff")
            continue
        if not os.path.exists(name):
            print(f"bench delta: {name} not produced by this run; skipping")
            continue
        old = flatten(name, zf.read(name))
        new = flatten(name, open(name, "rb").read())
        if not new:
            continue
        width = max(len(k) for k in new)
        print(f"\n{name}: run {prev['id']} -> this run "
              f"(gates only past {HARD_FACTOR:.0f}x)")
        print(f"  {'metric':<{width}}  {'previous':>12}  {'current':>12}  "
              f"{'delta':>8}")
        for metric, (now, higher_better) in new.items():
            if metric in old and old[metric][0] != 0:
                was = old[metric][0]
                pct = (now - was) / abs(was) * 100.0
                worse = -pct if higher_better else pct
                if hard_regressed(now, was, higher_better):
                    mark = "  <-- REGRESSION (gates)"
                    hard.append(f"{name}: {metric}: {was:.1f} -> {now:.1f}")
                elif worse > FLAG_THRESHOLD_PCT:
                    mark = "  <-- regression?"
                else:
                    mark = ""
                print(f"  {metric:<{width}}  {was:>12.1f}  {now:>12.1f}  "
                      f"{pct:>+7.1f}%{mark}")
            else:
                print(f"  {metric:<{width}}  {'-':>12}  {now:>12.1f}"
                      "       new")
    return hard


if __name__ == "__main__":
    try:
        regressions = main()
    except Exception as exc:  # noqa: BLE001 — plumbing failures never gate
        print(f"bench delta: skipped ({exc})")
        regressions = []
    if regressions:
        print(f"\nbench delta: FAIL — {len(regressions)} metric(s) more "
              f"than {HARD_FACTOR:.0f}x worse than the previous run:")
        for r in regressions:
            print(f"  {r}")
        sys.exit(1)
    sys.exit(0)
