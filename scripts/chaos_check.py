#!/usr/bin/env python3
"""Validate an `ns-lbp chaos --json` document (see EXPERIMENTS.md §Chaos).

Usage: chaos_check.py BENCH_chaos.json [--expect-scenario NAME]
                      [--same-schedule-as OTHER.json]

Checks, in order:

1. the document parses and carries the chaos schema (`scenario`, `seed`,
   `faults`, `schedule`, and per-scenario sections);
2. determinism: when `--same-schedule-as` names a second run, both runs
   must share the scenario, the seed, the effective fault knobs, and an
   identical `schedule` section (digest and event list) — the seeded
   schedule is the whole point, so any drift is a hard failure;
3. recovery (fleet scenarios): zero billed loss, zero orphaned tickets,
   recovery p99 within the `[faults] p99_budget`, and completed-frame
   logits bit-identical to the fault-free pass (`divergent == 0` over a
   non-empty comparison set);
4. the scenario actually injected something — a chaos run whose ledger
   is empty proves nothing: wire faults for flaky-transport, blackholes
   plus health dead/rejoin transitions and retransmits for node-flap,
   shard stalls for slow-shard;
5. bitflip-sweep: the nominal operating point is error-free
   (`nominal_rate == 0`), the Monte-Carlo flip rate / injected flips /
   logit divergence are all monotone in the sigma scale, and the top of
   the sweep actually flipped something.

Exit 0 on a valid document, 1 with a diagnostic on the first violated
check.
"""

import argparse
import json
import sys


def fail(msg):
    print(f"chaos check: FAIL: {msg}")
    sys.exit(1)


def load(path):
    with open(path, encoding="utf-8") as f:
        try:
            return json.load(f)
        except json.JSONDecodeError as exc:
            fail(f"{path}: not JSON ({exc})")


def check_schema(path, doc):
    for key in ("scenario", "seed", "frames", "faults", "schedule"):
        if key not in doc:
            fail(f"{path}: no {key!r} — not a chaos document")
    sched = doc["schedule"]
    for key in ("digest", "events"):
        if key not in sched:
            fail(f"{path}: schedule has no {key!r}")


def check_same_schedule(path_a, a, path_b, b):
    if a["scenario"] != b["scenario"] or a["seed"] != b["seed"]:
        fail(f"{path_b}: scenario/seed differ from {path_a} — the "
             "determinism comparison needs two identical invocations")
    if a["faults"] != b["faults"]:
        fail(f"{path_b}: effective fault knobs differ from {path_a}")
    if a["schedule"] != b["schedule"]:
        fail(f"{path_b}: schedule differs from {path_a} under the same "
             f"seed {a['seed']} — the fault plan is not deterministic")


def check_fleet_scenario(path, doc):
    for key in ("baseline", "faulted", "divergence", "gates"):
        if key not in doc:
            fail(f"{path}: no {key!r} section")
    gates = doc["gates"]
    report = doc["faulted"]["report"]
    wire = doc["faulted"]["wire"]

    if gates["billed_lost"] != 0:
        fail(f"{path}: {gates['billed_lost']} billed frame(s) lost")
    if gates["orphaned"] != 0:
        fail(f"{path}: {gates['orphaned']} orphaned responses")
    if gates["recovery_p99_ms"] > gates["p99_budget_ms"]:
        fail(f"{path}: recovery p99 {gates['recovery_p99_ms']:.3f} ms "
             f"blew the budget {gates['p99_budget_ms']:.1f} ms")
    div = doc["divergence"]
    if div["compared"] == 0:
        fail(f"{path}: no completed frame was comparable to the "
             "fault-free pass — the bit-identity gate is vacuous")
    if div["divergent"] != 0:
        fail(f"{path}: {div['divergent']}/{div['compared']} completed "
             "frames diverged from the fault-free logits")

    scenario = doc["scenario"]
    wire_total = (wire["dropped"] + wire["duplicated"] + wire["delayed"]
                  + wire["blackholed"])
    if scenario == "flaky-transport":
        if wire_total == 0:
            fail(f"{path}: flaky-transport injected no wire fault")
        if gates["retries"] == 0:
            fail(f"{path}: flaky-transport never exercised a retransmit")
    elif scenario == "node-flap":
        if wire["blackholed"] == 0:
            fail(f"{path}: node-flap black-holed nothing")
        health = report["health"]
        if health["dead"] < 1:
            fail(f"{path}: the flapped node was never declared dead")
        if health["rejoined"] < 1:
            fail(f"{path}: the flapped node never rejoined")
        if gates["retries"] == 0:
            fail(f"{path}: node-flap never exercised a retransmit")
    elif scenario == "slow-shard":
        if doc["faulted"]["shard_faults"] == 0:
            fail(f"{path}: slow-shard injected no stall")
    else:
        fail(f"{path}: unknown fleet scenario {scenario!r}")
    return (f"{report['completed']} completed, {wire_total} wire faults, "
            f"{doc['faulted']['shard_faults']} shard faults, "
            f"{gates['retries']} retransmits, p99 "
            f"{gates['recovery_p99_ms']:.1f} ms, 0 billed lost, "
            f"0/{div['compared']} divergent")


def check_bitflip_sweep(path, doc):
    for key in ("sweep", "gates"):
        if key not in doc:
            fail(f"{path}: no {key!r} section")
    gates = doc["gates"]
    sweep = doc["sweep"]
    if not sweep:
        fail(f"{path}: empty sweep")
    if gates["nominal_rate"] != 0:
        fail(f"{path}: nominal sigma flips bits (rate "
             f"{gates['nominal_rate']}) — the paper's operating point "
             "must be error-free")
    for gate in ("rates_monotone", "flips_monotone", "divergence_monotone"):
        if not gates[gate]:
            fail(f"{path}: {gate} is false — divergence must grow with "
                 "the sigma scale")
    top = sweep[-1]
    if top["rate"] <= 0:
        fail(f"{path}: the top of the sweep (sigma x{top['sigma_scale']}) "
             "still has flip rate 0 — the sweep proves nothing")
    if top["bitflips"] == 0:
        fail(f"{path}: rate {top['rate']} at sigma "
             f"x{top['sigma_scale']} but no bit was flipped")
    return (f"{len(sweep)} scales, top rate {top['rate']:.3e}, "
            f"{top['bitflips']} flips, {top['divergent']}/"
            f"{top['compared']} divergent at x{top['sigma_scale']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("doc", help="BENCH_chaos.json from chaos --json")
    ap.add_argument("--expect-scenario",
                    help="fail unless the document is this scenario")
    ap.add_argument("--same-schedule-as", metavar="OTHER",
                    help="second run of the same invocation; its schedule "
                         "section must be identical (determinism gate)")
    args = ap.parse_args()

    doc = load(args.doc)
    check_schema(args.doc, doc)
    scenario = doc["scenario"]
    if args.expect_scenario and scenario != args.expect_scenario:
        fail(f"{args.doc}: scenario {scenario!r}, expected "
             f"{args.expect_scenario!r}")

    if args.same_schedule_as:
        other = load(args.same_schedule_as)
        check_schema(args.same_schedule_as, other)
        check_same_schedule(args.doc, doc, args.same_schedule_as, other)

    if scenario == "bitflip-sweep":
        summary = check_bitflip_sweep(args.doc, doc)
    else:
        summary = check_fleet_scenario(args.doc, doc)

    bits = [f"seed {doc['seed']}", summary]
    if args.same_schedule_as:
        bits.append(f"schedule identical to {args.same_schedule_as}")
    print(f"chaos check: OK: {args.doc}: {scenario}: " + ", ".join(bits))


if __name__ == "__main__":
    main()
