#!/usr/bin/env python3
"""Validate an `ns-lbp fleet-bench --json` document (see EXPERIMENTS.md §Fleet).

Usage: fleet_check.py BENCH_fleet.json [--require-drill] [--require-push]

Checks, in order:

1. the document parses and carries the fleet-bench schema
   (`nodes`/`frames`/`baseline`, optional `drill`);
2. baseline sanity: no kill happened, nothing was re-routed or lost,
   every offered frame is accounted for
   (completed + rejected + dropped + failed == submitted);
3. per-node lifecycle balance, both passes: every live node's drain
   report balances (accepted == completed + dropped + failed), killed
   nodes carry no report (they die without drain), and the sum of
   router-side per-node completion credits equals the fleet's completed
   count;
4. zero billed loss in the drill: `billed_lost == 0` and the billed
   completions equal the billed offered count (the drill invariant);
5. re-homing actually happened when a node was killed (`rerouted > 0` —
   a drill that moved nothing proves nothing);
6. p99 bounded: `drill_p99_ms <= p99_budget * baseline_p99_ms` (the
   budget comes from `[fleet.drill] p99_budget` and is recorded in the
   document);
7. version convergence when a model was rolled: at least one ack, every
   ack's content-hash version identical and nonzero, and — when a node
   was killed first — no ack from the dead node.

Exit 0 on a valid document, 1 with a diagnostic on the first violated
check.  `--require-drill` / `--require-push` also fail when the document
lacks a drill / push section (CI runs with both).
"""

import argparse
import json
import sys


def fail(msg):
    print(f"fleet check: FAIL: {msg}")
    sys.exit(1)


def offered_total(offered):
    return sum(offered.values())


def check_node_balance(tag, report, killed):
    """Per-node lifecycle balance for one pass's fleet report."""
    nodes = report["nodes"]
    per_node = report["per_node"]
    if len(per_node) != nodes:
        fail(f"{tag}: per_node has {len(per_node)} entries for "
             f"{nodes} nodes")
    routed_sum = 0
    for entry in per_node:
        node = entry["node"]
        routed_sum += entry["completed_routed"]
        if entry["killed"] != (node in killed):
            fail(f"{tag}: node {node} killed flag disagrees with the "
                 f"kill list {killed}")
        rep = entry["report"]
        if node in killed:
            if rep is not None:
                fail(f"{tag}: killed node {node} produced a drain report")
            continue
        if rep is None:
            fail(f"{tag}: live node {node} has no drain report")
        if rep["accepted"] != rep["completed"] + rep["dropped"] + rep["failed"]:
            fail(f"{tag}: node {node} lifecycle imbalance: accepted "
                 f"{rep['accepted']} != completed {rep['completed']} + "
                 f"dropped {rep['dropped']} + failed {rep['failed']}")
    if routed_sum != report["completed"]:
        fail(f"{tag}: per-node completion credits sum to {routed_sum}, "
             f"fleet completed {report['completed']}")


def check_pass(tag, section, killed):
    report = section["report"]
    offered = section["offered_by_class"]
    check_node_balance(tag, report, killed)
    accounted = (report["completed"] + report["rejected"]
                 + report["dropped"] + report["failed"]
                 + sum(report["lost_by_class"].values()))
    if accounted < report["submitted"]:
        fail(f"{tag}: {report['submitted']} submitted but only "
             f"{accounted} accounted for")
    if report["orphaned"] != 0:
        fail(f"{tag}: {report['orphaned']} orphaned responses (a "
             "completion arrived for a request the router forgot)")
    # billed frames: the paying class must never be shed
    billed_offered = offered.get("billed", 0)
    if report["billed_lost"] != 0:
        fail(f"{tag}: {report['billed_lost']} billed frame(s) lost")
    if report["completed_by_class"]["billed"] != billed_offered:
        fail(f"{tag}: billed completions "
             f"{report['completed_by_class']['billed']} != billed "
             f"offered {billed_offered}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("doc", help="BENCH_fleet.json from fleet-bench --json")
    ap.add_argument("--require-drill", action="store_true",
                    help="fail when the document has no drill section")
    ap.add_argument("--require-push", action="store_true",
                    help="fail when the drill carries no model push")
    args = ap.parse_args()

    with open(args.doc, encoding="utf-8") as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as exc:
            fail(f"{args.doc}: not JSON ({exc})")

    for key in ("nodes", "frames", "baseline"):
        if key not in doc:
            fail(f"{args.doc}: no {key!r} — not a fleet-bench document")

    # -- baseline pass: an undisturbed fleet ---------------------------
    baseline = check_pass("baseline", doc["baseline"], killed=[])
    if baseline["killed"]:
        fail(f"baseline: kill list {baseline['killed']} is not empty")
    if baseline["rerouted"] != 0:
        fail(f"baseline: {baseline['rerouted']} re-homed frames with "
             "nobody killed")

    drill = doc.get("drill")
    if drill is None:
        if args.require_drill:
            fail("no drill section (run fleet-bench --drill)")
        if args.require_push:
            fail("no drill/push section (run fleet-bench --push-rollover)")
        print(f"fleet check: OK: {args.doc}: baseline only, "
              f"{doc['nodes']} nodes, {baseline['completed']} completed, "
              "0 billed lost")
        return

    # -- drill pass: kill + (optionally) rollover ----------------------
    killed = ([drill["killed_node"]] if "killed_node" in drill else [])
    if args.require_drill and not killed:
        fail("drill section has no killed_node (run with --drill)")
    report = check_pass("drill", drill, killed)
    if killed:
        if report["killed"] != killed:
            fail(f"drill: report kill list {report['killed']} != "
                 f"{killed}")
        if report["rerouted"] == 0:
            fail("drill: a node was killed but nothing was re-homed — "
                 "the drill proved nothing")
        # the clients' summed per-response re-home counts must agree
        # with the router's own counter: every re-home the router
        # performed is visible on exactly one completed response, except
        # frames shed *after* being re-homed (their count dies with the
        # drop), so equality is required whenever nothing was shed
        if "rehomed_observed" in drill:
            observed = drill["rehomed_observed"]
            if observed > report["rerouted"]:
                fail(f"drill: clients observed {observed} re-homes, the "
                     f"router only counted {report['rerouted']}")
            shed = (report["dropped"] + report["failed"]
                    + sum(report["lost_by_class"].values()))
            if shed == 0 and observed != report["rerouted"]:
                fail(f"drill: router re-homed {report['rerouted']} "
                     f"frame(s) but completed responses only carry "
                     f"{observed} — a re-home went unaccounted")
        budget = drill["p99_budget"]
        baseline_p99 = max(drill["baseline_p99_ms"], 1e-3)
        if drill["drill_p99_ms"] > budget * baseline_p99:
            fail(f"drill: p99 {drill['drill_p99_ms']:.3f} ms blew the "
                 f"budget ({budget}x baseline {baseline_p99:.3f} ms)")

    push = drill.get("push")
    if push is None:
        if args.require_push:
            fail("no model push in the drill (run with --push-rollover)")
    else:
        acks = push["acks"]
        if not acks:
            fail("push: no node acked the rolled artifact")
        versions = {a["version"] for a in acks}
        if len(versions) != 1:
            fail(f"push: acked versions diverge: {sorted(versions)}")
        version = versions.pop()
        if int(version, 16) == 0:
            fail("push: converged on the zero version (unstamped artifact)")
        dead_acks = [a["node"] for a in acks if a["node"] in killed]
        if dead_acks:
            fail(f"push: dead node(s) {dead_acks} acked the roll")

    bits = [f"{doc['nodes']} nodes", f"{report['completed']} completed",
            f"{report['rerouted']} re-homed", "0 billed lost"]
    if push is not None:
        bits.append(f"push converged on v{version} ({len(acks)} acks)")
    print(f"fleet check: OK: {args.doc}: " + ", ".join(bits))


if __name__ == "__main__":
    main()
