"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compute layer — hypothesis
sweeps shapes/values and asserts bit-exact equality (integer kernels, so
no tolerance).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lbp_encode import lbp_encode, ROWS_PER_BLOCK
from compile.kernels.bitserial_mlp import (bitserial_matmul,
                                           signed_bitserial_matmul)

# hypothesis deadline off: interpret-mode pallas is slow but deterministic
COMMON = dict(deadline=None, max_examples=25)


# ---------------------------------------------------------------------------
# LBP encode
# ---------------------------------------------------------------------------
@settings(**COMMON)
@given(
    rows=st.integers(1, 700),
    e=st.integers(1, 12),
    apx=st.integers(0, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_lbp_encode_matches_ref(rows, e, apx, seed):
    rng = np.random.default_rng(seed)
    nb = rng.integers(0, 256, (rows, e)).astype(np.int32)
    pv = rng.integers(0, 256, (rows,)).astype(np.int32)
    got = np.asarray(lbp_encode(jnp.asarray(nb), jnp.asarray(pv), apx=apx))
    want = np.asarray(ref.lbp_encode_ref(jnp.asarray(nb), jnp.asarray(pv),
                                         apx=apx))
    np.testing.assert_array_equal(got, want)


@settings(**COMMON)
@given(rows=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
def test_bitplane_algorithm_equals_functional_compare(rows, seed):
    """Algorithm 1 (MSB-first mismatch search) == plain >= comparison."""
    rng = np.random.default_rng(seed)
    nb = rng.integers(0, 256, (rows, 8)).astype(np.int32)
    pv = rng.integers(0, 256, (rows,)).astype(np.int32)
    bp = np.asarray(ref.lbp_compare_bitplane_ref(jnp.asarray(nb),
                                                 jnp.asarray(pv)))
    fn = np.asarray(ref.lbp_compare_ref(jnp.asarray(nb), jnp.asarray(pv)))
    np.testing.assert_array_equal(bp, fn)


def test_lbp_encode_equality_is_ge():
    """Pivot == neighbor must give bit 1 (cmp(i_n, i_c)=1 when i_n >= i_c)."""
    nb = jnp.full((4, 8), 77, dtype=jnp.int32)
    pv = jnp.full((4,), 77, dtype=jnp.int32)
    got = np.asarray(lbp_encode(nb, pv))
    assert (got == 255).all()


def test_lbp_encode_apx_zeroes_lsbs():
    """PAC skip-comparison: apx LSBs of the code must be zero."""
    rng = np.random.default_rng(3)
    nb = jnp.asarray(rng.integers(0, 256, (ROWS_PER_BLOCK, 8)), dtype=jnp.int32)
    pv = jnp.asarray(rng.integers(0, 256, (ROWS_PER_BLOCK,)), dtype=jnp.int32)
    for apx in range(5):
        codes = np.asarray(lbp_encode(nb, pv, apx=apx))
        assert (codes & ((1 << apx) - 1) == 0).all()
        # and the surviving bits agree with the un-approximated code
        full = np.asarray(lbp_encode(nb, pv, apx=0))
        np.testing.assert_array_equal(codes, full & ~((1 << apx) - 1))


def test_lbp_encode_extremes():
    nb = jnp.asarray([[0] * 8, [255] * 8], dtype=jnp.int32)
    pv = jnp.asarray([255, 0], dtype=jnp.int32)
    got = np.asarray(lbp_encode(nb, pv))
    assert got[0] == 0      # all neighbors below pivot
    assert got[1] == 255    # all neighbors above pivot


# ---------------------------------------------------------------------------
# bit-serial matmul
# ---------------------------------------------------------------------------
@settings(**COMMON)
@given(
    b=st.integers(1, 70),
    d=st.integers(1, 96),
    o=st.integers(1, 160),
    act_bits=st.integers(1, 6),
    w_bits=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitserial_matmul_matches_int_matmul(b, d, o, act_bits, w_bits, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 1 << act_bits, (b, d)).astype(np.int32)
    w = rng.integers(0, 1 << w_bits, (d, o)).astype(np.int32)
    got = np.asarray(bitserial_matmul(jnp.asarray(x), jnp.asarray(w),
                                      act_bits, w_bits))
    want = np.asarray(ref.int_matmul_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, want)


@settings(**COMMON)
@given(seed=st.integers(0, 2**31 - 1))
def test_bitserial_ref_decomposition(seed):
    """The Σ 2^{m+n} popcount(AND) identity itself (paper §5.2 / [45])."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 16, (9, 33)).astype(np.int32)
    w = rng.integers(0, 16, (33, 21)).astype(np.int32)
    a = np.asarray(ref.bitserial_matmul_ref(jnp.asarray(x), jnp.asarray(w), 4, 4))
    b_ = np.asarray(ref.int_matmul_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(a, b_)


@settings(**COMMON)
@given(seed=st.integers(0, 2**31 - 1), w_bits=st.integers(2, 5))
def test_signed_bitserial_offset_correction(seed, w_bits):
    """Unsigned-storage offset trick recovers the signed product exactly."""
    rng = np.random.default_rng(seed)
    half = 1 << (w_bits - 1)
    x = rng.integers(0, 16, (5, 40)).astype(np.int32)
    w_signed = rng.integers(-half, half, (40, 17)).astype(np.int32)
    got = np.asarray(signed_bitserial_matmul(
        jnp.asarray(x), jnp.asarray(w_signed + half), 4, w_bits))
    want = x.astype(np.int64) @ w_signed.astype(np.int64)
    np.testing.assert_array_equal(got, want)


def test_bitserial_zero_dims_rejected():
    with pytest.raises(Exception):
        bitserial_matmul(jnp.zeros((2, 3), jnp.int32),
                         jnp.zeros((4, 5), jnp.int32), 4, 4)
