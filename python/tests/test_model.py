"""L2 model tests: shapes, integer semantics, pallas/jnp path equality,
PAC monotonicity, params round-trip."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as m
from compile import data as data_mod


def tiny_cfg(**kw):
    base = dict(height=12, width=12, in_channels=1, n_lbp_layers=2,
                kernels_per_layer=4, pool=4, hidden=32, seed=5)
    base.update(kw)
    return m.ApLbpConfig(**base)


@pytest.fixture(scope="module")
def tiny_params():
    return m.init_params(tiny_cfg())


@pytest.fixture(scope="module")
def tiny_images():
    rng = np.random.default_rng(11)
    return jnp.asarray(rng.random((3, 12, 12, 1)).astype(np.float32))


def test_channels_after():
    cfg = tiny_cfg()
    assert cfg.channels_after == (1, 5, 9)
    assert cfg.feature_dim == (12 // 4) * (12 // 4) * 9


def test_config_for_matches_paper():
    assert m.config_for("mnist").n_lbp_layers == 3      # 5 blocks: 3 LBP + 2 FC
    assert m.config_for("svhn").n_lbp_layers == 8       # 10 blocks: 8 LBP + 2 FC
    assert m.config_for("mnist").hidden == 512
    assert m.config_for("svhn").in_channels == 3
    with pytest.raises(ValueError):
        m.config_for("cifar10")


def test_sensor_quantize_masks_lsbs():
    imgs = jnp.asarray(np.linspace(0, 1, 64, dtype=np.float32).reshape(1, 8, 8, 1))
    for apx in range(4):
        q = np.asarray(m.sensor_quantize(imgs, apx))
        assert q.min() >= 0 and q.max() <= 255
        assert (q & ((1 << apx) - 1) == 0).all()
    # apx=0 is plain round-to-nearest
    q0 = np.asarray(m.sensor_quantize(imgs, 0))
    np.testing.assert_array_equal(
        q0, np.clip(np.floor(np.asarray(imgs) * 255 + 0.5), 0, 255).astype(np.int32))


def test_shifted_relu_u8_range_and_knee():
    codes = jnp.arange(256, dtype=jnp.int32)
    out = np.asarray(m.shifted_relu_u8(codes, 8))
    assert out.min() == 0 and out.max() <= 255
    assert (out[:129] == 0).all()          # below/at the 2^{e-1} shift
    assert out[129] == 2 and out[255] == 254
    assert np.all(np.diff(out) >= 0)       # monotone


def test_forward_shapes(tiny_params, tiny_images):
    feats = m.forward_lbp(tiny_params, tiny_images)
    assert feats.shape == (3, tiny_params.config.feature_dim)
    logits = m.apply(tiny_params, tiny_images)
    assert logits.shape == (3, 10)


def test_features_are_act_bits_bounded(tiny_params, tiny_images):
    feats = np.asarray(m.forward_lbp(tiny_params, tiny_images))
    qmax = (1 << tiny_params.config.act_bits) - 1
    assert feats.min() >= 0 and feats.max() <= qmax


def test_pallas_and_jnp_paths_identical(tiny_params, tiny_images):
    """The L1 Pallas kernels and the oracle must agree through the whole
    network — logits bit-identical (all-integer until the final affine)."""
    a = np.asarray(m.apply(tiny_params, tiny_images, use_pallas=False))
    b = np.asarray(m.apply(tiny_params, tiny_images, use_pallas=True))
    np.testing.assert_array_equal(a, b)


def test_apx_code_prunes_feature_information():
    """More approximated bits ⇒ codes lose only their LSBs (Fig. 3b)."""
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(rng.random((2, 12, 12, 1)).astype(np.float32))
    cfgs = [tiny_cfg(apx_code=a, apx_pixel=0) for a in (0, 2)]
    ps = [m.init_params(c) for c in cfgs]
    # identical patterns (same seed) ⇒ codes differ only in masked bits.
    f0 = np.asarray(m.forward_lbp(ps[0], imgs))
    f2 = np.asarray(m.forward_lbp(ps[1], imgs))
    assert f0.shape == f2.shape
    assert not (f0 == f2).all() or True  # features may coincide after pooling
    # direct check at the code level:
    x = m.sensor_quantize(imgs, 0)
    lay = ps[0].lbp_layers[0]
    n, c = m._gather_neighbors(x, lay, 3)
    from compile.kernels import ref
    c0 = np.asarray(ref.lbp_encode_ref(n.reshape(-1, 8), c.reshape(-1), 0))
    c2 = np.asarray(ref.lbp_encode_ref(n.reshape(-1, 8), c.reshape(-1), 2))
    np.testing.assert_array_equal(c2, c0 & ~3)


def test_joint_block_preserves_input(tiny_params, tiny_images):
    """The joint op cascades ifmaps with ofmaps: first C channels pass through."""
    cfg = tiny_params.config
    x = m.sensor_quantize(tiny_images, cfg.apx_pixel)
    out = m.lbp_layer_forward(x, tiny_params.lbp_layers[0], cfg, False)
    np.testing.assert_array_equal(np.asarray(out[..., :1]), np.asarray(x))
    assert out.shape[-1] == 1 + cfg.kernels_per_layer


def test_params_roundtrip(tmp_path, tiny_params):
    p = tmp_path / "t.params.bin"
    m.save_params(tiny_params, str(p))
    back = m.load_params(str(p))
    # seed is not serialized (patterns are stored explicitly)
    import dataclasses
    assert dataclasses.replace(back.config, seed=tiny_params.config.seed) \
        == tiny_params.config
    for a, b in zip(back.lbp_layers, tiny_params.lbp_layers):
        np.testing.assert_array_equal(a.offsets, b.offsets)
        np.testing.assert_array_equal(a.pivot_ch, b.pivot_ch)
    for ga, gb in ((back.mlp1, tiny_params.mlp1), (back.mlp2, tiny_params.mlp2)):
        np.testing.assert_array_equal(ga.w_int, gb.w_int)
        np.testing.assert_array_equal(ga.scale, gb.scale)
        np.testing.assert_array_equal(ga.bias, gb.bias)


def test_params_roundtrip_inference_identical(tmp_path, tiny_params, tiny_images):
    p = tmp_path / "t.params.bin"
    m.save_params(tiny_params, str(p))
    back = m.load_params(str(p))
    np.testing.assert_array_equal(np.asarray(m.apply(back, tiny_images)),
                                  np.asarray(m.apply(tiny_params, tiny_images)))


def test_patterns_never_sample_pivot_position():
    for lay in m.init_lbp_patterns(m.config_for("mnist")):
        dy, dx = lay.offsets[..., 0], lay.offsets[..., 1]
        assert not ((dy == 0) & (dx == 0)).any()


def test_patterns_deterministic_in_seed():
    a = m.init_lbp_patterns(tiny_cfg(seed=9))
    b = m.init_lbp_patterns(tiny_cfg(seed=9))
    c = m.init_lbp_patterns(tiny_cfg(seed=10))
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(la.offsets, lb.offsets)
    assert any(not np.array_equal(la.offsets, lc.offsets)
               for la, lc in zip(a, c))


def test_surrogate_gradient_flows():
    """Paper footnote 1: binary comparisons are replaced by a shifted tanh
    in the backward pass.  Verify the surrogate has usable gradients."""
    def soft_compare(n, c, tau=0.1):
        return 0.5 * (jnp.tanh((n - c) / tau) + 1.0)

    g = jax.grad(lambda c: soft_compare(0.6, c).sum())(0.55)
    assert np.isfinite(g) and g < 0  # raising the pivot lowers the bit


def test_datasets_shapes_and_determinism():
    for name, shape in data_mod.SHAPES.items():
        x, y, xt, yt = data_mod.load_dataset(name, n_train=64, n_test=32)
        assert x.shape == (64, *shape) and xt.shape == (32, *shape)
        assert x.dtype == np.float32 and 0.0 <= x.min() and x.max() <= 1.0
        assert set(np.unique(y)) <= set(range(10))
        x2, y2, _, _ = data_mod.load_dataset(name, n_train=64, n_test=32)
        np.testing.assert_array_equal(x, x2)
        np.testing.assert_array_equal(y, y2)


def test_dataset_classes_balanced():
    _, y, _, _ = data_mod.load_dataset("mnist", n_train=200, n_test=10)
    counts = np.bincount(y, minlength=10)
    assert counts.min() >= 15  # 200/10 = 20 ± shuffle
