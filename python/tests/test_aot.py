"""AOT pipeline tests: HLO text emission, constant-elision guard,
manifest consistency, and the params binary the artifacts ship with."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as m


def test_to_hlo_text_emits_parseable_module():
    f = jax.jit(lambda x: (x * 2.0 + 1.0,))
    lowered = f.lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[4]" in text


def test_to_hlo_text_rejects_elided_constants():
    """Large baked-in constants round-trip as garbage — must be refused."""
    big = jnp.arange(100_000, dtype=jnp.float32).reshape(1000, 100)

    def fn(x):
        return (x @ big,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 1000), jnp.float32))
    with pytest.raises(RuntimeError, match="elided"):
        aot.to_hlo_text(lowered)


def test_export_units_roundtrip(tmp_path):
    manifest = []
    aot.export_units(str(tmp_path), manifest)
    assert (tmp_path / "lbp_encode_unit.hlo.txt").exists()
    assert (tmp_path / "bitserial_unit.hlo.txt").exists()
    assert len(manifest) == 2
    text = (tmp_path / "lbp_encode_unit.hlo.txt").read_text()
    assert "constant({...})" not in text


def test_cli_writes_manifest(tmp_path):
    """Full aot CLI run on the small mnist config only (fast)."""
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--datasets", "mnist", "--batch", "2"],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert out.returncode == 0, out.stderr
    lines = (tmp_path / "manifest.tsv").read_text().strip().splitlines()
    assert lines[0] == "name\tfile\tinputs\toutput"
    names = [l.split("\t")[0] for l in lines[1:]]
    assert "aplbp_mnist" in names and "lbp_encode_unit" in names
    # params round-trip through model.load_params
    p = m.load_params(str(tmp_path / "mnist.params.bin"))
    assert p.config.height == 28


def test_exported_params_match_shipped_artifacts():
    """The artifacts/ params must parse and have the documented shapes."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "mnist.params.bin")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    p = m.load_params(path)
    assert p.config.n_lbp_layers == 3
    assert p.mlp1.w_int.shape == (p.config.feature_dim, p.config.hidden)
    assert p.mlp2.w_int.shape == (p.config.hidden, p.config.n_classes)
    half = 1 << (p.config.w_bits - 1)
    assert p.mlp1.w_int.min() >= -half and p.mlp1.w_int.max() < half


def test_trained_params_compatible_with_artifact_shapes():
    """Trained params (make train) must slot into the same HLO artifact."""
    base = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    trained = os.path.join(base, "mnist_apx2.params.bin")
    shipped = os.path.join(base, "mnist.params.bin")
    if not (os.path.exists(trained) and os.path.exists(shipped)):
        pytest.skip("need `make artifacts` + a trained params file")
    a = m.load_params(trained)
    b = m.load_params(shipped)
    assert a.mlp1.w_int.shape == b.mlp1.w_int.shape
    assert a.mlp2.w_int.shape == b.mlp2.w_int.shape
    assert a.config.apx_code == b.config.apx_code


def test_training_smoke_improves_over_chance():
    """Three hundred steps on 400 images must beat 10% chance clearly."""
    from compile import train
    params, acc = train.train_aplbp("mnist", 2, steps=300, n_train=400,
                                    n_test=200, log=lambda *_: None)
    assert acc > 0.4, f"smoke training accuracy {acc}"
    # folded affines are finite and weights in range
    assert np.isfinite(params.mlp1.scale).all()
    assert np.isfinite(params.mlp1.bias).all()
