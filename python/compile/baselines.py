"""Baseline networks for Table 4 (paper §6.5).

Five comparison models, each with the same macro-topology budget as the
paper ("identical hyper-parameters such as number of basic blocks, number
of hidden neurons"):

* ``cnn``            — full-precision CNN baseline [49]
* ``bnn``            — Binarized NN [50]: sign() weights *and* activations
* ``binaryconnect``  — BinaryConnect [51]: binary weights, float activations
* ``lbcnn``          — Local Binary CNN [15]: fixed sparse ±1 ancestor
                       filters + learned 1x1 channel fusion
* ``lbpnet``         — LBPNet [44] == Ap-LBP with apx = 0 (model.py)

All are written as ``(init, apply)`` pairs over plain pytrees so the one
Adam loop in train.py drives everything.  Binarization uses the
straight-through estimator (STE), as in the original papers.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------
def _conv(x, w, stride=1):
    """NHWC x HWIO 'SAME' convolution."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _pool2(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0


def _ste_sign(w):
    """sign() in the forward pass, identity gradient in [-1, 1] (STE)."""
    s = jnp.sign(w) + (w - jax.lax.stop_gradient(w))
    return jnp.where(jnp.abs(w) <= 1.0, s, jnp.sign(w))


def _glorot(rng, shape):
    fan_in = int(np.prod(shape[:-1]))
    return (rng.standard_normal(shape) * np.sqrt(2.0 / fan_in)).astype(np.float32)


def _head_init(rng, feat_dim, hidden, n_classes):
    return {
        "fc1": _glorot(rng, (feat_dim, hidden)),
        "b1": np.zeros((hidden,), np.float32),
        "fc2": _glorot(rng, (hidden, n_classes)),
        "b2": np.zeros((n_classes,), np.float32),
    }


def _head_apply(p, x, binarize_w=False, binarize_a=False):
    h = x.reshape(x.shape[0], -1)
    w1 = _ste_sign(p["fc1"]) if binarize_w else p["fc1"]
    h = jnp.maximum(h @ w1 + p["b1"], 0.0)
    if binarize_a:
        h = _ste_sign(h)
    w2 = _ste_sign(p["fc2"]) if binarize_w else p["fc2"]
    return h @ w2 + p["b2"]


# ---------------------------------------------------------------------------
# CNN baseline [49]
# ---------------------------------------------------------------------------
def cnn_init(rng: np.random.Generator, shape, hidden=512, n_classes=10):
    h, w, c = shape
    feat = (h // 4) * (w // 4) * 32
    return {
        "c1": _glorot(rng, (3, 3, c, 16)),
        "c2": _glorot(rng, (3, 3, 16, 32)),
        **_head_init(rng, feat, hidden, n_classes),
    }


def cnn_apply(p, x):
    h = _pool2(jnp.maximum(_conv(x, p["c1"]), 0.0))
    h = _pool2(jnp.maximum(_conv(h, p["c2"]), 0.0))
    return _head_apply(p, h)


# ---------------------------------------------------------------------------
# BNN [50] — binarized weights + activations (first conv input stays float)
# ---------------------------------------------------------------------------
def bnn_init(rng, shape, hidden=512, n_classes=10):
    return cnn_init(rng, shape, hidden, n_classes)


def _bn_free_norm(h):
    """Batch-norm-free pre-activation normalization: keeps pre-sign values
    inside the STE's |x| ≤ 1 gradient window (BNNs are untrainable without
    it — the original paper uses batch-norm for the same purpose)."""
    return h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + 1e-5)


def bnn_apply(p, x):
    # standard BNN practice: first conv and final classifier stay
    # full-precision; hidden convs/FC binarize weights and activations.
    h = _pool2(jnp.maximum(_conv(x, p["c1"]), 0.0))
    h = _ste_sign(_bn_free_norm(h))
    h = _pool2(_conv(h, _ste_sign(p["c2"])))
    h = _ste_sign(_bn_free_norm(h))
    h = h.reshape(h.shape[0], -1)
    h = _ste_sign(_bn_free_norm(h @ _ste_sign(p["fc1"]) + p["b1"]))
    return h @ p["fc2"] + p["b2"]


# ---------------------------------------------------------------------------
# BinaryConnect [51] — binary weights, full-precision activations
# ---------------------------------------------------------------------------
def binaryconnect_init(rng, shape, hidden=512, n_classes=10):
    return cnn_init(rng, shape, hidden, n_classes)


def binaryconnect_apply(p, x):
    h = _pool2(jnp.maximum(_conv(x, _ste_sign(p["c1"])), 0.0))
    h = _pool2(jnp.maximum(_conv(h, _ste_sign(p["c2"])), 0.0))
    return _head_apply(p, h, binarize_w=True)


# ---------------------------------------------------------------------------
# LBCNN [15] — fixed sparse ±1 "ancestor" filters + learned 1x1 fusion.
# The ancestors are NOT trained (stop_gradient); only the 1x1 convs and the
# head learn, exactly the paper's premise.
# ---------------------------------------------------------------------------
def _lbcnn_ancestors(rng, c_in, n_anchor, sparsity=0.5):
    w = rng.standard_normal((3, 3, c_in, n_anchor)).astype(np.float32)
    mask = rng.random((3, 3, c_in, n_anchor)) < sparsity
    return np.sign(w) * mask


def lbcnn_init(rng, shape, hidden=512, n_classes=10, n_anchor=32):
    h, w, c = shape
    feat = (h // 4) * (w // 4) * 32
    return {
        "anc1": _lbcnn_ancestors(rng, c, n_anchor),
        "one1": _glorot(rng, (1, 1, n_anchor, 16)),
        "anc2": _lbcnn_ancestors(rng, 16, n_anchor),
        "one2": _glorot(rng, (1, 1, n_anchor, 32)),
        **_head_init(rng, feat, hidden, n_classes),
    }


def lbcnn_apply(p, x):
    a1 = jax.lax.stop_gradient(p["anc1"])
    h = jnp.maximum(_conv(x, a1), 0.0)
    h = _pool2(_conv(h, p["one1"]))          # 1x1 channel fusion (learned)
    a2 = jax.lax.stop_gradient(p["anc2"])
    h = jnp.maximum(_conv(h, a2), 0.0)
    h = _pool2(_conv(h, p["one2"]))
    return _head_apply(p, h)


REGISTRY = {
    "cnn": (cnn_init, cnn_apply),
    "bnn": (bnn_init, bnn_apply),
    "binaryconnect": (binaryconnect_init, binaryconnect_apply),
    "lbcnn": (lbcnn_init, lbcnn_apply),
    # "lbpnet" and "aplbp" are handled by train.py via model.py
}
