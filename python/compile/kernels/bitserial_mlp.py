"""Pallas kernel: bit-serial AND/bitcount/shift matmul (paper §5.2, Fig. 7).

The MLP layers of Ap-LBP are executed in-memory as DoReFa-style bit-plane
dot products:  ``out = Σ_{m,n} 2^{m+n} · bitcount(AND(C_m(I), C_n(W)))``.
In the NS-LBP cache this is a bulk bit-wise AND over the W/I regions plus
the DPU's bit-counter and shifter; on a TPU the natural mapping is one
*integer matmul per (m, n) bit-plane pair* — the popcount-of-AND over the
reduction dimension D is exactly a {0,1}-matrix product, which the MXU
executes as a dense dot.  The (M × N) plane loop is a static unroll.

VMEM budgeting (DESIGN.md §Hardware-Adaptation): a ``(B_blk, D)`` activation
tile, a ``(D, O_blk)`` weight tile, and the int32 accumulator tile live in
VMEM across the plane loop; plane extraction is a cheap VPU shift+mask, so
the kernel is MXU-bound like any quantized matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

B_BLOCK = 32
O_BLOCK = 128


def _bitserial_kernel(x_ref, w_ref, o_ref, *, act_bits: int, w_bits: int):
    x = x_ref[...]                       # (Bb, D) int32, unsigned M-bit
    w = w_ref[...]                       # (D, Ob) int32, unsigned N-bit
    acc = jnp.zeros((x.shape[0], w.shape[1]), dtype=jnp.int32)
    for m in range(act_bits):            # static unroll over bit planes
        xm = (x >> m) & 1
        for n in range(w_bits):
            wn = (w >> n) & 1
            # popcount(AND(C_m, C_n)) over D == {0,1} dot product
            acc = acc + ((1 << (m + n)) *
                         jax.lax.dot_general(
                             xm, wn,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.int32))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("act_bits", "w_bits"))
def bitserial_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray, act_bits: int = 4,
                     w_bits: int = 4) -> jnp.ndarray:
    """``(B, D) @ (D, O)`` over unsigned bit-planes → int32 ``(B, O)``.

    Exact integer semantics: equals ``ref.int_matmul_ref`` for inputs in
    range.  B is padded to B_BLOCK and O to O_BLOCK internally.
    """
    B, D = x_q.shape
    D2, O = w_q.shape
    assert D == D2, (D, D2)
    pb = (-B) % B_BLOCK
    po = (-O) % O_BLOCK
    if pb or po:
        out = bitserial_matmul(
            jnp.pad(x_q, ((0, pb), (0, 0))),
            jnp.pad(w_q, ((0, 0), (0, po))), act_bits, w_bits)
        return out[:B, :O]
    grid = (B // B_BLOCK, O // O_BLOCK)
    return pl.pallas_call(
        functools.partial(_bitserial_kernel, act_bits=act_bits, w_bits=w_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B_BLOCK, D), lambda i, j: (i, 0)),
            pl.BlockSpec((D, O_BLOCK), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((B_BLOCK, O_BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, O), jnp.int32),
        interpret=True,
    )(x_q.astype(jnp.int32), w_q.astype(jnp.int32))


def signed_bitserial_matmul(x_q: jnp.ndarray, w_q_unsigned: jnp.ndarray,
                            act_bits: int, w_bits: int) -> jnp.ndarray:
    """Matmul against *signed* weights stored with a ``2^{N-1}`` offset.

    The DPU stores weights as unsigned N-bit ``w_u = w + 2^{N-1}``; the true
    product is recovered as ``x @ w = x @ w_u - 2^{N-1} · rowsum(x)`` — one
    extra vector op, exactly how the Rust DPU model undoes the offset.
    """
    offset = 1 << (w_bits - 1)
    raw = bitserial_matmul(x_q, w_q_unsigned, act_bits, w_bits)
    rowsum = jnp.sum(x_q.astype(jnp.int32), axis=-1, keepdims=True)
    return raw - offset * rowsum
