"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact integer-semantics
reference here; pytest (``python/tests``) sweeps shapes/dtypes with
hypothesis and asserts bit-exact equality.  The Rust architectural
simulator is additionally cross-checked against the same semantics through
the AOT artifacts, so these functions are the single source of truth for
"what the hardware computes".
"""

from __future__ import annotations

import jax.numpy as jnp


def lbp_compare_ref(neighbors: jnp.ndarray, pivots: jnp.ndarray) -> jnp.ndarray:
    """Elementwise ``neighbors >= pivots`` as int32 bits.

    ``neighbors``: (R, e) int32 pixel intensities (0..255).
    ``pivots``:    (R,) or (R, 1) int32 pivot intensities.

    This is the *functional* definition of the paper's comparator; the
    in-memory Algorithm 1 (MSB-first bit-plane mismatch search) computes
    exactly this predicate — see ``lbp_compare_bitplane_ref`` below for the
    literal algorithmic form.
    """
    pv = pivots.reshape(-1, 1)
    return (neighbors >= pv).astype(jnp.int32)


def lbp_compare_bitplane_ref(neighbors: jnp.ndarray, pivots: jnp.ndarray,
                             n_bits: int = 8) -> jnp.ndarray:
    """Algorithm 1, literally: MSB-first parallel bit-plane mismatch search.

    For each (pixel, neighbor) pair, scan bit planes from MSB to LSB; at
    the first plane where the neighbor bit differs from the pivot bit the
    result is the neighbor's bit (neighbor>pivot iff its bit is 1 there).
    If no plane differs the values are equal and the comparator outputs 1
    (``>=`` convention).  Must equal ``lbp_compare_ref``.
    """
    pv = pivots.reshape(-1, 1).astype(jnp.int32)
    nb = neighbors.astype(jnp.int32)
    res = jnp.ones_like(nb)            # equality -> 1
    decided = jnp.zeros_like(nb, dtype=bool)
    for i in range(n_bits - 1, -1, -1):
        nbit = (nb >> i) & 1
        cbit = (pv >> i) & 1
        mism = (nbit != cbit) & (~decided)
        res = jnp.where(mism, nbit, res)
        decided = decided | mism
    return res


def lbp_encode_ref(neighbors: jnp.ndarray, pivots: jnp.ndarray,
                   apx: int = 0) -> jnp.ndarray:
    """Pack comparator bits into the LBP code with PAC skip-comparison.

    Bits ``0..apx-1`` (the least-significant mapping-table entries) are
    *skipped* — the hardware never issues those compares and the ofmap bits
    are written as zero (paper §3, step 1 of Fig. 3b).

    Returns (R,) int32 codes in ``[0, 2^e)``.
    """
    e = neighbors.shape[-1]
    bits = lbp_compare_ref(neighbors, pivots)
    weights = jnp.array([0 if n < apx else (1 << n) for n in range(e)],
                        dtype=jnp.int32)
    return jnp.sum(bits * weights[None, :], axis=-1)


def bitserial_matmul_ref(x_q: jnp.ndarray, w_q: jnp.ndarray,
                         act_bits: int, w_bits: int) -> jnp.ndarray:
    """DoReFa-style bit-serial integer matmul (paper §5.2).

    ``x_q``: (B, D) int32, unsigned ``act_bits``-bit activations.
    ``w_q``: (D, O) int32, unsigned ``w_bits``-bit weights.

    out[b, o] = sum_{m, n} 2^{m+n} * popcount(AND(C_m(x[b]), C_n(w[:, o])))
    which equals the plain integer matmul — asserted by tests.
    """
    acc = jnp.zeros((x_q.shape[0], w_q.shape[1]), dtype=jnp.int32)
    for m in range(act_bits):
        xm = (x_q >> m) & 1
        for n in range(w_bits):
            wn = (w_q >> n) & 1
            acc = acc + (1 << (m + n)) * jnp.dot(xm, wn)
    return acc


def int_matmul_ref(x_q: jnp.ndarray, w_q: jnp.ndarray) -> jnp.ndarray:
    """Plain integer matmul — ground truth for ``bitserial_matmul_ref``."""
    return jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
