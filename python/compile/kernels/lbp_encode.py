"""Pallas kernel: parallel bit-plane LBP encode (paper Alg. 1 + Fig. 6b).

The NS-LBP sub-array compares all neighbor pixels against the pivot in
parallel, one bit-plane per memory cycle, MSB→LSB, early-exiting per lane
once a mismatching plane is found.  On the 256-column sub-array this is a
row-parallel operation; here the same dataflow is expressed as a Pallas
kernel over a ``(rows, e)`` tile held in VMEM:

* the 8 bit-planes are unrolled statically (constant depth — the paper's
  "constant search time determined by the bit length"),
* the per-lane early exit becomes a ``decided`` mask (branch-free, exactly
  the Ctrl behaviour of Fig. 6b steps 1–4),
* PAC skip-comparison zeroes the ``apx`` least-significant code bits by
  never issuing those compares (their weight is 0 in the packing step).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the block is tiled so a
``(ROWS_PER_BLOCK, e)`` int32 tile plus its 8 plane temporaries stay well
inside VMEM; on a real TPU the plane extraction is a VPU op and the packing
a small reduction — no MXU needed, mirroring that the paper's LBP layer is
comparator-only (MAC-free).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of (pixel, pivot) pairs processed per grid step.  256 mirrors the
# sub-array's 256 bit-lines: one grid step == one mapped sub-array batch.
ROWS_PER_BLOCK = 256


def _lbp_encode_kernel(n_ref, c_ref, o_ref, *, e: int, apx: int, n_bits: int):
    """One grid step: encode ROWS_PER_BLOCK pivots against their e neighbors."""
    nb = n_ref[...]                      # (R, e) int32
    pv = c_ref[...]                      # (R, 1) int32
    # --- Algorithm 1: MSB-first parallel mismatch search ------------------
    res = jnp.ones_like(nb)              # equality => comparator outputs 1
    decided = jnp.zeros(nb.shape, dtype=jnp.bool_)
    for i in range(n_bits - 1, -1, -1):  # static unroll: constant time
        nbit = (nb >> i) & 1
        cbit = (pv >> i) & 1
        mism = (nbit != cbit) & (~decided)
        res = jnp.where(mism, nbit, res)
        decided = decided | mism
    # --- pack bits into the LBP code, PAC-skipping the apx LSBs ----------
    # (static unroll; no captured constant arrays — Pallas requirement)
    code = jnp.zeros((nb.shape[0], 1), dtype=jnp.int32)
    for n in range(apx, e):
        code = code + (res[:, n:n + 1] << n)
    o_ref[...] = code


@functools.partial(jax.jit, static_argnames=("apx", "n_bits"))
def lbp_encode(neighbors: jnp.ndarray, pivots: jnp.ndarray, apx: int = 0,
               n_bits: int = 8) -> jnp.ndarray:
    """LBP-encode ``(R, e)`` neighbors against ``(R,)`` pivots → ``(R,)`` codes.

    R must be a multiple of ``ROWS_PER_BLOCK`` for the block grid; callers
    (the L2 model) pad and slice.  Runs in interpret mode on CPU PJRT; the
    grid/BlockSpec structure is the real-TPU schedule.
    """
    R, e = neighbors.shape
    if R % ROWS_PER_BLOCK != 0:
        pad = ROWS_PER_BLOCK - R % ROWS_PER_BLOCK
        neighbors = jnp.pad(neighbors, ((0, pad), (0, 0)))
        pivots = jnp.pad(pivots, ((0, pad),))
        return lbp_encode(neighbors, pivots, apx, n_bits)[:R]
    grid = (R // ROWS_PER_BLOCK,)
    out = pl.pallas_call(
        functools.partial(_lbp_encode_kernel, e=e, apx=apx, n_bits=n_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_BLOCK, e), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_BLOCK, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_PER_BLOCK, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), jnp.int32),
        interpret=True,
    )(neighbors.astype(jnp.int32), pivots.reshape(-1, 1).astype(jnp.int32))
    return out[:, 0]
