"""Training driver for Ap-LBP and the Table-4 baselines (build-time only).

Ap-LBP training (paper §3, §6.5): the LBP sampling patterns are fixed
("our design approximates pre-trained LBP kernel parameters"), so nothing
upstream of the pooled features is learnable.  We therefore precompute the
pooled quantized features with the *exact integer inference path* of
model.py (no train/test skew) and train only the quantized 2-layer MLP with
straight-through-estimator 4-bit weights and a batch-norm that is folded
into the per-output (scale, bias) affine of ``MlpLayerParams`` afterwards.

The backward pass through the comparator would use the shifted-tanh
surrogate of the paper's footnote 1; it is exercised in tests
(test_model.py::test_surrogate_gradient) but unused here because patterns
stay frozen.

Usage:
  python -m compile.train --dataset mnist --model aplbp --apx 2
  python -m compile.train --all            # Table 4 + Fig 4 sweep
Outputs land in artifacts/: trained params (*.params.bin) and
accuracy/energy statistics tables (*.tsv) consumed by the Rust benches.
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from . import baselines as bl
from . import data as data_mod
from . import model as m

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


# ---------------------------------------------------------------------------
# minimal Adam (optax is unavailable offline)
# ---------------------------------------------------------------------------
def adam_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(grads, state, params, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    mm = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    vv = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), mm)
    vhat = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), vv)
    new = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                       params, mhat, vhat)
    return new, {"m": mm, "v": vv, "t": t}


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(labels.shape[0]), labels])


# ---------------------------------------------------------------------------
# Ap-LBP: quantized-MLP training over precomputed integer features
# ---------------------------------------------------------------------------
def _ste_quant_w(w, bits):
    """STE quantization to a signed ``bits``-bit integer grid.

    Forward: round(clip(w)·2^{bits-1}) — an integer-valued float matching
    ``MlpLayerParams.w_int``.  Backward: straight-through, d(wq)/dw = 2^{bits-1}.
    """
    half = 1 << (bits - 1)
    hard = jnp.round(jnp.clip(w, -1.0, 1.0 - 1.0 / half) * half)
    soft = w * half
    return hard + soft - jax.lax.stop_gradient(soft)


def precompute_features(params: m.ApLbpParams, x: np.ndarray,
                        batch: int = 256) -> np.ndarray:
    fwd = jax.jit(lambda im: m.forward_lbp(params, im, use_pallas=False))
    outs = []
    for i in range(0, len(x), batch):
        outs.append(np.asarray(fwd(jnp.asarray(x[i:i + batch]))))
    return np.concatenate(outs, axis=0)


def train_aplbp_mlp(cfg: m.ApLbpConfig, feats: np.ndarray, labels: np.ndarray,
                    steps: int = 1200, lr: float = 2e-3, batch: int = 128,
                    seed: int = 0, log=print):
    """Train the quantized MLP; return (mlp1, mlp2) with folded batch-norm."""
    rng = np.random.default_rng(seed)
    d = feats.shape[1]
    half = 1 << (cfg.w_bits - 1)
    qmax = (1 << cfg.act_bits) - 1
    xs = feats.astype(np.float32)  # integer values 0..qmax

    params = {
        "w1": (rng.standard_normal((d, cfg.hidden)) * 0.3).astype(np.float32),
        "g1": np.ones((cfg.hidden,), np.float32),
        "b1": np.zeros((cfg.hidden,), np.float32),
        "w2": (rng.standard_normal((cfg.hidden, cfg.n_classes)) * 0.3).astype(np.float32),
        "s2": np.full((cfg.n_classes,), 1.0 / (half * qmax), np.float32),
        "b2": np.zeros((cfg.n_classes,), np.float32),
    }
    params = jax.tree.map(jnp.asarray, params)
    running = {"mean": jnp.zeros((cfg.hidden,)), "var": jnp.ones((cfg.hidden,))}

    def forward(p, x_q, stats=None):
        w1q = _ste_quant_w(p["w1"], cfg.w_bits)          # ints in [-half, half)
        h = x_q @ w1q                                     # integer-valued float
        mean = jnp.mean(h, axis=0) if stats is None else stats["mean"]
        var = jnp.var(h, axis=0) if stats is None else stats["var"]
        hn = (h - mean) * jax.lax.rsqrt(var + 1e-5) * p["g1"] + p["b1"]
        # DPU activation: clip [0,1], requantize to act_bits with STE
        hc = jnp.clip(hn * 0.25 + 0.5, 0.0, 1.0)
        hq = jnp.floor(hc * qmax + 0.5)
        hq = hq + (hc * qmax - jax.lax.stop_gradient(hc * qmax))
        w2q = _ste_quant_w(p["w2"], cfg.w_bits)
        logits = (hq @ w2q) * p["s2"] + p["b2"]
        return logits, (mean, var)

    @jax.jit
    def step(p, opt, run, xb, yb):
        def loss_fn(p_):
            logits, (mean, var) = forward(p_, xb)
            return cross_entropy(logits, yb), (mean, var)
        (loss, (mean, var)), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
        p2, opt2 = adam_update(grads, opt, p, lr=lr)
        run2 = {"mean": 0.95 * run["mean"] + 0.05 * mean,
                "var": 0.95 * run["var"] + 0.05 * var}
        return p2, opt2, run2, loss

    opt = adam_init(params)
    n = len(xs)
    t0 = time.time()
    for it in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, opt, running, loss = step(params, opt, running,
                                          jnp.asarray(xs[idx]),
                                          jnp.asarray(labels[idx]))
        if it % 200 == 0 or it == steps - 1:
            log(f"  step {it:5d} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)")

    # ---- fold batch-norm + fixed affine into MlpLayerParams ---------------
    w1_int = np.asarray(jnp.round(jnp.clip(params["w1"], -1, 1 - 1 / half)
                                  * half), dtype=np.int8)
    w2_int = np.asarray(jnp.round(jnp.clip(params["w2"], -1, 1 - 1 / half)
                                  * half), dtype=np.int8)
    rs = np.asarray(jax.lax.rsqrt(running["var"] + 1e-5))
    g1 = np.asarray(params["g1"])
    b1 = np.asarray(params["b1"])
    mean = np.asarray(running["mean"])
    # hn = (h - mean)*rs*g1 + b1 ; hc = 0.25*hn + 0.5
    scale1 = (0.25 * rs * g1).astype(np.float32)
    bias1 = (0.25 * (b1 - mean * rs * g1) + 0.5).astype(np.float32)
    mlp1 = m.MlpLayerParams(w_int=w1_int, scale=scale1, bias=bias1)
    mlp2 = m.MlpLayerParams(w_int=w2_int,
                            scale=np.asarray(params["s2"], np.float32),
                            bias=np.asarray(params["b2"], np.float32))
    return mlp1, mlp2


def eval_aplbp(params: m.ApLbpParams, x: np.ndarray, y: np.ndarray,
               batch: int = 256) -> float:
    apply = jax.jit(lambda im: m.apply(params, im, use_pallas=False))
    correct = 0
    for i in range(0, len(x), batch):
        logits = np.asarray(apply(jnp.asarray(x[i:i + batch])))
        correct += int((logits.argmax(-1) == y[i:i + batch]).sum())
    return correct / len(x)


def train_aplbp(dataset: str, apx: int, steps: int, n_train: int, n_test: int,
                seed: int = 0, log=print) -> tuple[m.ApLbpParams, float]:
    cfg = m.config_for(dataset, apx=apx)
    params = m.init_params(cfg)
    x_tr, y_tr, x_te, y_te = data_mod.load_dataset(dataset, n_train, n_test)
    log(f"[aplbp/{dataset} apx={apx}] precomputing integer LBP features ...")
    f_tr = precompute_features(params, x_tr)
    mlp1, mlp2 = train_aplbp_mlp(cfg, f_tr, y_tr, steps=steps, seed=seed,
                                 log=log)
    params.mlp1, params.mlp2 = mlp1, mlp2
    acc = eval_aplbp(params, x_te, y_te)
    log(f"[aplbp/{dataset} apx={apx}] test accuracy {acc * 100:.2f}%")
    return params, acc


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------
def train_baseline(name: str, dataset: str, steps: int, n_train: int,
                   n_test: int, seed: int = 0, lr: float = 1e-3,
                   batch: int = 128, log=print) -> float:
    init, apply_fn = bl.REGISTRY[name]
    x_tr, y_tr, x_te, y_te = data_mod.load_dataset(dataset, n_train, n_test)
    shape = x_tr.shape[1:]
    rng = np.random.default_rng(seed)
    params = jax.tree.map(jnp.asarray, init(rng, shape))

    @jax.jit
    def step(p, opt, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p_: cross_entropy(apply_fn(p_, xb), yb))(p)
        p2, opt2 = adam_update(grads, opt, p, lr=lr)
        return p2, opt2, loss

    opt = adam_init(params)
    t0 = time.time()
    for it in range(steps):
        idx = rng.integers(0, len(x_tr), size=batch)
        params, opt, loss = step(params, opt, jnp.asarray(x_tr[idx]),
                                 jnp.asarray(y_tr[idx]))
        if it % 200 == 0 or it == steps - 1:
            log(f"  [{name}/{dataset}] step {it:5d} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)")

    apply_j = jax.jit(lambda xb: apply_fn(params, xb))
    correct = 0
    for i in range(0, len(x_te), 256):
        logits = np.asarray(apply_j(jnp.asarray(x_te[i:i + 256])))
        correct += int((logits.argmax(-1) == y_te[i:i + 256]).sum())
    acc = correct / len(x_te)
    log(f"[{name}/{dataset}] test accuracy {acc * 100:.2f}%")
    return acc


# ---------------------------------------------------------------------------
# sweeps: Table 4 + Fig 4
# ---------------------------------------------------------------------------
def run_table4(datasets, steps, n_train, n_test, out_path, log=print):
    """Regenerate Table 4: rows = models, cols = datasets, values = acc %."""
    models = ["cnn", "bnn", "binaryconnect", "lbcnn", "lbpnet",
              "aplbp1", "aplbp2"]
    rows = {mname: {} for mname in models}
    for ds in datasets:
        for name in models:
            if name in bl.REGISTRY:
                acc = train_baseline(name, ds, steps, n_train, n_test, log=log)
            else:
                apx = {"lbpnet": 0, "aplbp1": 1, "aplbp2": 2}[name]
                _, acc = train_aplbp(ds, apx, max(steps, 2000), n_train,
                                     n_test, log=log)
            rows[name][ds] = acc
    with open(out_path, "w") as f:
        f.write("model\t" + "\t".join(datasets) + "\n")
        for name in models:
            f.write(name + "\t" +
                    "\t".join(f"{rows[name][ds] * 100:.2f}"
                              for ds in datasets) + "\n")
    log(f"wrote {out_path}")
    return rows


def run_fig4(steps, n_train, n_test, out_path, log=print):
    """Fig. 4 sweep: accuracy vs number of approximated bits on MNIST.

    Energy per apx setting is computed by the Rust energy model
    (benches/fig4_apx_sweep.rs) from the op-count formulas; this writes the
    accuracy column it joins against.
    """
    with open(out_path, "w") as f:
        f.write("apx\taccuracy\n")
        for apx in range(0, 5):
            _, acc = train_aplbp("mnist", apx, max(steps, 2000), n_train,
                                 n_test, log=log)
            f.write(f"{apx}\t{acc * 100:.2f}\n")
    log(f"wrote {out_path}")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "fashionmnist", "svhn"])
    ap.add_argument("--model", default="aplbp",
                    choices=["aplbp", "lbpnet"] + sorted(bl.REGISTRY))
    ap.add_argument("--apx", type=int, default=0)
    ap.add_argument("--steps", type=int, default=1200)
    ap.add_argument("--n-train", type=int, default=4000)
    ap.add_argument("--n-test", type=int, default=1000)
    ap.add_argument("--all", action="store_true",
                    help="regenerate Table 4 + Fig 4 accuracy tables")
    ap.add_argument("--table4", action="store_true")
    ap.add_argument("--fig4", action="store_true")
    ap.add_argument("--out-dir", default=ARTIFACTS)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    if args.all or args.table4:
        run_table4(["mnist", "fashionmnist", "svhn"], args.steps,
                   args.n_train, args.n_test,
                   os.path.join(args.out_dir, "table4_accuracy.tsv"))
    if args.all or args.fig4:
        run_fig4(args.steps, args.n_train, args.n_test,
                 os.path.join(args.out_dir, "fig4_accuracy.tsv"))
    if args.all or args.table4 or args.fig4:
        return

    if args.model in ("aplbp", "lbpnet"):
        apx = 0 if args.model == "lbpnet" else args.apx
        params, acc = train_aplbp(args.dataset, apx, args.steps,
                                  args.n_train, args.n_test)
        out = os.path.join(args.out_dir,
                           f"{args.dataset}_apx{apx}.params.bin")
        m.save_params(params, out)
        print(f"saved {out} (accuracy {acc * 100:.2f}%)")
    else:
        train_baseline(args.model, args.dataset, args.steps, args.n_train,
                       args.n_test)


if __name__ == "__main__":
    main()
