"""Layer 2: the Ap-LBP network (paper §3) in JAX, calling the L1 kernels.

Structure (Fig. 1b): N LBP layers (LBP encode → approximate mapping →
shifted-ReLU → joint/concat) → average pooling → quantize → two bit-serial
MLP blocks with a folded batch-norm → logits.

Two execution paths share one parameter set:

* ``forward_lbp`` / ``apply`` — the **inference path**: exact integer
  semantics (u8 pixels, integer LBP codes, integer bit-serial matmuls).
  This is what gets AOT-lowered to HLO for the Rust runtime and what the
  Rust architectural simulator must reproduce bit-for-bit.
* ``apply`` with ``use_pallas=True`` routes the hot-spots through the L1
  Pallas kernels (identical integers, checked by tests); with
  ``use_pallas=False`` it uses the pure-jnp oracle (faster on CPU; used
  for accuracy sweeps).

Approximation knobs (PAC, §3):

* ``apx_code``  — skip the ``apx`` least-significant mapping-table bits
  (skip-comparison + skip-memory-access).
* ``apx_pixel`` — the sensor-side approximation: the ADC never converts the
  ``apx_pixel`` least-significant pixel bits (§4.1), modeled by masking.

Nothing upstream of the pooling layer is learnable (the LBP sampling
patterns are fixed after initialisation — we approximate *pre-trained* LBP
kernels, per the paper's §6.1), so training (train.py) precomputes LBP
features with this exact integer path and trains only the quantized MLP.
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np
import jax.numpy as jnp

from .kernels import ref
from .kernels.lbp_encode import lbp_encode
from .kernels.bitserial_mlp import signed_bitserial_matmul

MAGIC = b"NSLBPPRM"
FORMAT_VERSION = 3


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ApLbpConfig:
    """Hyper-parameters of an Ap-LBP instance (paper §6.5 settings)."""
    height: int = 28
    width: int = 28
    in_channels: int = 1
    n_lbp_layers: int = 3          # MNIST: 3 LBP + 2 FC; SVHN: 8 LBP + 2 FC
    kernels_per_layer: int = 8     # K: ofmap channels added per LBP layer
    e: int = 8                     # sampling points per LBP kernel
    window: int = 3                # f: LBP descriptor window (f x f)
    apx_code: int = 0              # PAC: skipped mapping-table LSBs
    apx_pixel: int = 0             # sensor ADC: skipped pixel LSBs
    pool: int = 4                  # average-pooling window/stride
    hidden: int = 512              # MLP hidden neurons (paper: 512)
    n_classes: int = 10
    act_bits: int = 4              # M: MLP activation bits
    w_bits: int = 4                # N: MLP weight bits
    seed: int = 42

    @property
    def channels_after(self) -> tuple[int, ...]:
        """ifmap channel count entering each LBP layer (joint grows it)."""
        chs = [self.in_channels]
        for _ in range(self.n_lbp_layers):
            chs.append(chs[-1] + self.kernels_per_layer)
        return tuple(chs)

    @property
    def feature_dim(self) -> int:
        ph = self.height // self.pool
        pw = self.width // self.pool
        return ph * pw * self.channels_after[-1]


def config_for(dataset: str, apx: int = 0, seed: int = 42) -> ApLbpConfig:
    """Paper §6.5: 5 blocks (3 LBP + 2 FC) for the MNIST pair, 10 blocks
    (8 LBP + 2 FC) for SVHN, 512 hidden neurons."""
    ds = dataset.lower()
    if ds in ("mnist", "fashionmnist"):
        return ApLbpConfig(height=28, width=28, in_channels=1,
                           n_lbp_layers=3, apx_code=apx, apx_pixel=apx,
                           seed=seed)
    if ds == "svhn":
        return ApLbpConfig(height=32, width=32, in_channels=3,
                           n_lbp_layers=8, apx_code=apx, apx_pixel=apx,
                           seed=seed)
    raise ValueError(f"unknown dataset {dataset!r}")


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LbpLayerParams:
    """One LBP layer's fixed pattern: for each of K kernels, ``e`` sampling
    points (dy, dx, ch) inside the f x f window and a pivot channel."""
    offsets: np.ndarray    # (K, e, 3) int32: dy, dx in [-p, p], ch
    pivot_ch: np.ndarray   # (K,) int32


@dataclasses.dataclass
class MlpLayerParams:
    """Quantized FC layer + folded affine (batch-norm / bias)."""
    w_int: np.ndarray      # (D, O) int8 in [-2^{N-1}, 2^{N-1})
    scale: np.ndarray      # (O,) f32 — folded BN scale (incl. weight scale)
    bias: np.ndarray       # (O,) f32 — folded BN shift


@dataclasses.dataclass
class ApLbpParams:
    config: ApLbpConfig
    lbp_layers: list[LbpLayerParams]
    mlp1: MlpLayerParams
    mlp2: MlpLayerParams


def init_lbp_patterns(cfg: ApLbpConfig) -> list[LbpLayerParams]:
    """Fixed random sparse sampling patterns (LBPNet-style).

    Deterministic in ``cfg.seed``; the params file stores them explicitly
    so the Rust side never has to replicate numpy's bit generator.
    """
    rng = np.random.default_rng(cfg.seed)
    p = (cfg.window - 1) // 2
    layers = []
    for in_ch in cfg.channels_after[:-1]:
        offs = np.zeros((cfg.kernels_per_layer, cfg.e, 3), dtype=np.int32)
        for k in range(cfg.kernels_per_layer):
            for n in range(cfg.e):
                while True:
                    dy = int(rng.integers(-p, p + 1))
                    dx = int(rng.integers(-p, p + 1))
                    if (dy, dx) != (0, 0):
                        break
                offs[k, n] = (dy, dx, int(rng.integers(0, in_ch)))
        piv = rng.integers(0, in_ch, size=cfg.kernels_per_layer).astype(np.int32)
        layers.append(LbpLayerParams(offsets=offs, pivot_ch=piv))
    return layers


def init_params(cfg: ApLbpConfig, rng: np.random.Generator | None = None) -> ApLbpParams:
    """Random (untrained) parameters — used by `make artifacts` and tests;
    train.py replaces the MLP weights/affines with trained values."""
    rng = rng or np.random.default_rng(cfg.seed + 1)
    half = 1 << (cfg.w_bits - 1)
    d = cfg.feature_dim

    def rand_mlp(din, dout):
        w = rng.integers(-half, half, size=(din, dout)).astype(np.int8)
        scale = np.full((dout,), 1.0 / (half * 15.0 * max(din, 1)),
                        dtype=np.float32)
        bias = np.zeros((dout,), dtype=np.float32)
        return MlpLayerParams(w_int=w, scale=scale, bias=bias)

    return ApLbpParams(
        config=cfg,
        lbp_layers=init_lbp_patterns(cfg),
        mlp1=rand_mlp(d, cfg.hidden),
        mlp2=rand_mlp(cfg.hidden, cfg.n_classes),
    )


# ---------------------------------------------------------------------------
# Inference path (exact integer semantics)
# ---------------------------------------------------------------------------
def sensor_quantize(images: jnp.ndarray, apx_pixel: int) -> jnp.ndarray:
    """float [0,1] → u8 pixels with the ADC skipping ``apx_pixel`` LSBs.

    Mirrors rust/src/sensor: the dual-mode ADC simply never resolves the
    low bits, so they read as zero.
    """
    u8 = jnp.clip(jnp.floor(images * 255.0 + 0.5), 0, 255).astype(jnp.int32)
    mask = 0xFF ^ ((1 << apx_pixel) - 1)
    return u8 & mask


def _gather_neighbors(x_u8: jnp.ndarray, layer: LbpLayerParams, window: int):
    """Collect (B,H,W,K,e) neighbor intensities + (B,H,W,K) pivots.

    Zero padding keeps ofmap size == ifmap size (paper Fig. 3a); each
    sampling point is a static slice of the padded tensor, which XLA fuses
    into cheap gathers.
    """
    p = (window - 1) // 2
    B, H, W, _ = x_u8.shape
    xpad = jnp.pad(x_u8, ((0, 0), (p, p), (p, p), (0, 0)))
    K, e, _ = layer.offsets.shape
    neigh = []
    for k in range(K):
        per_k = []
        for n in range(e):
            dy, dx, ch = (int(v) for v in layer.offsets[k, n])
            per_k.append(xpad[:, p + dy:p + dy + H, p + dx:p + dx + W, ch])
        neigh.append(jnp.stack(per_k, axis=-1))        # (B,H,W,e)
    neighbors = jnp.stack(neigh, axis=3)               # (B,H,W,K,e)
    pivots = jnp.stack([x_u8[..., int(c)] for c in layer.pivot_ch], axis=-1)
    return neighbors, pivots                           # ..., (B,H,W,K)


def shifted_relu_u8(code: jnp.ndarray, e: int) -> jnp.ndarray:
    """Approximate mapping + shifted ReLU, integer domain (paper §3).

    code ∈ [0, 2^e); ofmap = min(255, 2·max(0, code − 2^{e−1})) — a
    comparator + shifter op (MAC-free), keeping the ofmap an 8-bit pixel so
    the next LBP layer can consume it.
    """
    half = 1 << (e - 1)
    return jnp.minimum(2 * jnp.maximum(code - half, 0), 255)


def lbp_layer_forward(x_u8: jnp.ndarray, layer: LbpLayerParams,
                      cfg: ApLbpConfig, use_pallas: bool) -> jnp.ndarray:
    """One LBP layer: encode K kernels, shifted-ReLU, joint-concat."""
    B, H, W, _ = x_u8.shape
    K = layer.offsets.shape[0]
    neighbors, pivots = _gather_neighbors(x_u8, layer, cfg.window)
    flat_n = neighbors.reshape(-1, cfg.e)
    flat_c = pivots.reshape(-1)
    if use_pallas:
        codes = lbp_encode(flat_n, flat_c, apx=cfg.apx_code)
    else:
        codes = ref.lbp_encode_ref(flat_n, flat_c, apx=cfg.apx_code)
    codes = codes.reshape(B, H, W, K)
    ofmap = shifted_relu_u8(codes, cfg.e)
    return jnp.concatenate([x_u8, ofmap], axis=-1)     # joint block


def forward_lbp(params: ApLbpParams, images: jnp.ndarray,
                use_pallas: bool = False) -> jnp.ndarray:
    """images float [0,1] (B,H,W,C) → pooled quantized features (B, D) int32.

    Everything here is exact integer math; the Rust simulator reproduces it
    bit-for-bit (rust/tests/golden_model.rs).
    """
    cfg = params.config
    x = sensor_quantize(images, cfg.apx_pixel)
    for layer in params.lbp_layers:
        x = lbp_layer_forward(x, layer, cfg, use_pallas)
    # average pooling as integer sum + exact requantize to act_bits
    B, H, W, C = x.shape
    s = cfg.pool
    pooled = x.reshape(B, H // s, s, W // s, s, C).sum(axis=(2, 4))
    vmax = 255 * s * s
    qmax = (1 << cfg.act_bits) - 1
    # round-half-up in pure integer math (identical in Rust):
    q = (pooled * (2 * qmax) + vmax) // (2 * vmax)
    return q.reshape(B, -1).astype(jnp.int32)


def mlp_forward(params: ApLbpParams, feats_q: jnp.ndarray,
                use_pallas: bool = False) -> jnp.ndarray:
    """Quantized features → logits via two bit-serial FC layers."""
    cfg = params.config
    half = 1 << (cfg.w_bits - 1)
    qmax = (1 << cfg.act_bits) - 1

    def fc(x_q, mlp: MlpLayerParams):
        if use_pallas:
            w_u = jnp.asarray(mlp.w_int, dtype=jnp.int32) + half
            h = signed_bitserial_matmul(x_q, w_u, cfg.act_bits, cfg.w_bits)
        else:
            h = ref.int_matmul_ref(x_q, jnp.asarray(mlp.w_int, jnp.int32))
        return h.astype(jnp.float32) * mlp.scale[None, :] + mlp.bias[None, :]

    h = fc(feats_q, params.mlp1)
    # DPU activation: ReLU + requantize to act_bits (floor(x*qmax+0.5))
    h = jnp.clip(h, 0.0, 1.0)
    h_q = jnp.floor(h * qmax + 0.5).astype(jnp.int32)
    return fc(h_q, params.mlp2)


def apply(params: ApLbpParams, images: jnp.ndarray,
          use_pallas: bool = False) -> jnp.ndarray:
    """Full inference: images → logits (B, n_classes)."""
    feats = forward_lbp(params, images, use_pallas)
    return mlp_forward(params, feats, use_pallas)


# ---------------------------------------------------------------------------
# Parameter serialization (consumed by rust/src/params)
# ---------------------------------------------------------------------------
def save_params(params: ApLbpParams, path: str) -> None:
    """Write the little-endian binary format read by ``rust/src/params``.

    Layout (all ints LE):
      magic[8] | u32 version
      u32 x 14: H W C n_lbp K e window apx_code apx_pixel pool act_bits
                w_bits hidden n_classes
      per LBP layer: i32 offsets[K*e*3], i32 pivot_ch[K]
      per MLP layer (2): u32 D, u32 O, i8 w_int[D*O], f32 scale[O], f32 bias[O]
    """
    cfg = params.config
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", FORMAT_VERSION))
        f.write(struct.pack("<14I", cfg.height, cfg.width, cfg.in_channels,
                            cfg.n_lbp_layers, cfg.kernels_per_layer, cfg.e,
                            cfg.window, cfg.apx_code, cfg.apx_pixel, cfg.pool,
                            cfg.act_bits, cfg.w_bits, cfg.hidden,
                            cfg.n_classes))
        for layer in params.lbp_layers:
            f.write(np.ascontiguousarray(layer.offsets, dtype="<i4").tobytes())
            f.write(np.ascontiguousarray(layer.pivot_ch, dtype="<i4").tobytes())
        for mlp in (params.mlp1, params.mlp2):
            d, o = mlp.w_int.shape
            f.write(struct.pack("<2I", d, o))
            f.write(np.ascontiguousarray(mlp.w_int, dtype="i1").tobytes())
            f.write(np.ascontiguousarray(mlp.scale, dtype="<f4").tobytes())
            f.write(np.ascontiguousarray(mlp.bias, dtype="<f4").tobytes())


def load_params(path: str) -> ApLbpParams:
    """Inverse of ``save_params`` (round-trip tested)."""
    with open(path, "rb") as f:
        data = f.read()
    off = 0

    def take(n):
        nonlocal off
        chunk = data[off:off + n]
        off += n
        return chunk

    assert take(8) == MAGIC, "bad magic"
    (ver,) = struct.unpack("<I", take(4))
    assert ver == FORMAT_VERSION, f"params version {ver} != {FORMAT_VERSION}"
    vals = struct.unpack("<14I", take(14 * 4))
    (h, w, c, n_lbp, k, e, win, apx_c, apx_p, pool, ab, wb, hid, ncls) = vals
    cfg = ApLbpConfig(height=h, width=w, in_channels=c, n_lbp_layers=n_lbp,
                      kernels_per_layer=k, e=e, window=win, apx_code=apx_c,
                      apx_pixel=apx_p, pool=pool, hidden=hid, n_classes=ncls,
                      act_bits=ab, w_bits=wb)
    layers = []
    for _ in range(n_lbp):
        offs = np.frombuffer(take(k * e * 3 * 4), dtype="<i4").reshape(k, e, 3)
        piv = np.frombuffer(take(k * 4), dtype="<i4")
        layers.append(LbpLayerParams(offsets=offs.copy(), pivot_ch=piv.copy()))
    mlps = []
    for _ in range(2):
        d, o = struct.unpack("<2I", take(8))
        w_int = np.frombuffer(take(d * o), dtype="i1").reshape(d, o)
        scale = np.frombuffer(take(o * 4), dtype="<f4")
        bias = np.frombuffer(take(o * 4), dtype="<f4")
        mlps.append(MlpLayerParams(w_int=w_int.copy(), scale=scale.copy(),
                                   bias=bias.copy()))
    assert off == len(data), "trailing bytes in params file"
    return ApLbpParams(config=cfg, lbp_layers=layers, mlp1=mlps[0], mlp2=mlps[1])
