"""AOT compile path: lower the L2/L1 stack to HLO text for the Rust runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the runtime's XLA
(xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to ``artifacts/`` (all consumed by rust/src/runtime):

* ``aplbp_<ds>.hlo.txt``       — full inference: images f32[B,H,W,C] → logits
                                 f32[B,10]; params baked in as constants;
                                 Pallas kernels lowered inside (interpret=True).
* ``features_<ds>.hlo.txt``    — LBP front-end only: images → pooled int32
                                 features (golden model for the architectural
                                 simulator cross-check).
* ``lbp_encode_unit.hlo.txt``  — the L1 LBP kernel alone: (256,8)+(256,) i32
                                 → (256,) i32 codes.
* ``bitserial_unit.hlo.txt``   — the L1 bit-serial matmul alone:
                                 (32,64)+(64,128) i32 → (32,128) i32.
* ``<ds>.params.bin``          — network parameters for the architectural
                                 path (model.save_params format).
* ``manifest.tsv``             — name, file, input shapes, output shape.

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import functools
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as m
from .kernels.lbp_encode import lbp_encode
from .kernels.bitserial_mlp import bitserial_matmul

DEFAULT_BATCH = 4


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible route).

    Guards against silent constant elision: XLA's text printer replaces
    large dense constants with ``constant({...})``, which would round-trip
    as garbage.  All big tensors (MLP weights/affines) are therefore passed
    as *parameters* (see ``export_dataset``) and this check enforces it.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    text = comp.as_hlo_text()
    if "constant({...})" in text:
        raise RuntimeError(
            "HLO text contains an elided large constant; pass the tensor "
            "as a parameter instead")
    return text


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1024:.0f} KiB)")


def export_dataset(ds: str, out_dir: str, batch: int, apx: int,
                   manifest: list[str], params_path: str | None = None) -> None:
    """Lower full-inference + features-only graphs for one dataset config."""
    if params_path and os.path.exists(params_path):
        params = m.load_params(params_path)
        print(f"[{ds}] using trained params from {params_path}")
    else:
        params = m.init_params(m.config_for(ds, apx=apx))
    cfg = params.config
    spec = jax.ShapeDtypeStruct((batch, cfg.height, cfg.width,
                                 cfg.in_channels), jnp.float32)

    # MLP weights/affines are runtime *parameters* (HLO text elides large
    # constants — see to_hlo_text); the Rust runtime feeds them from
    # <ds>.params.bin in this exact order.
    def full_fn(images, w1, s1, b1, w2, s2, b2):
        p = m.ApLbpParams(
            config=cfg,
            lbp_layers=params.lbp_layers,
            mlp1=m.MlpLayerParams(w_int=w1, scale=s1, bias=b1),
            mlp2=m.MlpLayerParams(w_int=w2, scale=s2, bias=b2),
        )
        return m.apply(p, images, use_pallas=True)

    def shape_of(a, dt):
        return jax.ShapeDtypeStruct(a.shape, dt)

    w_specs = [
        shape_of(params.mlp1.w_int, jnp.int32),
        shape_of(params.mlp1.scale, jnp.float32),
        shape_of(params.mlp1.bias, jnp.float32),
        shape_of(params.mlp2.w_int, jnp.int32),
        shape_of(params.mlp2.scale, jnp.float32),
        shape_of(params.mlp2.bias, jnp.float32),
    ]
    full = jax.jit(full_fn)
    _write(os.path.join(out_dir, f"aplbp_{ds}.hlo.txt"),
           to_hlo_text(full.lower(spec, *w_specs)))
    d1, hid = params.mlp1.w_int.shape
    ncls = cfg.n_classes
    manifest.append(
        f"aplbp_{ds}\taplbp_{ds}.hlo.txt\t"
        f"f32[{batch},{cfg.height},{cfg.width},{cfg.in_channels}];"
        f"s32[{d1},{hid}];f32[{hid}];f32[{hid}];"
        f"s32[{hid},{ncls}];f32[{ncls}];f32[{ncls}]\t"
        f"f32[{batch},{cfg.n_classes}]")

    feats = jax.jit(functools.partial(m.forward_lbp, params, use_pallas=True))
    _write(os.path.join(out_dir, f"features_{ds}.hlo.txt"),
           to_hlo_text(feats.lower(spec)))
    manifest.append(f"features_{ds}\tfeatures_{ds}.hlo.txt\t"
                    f"f32[{batch},{cfg.height},{cfg.width},{cfg.in_channels}]\t"
                    f"s32[{batch},{cfg.feature_dim}]")

    pbin = os.path.join(out_dir, f"{ds}.params.bin")
    m.save_params(params, pbin)
    print(f"  wrote {pbin}")
    manifest.append(f"params_{ds}\t{ds}.params.bin\t-\t-")


def export_units(out_dir: str, manifest: list[str]) -> None:
    """Standalone kernel artifacts for runtime unit tests."""
    n_spec = jax.ShapeDtypeStruct((256, 8), jnp.int32)
    c_spec = jax.ShapeDtypeStruct((256,), jnp.int32)
    enc = jax.jit(functools.partial(lbp_encode, apx=0))
    _write(os.path.join(out_dir, "lbp_encode_unit.hlo.txt"),
           to_hlo_text(enc.lower(n_spec, c_spec)))
    manifest.append("lbp_encode_unit\tlbp_encode_unit.hlo.txt\t"
                    "s32[256,8];s32[256]\ts32[256]")

    x_spec = jax.ShapeDtypeStruct((32, 64), jnp.int32)
    w_spec = jax.ShapeDtypeStruct((64, 128), jnp.int32)
    bs = jax.jit(functools.partial(bitserial_matmul, act_bits=4, w_bits=4))
    _write(os.path.join(out_dir, "bitserial_unit.hlo.txt"),
           to_hlo_text(bs.lower(x_spec, w_spec)))
    manifest.append("bitserial_unit\tbitserial_unit.hlo.txt\t"
                    "s32[32,64];s32[64,128]\ts32[32,128]")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--apx", type=int, default=2,
                    help="PAC approximated bits baked into the exported model "
                         "(paper's optimum: 2)")
    ap.add_argument("--datasets", nargs="+", default=["mnist", "svhn"])
    ap.add_argument("--trained-dir", default=None,
                    help="directory with trained <ds>_apx<N>.params.bin to "
                         "bake in instead of deterministic init")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest: list[str] = []
    for ds in args.datasets:
        tp = (os.path.join(args.trained_dir, f"{ds}_apx{args.apx}.params.bin")
              if args.trained_dir else None)
        export_dataset(ds, args.out_dir, args.batch, args.apx, manifest, tp)
    export_units(args.out_dir, manifest)

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("name\tfile\tinputs\toutput\n")
        f.write("\n".join(manifest) + "\n")
    print(f"  wrote {os.path.join(args.out_dir, 'manifest.tsv')}")


if __name__ == "__main__":
    main()
