"""Procedural stand-ins for MNIST / FashionMNIST / SVHN.

The evaluation environment has no network access, so the real corpora are
unavailable.  Per the substitution rule (DESIGN.md §Substitutions) we
generate deterministic, procedurally rendered look-alikes that preserve the
properties the paper's evaluation depends on:

* same tensor shapes (28x28x1 for the MNIST pair, 32x32x3 for SVHN-like),
* 10 balanced classes,
* intra-class variation (affine jitter, stroke-width, noise, distractors)
  so that model *capacity ordering* is exercised: a full-precision CNN
  should beat binarized nets, which should beat LBP nets, and Ap-LBP's
  accuracy should fall monotonically with the number of approximated bits.

If real IDX/NPZ files are placed under ``data/<name>/`` they are used
instead (``load_dataset`` probes for them first).
"""

from __future__ import annotations

import os
import numpy as np

# ----------------------------------------------------------------------------
# 5x7 bitmap glyphs for digits 0-9 (classic font), rows top->bottom.
# ----------------------------------------------------------------------------
_GLYPHS = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}

# 8x8 coarse silhouettes for the 10 FashionMNIST classes (t-shirt, trouser,
# pullover, dress, coat, sandal, shirt, sneaker, bag, ankle-boot).
_FASHION = [
    ["00000000", "11100111", "11111111", "01111110", "01111110", "01111110", "01111110", "00000000"],
    ["00111100", "00111100", "00111100", "00100100", "00100100", "00100100", "00100100", "00100100"],
    ["01100110", "11111111", "11111111", "01111110", "01111110", "01111110", "01111110", "01111110"],
    ["00111100", "00111100", "00111100", "00111100", "01111110", "01111110", "11111111", "11111111"],
    ["11100111", "11111111", "11111111", "11111111", "01111110", "01111110", "01111110", "01111110"],
    ["00000000", "00000000", "00000011", "00001110", "00111000", "11100000", "11111111", "00000000"],
    ["01100110", "11111111", "11011011", "01111110", "01011010", "01111110", "01011010", "01111110"],
    ["00000000", "00000000", "00000110", "00011110", "01111110", "11111111", "11111110", "00000000"],
    ["00111100", "01000010", "11111111", "10000001", "10000001", "10000001", "11111111", "00000000"],
    ["00011110", "00011110", "00011110", "00011110", "00111110", "01111110", "11111100", "11111100"],
]


def _render_glyph(rows: list[str]) -> np.ndarray:
    g = np.array([[int(c) for c in r] for r in rows], dtype=np.float32)
    return g


def _place(canvas: np.ndarray, glyph: np.ndarray, cy: int, cx: int, scale: int,
           value: float) -> None:
    """Nearest-neighbour upscale ``glyph`` by ``scale`` and stamp onto canvas."""
    g = np.kron(glyph, np.ones((scale, scale), dtype=np.float32)) * value
    h, w = g.shape
    H, W = canvas.shape
    y0, x0 = cy - h // 2, cx - w // 2
    ys0, xs0 = max(0, -y0), max(0, -x0)
    y0, x0 = max(0, y0), max(0, x0)
    y1, x1 = min(H, y0 + h - ys0), min(W, x0 + w - xs0)
    if y1 <= y0 or x1 <= x0:
        return
    patch = g[ys0:ys0 + (y1 - y0), xs0:xs0 + (x1 - x0)]
    canvas[y0:y1, x0:x1] = np.maximum(canvas[y0:y1, x0:x1], patch)


def _jitter(img: np.ndarray, rng: np.random.Generator, max_shift: int = 2) -> np.ndarray:
    dy, dx = rng.integers(-max_shift, max_shift + 1, size=2)
    out = np.zeros_like(img)
    H, W = img.shape[:2]
    ys, yd = (dy, 0) if dy >= 0 else (0, -dy)
    xs, xd = (dx, 0) if dx >= 0 else (0, -dx)
    out[yd:H - ys, xd:W - xs, ...] = img[ys:H - yd, xs:W - xd, ...]
    return out


def _make_mnist_like(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, 28, 28, 1), dtype=np.float32)
    ys = (np.arange(n) % 10).astype(np.int32)
    rng.shuffle(ys)
    for i in range(n):
        canvas = np.zeros((28, 28), dtype=np.float32)
        scale = int(rng.integers(3, 5))  # 3 or 4 -> glyph 15x9..28x20
        cy = 14 + int(rng.integers(-2, 3))
        cx = 14 + int(rng.integers(-2, 3))
        value = float(rng.uniform(0.75, 1.0))
        _place(canvas, _render_glyph(_GLYPHS[int(ys[i])]), cy, cx, scale, value)
        canvas += rng.normal(0.0, 0.025, size=canvas.shape).astype(np.float32)
        xs[i, :, :, 0] = np.clip(canvas, 0.0, 1.0)
        xs[i] = _jitter(xs[i], rng)
    return xs, ys


def _make_fashion_like(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, 28, 28, 1), dtype=np.float32)
    ys = (np.arange(n) % 10).astype(np.int32)
    rng.shuffle(ys)
    for i in range(n):
        canvas = np.zeros((28, 28), dtype=np.float32)
        sil = _render_glyph(_FASHION[int(ys[i])])
        value = float(rng.uniform(0.55, 0.95))
        _place(canvas, sil, 14 + int(rng.integers(-1, 2)),
               14 + int(rng.integers(-1, 2)), 3, value)
        # fabric texture: low-amplitude sinusoid modulated by class parity
        yy, xx = np.mgrid[0:28, 0:28].astype(np.float32)
        tex = 0.08 * np.sin(yy / (1.5 + ys[i] % 3) + rng.uniform(0, 3.14)) \
            * (canvas > 0)
        canvas = canvas + tex + rng.normal(0.0, 0.03, canvas.shape).astype(np.float32)
        xs[i, :, :, 0] = np.clip(canvas, 0.0, 1.0)
        xs[i] = _jitter(xs[i], rng)
    return xs, ys


def _make_svhn_like(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, 32, 32, 3), dtype=np.float32)
    ys = (np.arange(n) % 10).astype(np.int32)
    rng.shuffle(ys)
    yy, xx = np.mgrid[0:32, 0:32].astype(np.float32)
    for i in range(n):
        # textured house-facade background
        bg = rng.uniform(0.2, 0.6, size=3).astype(np.float32)
        img = np.ones((32, 32, 3), dtype=np.float32) * bg
        img += 0.06 * np.sin(xx / rng.uniform(2, 6) + rng.uniform(0, 6.28))[..., None]
        # central digit in a contrasting colour
        digit = np.zeros((32, 32), dtype=np.float32)
        scale = int(rng.integers(3, 5))
        _place(digit, _render_glyph(_GLYPHS[int(ys[i])]),
               16 + int(rng.integers(-3, 4)), 16 + int(rng.integers(-3, 4)),
               scale, 1.0)
        fg = rng.uniform(0.0, 1.0, size=3).astype(np.float32)
        while np.abs(fg - bg).sum() < 0.9:  # ensure contrast
            fg = rng.uniform(0.0, 1.0, size=3).astype(np.float32)
        img = img * (1 - digit[..., None]) + fg * digit[..., None]
        # distractor digit fragments at the borders (SVHN crops overlap)
        for _ in range(int(rng.integers(0, 3))):
            d2 = np.zeros((32, 32), dtype=np.float32)
            _place(d2, _render_glyph(_GLYPHS[int(rng.integers(0, 10))]),
                   int(rng.integers(0, 32)),
                   int(rng.choice([2, 30])), 3, 1.0)
            img = img * (1 - 0.7 * d2[..., None]) + fg * 0.7 * d2[..., None]
        img += rng.normal(0.0, 0.025, img.shape).astype(np.float32)
        xs[i] = np.clip(img, 0.0, 1.0)
    return xs, ys


_MAKERS = {
    "mnist": _make_mnist_like,
    "fashionmnist": _make_fashion_like,
    "svhn": _make_svhn_like,
}

SHAPES = {
    "mnist": (28, 28, 1),
    "fashionmnist": (28, 28, 1),
    "svhn": (32, 32, 3),
}


def load_dataset(name: str, n_train: int = 4000, n_test: int = 1000,
                 seed: int = 7, data_dir: str | None = None):
    """Return ``(x_train, y_train, x_test, y_test)`` float32 in [0,1] / int32.

    Prefers real data from ``data/<name>.npz`` (keys x_train/y_train/
    x_test/y_test) when present; otherwise generates the procedural
    look-alike.  Train/test use disjoint seeds so memorisation of the
    generator is impossible.
    """
    name = name.lower()
    if name not in _MAKERS:
        raise ValueError(f"unknown dataset {name!r}; options: {sorted(_MAKERS)}")
    data_dir = data_dir or os.environ.get("NSLBP_DATA_DIR", "data")
    npz = os.path.join(data_dir, f"{name}.npz")
    if os.path.exists(npz):
        z = np.load(npz)
        return (z["x_train"][:n_train].astype(np.float32),
                z["y_train"][:n_train].astype(np.int32),
                z["x_test"][:n_test].astype(np.float32),
                z["y_test"][:n_test].astype(np.int32))
    mk = _MAKERS[name]
    x_tr, y_tr = mk(n_train, seed)
    x_te, y_te = mk(n_test, seed + 7919)
    return x_tr, y_tr, x_te, y_te


def quantize_u8(x: np.ndarray) -> np.ndarray:
    """Sensor ADC model: [0,1] float -> 8-bit pixel."""
    return np.clip(np.round(x * 255.0), 0, 255).astype(np.uint8)
