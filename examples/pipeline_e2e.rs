//! End-to-end driver (DESIGN.md §Experiment index): the full near-sensor
//! system on a real small workload, proving all layers compose.
//!
//! * renders a procedurally generated digit workload (the same glyph
//!   generator as `python/compile/data.py`, so trained parameters
//!   transfer),
//! * digitizes it through the CDS + LSB-skipping ADC sensor model,
//! * classifies every frame with the **architectural path** — Algorithm-1
//!   LBP comparisons and the in-memory bit-serial MLP on simulated compute
//!   sub-arrays — cross-checked against the functional model on every
//!   frame,
//! * golden-checks one batch against the AOT JAX/Pallas artifact on PJRT,
//! * reports accuracy, modeled latency/throughput, energy per frame, and
//!   the paper's headline TOPS/W.
//!
//! Uses trained parameters (`make train`, artifacts/mnist_apx2.params.bin)
//! when present; otherwise falls back to the deterministic untrained set
//! (pipeline still validates, accuracy is chance).
//!
//! ```bash
//! make artifacts && cargo run --release --example pipeline_e2e
//! ```

use ns_lbp::coordinator::{ArchSim, Coordinator, CoordinatorConfig};
use ns_lbp::energy::EnergyModel;
use ns_lbp::engine::{BackendKind, Engine};
use ns_lbp::params;
use ns_lbp::rng::Xoshiro256;
use ns_lbp::sensor::{FrameSource, ReplaySensor, SensorConfig};

const FRAMES: usize = 64;

/// 5x7 digit glyphs — identical to python/compile/data.py.
const GLYPHS: [[&str; 7]; 10] = [
    ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],
    ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
];

/// Render one 28x28 digit scene with jitter — mirrors data._make_mnist_like
/// closely enough that trained parameters transfer.
fn render_digit(digit: usize, rng: &mut Xoshiro256) -> Vec<f64> {
    let mut canvas = vec![0.0f64; 28 * 28];
    let scale = rng.range_i64(3, 4) as usize;
    let cy = (14 + rng.range_i64(-2, 2)) as i64;
    let cx = (14 + rng.range_i64(-2, 2)) as i64;
    let value = rng.range_f64(0.75, 1.0);
    let (gh, gw) = (7 * scale, 5 * scale);
    let y0 = cy - gh as i64 / 2;
    let x0 = cx - gw as i64 / 2;
    for gy in 0..7 {
        for gx in 0..5 {
            if GLYPHS[digit][gy].as_bytes()[gx] == b'1' {
                for sy in 0..scale {
                    for sx in 0..scale {
                        let y = y0 + (gy * scale + sy) as i64;
                        let x = x0 + (gx * scale + sx) as i64;
                        if (0..28).contains(&y) && (0..28).contains(&x) {
                            canvas[(y * 28 + x) as usize] = value;
                        }
                    }
                }
            }
        }
    }
    for v in canvas.iter_mut() {
        *v = (*v + rng.gauss_ms(0.0, 0.025)).clamp(0.0, 1.0);
    }
    canvas
}

fn main() -> ns_lbp::Result<()> {
    // --- parameters: trained if available ---------------------------------
    let (params, trained) = match params::load("artifacts/mnist_apx2.params.bin") {
        Ok(p) => (p, true),
        Err(_) => (params::load("artifacts/mnist.params.bin")?, false),
    };
    let cfg = params.config;
    println!(
        "Ap-LBP ({}) | {}x{}x{} | {} LBP layers | apx_code {} apx_pixel {}",
        if trained { "trained" } else { "untrained fallback — run `make train`" },
        cfg.height, cfg.width, cfg.in_channels, cfg.n_lbp_layers,
        cfg.apx_code, cfg.apx_pixel
    );

    // --- workload ----------------------------------------------------------
    let mut rng = Xoshiro256::new(2024);
    let mut labels = Vec::with_capacity(FRAMES);
    let mut scenes = Vec::with_capacity(FRAMES);
    for i in 0..FRAMES {
        let digit = i % 10;
        labels.push(digit);
        scenes.push(render_digit(digit, &mut rng));
    }

    // --- sensor + coordinator (full architectural simulation) --------------
    let scfg = SensorConfig {
        rows: cfg.height, cols: cfg.width, channels: cfg.in_channels,
        skip_lsbs: cfg.apx_pixel, ..Default::default()
    };
    let mut sensor = ReplaySensor::new(scfg, scenes.clone(), 11)?;
    let mut frames = Vec::with_capacity(FRAMES);
    while let Some(f) = sensor.next_frame() {
        frames.push(f);
    }
    let coord = Coordinator::new(
        params.clone(),
        CoordinatorConfig {
            arch: ArchSim { lbp: true, mlp: true, early_exit: false },
            ..Default::default()
        },
    )?;
    let t0 = std::time::Instant::now();
    let (reports, summary) = coord.run_frames(&frames)?;
    let wall = t0.elapsed();

    if summary.arch_mismatches != 0 {
        return Err(ns_lbp::Error::Coordinator(
            "architectural/functional divergence!".into(),
        ));
    }
    let correct = reports.iter().zip(&labels)
        .filter(|(r, &l)| r.predicted == l)
        .count();

    // --- golden check: one batch through the PJRT engine backend ------------
    // (skipped gracefully when the HLO artifact or the PJRT backend —
    // cargo feature `pjrt` — is unavailable; the engine's capabilities
    // probe turns that into one early error instead of a late failure)
    let golden_engine = Engine::builder()
        .config(coord.config.clone())
        .params(params.clone())
        .backend(BackendKind::Pjrt)
        .no_cross_check()
        .artifact("aplbp_mnist")
        .build();
    let golden = match golden_engine {
        Ok(mut engine) => {
            // feed the *digitized* frames so PJRT sees exactly what the
            // simulator saw (the sensor is deterministic and noise adds
            // only what CDS leaves, which is 0 here)
            let out = engine.infer_batch(&frames[..4])?;
            let mut golden_ok = true;
            for (o, r) in out.frames.iter().zip(&reports) {
                if o.predicted != r.predicted {
                    golden_ok = false;
                    eprintln!("golden mismatch on frame {}: pjrt {} vs sim {}",
                              o.seq, o.predicted, r.predicted);
                }
            }
            if !golden_ok {
                return Err(ns_lbp::Error::Runtime(
                    "PJRT golden check failed".into(),
                ));
            }
            "OK on batch of 4".to_string()
        }
        Err(e) => format!("skipped ({e})"),
    };

    // --- report --------------------------------------------------------------
    let em = EnergyModel::default();
    println!("\n== END-TO-END REPORT ==");
    println!("frames             : {FRAMES}");
    println!("accuracy           : {:.1}% ({} / {FRAMES}){}",
             100.0 * correct as f64 / FRAMES as f64, correct,
             if trained { "" } else { "  [untrained params — chance level]" });
    println!("golden (PJRT)      : {golden}");
    println!("arch mismatches    : {}", summary.arch_mismatches);
    println!("energy / frame     : {:.2} µJ", summary.energy_per_frame_uj());
    println!("modeled latency    : {:.2} µs/frame",
             summary.total_arch_time_ns / 1e3 / FRAMES as f64);
    println!("modeled throughput : {:.0} fps",
             summary.frames_per_second_modeled());
    println!("peak efficiency    : {:.1} TOPS/W (paper: 37.4)",
             em.tops_per_watt(256));
    println!("host wall clock    : {:.2} s ({:.1} ms/frame simulated)",
             wall.as_secs_f64(), wall.as_secs_f64() * 1e3 / FRAMES as f64);
    Ok(())
}
