//! QoS-routed serving: two sensor streams with different service
//! classes fan into one server — always-on best-effort pixels ride the
//! cheap functional path with drop-oldest admission, billed frames ride
//! the fully accounted architectural path — and the final report breaks
//! latency and drop/reject counts down per class.
//!
//! ```bash
//! cargo run --release --example serve_qos
//! ```

use std::time::Duration;

use ns_lbp::coordinator::{ArchSim, CoordinatorConfig};
use ns_lbp::engine::{BackendKind, QosClass};
use ns_lbp::params;
use ns_lbp::serve::Server;
use ns_lbp::testing::synth_frames;

fn main() -> ns_lbp::Result<()> {
    // 1. network parameters (synthetic fallback keeps the example
    //    runnable from a bare checkout)
    let params = match params::load("artifacts/mnist.params.bin") {
        Ok(p) => p,
        Err(_) => {
            println!("artifacts missing — using a synthetic network \
                      (run `make artifacts` for the real one)");
            params::synth::synth_params(7).1
        }
    };

    // 2. a server with class-differentiated routing: best-effort pixels
    //    on the functional path, billed output on the architectural one
    let mut config = CoordinatorConfig {
        arch: ArchSim { lbp: true, mlp: false, early_exit: false },
        ..Default::default()
    };
    config.system.serve.shards = 2;
    config.system.serve.max_batch = 8;
    config.system.serve.queue_depth = 128;
    config.system.engine.routing
        .set(QosClass::BestEffort, BackendKind::Functional);
    config.system.engine.routing
        .set(QosClass::Billed, BackendKind::Architectural);
    let server = Server::start(params.clone(), config)?;

    // 3. two sensor streams, each with its own session (and therefore
    //    its own sequence space), different classes and freshness bounds
    let doorbell = server
        .session(0)
        .with_class(QosClass::BestEffort)
        .with_deadline(Duration::from_millis(50)); // stale pixels are waste
    let turnstile = server.session(1).with_class(QosClass::Billed);

    let frames = synth_frames(&params, 32, 42)?;
    let mut tickets = Vec::new();
    for frame in &frames {
        tickets.push(doorbell.submit(frame.clone())?);
        tickets.push(turnstile.submit(frame.clone())?);
    }
    drop(doorbell);
    drop(turnstile);

    // 4. tickets resolve to typed responses (or drop errors for shed
    //    best-effort frames); wait_timeout bounds every wait
    let mut shed = 0u32;
    for ticket in tickets {
        match ticket.wait_timeout(Duration::from_secs(10)) {
            Some(Ok(r)) => {
                if r.seq() < 2 {
                    println!(
                        "sensor {} seq {} [{} → {}]: predicted {} in \
                         {:.2} ms (batch of {}, shard {})",
                        r.sensor_id, r.seq(), r.class, r.backend,
                        r.predicted(), r.latency.as_secs_f64() * 1e3,
                        r.batch_size, r.shard
                    );
                }
            }
            Some(Err(ns_lbp::Error::Dropped(_))) => shed += 1,
            Some(Err(e)) => println!("serve error: {e}"),
            None => println!("a ticket timed out (wedged shard?)"),
        }
    }
    if shed > 0 {
        println!("{shed} best-effort frames shed (drop-oldest/deadline)");
    }

    // 5. the drained report carries the per-class breakdown
    let report = server.drain()?;
    report.print("qos-routed example");
    Ok(())
}
