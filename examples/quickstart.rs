//! Quickstart: load the Ap-LBP network, stream a few frames through the
//! near-sensor pipeline, print classifications and the energy/latency
//! account.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ns_lbp::coordinator::{ArchSim, Coordinator, CoordinatorConfig};
use ns_lbp::params;
use ns_lbp::rng::Xoshiro256;
use ns_lbp::sensor::{ReplaySensor, SensorConfig};

fn main() -> ns_lbp::Result<()> {
    // 1. network parameters exported by `make artifacts` (deterministic
    //    synthetic fallback keeps the example runnable from a bare checkout)
    let params = match params::load("artifacts/mnist.params.bin") {
        Ok(p) => p,
        Err(_) => {
            println!("artifacts missing — using a synthetic network \
                      (run `make artifacts` for the real one)");
            params::synth::synth_params(7).1
        }
    };
    let cfg = params.config;
    println!(
        "Ap-LBP: {}x{}x{} input, {} LBP layers (K={}, e={}), apx={}, {} hidden",
        cfg.height, cfg.width, cfg.in_channels, cfg.n_lbp_layers,
        cfg.kernels_per_layer, cfg.e, cfg.apx_code, cfg.hidden
    );

    // 2. a sensor replaying synthetic radiance maps
    let scfg = SensorConfig {
        rows: cfg.height,
        cols: cfg.width,
        channels: cfg.in_channels,
        skip_lsbs: cfg.apx_pixel,
        ..Default::default()
    };
    let mut rng = Xoshiro256::new(42);
    let scenes: Vec<Vec<f64>> = (0..4)
        .map(|_| (0..scfg.pixels()).map(|_| rng.next_f64()).collect())
        .collect();
    let mut sensor = ReplaySensor::new(scfg, scenes, 7)?;

    // 3. the coordinator: in-memory LBP (Algorithm 1) on simulated
    //    sub-arrays, functional MLP, full cross-checking
    let coord = Coordinator::new(
        params,
        CoordinatorConfig { arch: ArchSim::default(), ..Default::default() },
    )?;
    let (reports, summary) = coord.run(&mut sensor, 4)?;

    for r in &reports {
        println!(
            "frame {}: class {} | {} ISA instrs | {:.2} µJ | {:.2} µs modeled",
            r.seq, r.predicted, r.telemetry.exec.instructions,
            r.telemetry.cost.energy.total_pj() / 1e6,
            r.telemetry.cost.time_ns / 1e3
        );
    }
    println!(
        "\n{} frames, {} arch/functional mismatches (must be 0)",
        summary.frames, summary.arch_mismatches
    );
    println!(
        "energy {:.2} µJ/frame | modeled throughput {:.0} fps",
        summary.energy_per_frame_uj(),
        summary.frames_per_second_modeled()
    );
    Ok(())
}
