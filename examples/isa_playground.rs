//! ISA playground: assemble a Table-2 program, run it on a compute
//! sub-array, and inspect the execution/energy statistics — the
//! "programmer's view" of NS-LBP as a third-party accelerator.
//!
//! ```bash
//! cargo run --release --example isa_playground
//! ```

use ns_lbp::energy::EnergyModel;
use ns_lbp::isa::{assemble, Executor};
use ns_lbp::sram::SubArray;

const PROGRAM: &str = r#"
; in-memory 1-bit full adder over rows 0,1,2 -> sum in r10, carry in r11
ini r10, zeros
ini r11, zeros
sum r0 r1 r2 -> r10
carry r0 r1 r2 -> r11
; 2-input ops via constant rows (r8 = all-ones, r9 = all-zeros)
ini r8, ones
ini r9, zeros
cmp r0 r1 -> r12          ; XOR2
search r0 k1 -> r13       ; XNOR (equality search against key row 1)
carry r0 r1 r9 -> r14     ; AND2 = MAJ3(a, b, 0)
carry r0 r1 r8 -> r15     ; OR2  = MAJ3(a, b, 1)
"#;

fn main() -> ns_lbp::Result<()> {
    let program = assemble(PROGRAM)?;
    println!("assembled {} instructions:", program.len());
    for inst in &program {
        println!("  {inst}");
    }

    let mut sa = SubArray::new(256, 256);
    // operand rows: three walking bit patterns
    let a = 0b1010_1100_0011_0101u64;
    let b = 0b0110_0110_1111_0000u64;
    let c = 0b1111_0000_1010_1010u64;
    for (row, v) in [(0, a), (1, b), (2, c)] {
        let mut words = vec![0u64; 4];
        words[0] = v;
        sa.write_row(row, &words)?;
    }

    let mut ex = Executor::new(&mut sa);
    ex.run(&program)?;

    println!("\nresults (low 16 bits per destination row):");
    for (name, row, expect) in [
        ("SUM  ", 10, a ^ b ^ c),
        ("CARRY", 11, (a & b) | (a & c) | (b & c)),
        ("XOR2 ", 12, a ^ b),
        ("XNOR ", 13, !(a ^ b)),
        ("AND2 ", 14, a & b),
        ("OR2  ", 15, a | b),
    ] {
        let got = ex.array.read_row(row)?[0];
        println!("  {name} r{row:<2} = {:016b} (expect {:016b})",
                 got & 0xFFFF, expect & 0xFFFF);
        assert_eq!(got, expect, "{name}");
    }

    let em = EnergyModel::default();
    let e = em.exec_energy(&ex.stats);
    println!("\nstats: {} instrs, {} cycles, {} compute ops, {} writes",
             ex.stats.instructions, ex.stats.cycles, ex.stats.compute_ops,
             ex.stats.row_writes);
    println!("energy: {:.1} pJ total ({:.1} compute / {:.1} write / {:.1} ctrl)",
             e.total_pj(), e.compute_pj, e.write_pj, e.ctrl_pj);
    println!("latency: {:.1} ns at {} GHz", em.exec_time_ns(&ex.stats),
             em.params.freq_ghz);
    Ok(())
}
