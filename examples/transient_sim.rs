//! Circuit-level showcase (paper §6.2): RBL discharge transients for all
//! input combinations (Fig. 9), the three-reference SA decisions, the
//! capacitive-majority XOR3, and a Monte-Carlo margin sweep over VDD
//! (Fig. 10's "lower voltages shrink the V_Ref window" observation).
//!
//! ```bash
//! cargo run --release --example transient_sim
//! ```

use ns_lbp::circuit::{sense, CircuitParams, MonteCarlo, SENSE_DELAY_PS};

fn main() -> ns_lbp::Result<()> {
    let p = CircuitParams::default();
    p.validate()?;

    // --- Fig. 9: transient waveforms ------------------------------------
    println!("== RBL discharge transients (VDD {} V) ==", p.vdd);
    println!("{:>7} {:>8} {:>8} {:>8} {:>8}", "t[ps]", "\"000\"", "\"001\"",
             "\"011\"", "\"111\"");
    let mut t = 0.0;
    while t <= 800.0 {
        print!("{t:>7.0}");
        for ones in 0..=3 {
            print!(" {:8.3}", p.rbl_waveform(ones, t)?);
        }
        println!();
        t += 50.0;
    }
    let [r1, r2, r3] = p.refs();
    println!("references: V_R1 {r1:.3} V | V_R2 {r2:.3} V | V_R3 {r3:.3} V");
    println!("SA strobe at {SENSE_DELAY_PS} ps (cycle {} ps)\n", p.cycle_ps());

    // --- single-cycle logic outputs --------------------------------------
    println!("== SA decisions per activated-ones count ==");
    println!("{:>5} {:>6} {:>6} {:>6} {:>6} {:>6} {:>6}", "ones", "OR3",
             "MAJ3", "AND3", "NOR3", "NAND3", "XOR3");
    for ones in 0..=3 {
        let sa = sense(&p, ones, 0.0)?;
        println!(
            "{ones:>5} {:>6} {:>6} {:>6} {:>6} {:>6} {:>6}",
            sa.or3 as u8, sa.maj3 as u8, sa.and3 as u8, sa.nor3() as u8,
            sa.nand3() as u8, sa.xor3() as u8
        );
    }

    // --- Fig. 10: Monte-Carlo margins vs VDD ------------------------------
    println!("\n== Monte-Carlo V_Ref windows vs VDD (200 x 256 samples) ==");
    println!("{:>6} {:>12} {:>12} {:>12} {:>10}", "VDD", "gap 000/001",
             "gap 001/011", "gap 011/111", "min [mV]");
    for vdd in [0.9, 1.0, 1.1] {
        let params = CircuitParams { vdd, ..CircuitParams::default() };
        let r = MonteCarlo::new(params).run(7);
        println!(
            "{vdd:>6.1} {:>12.1} {:>12.1} {:>12.1} {:>10.1}",
            r.level_gaps[0] * 1e3, r.level_gaps[1] * 1e3,
            r.level_gaps[2] * 1e3, r.min_margin * 1e3
        );
        assert_eq!(r.decision_error_rate, 0.0);
    }
    println!("\npaper: ~92 mV minimum margin at 1.1 V — reproduced above.");
    Ok(())
}
