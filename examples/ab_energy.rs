//! A/B energy harness walkthrough: price the same frames under two
//! hardware profiles and diff the result (the library form of
//! `ns-lbp ab --profile A --profile B`).
//!
//! Run with: `cargo run --example ab_energy`

use ns_lbp::coordinator::{ArchSim, CoordinatorConfig};
use ns_lbp::hw::{ab::AbHarness, CostModel, HwProfile};
use ns_lbp::params::synth::synth_params;
use ns_lbp::testing::synth_frames;

fn main() -> ns_lbp::Result<()> {
    // a synthetic network + workload (swap in `params::load(...)` for a
    // real artifact)
    let (_, params) = synth_params(7);
    let frames = synth_frames(&params, 8, 7)?;

    // arm A: the paper's 65 nm NS-LBP point; arm B: the prior-generation
    // 28 nm compute-SRAM.  Any profile works here — a builtin name via
    // HwProfile::resolve("..."), a configs/profiles/*.toml path, or a
    // hand-built HwProfile value.
    let a = HwProfile::ns_lbp_65nm();
    let b = HwProfile::sram38_28nm();
    println!(
        "A = {} ({:.2} GHz, {:.1} TOPS/W) vs B = {} ({:.2} GHz, {:.1} TOPS/W)\n",
        a.name, a.energy.freq_ghz, a.tops_per_watt(256),
        b.name, b.energy.freq_ghz, b.tops_per_watt(256),
    );

    let config = CoordinatorConfig {
        arch: ArchSim { lbp: true, mlp: true, early_exit: false },
        ..Default::default()
    };
    let harness = AbHarness::new(params, config, a, b)?;
    let report = harness.run(&frames)?;
    report.print();

    println!("\nmachine-readable: {}", report.to_json());
    Ok(())
}
