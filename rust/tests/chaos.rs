//! Integration tests for the deterministic fault-injection plane: end
//! to end panic isolation in the serve plane, node-flap recovery in the
//! fleet, and schedule determinism.
//!
//! These live in their own integration binary (not unit tests) because
//! the injected-panic token is process-wide: a unit test panicking a
//! shard would race every other `#[test]` sharing the library test
//! process.

use ns_lbp::config::SystemConfig;
use ns_lbp::engine::{ArchSim, BackendKind, EngineConfig, QosClass};
use ns_lbp::faults::{
    artifact_corruption, reset_panic_token, BitFlips, FaultPlan,
    FaultyTransport,
};
use ns_lbp::fleet::{ChannelTransport, Fleet};
use ns_lbp::params::synth::synth_params;
use ns_lbp::serve::{Request, Server};
use ns_lbp::testing::synth_frames;

fn quiet_system() -> SystemConfig {
    let mut system = SystemConfig::default();
    system.engine.backend = BackendKind::Functional;
    system.engine.cross_check = None;
    system
}

fn engine_config(system: &SystemConfig) -> EngineConfig {
    EngineConfig {
        system: system.clone(),
        arch: ArchSim { lbp: false, mlp: false, early_exit: false },
        shard: None,
    }
}

/// An injected shard panic mid-dispatch must not take the serve plane
/// down: the worker catches it, fails the batch's pending tickets with
/// a typed error, and keeps serving later batches (the process-wide
/// panic token degrades further injected panics to stalls, modelling a
/// crash that does not recur per-dispatch).
#[test]
fn injected_shard_panic_is_isolated_end_to_end() {
    reset_panic_token();
    let (_, params) = synth_params(3);
    let mut system = quiet_system();
    system.serve.shards = 1;
    {
        let f = &mut system.faults;
        f.enabled = true;
        f.seed = 99;
        f.panic_prob = 1.0;
        f.stall_us = 100;
    }
    let frames = synth_frames(&params, 12, 5).unwrap();
    let server = Server::start(params, engine_config(&system)).unwrap();

    let mut failed = 0u64;
    let mut completed = 0u64;
    // submit one frame at a time so the poisoned batch is small and
    // later batches prove the worker thread survived the panic
    for (i, frame) in frames.iter().enumerate() {
        let request = Request::builder(frame.clone().with_seq(i as u64))
            .sensor_id(0)
            .class(QosClass::Standard)
            .build();
        let ticket = server.submit(request).unwrap();
        match ticket.wait() {
            Ok(_) => completed += 1,
            Err(ns_lbp::Error::Serve(msg)) => {
                assert!(
                    msg.contains("panicked"),
                    "expected a panic-failure error, got: {msg}"
                );
                failed += 1;
            }
            Err(e) => panic!("unexpected error under injected panic: {e}"),
        }
    }
    let report = server.drain().unwrap();
    assert_eq!(failed, 1, "exactly one dispatch should really panic");
    assert_eq!(completed, frames.len() as u64 - 1,
               "the worker must keep serving after the caught panic");
    assert!(report.faults_injected >= frames.len() as u64,
            "every dispatch drew an injected fault (one panic, then \
             stalls), got {}", report.faults_injected);
    assert_eq!(report.completed, completed);
}

/// Node-flap drill through the library API: the flapped node's links
/// black-hole for a message window, the health machine walks
/// alive→suspect→dead, pending frames re-home, and once the window
/// passes the node rejoins — with zero billed loss and no orphaned
/// tickets.
#[test]
fn node_flap_recovers_and_rejoins() {
    let (_, params) = synth_params(7);
    let mut system = quiet_system();
    system.fleet.nodes = 2;
    {
        let f = &mut system.faults;
        f.enabled = true;
        f.seed = 4242;
        f.flap_node = 1;
        f.flap_after = 5;
        f.flap_len = 30;
        // fast recovery clocks so the whole drill fits in seconds
        f.retransmit_ms = 40;
        f.probe_ms = 10;
        f.suspect_ms = 40;
        f.dead_ms = 120;
    }
    let frames = synth_frames(&params, 48, 11).unwrap();
    let depth: usize = system.fleet.capacity.iter().sum::<usize>() * 4 + 64;
    let plan = FaultPlan::new(system.faults.clone());
    let transport = FaultyTransport::new(
        Box::new(ChannelTransport::new(depth)),
        std::sync::Arc::clone(&plan),
    );
    let fleet = Fleet::start_with_transport(
        params.clone(), engine_config(&system), Box::new(transport))
        .unwrap();

    let mut retrier = ns_lbp::faults::Retrier::new(
        ns_lbp::faults::RetryPolicy::admission(), 1);
    let sensors: Vec<u32> = (0..4).collect();
    let mut seqs = std::collections::HashMap::new();
    let mut tickets = Vec::new();
    for (i, frame) in frames.iter().enumerate() {
        let sensor = sensors[i % sensors.len()];
        let class = [QosClass::Billed, QosClass::Standard][i % 2];
        let seq = *seqs.get(&sensor).unwrap_or(&0);
        let t = retrier
            .run(|| {
                fleet.submit_stamped(sensor, class, 0,
                                     frame.clone().with_seq(seq))
            })
            .unwrap();
        seqs.insert(sensor, seq + 1);
        tickets.push(t);
    }
    for t in tickets {
        match t.wait_timeout(std::time::Duration::from_secs(20)) {
            Some(Ok(_))
            | Some(Err(ns_lbp::Error::Dropped(_)))
            | Some(Err(ns_lbp::Error::Serve(_))) => {}
            Some(Err(e)) => panic!("unexpected terminal error: {e}"),
            None => panic!("frame unresolved after 20 s under node flap"),
        }
    }
    // give the probes time to walk the blackhole window off the link so
    // the flapped node can rejoin before we read the rollup
    std::thread::sleep(std::time::Duration::from_millis(1200));
    plan.disarm();
    let report = fleet.drain().unwrap();

    assert!(report.health_dead >= 1,
            "the flapped node was never declared dead");
    assert!(report.health_rejoined >= 1,
            "the flapped node never rejoined after the window passed");
    assert_eq!(report.billed_lost(), 0, "billed frame lost in the flap");
    assert_eq!(report.orphaned, 0, "ticket leaked without a response");
    assert!(report.retries + report.rerouted > 0,
            "recovery machinery never engaged");
}

/// Identical seed and knobs ⇒ identical fault schedule, artifact
/// corruption plan, and comparator flip rate; the flip rate is zero at
/// nominal sigma and monotone in the sigma scale.
#[test]
fn fault_schedules_are_deterministic_in_the_seed() {
    let mut cfg = SystemConfig::default().faults;
    cfg.enabled = true;
    cfg.seed = 0xDEAD_BEEF;
    cfg.drop_prob = 0.05;
    cfg.dup_prob = 0.05;
    cfg.delay_prob = 0.1;
    cfg.delay_slots = 3;
    cfg.flap_node = 1;
    cfg.flap_after = 8;
    cfg.flap_len = 16;
    cfg.artifact_corrupt_prob = 0.3;

    let a = FaultPlan::new(cfg.clone());
    let b = FaultPlan::new(cfg.clone());
    assert_eq!(a.schedule_digest(3, 512), b.schedule_digest(3, 512));
    let ea = a.schedule_events(3, 128, 64);
    let eb = b.schedule_events(3, 128, 64);
    assert_eq!(ea.len(), eb.len());
    for (x, y) in ea.iter().zip(&eb) {
        assert_eq!((x.node, x.dir, x.index, x.fault),
                   (y.node, y.dir, y.index, y.fault));
    }
    assert!(!ea.is_empty(), "a lossy schedule must name its faults");

    // a different seed reshuffles the schedule
    let mut other = cfg.clone();
    other.seed ^= 1;
    let c = FaultPlan::new(other);
    assert_ne!(a.schedule_digest(3, 512), c.schedule_digest(3, 512));

    // artifact corruption is pure in (seed, node, index)
    for node in 0..3usize {
        for index in 0..32u64 {
            assert_eq!(artifact_corruption(&cfg, node, index, 4096),
                       artifact_corruption(&cfg, node, index, 4096));
        }
    }

    // comparator flip rate: zero at nominal sigma, monotone in scale
    let circuit = SystemConfig::default().circuit;
    let mut nominal = cfg.clone();
    nominal.bitflip_sigma_scale = 1.0;
    assert_eq!(BitFlips::rate_for(&nominal, &circuit), 0.0,
               "the paper's nominal operating point must be error-free");
    let mut last = 0.0f64;
    for scale in [4.0, 8.0, 16.0, 32.0] {
        let mut c = cfg.clone();
        c.bitflip_sigma_scale = scale;
        let rate = BitFlips::rate_for(&c, &circuit);
        assert!(rate >= last,
                "flip rate not monotone: {rate} at x{scale} after {last}");
        last = rate;
    }
}
