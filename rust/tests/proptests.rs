//! Property-based tests over the coordinator substrate invariants
//! (in-house framework — proptest is unavailable offline; see
//! `ns_lbp::testing`).

use ns_lbp::circuit::{ideal_outputs, sense, CircuitParams};
use ns_lbp::dpu::Dpu;
use ns_lbp::isa::{assemble, Executor, Instruction};
use ns_lbp::lbp::opcount::LbpCost;
use ns_lbp::lbp::{compare_ref, parallel_compare};
use ns_lbp::mapping::{partition, partition_stats, LbpSubarrayMap};
use ns_lbp::mlp::{dot_unsigned_ref, MlpSubarrayMap};
use ns_lbp::serve::queue::{BoundedQueue, PushError};
use ns_lbp::sram::{CacheGeometry, Region, RegionLayout, SubArray};
use ns_lbp::testing::{check, Config, Gen};

fn default_map() -> LbpSubarrayMap {
    LbpSubarrayMap::new(RegionLayout::default(), 8).unwrap()
}

/// Algorithm 1 equals the scalar `>=` oracle for arbitrary lane sets,
/// lane counts, skip settings and early-exit choices.
#[test]
fn prop_algorithm1_equals_oracle() {
    let map = default_map();
    check(Config::default().cases(60), "alg1 == oracle", |g: &mut Gen| {
        let lanes = g.usize_in(1, 256);
        let skip = g.usize_in(0, 3);
        let early = g.bool();
        let mask = 0xFFu8 ^ ((1u8 << skip) - 1);
        let pairs: Vec<(u8, u8)> = (0..lanes)
            .map(|_| (g.u8() & mask, g.u8() & mask))
            .collect();
        let mut sa = SubArray::new(256, 256);
        map.load_lanes(&mut sa, 0, &pairs).unwrap();
        let mut ex = Executor::new(&mut sa);
        let got = parallel_compare(&mut ex, &map, 0, lanes, skip, early).unwrap();
        assert_eq!(got.bits, compare_ref(&pairs));
    });
}

/// The ISA executor's 3-input ops agree with the analog SA decision model
/// on random row contents (not just per-bit truth tables).
#[test]
fn prop_isa_matches_circuit_sense() {
    let p = CircuitParams::default();
    check(Config::default().cases(40), "isa == sense", |g: &mut Gen| {
        let a = g.rng().next_u64();
        let b = g.rng().next_u64();
        let c = g.rng().next_u64();
        let mut sa = SubArray::new(8, 64);
        sa.write_row(0, &[a]).unwrap();
        sa.write_row(1, &[b]).unwrap();
        sa.write_row(2, &[c]).unwrap();
        let mut ex = Executor::new(&mut sa);
        ex.run(&assemble("sum r0 r1 r2 -> r4\ncarry r0 r1 r2 -> r5").unwrap())
            .unwrap();
        let sum = ex.array.read_row(4).unwrap()[0];
        let carry = ex.array.read_row(5).unwrap()[0];
        let bit = g.usize_in(0, 63);
        let ones = ((a >> bit) & 1) + ((b >> bit) & 1) + ((c >> bit) & 1);
        let sa_out = sense(&p, ones as usize, 0.0).unwrap();
        assert_eq!((sum >> bit) & 1 == 1, sa_out.xor3());
        assert_eq!((carry >> bit) & 1 == 1, sa_out.carry());
        assert_eq!(sa_out, ideal_outputs(ones as usize));
    });
}

/// Partitioning covers every lane exactly once, never splits a batch
/// across sub-arrays, and respects geometry bounds.
#[test]
fn prop_partition_is_exact_cover() {
    check(Config::default().cases(50), "partition cover", |g: &mut Gen| {
        let geometry = CacheGeometry {
            banks: g.usize_in(1, 8),
            mats_per_bank: g.usize_in(1, 3),
            subarrays_per_mat: g.usize_in(1, 3),
            ..CacheGeometry::default()
        };
        let map = default_map();
        let n = g.usize_in(0, 4000);
        let pairs: Vec<(u8, u8)> = (0..n).map(|_| (g.u8(), g.u8())).collect();
        let batches = partition(&pairs, &geometry, &map).unwrap();
        let mut seen = vec![false; n];
        for b in &batches {
            assert!(b.pairs.len() <= geometry.cols);
            assert!(b.target.bank < geometry.banks);
            assert!(b.target.mat < geometry.mats_per_bank);
            assert!(b.target.subarray < geometry.subarrays_per_mat);
            assert!(b.slot < map.slots());
            for (j, &pair) in b.pairs.iter().enumerate() {
                let idx = b.lane_offset + j;
                assert!(!seen[idx], "lane {idx} double-covered");
                seen[idx] = true;
                assert_eq!(pair, pairs[idx]);
            }
        }
        assert!(seen.iter().all(|&s| s));
        let stats = partition_stats(&batches, &map);
        assert_eq!(stats.total_lanes, n);
        assert!(stats.subarrays_used
            <= geometry.total_subarrays().min(batches.len().max(1)));
    });
}

/// In-memory bit-serial dot == integer dot for random widths and values.
#[test]
fn prop_inmemory_dot_equals_integer_dot() {
    check(Config::default().cases(30), "dot == ref", |g: &mut Gen| {
        let act_bits = g.usize_in(1, 4);
        let w_bits = g.usize_in(1, 4);
        let map = MlpSubarrayMap::new(default_map(), act_bits, w_bits).unwrap();
        let lanes = g.usize_in(1, 256);
        let x: Vec<u8> = (0..lanes)
            .map(|_| g.u8() & ((1 << act_bits) - 1))
            .collect();
        let w: Vec<u8> = (0..lanes)
            .map(|_| g.u8() & ((1 << w_bits) - 1))
            .collect();
        let mut sa = SubArray::new(256, 256);
        let mut ex = Executor::new(&mut sa);
        map.load_vector(&mut ex, Region::Input, 0, &x).unwrap();
        map.load_vector(&mut ex, Region::Weight, 0, &w).unwrap();
        let mut dpu = Dpu::default();
        let got = map.dot_unsigned(&mut ex, &mut dpu, 0, 0, lanes).unwrap();
        assert_eq!(got, dot_unsigned_ref(&x, &w));
    });
}

/// Eq. 1 ≥ Eq. 2 for every parameter combination, with equality iff apx=0,
/// and counts never underflow.
#[test]
fn prop_opcounts_ordered() {
    check(Config::default().cases(200), "eq1 >= eq2", |g: &mut Gen| {
        let e = g.i64_in(1, 16) as u64;
        let cost = LbpCost {
            e,
            ch: g.i64_in(1, 64) as u64,
            m: g.i64_in(1, 16) as u64,
            apx: g.i64_in(0, e as i64 - 1) as u64,
        };
        let exact = cost.lbpnet_ops();
        let approx = cost.aplbp_ops();
        assert!(approx.reads <= exact.reads);
        assert!(approx.comparisons <= exact.comparisons);
        assert!(approx.writes <= exact.writes);
        if cost.apx == 0 {
            assert_eq!(exact, approx);
        } else {
            assert!(approx.total() < exact.total());
        }
    });
}

/// Sub-array single-bit writes and whole-row ops are consistent views.
#[test]
fn prop_subarray_bit_row_consistency() {
    check(Config::default().cases(40), "bit/row views", |g: &mut Gen| {
        let cols = 64 * g.usize_in(1, 4);
        let mut sa = SubArray::new(16, cols);
        let row = g.usize_in(0, 15);
        let mut expect = vec![0u64; cols / 64];
        for _ in 0..g.usize_in(0, 100) {
            let col = g.usize_in(0, cols - 1);
            let v = g.bool();
            sa.set(row, col, v).unwrap();
            if v {
                expect[col / 64] |= 1 << (col % 64);
            } else {
                expect[col / 64] &= !(1 << (col % 64));
            }
        }
        assert_eq!(sa.read_row(row).unwrap(), expect);
        let back = sa.read_row(row).unwrap();
        sa.write_row(row, &back).unwrap();
        assert_eq!(sa.read_row(row).unwrap(), expect);
    });
}

/// Params serializer/parser round-trips arbitrary valid parameter sets and
/// rejects any single-byte corruption of the header.
#[test]
fn prop_params_roundtrip_and_header_corruption() {
    use ns_lbp::params::parse;
    check(Config::default().cases(20), "params fuzz", |g: &mut Gen| {
        let (blob, params) = ns_lbp_params_synth(g.rng().next_u64());
        let parsed = parse(&blob).unwrap();
        assert_eq!(parsed, params);
        // corrupt one header byte (magic/version region)
        let mut bad = blob.clone();
        let idx = g.usize_in(0, 11);
        bad[idx] ^= 0xFF;
        assert!(parse(&bad).is_err(), "corruption at byte {idx} accepted");
    });
}

// A minimal local blob generator, intentionally *independent* of the
// crate's own `params::synth` so the property tests do not share a code
// path with the serializer under test.
fn ns_lbp_params_synth(seed: u64) -> (Vec<u8>, ns_lbp::params::NetParams) {
    use ns_lbp::params::*;
    use ns_lbp::rng::Xoshiro256;
    let config = NetConfig {
        height: 8, width: 8, in_channels: 1, n_lbp_layers: 1,
        kernels_per_layer: 2, e: 8, window: 3, apx_code: 0, apx_pixel: 0,
        pool: 4, act_bits: 4, w_bits: 4, hidden: 8, n_classes: 10,
    };
    let mut rng = Xoshiro256::new(seed);
    let mut offsets = Vec::new();
    for _ in 0..config.kernels_per_layer {
        let mut pts = Vec::new();
        while pts.len() < config.e {
            let dy = rng.range_i64(-1, 1) as i32;
            let dx = rng.range_i64(-1, 1) as i32;
            if (dy, dx) != (0, 0) {
                pts.push(SamplePoint { dy, dx, ch: 0 });
            }
        }
        offsets.push(pts);
    }
    let lbp_layers = vec![LbpLayer { offsets, pivot_ch: vec![0, 0] }];
    let mk = |rng: &mut Xoshiro256, d: usize, o: usize| MlpLayer {
        d, o,
        w: (0..d * o).map(|_| (rng.below(16) as i8) - 8).collect(),
        scale: (0..o).map(|_| 0.001f32).collect(),
        bias: (0..o).map(|_| 0.0f32).collect(),
    };
    let mlp1 = mk(&mut rng, config.feature_dim(), config.hidden);
    let mlp2 = mk(&mut rng, config.hidden, config.n_classes);
    let params = NetParams { config, lbp_layers, mlp1, mlp2 };

    // serialize (mirror of python save_params)
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    for v in [config.height, config.width, config.in_channels,
              config.n_lbp_layers, config.kernels_per_layer, config.e,
              config.window, config.apx_code, config.apx_pixel, config.pool,
              config.act_bits, config.w_bits, config.hidden, config.n_classes] {
        out.extend_from_slice(&(v as u32).to_le_bytes());
    }
    for layer in &params.lbp_layers {
        for pts in &layer.offsets {
            for pt in pts {
                out.extend_from_slice(&pt.dy.to_le_bytes());
                out.extend_from_slice(&pt.dx.to_le_bytes());
                out.extend_from_slice(&pt.ch.to_le_bytes());
            }
        }
        for &ch in &layer.pivot_ch {
            out.extend_from_slice(&ch.to_le_bytes());
        }
    }
    for mlp in [&params.mlp1, &params.mlp2] {
        out.extend_from_slice(&(mlp.d as u32).to_le_bytes());
        out.extend_from_slice(&(mlp.o as u32).to_le_bytes());
        out.extend(mlp.w.iter().map(|&v| v as u8));
        for &s in &mlp.scale {
            out.extend_from_slice(&s.to_le_bytes());
        }
        for &b in &mlp.bias {
            out.extend_from_slice(&b.to_le_bytes());
        }
    }
    (out, params)
}

/// `BoundedQueue` under concurrent submit and a mid-stream close: no
/// admitted item is lost or duplicated, every rejection is explicit, and
/// fullness rejects exactly past the configured depth.
#[test]
fn prop_bounded_queue_concurrent_submit_close() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    check(Config::default().cases(25), "queue submit/close", |g: &mut Gen| {
        let capacity = g.usize_in(1, 8);
        let producers = g.usize_in(1, 4);
        let per_producer = g.usize_in(1, 60) as u32;
        let close_after = g.usize_in(0, 40) as u32;

        // phase 1 (single-threaded): fullness is exact at `capacity`
        {
            let q: BoundedQueue<u32> = BoundedQueue::new(capacity);
            for i in 0..capacity as u32 {
                q.try_push(i).unwrap();
            }
            let (err, item) = q.try_push(999).unwrap_err();
            assert_eq!(err, PushError::Full);
            assert_eq!(item, 999);
            assert_eq!(q.len(), capacity);
        }

        // phase 2 (concurrent): producers try_push unique values while a
        // consumer drains and a closer closes mid-stream
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(capacity));
        let closed_flag = Arc::new(AtomicBool::new(false));
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            })
        };
        let closer = {
            let q = Arc::clone(&q);
            let closed_flag = Arc::clone(&closed_flag);
            std::thread::spawn(move || {
                while q.len() < capacity.min(close_after as usize)
                    && !closed_flag.load(Ordering::Acquire)
                {
                    std::thread::yield_now();
                }
                q.close();
            })
        };
        let handles: Vec<_> = (0..producers as u32)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut accepted = Vec::new();
                    for i in 0..per_producer {
                        let v = p * 10_000 + i;
                        match q.try_push(v) {
                            Ok(()) => accepted.push(v),
                            Err((PushError::Full, back)) => {
                                // handed back intact; not admitted
                                assert_eq!(back, v);
                            }
                            Err((PushError::Closed, back)) => {
                                assert_eq!(back, v);
                                break; // closed stays closed
                            }
                        }
                    }
                    accepted
                })
            })
            .collect();
        let mut accepted: Vec<u32> = Vec::new();
        for h in handles {
            accepted.extend(h.join().unwrap());
        }
        closed_flag.store(true, Ordering::Release);
        closer.join().unwrap();
        let mut delivered = consumer.join().unwrap();

        // exactly-once delivery of exactly the accepted set
        accepted.sort_unstable();
        delivered.sort_unstable();
        assert_eq!(delivered, accepted, "lost or duplicated items");
    });
}

/// DPU pooled quantization: bounded, monotone, exact at the extremes.
#[test]
fn prop_dpu_quantize_monotone_bounded() {
    check(Config::default().cases(100), "quantize", |g: &mut Gen| {
        let mut dpu = Dpu::default();
        let pool = [1usize, 2, 4][g.usize_in(0, 2)];
        let vmax = (255 * pool * pool) as u32;
        let bits = g.usize_in(1, 6) as u32;
        let qmax = (1u32 << bits) - 1;
        let a = g.usize_in(0, vmax as usize) as u32;
        let b = g.usize_in(0, vmax as usize) as u32;
        let qa = dpu.quantize_pooled(a, vmax, bits).unwrap() as u32;
        let qb = dpu.quantize_pooled(b, vmax, bits).unwrap() as u32;
        assert!(qa <= qmax && qb <= qmax);
        if a <= b {
            assert!(qa <= qb);
        } else {
            assert!(qb <= qa);
        }
        assert_eq!(dpu.quantize_pooled(0, vmax, bits).unwrap(), 0);
        assert_eq!(dpu.quantize_pooled(vmax, vmax, bits).unwrap() as u32, qmax);
    });
}

/// Config parser: printing a config back through overrides round-trips.
#[test]
fn prop_config_override_roundtrip() {
    use ns_lbp::config::{ConfigFile, SystemConfig};
    check(Config::default().cases(40), "config overrides", |g: &mut Gen| {
        let banks = g.usize_in(1, 200);
        let freq = (g.usize_in(1, 40) as f64) / 10.0;
        let mut f = ConfigFile::default();
        f.set_override(&format!("cache.banks={banks}")).unwrap();
        f.set_override(&format!("circuit.freq_ghz={freq}")).unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert_eq!(sc.cache.banks, banks);
        assert!((sc.circuit.freq_ghz - freq).abs() < 1e-12);
    });
}

/// Arbitrary *valid* hardware profiles never produce negative or NaN
/// costs on arbitrary traces, and they survive TOML serialization
/// losslessly (serialize → parse → equal).
#[test]
fn prop_hw_profiles_cost_sane_and_roundtrip() {
    use ns_lbp::dpu::DpuStats;
    use ns_lbp::hw::{CostModel, HwProfile, AREA_FIELDS, ENERGY_FIELDS};
    use ns_lbp::isa::{ExecStats, Opcode};

    check(Config::default().cases(60), "hw profile sanity", |g: &mut Gen| {
        // random valid profile
        let mut p = HwProfile::ns_lbp_65nm();
        p.name = format!("synth_{}", g.usize_in(0, 1 << 20));
        for &field in ENERGY_FIELDS {
            p.set_energy_field(field, g.f64_in(0.001, 100.0)).unwrap();
        }
        // freq must stay positive
        p.energy.freq_ghz = g.f64_in(0.05, 5.0);
        for &field in AREA_FIELDS {
            p.set_area_field(field, g.f64_in(0.0, 10_000.0)).unwrap();
        }
        p.energy_scale = g.f64_in(0.1, 10.0);
        p.mac_cycles = g.usize_in(0, 64) as u64;
        p.mac_lanes = g.usize_in(0, 1 << 16) as u64;
        p.flop_lanes = g.usize_in(0, 4096) as u64;
        for op in Opcode::ALL {
            p.cycles.set(op, g.usize_in(1, 8) as u64);
        }
        p.validate().unwrap();

        // random trace
        let mut stats = ExecStats::default();
        stats.instructions = g.usize_in(0, 100_000) as u64;
        stats.cycles = g.usize_in(0, 100_000) as u64;
        stats.row_reads = g.usize_in(0, 100_000) as u64;
        stats.row_writes = g.usize_in(0, 100_000) as u64;
        stats.compute_ops = g.usize_in(0, 100_000) as u64;
        for op in Opcode::ALL {
            if g.bool() {
                stats.by_opcode.insert(op, g.usize_in(0, 10_000) as u64);
            }
        }
        let dpu = DpuStats {
            quantize_ops: g.usize_in(0, 100_000) as u64,
            bitcounts: g.usize_in(0, 100_000) as u64,
            shifts: g.usize_in(0, 100_000) as u64,
            adds: g.usize_in(0, 100_000) as u64,
            activations: g.usize_in(0, 100_000) as u64,
            shifted_relus: g.usize_in(0, 100_000) as u64,
        };

        // never negative, never NaN
        for cost in [
            p.exec_cost(&stats),
            p.dpu_cost(&dpu),
            p.sensor_cost(g.usize_in(0, 1 << 20) as u64,
                          g.usize_in(0, 16) as u64),
            p.transmission_cost(g.usize_in(0, 1 << 24) as u64),
        ] {
            assert!(cost.is_sane(), "insane cost {cost:?} under {p:?}");
        }
        assert!(p.cycle_ns().is_finite() && p.cycle_ns() > 0.0);
        assert!(p.tops_per_watt(256).is_finite());

        // lossless TOML round-trip
        let back = HwProfile::from_toml(&p.to_toml()).unwrap();
        assert_eq!(back, p);
    });
}

/// `percentile_ns` (nearest-rank, the serve-metrics and trace-summary
/// quantile): monotone in q, always bounded by the sample extremes,
/// exact on a singleton, and 0 on an empty slice.
#[test]
fn prop_percentile_monotone_bounded_exact() {
    use ns_lbp::serve::percentile_ns;
    check(Config::default().cases(120), "percentile", |g: &mut Gen| {
        let mut samples: Vec<u64> = g.vec(1, 400, |g| {
            g.usize_in(0, 1 << 40) as u64
        });
        samples.sort_unstable();
        // bounded by the extremes at arbitrary q
        let q1 = g.f64_in(0.0, 1.0);
        let q2 = g.f64_in(0.0, 1.0);
        let (lo, hi) = (q1.min(q2), q1.max(q2));
        let p_lo = percentile_ns(&samples, lo);
        let p_hi = percentile_ns(&samples, hi);
        assert!(*samples.first().unwrap() <= p_lo);
        assert!(p_hi <= *samples.last().unwrap());
        // monotone in q
        assert!(p_lo <= p_hi, "q={lo} -> {p_lo} > q={hi} -> {p_hi}");
        // q=1 is the max; q→0 stays within range
        assert_eq!(percentile_ns(&samples, 1.0), *samples.last().unwrap());
        // exact on a singleton, whatever q
        let only = samples[g.usize_in(0, samples.len() - 1)];
        assert_eq!(percentile_ns(&[only], q1), only);
        // empty slice is defined as 0 (no samples, no panic)
        assert_eq!(percentile_ns(&[], q1), 0);
    });
}

/// Warm engines with reused scratch arenas stay bit-identical to cold
/// ones over *random batch-size sequences* (both in-tree backends): the
/// PR-5 allocation-free hot path must never leak state between batches,
/// whatever shape history the arena has seen.
#[test]
fn prop_warm_arena_matches_cold_over_random_batch_sizes() {
    use ns_lbp::engine::{ArchSim, BackendKind, Engine, EngineConfig};
    use ns_lbp::params::synth::synth_params;
    use ns_lbp::testing::synth_frames;

    let (_, params) = synth_params(5);
    check(Config::default().cases(6), "warm == cold over random batches",
          |g: &mut Gen| {
        let kind = if g.bool() {
            BackendKind::Functional
        } else {
            BackendKind::Architectural
        };
        let config = EngineConfig {
            arch: ArchSim { lbp: true, mlp: true, early_exit: g.bool() },
            ..Default::default()
        };
        let mut warm = Engine::builder()
            .config(config.clone())
            .params(params.clone())
            .backend(kind)
            .build()
            .unwrap();
        let rounds = g.usize_in(1, 3);
        for round in 0..rounds {
            let n = g.usize_in(1, 5);
            let seed = 1000 + 17 * round as u64 + n as u64;
            let frames = synth_frames(&params, n, seed).unwrap();
            let got = warm.infer_batch(&frames).unwrap();
            let mut cold = Engine::builder()
                .config(config.clone())
                .params(params.clone())
                .backend(kind)
                .build()
                .unwrap();
            let want = cold.infer_batch(&frames).unwrap();
            assert_eq!(got.frames.len(), want.frames.len());
            for (a, b) in got.frames.iter().zip(&want.frames) {
                assert_eq!(a.logits, b.logits, "{kind} round {round}");
                assert_eq!(a.features, b.features, "{kind} round {round}");
                assert_eq!(a.telemetry.exec, b.telemetry.exec);
                assert_eq!(a.telemetry.dpu, b.telemetry.dpu);
                assert_eq!(a.telemetry.arch_mismatches, 0);
            }
        }
    });
}

/// Rendezvous hashing is minimally disruptive: removing one node moves
/// only the sensors it owned (each to its former second choice) and
/// leaves every other sensor's owner untouched; adding a node back only
/// moves sensors the new node now wins.
#[test]
fn prop_rendezvous_rehoming_is_minimal() {
    use ns_lbp::fleet::{rendezvous_owner, rendezvous_rank};

    check(Config::default().cases(60), "rendezvous minimal disruption",
          |g: &mut Gen| {
        let n = g.usize_in(2, 8);
        let nodes: Vec<usize> = (0..n).collect();
        let departed = g.usize_in(0, n - 1);
        let survivors: Vec<usize> =
            nodes.iter().copied().filter(|&x| x != departed).collect();
        let sensors: Vec<u32> =
            (0..64).map(|_| g.u32_below(1 << 20)).collect();
        for &sensor in &sensors {
            let before = rendezvous_owner(sensor, &nodes).unwrap();
            let after = rendezvous_owner(sensor, &survivors).unwrap();
            if before == departed {
                // an orphaned sensor lands on its next-ranked survivor
                let rank = rendezvous_rank(sensor, &nodes);
                assert_eq!(after, rank[1],
                           "sensor {sensor} skipped its second choice");
            } else {
                assert_eq!(before, after,
                           "sensor {sensor} moved although its owner \
                            {before} survived");
            }
            // re-join: the only sensors that move to the full set's
            // owner are the ones the returning node wins outright
            if after != before {
                assert_eq!(before, departed);
            }
        }
    });
}

/// The fleet admission ledger never exceeds any (node, class) capacity
/// under arbitrary admit/release/kill interleavings, places every admit
/// on a live node, and refuses only when every live node is full.
#[test]
fn prop_routing_table_caps_are_never_exceeded() {
    use ns_lbp::engine::QosClass;
    use ns_lbp::fleet::RoutingTable;

    check(Config::default().cases(40), "routing-table capacity",
          |g: &mut Gen| {
        let n = g.usize_in(1, 6);
        let capacity = [
            g.usize_in(1, 5),
            g.usize_in(1, 5),
            g.usize_in(1, 5),
        ];
        let mut table = RoutingTable::new(n, capacity);
        // shadow ledger of outstanding (node, class) admits
        let mut flat: Vec<(usize, QosClass)> = Vec::new();
        let steps = g.usize_in(20, 200);
        for _ in 0..steps {
            match g.usize_in(0, 9) {
                // mostly admits
                0..=5 => {
                    let sensor = g.u32_below(256);
                    let class = QosClass::ALL[g.usize_in(0, 2)];
                    match table.admit(sensor, class) {
                        Some(p) => {
                            assert!(table.is_live(p.node),
                                    "admitted onto a dead node");
                            flat.push((p.node, class));
                        }
                        None => {
                            // refusal is only legal when every live
                            // node is at capacity for this class
                            for node in table.live_nodes() {
                                assert_eq!(
                                    table.in_flight(node, class),
                                    table.capacity(class),
                                    "refused with headroom on node {node}"
                                );
                            }
                        }
                    }
                }
                // releases (random completion order)
                6..=8 => {
                    if !flat.is_empty() {
                        let i = g.usize_in(0, flat.len() - 1);
                        let (node, class) = flat.swap_remove(i);
                        table.release(node, class);
                    }
                }
                // rare kill
                _ => {
                    let node = g.usize_in(0, n - 1);
                    table.mark_dead(node);
                    // the dead node's outstanding admits vanish from
                    // the ledger; drop our shadow entries too so later
                    // releases don't double-release survivors
                    flat.retain(|&(owner, _)| owner != node);
                }
            }
            // the invariant: no (live node, class) ledger above capacity
            for node in 0..n {
                for class in QosClass::ALL {
                    let used = table.in_flight(node, class);
                    assert!(used <= table.capacity(class),
                            "node {node} {class:?} at {used} > cap");
                    if !table.is_live(node) {
                        assert_eq!(used, 0, "dead node {node} holds slots");
                    }
                }
            }
            // shadow ledger and table agree for live nodes
            for node in table.live_nodes() {
                for class in QosClass::ALL {
                    let shadow = flat.iter()
                        .filter(|&&(o, c)| o == node && c == class)
                        .count();
                    assert_eq!(table.in_flight(node, class), shadow,
                               "ledger drift on node {node}");
                }
            }
        }
    });
}

/// Chaos shadow ledger: duplicated, reordered, delayed (and sometimes
/// dropped) wire messages never double-complete a ticket and never lose
/// a billed frame.  The router's resolved ledger absorbs every late or
/// duplicate completion (surfacing as `deduped`, not as a second
/// response), retransmits recover dropped messages, and every billed
/// frame offered comes back exactly once while the nodes stay alive.
#[test]
fn prop_faulty_wire_exactly_once_no_billed_loss() {
    use ns_lbp::engine::{ArchSim, BackendKind, EngineConfig, QosClass};
    use ns_lbp::faults::{FaultPlan, FaultyTransport, Retrier, RetryPolicy};
    use ns_lbp::fleet::{ChannelTransport, Fleet};
    use ns_lbp::params::synth::synth_params;
    use ns_lbp::testing::synth_frames;
    use std::collections::HashSet;

    let (_, params) = synth_params(11);
    check(Config::default().cases(4), "faulty wire exactly-once",
          |g: &mut Gen| {
        let mut system = ns_lbp::config::SystemConfig::default();
        system.engine.backend = BackendKind::Functional;
        system.engine.cross_check = None;
        system.fleet.nodes = g.usize_in(2, 3);
        {
            let f = &mut system.faults;
            f.enabled = true;
            f.seed = g.rng().next_u64();
            f.dup_prob = g.f64_in(0.05, 0.15);
            f.delay_prob = g.f64_in(0.05, 0.15);
            f.delay_slots = g.usize_in(1, 4);
            f.drop_prob = if g.bool() { 0.02 } else { 0.0 };
            // fast recovery clocks so dropped messages retransmit
            // within the test budget
            f.retransmit_ms = 40;
            f.probe_ms = 10;
            f.suspect_ms = 60;
            f.dead_ms = 250;
        }
        let n_frames = g.usize_in(24, 48);
        let frames =
            synth_frames(&params, n_frames, system.faults.seed ^ 0x9e37)
                .unwrap();
        let sensors: Vec<u32> =
            (0..(system.fleet.nodes as u32 * 2)).collect();
        let mix = [QosClass::Billed, QosClass::Standard, QosClass::BestEffort];

        let depth: usize =
            system.fleet.capacity.iter().sum::<usize>() * 4 + 64;
        let plan = FaultPlan::new(system.faults.clone());
        let transport = FaultyTransport::new(
            Box::new(ChannelTransport::new(depth)),
            std::sync::Arc::clone(&plan),
        );
        let config = EngineConfig {
            system: system.clone(),
            arch: ArchSim { lbp: false, mlp: false, early_exit: false },
            shard: None,
        };
        let fleet =
            Fleet::start_with_transport(params.clone(), config,
                                        Box::new(transport))
                .unwrap();

        let mut retrier =
            Retrier::new(RetryPolicy::admission(), system.faults.seed);
        let mut seqs: std::collections::HashMap<u32, u64> =
            std::collections::HashMap::new();
        let mut offered_billed = 0u64;
        let mut tickets = Vec::with_capacity(frames.len());
        for (i, frame) in frames.iter().enumerate() {
            let sensor = sensors[i % sensors.len()];
            let class = mix[i % mix.len()];
            if class == QosClass::Billed {
                offered_billed += 1;
            }
            let seq = *seqs.get(&sensor).unwrap_or(&0);
            let ticket = retrier
                .run(|| {
                    fleet.submit_stamped(sensor, class, 0,
                                         frame.clone().with_seq(seq))
                })
                .unwrap();
            seqs.insert(sensor, seq + 1);
            tickets.push(ticket);
        }

        // exactly-once: no (sensor, seq) resolves twice, and the
        // router's completed counter agrees with what clients saw
        let mut seen: HashSet<(u32, u64)> = HashSet::new();
        let mut ok = 0u64;
        let mut billed_ok = 0u64;
        for t in tickets {
            match t.wait_timeout(std::time::Duration::from_secs(20)) {
                Some(Ok(r)) => {
                    ok += 1;
                    if r.inner.class == QosClass::Billed {
                        billed_ok += 1;
                    }
                    assert!(
                        seen.insert((r.inner.sensor_id, r.seq())),
                        "frame ({}, {}) completed twice",
                        r.inner.sensor_id, r.seq()
                    );
                }
                Some(Err(ns_lbp::Error::Dropped(_)))
                | Some(Err(ns_lbp::Error::Serve(_))) => {}
                Some(Err(e)) => panic!("unexpected terminal error: {e}"),
                None => panic!("frame unresolved after 20 s under faults"),
            }
        }
        plan.disarm();
        let report = fleet.drain().unwrap();

        assert_eq!(report.completed, ok, "router/client completion drift");
        assert_eq!(report.orphaned, 0, "ticket leaked without a response");
        assert_eq!(report.billed_lost(), 0, "billed frame lost");
        assert_eq!(billed_ok, offered_billed,
                   "billed frame shed while every node stayed alive");
        // the ledger absorbed every duplicate the schedule executed: a
        // duplicated response must never surface as a second completion
        let duplicated =
            plan.ledger.duplicated.load(std::sync::atomic::Ordering::Relaxed);
        assert!(report.deduped <= duplicated + report.retries,
                "deduped {} exceeds duplicates {} + retransmits {}",
                report.deduped, duplicated, report.retries);
    });
}
