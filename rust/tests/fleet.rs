//! Fleet integration tests: the failure drill (kill a node mid-stream,
//! zero billed loss, re-routed completions, logits bit-identical to a
//! single-node run, p99 bounded by the drill budget) and a mid-stream
//! model rollover that converges on one content-hash version without
//! dropping in-flight frames.

use std::collections::HashMap;
use std::path::Path;
use std::time::Duration;

use ns_lbp::compile::{build_model, ModelSpec};
use ns_lbp::coordinator::{ArchSim, CoordinatorConfig};
use ns_lbp::engine::QosClass;
use ns_lbp::fleet::Fleet;
use ns_lbp::params::synth::synth_params;
use ns_lbp::params::NetParams;
use ns_lbp::sensor::Frame;
use ns_lbp::serve::{Request, Server};

fn synth_frames(n: usize, seed: u64) -> (NetParams, Vec<Frame>) {
    let (_, params) = synth_params(5);
    let frames = ns_lbp::testing::synth_frames(&params, n, seed).unwrap();
    (params, frames)
}

/// Fleet config with a slow batch deadline, so submitted frames are
/// still in flight when the drill kills a node.
fn drill_config(nodes: usize, deadline_us: u64) -> CoordinatorConfig {
    let mut config = CoordinatorConfig {
        arch: ArchSim { lbp: false, mlp: false, early_exit: false },
        ..Default::default()
    };
    config.system.serve.shards = 1;
    config.system.serve.max_batch = 64;
    config.system.serve.batch_deadline_us = deadline_us;
    config.system.fleet.nodes = nodes;
    config
}

/// Replay `frames` round-robin across `sensors` (all billed), killing
/// `kill` after submission if given, and return (per-(sensor,seq)
/// logits, drill report, frames that arrived re-routed).
fn replay(
    fleet: Fleet,
    frames: &[Frame],
    sensors: &[u32],
    kill: Option<usize>,
) -> (HashMap<(u32, u64), Vec<f32>>, ns_lbp::fleet::FleetReport, u64) {
    let mut tickets = Vec::new();
    for (i, frame) in frames.iter().enumerate() {
        let sensor = sensors[i % sensors.len()];
        let session = fleet.session(sensor).with_class(QosClass::Billed);
        tickets.push((sensor, session.submit(frame.clone()).unwrap()));
    }
    if let Some(victim) = kill {
        // let the victim pull its frames off the wire first, so the
        // drill exercises re-homing of work the node truly owned
        std::thread::sleep(Duration::from_millis(20));
        fleet.kill_node(victim).unwrap();
        assert!(!fleet.live_nodes().contains(&victim));
    }
    let mut logits = HashMap::new();
    let mut rerouted = 0u64;
    for (sensor, t) in tickets {
        // the drill invariant: every billed frame still completes
        let r = t.wait().unwrap();
        if r.rerouted > 0 {
            rerouted += 1;
        }
        logits.insert((sensor, r.seq()), r.inner.report.logits);
    }
    let report = fleet.drain().unwrap();
    (logits, report, rerouted)
}

#[test]
fn drill_kill_node_rehomes_with_zero_billed_loss() {
    let (params, frames) = synth_frames(24, 17);
    let sensors: Vec<u32> = (0..6).collect();

    // Baseline pass: same fleet shape, nobody dies.
    let baseline_fleet =
        Fleet::start(params.clone(), drill_config(3, 150_000)).unwrap();
    let (_, baseline, _) = replay(baseline_fleet, &frames, &sensors, None);
    assert_eq!(baseline.completed, frames.len() as u64);
    assert_eq!(baseline.rerouted, 0);

    // Drill pass: kill the node that owns sensor 0, mid-stream.
    let fleet = Fleet::start(params.clone(), drill_config(3, 150_000)).unwrap();
    let victim = fleet.owner_of(sensors[0]).unwrap();
    let p99_budget = fleet.config().drill.p99_budget;
    let (fleet_logits, report, rerouted) =
        replay(fleet, &frames, &sensors, Some(victim));

    assert!(rerouted > 0, "the drill re-homed nothing: the victim owned \
                           no in-flight frames");
    assert_eq!(report.killed, vec![victim]);
    assert_eq!(report.completed, frames.len() as u64,
               "zero billed-frame loss: every submitted frame completes");
    assert_eq!(report.billed_lost(), 0);
    assert_eq!(report.lost.iter().sum::<u64>(), 0);
    assert_eq!(report.orphaned, 0);
    assert_eq!(report.rerouted, rerouted,
               "router re-home count matches re-routed completions");
    assert_eq!(report.completed_by_node.iter().sum::<u64>(),
               report.completed);
    assert!(report.node_reports[victim].is_none(),
            "a killed node dies without a drain report");
    for &node in &report.live {
        let r = report.node_reports[node]
            .as_ref()
            .expect("live nodes drain a report");
        assert_eq!(r.accepted, r.completed + r.dropped + r.failed,
                   "node {node} lifecycle balance");
    }
    // p99 inflation bounded by the drill budget (generous by default —
    // the CI gate in fleet_check.py uses the configured value too).
    assert!(
        report.p99_ms <= baseline.p99_ms.max(0.001) * p99_budget,
        "drill p99 {:.3} ms blew the budget ({}x baseline {:.3} ms)",
        report.p99_ms, p99_budget, baseline.p99_ms
    );

    // Bit-identical to a single-node run: placement and re-homing must
    // never change the math.
    let server = Server::start(params, drill_config(1, 500)).unwrap();
    let mut seqs: HashMap<u32, u64> = HashMap::new();
    let mut single = Vec::new();
    for (i, frame) in frames.iter().enumerate() {
        let sensor = sensors[i % sensors.len()];
        let seq = seqs.entry(sensor).or_insert(0);
        let request = Request::builder(frame.clone().with_seq(*seq))
            .sensor_id(sensor)
            .class(QosClass::Billed)
            .build();
        *seq += 1;
        single.push((sensor, server.submit(request).unwrap()));
    }
    for (sensor, ticket) in single {
        let resp = ticket.wait().unwrap();
        let fleet_l = &fleet_logits[&(sensor, resp.seq())];
        assert_eq!(fleet_l, &resp.report.logits,
                   "sensor {sensor} seq {} diverged from the single-node \
                    run", resp.seq());
    }
    server.drain().unwrap();
}

#[test]
fn push_model_mid_stream_converges_without_dropping_in_flight() {
    let (params, frames) = synth_frames(12, 29);
    let config = drill_config(3, 20_000);
    let fleet = Fleet::start(params, config.clone()).unwrap();

    // First half queued on model 0 (the 20 ms batch deadline keeps them
    // in flight while the roll happens).
    let mut first = Vec::new();
    for (i, frame) in frames[..6].iter().enumerate() {
        first.push(fleet.session(i as u32).submit(frame.clone()).unwrap());
    }

    let spec = ModelSpec::parse(
        "[model]\nname = \"alt\"\nseed = 7\n",
        Path::new("."),
    )
    .unwrap();
    let model = build_model(&spec, &config.system).unwrap();
    let acks = fleet.push_model(1, &model).unwrap();
    assert_eq!(acks.len(), 3, "every live node acked the roll");
    assert!(acks.iter().all(|&(_, v)| v == acks[0].1 && v != 0),
            "acks did not converge on one content-hash version: {acks:?}");

    // Second half rides the freshly rolled model on every node.
    let mut second = Vec::new();
    for (i, frame) in frames[6..].iter().enumerate() {
        let session = fleet.session(100 + i as u32).with_model(1);
        second.push(session.submit(frame.clone()).unwrap());
    }
    for t in first {
        let r = t.wait().unwrap();
        assert_eq!(r.inner.model_id, 0, "an in-flight frame switched models");
    }
    for t in second {
        let r = t.wait().unwrap();
        assert_eq!(r.inner.model_id, 1);
    }
    let report = fleet.drain().unwrap();
    assert_eq!(report.completed, frames.len() as u64);
    assert_eq!(
        report.dropped + report.failed + report.lost.iter().sum::<u64>(),
        0,
        "the roll dropped traffic"
    );
}
