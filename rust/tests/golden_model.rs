//! Golden-model equivalence: the three implementations of Ap-LBP must
//! agree on the artifact inputs.
//!
//! 1. AOT JAX/Pallas HLO executed via PJRT (`artifacts/*.hlo.txt`);
//! 2. the Rust functional model (`ns_lbp::model`);
//! 3. the architectural path (Algorithm 1 + in-memory MLP over the
//!    simulated sub-arrays) — checked inside the coordinator.
//!
//! Requires `make artifacts` and a `pjrt`-featured build; from a bare
//! checkout every test here *skips* with a message instead of failing.

use ns_lbp::coordinator::{ArchSim, Coordinator, CoordinatorConfig};
use ns_lbp::dpu::Dpu;
use ns_lbp::model;
use ns_lbp::params;
use ns_lbp::rng::Xoshiro256;
use ns_lbp::runtime::Runtime;
use ns_lbp::sensor::{Frame, FrameSource, SensorConfig};

const BATCH: usize = 4; // the artifacts' static batch size

fn artifacts_dir() -> String {
    std::env::var("NSLBP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

use ns_lbp::testing::artifact_params as try_params;

/// Params + PJRT runtime, or `None` (with a skip message) when either
/// the artifacts or the `pjrt` cargo feature are unavailable.
fn try_load(dataset: &str) -> Option<(params::NetParams, Runtime)> {
    let p = try_params(dataset)?;
    if !ns_lbp::runtime::pjrt_available() {
        eprintln!(
            "skipping: PJRT backend not compiled in (cargo feature `pjrt`)"
        );
        return None;
    }
    let rt = Runtime::new(artifacts_dir()).expect("PJRT client");
    Some((p, rt))
}

fn random_images(p: &params::NetParams, seed: u64, n: usize) -> Vec<f32> {
    let cfg = &p.config;
    let mut rng = Xoshiro256::new(seed);
    (0..n * cfg.height * cfg.width * cfg.in_channels)
        .map(|_| rng.next_f64() as f32)
        .collect()
}

#[test]
fn pjrt_features_match_functional_model_mnist() {
    let Some((p, mut rt)) = try_load("mnist") else { return };
    rt.load("features_mnist").unwrap();
    let images = random_images(&p, 11, BATCH);
    let feats_pjrt = rt.run_features("features_mnist", &p, &images, BATCH).unwrap();

    let cfg = &p.config;
    let npix = cfg.height * cfg.width * cfg.in_channels;
    for b in 0..BATCH {
        let img = &images[b * npix..(b + 1) * npix];
        let q = model::sensor_quantize(img, cfg.apx_pixel);
        let t = model::TensorU8 { h: cfg.height, w: cfg.width,
                                  c: cfg.in_channels, data: q };
        let feats_rust = model::forward_lbp(&p, &t, &mut Dpu::default()).unwrap();
        let rust_i32: Vec<i32> = feats_rust.iter().map(|&v| v as i32).collect();
        assert_eq!(feats_pjrt[b], rust_i32, "batch {b}: integer features differ");
    }
}

#[test]
fn pjrt_logits_match_functional_model_mnist() {
    let Some((p, mut rt)) = try_load("mnist") else { return };
    rt.load("aplbp_mnist").unwrap();
    let images = random_images(&p, 13, BATCH);
    let logits_pjrt = rt.run_aplbp("aplbp_mnist", &p, &images, BATCH).unwrap();

    let cfg = &p.config;
    let npix = cfg.height * cfg.width * cfg.in_channels;
    for b in 0..BATCH {
        let img = &images[b * npix..(b + 1) * npix];
        let logits_rust = model::apply(&p, img, &mut Dpu::default()).unwrap();
        for (i, (a, w)) in logits_pjrt[b].iter().zip(&logits_rust).enumerate() {
            assert!(
                (a - w).abs() <= 1e-4 * w.abs().max(1.0),
                "batch {b} logit {i}: pjrt {a} vs rust {w}"
            );
        }
        assert_eq!(model::argmax(&logits_pjrt[b]), model::argmax(&logits_rust));
    }
}

#[test]
fn pjrt_logits_match_functional_model_svhn() {
    let Some((p, mut rt)) = try_load("svhn") else { return };
    rt.load("aplbp_svhn").unwrap();
    let images = random_images(&p, 17, BATCH);
    let logits_pjrt = rt.run_aplbp("aplbp_svhn", &p, &images, BATCH).unwrap();
    let cfg = &p.config;
    let npix = cfg.height * cfg.width * cfg.in_channels;
    for b in 0..BATCH {
        let img = &images[b * npix..(b + 1) * npix];
        let logits_rust = model::apply(&p, img, &mut Dpu::default()).unwrap();
        for (a, w) in logits_pjrt[b].iter().zip(&logits_rust) {
            assert!((a - w).abs() <= 1e-4 * w.abs().max(1.0),
                    "pjrt {a} vs rust {w}");
        }
    }
}

#[test]
fn architectural_path_matches_pjrt_end_to_end() {
    // the full triangle: arch sim == functional == PJRT on one frame batch
    let Some((p, mut rt)) = try_load("mnist") else { return };
    rt.load("aplbp_mnist").unwrap();
    let cfg = p.config;
    let images = random_images(&p, 19, BATCH);
    let logits_pjrt = rt.run_aplbp("aplbp_mnist", &p, &images, BATCH).unwrap();

    let coord = Coordinator::new(
        p.clone(),
        CoordinatorConfig {
            arch: ArchSim { lbp: true, mlp: true, early_exit: false },
            ..Default::default()
        },
    )
    .unwrap();
    let mut handle = coord.frame_handle().unwrap();
    let npix = cfg.height * cfg.width * cfg.in_channels;
    for b in 0..BATCH {
        let img = &images[b * npix..(b + 1) * npix];
        let q = model::sensor_quantize(img, cfg.apx_pixel);
        let frame = Frame { rows: cfg.height, cols: cfg.width,
                            channels: cfg.in_channels, pixels: q,
                            seq: b as u64 };
        let report = handle.process(&frame).unwrap();
        assert_eq!(report.telemetry.arch_mismatches, 0,
                   "frame {b}: arch != functional");
        for (a, w) in report.logits.iter().zip(&logits_pjrt[b]) {
            assert!((a - w).abs() <= 1e-4 * w.abs().max(1.0),
                    "frame {b}: arch {a} vs pjrt {w}");
        }
    }
}

#[test]
fn unit_kernel_lbp_encode_matches_rust() {
    // the standalone L1 Pallas kernel artifact vs the scalar oracle
    let Some((_, mut rt)) = try_load("mnist") else { return };
    rt.load("lbp_encode_unit").unwrap();
    let mut rng = Xoshiro256::new(23);
    let neighbors: Vec<i32> = (0..256 * 8).map(|_| (rng.next_u64() % 256) as i32).collect();
    let pivots: Vec<i32> = (0..256).map(|_| (rng.next_u64() % 256) as i32).collect();
    let out = rt
        .execute(
            "lbp_encode_unit",
            &[
                ns_lbp::runtime::literal_i32(&neighbors, &[256, 8]).unwrap(),
                ns_lbp::runtime::literal_i32(&pivots, &[256]).unwrap(),
            ],
        )
        .unwrap();
    let codes = out.to_vec::<i32>().unwrap();
    assert_eq!(codes.len(), 256);
    for (r, &code) in codes.iter().enumerate() {
        let mut want = 0i32;
        for n in 0..8 {
            if neighbors[r * 8 + n] >= pivots[r] {
                want |= 1 << n;
            }
        }
        assert_eq!(code, want, "row {r}");
    }
}

#[test]
fn unit_kernel_bitserial_matches_rust() {
    let Some((_, mut rt)) = try_load("mnist") else { return };
    rt.load("bitserial_unit").unwrap();
    let mut rng = Xoshiro256::new(29);
    let x: Vec<i32> = (0..32 * 64).map(|_| (rng.next_u64() % 16) as i32).collect();
    let w: Vec<i32> = (0..64 * 128).map(|_| (rng.next_u64() % 16) as i32).collect();
    let out = rt
        .execute(
            "bitserial_unit",
            &[
                ns_lbp::runtime::literal_i32(&x, &[32, 64]).unwrap(),
                ns_lbp::runtime::literal_i32(&w, &[64, 128]).unwrap(),
            ],
        )
        .unwrap();
    let got = out.to_vec::<i32>().unwrap();
    for b in 0..32 {
        for o in 0..128 {
            let want: i32 = (0..64).map(|d| x[b * 64 + d] * w[d * 128 + o]).sum();
            assert_eq!(got[b * 128 + o], want, "({b},{o})");
        }
    }
}

#[test]
fn sensor_frame_feeds_identical_to_direct_quantization() {
    // ADC path == model.sensor_quantize for noise-free scenes
    // (params-only: runs whenever the artifact exists, PJRT or not)
    let Some(p) = try_params("mnist") else { return };
    let cfg = p.config;
    let scfg = SensorConfig { rows: cfg.height, cols: cfg.width,
                              channels: cfg.in_channels,
                              skip_lsbs: cfg.apx_pixel, ..Default::default() };
    let mut rng = Xoshiro256::new(37);
    let scene: Vec<f64> = (0..scfg.pixels()).map(|_| rng.next_f64()).collect();
    let mut sensor = ns_lbp::sensor::ReplaySensor::new(scfg, vec![scene.clone()], 1)
        .unwrap();
    let frame = sensor.next_frame().unwrap();
    let scene_f32: Vec<f32> = scene.iter().map(|&v| v as f32).collect();
    let want = model::sensor_quantize(&scene_f32, cfg.apx_pixel);
    assert_eq!(frame.pixels, want);
}
