//! Compile-pipeline integration tests: the bit-identity gate (an engine
//! built from a versioned artifact reproduces a from-params engine
//! exactly, logits and modeled cost alike), incremental recompiles (a
//! second compile of an unchanged spec hits every stage cache and does
//! zero packing), cache invalidation granularity, and corruption
//! rejection on load.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use ns_lbp::compile::{self, CompileOptions, CompiledModel, ModelSpec};
use ns_lbp::config::SystemConfig;
use ns_lbp::coordinator::{ArchSim, CoordinatorConfig};
use ns_lbp::engine::{BackendKind, Engine};
use ns_lbp::hw::HwProfile;

/// A fresh per-test scratch directory; `tag` keeps parallel tests from
/// colliding, the pid + clock keep reruns from seeing stale caches.
fn tmpdir(tag: &str) -> PathBuf {
    let n = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let d = std::env::temp_dir().join(format!(
        "ns-lbp-compile-{tag}-{}-{n}",
        std::process::id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn spec(seed: u64) -> ModelSpec {
    ModelSpec::parse(
        &format!("[model]\nname = \"t\"\nseed = {seed}\n"),
        Path::new("."),
    )
    .unwrap()
}

fn opts(root: &Path) -> CompileOptions {
    CompileOptions {
        out_dir: root.join("models"),
        cache_dir: root.join("cache"),
    }
}

/// The PR's acceptance gate: for both backends, an engine fed the
/// artifact's prepacked tables is bit-identical — logits, predictions,
/// and modeled cost — to an engine that packs the same params itself.
#[test]
fn artifact_engines_are_bit_identical_to_from_params_engines() {
    let root = tmpdir("identity");
    let system = SystemConfig::default();
    let (_, report) = compile::compile(&spec(11), &system, &opts(&root)).unwrap();
    let loaded = CompiledModel::load(&report.path).unwrap();
    assert_eq!(loaded.version, report.version);
    assert_ne!(loaded.version, 0, "version 0 is the unstamped sentinel");

    let frames = ns_lbp::testing::synth_frames(&loaded.params, 5, 29).unwrap();
    for kind in [BackendKind::Functional, BackendKind::Architectural] {
        let config = CoordinatorConfig {
            arch: ArchSim { lbp: true, mlp: true, early_exit: false },
            ..Default::default()
        };
        let mut from_params = Engine::builder()
            .config(config.clone())
            .params(loaded.params.clone())
            .backend(kind)
            .no_cross_check()
            .build()
            .unwrap();
        let mut from_artifact = Engine::builder()
            .config(config)
            .params(loaded.params.clone())
            .backend(kind)
            .no_cross_check()
            .prepacked(Arc::new(loaded.prepacked()))
            .build()
            .unwrap();
        let want = from_params.infer_batch(&frames).unwrap();
        let got = from_artifact.infer_batch(&frames).unwrap();
        assert_eq!(want.frames.len(), got.frames.len());
        for (w, g) in want.frames.iter().zip(&got.frames) {
            assert_eq!(w.logits, g.logits, "{kind}: logits diverged");
            assert_eq!(w.predicted, g.predicted);
            assert_eq!(w.features, g.features);
        }
        let (tw, tg) = (want.telemetry(), got.telemetry());
        assert_eq!(tw.cost.energy.total_pj(), tg.cost.energy.total_pj(),
                   "{kind}: artifact engine priced differently");
        assert_eq!(tw.cost.time_ns, tg.cost.time_ns);
        assert_eq!(tw.exec.instructions, tg.exec.instructions);
        assert_eq!(tw.exec.cycles, tg.exec.cycles);
    }
    std::fs::remove_dir_all(&root).ok();
}

/// An unchanged spec recompiles entirely from the stage caches — zero
/// packing work — and reproduces the artifact byte for byte, so the
/// version (a content hash) is stable across compiles.
#[test]
fn second_compile_hits_every_cache_and_reproduces_the_artifact() {
    let root = tmpdir("cache-hit");
    let system = SystemConfig::default();
    let opts = opts(&root);
    let (_, first) = compile::compile(&spec(3), &system, &opts).unwrap();
    assert!(
        first.stages.iter().all(|s| !s.cached),
        "a cold cache must build every stage: {:?}",
        first.stages
    );
    let bytes1 = std::fs::read(&first.path).unwrap();

    let (_, second) = compile::compile(&spec(3), &system, &opts).unwrap();
    assert!(
        second.all_cached(),
        "an unchanged spec must hit every stage cache: {:?}",
        second.stages
    );
    assert_eq!(second.version, first.version);
    assert_eq!(second.path, first.path);
    assert_eq!(std::fs::read(&second.path).unwrap(), bytes1);

    // the in-memory builder agrees with the staged pipeline bit for bit
    let direct = compile::build_model(&spec(3), &system).unwrap();
    assert_eq!(direct.version, first.version);
    std::fs::remove_dir_all(&root).ok();
}

/// Changing the weights (the seed) invalidates `analyze` and everything
/// downstream of it; changing only the hw profile re-prices without
/// re-packing (the pack stage still hits).
#[test]
fn cache_invalidation_follows_the_stage_inputs() {
    let root = tmpdir("invalidate");
    let system = SystemConfig::default();
    let opts = opts(&root);
    let (_, base) = compile::compile(&spec(3), &system, &opts).unwrap();

    let (_, reseeded) = compile::compile(&spec(4), &system, &opts).unwrap();
    assert!(
        reseeded.stages.iter().all(|s| !s.cached),
        "a new seed feeds every stage new input: {:?}",
        reseeded.stages
    );
    assert_ne!(reseeded.version, base.version);

    let mut repriced_system = system.clone();
    repriced_system.hw.profile = HwProfile::resolve("sram38_28nm").unwrap();
    let (_, repriced) =
        compile::compile(&spec(3), &repriced_system, &opts).unwrap();
    for s in &repriced.stages {
        let expect_cached = s.stage != "price";
        assert_eq!(
            s.cached, expect_cached,
            "profile swap should only rebuild the price stage: {:?}",
            repriced.stages
        );
    }
    assert_ne!(repriced.version, base.version,
               "the priced cost is part of the artifact payload");
    std::fs::remove_dir_all(&root).ok();
}

/// The loader re-hashes the payload, so any flipped byte on disk is
/// refused rather than served.
#[test]
fn corrupted_artifact_is_rejected_on_load() {
    let root = tmpdir("corrupt");
    let system = SystemConfig::default();
    let (_, report) = compile::compile(&spec(9), &system, &opts(&root)).unwrap();
    let mut bytes = std::fs::read(&report.path).unwrap();
    assert!(CompiledModel::load(&report.path).is_ok());
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&report.path, &bytes).unwrap();
    let err = CompiledModel::load(&report.path).unwrap_err().to_string();
    assert!(
        err.contains("corrupt") || err.contains("hash")
            || err.contains("version"),
        "corruption should be named in the error: {err}"
    );
    std::fs::remove_dir_all(&root).ok();
}

/// `CompileOptions::from_system` picks up the `[compile]` config section.
#[test]
fn compile_options_come_from_the_config_section() {
    let mut system = SystemConfig::default();
    system.compile.out_dir = "x/models".into();
    system.compile.cache_dir = "x/cache".into();
    let o = CompileOptions::from_system(&system);
    assert_eq!(o.out_dir, PathBuf::from("x/models"));
    assert_eq!(o.cache_dir, PathBuf::from("x/cache"));
}
