//! Serving-layer integration tests: admission control, deadline
//! batching, graceful drain, and — the load-bearing property — shard
//! count not changing model outputs.

use std::time::{Duration, Instant};

use ns_lbp::coordinator::{ArchSim, Coordinator, CoordinatorConfig};
use ns_lbp::params::synth::synth_params;
use ns_lbp::params::NetParams;
use ns_lbp::sensor::Frame;
use ns_lbp::serve::batcher::{BatchPolicy, Batcher};
use ns_lbp::serve::queue::{BoundedQueue, PushError};
use ns_lbp::serve::{InferResponse, Server};

fn synth_frames(n: usize, seed: u64) -> (NetParams, Vec<Frame>) {
    let (_, params) = synth_params(5);
    let frames = ns_lbp::testing::synth_frames(&params, n, seed).unwrap();
    (params, frames)
}

fn serve_all(params: &NetParams, frames: &[Frame], shards: usize,
             arch: ArchSim) -> Vec<InferResponse> {
    let mut config = CoordinatorConfig { arch, ..Default::default() };
    config.system.serve.shards = shards;
    config.system.serve.max_batch = 4;
    config.system.serve.batch_deadline_us = 300;
    config.system.serve.queue_depth = frames.len().max(1);
    let server = Server::start(params.clone(), config).unwrap();
    let tickets: Vec<_> = frames
        .iter()
        .map(|f| server.submit(f.clone()).unwrap())
        .collect();
    let mut responses: Vec<InferResponse> =
        tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    let report = server.drain().unwrap();
    assert_eq!(report.completed, frames.len() as u64);
    assert_eq!(report.arch_mismatches, 0);
    responses.sort_by_key(|r| r.seq());
    responses
}

#[test]
fn queue_backpressure_full_queue_rejects() {
    let q: BoundedQueue<u32> = BoundedQueue::new(3);
    for i in 0..3 {
        q.try_push(i).unwrap();
    }
    let (err, rejected) = q.try_push(99).unwrap_err();
    assert_eq!(err, PushError::Full);
    assert_eq!(rejected, 99); // the item comes back to the caller
    assert_eq!(q.len(), 3); // nothing was dropped to make room
    q.pop().unwrap();
    q.try_push(99).unwrap(); // space reopens after a pop
}

#[test]
fn batcher_deadline_flushes_partial_batch() {
    let q: BoundedQueue<u32> = BoundedQueue::new(16);
    q.try_push(7).unwrap();
    q.try_push(8).unwrap();
    let deadline = Duration::from_millis(30);
    let b = Batcher::new(&q, BatchPolicy { max_batch: 64, max_delay: deadline });
    let t0 = Instant::now();
    let batch = b.next_batch().unwrap();
    let waited = t0.elapsed();
    // far short of max_batch, the deadline ships what is there
    assert_eq!(batch, vec![7, 8]);
    assert!(waited >= Duration::from_millis(25), "flushed early: {waited:?}");
    assert!(waited < Duration::from_secs(2), "deadline ignored: {waited:?}");
}

#[test]
fn server_admission_control_rejects_past_depth() {
    let (params, frames) = synth_frames(1, 9);
    let mut config = CoordinatorConfig {
        // the slow architectural path: each frame takes milliseconds, so
        // the pipeline saturates while the µs-scale submit loop runs
        arch: ArchSim { lbp: true, mlp: false, early_exit: false },
        ..Default::default()
    };
    config.system.serve.shards = 1;
    config.system.serve.queue_depth = 2;
    config.system.serve.max_batch = 1;
    config.system.serve.batch_deadline_us = 1;
    let server = Server::start(params, config).unwrap();

    // at most 1 (processing) + 2 (batch queue) + 1 (batcher in hand)
    // + 2 (request queue) = 6 frames can be in flight; the rest of the
    // burst must bounce off admission control
    let mut tickets = Vec::new();
    let mut rejected = 0;
    for _ in 0..16 {
        match server.submit(frames[0].clone()) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                rejected += 1;
                assert!(e.to_string().contains("admission"), "{e}");
            }
        }
    }
    assert!(rejected > 0, "overfilling a depth-2 queue must reject");
    for t in tickets {
        t.wait().unwrap();
    }
    let report = server.drain().unwrap();
    assert_eq!(report.rejected, rejected);
    assert_eq!(report.completed + report.rejected, 16);
}

#[test]
fn shard_determinism_one_vs_four_shards() {
    let (params, frames) = synth_frames(16, 21);
    let arch = ArchSim { lbp: true, mlp: false, early_exit: false };
    let one = serve_all(&params, &frames, 1, arch);
    let four = serve_all(&params, &frames, 4, arch);
    assert_eq!(one.len(), frames.len());
    assert_eq!(four.len(), frames.len());
    // four shards actually participated
    let shards_used: std::collections::BTreeSet<usize> =
        four.iter().map(|r| r.shard).collect();
    assert!(shards_used.len() > 1, "all frames landed on one shard");
    // ... and sharding changed no model output whatsoever
    for (a, b) in one.iter().zip(&four) {
        assert_eq!(a.seq(), b.seq());
        assert_eq!(a.report.logits, b.report.logits, "frame {}", a.seq());
        assert_eq!(a.predicted(), b.predicted());
    }
    // the serve path agrees with the plain coordinator run loop too
    let coord = Coordinator::new(
        params,
        CoordinatorConfig { arch, ..Default::default() },
    )
    .unwrap();
    let mut handle = coord.frame_handle().unwrap();
    for r in &one {
        let direct = handle.process(&frames[r.seq() as usize]).unwrap();
        assert_eq!(direct.logits, r.report.logits);
    }
}

#[test]
fn drain_completes_every_admitted_frame() {
    let (params, frames) = synth_frames(12, 33);
    let mut config = CoordinatorConfig {
        arch: ArchSim { lbp: false, mlp: false, early_exit: false },
        ..Default::default()
    };
    config.system.serve.shards = 2;
    config.system.serve.max_batch = 5;
    config.system.serve.batch_deadline_us = 200;
    config.system.serve.queue_depth = 64;
    let server = Server::start(params, config).unwrap();
    let tickets: Vec<_> = frames
        .iter()
        .map(|f| server.submit(f.clone()).unwrap())
        .collect();
    // drain without waiting on tickets first: the graceful path must
    // still deliver every admitted frame before returning
    let report = server.drain().unwrap();
    assert_eq!(report.accepted, 12);
    assert_eq!(report.completed, 12);
    for t in tickets {
        let r = t.try_take().expect("drained server left a pending ticket");
        r.unwrap();
    }
}
