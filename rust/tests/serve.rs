//! Serving-layer integration tests: typed-request admission control,
//! QoS-class routing with per-class metrics, drop-oldest shedding,
//! deadline batching, bounded ticket waits, graceful drain, and — the
//! load-bearing property — shard count not changing model outputs.

use std::time::{Duration, Instant};

use ns_lbp::coordinator::{ArchSim, Coordinator, CoordinatorConfig};
use ns_lbp::engine::{BackendKind, QosClass};
use ns_lbp::params::synth::synth_params;
use ns_lbp::params::NetParams;
use ns_lbp::sensor::Frame;
use ns_lbp::serve::batcher::{BatchPolicy, Batcher};
use ns_lbp::serve::queue::{BoundedQueue, PushError};
use ns_lbp::serve::{InferResponse, Request, Server};

fn synth_frames(n: usize, seed: u64) -> (NetParams, Vec<Frame>) {
    let (_, params) = synth_params(5);
    let frames = ns_lbp::testing::synth_frames(&params, n, seed).unwrap();
    (params, frames)
}

fn serve_all(params: &NetParams, frames: &[Frame], shards: usize,
             arch: ArchSim) -> Vec<InferResponse> {
    let mut config = CoordinatorConfig { arch, ..Default::default() };
    config.system.serve.shards = shards;
    config.system.serve.max_batch = 4;
    config.system.serve.batch_deadline_us = 300;
    config.system.serve.queue_depth = frames.len().max(1);
    let server = Server::start(params.clone(), config).unwrap();
    let tickets: Vec<_> = frames
        .iter()
        .map(|f| server.submit(Request::from_frame(f.clone())).unwrap())
        .collect();
    let mut responses: Vec<InferResponse> =
        tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    let report = server.drain().unwrap();
    assert_eq!(report.completed, frames.len() as u64);
    assert_eq!(report.arch_mismatches, 0);
    responses.sort_by_key(|r| r.seq());
    responses
}

#[test]
fn queue_backpressure_full_queue_rejects() {
    let q: BoundedQueue<u32> = BoundedQueue::new(3);
    for i in 0..3 {
        q.try_push(i).unwrap();
    }
    let (err, rejected) = q.try_push(99).unwrap_err();
    assert_eq!(err, PushError::Full);
    assert_eq!(rejected, 99); // the item comes back to the caller
    assert_eq!(q.len(), 3); // nothing was dropped to make room
    q.pop().unwrap();
    q.try_push(99).unwrap(); // space reopens after a pop
}

#[test]
fn batcher_deadline_flushes_partial_batch() {
    let q: BoundedQueue<u32> = BoundedQueue::new(16);
    q.try_push(7).unwrap();
    q.try_push(8).unwrap();
    let deadline = Duration::from_millis(30);
    let b = Batcher::new(&q, BatchPolicy { max_batch: 64, max_delay: deadline });
    let t0 = Instant::now();
    let batch = b.next_batch().unwrap();
    let waited = t0.elapsed();
    // far short of max_batch, the deadline ships what is there
    assert_eq!(batch, vec![7, 8]);
    assert!(waited >= Duration::from_millis(25), "flushed early: {waited:?}");
    assert!(waited < Duration::from_secs(2), "deadline ignored: {waited:?}");
}

#[test]
fn server_admission_control_rejects_past_depth() {
    let (params, frames) = synth_frames(1, 9);
    let mut config = CoordinatorConfig {
        // the slow architectural path: each frame takes milliseconds, so
        // the pipeline saturates while the µs-scale submit loop runs
        arch: ArchSim { lbp: true, mlp: false, early_exit: false },
        ..Default::default()
    };
    config.system.serve.shards = 1;
    config.system.serve.queue_depth = 2;
    config.system.serve.max_batch = 1;
    config.system.serve.batch_deadline_us = 1;
    let server = Server::start(params, config).unwrap();

    // at most 1 (processing) + 2 (batch queue) + 1 (batcher in hand)
    // + 2 (request queue) = 6 frames can be in flight; the rest of the
    // burst must bounce off admission control (standard class rejects
    // the newest rather than dropping the oldest)
    let mut tickets = Vec::new();
    let mut rejected = 0;
    for _ in 0..16 {
        match server.submit(Request::from_frame(frames[0].clone())) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                rejected += 1;
                assert!(e.to_string().contains("admission"), "{e}");
            }
        }
    }
    assert!(rejected > 0, "overfilling a depth-2 queue must reject");
    for t in tickets {
        t.wait().unwrap();
    }
    let report = server.drain().unwrap();
    assert_eq!(report.rejected, rejected);
    assert_eq!(report.completed + report.rejected, 16);
    assert_eq!(report.dropped, 0);
    let std_class = report.class(QosClass::Standard).unwrap();
    assert_eq!(std_class.rejected, rejected);
}

#[test]
fn shard_determinism_one_vs_four_shards() {
    let (params, frames) = synth_frames(16, 21);
    let arch = ArchSim { lbp: true, mlp: false, early_exit: false };
    let one = serve_all(&params, &frames, 1, arch);
    let four = serve_all(&params, &frames, 4, arch);
    assert_eq!(one.len(), frames.len());
    assert_eq!(four.len(), frames.len());
    // four shards actually participated
    let shards_used: std::collections::BTreeSet<usize> =
        four.iter().map(|r| r.shard).collect();
    assert!(shards_used.len() > 1, "all frames landed on one shard");
    // ... and sharding changed no model output whatsoever
    for (a, b) in one.iter().zip(&four) {
        assert_eq!(a.seq(), b.seq());
        assert_eq!(a.report.logits, b.report.logits, "frame {}", a.seq());
        assert_eq!(a.predicted(), b.predicted());
    }
    // the serve path agrees with the plain coordinator run loop too
    let coord = Coordinator::new(
        params,
        CoordinatorConfig { arch, ..Default::default() },
    )
    .unwrap();
    let mut handle = coord.frame_handle().unwrap();
    for r in &one {
        let direct = handle.process(&frames[r.seq() as usize]).unwrap();
        assert_eq!(direct.logits, r.report.logits);
    }
}

#[test]
fn drain_completes_every_admitted_frame() {
    let (params, frames) = synth_frames(12, 33);
    let mut config = CoordinatorConfig {
        arch: ArchSim { lbp: false, mlp: false, early_exit: false },
        ..Default::default()
    };
    config.system.serve.shards = 2;
    config.system.serve.max_batch = 5;
    config.system.serve.batch_deadline_us = 200;
    config.system.serve.queue_depth = 64;
    let server = Server::start(params, config).unwrap();
    let tickets: Vec<_> = frames
        .iter()
        .map(|f| server.submit(Request::from_frame(f.clone())).unwrap())
        .collect();
    // drain without waiting on tickets first: the graceful path must
    // still deliver every admitted frame before returning
    let report = server.drain().unwrap();
    assert_eq!(report.accepted, 12);
    assert_eq!(report.completed, 12);
    for t in tickets {
        let r = t.try_take().expect("drained server left a pending ticket");
        r.unwrap();
    }
}

/// The acceptance-criteria scenario: two classes routed to two different
/// backends through one server, with per-class latency and drop/reject
/// metrics in the final report — and identical logits for identical
/// frames regardless of which class (and therefore backend) served them.
#[test]
fn routed_two_class_serve_reports_per_class_metrics() {
    let (params, frames) = synth_frames(6, 77);
    let mut config = CoordinatorConfig {
        arch: ArchSim { lbp: true, mlp: false, early_exit: false },
        ..Default::default()
    };
    config.system.engine.backend = BackendKind::Functional;
    config.system.engine.routing
        .set(QosClass::BestEffort, BackendKind::Functional);
    config.system.engine.routing
        .set(QosClass::Billed, BackendKind::Architectural);
    config.system.serve.shards = 2;
    config.system.serve.max_batch = 4;
    config.system.serve.queue_depth = 64;
    config.system.serve.batch_deadline_us = 300;
    let server = Server::start(params, config).unwrap();

    // two sensor streams, one per class, submitting the *same* frames
    let cheap = server.session(1).with_class(QosClass::BestEffort);
    let billed = server.session(2).with_class(QosClass::Billed);
    let mut tickets = Vec::new();
    for f in &frames {
        tickets.push(cheap.submit(f.clone()).unwrap());
        tickets.push(billed.submit(f.clone()).unwrap());
    }
    let mut responses: Vec<InferResponse> =
        tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    for r in &responses {
        match r.class {
            QosClass::BestEffort => {
                assert_eq!(r.sensor_id, 1);
                assert_eq!(r.backend, BackendKind::Functional);
                // the cheap path models no hardware time
                assert_eq!(r.report.telemetry.cost.time_ns, 0.0);
            }
            QosClass::Billed => {
                assert_eq!(r.sensor_id, 2);
                assert_eq!(r.backend, BackendKind::Architectural);
                assert!(r.report.telemetry.cost.time_ns > 0.0);
                assert_eq!(r.report.telemetry.arch_mismatches, 0);
            }
            QosClass::Standard => panic!("no standard traffic submitted"),
        }
    }
    // same frame, either backend, same logits
    responses.sort_by_key(|r| (r.sensor_id, r.seq()));
    let (cheap_rs, billed_rs) = responses.split_at(frames.len());
    for (a, b) in cheap_rs.iter().zip(billed_rs) {
        assert_eq!(a.seq(), b.seq());
        assert_eq!(a.report.logits, b.report.logits, "frame {}", a.seq());
    }

    drop(cheap);
    drop(billed);
    let report = server.drain().unwrap();
    assert_eq!(report.completed, 2 * frames.len() as u64);
    assert_eq!(report.arch_mismatches, 0);
    let be = report.class(QosClass::BestEffort).unwrap();
    assert_eq!(be.accepted, frames.len() as u64);
    assert_eq!(be.completed, frames.len() as u64);
    assert_eq!(be.rejected + be.dropped + be.failed, 0);
    assert!(be.p50_ms > 0.0);
    assert!(be.p50_ms <= be.p95_ms && be.p95_ms <= be.p99_ms);
    let bl = report.class(QosClass::Billed).unwrap();
    assert_eq!(bl.completed, frames.len() as u64);
    assert!(bl.p50_ms > 0.0);
    assert!(bl.p50_ms <= bl.p99_ms);
    let std_class = report.class(QosClass::Standard).unwrap();
    assert!(!std_class.active(), "no standard traffic was offered");
}

/// Best-effort admission under overload sheds the *oldest* queued frame
/// (fresh sensor pixels win), resolves the shed ticket with an error,
/// and accounts every shed in the per-class drop counter.
#[test]
fn drop_oldest_sheds_stale_best_effort_frames() {
    let (params, frames) = synth_frames(1, 88);
    let mut config = CoordinatorConfig {
        arch: ArchSim { lbp: true, mlp: false, early_exit: false },
        ..Default::default()
    };
    config.system.serve.shards = 1;
    config.system.serve.max_batch = 1;
    config.system.serve.batch_deadline_us = 1;
    config.system.serve.classes[QosClass::BestEffort.index()].queue_depth =
        Some(2);
    let server = Server::start(params, config).unwrap();
    let cam = server.session(7).with_class(QosClass::BestEffort);
    // 16 fast submits into a depth-2 queue over a ms-per-frame backend:
    // every submit is accepted (never rejected), the backlog is shed
    let tickets: Vec<_> = (0..16)
        .map(|_| cam.submit(frames[0].clone()).unwrap())
        .collect();
    let mut completed = 0u64;
    let mut dropped = 0u64;
    for t in tickets {
        match t.wait() {
            Ok(r) => {
                assert_eq!(r.class, QosClass::BestEffort);
                completed += 1;
            }
            Err(e) => {
                assert!(e.to_string().contains("dropped"), "{e}");
                dropped += 1;
            }
        }
    }
    assert!(dropped > 0, "a depth-2 drop-oldest queue must shed backlog");
    drop(cam);
    let report = server.drain().unwrap();
    let be = report.class(QosClass::BestEffort).unwrap();
    assert_eq!(be.accepted, 16);
    assert_eq!(be.rejected, 0);
    assert_eq!(be.dropped, dropped);
    assert_eq!(be.completed, completed);
    assert_eq!(report.completed + report.dropped, 16);
}

/// A per-request deadline bounds queue staleness: a request still queued
/// past its deadline is shed at dispatch, not inferred.
#[test]
fn per_request_deadline_expires_stale_requests() {
    let (params, frames) = synth_frames(1, 91);
    let mut config = CoordinatorConfig {
        arch: ArchSim { lbp: false, mlp: false, early_exit: false },
        ..Default::default()
    };
    config.system.serve.shards = 1;
    config.system.serve.max_batch = 8;
    // the lone frame waits out the full 2 ms batch deadline, far past
    // its 1 µs freshness bound
    config.system.serve.batch_deadline_us = 2000;
    let server = Server::start(params, config).unwrap();
    let cam = server
        .session(3)
        .with_class(QosClass::Billed)
        .with_deadline(Duration::from_micros(1));
    let ticket = cam.submit(frames[0].clone()).unwrap();
    let err = ticket.wait().unwrap_err();
    assert!(err.to_string().contains("deadline expired"), "{err}");
    drop(cam);
    let report = server.drain().unwrap();
    let bl = report.class(QosClass::Billed).unwrap();
    assert_eq!(bl.accepted, 1);
    assert_eq!(bl.dropped, 1);
    assert_eq!(bl.completed, 0);
}

/// Trace-feed well-formedness under concurrent shards: a traced routed
/// run writes a JSONL feed where every line parses through the shared
/// flat-JSON reader, every admitted request's lifecycle balances (one
/// submit, exactly one terminal event, exactly one queue-wait span), the
/// ring dropped nothing at this load, and the span-derived summary
/// reproduces the metrics report's end-to-end p99 exactly — both sides
/// percentile the identical latency samples.
#[test]
fn traced_serve_feed_is_balanced_and_matches_report() {
    use std::collections::BTreeMap;

    let (params, frames) = synth_frames(12, 123);
    let mut config = CoordinatorConfig {
        // the billed class's architectural engines simulate the in-SRAM
        // LBP stage, so their Infer spans carry a nonzero cycle model
        arch: ArchSim { lbp: true, mlp: false, early_exit: false },
        ..Default::default()
    };
    config.system.engine.routing
        .set(QosClass::Billed, BackendKind::Architectural);
    config.system.serve.shards = 2;
    config.system.serve.max_batch = 4;
    config.system.serve.batch_deadline_us = 300;
    config.system.serve.queue_depth = 64;
    let dir = std::env::temp_dir().join(format!(
        "nslbp-serve-trace-{}", std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let feed_path = dir.join("serve.jsonl");
    config.system.obs.enabled = true;
    config.system.obs.jsonl_path = feed_path.to_str().unwrap().to_string();
    let server = Server::start(params, config).unwrap();

    // two classes → two backends → disjoint shard engines, all tracing
    // into one ring concurrently
    let cam0 = server.session(0);
    let cam1 = server.session(1).with_class(QosClass::Billed);
    let mut tickets = Vec::new();
    for f in &frames {
        tickets.push(cam0.submit(f.clone()).unwrap());
        tickets.push(cam1.submit(f.clone()).unwrap());
    }
    for t in tickets {
        t.wait().unwrap();
    }
    drop(cam0);
    drop(cam1);
    let report = server.drain().unwrap();
    assert_eq!(report.completed, 2 * frames.len() as u64);

    let feed = std::fs::read_to_string(&feed_path).unwrap();
    #[derive(Default)]
    struct Life {
        submits: u64,
        queues: u64,
        terminals: u64,
    }
    let mut lives: BTreeMap<(String, u64, u64), Life> = BTreeMap::new();
    for (i, line) in feed.lines().enumerate() {
        let fields = ns_lbp::obs::json::parse_flat_object(line)
            .unwrap_or_else(|e| panic!("feed line {}: {e}", i + 1));
        let get = |k: &str| {
            fields.iter().find(|(n, _)| n == k).map(|(_, v)| v)
        };
        let kind = get("kind")
            .and_then(|v| v.as_str())
            .expect("every record carries a kind")
            .to_string();
        if !matches!(kind.as_str(),
                     "submit" | "reject" | "queue" | "complete" | "drop"
                     | "expire" | "fail") {
            continue; // batch/infer/phase/gauge are not per-request
        }
        let class = get("class").and_then(|v| v.as_str()).unwrap().into();
        let sensor = get("sensor_id").and_then(|v| v.as_u64()).unwrap();
        let seq = get("seq").and_then(|v| v.as_u64()).unwrap();
        let life = lives.entry((class, sensor, seq)).or_default();
        match kind.as_str() {
            "submit" => life.submits += 1,
            "queue" => life.queues += 1,
            _ => life.terminals += 1, // complete/drop/expire/fail
        }
    }
    assert_eq!(lives.len(), 2 * frames.len(),
               "one lifecycle per admitted request");
    for ((class, sensor, seq), life) in &lives {
        let at = format!("{class} sensor {sensor} seq {seq}");
        assert_eq!(life.submits, 1, "{at}: submit count");
        assert_eq!(life.terminals, 1, "{at}: terminal count");
        assert_eq!(life.queues, 1, "{at}: queue-wait span count");
    }

    let summary = ns_lbp::obs::summarize(&feed).unwrap();
    assert_eq!(summary.events_dropped, 0, "ring overflowed at test load");
    assert_eq!(summary.completed.iter().sum::<u64>(), report.completed);
    assert_eq!(summary.completed[QosClass::Billed.index()],
               frames.len() as u64);
    // Complete spans carry the very latency samples the metrics
    // reservoir percentiles, so the two p99s agree to the nanosecond
    // (compared with float slack: the report keeps milliseconds)
    let trace_p99_ms = summary.e2e_ns.2 as f64 / 1e6;
    assert!((trace_p99_ms - report.p99_ms).abs()
                <= report.p99_ms * 1e-6 + 1e-9,
            "trace p99 {trace_p99_ms} ms != report p99 {} ms",
            report.p99_ms);
    assert!(summary.modeled_ns > 0, "billed infer spans carry cost model");
    assert!(summary.energy_pj.0 > 0.0 && summary.energy_pj.1 > 0.0);

    std::fs::remove_dir_all(&dir).ok();
}

/// The async plane is an execution strategy, not a math change: the same
/// frames through the event-driven plane (small worker pool, DRR
/// fairness, autoscaling shard pool) and through the thread-per-stage
/// plane yield bit-identical logits per (sensor, seq) — even though the
/// async run may grow and shrink its engine pool mid-stream.
#[test]
fn async_plane_logits_bit_identical_to_threaded() {
    use std::collections::BTreeMap;

    let (params, frames) = synth_frames(16, 55);
    let arch = ArchSim { lbp: true, mlp: false, early_exit: false };
    let sensors = 4u32;
    let run = |event_driven: bool| -> BTreeMap<(u32, u64), (Vec<f32>, usize)> {
        let mut config = CoordinatorConfig { arch, ..Default::default() };
        config.system.serve.shards = 2;
        config.system.serve.max_batch = 4;
        config.system.serve.batch_deadline_us = 200;
        config.system.serve.queue_depth = 64;
        if event_driven {
            config.system.serve.async_plane.enabled = true;
            config.system.serve.async_plane.workers = 2;
            config.system.serve.async_plane.min_shards = 1;
            config.system.serve.async_plane.max_shards = 4;
        }
        let server = Server::start(params.clone(), config).unwrap();
        // explicit per-sensor seq stamping, so both planes key responses
        // identically no matter how batches interleave
        let mut seqs = vec![0u64; sensors as usize];
        let tickets: Vec<_> = frames
            .iter()
            .enumerate()
            .map(|(i, f)| {
                let s = i as u32 % sensors;
                let seq = seqs[s as usize];
                seqs[s as usize] += 1;
                server
                    .submit(Request::builder(f.clone().with_seq(seq))
                        .sensor_id(s)
                        .build())
                    .unwrap()
            })
            .collect();
        let mut out = BTreeMap::new();
        for t in tickets {
            let r = t.wait().unwrap();
            out.insert((r.sensor_id, r.seq()),
                       (r.report.logits.clone(), r.predicted()));
        }
        let report = server.drain().unwrap();
        assert_eq!(report.completed, frames.len() as u64);
        assert_eq!(report.dropped + report.rejected + report.failed, 0);
        assert_eq!(report.arch_mismatches, 0);
        out
    };
    let threaded = run(false);
    let evented = run(true);
    assert_eq!(threaded.len(), frames.len());
    assert_eq!(evented.len(), frames.len());
    for (key, (logits, predicted)) in &threaded {
        let (ev_logits, ev_predicted) = &evented[key];
        assert_eq!(logits, ev_logits, "logits diverge at {key:?}");
        assert_eq!(predicted, ev_predicted, "argmax diverges at {key:?}");
    }
}

/// A server dropped without `drain()` orphans whatever was still queued;
/// `Ticket::wait_timeout` bounds the wait instead of blocking forever.
#[test]
fn wait_timeout_never_blocks_forever_on_a_dropped_server() {
    let (params, frames) = synth_frames(8, 99);
    let mut config = CoordinatorConfig {
        arch: ArchSim { lbp: true, mlp: false, early_exit: false },
        ..Default::default()
    };
    config.system.serve.shards = 1;
    config.system.serve.max_batch = 1;
    config.system.serve.batch_deadline_us = 1;
    config.system.serve.queue_depth = 64;
    let server = Server::start(params, config).unwrap();
    let tickets: Vec<_> = frames
        .iter()
        .map(|f| server.submit(Request::from_frame(f.clone())).unwrap())
        .collect();
    drop(server); // no drain: queues force-closed, backlog may be orphaned
    let t0 = Instant::now();
    let mut resolved = 0;
    let mut orphaned = 0;
    for t in &tickets {
        match t.wait_timeout(Duration::from_millis(100)) {
            Some(_) => resolved += 1,
            None => orphaned += 1,
        }
    }
    assert_eq!(resolved + orphaned, tickets.len());
    // the point of wait_timeout: bounded, no matter what died underneath
    assert!(t0.elapsed() < Duration::from_secs(5),
            "wait_timeout failed to bound the wait");
}
