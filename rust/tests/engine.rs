//! Engine-layer integration tests: trait-level backend parity, the
//! builder's backend/cross-check selection, and end-to-end engine use by
//! the serving layer — generalizing the earlier ad-hoc 1-vs-4-shard
//! determinism check into "any two available backends agree on logits".

use ns_lbp::coordinator::{ArchSim, CoordinatorConfig};
use ns_lbp::engine::{ArchitecturalBackend, BackendKind, Engine, EngineConfig,
                     FunctionalBackend, InferenceBackend};
use ns_lbp::params::synth::synth_params;
use ns_lbp::params::NetParams;
use ns_lbp::sensor::Frame;
use ns_lbp::serve::{Request, Server};
use ns_lbp::testing::synth_frames;

fn setup(n: usize, seed: u64) -> (NetParams, Vec<Frame>) {
    let (_, params) = synth_params(5);
    let frames = synth_frames(&params, n, seed).unwrap();
    (params, frames)
}

/// Trait-level parity: every available backend produces identical logits
/// (and identical argmax classes) on the same seeded random frames.
#[test]
fn functional_and_architectural_backends_agree_on_logits() {
    let (params, frames) = setup(6, 41);
    let config = EngineConfig {
        arch: ArchSim { lbp: true, mlp: true, early_exit: false },
        ..Default::default()
    };
    let mut backends: Vec<Box<dyn InferenceBackend>> = vec![
        Box::new(FunctionalBackend::new(params.clone(), &config).unwrap()),
        Box::new(ArchitecturalBackend::new(params.clone(), config.clone())
            .unwrap()),
    ];
    let outputs: Vec<_> = backends
        .iter_mut()
        .map(|b| {
            assert!(b.capabilities().available, "{}", b.kind());
            b.infer_batch(&frames).unwrap()
        })
        .collect();
    let reference = &outputs[0];
    for (b, out) in backends.iter().zip(&outputs) {
        assert_eq!(out.frames.len(), frames.len(), "{}", b.kind());
        for (r, f) in reference.frames.iter().zip(&out.frames) {
            assert_eq!(r.seq, f.seq);
            assert_eq!(r.logits, f.logits,
                       "backend {} diverges on frame {}", b.kind(), f.seq);
            assert_eq!(r.predicted, f.predicted);
        }
        // the architectural path's internal bit-level check must be clean
        assert_eq!(out.telemetry().arch_mismatches, 0, "{}", b.kind());
    }
    // only the architectural backend models hardware time
    assert_eq!(outputs[0].telemetry().cost.time_ns, 0.0);
    assert!(outputs[1].telemetry().cost.time_ns > 0.0);
}

/// The engine's pluggable cross-check: architectural primary vs
/// functional reference, zero mismatches, counts present in telemetry.
#[test]
fn engine_cross_check_is_clean_and_counted() {
    let (params, frames) = setup(4, 43);
    let mut engine = Engine::builder()
        .params(params)
        .backend(BackendKind::Architectural)
        .cross_check(BackendKind::Functional)
        .build()
        .unwrap();
    let out = engine.infer_batch(&frames).unwrap();
    assert_eq!(out.frames.len(), 4);
    let t = engine.telemetry();
    assert_eq!(t.cross_check_frames, 4);
    assert_eq!(t.cross_check_mismatches, 0);
    assert_eq!(t.arch_mismatches, 0);
}

/// Backend selection flows from the config (`engine.backend`), and the
/// builder override wins over it.
#[test]
fn backend_selection_from_config_and_builder() {
    let (params, frames) = setup(1, 47);
    let mut config = CoordinatorConfig::default();
    config.system.engine.backend = BackendKind::Functional;
    let mut from_config = Engine::builder()
        .config(config.clone())
        .params(params.clone())
        .build()
        .unwrap();
    assert_eq!(from_config.kind(), BackendKind::Functional);
    let mut overridden = Engine::builder()
        .config(config)
        .params(params)
        .backend(BackendKind::Architectural)
        .build()
        .unwrap();
    assert_eq!(overridden.kind(), BackendKind::Architectural);
    let a = from_config.infer_frame(&frames[0]).unwrap();
    let b = overridden.infer_frame(&frames[0]).unwrap();
    assert_eq!(a.logits, b.logits);
}

/// The serving layer inherits the engine's backend selection: a
/// functional-backend server and an architectural-backend server return
/// identical logits on the same frames.
#[test]
fn serve_layer_backend_parity() {
    let (params, frames) = setup(8, 53);
    let mut logits_by_kind = Vec::new();
    for kind in [BackendKind::Functional, BackendKind::Architectural] {
        let mut config = CoordinatorConfig::default();
        config.system.engine.backend = kind;
        config.system.serve.shards = 2;
        config.system.serve.max_batch = 4;
        config.system.serve.queue_depth = frames.len();
        let server = Server::start(params.clone(), config).unwrap();
        let tickets: Vec<_> = frames
            .iter()
            .map(|f| server.submit(Request::from_frame(f.clone())).unwrap())
            .collect();
        let mut responses: Vec<_> =
            tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        responses.sort_by_key(|r| r.seq());
        let report = server.drain().unwrap();
        assert_eq!(report.completed, frames.len() as u64);
        assert_eq!(report.arch_mismatches, 0);
        logits_by_kind.push(
            responses
                .into_iter()
                .map(|r| r.report.logits)
                .collect::<Vec<_>>(),
        );
    }
    assert_eq!(logits_by_kind[0], logits_by_kind[1]);
}

/// Cross-check mismatch counts surface in the serve metrics report.
#[test]
fn serve_layer_reports_cross_check_counts() {
    let (params, frames) = setup(3, 59);
    let mut config = CoordinatorConfig::default();
    config.system.engine.cross_check = Some(BackendKind::Functional);
    config.system.serve.shards = 1;
    config.system.serve.queue_depth = frames.len();
    let server = Server::start(params, config).unwrap();
    let tickets: Vec<_> = frames
        .iter()
        .map(|f| server.submit(Request::from_frame(f.clone())).unwrap())
        .collect();
    for t in tickets {
        let r = t.wait().unwrap();
        assert_eq!(r.report.telemetry.cross_check_frames, 1);
        assert_eq!(r.report.telemetry.cross_check_mismatches, 0);
    }
    let report = server.drain().unwrap();
    assert_eq!(report.cross_checked, 3);
    assert_eq!(report.cross_check_mismatches, 0);
}

/// Whole-batch dispatch parity (the acceptance-criteria test): for both
/// in-tree backends, one `infer_batch` over N frames produces exactly
/// the logits of N per-frame `infer_frame` calls — so the batch-aware
/// paths (weight-stationary functional MLP, architectural multi-frame
/// sub-array packing) change cost, never results.
#[test]
fn batched_and_per_frame_logits_match_on_both_backends() {
    let (params, frames) = setup(5, 67);
    // early_exit matters for the architectural path: a packed chunk may
    // carry lanes from two frames, and the exit must still wait for
    // every lane — parity has to hold in both modes
    for (kind, early_exit) in [
        (BackendKind::Functional, false),
        (BackendKind::Architectural, false),
        (BackendKind::Architectural, true),
    ] {
        let config = EngineConfig {
            arch: ArchSim { lbp: true, mlp: true, early_exit },
            ..Default::default()
        };
        let mut batched_engine = Engine::builder()
            .config(config.clone())
            .params(params.clone())
            .backend(kind)
            .build()
            .unwrap();
        let mut per_frame_engine = Engine::builder()
            .config(config)
            .params(params.clone())
            .backend(kind)
            .build()
            .unwrap();
        let batched = batched_engine.infer_batch(&frames).unwrap();
        assert_eq!(batched.frames.len(), frames.len(), "{kind}");
        for (frame, out) in frames.iter().zip(&batched.frames) {
            let single = per_frame_engine.infer_frame(frame).unwrap();
            assert_eq!(single.seq, out.seq, "{kind}");
            assert_eq!(single.logits, out.logits,
                       "backend {kind} batch/per-frame divergence on \
                        frame {}", out.seq);
            assert_eq!(single.predicted, out.predicted);
        }
        assert_eq!(batched.telemetry().arch_mismatches, 0, "{kind}");
        if kind == BackendKind::Architectural {
            // batched fleet passes amortize: the batch's modeled time is
            // below the per-frame sum (5x the chunks, same pass count
            // under the default 320-sub-array budget)
            assert!(batched.telemetry().cost.time_ns
                        < per_frame_engine.telemetry().cost.time_ns,
                    "no sub-array pass packing across the batch");
        }
    }
}

/// Persistent-scratch-arena regression (the PR-5 acceptance test): a
/// *warm* engine — one that has already served several batches and so
/// reuses sized arena buffers, prepacked weight planes, and a dirty
/// scratch sub-array — must be bit-identical to a *cold* (freshly
/// built) engine on the same frames, for both in-tree backends, with
/// identical per-frame telemetry counters.
#[test]
fn warm_reused_scratch_engines_match_cold_engines_bitwise() {
    let (params, warmup) = setup(7, 71);
    let frames = synth_frames(&params, 3, 73).unwrap();
    for kind in [BackendKind::Functional, BackendKind::Architectural] {
        let config = EngineConfig {
            arch: ArchSim { lbp: true, mlp: true, early_exit: false },
            ..Default::default()
        };
        let mut warm = Engine::builder()
            .config(config.clone())
            .params(params.clone())
            .backend(kind)
            .build()
            .unwrap();
        // heat the arena across varied batch shapes (grow, shrink, grow)
        warm.infer_batch(&warmup[..5]).unwrap();
        warm.infer_batch(&warmup[5..6]).unwrap();
        warm.infer_batch(&warmup).unwrap();
        let got = warm.infer_batch(&frames).unwrap();

        let mut cold = Engine::builder()
            .config(config)
            .params(params.clone())
            .backend(kind)
            .build()
            .unwrap();
        let want = cold.infer_batch(&frames).unwrap();

        assert_eq!(got.frames.len(), want.frames.len(), "{kind}");
        for (g, w) in got.frames.iter().zip(&want.frames) {
            assert_eq!(g.seq, w.seq, "{kind}");
            assert_eq!(g.logits, w.logits,
                       "warm/cold divergence on backend {kind} frame {}",
                       g.seq);
            assert_eq!(g.features, w.features, "{kind} frame {}", g.seq);
            assert_eq!(g.predicted, w.predicted, "{kind}");
            assert_eq!(g.telemetry.exec, w.telemetry.exec, "{kind}");
            assert_eq!(g.telemetry.dpu, w.telemetry.dpu, "{kind}");
            assert_eq!(g.telemetry.arch_mismatches, 0, "{kind}");
        }
    }
}

/// Without the `pjrt` cargo feature the PJRT backend must fail at
/// build time with the capabilities detail, not on the first frame.
#[test]
fn pjrt_selection_fails_early_when_unavailable() {
    if ns_lbp::runtime::pjrt_available() {
        return;
    }
    let (params, _) = setup(1, 61);
    let err = Engine::builder()
        .params(params)
        .backend(BackendKind::Pjrt)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("unavailable"), "{err}");
}

/// Regression: enabling cross-checking must not inflate the primary
/// profile's energy/time accounting.  The reference backend's redundant
/// run lands in `Telemetry::cross_check_cost` — strictly apart from the
/// primary `cost` — so a cross-checked run reports exactly the same
/// primary energy as an unchecked one.
#[test]
fn cross_check_does_not_inflate_primary_cost() {
    let (params, frames) = setup(3, 47);
    let mut plain = Engine::builder()
        .params(params.clone())
        .backend(BackendKind::Architectural)
        .no_cross_check()
        .build()
        .unwrap();
    let mut checked = Engine::builder()
        .params(params)
        .backend(BackendKind::Architectural)
        .cross_check(BackendKind::Functional)
        .build()
        .unwrap();
    let out_plain = plain.infer_batch(&frames).unwrap();
    let out_checked = checked.infer_batch(&frames).unwrap();

    let (tp, tc) = (out_plain.telemetry(), out_checked.telemetry());
    // primary accounting identical, frame by frame and in aggregate
    for (a, b) in out_plain.frames.iter().zip(&out_checked.frames) {
        assert_eq!(a.telemetry.cost, b.telemetry.cost, "frame {}", a.seq);
        assert_eq!(a.telemetry.profile, b.telemetry.profile);
    }
    assert_eq!(tp.cost, tc.cost);
    assert_eq!(tp.profile, "ns_lbp_65nm");
    // ... while the reference run's cost is visible, but separate
    assert_eq!(tp.cross_check_cost, ns_lbp::hw::Cost::default());
    assert!(tc.cross_check_cost.energy.total_pj() > 0.0);
    assert_eq!(tc.cross_check_frames, 3);
    assert_eq!(tc.cross_check_mismatches, 0);
    // engine-accumulated telemetry obeys the same split
    assert_eq!(checked.telemetry().cost, plain.telemetry().cost);
    assert!(checked.telemetry().cross_check_cost.energy.total_pj() > 0.0);
}

/// The builder's `--hw-profile` override re-prices telemetry without
/// changing logits, and stamps the profile name on every frame.
#[test]
fn hw_profile_override_reprices_without_changing_results() {
    use ns_lbp::hw::HwProfile;
    let (params, frames) = setup(2, 53);
    let mut base = Engine::builder()
        .params(params.clone())
        .backend(BackendKind::Architectural)
        .build()
        .unwrap();
    let mut prior = Engine::builder()
        .params(params)
        .backend(BackendKind::Architectural)
        .hw_profile(HwProfile::sram38_28nm())
        .build()
        .unwrap();
    let out_base = base.infer_batch(&frames).unwrap();
    let out_prior = prior.infer_batch(&frames).unwrap();
    for (a, b) in out_base.frames.iter().zip(&out_prior.frames) {
        assert_eq!(a.logits, b.logits, "frame {}", a.seq);
        assert_eq!(a.telemetry.profile, "ns_lbp_65nm");
        assert_eq!(b.telemetry.profile, "sram38_28nm");
        // same trace, costlier platform
        assert_eq!(a.telemetry.exec, b.telemetry.exec);
        assert!(b.telemetry.cost.energy.total_pj()
                    > a.telemetry.cost.energy.total_pj());
        assert!(b.telemetry.cost.time_ns > a.telemetry.cost.time_ns);
    }
}
