//! Cross-module integration tests: config → coordinator → energy reports,
//! manifest integrity, CLI parsing, and the SVHN-sized network on the
//! architectural path.  (PJRT round-trips live in golden_model.rs.)

use ns_lbp::config::SystemConfig;
use ns_lbp::coordinator::{ArchSim, Coordinator, CoordinatorConfig};
use ns_lbp::energy::EnergyModel;
use ns_lbp::rng::Xoshiro256;
use ns_lbp::runtime::read_manifest;
use ns_lbp::sensor::{ReplaySensor, SensorConfig};

use ns_lbp::testing::artifact_params as try_params;

fn artifacts_dir() -> String {
    std::env::var("NSLBP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

#[test]
fn default_config_file_parses_to_paper_setup() {
    let sc = SystemConfig::load(Some("configs/nslbp_default.toml"), &[]).unwrap();
    assert_eq!(sc, SystemConfig::default());
}

#[test]
fn config_overrides_stack_on_file() {
    let sc = SystemConfig::load(
        Some("configs/nslbp_default.toml"),
        &["cache.banks=10".into(), "circuit.freq_ghz=1.0".into()],
    )
    .unwrap();
    assert_eq!(sc.cache.banks, 10);
    assert!((sc.circuit.freq_ghz - 1.0).abs() < 1e-12);
}

#[test]
fn manifest_lists_all_artifacts_and_files_exist() {
    let dir = artifacts_dir();
    if !std::path::Path::new(&dir).join("manifest.tsv").exists() {
        eprintln!("skipping: {dir}/manifest.tsv missing — run `make artifacts`");
        return;
    }
    let entries = read_manifest(std::path::Path::new(&dir)).unwrap();
    let names: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
    for want in ["aplbp_mnist", "features_mnist", "aplbp_svhn", "features_svhn",
                 "lbp_encode_unit", "bitserial_unit", "params_mnist",
                 "params_svhn"] {
        assert!(names.contains(&want), "manifest missing {want}");
    }
    for e in &entries {
        let p = std::path::Path::new(&dir).join(&e.file);
        assert!(p.exists(), "artifact file missing: {}", p.display());
    }
}

#[test]
fn mnist_pipeline_end_to_end_with_energy_report() {
    let Some(params) = try_params("mnist") else { return };
    let cfg = params.config;
    let system = SystemConfig::load(Some("configs/nslbp_default.toml"), &[]).unwrap();
    let coord = Coordinator::new(
        params,
        CoordinatorConfig { system, arch: ArchSim::default(), shard: None },
    )
    .unwrap();

    let scfg = SensorConfig {
        rows: cfg.height, cols: cfg.width, channels: cfg.in_channels,
        skip_lsbs: cfg.apx_pixel, ..Default::default()
    };
    let mut rng = Xoshiro256::new(99);
    let scenes: Vec<Vec<f64>> = (0..5)
        .map(|_| (0..scfg.pixels()).map(|_| rng.next_f64()).collect())
        .collect();
    let mut sensor = ReplaySensor::new(scfg, scenes, 3).unwrap();
    let (reports, summary) = coord.run(&mut sensor, 5).unwrap();

    assert_eq!(reports.len(), 5);
    assert_eq!(summary.arch_mismatches, 0);
    // sanity of the modeled physics: per-frame energy in a plausible
    // near-sensor band (µJ scale), latency in the µs scale
    let e = summary.energy_per_frame_uj();
    assert!((0.01..100.0).contains(&e), "energy/frame {e} µJ");
    let fps = summary.frames_per_second_modeled();
    assert!(fps > 1000.0, "modeled fps {fps}");
    // energy must itemize: compute+write dominate an LBP pass
    assert!(summary.energy.compute_pj > 0.0);
    assert!(summary.energy.write_pj > 0.0);
    assert!(summary.energy.sensor_pj > 0.0);
}

#[test]
fn svhn_network_architectural_path_clean() {
    let Some(params) = try_params("svhn") else { return };
    let cfg = params.config;
    assert_eq!(cfg.n_lbp_layers, 8); // the paper's 10-block SVHN network
    let coord = Coordinator::new(
        params,
        CoordinatorConfig::default(), // arch lbp on
    )
    .unwrap();
    let scfg = SensorConfig {
        rows: cfg.height, cols: cfg.width, channels: cfg.in_channels,
        skip_lsbs: cfg.apx_pixel, ..Default::default()
    };
    let mut rng = Xoshiro256::new(5);
    let scenes: Vec<Vec<f64>> =
        vec![(0..scfg.pixels()).map(|_| rng.next_f64()).collect()];
    let mut sensor = ReplaySensor::new(scfg, scenes, 1).unwrap();
    let (reports, summary) = coord.run(&mut sensor, 1).unwrap();
    assert_eq!(summary.arch_mismatches, 0);
    assert!(reports[0].telemetry.exec.instructions > 10_000); // 8 layers of compares
}

#[test]
fn apx_reduces_energy_on_the_same_frames() {
    // Fig. 4's premise at system level: more approximated bits ⇒ less
    // energy per frame, identical pipeline otherwise.
    let Some(base) = try_params("mnist") else { return };
    let mut energies = Vec::new();
    for apx in [0usize, 2] {
        let mut p = base.clone();
        p.config.apx_code = apx;
        p.config.apx_pixel = apx;
        let cfg = p.config;
        let coord = Coordinator::new(p, CoordinatorConfig::default()).unwrap();
        let scfg = SensorConfig {
            rows: cfg.height, cols: cfg.width, channels: cfg.in_channels,
            skip_lsbs: cfg.apx_pixel, ..Default::default()
        };
        let mut rng = Xoshiro256::new(123);
        let scenes: Vec<Vec<f64>> = (0..2)
            .map(|_| (0..scfg.pixels()).map(|_| rng.next_f64()).collect())
            .collect();
        let mut sensor = ReplaySensor::new(scfg, scenes, 9).unwrap();
        let (_, summary) = coord.run(&mut sensor, 2).unwrap();
        assert_eq!(summary.arch_mismatches, 0);
        energies.push(summary.energy_per_frame_uj());
    }
    assert!(energies[1] < energies[0],
            "apx=2 ({}) not cheaper than apx=0 ({})", energies[1], energies[0]);
}

#[test]
fn headline_numbers_from_config() {
    let system = SystemConfig::load(Some("configs/nslbp_default.toml"), &[]).unwrap();
    let em = EnergyModel::default();
    assert!((em.tops_per_watt(system.cache.cols as u64) - 37.4).abs() < 1e-9);
    assert!((system.circuit.freq_ghz - 1.25).abs() < 1e-12);
    assert_eq!(system.cache.total_bytes(), 2_621_440); // 2.5 MB
}

#[test]
fn cli_surface_parses() {
    use ns_lbp::cli::Command;
    let cmd = Command::new("ns-lbp", "t")
        .subcommand("run", "r")
        .opt("frames", "N", "n")
        .flag("golden", "g");
    let p = cmd
        .parse(&["run".into(), "--frames".into(), "3".into(), "--golden".into()])
        .unwrap();
    assert_eq!(p.subcommand.as_deref(), Some("run"));
    assert_eq!(p.opt_parse::<usize>("frames", 0).unwrap(), 3);
    assert!(p.flag("golden"));
}
