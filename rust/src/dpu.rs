//! The Digital Processing Unit (paper §4.1, Fig. 5a) shared by all banks.
//!
//! The DPU performs the non-bitwise digital steps of the pipeline:
//! activation quantization, the bit-counter + shifter + adder tree of the
//! MLP layer (Fig. 7), and the shifted-ReLU activation.  Every helper is
//! *exact integer math* mirroring `python/compile/model.py`, so the
//! architectural path stays bit-identical to the AOT golden model, and
//! every call is counted in [`DpuStats`] for the energy model.

use crate::error::{Error, Result};

/// DPU activity counters (inputs to the energy model).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DpuStats {
    /// Pooled-activation quantizations.
    pub quantize_ops: u64,
    /// 256-bit population counts.
    pub bitcounts: u64,
    /// Barrel-shifter uses.
    pub shifts: u64,
    /// Adder-tree accumulations.
    pub adds: u64,
    /// Activation-function evaluations (ReLU + requantize).
    pub activations: u64,
    /// Shifted-ReLU mapping evaluations (LBP ofmap pixels).
    pub shifted_relus: u64,
}

impl DpuStats {
    pub fn merge(&mut self, o: &DpuStats) {
        self.quantize_ops += o.quantize_ops;
        self.bitcounts += o.bitcounts;
        self.shifts += o.shifts;
        self.adds += o.adds;
        self.activations += o.activations;
        self.shifted_relus += o.shifted_relus;
    }
}

/// The DPU proper.
#[derive(Clone, Debug, Default)]
pub struct Dpu {
    pub stats: DpuStats,
}

impl Dpu {
    /// Shifted ReLU + approximate mapping of an LBP code to an 8-bit ofmap
    /// pixel: `min(255, 2·max(0, code − 2^{e−1}))` (model.shifted_relu_u8).
    ///
    /// Degenerate widths saturate instead of faulting: `e == 0` (no
    /// samples) uses a zero threshold — `1 << (e-1)` would underflow —
    /// and `e > 32` pins the threshold at `u32::MAX`.
    pub fn shifted_relu_u8(&mut self, code: u32, e: u32) -> u8 {
        self.stats.shifted_relus += 1;
        let half = match e {
            0 => 0,
            _ => 1u32.checked_shl(e - 1).unwrap_or(u32::MAX),
        };
        2u32.saturating_mul(code.saturating_sub(half)).min(255) as u8
    }

    /// Quantize an integer pooled sum to `act_bits` with round-half-up:
    /// `q = (sum · 2·qmax + vmax) // (2·vmax)` (model.forward_lbp).
    pub fn quantize_pooled(&mut self, sum: u32, vmax: u32, act_bits: u32) -> Result<u8> {
        if vmax == 0 {
            return Err(Error::Isa("quantize_pooled: vmax = 0".into()));
        }
        if sum > vmax {
            return Err(Error::Isa(format!(
                "pooled sum {sum} exceeds vmax {vmax}"
            )));
        }
        self.stats.quantize_ops += 1;
        let qmax = (1u32 << act_bits) - 1;
        Ok(((sum as u64 * 2 * qmax as u64 + vmax as u64)
            / (2 * vmax as u64)) as u8)
    }

    /// Population count of a packed row (the Fig.-7 bit-counter).
    pub fn bitcount(&mut self, words: &[u64]) -> u32 {
        self.stats.bitcounts += 1;
        words.iter().map(|w| w.count_ones()).sum()
    }

    /// Population count of the first `lanes` bits of a packed row — one
    /// bit-counter use, identical to masking the row to `lanes` lanes and
    /// calling [`Self::bitcount`], but without materializing the masked
    /// copy (hot path of the in-memory bit-serial dot, §Perf).
    pub fn bitcount_masked(&mut self, words: &[u64], lanes: usize) -> u32 {
        self.stats.bitcounts += 1;
        let full = lanes / 64;
        let mut count: u32 = words[..full].iter().map(|w| w.count_ones()).sum();
        let rem = lanes % 64;
        if rem != 0 {
            count += (words[full] & ((1u64 << rem) - 1)).count_ones();
        }
        count
    }

    /// Barrel shift: `value << amount` (the `×2^{m+n}` step of Fig. 7).
    pub fn shift(&mut self, value: i64, amount: u32) -> i64 {
        self.stats.shifts += 1;
        value << amount
    }

    /// Adder-tree accumulate.
    pub fn add(&mut self, acc: i64, value: i64) -> i64 {
        self.stats.adds += 1;
        acc + value
    }

    /// MLP activation: folded-affine + ReLU-clip + requantize to
    /// `act_bits` (`floor(clip(h·scale + bias, 0, 1)·qmax + 0.5)`),
    /// mirroring `model.mlp_forward` exactly (f32 arithmetic).
    pub fn activation(&mut self, h: i64, scale: f32, bias: f32, act_bits: u32) -> u8 {
        self.stats.activations += 1;
        let qmax = ((1u32 << act_bits) - 1) as f32;
        let v = (h as f32) * scale + bias;
        let v = v.clamp(0.0, 1.0);
        (v * qmax + 0.5).floor() as u8
    }

    /// Final-layer affine (logits): no clipping/quantization.
    pub fn affine(&mut self, h: i64, scale: f32, bias: f32) -> f32 {
        self.stats.adds += 1;
        (h as f32) * scale + bias
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shifted_relu_matches_model() {
        let mut d = Dpu::default();
        assert_eq!(d.shifted_relu_u8(0, 8), 0);
        assert_eq!(d.shifted_relu_u8(128, 8), 0);
        assert_eq!(d.shifted_relu_u8(129, 8), 2);
        assert_eq!(d.shifted_relu_u8(255, 8), 254);
        assert_eq!(d.shifted_relu_u8(255, 4), 255); // saturates for small e
        assert_eq!(d.stats.shifted_relus, 5);
    }

    #[test]
    fn shifted_relu_degenerate_widths_saturate() {
        // regression: e == 0 used to underflow `1 << (e - 1)` and panic
        // in debug builds
        let mut d = Dpu::default();
        assert_eq!(d.shifted_relu_u8(0, 0), 0);
        assert_eq!(d.shifted_relu_u8(5, 0), 10); // zero threshold: 2*code
        assert_eq!(d.shifted_relu_u8(200, 0), 255);
        // e > 32 pins the threshold at u32::MAX -> everything clips to 0
        assert_eq!(d.shifted_relu_u8(u32::MAX, 40), 0);
        // huge codes cannot overflow the doubling
        assert_eq!(d.shifted_relu_u8(u32::MAX, 1), 255);
    }

    #[test]
    fn quantize_pooled_matches_python_formula() {
        let mut d = Dpu::default();
        let vmax = 255 * 16; // pool 4x4
        // python: q = (sum*2*qmax + vmax) // (2*vmax)
        for sum in [0u32, 1, 100, 2000, 4080] {
            let want = ((sum as u64 * 30 + vmax as u64) / (2 * vmax as u64)) as u8;
            assert_eq!(d.quantize_pooled(sum, vmax, 4).unwrap(), want);
        }
        assert_eq!(d.quantize_pooled(vmax, vmax, 4).unwrap(), 15);
        assert!(d.quantize_pooled(vmax + 1, vmax, 4).is_err());
        assert!(d.quantize_pooled(1, 0, 4).is_err());
    }

    #[test]
    fn bitcount_shift_add() {
        let mut d = Dpu::default();
        assert_eq!(d.bitcount(&[0b1011, u64::MAX]), 3 + 64);
        assert_eq!(d.shift(3, 4), 48);
        assert_eq!(d.add(40, 2), 42);
        assert_eq!(d.stats.bitcounts, 1);
        assert_eq!(d.stats.shifts, 1);
        assert_eq!(d.stats.adds, 1);
    }

    #[test]
    fn bitcount_masked_equals_masked_bitcount() {
        let words = [u64::MAX, 0xDEAD_BEEF_0123_4567, u64::MAX, 0];
        let mut d = Dpu::default();
        for lanes in [1usize, 63, 64, 65, 100, 128, 200, 256] {
            // reference: materialize the masked row, then bitcount
            let w = lanes.div_ceil(64);
            let mut masked: Vec<u64> = words[..w].to_vec();
            if lanes % 64 != 0 {
                masked[w - 1] &= (1u64 << (lanes % 64)) - 1;
            }
            let mut dref = Dpu::default();
            let want = dref.bitcount(&masked);
            assert_eq!(d.bitcount_masked(&words, lanes), want, "lanes={lanes}");
        }
        assert_eq!(d.stats.bitcounts, 8);
    }

    #[test]
    fn activation_clamps_and_quantizes() {
        let mut d = Dpu::default();
        // scale chosen so h=100 -> 0.5 -> q=8 (floor(7.5+0.5))
        assert_eq!(d.activation(100, 0.005, 0.0, 4), 8);
        assert_eq!(d.activation(-50, 0.005, 0.0, 4), 0); // relu clip
        assert_eq!(d.activation(1_000_000, 0.005, 0.0, 4), 15); // sat
    }

    #[test]
    fn stats_merge() {
        let mut a = DpuStats { adds: 1, ..Default::default() };
        let b = DpuStats { adds: 2, bitcounts: 3, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.adds, 3);
        assert_eq!(a.bitcounts, 3);
    }
}
