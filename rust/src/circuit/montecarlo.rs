//! Monte-Carlo variation analysis of the RBL / sense-amplifier margins.
//!
//! Reproduces the paper's Fig. 10 methodology (§6.2): post-layout Monte
//! Carlo over process (inter-die) and mismatch (intra-die) variation, "all
//! 256 bit-lines within each NS-LBP sub-array, 200 times, for all possible
//! bit value combinations", at core VDD and 1.25 GHz.  The Cadence Spectre
//! runs are substituted by a parametric Gaussian model (DESIGN.md
//! §Substitutions): each trial draws one process shift for the die plus an
//! independent mismatch term per bit-line for both the RBL level and the
//! SA references, then records the realized sensing margins.
//!
//! Paper headline to reproduce: ≥ ~92 mV minimum margin (observed between
//! the "111" and "011" cases) and zero decision errors at nominal VDD.

use crate::circuit::{ideal_outputs, CircuitParams, SaOutputs};
use crate::rng::Xoshiro256;

/// Summary statistics for one sampled quantity [V].
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Stats {
    pub fn from_samples(xs: &[f64]) -> Self {
        let n = xs.len();
        if n == 0 {
            return Self::default();
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Self { mean, std: var.sqrt(), min, max, n }
    }
}

/// One margin lane: the distance from an RBL level to the reference that
/// must separate it (positive = correctly separated).
#[derive(Clone, Copy, Debug)]
pub struct MarginLane {
    /// Number of '1' cells in the activation ("000" → 0, ..., "111" → 3).
    pub ones: usize,
    /// Which reference (0 → V_R1, 1 → V_R2, 2 → V_R3).
    pub reference: usize,
    /// True if the level must sit *above* the reference.
    pub above: bool,
    pub stats: Stats,
}

/// Full Fig.-10 style report.
#[derive(Clone, Debug)]
pub struct SenseMarginReport {
    /// Realized RBL level stats per number of ones.
    pub levels: [Stats; 4],
    /// Realized reference stats (V_R1..V_R3).
    pub references: [Stats; 3],
    /// All six margin lanes (000<R1, R1<001<R2, R2<011<R3, 111>R3).
    pub lanes: Vec<MarginLane>,
    /// V_Ref placement windows between adjacent level distributions:
    /// `min(samples of level i+1) − max(samples of level i)` for i = 0..3.
    /// This is the paper's "margin between each two combinations" — the
    /// smallest one (between the "111" and "011" clusters) is ~92 mV.
    pub level_gaps: [f64; 3],
    /// Smallest placement window observed [V] (paper: ~0.092 V).
    pub min_margin: f64,
    /// Fraction of samples whose full SA decision differed from ideal.
    pub decision_error_rate: f64,
    pub trials: usize,
    pub bitlines: usize,
}

/// Monte-Carlo engine.
pub struct MonteCarlo {
    pub params: CircuitParams,
    pub trials: usize,
    pub bitlines: usize,
}

impl Default for MonteCarlo {
    fn default() -> Self {
        Self {
            params: CircuitParams::default(),
            trials: 200,   // paper: 200 runs
            bitlines: 256, // paper: all 256 bit-lines
        }
    }
}

impl MonteCarlo {
    pub fn new(params: CircuitParams) -> Self {
        Self { params, ..Self::default() }
    }

    /// Run the sweep; deterministic in `seed`.
    pub fn run(&self, seed: u64) -> SenseMarginReport {
        let mut rng = Xoshiro256::new(seed);
        let p = &self.params;
        let [r1n, r2n, r3n] = p.refs();
        let nominal_refs = [r1n, r2n, r3n];

        let n_samples = self.trials * self.bitlines;
        let mut level_samples: [Vec<f64>; 4] =
            std::array::from_fn(|_| Vec::with_capacity(n_samples));
        let mut ref_samples: [Vec<f64>; 3] =
            std::array::from_fn(|_| Vec::with_capacity(n_samples));
        // (ones, ref index, above)
        let lane_defs: [(usize, usize, bool); 6] = [
            (0, 0, false), // "000" below V_R1
            (1, 0, true),  // "001" above V_R1
            (1, 1, false), // "001" below V_R2
            (2, 1, true),  // "011" above V_R2
            (2, 2, false), // "011" below V_R3
            (3, 2, true),  // "111" above V_R3
        ];
        let mut lane_samples: Vec<Vec<f64>> =
            (0..lane_defs.len()).map(|_| Vec::with_capacity(n_samples)).collect();
        let mut errors = 0usize;
        let mut total = 0usize;

        for _ in 0..self.trials {
            // one inter-die process draw per trial, shared by the whole array
            let process = rng.gauss_ms(0.0, p.sigma_process);
            for _ in 0..self.bitlines {
                // intra-die mismatch: independent per bit-line and per ref
                let refs = [
                    nominal_refs[0] + process + rng.gauss_ms(0.0, p.sigma_mismatch),
                    nominal_refs[1] + process + rng.gauss_ms(0.0, p.sigma_mismatch),
                    nominal_refs[2] + process + rng.gauss_ms(0.0, p.sigma_mismatch),
                ];
                for k in 0..3 {
                    ref_samples[k].push(refs[k]);
                }
                let mut v_level = [0.0f64; 4];
                for (ones, v) in v_level.iter_mut().enumerate() {
                    *v = p.rbl_level(ones).expect("ones<=3")
                        + process
                        + rng.gauss_ms(0.0, p.sigma_mismatch);
                    level_samples[ones].push(*v);
                }
                for (lane, &(ones, r, above)) in lane_defs.iter().enumerate() {
                    let m = if above {
                        v_level[ones] - refs[r]
                    } else {
                        refs[r] - v_level[ones]
                    };
                    lane_samples[lane].push(m);
                }
                // decision check for every combination
                for (ones, &v) in v_level.iter().enumerate() {
                    let got = SaOutputs {
                        or3: v > refs[0],
                        maj3: v > refs[1],
                        and3: v > refs[2],
                    };
                    if got != ideal_outputs(ones) {
                        errors += 1;
                    }
                    total += 1;
                }
            }
        }

        let lanes: Vec<MarginLane> = lane_defs
            .iter()
            .zip(&lane_samples)
            .map(|(&(ones, reference, above), samples)| MarginLane {
                ones,
                reference,
                above,
                stats: Stats::from_samples(samples),
            })
            .collect();

        let levels: [Stats; 4] =
            std::array::from_fn(|i| Stats::from_samples(&level_samples[i]));
        // V_Ref placement windows between adjacent clusters (paper Fig. 10):
        // a fixed reference must fit between the worst-case samples of the
        // two neighbouring combinations across all dies.
        let level_gaps: [f64; 3] =
            std::array::from_fn(|i| levels[i + 1].min - levels[i].max);
        let min_margin = level_gaps.iter().cloned().fold(f64::INFINITY, f64::min);

        SenseMarginReport {
            levels,
            references: std::array::from_fn(|i| Stats::from_samples(&ref_samples[i])),
            lanes,
            level_gaps,
            min_margin,
            decision_error_rate: errors as f64 / total.max(1) as f64,
            trials: self.trials,
            bitlines: self.bitlines,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_shape() {
        let mc = MonteCarlo { trials: 10, bitlines: 16, ..MonteCarlo::default() };
        let r = mc.run(1);
        assert_eq!(r.lanes.len(), 6);
        assert_eq!(r.trials, 10);
        assert_eq!(r.levels[0].n, 160);
    }

    #[test]
    fn deterministic_in_seed() {
        let mc = MonteCarlo { trials: 5, bitlines: 8, ..MonteCarlo::default() };
        let a = mc.run(42);
        let b = mc.run(42);
        assert_eq!(a.min_margin, b.min_margin);
        let c = mc.run(43);
        assert_ne!(a.min_margin, c.min_margin);
    }

    #[test]
    fn nominal_run_reproduces_paper_margin_and_no_errors() {
        // full paper-size sweep: 200 trials × 256 bit-lines
        let r = MonteCarlo::default().run(7);
        assert_eq!(r.decision_error_rate, 0.0, "no sensing errors at 1.1 V");
        // ~92 mV minimum V_Ref placement window (paper §6.2); the MC band
        // around the paper's observation
        assert!(
            (0.080..0.110).contains(&r.min_margin),
            "min margin {} V outside the paper's ~92 mV band",
            r.min_margin
        );
        // the tightest windows are the 215 mV nominal gaps (280↔495 and
        // 735↔950, the latter being the paper's "111"/"011" observation);
        // the 240 mV middle gap is never the minimum
        assert!(r.level_gaps[1] > r.min_margin);
        // every reference still fits inside its window: no decision errors
        for lane in &r.lanes {
            assert!(lane.stats.min > 0.0, "lane {lane:?} violated");
        }
    }

    #[test]
    fn levels_track_fig9_nominals() {
        let r = MonteCarlo::default().run(3);
        for (ones, want) in [(0, 0.280), (1, 0.495), (2, 0.735), (3, 0.950)] {
            assert!(
                (r.levels[ones].mean - want).abs() < 0.003,
                "level {ones}: mean {} vs {want}",
                r.levels[ones].mean
            );
        }
    }

    #[test]
    fn larger_sigma_degrades_margin() {
        let mut p = CircuitParams::default();
        p.sigma_process = 0.030;
        p.sigma_mismatch = 0.020;
        let noisy = MonteCarlo::new(p).run(5);
        let nominal = MonteCarlo::default().run(5);
        assert!(noisy.min_margin < nominal.min_margin);
    }

    #[test]
    fn low_vdd_shrinks_margins() {
        // paper: "at lower voltages the maximum operating frequency is
        // limited by the reduction of V_Ref ranges"
        let p09 = CircuitParams { vdd: 0.9, ..CircuitParams::default() };
        let low = MonteCarlo::new(p09).run(9);
        let high = MonteCarlo::default().run(9);
        assert!(low.min_margin < high.min_margin);
    }
}
