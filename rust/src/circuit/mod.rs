//! Behavioral analog model of the NS-LBP computational sub-array circuit.
//!
//! Substitutes the paper's TSMC 65 nm post-layout Cadence simulations
//! (DESIGN.md §Substitutions): the architecture above only consumes
//! (a) the *decision function* — which of the four RBL discharge levels the
//! reconfigurable SA resolves for a three-row activation — and (b) the
//! timing/energy scalars, so a table-driven analytic model calibrated to
//! the paper's reported post-layout voltages reproduces the behaviour
//! exactly.
//!
//! Calibration points (paper §6.2, Fig. 9, VDD = 1.1 V, RWL under-driven to
//! 790 mV, sense at ~400 ps):
//!
//! | cells ("abc")    | #ones | RBL after discharge |
//! |------------------|-------|---------------------|
//! | "000"            | 0     | 280 mV              |
//! | "001"            | 1     | 495 mV              |
//! | "011"            | 2     | 735 mV              |
//! | "111"            | 3     | 950 mV              |
//!
//! Sense references: V_R1 = 360 mV < V_R2 = 550 mV < V_R3 = 850 mV, giving
//! the three sub-SA outputs OR3 (RBL > V_R1), MAJ3 (RBL > V_R2) and AND3
//! (RBL > V_R3) in a single read cycle; XOR3 is produced by the capacitive
//! majority of (OR3, ¬MAJ3, AND3) — `XOR3 = MAJ(A+B+C, ¬MAJ(A,B,C), ABC)`.
//!
//! A cell holding '1' keeps its read transistor T8 OFF (no discharge), so
//! more ones ⇒ higher residual RBL voltage.

pub mod montecarlo;

pub use montecarlo::{MonteCarlo, SenseMarginReport};

use crate::error::{Error, Result};

/// Circuit calibration parameters (65 nm-GP defaults from the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CircuitParams {
    /// Core supply voltage [V]; paper sweeps 0.9–1.1 V.
    pub vdd: f64,
    /// Under-driven read word-line voltage [V] (6-sigma stability point).
    pub rwl_voltage: f64,
    /// Sub-SA reference voltages [V] at VDD = 1.1 V.
    pub v_r1: f64,
    pub v_r2: f64,
    pub v_r3: f64,
    /// Maximum clock frequency [GHz] at 1.1 V (paper: 1.25 GHz).
    pub freq_ghz: f64,
    /// Monte-Carlo process (inter-die) sigma on RBL levels [V].
    pub sigma_process: f64,
    /// Monte-Carlo mismatch (intra-die) sigma on RBL/V_R [V].
    pub sigma_mismatch: f64,
}

impl Default for CircuitParams {
    fn default() -> Self {
        Self {
            vdd: 1.1,
            rwl_voltage: 0.790,
            v_r1: 0.360,
            v_r2: 0.550,
            v_r3: 0.850,
            freq_ghz: 1.25,
            // Calibrated so the Fig. 10 Monte-Carlo reproduces the paper's
            // ~92 mV minimum V_Ref placement window at 51 200 samples per
            // combination while keeping zero decision errors at 1.1 V.
            sigma_process: 0.0145,
            sigma_mismatch: 0.007,
        }
    }
}

/// Post-discharge RBL levels at VDD = 1.1 V, indexed by the number of
/// activated cells holding '1' (paper Fig. 9).
pub const RBL_LEVELS_1V1: [f64; 4] = [0.280, 0.495, 0.735, 0.950];

/// Nominal sensing delay from SA-enable to output [ps] (paper: ~400 ps).
pub const SENSE_DELAY_PS: f64 = 400.0;

/// RBL discharge time-constant for the waveform model [ps]; chosen so the
/// nominal levels are reached well within the 400 ps sensing window.
pub const RBL_TAU_PS: f64 = 120.0;

impl CircuitParams {
    pub fn validate(&self) -> Result<()> {
        if !(0.5..=1.3).contains(&self.vdd) {
            return Err(Error::Circuit(format!(
                "vdd {} V outside calibrated 0.5–1.3 V envelope", self.vdd
            )));
        }
        if !(self.v_r1 < self.v_r2 && self.v_r2 < self.v_r3) {
            return Err(Error::Circuit(
                "references must satisfy V_R1 < V_R2 < V_R3".into(),
            ));
        }
        if self.rwl_voltage >= self.vdd {
            return Err(Error::Circuit(
                "RWL under-drive must be below VDD".into(),
            ));
        }
        if self.freq_ghz <= 0.0 {
            return Err(Error::Circuit("frequency must be positive".into()));
        }
        Ok(())
    }

    /// Clock period [ps].
    pub fn cycle_ps(&self) -> f64 {
        1000.0 / self.freq_ghz
    }

    /// Nominal settled RBL voltage for `ones` activated '1'-cells out of 3.
    ///
    /// Levels scale linearly with VDD around the 1.1 V calibration point —
    /// adequate over the paper's 0.9–1.1 V range.
    pub fn rbl_level(&self, ones: usize) -> Result<f64> {
        if ones > 3 {
            return Err(Error::Circuit(format!(
                "three-row activation has at most 3 ones, got {ones}"
            )));
        }
        Ok(RBL_LEVELS_1V1[ones] * (self.vdd / 1.1))
    }

    /// References scaled to the operating VDD.
    pub fn refs(&self) -> [f64; 3] {
        let k = self.vdd / 1.1;
        [self.v_r1 * k, self.v_r2 * k, self.v_r3 * k]
    }

    /// RBL waveform sample at `t_ps` after RWL activation (Fig. 9 transient):
    /// exponential discharge from the precharged VDD toward the settled
    /// level, rate ∝ number of conducting pull-downs (3 − ones).
    pub fn rbl_waveform(&self, ones: usize, t_ps: f64) -> Result<f64> {
        let settle = self.rbl_level(ones)?;
        let zeros = (3 - ones) as f64;
        if zeros == 0.0 {
            // only leakage: small dip from VDD to the 0.95·k level
            let tau = 4.0 * RBL_TAU_PS;
            return Ok(settle + (self.vdd - settle) * (-t_ps / tau).exp());
        }
        let tau = RBL_TAU_PS / zeros;
        Ok(settle + (self.vdd - settle) * (-t_ps / tau).exp())
    }
}

/// The three simultaneous sub-SA decisions of the reconfigurable SA
/// (paper Fig. 5e) for one bit-line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SaOutputs {
    /// RBL > V_R1 — true iff at least one activated cell holds '1'.
    pub or3: bool,
    /// RBL > V_R2 — true iff at least two activated cells hold '1'.
    pub maj3: bool,
    /// RBL > V_R3 — true iff all three activated cells hold '1'.
    pub and3: bool,
}

impl SaOutputs {
    /// Derived single-cycle outputs (paper §4.1 "complete set of Boolean
    /// operations ... in only one single memory cycle").
    pub fn nor3(self) -> bool {
        !self.or3
    }

    pub fn nand3(self) -> bool {
        !self.and3
    }

    /// MIN = ¬MAJ (the complementary node of the MAJ sub-SA).
    pub fn min3(self) -> bool {
        !self.maj3
    }

    /// Capacitive-divider majority of (OR3, ¬MAJ3, AND3) ⇒ XOR3/Sum
    /// (paper Fig. 5g): `XOR3 = MAJ((A+B+C), ¬MAJ(A,B,C), ABC)`.
    pub fn xor3(self) -> bool {
        majority3(self.or3, self.min3(), self.and3)
    }

    /// Carry output of the in-memory full adder.
    pub fn carry(self) -> bool {
        self.maj3
    }
}

/// Boolean 3-input majority.
#[inline]
pub fn majority3(a: bool, b: bool, c: bool) -> bool {
    (a && b) || (a && c) || (b && c)
}

/// Resolve one bit-line: count of '1' cells → RBL level → three voltage
/// comparisons.  `noise` perturbs the RBL voltage (Monte-Carlo hook; pass
/// 0.0 for nominal behaviour).
pub fn sense(params: &CircuitParams, ones: usize, noise_v: f64) -> Result<SaOutputs> {
    let v = params.rbl_level(ones)? + noise_v;
    let [r1, r2, r3] = params.refs();
    Ok(SaOutputs { or3: v > r1, maj3: v > r2, and3: v > r3 })
}

/// Exhaustive functional check used by tests and the transient example:
/// the sensed outputs for `ones` ones must match ideal 3-input gates.
pub fn ideal_outputs(ones: usize) -> SaOutputs {
    SaOutputs { or3: ones >= 1, maj3: ones >= 2, and3: ones == 3 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_params_valid() {
        CircuitParams::default().validate().unwrap();
    }

    #[test]
    fn validation_rejects_bad_refs() {
        let p = CircuitParams { v_r2: 0.9, ..CircuitParams::default() };
        assert!(p.validate().is_err());
        let p = CircuitParams { vdd: 0.3, ..CircuitParams::default() };
        assert!(p.validate().is_err());
    }

    #[test]
    fn rbl_levels_match_paper_fig9() {
        let p = CircuitParams::default();
        assert_eq!(p.rbl_level(0).unwrap(), 0.280);
        assert_eq!(p.rbl_level(1).unwrap(), 0.495);
        assert_eq!(p.rbl_level(2).unwrap(), 0.735);
        assert_eq!(p.rbl_level(3).unwrap(), 0.950);
        assert!(p.rbl_level(4).is_err());
    }

    #[test]
    fn sense_decisions_match_ideal_gates_nominal() {
        let p = CircuitParams::default();
        for ones in 0..=3 {
            let got = sense(&p, ones, 0.0).unwrap();
            assert_eq!(got, ideal_outputs(ones), "ones={ones}");
        }
    }

    #[test]
    fn xor3_via_capacitive_majority_truth_table() {
        for bits in 0u8..8 {
            let (a, b, c) = (bits & 1 == 1, bits & 2 == 2, bits & 4 == 4);
            let ones = a as usize + b as usize + c as usize;
            let sa = ideal_outputs(ones);
            assert_eq!(sa.xor3(), a ^ b ^ c, "bits={bits:03b}");
            assert_eq!(sa.carry(), majority3(a, b, c));
            assert_eq!(sa.nand3(), !(a && b && c));
            assert_eq!(sa.nor3(), !(a || b || c));
        }
    }

    #[test]
    fn decisions_survive_small_noise() {
        // ±20 mV is well inside every nominal margin (min 55 mV to V_R2).
        let p = CircuitParams::default();
        for ones in 0..=3 {
            for noise in [-0.02, 0.02] {
                assert_eq!(sense(&p, ones, noise).unwrap(), ideal_outputs(ones));
            }
        }
    }

    #[test]
    fn vdd_scaling_keeps_decisions() {
        for vdd in [0.9, 1.0, 1.1] {
            let p = CircuitParams { vdd, ..CircuitParams::default() };
            for ones in 0..=3 {
                assert_eq!(sense(&p, ones, 0.0).unwrap(), ideal_outputs(ones));
            }
        }
    }

    #[test]
    fn waveform_starts_at_vdd_and_settles() {
        let p = CircuitParams::default();
        for ones in 0..=3 {
            let v0 = p.rbl_waveform(ones, 0.0).unwrap();
            assert!((v0 - p.vdd).abs() < 1e-9);
            let vend = p.rbl_waveform(ones, 10.0 * RBL_TAU_PS).unwrap();
            let settle = p.rbl_level(ones).unwrap();
            assert!((vend - settle).abs() < 0.02, "ones={ones} vend={vend}");
            // monotone decreasing
            let mut prev = v0;
            for i in 1..50 {
                let v = p.rbl_waveform(ones, i as f64 * 20.0).unwrap();
                assert!(v <= prev + 1e-12);
                prev = v;
            }
        }
    }

    #[test]
    fn sense_window_resolves_before_cycle_end() {
        let p = CircuitParams::default();
        // At the 400 ps SA strobe every level must already be on the correct
        // side of its references.
        for ones in 0..=3 {
            let v = p.rbl_waveform(ones, SENSE_DELAY_PS).unwrap();
            let [r1, r2, r3] = p.refs();
            let sa = SaOutputs { or3: v > r1, maj3: v > r2, and3: v > r3 };
            assert_eq!(sa, ideal_outputs(ones), "ones={ones}, v={v}");
        }
        assert!(SENSE_DELAY_PS < p.cycle_ps());
    }
}
