//! CMOS image-sensor front-end model (paper §4.1, Fig. 5a).
//!
//! A rolling-shutter m×n photodiode array with Correlated Double Sampling
//! (CDS) and a per-column dual-mode ADC.  Two paper-specific behaviours:
//!
//! * **CDS**: the pixel value is the difference of the pre-/post-exposure
//!   photodiode voltages; we model the residual read noise that CDS does
//!   not cancel as a small Gaussian on the analog value.
//! * **Ap-LBP ADC approximation**: the modified controller "simply avoids
//!   pixel conversion for less significant bits" — the ADC resolves only
//!   the top `adc_bits − skip_lsbs` bits, so each conversion costs fewer
//!   cycles and less energy (accounted in [`crate::energy`]), and the LSBs
//!   read as zero.  This must match `model.sensor_quantize` in the Python
//!   build path bit-for-bit for noise-free inputs.
//!
//! The sensor is the head of the coordinator pipeline: `FrameSource`
//! yields frames (either synthetic procedural scenes or frames handed in
//! by the caller), `Adc::convert` digitizes row-by-row in rolling-shutter
//! order.

use crate::error::{Error, Result};
use crate::rng::Xoshiro256;

/// Sensor configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SensorConfig {
    pub rows: usize,
    pub cols: usize,
    pub channels: usize,
    /// Full ADC resolution (paper: 8-bit pixels).
    pub adc_bits: usize,
    /// Ap-LBP approximation: LSBs never converted (0 = exact).
    pub skip_lsbs: usize,
    /// Frame rate used for latency accounting.
    pub fps: f64,
}

impl Default for SensorConfig {
    fn default() -> Self {
        Self { rows: 28, cols: 28, channels: 1, adc_bits: 8, skip_lsbs: 0,
               fps: 1000.0 }
    }
}

impl SensorConfig {
    pub fn validate(&self) -> Result<()> {
        if self.rows == 0 || self.cols == 0 || self.channels == 0 {
            return Err(Error::Config("sensor dimensions must be non-zero".into()));
        }
        if self.adc_bits == 0 || self.adc_bits > 16 {
            return Err(Error::Config(format!(
                "adc_bits {} outside 1..=16", self.adc_bits
            )));
        }
        if self.skip_lsbs >= self.adc_bits {
            return Err(Error::Config(format!(
                "skip_lsbs {} must be < adc_bits {}",
                self.skip_lsbs, self.adc_bits
            )));
        }
        if self.fps <= 0.0 {
            return Err(Error::Config("fps must be positive".into()));
        }
        Ok(())
    }

    pub fn pixels(&self) -> usize {
        self.rows * self.cols * self.channels
    }

    /// Bits actually resolved per conversion.
    pub fn effective_bits(&self) -> usize {
        self.adc_bits - self.skip_lsbs
    }
}

/// One digitized frame: row-major `rows × cols × channels` u8 pixels.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub rows: usize,
    pub cols: usize,
    pub channels: usize,
    pub pixels: Vec<u8>,
    /// Frame sequence number (rolling shutter order).
    pub seq: u64,
}

impl Frame {
    pub fn get(&self, r: usize, c: usize, ch: usize) -> u8 {
        self.pixels[(r * self.cols + c) * self.channels + ch]
    }

    /// Re-stamp the sequence number — serve sessions re-sequence frames
    /// from independent sources into one per-sensor sequence space.
    pub fn with_seq(mut self, seq: u64) -> Self {
        self.seq = seq;
        self
    }
}

/// Dual-mode column ADC with the LSB-skip approximation.
#[derive(Clone, Debug)]
pub struct Adc {
    pub config: SensorConfig,
}

impl Adc {
    /// Digitize one analog sample in [0, 1]; mirrors
    /// `model.sensor_quantize`: round-half-up to 8 bits, then mask LSBs.
    pub fn convert(&self, analog: f64) -> u8 {
        let full = (analog.clamp(0.0, 1.0) * 255.0 + 0.5).floor() as u32;
        let full = full.min(255) as u8;
        let mask = 0xFFu8 ^ ((1u8 << self.config.skip_lsbs).wrapping_sub(1));
        full & mask
    }

    /// SAR-style conversion cycle count: one cycle per resolved bit.
    pub fn cycles_per_conversion(&self) -> usize {
        self.config.effective_bits()
    }
}

/// Correlated double sampling: reset-level and signal-level reads whose
/// difference cancels pixel fixed-pattern offset; residual temporal noise
/// remains.
#[derive(Clone, Debug)]
pub struct Cds {
    /// Residual temporal noise sigma (fraction of full scale).
    pub noise_sigma: f64,
}

impl Default for Cds {
    fn default() -> Self {
        Self { noise_sigma: 0.0 } // noise-free by default: bit-exact path
    }
}

impl Cds {
    /// Apply CDS to a scene radiance sample: subtracting the reset sample
    /// cancels `offset` exactly; temporal noise is left over.
    pub fn sample(&self, radiance: f64, offset: f64, rng: &mut Xoshiro256) -> f64 {
        let reset = offset + self.read_noise(rng);
        let signal = radiance + offset + self.read_noise(rng);
        signal - reset
    }

    fn read_noise(&self, rng: &mut Xoshiro256) -> f64 {
        if self.noise_sigma == 0.0 {
            0.0
        } else {
            rng.gauss_ms(0.0, self.noise_sigma / std::f64::consts::SQRT_2)
        }
    }
}

/// Frame source abstraction for the coordinator.
pub trait FrameSource: Send {
    /// Next digitized frame, or `None` when the stream ends.
    fn next_frame(&mut self) -> Option<Frame>;
    fn config(&self) -> &SensorConfig;
}

/// Sensor that digitizes caller-provided analog scenes (e.g. dataset
/// images replayed as radiance maps) through CDS + ADC in rolling-shutter
/// row order.
pub struct ReplaySensor {
    config: SensorConfig,
    cds: Cds,
    adc: Adc,
    scenes: Vec<Vec<f64>>, // radiance in [0,1], row-major
    fixed_offsets: Vec<f64>,
    next: usize,
    rng: Xoshiro256,
}

impl ReplaySensor {
    pub fn new(config: SensorConfig, scenes: Vec<Vec<f64>>, seed: u64) -> Result<Self> {
        config.validate()?;
        for (i, s) in scenes.iter().enumerate() {
            if s.len() != config.pixels() {
                return Err(Error::Config(format!(
                    "scene {i} has {} samples, sensor needs {}",
                    s.len(),
                    config.pixels()
                )));
            }
        }
        let mut rng = Xoshiro256::new(seed);
        // per-pixel fixed-pattern offsets (cancelled by CDS)
        let fixed_offsets =
            (0..config.pixels()).map(|_| rng.range_f64(0.0, 0.05)).collect();
        Ok(Self {
            adc: Adc { config },
            cds: Cds::default(),
            config,
            scenes,
            fixed_offsets,
            next: 0,
            rng,
        })
    }

    /// Enable residual temporal noise (fraction of full scale).
    pub fn with_noise(mut self, sigma: f64) -> Self {
        self.cds = Cds { noise_sigma: sigma };
        self
    }
}

impl FrameSource for ReplaySensor {
    fn next_frame(&mut self) -> Option<Frame> {
        if self.next >= self.scenes.len() {
            return None;
        }
        let scene = &self.scenes[self.next];
        let mut pixels = Vec::with_capacity(self.config.pixels());
        // rolling shutter: rows exposed and read out sequentially
        for r in 0..self.config.rows {
            for c in 0..self.config.cols {
                for ch in 0..self.config.channels {
                    let idx = (r * self.config.cols + c) * self.config.channels + ch;
                    let analog = self.cds.sample(
                        scene[idx],
                        self.fixed_offsets[idx],
                        &mut self.rng,
                    );
                    pixels.push(self.adc.convert(analog));
                }
            }
        }
        let frame = Frame {
            rows: self.config.rows,
            cols: self.config.cols,
            channels: self.config.channels,
            pixels,
            seq: self.next as u64,
        };
        self.next += 1;
        Some(frame)
    }

    fn config(&self) -> &SensorConfig {
        &self.config
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        SensorConfig::default().validate().unwrap();
        assert!(SensorConfig { skip_lsbs: 8, ..Default::default() }
            .validate()
            .is_err());
        assert!(SensorConfig { rows: 0, ..Default::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn adc_matches_python_sensor_quantize() {
        // floor(x*255 + 0.5) masked — same formula as model.sensor_quantize
        let adc = Adc { config: SensorConfig::default() };
        assert_eq!(adc.convert(0.0), 0);
        assert_eq!(adc.convert(1.0), 255);
        assert_eq!(adc.convert(0.5), 128); // 127.5+0.5 = 128
        let adc2 = Adc {
            config: SensorConfig { skip_lsbs: 2, ..Default::default() },
        };
        for i in 0..=100 {
            let x = i as f64 / 100.0;
            assert_eq!(adc2.convert(x), adc.convert(x) & 0xFC);
        }
    }

    #[test]
    fn adc_skip_reduces_cycles() {
        let full = Adc { config: SensorConfig::default() };
        let apx = Adc {
            config: SensorConfig { skip_lsbs: 2, ..Default::default() },
        };
        assert_eq!(full.cycles_per_conversion(), 8);
        assert_eq!(apx.cycles_per_conversion(), 6);
    }

    #[test]
    fn cds_cancels_fixed_offset() {
        let cds = Cds::default();
        let mut rng = Xoshiro256::new(1);
        let v = cds.sample(0.7, 0.33, &mut rng);
        assert!((v - 0.7).abs() < 1e-12);
    }

    #[test]
    fn replay_sensor_noise_free_is_bit_exact() {
        let cfg = SensorConfig { rows: 4, cols: 4, ..Default::default() };
        let scene: Vec<f64> = (0..16).map(|i| i as f64 / 15.0).collect();
        let mut s = ReplaySensor::new(cfg, vec![scene.clone()], 9).unwrap();
        let f = s.next_frame().unwrap();
        for (i, &p) in f.pixels.iter().enumerate() {
            let want = ((scene[i] * 255.0 + 0.5).floor() as u32).min(255) as u8;
            assert_eq!(p, want);
        }
        assert!(s.next_frame().is_none());
    }

    #[test]
    fn replay_sensor_rejects_bad_scene_size() {
        let cfg = SensorConfig { rows: 4, cols: 4, ..Default::default() };
        assert!(ReplaySensor::new(cfg, vec![vec![0.0; 7]], 0).is_err());
    }

    #[test]
    fn frame_indexing() {
        let cfg = SensorConfig { rows: 2, cols: 3, channels: 2, ..Default::default() };
        let scene: Vec<f64> = (0..12).map(|i| i as f64 / 255.0).collect();
        let mut s = ReplaySensor::new(cfg, vec![scene], 0).unwrap();
        let f = s.next_frame().unwrap();
        assert_eq!(f.get(1, 2, 1), f.pixels[11]);
        assert_eq!(f.seq, 0);
        let f = f.with_seq(42);
        assert_eq!(f.seq, 42);
    }

    #[test]
    fn noisy_sensor_stays_close() {
        let cfg = SensorConfig { rows: 8, cols: 8, ..Default::default() };
        let scene = vec![0.5; 64];
        let mut s = ReplaySensor::new(cfg, vec![scene], 3)
            .unwrap()
            .with_noise(0.01);
        let f = s.next_frame().unwrap();
        for &p in &f.pixels {
            assert!((p as i32 - 128).abs() < 16, "pixel {p} too far from 128");
        }
    }
}
