//! Correlated data partitioning and hardware mapping (paper §5.1, Fig. 6).
//!
//! The LBP layer's memory accesses are fully predictable, so pixels and the
//! pivots they are compared against are co-located in the *same* sub-array:
//! computation never crosses the sub-array boundary (no inter-bank/chip
//! traffic).  Concretely each 256×256 compute sub-array is split into
//! P (64 rows), C (64), Resv (64), W (32), I (32):
//!
//! * **P** holds up to 8 *lane-transposed* 8-bit pixel vectors: bit `i` of
//!   lane `l` of slot `s` lives at row `s·8 + (7−i)`, column `l` — one row
//!   per bit-plane, MSB first, 256 lanes wide.
//! * **C** mirrors P with the pivot value each lane must be compared to
//!   (the paper stores a transposed *copy* of the pivot per pixel vector so
//!   the comparison is positionally aligned).
//! * **Resv** carries the named working rows of Algorithm 1:
//!   `Result_array`, `LBP_array`, the all-0/all-1 constants, and the
//!   controller's `decided` mask plus scratch.
//!
//! [`LaneBatch`] is the unit of work: up to 256 (neighbor, pivot) pairs
//! that one sub-array pass compares in parallel.  [`partition`] splits a
//! whole LBP layer (`H·W·K·e` comparisons) into lane batches and
//! round-robins them over the cache's compute sub-arrays — the paper's
//! throughput-maximising partitioning.

use crate::error::{Error, Result};
use crate::sram::{CacheGeometry, Region, RegionLayout, SubArray, SubArrayId};

/// Named reserved rows (offsets inside the Resv region).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResvRow {
    /// XOR result of the current bit-plane comparison.
    Result = 0,
    /// Accumulated LBP bits (the algorithm's output row).
    Lbp = 1,
    /// All-zero constant row.
    Zero = 2,
    /// All-one constant row.
    One = 3,
    /// Lanes already decided (controller bookkeeping mask).
    Decided = 4,
    /// Scratch row for 2-input compositions.
    Scratch = 5,
    /// Second scratch row.
    Scratch2 = 6,
}

/// Row-address helper for the Fig. 6(a) layout of one sub-array.
#[derive(Clone, Copy, Debug)]
pub struct LbpSubarrayMap {
    pub layout: RegionLayout,
    /// Pixel/pivot word width in bits (8 for u8 sensors).
    pub bits: usize,
}

impl LbpSubarrayMap {
    pub fn new(layout: RegionLayout, bits: usize) -> Result<Self> {
        if bits == 0 || bits > 16 {
            return Err(Error::Mapping(format!("bits {bits} outside 1..=16")));
        }
        let map = Self { layout, bits };
        if map.slots() == 0 {
            return Err(Error::Mapping(
                "pixel region too small for one slot".into(),
            ));
        }
        if layout.reserved_rows < 7 {
            return Err(Error::Mapping(
                "reserved region needs ≥ 7 rows (Alg. 1 bookkeeping)".into(),
            ));
        }
        Ok(map)
    }

    /// Number of resident pixel-vector slots (paper: 64/8 = 8).
    pub fn slots(&self) -> usize {
        self.layout.pixel_rows / self.bits
    }

    /// Row of bit `bit` (0 = LSB) of pixel slot `slot` — MSB stored first.
    pub fn pixel_bit_row(&self, slot: usize, bit: usize) -> Result<usize> {
        self.check(slot, bit)?;
        self.layout
            .row(Region::Pixel, slot * self.bits + (self.bits - 1 - bit))
    }

    /// Row of bit `bit` of the pivot vector for `slot`.
    pub fn pivot_bit_row(&self, slot: usize, bit: usize) -> Result<usize> {
        self.check(slot, bit)?;
        self.layout
            .row(Region::Pivot, slot * self.bits + (self.bits - 1 - bit))
    }

    /// Global row of a named reserved row.
    pub fn resv(&self, r: ResvRow) -> usize {
        self.layout.base(Region::Reserved) + r as usize
    }

    fn check(&self, slot: usize, bit: usize) -> Result<()> {
        if slot >= self.slots() {
            return Err(Error::Mapping(format!(
                "slot {slot} out of range ({} slots)",
                self.slots()
            )));
        }
        if bit >= self.bits {
            return Err(Error::Mapping(format!("bit {bit} out of range")));
        }
        Ok(())
    }

    /// Load `lanes` (neighbor, pivot) pairs lane-transposed into `slot`.
    ///
    /// Writes `2 × bits` rows (one per bit-plane of P and C); lanes beyond
    /// `pairs.len()` are zero-filled.  Returns the number of loaded lanes.
    /// Convenience wrapper around [`Self::load_lanes_with`] that owns a
    /// transient plane buffer; steady-state callers thread a persistent
    /// buffer through `load_lanes_with` instead (§Perf).
    pub fn load_lanes(&self, sa: &mut SubArray, slot: usize,
                      pairs: &[(u8, u8)]) -> Result<usize> {
        let mut planes = Vec::new();
        self.load_lanes_with(sa, slot, pairs, &mut planes)
    }

    /// Allocation-free [`Self::load_lanes`]: the pixel/pivot bit-plane
    /// staging buffer is caller-owned (cleared, re-zeroed, and reused —
    /// a warm buffer never reallocates), so the per-chunk lane load of
    /// the architectural batch path performs no heap allocation.
    pub fn load_lanes_with(&self, sa: &mut SubArray, slot: usize,
                           pairs: &[(u8, u8)], planes: &mut Vec<u64>)
                           -> Result<usize> {
        if pairs.len() > sa.cols() {
            return Err(Error::Mapping(format!(
                "{} lanes exceed {} columns",
                pairs.len(),
                sa.cols()
            )));
        }
        // single pass over lanes, one flat zeroed buffer for all 2×bits
        // bit-plane rows
        let words = sa.cols() / 64;
        planes.clear();
        planes.resize(2 * self.bits * words, 0);
        if self.bits == 8 {
            // SWAR fast path: transpose 8 lanes × 8 bits at a time
            // (Hacker's-Delight 8×8 bit-matrix transpose), ~3× fewer ops
            // than per-bit scatter (§Perf).
            for (g, group) in pairs.chunks(8).enumerate() {
                let mut px = 0u64;
                let mut cx = 0u64;
                for (i, &(p, c)) in group.iter().enumerate() {
                    px |= (p as u64) << (8 * i);
                    cx |= (c as u64) << (8 * i);
                }
                let (tp, tc) = (transpose8x8(px), transpose8x8(cx));
                let word = g / 8;
                let shift = 8 * (g % 8);
                for bit in 0..8 {
                    planes[bit * words + word] |=
                        ((tp >> (8 * bit)) & 0xFF) << shift;
                    planes[(8 + bit) * words + word] |=
                        ((tc >> (8 * bit)) & 0xFF) << shift;
                }
            }
        } else {
            for (lane, &(p, c)) in pairs.iter().enumerate() {
                let word = lane / 64;
                let shift = (lane % 64) as u32;
                for bit in 0..self.bits {
                    // branchless bit scatter
                    planes[bit * words + word] |=
                        (((p >> bit) & 1) as u64) << shift;
                    planes[(self.bits + bit) * words + word] |=
                        (((c >> bit) & 1) as u64) << shift;
                }
            }
        }
        for bit in 0..self.bits {
            sa.write_row(self.pixel_bit_row(slot, bit)?,
                         &planes[bit * words..(bit + 1) * words])?;
            sa.write_row(self.pivot_bit_row(slot, bit)?,
                         &planes[(self.bits + bit) * words
                                 ..(self.bits + bit + 1) * words])?;
        }
        Ok(pairs.len())
    }

    /// Read back `lanes` bits from a reserved row (e.g. the LBP_array).
    pub fn read_resv_bits(&self, sa: &SubArray, row: ResvRow,
                          lanes: usize) -> Result<Vec<bool>> {
        let mut out = Vec::with_capacity(lanes);
        self.read_resv_bits_into(sa, row, lanes, &mut out)?;
        Ok(out)
    }

    /// Append `lanes` bits of a reserved row to a caller-owned buffer —
    /// the allocation-free variant the batched architectural path uses
    /// to accumulate every chunk's comparator bits into one arena vector.
    pub fn read_resv_bits_into(&self, sa: &SubArray, row: ResvRow,
                               lanes: usize, out: &mut Vec<bool>)
                               -> Result<()> {
        let words = sa.row_words(self.resv(row))?;
        out.reserve(lanes);
        for l in 0..lanes {
            out.push(words[l / 64] >> (l % 64) & 1 == 1);
        }
        Ok(())
    }
}

/// One unit of parallel work: ≤ `cols` comparison pairs for one sub-array.
#[derive(Clone, Debug, PartialEq)]
pub struct LaneBatch {
    /// Target sub-array.
    pub target: SubArrayId,
    /// Resident slot in the P/C regions.
    pub slot: usize,
    /// Global lane offset of this batch within the layer's comparisons.
    pub lane_offset: usize,
    /// (neighbor intensity, pivot intensity) per lane.
    pub pairs: Vec<(u8, u8)>,
}

/// Partition a layer's comparison stream over the cache's sub-arrays.
///
/// `pairs` is the flattened `(neighbor, pivot)` stream (H·W·K·e entries in
/// raster order).  Batches of `cols` lanes are dealt round-robin across
/// sub-arrays, then across the slots of each sub-array — matching the
/// paper's "fully local computation" goal: a batch never splits across
/// sub-arrays.
pub fn partition(pairs: &[(u8, u8)], geometry: &CacheGeometry,
                 map: &LbpSubarrayMap) -> Result<Vec<LaneBatch>> {
    geometry.validate()?;
    let cols = geometry.cols;
    let ids: Vec<SubArrayId> = (0..geometry.banks)
        .flat_map(|bank| {
            (0..geometry.mats_per_bank).flat_map(move |mat| {
                (0..geometry.subarrays_per_mat)
                    .map(move |subarray| SubArrayId { bank, mat, subarray })
            })
        })
        .collect();
    let slots = map.slots();
    let mut batches = Vec::new();
    for (i, chunk) in pairs.chunks(cols).enumerate() {
        let target = ids[i % ids.len()];
        let slot = (i / ids.len()) % slots;
        batches.push(LaneBatch {
            target,
            slot,
            lane_offset: i * cols,
            pairs: chunk.to_vec(),
        });
    }
    Ok(batches)
}

/// 8×8 bit-matrix transpose (Hacker's Delight §7-3): input byte `i` holds
/// the 8 bits of lane `i`; output byte `b` holds bit `b` of all 8 lanes.
#[inline]
fn transpose8x8(mut x: u64) -> u64 {
    let t = (x ^ (x >> 7)) & 0x00AA_00AA_00AA_00AA;
    x ^= t ^ (t << 7);
    let t = (x ^ (x >> 14)) & 0x0000_CCCC_0000_CCCC;
    x ^= t ^ (t << 14);
    let t = (x ^ (x >> 28)) & 0x0000_0000_F0F0_F0F0;
    x ^= t ^ (t << 28);
    x
}

/// Summary of a partition — used by the energy model for data-loading cost.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PartitionStats {
    pub total_lanes: usize,
    pub batches: usize,
    pub subarrays_used: usize,
    /// Row writes needed to load all batches (2·bits rows per batch).
    pub load_row_writes: usize,
}

pub fn partition_stats(batches: &[LaneBatch], map: &LbpSubarrayMap) -> PartitionStats {
    let mut subarrays: Vec<SubArrayId> = batches.iter().map(|b| b.target).collect();
    subarrays.sort_by_key(|id| (id.bank, id.mat, id.subarray));
    subarrays.dedup();
    PartitionStats {
        total_lanes: batches.iter().map(|b| b.pairs.len()).sum(),
        batches: batches.len(),
        subarrays_used: subarrays.len(),
        load_row_writes: batches.len() * 2 * map.bits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sram::RegionLayout;

    fn map() -> LbpSubarrayMap {
        LbpSubarrayMap::new(RegionLayout::default(), 8).unwrap()
    }

    #[test]
    fn paper_layout_has_eight_slots() {
        assert_eq!(map().slots(), 8);
    }

    #[test]
    fn row_addresses_msb_first_and_disjoint() {
        let m = map();
        // MSB of slot 0 at the top of P
        assert_eq!(m.pixel_bit_row(0, 7).unwrap(), 0);
        assert_eq!(m.pixel_bit_row(0, 0).unwrap(), 7);
        assert_eq!(m.pixel_bit_row(1, 7).unwrap(), 8);
        // pivot region is offset by 64
        assert_eq!(m.pivot_bit_row(0, 7).unwrap(), 64);
        // all rows distinct
        let mut rows = Vec::new();
        for slot in 0..m.slots() {
            for bit in 0..8 {
                rows.push(m.pixel_bit_row(slot, bit).unwrap());
                rows.push(m.pivot_bit_row(slot, bit).unwrap());
            }
        }
        rows.sort_unstable();
        rows.dedup();
        assert_eq!(rows.len(), 2 * 8 * 8);
    }

    #[test]
    fn resv_rows_inside_reserved_region() {
        let m = map();
        for r in [ResvRow::Result, ResvRow::Lbp, ResvRow::Zero, ResvRow::One,
                  ResvRow::Decided, ResvRow::Scratch, ResvRow::Scratch2] {
            let row = m.resv(r);
            assert_eq!(m.layout.region_of(row), Some(Region::Reserved), "{r:?}");
        }
    }

    #[test]
    fn bounds_checked() {
        let m = map();
        assert!(m.pixel_bit_row(8, 0).is_err());
        assert!(m.pixel_bit_row(0, 8).is_err());
        assert!(LbpSubarrayMap::new(RegionLayout::default(), 0).is_err());
    }

    #[test]
    fn load_lanes_transposed_roundtrip() {
        let m = map();
        let mut sa = SubArray::new(256, 256);
        let pairs: Vec<(u8, u8)> =
            (0..200).map(|i| ((i * 7 + 3) as u8, (i * 13 + 1) as u8)).collect();
        m.load_lanes(&mut sa, 2, &pairs).unwrap();
        for (lane, &(p, c)) in pairs.iter().enumerate() {
            let mut pv = 0u8;
            let mut cv = 0u8;
            for bit in 0..8 {
                if sa.get(m.pixel_bit_row(2, bit).unwrap(), lane).unwrap() {
                    pv |= 1 << bit;
                }
                if sa.get(m.pivot_bit_row(2, bit).unwrap(), lane).unwrap() {
                    cv |= 1 << bit;
                }
            }
            assert_eq!((pv, cv), (p, c), "lane {lane}");
        }
    }

    #[test]
    fn load_never_touches_other_regions() {
        let m = map();
        let mut sa = SubArray::new(256, 256);
        // poison W and I regions, then load
        for row in 192..256 {
            sa.fill_row(row, true).unwrap();
        }
        m.load_lanes(&mut sa, 0, &[(0xFF, 0x00); 256]).unwrap();
        for row in 192..256 {
            assert!(sa.read_row(row).unwrap().iter().all(|&w| w == u64::MAX));
        }
    }

    #[test]
    fn load_rejects_oversized_batch() {
        let m = map();
        let mut sa = SubArray::new(256, 256);
        assert!(m.load_lanes(&mut sa, 0, &[(0, 0); 257]).is_err());
    }

    #[test]
    fn partition_covers_every_lane_once() {
        let g = CacheGeometry { banks: 3, mats_per_bank: 2, subarrays_per_mat: 1,
                                ..CacheGeometry::default() };
        let m = map();
        let pairs: Vec<(u8, u8)> =
            (0..2000).map(|i| (i as u8, (i >> 8) as u8)).collect();
        let batches = partition(&pairs, &g, &m).unwrap();
        // reassemble and compare
        let mut got = vec![None; pairs.len()];
        for b in &batches {
            for (j, &p) in b.pairs.iter().enumerate() {
                let idx = b.lane_offset + j;
                assert!(got[idx].is_none(), "lane {idx} assigned twice");
                got[idx] = Some(p);
            }
            assert!(b.pairs.len() <= g.cols);
            assert!(b.slot < m.slots());
            assert!(b.target.bank < g.banks);
        }
        assert!(got.iter().all(|o| o.is_some()));
        let reassembled: Vec<(u8, u8)> = got.into_iter().map(|o| o.unwrap()).collect();
        assert_eq!(reassembled, pairs);
    }

    #[test]
    fn partition_round_robins_subarrays() {
        let g = CacheGeometry { banks: 2, mats_per_bank: 1, subarrays_per_mat: 1,
                                ..CacheGeometry::default() };
        let m = map();
        let pairs = vec![(1u8, 2u8); 256 * 4];
        let batches = partition(&pairs, &g, &m).unwrap();
        assert_eq!(batches.len(), 4);
        assert_eq!(batches[0].target.bank, 0);
        assert_eq!(batches[1].target.bank, 1);
        assert_eq!(batches[2].target.bank, 0);
        assert_eq!(batches[2].slot, 1); // second slot on the wrap-around
        let stats = partition_stats(&batches, &m);
        assert_eq!(stats.total_lanes, 1024);
        assert_eq!(stats.subarrays_used, 2);
        assert_eq!(stats.load_row_writes, 4 * 16);
    }
}
