//! `hw` — the unified hardware cost-model subsystem.
//!
//! The paper's headline numbers (1.25 GHz, 37.4 TOPS/W, the 2.2×/4×
//! energy/time wins of Fig. 11, the 3.4× reconfigurable-SA area factor of
//! Table 3) all come from one calibrated cost model.  Before this module
//! that model was smeared across four surfaces — `energy.rs` constants,
//! `Opcode::cycles()` baked into the ISA enum, `baselines.rs` platform
//! constants, and the circuit calibration — so swapping in an alternative
//! hardware point (a 28 nm compute-SRAM, a digital MAC datapath, a
//! PISA-style near-sensor design) meant editing four files.  Now:
//!
//! * [`HwProfile`] is a *named, serializable* description of one hardware
//!   point: clock frequency, the per-event pJ table
//!   ([`crate::energy::EnergyParams`]), the per-opcode cycle table
//!   ([`CycleTable`]), the area factors ([`crate::energy::AreaModel`]),
//!   and the platform datapath shape (energy scale, bit-serial MAC
//!   cycles/lanes, float lanes).
//! * [`CostModel`] is the trait every consumer prices through:
//!   `exec_cost(&ExecStats) -> Cost`, `dpu_cost`, `sensor_cost`,
//!   `transmission_cost`, `cycle_ns`, `area_mm2`, `tops_per_watt`.
//!   [`Cost`] pairs an itemized [`EnergyBreakdown`] with modeled time.
//! * Built-in profiles: [`HwProfile::ns_lbp_65nm`] (bit-identical to the
//!   historical `EnergyParams::default()` + `Opcode::cycles()` model),
//!   plus [`HwProfile::sram38_28nm`], [`HwProfile::cnn8_digital`] and
//!   [`HwProfile::lbcnn`] — the Fig.-11 comparison platforms migrated out
//!   of `baselines.rs`.
//! * [`ab::AbHarness`] (the `ns-lbp ab` subcommand) runs the same frames
//!   through two engines under two profiles and diffs energy, time,
//!   TOPS/W and area.
//!
//! # Swapping hardware profiles
//!
//! Every layer above this one selects hardware by *name*:
//!
//! ```text
//! # config file
//! [hw]
//! profile = "sram38_28nm"          # builtin name, or a path to a
//!                                  # configs/profiles/*.toml file
//! compute_op_pj = 9.5              # optional field-level overrides
//!
//! # CLI (run / serve-bench / info)
//! ns-lbp run --hw-profile sram38_28nm
//! ns-lbp ab  --profile ns_lbp_65nm --profile sram38_28nm --json
//!
//! # print any profile as a standalone TOML file
//! ns-lbp profile --hw-profile ns_lbp_65nm > configs/profiles/mine.toml
//! ```
//!
//! Programmatically:
//!
//! ```
//! use ns_lbp::hw::{CostModel, HwProfile};
//! use ns_lbp::isa::ExecStats;
//!
//! let profile = HwProfile::resolve("sram38_28nm").unwrap();
//! let mut stats = ExecStats::default();
//! stats.compute_ops = 100;
//! stats.cycles = 100;
//! let cost = profile.exec_cost(&stats);
//! assert!(cost.energy.total_pj() > 0.0 && cost.time_ns > 0.0);
//! // round-trips losslessly through TOML
//! let back = HwProfile::from_toml(&profile.to_toml()).unwrap();
//! assert_eq!(back, profile);
//! ```
//!
//! The engine stamps every frame's [`crate::engine::Telemetry`] with the
//! profile name and a [`Cost`] priced by that profile, and
//! `serve::MetricsReport` reports per-class energy under the active
//! profile — so an A/B comparison is two engine builds away, not a
//! four-file patch.

pub mod ab;

use crate::config::ConfigFile;
use crate::dpu::DpuStats;
use crate::energy::{AreaModel, EnergyBreakdown, EnergyParams};
use crate::error::{Error, Result};
use crate::isa::{ExecStats, Opcode};
use crate::sram::CacheGeometry;

// ---------------------------------------------------------------------------
// Cost
// ---------------------------------------------------------------------------

/// What one activity costs under a profile: an itemized energy account
/// plus the modeled accelerator time it occupies.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    pub energy: EnergyBreakdown,
    /// Modeled time [ns] (0 for activities that don't occupy the array,
    /// e.g. DPU/sensor events priced per occurrence).
    pub time_ns: f64,
}

impl Cost {
    pub fn add(&mut self, o: &Cost) {
        self.energy.add(&o.energy);
        self.time_ns += o.time_ns;
    }

    pub fn total_pj(&self) -> f64 {
        self.energy.total_pj()
    }

    /// True when every component is a finite, non-negative number.
    pub fn is_sane(&self) -> bool {
        let e = &self.energy;
        [e.compute_pj, e.read_pj, e.write_pj, e.ctrl_pj, e.dpu_pj,
         e.sensor_pj, e.transmission_pj, self.time_ns]
            .iter()
            .all(|v| v.is_finite() && *v >= 0.0)
    }
}

// ---------------------------------------------------------------------------
// Per-opcode cycle table
// ---------------------------------------------------------------------------

/// Memory cycles per ISA opcode, indexed by [`Opcode::index`].  The
/// NS-LBP table ([`CycleTable::NS_LBP`]) is the paper's single-cycle
/// multi-row activation model: compute ops resolve in one read cycle
/// (result latched through the decoupled write port), `copy` needs
/// read + write, `ini` is one write.  `Opcode::cycles()` delegates here,
/// so the executor's live cycle accounting and the cost model share one
/// table; a profile with a different table (e.g. a bit-serial platform)
/// re-prices a recorded trace through [`CostModel::exec_cost`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CycleTable {
    /// One entry per [`Opcode::ALL`] member, in `Opcode::index` order.
    table: [u64; 8],
}

impl CycleTable {
    /// The paper's NS-LBP timing (Table 2 / §4.1).
    pub const NS_LBP: CycleTable =
        CycleTable { table: [2, 1, 1, 1, 1, 1, 1, 1] };

    pub fn of(&self, op: Opcode) -> u64 {
        self.table[op.index()]
    }

    pub fn set(&mut self, op: Opcode, cycles: u64) {
        self.table[op.index()] = cycles;
    }
}

impl Default for CycleTable {
    fn default() -> Self {
        Self::NS_LBP
    }
}

// ---------------------------------------------------------------------------
// HwProfile
// ---------------------------------------------------------------------------

/// Built-in profile names, resolvable through [`HwProfile::resolve`].
pub const BUILTIN_PROFILES: &[&str] =
    &["ns_lbp_65nm", "sram38_28nm", "cnn8_digital", "lbcnn"];

/// Per-event energy field names, in [`EnergyParams`] declaration order —
/// the serialization schema of the `[energy]` profile section and the
/// legal `hw.<field>` config overrides.
pub const ENERGY_FIELDS: &[&str] = &[
    "freq_ghz", "compute_op_pj", "row_read_pj", "row_write_pj",
    "ctrl_cycle_pj", "bitcount_pj", "shift_pj", "add_pj", "activation_pj",
    "quantize_pj", "shifted_relu_pj", "adc_bit_pj", "pixel_read_pj",
    "offchip_bit_pj", "mac8_pj", "flop_pj",
];

/// Area field names (`[area]` profile section, `hw.<field>` overrides).
pub const AREA_FIELDS: &[&str] =
    &["bitcell_um2", "sa_um2", "sa_overhead", "periphery_um2"];

/// One named hardware design point: everything the evaluation framework
/// needs to convert event counts into pJ / ns / mm².
#[derive(Clone, Debug, PartialEq)]
pub struct HwProfile {
    /// Profile name, stamped into telemetry and reports verbatim —
    /// restricted by [`HwProfile::validate`] to ASCII
    /// alphanumeric/`_`/`-`/`.` so it embeds safely in TOML and JSON.
    pub name: String,
    /// Per-event energy table; `energy.freq_ghz` is the clock.
    pub energy: EnergyParams,
    /// Per-opcode cycle table.
    pub cycles: CycleTable,
    /// Area factors (bit-cell, SA, SA overhead, periphery).
    pub area: AreaModel,
    /// Multiplier on the node-local energies (compute/read/write/ctrl/
    /// DPU) for older nodes or costlier arrays; sensor and off-chip
    /// transmission are node-independent and never scaled.
    pub energy_scale: f64,
    /// Cycles per 8-bit MAC on this platform's (bit-serial) datapath.
    pub mac_cycles: u64,
    /// Parallel 8-bit MAC lanes.
    pub mac_lanes: u64,
    /// Parallel float lanes (LBCNN's 1×1/batch-norm path).
    pub flop_lanes: u64,
}

impl Default for HwProfile {
    fn default() -> Self {
        Self::ns_lbp_65nm()
    }
}

impl HwProfile {
    /// NS-LBP itself: TSMC 65 nm GP @ 1.1 V, 1.25 GHz — bit-identical to
    /// the historical `EnergyParams::default()` + `Opcode::cycles()`
    /// model (asserted by the cost-parity tests).
    pub fn ns_lbp_65nm() -> Self {
        Self {
            name: "ns_lbp_65nm".into(),
            energy: EnergyParams::default(),
            cycles: CycleTable::NS_LBP,
            area: AreaModel::default(),
            energy_scale: 1.0,
            mac_cycles: 0,
            mac_lanes: 0,
            flop_lanes: 0,
        }
    }

    /// The [38]-style prior-generation compute-SRAM (28 nm transposable
    /// 8T, 475 MHz, bit-serial arithmetic, 5.52× SA overhead).  The
    /// energy scale folds the costlier SA and bit-serial data movement.
    pub fn sram38_28nm() -> Self {
        Self {
            name: "sram38_28nm".into(),
            energy: EnergyParams { freq_ghz: 0.475, ..EnergyParams::default() },
            cycles: CycleTable::NS_LBP,
            area: AreaModel { sa_overhead: 5.52, ..AreaModel::default() },
            energy_scale: 1.55,
            // 8-bit × 8-bit bit-serial multiply-accumulate; effective MAC
            // lanes: all 4×128×256 bit-cells of [38] in bit-serial
            // column-parallel mode ÷ 8-bit operand width
            mac_cycles: 16,
            mac_lanes: 4 * 128 * 256 / 8,
            flop_lanes: 512,
        }
    }

    /// The 8-bit digital-CNN view of the [38] platform (Fig. 11's CNN
    /// baseline): same array, priced through the bit-serial MAC datapath.
    pub fn cnn8_digital() -> Self {
        Self { name: "cnn8_digital".into(), ..Self::sram38_28nm() }
    }

    /// The LBCNN platform point (Fig. 11): binary ancestor convolutions
    /// on the [38] array plus the SIMD float path for 1×1 fusion and
    /// batch-norm.
    pub fn lbcnn() -> Self {
        Self { name: "lbcnn".into(), ..Self::sram38_28nm() }
    }

    /// Look up a built-in profile by name.
    pub fn builtin(name: &str) -> Option<HwProfile> {
        match name {
            "ns_lbp_65nm" => Some(Self::ns_lbp_65nm()),
            "sram38_28nm" => Some(Self::sram38_28nm()),
            "cnn8_digital" => Some(Self::cnn8_digital()),
            "lbcnn" => Some(Self::lbcnn()),
            _ => None,
        }
    }

    /// Resolve a profile spec: a built-in name, or a path to a standalone
    /// profile TOML file (`configs/profiles/*.toml`).
    pub fn resolve(spec: &str) -> Result<HwProfile> {
        if let Some(p) = Self::builtin(spec) {
            return Ok(p);
        }
        if std::path::Path::new(spec).exists() {
            return Self::load(spec);
        }
        Err(Error::Config(format!(
            "unknown hw profile {spec:?} (builtins: {}; or a path to a \
             profile TOML file)",
            BUILTIN_PROFILES.join("|")
        )))
    }

    /// Load a standalone profile TOML file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<HwProfile> {
        let file = ConfigFile::load(path.as_ref())?;
        Self::from_config(&file).map_err(|e| {
            Error::Config(format!("{}: {e}", path.as_ref().display()))
        })
    }

    /// Parse from profile-TOML text (the [`HwProfile::to_toml`] format).
    pub fn from_toml(text: &str) -> Result<HwProfile> {
        Self::from_config(&ConfigFile::parse(text)?)
    }

    /// Build from a parsed `[profile]`/`[energy]`/`[area]`/`[cycles]`
    /// file.  Unset fields default to [`HwProfile::ns_lbp_65nm`]; unknown
    /// keys are rejected so typos fail loudly.
    pub fn from_config(file: &ConfigFile) -> Result<HwProfile> {
        for key in file.keys() {
            let known = matches!(key,
                "profile.name" | "profile.energy_scale" | "profile.mac_cycles"
                | "profile.mac_lanes" | "profile.flop_lanes")
                || key.strip_prefix("energy.")
                    .is_some_and(|f| ENERGY_FIELDS.contains(&f))
                || key.strip_prefix("area.")
                    .is_some_and(|f| AREA_FIELDS.contains(&f))
                || key.strip_prefix("cycles.")
                    .is_some_and(|m| Opcode::from_mnemonic(m).is_some());
            if !known {
                return Err(Error::Config(format!(
                    "unknown profile key {key:?}"
                )));
            }
        }
        let mut p = Self::ns_lbp_65nm();
        p.name = file.get_str("profile.name", "")?;
        if p.name.is_empty() {
            return Err(Error::Config("profile.name is required".into()));
        }
        p.apply_fields(file, "energy.", "area.", "profile.", "cycles.")?;
        p.validate()?;
        Ok(p)
    }

    /// True when `field` names a legal flat profile override — the
    /// `hw.<field>` config surface: any [`ENERGY_FIELDS`] /
    /// [`AREA_FIELDS`] member, a platform field, or `cycles.<mnemonic>`.
    pub fn is_override_field(field: &str) -> bool {
        field == "energy_scale"
            || field == "mac_cycles"
            || field == "mac_lanes"
            || field == "flop_lanes"
            || ENERGY_FIELDS.contains(&field)
            || AREA_FIELDS.contains(&field)
            || field.strip_prefix("cycles.")
                .is_some_and(|m| Opcode::from_mnemonic(m).is_some())
    }

    /// Apply flat `<prefix><field>` overrides from a parsed config (the
    /// `[hw]` section uses prefix `"hw."`) — the same field machinery
    /// [`HwProfile::from_config`] uses for sectioned profile files, so
    /// the two surfaces cannot drift.  Does not re-validate; callers
    /// validate once after all overrides are in.
    pub fn apply_overrides(&mut self, file: &ConfigFile, prefix: &str)
                           -> Result<()> {
        let cycles = format!("{prefix}cycles.");
        self.apply_fields(file, prefix, prefix, prefix, &cycles)
    }

    /// Shared field-application core: each category reads its fields at
    /// `<category_prefix><field>`.
    fn apply_fields(&mut self, file: &ConfigFile, energy: &str, area: &str,
                    platform: &str, cycles: &str) -> Result<()> {
        for &field in ENERGY_FIELDS {
            let key = format!("{energy}{field}");
            if file.contains(&key) {
                self.set_energy_field(field, file.get_f64(&key, 0.0)?)?;
            }
        }
        for &field in AREA_FIELDS {
            let key = format!("{area}{field}");
            if file.contains(&key) {
                self.set_area_field(field, file.get_f64(&key, 0.0)?)?;
            }
        }
        let key = format!("{platform}energy_scale");
        if file.contains(&key) {
            self.energy_scale = file.get_f64(&key, self.energy_scale)?;
        }
        let key = format!("{platform}mac_cycles");
        if file.contains(&key) {
            self.mac_cycles = file.get_usize(&key, 0)? as u64;
        }
        let key = format!("{platform}mac_lanes");
        if file.contains(&key) {
            self.mac_lanes = file.get_usize(&key, 0)? as u64;
        }
        let key = format!("{platform}flop_lanes");
        if file.contains(&key) {
            self.flop_lanes = file.get_usize(&key, 0)? as u64;
        }
        for op in Opcode::ALL {
            let key = format!("{cycles}{}", op.mnemonic());
            if file.contains(&key) {
                self.cycles.set(op, file.get_usize(&key, 0)? as u64);
            }
        }
        Ok(())
    }

    /// Serialize as a standalone profile TOML file.  Floats use Rust's
    /// shortest round-trip formatting, so `to_toml` → [`from_toml`] is
    /// lossless (`assert_eq!` level — see the round-trip tests).
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "# hardware profile {:?} — load with `--hw-profile <path>` or\n\
             # `[hw] profile = \"<path>\"`; regenerate with \
             `ns-lbp profile`\n\n[profile]\nname = {:?}\n",
            self.name, self.name
        ));
        s.push_str(&format!("energy_scale = {:?}\n", self.energy_scale));
        s.push_str(&format!("mac_cycles = {}\n", self.mac_cycles));
        s.push_str(&format!("mac_lanes = {}\n", self.mac_lanes));
        s.push_str(&format!("flop_lanes = {}\n", self.flop_lanes));
        s.push_str("\n[energy]\n");
        for &field in ENERGY_FIELDS {
            s.push_str(&format!("{field} = {:?}\n",
                                energy_get(&self.energy, field)));
        }
        s.push_str("\n[area]\n");
        for &field in AREA_FIELDS {
            s.push_str(&format!("{field} = {:?}\n",
                                area_get(&self.area, field)));
        }
        s.push_str("\n[cycles]\n");
        for op in Opcode::ALL {
            s.push_str(&format!("{} = {}\n", op.mnemonic(),
                                self.cycles.of(op)));
        }
        s
    }

    /// Reject profiles that would produce nonsensical costs, and names
    /// that could not be embedded safely in TOML / JSON output.
    pub fn validate(&self) -> Result<()> {
        let name_ok = !self.name.is_empty()
            && self.name.chars().all(|c| {
                c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.')
            });
        if !name_ok {
            return Err(Error::Config(format!(
                "hw profile name {:?} must be non-empty ASCII \
                 alphanumeric/'_'/'-'/'.' (it is embedded in TOML and \
                 JSON reports verbatim)",
                self.name
            )));
        }
        if self.name == crate::engine::Telemetry::MIXED_PROFILES {
            return Err(Error::Config(format!(
                "hw profile name {:?} is reserved (it marks telemetry \
                 merged across different profiles)",
                self.name
            )));
        }
        for &field in ENERGY_FIELDS {
            let v = energy_get(&self.energy, field);
            if !v.is_finite() || v < 0.0 {
                return Err(Error::Config(format!(
                    "hw profile {:?}: energy.{field} = {v} must be a \
                     non-negative finite number",
                    self.name
                )));
            }
        }
        if self.energy.freq_ghz <= 0.0 {
            return Err(Error::Config(format!(
                "hw profile {:?}: freq_ghz must be > 0",
                self.name
            )));
        }
        if self.energy.compute_op_pj <= 0.0 {
            return Err(Error::Config(format!(
                "hw profile {:?}: compute_op_pj must be > 0 \
                 (tops_per_watt divides by it)",
                self.name
            )));
        }
        for &field in AREA_FIELDS {
            let v = area_get(&self.area, field);
            if !v.is_finite() || v < 0.0 {
                return Err(Error::Config(format!(
                    "hw profile {:?}: area.{field} = {v} must be a \
                     non-negative finite number",
                    self.name
                )));
            }
        }
        if !self.energy_scale.is_finite() || self.energy_scale <= 0.0 {
            return Err(Error::Config(format!(
                "hw profile {:?}: energy_scale must be a positive finite \
                 number",
                self.name
            )));
        }
        Ok(())
    }

    /// Override one per-event energy field by name (any
    /// [`ENERGY_FIELDS`] member — the `hw.<field>` config surface).
    pub fn set_energy_field(&mut self, field: &str, v: f64) -> Result<()> {
        energy_set(&mut self.energy, field, v)
    }

    /// Override one area field by name (any [`AREA_FIELDS`] member).
    pub fn set_area_field(&mut self, field: &str, v: f64) -> Result<()> {
        area_set(&mut self.area, field, v)
    }

    /// Re-price a recorded trace's cycle count under this profile's
    /// opcode table: the executor records [`CycleTable::NS_LBP`] cycles
    /// live (plus manual Ctrl/load cycles), so a profile with a different
    /// table adjusts by the per-opcode delta.
    fn exec_cycles(&self, stats: &ExecStats) -> f64 {
        let mut cycles = stats.cycles as i64;
        for (op, n) in stats.by_opcode.iter() {
            let delta =
                self.cycles.of(op) as i64 - CycleTable::NS_LBP.of(op) as i64;
            cycles += n as i64 * delta;
        }
        cycles.max(0) as f64
    }

    fn scaled(&self, mut energy: EnergyBreakdown, time_ns: f64) -> Cost {
        energy.compute_pj *= self.energy_scale;
        energy.read_pj *= self.energy_scale;
        energy.write_pj *= self.energy_scale;
        energy.ctrl_pj *= self.energy_scale;
        energy.dpu_pj *= self.energy_scale;
        // sensor + transmission are node-independent: never scaled
        Cost { energy, time_ns }
    }
}

// ---------------------------------------------------------------------------
// The CostModel trait
// ---------------------------------------------------------------------------

/// The pricing API every consumer goes through: event counts in,
/// [`Cost`] out.  Implemented by [`HwProfile`]; the trait exists so
/// exotic models (e.g. measurement-driven ones) can slot in behind the
/// same call sites.
pub trait CostModel {
    /// Profile name for telemetry stamping.
    fn profile_name(&self) -> &str;

    /// Cycle time [ns].
    fn cycle_ns(&self) -> f64;

    /// Cost of an ISA execution trace on one sub-array.
    fn exec_cost(&self, stats: &ExecStats) -> Cost;

    /// Cost of the DPU activity (no array time).
    fn dpu_cost(&self, stats: &DpuStats) -> Cost;

    /// Sensor-side cost: CDS readout + per-bit ADC (the Ap-LBP LSB skip
    /// reduces `effective_bits`).
    fn sensor_cost(&self, pixels: u64, effective_bits: u64) -> Cost;

    /// Off-chip transmission cost of shipping `bits` out of the node.
    fn transmission_cost(&self, bits: u64) -> Cost;

    /// Whole cache slice area [mm²].
    fn area_mm2(&self, geometry: &CacheGeometry) -> f64;

    /// Peak compute efficiency [TOPS/W]: bit-ops per compute activation
    /// over its (scaled) energy.  Reproduces the paper's 37.4 for
    /// `ns_lbp_65nm` at 256 lanes.
    fn tops_per_watt(&self, lanes_per_op: u64) -> f64;

    /// Peak throughput of a whole cache slice [Tera-ops/s]: every
    /// compute sub-array issues one row-op per cycle.
    fn peak_tops(&self, geometry: &CacheGeometry) -> f64;
}

impl CostModel for HwProfile {
    fn profile_name(&self) -> &str {
        &self.name
    }

    fn cycle_ns(&self) -> f64 {
        1.0 / self.energy.freq_ghz
    }

    fn exec_cost(&self, stats: &ExecStats) -> Cost {
        let cycles = self.exec_cycles(stats);
        let p = &self.energy;
        let energy = EnergyBreakdown {
            compute_pj: stats.compute_ops as f64 * p.compute_op_pj,
            read_pj: stats.row_reads as f64 * p.row_read_pj,
            write_pj: stats.row_writes as f64 * p.row_write_pj,
            ctrl_pj: cycles * p.ctrl_cycle_pj,
            ..Default::default()
        };
        self.scaled(energy, cycles * self.cycle_ns())
    }

    fn dpu_cost(&self, stats: &DpuStats) -> Cost {
        let p = &self.energy;
        let energy = EnergyBreakdown {
            dpu_pj: stats.bitcounts as f64 * p.bitcount_pj
                + stats.shifts as f64 * p.shift_pj
                + stats.adds as f64 * p.add_pj
                + stats.activations as f64 * p.activation_pj
                + stats.quantize_ops as f64 * p.quantize_pj
                + stats.shifted_relus as f64 * p.shifted_relu_pj,
            ..Default::default()
        };
        self.scaled(energy, 0.0)
    }

    fn sensor_cost(&self, pixels: u64, effective_bits: u64) -> Cost {
        Cost {
            energy: EnergyBreakdown {
                sensor_pj: pixels as f64
                    * (self.energy.pixel_read_pj
                        + effective_bits as f64 * self.energy.adc_bit_pj),
                ..Default::default()
            },
            time_ns: 0.0,
        }
    }

    fn transmission_cost(&self, bits: u64) -> Cost {
        Cost {
            energy: EnergyBreakdown {
                transmission_pj: bits as f64 * self.energy.offchip_bit_pj,
                ..Default::default()
            },
            time_ns: 0.0,
        }
    }

    fn area_mm2(&self, geometry: &CacheGeometry) -> f64 {
        self.area.slice_mm2(geometry)
    }

    fn tops_per_watt(&self, lanes_per_op: u64) -> f64 {
        // ops / pJ == TOPS/W (1 op/pJ = 1 TOPS/W)
        lanes_per_op as f64 / (self.energy.compute_op_pj * self.energy_scale)
    }

    fn peak_tops(&self, geometry: &CacheGeometry) -> f64 {
        geometry.total_subarrays() as f64
            * geometry.cols as f64
            * self.energy.freq_ghz
            * 1e9
            / 1e12
    }
}

// ---------------------------------------------------------------------------
// Field tables (serialization + config overrides)
// ---------------------------------------------------------------------------

pub(crate) fn energy_get(p: &EnergyParams, field: &str) -> f64 {
    match field {
        "freq_ghz" => p.freq_ghz,
        "compute_op_pj" => p.compute_op_pj,
        "row_read_pj" => p.row_read_pj,
        "row_write_pj" => p.row_write_pj,
        "ctrl_cycle_pj" => p.ctrl_cycle_pj,
        "bitcount_pj" => p.bitcount_pj,
        "shift_pj" => p.shift_pj,
        "add_pj" => p.add_pj,
        "activation_pj" => p.activation_pj,
        "quantize_pj" => p.quantize_pj,
        "shifted_relu_pj" => p.shifted_relu_pj,
        "adc_bit_pj" => p.adc_bit_pj,
        "pixel_read_pj" => p.pixel_read_pj,
        "offchip_bit_pj" => p.offchip_bit_pj,
        "mac8_pj" => p.mac8_pj,
        "flop_pj" => p.flop_pj,
        other => unreachable!("unknown energy field {other}"),
    }
}

pub(crate) fn energy_set(p: &mut EnergyParams, field: &str, v: f64)
                         -> Result<()> {
    match field {
        "freq_ghz" => p.freq_ghz = v,
        "compute_op_pj" => p.compute_op_pj = v,
        "row_read_pj" => p.row_read_pj = v,
        "row_write_pj" => p.row_write_pj = v,
        "ctrl_cycle_pj" => p.ctrl_cycle_pj = v,
        "bitcount_pj" => p.bitcount_pj = v,
        "shift_pj" => p.shift_pj = v,
        "add_pj" => p.add_pj = v,
        "activation_pj" => p.activation_pj = v,
        "quantize_pj" => p.quantize_pj = v,
        "shifted_relu_pj" => p.shifted_relu_pj = v,
        "adc_bit_pj" => p.adc_bit_pj = v,
        "pixel_read_pj" => p.pixel_read_pj = v,
        "offchip_bit_pj" => p.offchip_bit_pj = v,
        "mac8_pj" => p.mac8_pj = v,
        "flop_pj" => p.flop_pj = v,
        other => {
            return Err(Error::Config(format!("unknown energy field {other}")))
        }
    }
    Ok(())
}

pub(crate) fn area_get(a: &AreaModel, field: &str) -> f64 {
    match field {
        "bitcell_um2" => a.bitcell_um2,
        "sa_um2" => a.sa_um2,
        "sa_overhead" => a.sa_overhead,
        "periphery_um2" => a.periphery_um2,
        other => unreachable!("unknown area field {other}"),
    }
}

pub(crate) fn area_set(a: &mut AreaModel, field: &str, v: f64) -> Result<()> {
    match field {
        "bitcell_um2" => a.bitcell_um2 = v,
        "sa_um2" => a.sa_um2 = v,
        "sa_overhead" => a.sa_overhead = v,
        "periphery_um2" => a.periphery_um2 = v,
        other => {
            return Err(Error::Config(format!("unknown area field {other}")))
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyModel;

    /// A fixed trace fixture exercising every accounting channel.
    fn exec_fixture() -> ExecStats {
        let mut stats = ExecStats::default();
        stats.instructions = 40;
        stats.cycles = 55;
        stats.row_reads = 9;
        stats.row_writes = 31;
        stats.compute_ops = 25;
        stats.by_opcode.insert(Opcode::Copy, 5);
        stats.by_opcode.insert(Opcode::Ini, 2);
        stats.by_opcode.insert(Opcode::Cmp, 12);
        stats.by_opcode.insert(Opcode::Carry, 13);
        stats
    }

    fn dpu_fixture() -> DpuStats {
        DpuStats {
            quantize_ops: 11,
            bitcounts: 7,
            shifts: 7,
            adds: 9,
            activations: 3,
            shifted_relus: 100,
        }
    }

    #[test]
    fn ns_lbp_profile_is_cost_identical_to_legacy_model() {
        // the acceptance-criterion parity: the built-in ns_lbp_65nm
        // profile prices a fixed trace exactly like the pre-refactor
        // EnergyModel + Opcode::cycles() defaults
        let profile = HwProfile::ns_lbp_65nm();
        let legacy = EnergyModel::default();
        let stats = exec_fixture();
        let cost = profile.exec_cost(&stats);
        assert_eq!(cost.energy, legacy.exec_energy(&stats));
        assert!((cost.time_ns - legacy.exec_time_ns(&stats)).abs() < 1e-12);
        let dpu = dpu_fixture();
        assert_eq!(profile.dpu_cost(&dpu).energy, legacy.dpu_energy(&dpu));
        assert_eq!(profile.sensor_cost(784, 6).energy,
                   legacy.sensor_energy(784, 6));
        assert_eq!(profile.transmission_cost(6272).energy,
                   legacy.transmission_energy(6272));
        assert!((profile.cycle_ns() - legacy.cycle_ns()).abs() < 1e-15);
        assert!((profile.tops_per_watt(256) - legacy.tops_per_watt(256))
            .abs() < 1e-12);
    }

    #[test]
    fn golden_headline_anchors() {
        // the paper's anchors, straight off the built-in profile
        let p = HwProfile::ns_lbp_65nm();
        assert!((p.tops_per_watt(256) - 37.4).abs() < 1e-9);
        assert!((p.energy.freq_ghz - 1.25).abs() < 1e-12);
        assert!((p.cycle_ns() - 0.8).abs() < 1e-12);
        assert!((p.area.sa_overhead - 3.4).abs() < 1e-12);
        assert!(p.area_mm2(&CacheGeometry::default()) > 0.0);
        // 320 sub-arrays × 256 lanes × 1.25 GHz = 102.4 TOPS
        assert!((p.peak_tops(&CacheGeometry::default()) - 102.4).abs()
            < 1e-9);
    }

    #[test]
    fn builtins_resolve_validate_and_roundtrip() {
        for &name in BUILTIN_PROFILES {
            let p = HwProfile::resolve(name).unwrap();
            assert_eq!(p.name, name);
            p.validate().unwrap();
            // serialize → parse → equal (lossless float round-trip)
            let back = HwProfile::from_toml(&p.to_toml()).unwrap();
            assert_eq!(back, p, "{name} TOML round-trip");
        }
        assert!(HwProfile::resolve("tpu_v9").is_err());
        assert!(HwProfile::builtin("tpu_v9").is_none());
    }

    #[test]
    fn shipped_profile_files_match_builtins() {
        // configs/profiles/*.toml are the on-disk form of the builtins;
        // loading them (by path, through the resolve() surface users
        // take) must reproduce the in-code profiles exactly
        let dir =
            concat!(env!("CARGO_MANIFEST_DIR"), "/configs/profiles");
        for &name in BUILTIN_PROFILES {
            let path = format!("{dir}/{name}.toml");
            let loaded = HwProfile::resolve(&path)
                .unwrap_or_else(|e| panic!("{path}: {e}"));
            assert_eq!(loaded, HwProfile::builtin(name).unwrap(),
                       "{name} file drifted from the builtin");
        }
    }

    #[test]
    fn cycle_table_reprices_traces() {
        let mut p = HwProfile::ns_lbp_65nm();
        p.name = "slow_compare".into();
        p.cycles.set(Opcode::Cmp, 3); // +2 cycles per cmp
        let stats = exec_fixture(); // 12 cmp instructions, 55 cycles
        let base = HwProfile::ns_lbp_65nm().exec_cost(&stats);
        let slow = p.exec_cost(&stats);
        let extra_cycles = 12.0 * 2.0;
        assert!((slow.time_ns
            - (base.time_ns + extra_cycles * p.cycle_ns()))
            .abs() < 1e-9);
        assert!((slow.energy.ctrl_pj
            - (base.energy.ctrl_pj
                + extra_cycles * p.energy.ctrl_cycle_pj))
            .abs() < 1e-9);
        // and the table survives serialization
        let back = HwProfile::from_toml(&p.to_toml()).unwrap();
        assert_eq!(back.cycles.of(Opcode::Cmp), 3);
        assert_eq!(back, p);
    }

    #[test]
    fn energy_scale_applies_to_node_local_channels_only() {
        let prior = HwProfile::sram38_28nm();
        let base = HwProfile::ns_lbp_65nm();
        let stats = exec_fixture();
        let (a, b) = (base.exec_cost(&stats), prior.exec_cost(&stats));
        assert!((b.energy.compute_pj
            - a.energy.compute_pj * prior.energy_scale)
            .abs() < 1e-9);
        // time scales with the slower clock, not the energy scale
        assert!((b.time_ns - a.time_ns * (1.25 / 0.475)).abs() < 1e-6);
        // sensor/transmission are node-independent
        assert_eq!(prior.sensor_cost(100, 8), base.sensor_cost(100, 8));
        assert_eq!(prior.transmission_cost(800),
                   base.transmission_cost(800));
        // efficiency drops with the scale
        assert!(prior.tops_per_watt(256) < base.tops_per_watt(256));
    }

    #[test]
    fn from_config_rejects_bad_profiles() {
        // unknown keys
        assert!(HwProfile::from_toml(
            "[profile]\nname = \"x\"\n[energy]\nwarp_pj = 1.0"
        )
        .is_err());
        // missing name
        assert!(HwProfile::from_toml("[energy]\nfreq_ghz = 1.0").is_err());
        // names unsafe for TOML/JSON embedding (spaces, control chars)
        assert!(HwProfile::from_toml("[profile]\nname = \"white space\"")
            .is_err());
        let mut odd = HwProfile::ns_lbp_65nm();
        odd.name = "tab\tname".into();
        assert!(odd.validate().is_err());
        // "mixed" is the merged-telemetry sentinel, not a profile name
        odd.name = "mixed".into();
        assert!(odd.validate().is_err());
        // invalid values
        assert!(HwProfile::from_toml(
            "[profile]\nname = \"x\"\n[energy]\nfreq_ghz = 0.0"
        )
        .is_err());
        assert!(HwProfile::from_toml(
            "[profile]\nname = \"x\"\nenergy_scale = -1.0"
        )
        .is_err());
        assert!(HwProfile::from_toml(
            "[profile]\nname = \"x\"\n[energy]\nrow_read_pj = -4.0"
        )
        .is_err());
        assert!(HwProfile::from_toml(
            "[profile]\nname = \"x\"\n[energy]\ncompute_op_pj = 0.0"
        )
        .is_err());
        // unset fields default to ns_lbp_65nm
        let p = HwProfile::from_toml("[profile]\nname = \"just_named\"")
            .unwrap();
        assert_eq!(p.energy, EnergyParams::default());
        assert_eq!(p.cycles, CycleTable::NS_LBP);
    }

    #[test]
    fn cost_add_and_sanity() {
        let p = HwProfile::ns_lbp_65nm();
        let mut c = p.exec_cost(&exec_fixture());
        let d = p.dpu_cost(&dpu_fixture());
        let before = c.total_pj();
        c.add(&d);
        assert!((c.total_pj() - (before + d.total_pj())).abs() < 1e-9);
        assert!(c.is_sane());
        let bad = Cost { time_ns: f64::NAN, ..Default::default() };
        assert!(!bad.is_sane());
    }
}
