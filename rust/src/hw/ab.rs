//! A/B energy harness: the same frames through two engines under two
//! hardware profiles, diffed side by side (the ROADMAP "A/B energy
//! harness" follow-on, surfaced as `ns-lbp ab`).
//!
//! Both arms run the *same* workload — identical frames, network
//! parameters, architectural-simulation switches and cache geometry — so
//! every difference in the report is attributable to the
//! [`HwProfile`] swap: clock, per-event energies, cycle table, energy
//! scale, area factors.  Logits are asserted identical across arms
//! (the profile prices the hardware; it must never change the math).
//!
//! ```no_run
//! use ns_lbp::engine::EngineConfig;
//! use ns_lbp::hw::{ab::AbHarness, HwProfile};
//! use ns_lbp::params::synth::synth_params;
//! use ns_lbp::testing::synth_frames;
//!
//! let (_, params) = synth_params(7);
//! let frames = synth_frames(&params, 4, 7).unwrap();
//! let harness = AbHarness::new(
//!     params,
//!     EngineConfig::default(),
//!     HwProfile::ns_lbp_65nm(),
//!     HwProfile::sram38_28nm(),
//! ).unwrap();
//! let report = harness.run(&frames).unwrap();
//! report.print();
//! assert!(report.energy_ratio() > 1.0); // NS-LBP wins on energy
//! ```

use crate::energy::EnergyBreakdown;
use crate::engine::{BackendKind, Engine, EngineConfig};
use crate::error::{Error, Result};
use crate::params::NetParams;
use crate::sensor::Frame;

use super::{CostModel, HwProfile};

/// The A/B runner: one engine per profile over a shared workload.
pub struct AbHarness {
    params: NetParams,
    config: EngineConfig,
    a: HwProfile,
    b: HwProfile,
}

impl AbHarness {
    /// Build a harness comparing profiles `a` and `b` under `config`'s
    /// geometry and architectural-simulation switches.
    pub fn new(params: NetParams, config: EngineConfig, a: HwProfile,
               b: HwProfile) -> Result<Self> {
        config.validate()?;
        a.validate()?;
        b.validate()?;
        if a.name == b.name {
            return Err(Error::Config(format!(
                "A/B harness: both arms are profile {:?} — nothing to diff",
                a.name
            )));
        }
        Ok(Self { params, config, a, b })
    }

    fn run_arm(&self, profile: &HwProfile, frames: &[Frame])
               -> Result<(ArmReport, Vec<Vec<f32>>)> {
        let mut config = self.config.clone();
        config.system.hw.profile = profile.clone();
        // each arm's clock is the profile's own — without this, an
        // ns_lbp_65nm arm at stock clock would be re-clocked by
        // [circuit] freq_ghz and the diff would no longer be
        // attributable to the profile swap alone
        config.system.hw.clock_explicit = true;
        let mut engine = Engine::builder()
            .config(config.clone())
            .params(self.params.clone())
            .backend(BackendKind::Architectural)
            .no_cross_check()
            .build()?;
        let out = engine.infer_batch(frames)?;
        let t = out.telemetry();
        if t.arch_mismatches != 0 {
            return Err(Error::Engine(format!(
                "A/B arm {:?}: {} architectural/functional divergences",
                profile.name, t.arch_mismatches
            )));
        }
        let n = out.frames.len().max(1) as f64;
        let resolved = config.system.hw_profile();
        let report = ArmReport {
            profile: profile.name.clone(),
            frames: out.frames.len() as u64,
            energy: t.cost.energy,
            total_time_ns: t.cost.time_ns,
            energy_uj_per_frame: t.cost.energy.total_pj() / 1e6 / n,
            time_us_per_frame: t.cost.time_ns / 1e3 / n,
            tops_per_watt: resolved
                .tops_per_watt(config.system.cache.cols as u64),
            area_mm2: resolved.area_mm2(&config.system.cache),
        };
        let logits = out.frames.into_iter().map(|f| f.logits).collect();
        Ok((report, logits))
    }

    /// Run both arms over `frames` and diff them.  Errors if the arms'
    /// logits diverge — a cost model must never change the math.
    pub fn run(&self, frames: &[Frame]) -> Result<AbReport> {
        if frames.is_empty() {
            return Err(Error::Engine("A/B harness: no frames".into()));
        }
        let (a, logits_a) = self.run_arm(&self.a, frames)?;
        let (b, logits_b) = self.run_arm(&self.b, frames)?;
        if logits_a != logits_b {
            return Err(Error::Engine(
                "A/B harness: logits diverged between arms — a hardware \
                 profile must only re-price, never change results"
                    .into(),
            ));
        }
        Ok(AbReport { a, b })
    }
}

/// One arm's aggregate: totals plus the per-frame and headline figures.
#[derive(Clone, Debug)]
pub struct ArmReport {
    pub profile: String,
    pub frames: u64,
    /// Itemized energy totals over the whole run.
    pub energy: EnergyBreakdown,
    /// Summed modeled accelerator time [ns].
    pub total_time_ns: f64,
    pub energy_uj_per_frame: f64,
    pub time_us_per_frame: f64,
    /// Peak efficiency at this geometry's lane width.
    pub tops_per_watt: f64,
    /// Whole cache slice area under this profile's factors [mm²].
    pub area_mm2: f64,
}

impl ArmReport {
    fn to_json(&self) -> String {
        format!(
            "{{\"profile\":\"{}\",\"frames\":{},\
             \"energy_uj_per_frame\":{},\"time_us_per_frame\":{},\
             \"tops_per_watt\":{},\"area_mm2\":{}}}",
            self.profile, self.frames, self.energy_uj_per_frame,
            self.time_us_per_frame, self.tops_per_watt, self.area_mm2
        )
    }
}

/// The side-by-side diff of one A/B run.
#[derive(Clone, Debug)]
pub struct AbReport {
    pub a: ArmReport,
    pub b: ArmReport,
}

impl AbReport {
    /// B's per-frame energy over A's (> 1 means A is cheaper).
    pub fn energy_ratio(&self) -> f64 {
        self.b.energy_uj_per_frame / self.a.energy_uj_per_frame.max(1e-12)
    }

    /// B's per-frame modeled time over A's (> 1 means A is faster).
    pub fn time_ratio(&self) -> f64 {
        self.b.time_us_per_frame / self.a.time_us_per_frame.max(1e-12)
    }

    /// Name of the arm that wins on energy.
    pub fn energy_winner(&self) -> &str {
        if self.energy_ratio() >= 1.0 { &self.a.profile } else { &self.b.profile }
    }

    pub fn print(&self) {
        println!("== A/B energy report: {} vs {} ({} frames) ==",
                 self.a.profile, self.b.profile, self.a.frames);
        println!("  {:<22} {:>14} {:>14} {:>9}", "metric",
                 self.a.profile, self.b.profile, "B/A");
        let rows: [(&str, f64, f64); 4] = [
            ("energy [µJ/frame]", self.a.energy_uj_per_frame,
             self.b.energy_uj_per_frame),
            ("time [µs/frame]", self.a.time_us_per_frame,
             self.b.time_us_per_frame),
            ("peak TOPS/W", self.a.tops_per_watt, self.b.tops_per_watt),
            ("slice area [mm²]", self.a.area_mm2, self.b.area_mm2),
        ];
        for (label, va, vb) in rows {
            println!("  {:<22} {:>14.4} {:>14.4} {:>8.2}x", label, va, vb,
                     vb / va.max(1e-12));
        }
        println!("  energy winner: {} ({:.2}x); time winner: {} ({:.2}x)",
                 self.energy_winner(), self.energy_ratio().max(1.0 / self.energy_ratio()),
                 if self.time_ratio() >= 1.0 { &self.a.profile } else { &self.b.profile },
                 self.time_ratio().max(1.0 / self.time_ratio()));
    }

    /// One machine-readable JSON document (`ns-lbp ab --json`).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"a\":{},\"b\":{},\"energy_ratio\":{},\"time_ratio\":{},\
             \"energy_winner\":\"{}\"}}",
            self.a.to_json(), self.b.to_json(), self.energy_ratio(),
            self.time_ratio(), self.energy_winner()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::synth::synth_params;
    use crate::testing::synth_frames;

    fn harness() -> (AbHarness, Vec<Frame>) {
        let (_, params) = synth_params(5);
        let frames = synth_frames(&params, 3, 23).unwrap();
        let h = AbHarness::new(
            params,
            EngineConfig::default(),
            HwProfile::ns_lbp_65nm(),
            HwProfile::sram38_28nm(),
        )
        .unwrap();
        (h, frames)
    }

    #[test]
    fn ns_lbp_wins_energy_and_time_vs_prior_sram() {
        let (h, frames) = harness();
        let r = h.run(&frames).unwrap();
        assert_eq!(r.a.frames, 3);
        assert_eq!(r.b.frames, 3);
        // Fig.-11-consistent ordering: the 65 nm NS-LBP point beats the
        // 28 nm prior compute-SRAM on both axes
        assert!(r.energy_ratio() > 1.0, "energy ratio {}", r.energy_ratio());
        assert!(r.time_ratio() > 1.0, "time ratio {}", r.time_ratio());
        assert_eq!(r.energy_winner(), "ns_lbp_65nm");
        // rough factor bands: energy tracks the 1.55x node scale (diluted
        // by the unscaled sensor term), time the 1.25/0.475 clock ratio
        assert!((1.2..3.5).contains(&r.energy_ratio()),
                "energy ratio {}", r.energy_ratio());
        assert!((1.8..5.0).contains(&r.time_ratio()),
                "time ratio {}", r.time_ratio());
        assert!(r.a.tops_per_watt > r.b.tops_per_watt);
        // the prior platform's SA overhead (5.52x vs 3.4x) costs area
        assert!(r.b.area_mm2 > r.a.area_mm2);
    }

    #[test]
    fn json_report_is_well_formed_and_arms_differ() {
        let (h, frames) = harness();
        let r = h.run(&frames[..1]).unwrap();
        let json = r.to_json();
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        for key in ["\"a\":", "\"b\":", "\"energy_ratio\":",
                    "\"time_ratio\":", "\"profile\":\"ns_lbp_65nm\"",
                    "\"profile\":\"sram38_28nm\"", "\"energy_winner\":"] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert_ne!(r.a.energy_uj_per_frame, r.b.energy_uj_per_frame);
    }

    #[test]
    fn rejects_identical_arms_and_empty_runs() {
        let (_, params) = synth_params(5);
        assert!(AbHarness::new(
            params.clone(),
            EngineConfig::default(),
            HwProfile::ns_lbp_65nm(),
            HwProfile::ns_lbp_65nm()
        )
        .is_err());
        let (h, _) = harness();
        assert!(h.run(&[]).is_err());
    }
}
