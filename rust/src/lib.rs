//! # NS-LBP: near-sensor processing-in-SRAM accelerator for Ap-LBP networks
//!
//! Full-system reproduction of *"A Near-Sensor Processing Accelerator for
//! Approximate Local Binary Pattern Networks"* (Angizi et al., 2022) as the
//! Layer-3 runtime of a three-layer Rust + JAX + Pallas stack (DESIGN.md).
//!
//! Module map (bottom-up):
//!
//! * [`rng`], [`testing`], [`config`], [`cli`], [`bench_harness`] — offline
//!   substrate (PRNG, property tests, config/CLI parsing, bench statistics
//!   with `BENCH_*.json` trajectory output — see EXPERIMENTS.md);
//!   crates.io is unreachable in this environment, so these replace
//!   rand/proptest/serde/clap/criterion.
//! * [`circuit`] — behavioral analog model of the 8T sub-array: RBL
//!   discharge, the reconfigurable 3-reference sense amplifier, the
//!   capacitive MAJ/XOR3 generator, and Monte-Carlo variation (paper §4.1,
//!   Figs. 5, 9, 10).
//! * [`sram`] — the memory geometry: 256×256 computational sub-arrays →
//!   16 KB mats → 32 KB banks → the 2.5 MB near-sensor cache slice, plus the
//!   P/C/Resv/W/I region split (paper Figs. 5a–c, 6a).
//! * [`isa`] — the NS-LBP instruction set of Table 2 (copy/ini/cmp/search/
//!   nand3/nor3/maj3/xor3), an assembler, and a trace-collecting executor.
//! * [`lbp`] — the parallel in-memory LBP algorithm (Algorithm 1), the PAC
//!   approximation accounting, and the op-count formulas of Eqs. 1–2.
//! * [`mapping`] — correlated data partitioning of pixels/pivots into
//!   sub-array regions (paper §5.1, Fig. 6).
//! * [`mlp`] — bit-serial in-memory MLP: AND / bitcount / shift (paper §5.2,
//!   Fig. 7), plus `WeightPlanes` — the static weight bit-planes
//!   transposed once at engine build and bulk-written into the W region
//!   (the allocation-free hot path, EXPERIMENTS.md §Perf).
//! * [`dpu`] — the digital processing unit: quantizer, activation,
//!   bit-counter, shifter, adder tree.
//! * [`sensor`] — rolling-shutter CMOS sensor front-end with CDS and the
//!   LSB-skipping dual-mode ADC (paper §4.1).
//! * [`energy`] — the Cacti-like timing/energy/area arithmetic calibrated
//!   to the paper's 65 nm post-layout numbers (§6.1, Table 3); the raw
//!   per-event tables behind the `hw` profiles.
//! * [`hw`] — the unified hardware cost-model subsystem: the `CostModel`
//!   trait (`exec_cost`/`dpu_cost`/`sensor_cost`/`transmission_cost`/
//!   `cycle_ns`/`area_mm2`), named serializable `HwProfile`s (built-ins
//!   `ns_lbp_65nm`, `sram38_28nm`, `cnn8_digital`, `lbcnn`; `[hw]`
//!   config section, `configs/profiles/*.toml`, `--hw-profile`), and the
//!   `ab` A/B energy harness (`ns-lbp ab`).  Every consumer — backends,
//!   baselines, serve metrics — prices event counts through this API.
//! * [`params`], [`model`] — the Ap-LBP network parameters (read from
//!   `artifacts/*.params.bin`) and a bit-exact integer functional model that
//!   mirrors `python/compile/model.py`.
//! * [`baselines`] — analytic cost models for the comparison systems of
//!   Fig. 11 (8-bit CNN, LBCNN, LBPNet on the same cache substrate).
//! * [`runtime`] — PJRT client wrapper: loads `artifacts/*.hlo.txt` (the
//!   AOT-lowered JAX/Pallas graphs) and executes them on the request path.
//! * [`engine`] — the unified inference API: the `InferenceBackend` trait
//!   with one implementation per execution path (functional model,
//!   in-SRAM architectural simulation, PJRT golden graph), backend
//!   selection via `BackendKind`, pluggable cross-checking with mismatch
//!   accounting, and the merged cycle/energy/DPU `Telemetry`.  Both
//!   in-tree backends precompute everything static at build (prepacked
//!   weight planes, sub-array maps, LBP gather plans) and run their
//!   steady-state batch loops out of persistent scratch arenas —
//!   bit-identical to a cold engine, parity-tested.  Everything
//!   above this layer constructs backends exclusively through
//!   `engine::Engine`.
//! * [`coordinator`] — the near-sensor run loop: digitizes frames from a
//!   sensor, fans them out over worker threads (one engine each), and
//!   aggregates per-frame reports into a `RunSummary`.
//! * [`compile`] — staged model compilation (`ns-lbp compile`): a
//!   `ModelSpec` TOML description is lowered analyze → map → pack →
//!   price into a versioned `CompiledModel` artifact (canonical params,
//!   LBP gather plans, prepacked MLP weight planes, `hw`-priced cost),
//!   with every stage cached on disk by content hash so recompiles are
//!   incremental; engines built from an artifact skip all packing and
//!   are bit-identical to from-params engines.
//! * [`exec`] — zero-dependency event-driven executor: cooperative
//!   `Task` state machines multiplexed onto a small worker pool, a
//!   hashed `TimerWheel` for deadlines and batch flushes, and the
//!   `Waker`/`EventSource` readiness abstraction (`Notify`,
//!   `ExecQueue`) that an epoll-backed reactor can later slot into —
//!   the substrate under the async serve plane (`[serve.async]`).
//! * [`serve`] — the traffic-facing layer on top of the engine: typed
//!   requests (`Request`/`RequestBuilder`, per-sensor `Session` sequence
//!   spaces) with a `QosClass` each, per-class bounded admission queues
//!   (reject-newest or drop-oldest) and per-class batchers, class→backend
//!   routing (`engine::RoutingPolicy`), whole-batch shard dispatch onto
//!   engines pinned to disjoint bank slices, per-class p50/p95/p99 +
//!   drop/reject metrics, and graceful drain (`ns-lbp serve-bench`
//!   drives it end to end).
//! * [`fleet`] — multi-node serving: N in-process serve nodes behind a
//!   socket-shaped `Transport`, a router that places sensor sessions by
//!   rendezvous hash with per-node per-class admission capacity,
//!   versioned weight replication (`Fleet::push_model` rolls a compiled
//!   artifact node-by-node without dropping in-flight frames), and
//!   failure drills — kill a node mid-stream and the router re-homes
//!   its frames with zero billed loss (`ns-lbp fleet-bench`).
//! * [`obs`] — end-to-end tracing: per-request spans (submit → queue →
//!   batch → infer → complete) with `hw` energy attribution, written
//!   lock-cheaply into a bounded ring and exported off-thread as a
//!   JSONL feed plus a Chrome/Perfetto trace, with periodic queue-depth
//!   and in-flight gauges; `ns-lbp trace` summarizes a feed and
//!   `obs::json` is the crate-wide escaping JSON writer.
//!
//! Python appears only at build time (`make artifacts`); this crate is
//! self-contained at runtime.

pub mod bench_harness;
pub mod baselines;
pub mod circuit;
pub mod cli;
pub mod compile;
pub mod config;
pub mod coordinator;
pub mod dpu;
pub mod energy;
pub mod engine;
pub mod error;
pub mod exec;
pub mod faults;
pub mod fleet;
pub mod hw;
pub mod isa;
pub mod lbp;
pub mod mapping;
pub mod mlp;
pub mod model;
pub mod obs;
pub mod params;
pub mod rng;
pub mod runtime;
pub mod sensor;
pub mod serve;
pub mod sram;
pub mod testing;

pub use error::{Error, Result};

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default artifacts directory, relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";
