//! Parser for `artifacts/<ds>.params.bin` — the Ap-LBP network parameters
//! exported by `python/compile/model.py::save_params` (format v3).
//!
//! Layout (little-endian):
//! ```text
//! magic "NSLBPPRM" | u32 version
//! u32 × 14: H W C n_lbp K e window apx_code apx_pixel pool act_bits
//!           w_bits hidden n_classes
//! per LBP layer: i32 offsets[K·e·3] (dy, dx, ch), i32 pivot_ch[K]
//! per MLP layer (×2): u32 D, u32 O, i8 w[D·O], f32 scale[O], f32 bias[O]
//! ```

use std::path::Path;

use crate::error::{Error, Result};

pub const MAGIC: &[u8; 8] = b"NSLBPPRM";
pub const FORMAT_VERSION: u32 = 3;

/// Network hyper-parameters (mirrors `ApLbpConfig`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NetConfig {
    pub height: usize,
    pub width: usize,
    pub in_channels: usize,
    pub n_lbp_layers: usize,
    pub kernels_per_layer: usize,
    pub e: usize,
    pub window: usize,
    pub apx_code: usize,
    pub apx_pixel: usize,
    pub pool: usize,
    pub act_bits: usize,
    pub w_bits: usize,
    pub hidden: usize,
    pub n_classes: usize,
}

impl NetConfig {
    /// Channels entering each LBP layer (joint concat grows them).
    pub fn channels_after(&self) -> Vec<usize> {
        let mut chs = vec![self.in_channels];
        for _ in 0..self.n_lbp_layers {
            chs.push(chs.last().unwrap() + self.kernels_per_layer);
        }
        chs
    }

    pub fn feature_dim(&self) -> usize {
        (self.height / self.pool) * (self.width / self.pool)
            * self.channels_after()[self.n_lbp_layers]
    }
}

/// One sampling point: window offset + source channel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SamplePoint {
    pub dy: i32,
    pub dx: i32,
    pub ch: i32,
}

/// One LBP layer's fixed pattern.
#[derive(Clone, Debug, PartialEq)]
pub struct LbpLayer {
    /// `[kernel][sample]` points.
    pub offsets: Vec<Vec<SamplePoint>>,
    /// Pivot channel per kernel.
    pub pivot_ch: Vec<i32>,
}

/// One quantized FC layer with folded affine.
#[derive(Clone, Debug, PartialEq)]
pub struct MlpLayer {
    pub d: usize,
    pub o: usize,
    /// Row-major `[d][o]` signed w_bits-bit weights.
    pub w: Vec<i8>,
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
}

impl MlpLayer {
    #[inline]
    pub fn weight(&self, di: usize, oi: usize) -> i8 {
        self.w[di * self.o + oi]
    }
}

/// Full parameter set.
#[derive(Clone, Debug, PartialEq)]
pub struct NetParams {
    pub config: NetConfig,
    pub lbp_layers: Vec<LbpLayer>,
    pub mlp1: MlpLayer,
    pub mlp2: MlpLayer,
}

struct Cursor<'a> {
    data: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.data.len() {
            return Err(Error::Params(format!(
                "truncated file: need {n} bytes at offset {}, have {}",
                self.off,
                self.data.len() - self.off
            )));
        }
        let s = &self.data[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> Result<usize> {
        Ok(self.u32()? as usize)
    }
}

/// Parse a params file from bytes.
pub fn parse(data: &[u8]) -> Result<NetParams> {
    let mut c = Cursor { data, off: 0 };
    if c.take(8)? != MAGIC {
        return Err(Error::Params("bad magic".into()));
    }
    let version = c.u32()?;
    if version != FORMAT_VERSION {
        return Err(Error::Params(format!(
            "format version {version}, expected {FORMAT_VERSION}"
        )));
    }
    let config = NetConfig {
        height: c.usize()?,
        width: c.usize()?,
        in_channels: c.usize()?,
        n_lbp_layers: c.usize()?,
        kernels_per_layer: c.usize()?,
        e: c.usize()?,
        window: c.usize()?,
        apx_code: c.usize()?,
        apx_pixel: c.usize()?,
        pool: c.usize()?,
        act_bits: c.usize()?,
        w_bits: c.usize()?,
        hidden: c.usize()?,
        n_classes: c.usize()?,
    };
    validate_config(&config)?;

    let mut lbp_layers = Vec::with_capacity(config.n_lbp_layers);
    let chs = config.channels_after();
    for (li, &in_ch) in chs[..config.n_lbp_layers].iter().enumerate() {
        let mut offsets = Vec::with_capacity(config.kernels_per_layer);
        let p = (config.window as i32 - 1) / 2;
        for _ in 0..config.kernels_per_layer {
            let mut pts = Vec::with_capacity(config.e);
            for _ in 0..config.e {
                let (dy, dx, ch) = (c.i32()?, c.i32()?, c.i32()?);
                if dy.abs() > p || dx.abs() > p || ch < 0 || ch as usize >= in_ch {
                    return Err(Error::Params(format!(
                        "layer {li}: sample point ({dy},{dx},{ch}) outside \
                         window ±{p} / {in_ch} channels"
                    )));
                }
                pts.push(SamplePoint { dy, dx, ch });
            }
            offsets.push(pts);
        }
        let mut pivot_ch = Vec::with_capacity(config.kernels_per_layer);
        for _ in 0..config.kernels_per_layer {
            let ch = c.i32()?;
            if ch < 0 || ch as usize >= in_ch {
                return Err(Error::Params(format!(
                    "layer {li}: pivot channel {ch} out of range {in_ch}"
                )));
            }
            pivot_ch.push(ch);
        }
        lbp_layers.push(LbpLayer { offsets, pivot_ch });
    }

    let mut mlps = Vec::with_capacity(2);
    for idx in 0..2 {
        let d = c.usize()?;
        let o = c.usize()?;
        let raw = c.take(d * o)?;
        let w: Vec<i8> = raw.iter().map(|&b| b as i8).collect();
        let half = 1i8 << (config.w_bits - 1);
        if let Some(&bad) = w.iter().find(|&&v| v < -half || v >= half) {
            return Err(Error::Params(format!(
                "mlp{}: weight {bad} outside signed {}-bit range",
                idx + 1,
                config.w_bits
            )));
        }
        let mut scale = Vec::with_capacity(o);
        for _ in 0..o {
            scale.push(c.f32()?);
        }
        let mut bias = Vec::with_capacity(o);
        for _ in 0..o {
            bias.push(c.f32()?);
        }
        mlps.push(MlpLayer { d, o, w, scale, bias });
    }
    let mlp2 = mlps.pop().unwrap();
    let mlp1 = mlps.pop().unwrap();

    if c.off != data.len() {
        return Err(Error::Params(format!(
            "{} trailing bytes",
            data.len() - c.off
        )));
    }
    if mlp1.d != config.feature_dim() {
        return Err(Error::Params(format!(
            "mlp1 input dim {} != feature dim {}",
            mlp1.d,
            config.feature_dim()
        )));
    }
    if mlp1.o != config.hidden || mlp2.d != config.hidden
        || mlp2.o != config.n_classes
    {
        return Err(Error::Params("MLP shape chain mismatch".into()));
    }
    Ok(NetParams { config, lbp_layers, mlp1, mlp2 })
}

pub(crate) fn validate_config(c: &NetConfig) -> Result<()> {
    if c.height == 0 || c.width == 0 || c.in_channels == 0 {
        return Err(Error::Params("zero image dims".into()));
    }
    if c.e == 0 || c.e > 32 || c.window % 2 == 0 {
        return Err(Error::Params(format!(
            "bad kernel geometry e={} window={}",
            c.e, c.window
        )));
    }
    if c.apx_code >= c.e || c.apx_pixel >= 8 {
        return Err(Error::Params("approximation bits out of range".into()));
    }
    if c.pool == 0 || c.height % c.pool != 0 || c.width % c.pool != 0 {
        return Err(Error::Params(format!(
            "pool {} does not divide {}x{}",
            c.pool, c.height, c.width
        )));
    }
    if c.act_bits == 0 || c.act_bits > 8 || c.w_bits == 0 || c.w_bits > 8 {
        return Err(Error::Params("bad bit widths".into()));
    }
    Ok(())
}

/// Load from a file path.
pub fn load(path: impl AsRef<Path>) -> Result<NetParams> {
    let data = std::fs::read(path.as_ref()).map_err(|e| {
        Error::Params(format!("cannot read {}: {e}", path.as_ref().display()))
    })?;
    parse(&data)
}

/// Synthetic parameter generation — the deterministic fallback used by
/// tests, benches, and `serve-bench` when `artifacts/*.params.bin` are
/// absent (production params come from the Python build path).
pub mod synth {
    use super::*;
    use crate::rng::Xoshiro256;

    /// Build a small, valid params blob (and its parsed form) with the
    /// default test geometry.
    pub fn synth_params(seed: u64) -> (Vec<u8>, NetParams) {
        synth_params_for(default_config(), seed)
    }

    /// The geometry `synth_params` has always used; spec files that omit
    /// keys inherit these values too.
    pub fn default_config() -> NetConfig {
        NetConfig {
            height: 12, width: 12, in_channels: 1, n_lbp_layers: 2,
            kernels_per_layer: 4, e: 8, window: 3, apx_code: 0, apx_pixel: 0,
            pool: 4, act_bits: 4, w_bits: 4, hidden: 16, n_classes: 10,
        }
    }

    /// Build a valid params blob for an arbitrary geometry. Sample-point
    /// offsets are drawn within the config's window and weights within
    /// its signed `w_bits` range; for `default_config()` the draw
    /// sequence is bit-identical to what `synth_params` always produced.
    pub fn synth_params_for(config: NetConfig, seed: u64) -> (Vec<u8>, NetParams) {
        let mut rng = Xoshiro256::new(seed);
        let p = (config.window as i64 - 1) / 2;
        let half = 1i64 << (config.w_bits - 1);
        let chs = config.channels_after();
        let mut lbp_layers = Vec::new();
        for &in_ch in &chs[..config.n_lbp_layers] {
            let mut offsets = Vec::new();
            for _ in 0..config.kernels_per_layer {
                let mut pts = Vec::new();
                for _ in 0..config.e {
                    loop {
                        let dy = rng.range_i64(-p, p) as i32;
                        let dx = rng.range_i64(-p, p) as i32;
                        if (dy, dx) != (0, 0) {
                            pts.push(SamplePoint {
                                dy, dx,
                                ch: rng.below(in_ch as u64) as i32,
                            });
                            break;
                        }
                    }
                }
                offsets.push(pts);
            }
            let pivot_ch = (0..config.kernels_per_layer)
                .map(|_| rng.below(in_ch as u64) as i32)
                .collect();
            lbp_layers.push(LbpLayer { offsets, pivot_ch });
        }
        let mk_mlp = |rng: &mut Xoshiro256, d: usize, o: usize| MlpLayer {
            d, o,
            w: (0..d * o)
                .map(|_| (rng.below(2 * half as u64) as i64 - half) as i8)
                .collect(),
            scale: (0..o).map(|_| 0.001 + rng.next_f64() as f32 * 0.001).collect(),
            bias: (0..o).map(|_| rng.next_f64() as f32 * 0.1).collect(),
        };
        let mlp1 = mk_mlp(&mut rng, config.feature_dim(), config.hidden);
        let mlp2 = mk_mlp(&mut rng, config.hidden, config.n_classes);
        let params = NetParams { config, lbp_layers, mlp1, mlp2 };
        (serialize(&params), params)
    }

    /// Serializer (mirrors `python/compile/model.py::save_params`).
    pub fn serialize(p: &NetParams) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        let c = &p.config;
        for v in [c.height, c.width, c.in_channels, c.n_lbp_layers,
                  c.kernels_per_layer, c.e, c.window, c.apx_code, c.apx_pixel,
                  c.pool, c.act_bits, c.w_bits, c.hidden, c.n_classes] {
            out.extend_from_slice(&(v as u32).to_le_bytes());
        }
        for layer in &p.lbp_layers {
            for pts in &layer.offsets {
                for pt in pts {
                    out.extend_from_slice(&pt.dy.to_le_bytes());
                    out.extend_from_slice(&pt.dx.to_le_bytes());
                    out.extend_from_slice(&pt.ch.to_le_bytes());
                }
            }
            for &ch in &layer.pivot_ch {
                out.extend_from_slice(&ch.to_le_bytes());
            }
        }
        for mlp in [&p.mlp1, &p.mlp2] {
            out.extend_from_slice(&(mlp.d as u32).to_le_bytes());
            out.extend_from_slice(&(mlp.o as u32).to_le_bytes());
            out.extend(mlp.w.iter().map(|&v| v as u8));
            for &s in &mlp.scale {
                out.extend_from_slice(&s.to_le_bytes());
            }
            for &b in &mlp.bias {
                out.extend_from_slice(&b.to_le_bytes());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::synth::{serialize, synth_params};
    use super::*;

    #[test]
    fn roundtrip() {
        let (blob, params) = synth_params(1);
        let parsed = parse(&blob).unwrap();
        assert_eq!(parsed, params);
        assert_eq!(serialize(&parsed), blob);
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let (mut blob, _) = synth_params(2);
        blob[0] = b'X';
        assert!(parse(&blob).is_err());
        let (mut blob, _) = synth_params(2);
        blob[8] = 99;
        assert!(parse(&blob).is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let (blob, _) = synth_params(3);
        assert!(parse(&blob[..blob.len() - 1]).is_err());
        let mut extended = blob.clone();
        extended.push(0);
        assert!(parse(&extended).is_err());
    }

    #[test]
    fn rejects_out_of_window_sample_point() {
        let (_, mut params) = synth_params(4);
        params.lbp_layers[0].offsets[0][0].dy = 5; // outside ±1 window
        assert!(parse(&serialize(&params)).is_err());
    }

    #[test]
    fn rejects_out_of_range_weight() {
        let (_, mut params) = synth_params(5);
        params.mlp1.w[0] = 9; // outside signed 4-bit [−8, 8)
        assert!(parse(&serialize(&params)).is_err());
    }

    #[test]
    fn config_derived_shapes() {
        let (_, params) = synth_params(6);
        assert_eq!(params.config.channels_after(), vec![1, 5, 9]);
        assert_eq!(params.config.feature_dim(), 3 * 3 * 9);
        assert_eq!(params.mlp1.d, 81);
        assert_eq!(params.mlp2.o, 10);
        assert_eq!(params.mlp1.weight(0, 0), params.mlp1.w[0]);
    }
}
