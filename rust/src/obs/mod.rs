//! Observability: end-to-end request tracing and live gauges for the
//! serve plane.
//!
//! Near-sensor designs justify themselves on *per-stage* time/energy
//! budgets — sensing, in-SRAM compute, transmission — so the
//! reproduction exposes the same decomposition live instead of only as
//! an end-of-run [`crate::serve::MetricsReport`].  Every stage of a
//! request's life (admission, queue wait, batch formation, shard
//! dispatch, backend phases, completion/drop) emits a [`TraceEvent`]
//! stamped with monotonic timestamps, the request identity
//! (class/sensor/seq), the batch and shard that carried it, and —
//! on dispatch spans — the [`crate::hw::Cost`] energy attribution
//! pulled from [`crate::engine::Telemetry`].
//!
//! The design constraint is the hot path PR 5 made allocation-free:
//! [`Tracer::emit`] never blocks and never allocates.  Events go into a
//! preallocated bounded ring ([`Tracer`] holds it behind a mutex whose
//! critical section is a few stores); when the ring is full the event
//! is counted in `events_dropped` and discarded — the feed degrades,
//! the serve plane does not.  A disabled tracer (the default) reduces
//! every instrumentation site to one branch.
//!
//! A background exporter thread ([`TraceSession`]) drains the ring into
//! (a) a streaming JSONL feed — one flat object per line, parseable by
//! [`json::parse_flat_object`] and `scripts/trace_check.py` — and
//! (b) a Chrome trace-event file loadable in Perfetto, and periodically
//! samples queue-depth / in-flight gauges per class.  See
//! `EXPERIMENTS.md` §Tracing for the field glossary and capture
//! workflow.

pub mod json;

use std::collections::HashSet;
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::engine::{BackendKind, QosClass};
use crate::error::{Error, Result};

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// `[obs]` config section: tracing knobs (see `configs/nslbp_default.toml`).
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Master switch; `serve-bench --trace PATH` flips it on.
    pub enabled: bool,
    /// Ring-buffer capacity in events; overflow increments
    /// `events_dropped` instead of blocking producers.
    pub ring_capacity: usize,
    /// Gauge sample period in microseconds (queue depth / in-flight).
    pub sample_period_us: u64,
    /// JSONL sink path; the Chrome trace lands next to it
    /// (`foo.jsonl` → `foo.trace.json`).
    pub jsonl_path: String,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            ring_capacity: 65_536,
            sample_period_us: 10_000,
            jsonl_path: "trace.jsonl".into(),
        }
    }
}

impl ObsConfig {
    /// Path of the Chrome trace-event file derived from the JSONL sink.
    pub fn chrome_path(&self) -> String {
        let base = self
            .jsonl_path
            .strip_suffix(".jsonl")
            .unwrap_or(&self.jsonl_path);
        format!("{base}.trace.json")
    }
}

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// What a [`TraceEvent`] records.  Span kinds carry a non-zero
/// `dur_ns`; instant kinds have `dur_ns == 0`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Request admitted by `Server::submit` (instant).
    Submit,
    /// Request refused at admission (instant; `label` = cause).
    Reject,
    /// Queue wait: admission → batch seal (span).
    Queue,
    /// Batch formation window in the batcher (span; `label` = flush
    /// reason, `value` = batch size).
    Batch,
    /// One `Engine::infer_batch` dispatch on a shard (span; carries the
    /// telemetry energy decomposition and modeled time).
    Infer,
    /// A backend-internal phase within a dispatch (span; `label` =
    /// `lbp` / `mlp` / `cross_check`).
    Phase,
    /// Request fulfilled; `dur_ns` is the exact end-to-end latency the
    /// metrics reservoir records (span from admission).
    Complete,
    /// Queued request displaced by drop-oldest admission (instant).
    Drop,
    /// Per-request deadline expired before dispatch (instant).
    Expire,
    /// Backend failure fanned out to the request (instant).
    Fail,
    /// Periodic sampler output (`label` = gauge name, `value` = level).
    Gauge,
}

impl EventKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::Submit => "submit",
            EventKind::Reject => "reject",
            EventKind::Queue => "queue",
            EventKind::Batch => "batch",
            EventKind::Infer => "infer",
            EventKind::Phase => "phase",
            EventKind::Complete => "complete",
            EventKind::Drop => "drop",
            EventKind::Expire => "expire",
            EventKind::Fail => "fail",
            EventKind::Gauge => "gauge",
        }
    }

    /// True for kinds scoped to one request (they carry sensor/seq).
    fn per_request(self) -> bool {
        matches!(
            self,
            EventKind::Submit
                | EventKind::Reject
                | EventKind::Queue
                | EventKind::Complete
                | EventKind::Drop
                | EventKind::Expire
                | EventKind::Fail
        )
    }
}

/// One trace record.  Flat and `Copy` so the ring is a preallocated
/// `Vec<TraceEvent>` written in place — no allocation on emit.
/// Timestamps are nanoseconds since the tracer's epoch (monotonic).
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub kind: EventKind,
    pub ts_ns: u64,
    pub dur_ns: u64,
    pub class: Option<QosClass>,
    pub sensor_id: u32,
    pub seq: u64,
    /// Which registered model the request targets (0 = the server's
    /// default; emitted only when non-zero, so single-model feeds are
    /// unchanged).
    pub model_id: u32,
    /// Batch correlation id (ids start at 1; 0 = not batched).
    pub batch_id: u64,
    /// Shard index (−1 = not on a shard).
    pub shard: i32,
    pub backend: Option<BackendKind>,
    /// Flush reason / drop cause / phase name / gauge name.
    pub label: &'static str,
    /// Gauge level or batch size.
    pub value: f64,
    /// Energy attribution (dispatch spans): sensing stage.
    pub sensor_pj: f64,
    /// In-SRAM compute stage (compute + row read/write + control).
    pub compute_pj: f64,
    /// Near-memory DPU stage.
    pub dpu_pj: f64,
    /// Off-chip transmission stage.
    pub tx_pj: f64,
    /// Modeled (cost-model) time for the dispatch, ns.
    pub modeled_ns: u64,
}

impl Default for TraceEvent {
    fn default() -> Self {
        Self {
            kind: EventKind::Gauge,
            ts_ns: 0,
            dur_ns: 0,
            class: None,
            sensor_id: 0,
            seq: 0,
            model_id: 0,
            batch_id: 0,
            shard: -1,
            backend: None,
            label: "",
            value: 0.0,
            sensor_pj: 0.0,
            compute_pj: 0.0,
            dpu_pj: 0.0,
            tx_pj: 0.0,
            modeled_ns: 0,
        }
    }
}

impl TraceEvent {
    /// The event as one flat JSON object (no trailing newline).
    /// Fields that are "not applicable" for the kind are omitted so
    /// the feed stays compact; `scripts/trace_check.py` and
    /// [`summarize`] treat missing keys as absent.
    pub fn to_jsonl(&self) -> String {
        let mut s = String::with_capacity(128);
        s.push('{');
        json::push_str_field(&mut s, "kind", self.kind.as_str());
        json::push_u64_field(&mut s, "ts_ns", self.ts_ns);
        if self.dur_ns > 0 {
            json::push_u64_field(&mut s, "dur_ns", self.dur_ns);
        }
        if let Some(c) = self.class {
            json::push_str_field(&mut s, "class", c.as_str());
        }
        if self.kind.per_request() {
            json::push_u64_field(&mut s, "sensor_id", self.sensor_id as u64);
            json::push_u64_field(&mut s, "seq", self.seq);
        }
        if self.model_id > 0 {
            json::push_u64_field(&mut s, "model_id", self.model_id as u64);
        }
        if self.batch_id > 0 {
            json::push_u64_field(&mut s, "batch_id", self.batch_id);
        }
        if self.shard >= 0 {
            json::push_u64_field(&mut s, "shard", self.shard as u64);
        }
        if let Some(b) = self.backend {
            json::push_str_field(&mut s, "backend", b.as_str());
        }
        if !self.label.is_empty() {
            json::push_str_field(&mut s, "label", self.label);
        }
        if matches!(self.kind, EventKind::Gauge | EventKind::Batch) {
            json::push_f64_field(&mut s, "value", self.value);
        }
        if self.kind == EventKind::Infer {
            json::push_f64_field(&mut s, "sensor_pj", self.sensor_pj);
            json::push_f64_field(&mut s, "compute_pj", self.compute_pj);
            json::push_f64_field(&mut s, "dpu_pj", self.dpu_pj);
            json::push_f64_field(&mut s, "tx_pj", self.tx_pj);
            json::push_u64_field(&mut s, "modeled_ns", self.modeled_ns);
        }
        s.pop(); // trailing comma
        s.push('}');
        s
    }
}

// ---------------------------------------------------------------------------
// Tracer: the lock-cheap bounded ring
// ---------------------------------------------------------------------------

struct Ring {
    buf: Vec<TraceEvent>,
    head: usize,
    len: usize,
}

struct TracerCore {
    epoch: Instant,
    ring: Mutex<Ring>,
    dropped: AtomicU64,
    next_batch: AtomicU64,
}

/// Shared handle to the trace ring.  `Clone` is an `Arc` bump;
/// `Default` is the *disabled* tracer, whose [`Tracer::emit`] is a
/// single branch — the hot path pays nothing when tracing is off.
#[derive(Clone, Default)]
pub struct Tracer(Option<Arc<TracerCore>>);

impl Tracer {
    /// An enabled tracer with a preallocated `capacity`-event ring.
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(16);
        Tracer(Some(Arc::new(TracerCore {
            epoch: Instant::now(),
            ring: Mutex::new(Ring {
                buf: vec![TraceEvent::default(); capacity],
                head: 0,
                len: 0,
            }),
            dropped: AtomicU64::new(0),
            next_batch: AtomicU64::new(1),
        })))
    }

    /// The disabled tracer (same as `Default`).
    pub fn disabled() -> Self {
        Tracer(None)
    }

    /// False for the disabled tracer — instrumentation sites guard
    /// their timestamp reads and event construction behind this.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Nanoseconds from the tracer epoch to `at` (saturating: an
    /// `Instant` captured before the epoch maps to 0).  Disabled → 0.
    #[inline]
    pub fn ts(&self, at: Instant) -> u64 {
        match &self.0 {
            Some(core) => {
                at.saturating_duration_since(core.epoch).as_nanos() as u64
            }
            None => 0,
        }
    }

    /// Nanoseconds from the tracer epoch to now.
    #[inline]
    pub fn now(&self) -> u64 {
        self.ts(Instant::now())
    }

    /// Record `ev` into the ring.  Never blocks, never allocates: a
    /// full ring drops the event and bumps `events_dropped`.
    #[inline]
    pub fn emit(&self, ev: TraceEvent) {
        let Some(core) = &self.0 else { return };
        let mut g = core.ring.lock().unwrap();
        if g.len == g.buf.len() {
            drop(g);
            core.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let cap = g.buf.len();
        let idx = (g.head + g.len) % cap;
        g.buf[idx] = ev;
        g.len += 1;
    }

    /// Allocate a batch correlation id (monotonic, starting at 1).
    /// Disabled → 0 ("not batched" sentinel).
    pub fn next_batch_id(&self) -> u64 {
        match &self.0 {
            Some(core) => core.next_batch.fetch_add(1, Ordering::Relaxed),
            None => 0,
        }
    }

    /// Events discarded because the ring was full.
    pub fn events_dropped(&self) -> u64 {
        match &self.0 {
            Some(core) => core.dropped.load(Ordering::Relaxed),
            None => 0,
        }
    }

    /// Move every buffered event into `out` (exporter side).
    pub fn drain_into(&self, out: &mut Vec<TraceEvent>) {
        let Some(core) = &self.0 else { return };
        let mut g = core.ring.lock().unwrap();
        let cap = g.buf.len();
        for i in 0..g.len {
            out.push(g.buf[(g.head + i) % cap]);
        }
        g.head = 0;
        g.len = 0;
    }
}

// ---------------------------------------------------------------------------
// Exporter session
// ---------------------------------------------------------------------------

/// Background exporter: owns the tracer, drains the ring into the
/// JSONL feed and the Chrome trace file, and runs the periodic gauge
/// sampler.  Created by `Server::start` when `[obs] enabled`;
/// [`TraceSession::finish`] (after the worker pool drains) flushes the
/// tail, emits the final `events_dropped` gauge, and closes both files.
pub struct TraceSession {
    tracer: Tracer,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<Result<()>>>,
}

impl TraceSession {
    /// Start the exporter.  `gauges` is invoked every
    /// `sample_period_us` on the exporter thread and should emit
    /// [`EventKind::Gauge`] events for whatever levels it can observe
    /// (queue depths, in-flight counts).
    pub fn start<G>(cfg: &ObsConfig, gauges: G) -> Result<TraceSession>
    where
        G: Fn(&Tracer) + Send + 'static,
    {
        if !cfg.enabled {
            return Ok(TraceSession {
                tracer: Tracer::disabled(),
                stop: Arc::new(AtomicBool::new(false)),
                handle: None,
            });
        }
        let tracer = Tracer::new(cfg.ring_capacity);
        let stop = Arc::new(AtomicBool::new(false));
        let mut jsonl = std::io::BufWriter::new(
            std::fs::File::create(&cfg.jsonl_path).map_err(Error::Io)?,
        );
        let mut chrome = ChromeWriter::create(&cfg.chrome_path())?;
        let sample_period = Duration::from_micros(cfg.sample_period_us.max(1));
        let exporter = {
            let tracer = tracer.clone();
            let stop = Arc::clone(&stop);
            move || -> Result<()> {
                let mut buf: Vec<TraceEvent> = Vec::with_capacity(1024);
                let mut last_sample = Instant::now();
                gauges(&tracer); // one sample at t=0
                loop {
                    let stopping = stop.load(Ordering::Acquire);
                    if stopping || last_sample.elapsed() >= sample_period {
                        gauges(&tracer);
                        last_sample = Instant::now();
                    }
                    if stopping {
                        // producers are done (the pool joined before
                        // finish()): account the overflow, then drain
                        let ev = TraceEvent {
                            kind: EventKind::Gauge,
                            ts_ns: tracer.now(),
                            label: "events_dropped",
                            value: tracer.events_dropped() as f64,
                            ..TraceEvent::default()
                        };
                        tracer.emit(ev);
                    }
                    buf.clear();
                    tracer.drain_into(&mut buf);
                    for ev in &buf {
                        jsonl
                            .write_all(ev.to_jsonl().as_bytes())
                            .and_then(|()| jsonl.write_all(b"\n"))
                            .map_err(Error::Io)?;
                        chrome.record(ev)?;
                    }
                    if stopping {
                        jsonl.flush().map_err(Error::Io)?;
                        chrome.finish()?;
                        return Ok(());
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        };
        let handle = std::thread::Builder::new()
            .name("nslbp-trace-export".into())
            .spawn(exporter)
            .map_err(Error::Io)?;
        Ok(TraceSession { tracer, stop, handle: Some(handle) })
    }

    /// Handle for instrumentation sites (cheap clone; disabled when
    /// the session is disabled).
    pub fn tracer(&self) -> Tracer {
        self.tracer.clone()
    }

    /// Stop the exporter after a final drain and close the sinks.
    /// Call once every producer thread has finished.
    pub fn finish(mut self) -> Result<()> {
        self.stop.store(true, Ordering::Release);
        match self.handle.take() {
            Some(h) => h
                .join()
                .map_err(|_| Error::Serve("trace exporter panicked".into()))?,
            None => Ok(()),
        }
    }
}

impl Drop for TraceSession {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event output
// ---------------------------------------------------------------------------

/// Streaming Chrome trace-event (JSON array) writer.  Perfetto and
/// `chrome://tracing` both load the result.  Track layout:
///
/// * `sensor-<id>`  — per-request spans/instants (submit, queue,
///   request/<class>, drops); requests from one sensor are sequential,
///   so the track nests cleanly,
/// * `batcher-<class>` — batch-formation spans,
/// * `shard-<n>`    — dispatch spans with backend phases nested inside,
/// * counters       — queue depth / in-flight / events_dropped gauges.
struct ChromeWriter {
    out: std::io::BufWriter<std::fs::File>,
    first: bool,
    named_tids: HashSet<u64>,
    line: String,
}

impl ChromeWriter {
    fn create(path: &str) -> Result<Self> {
        let mut out = std::io::BufWriter::new(
            std::fs::File::create(path).map_err(Error::Io)?,
        );
        out.write_all(b"[\n").map_err(Error::Io)?;
        Ok(Self { out, first: true, named_tids: HashSet::new(), line:
            String::with_capacity(256) })
    }

    fn tid(ev: &TraceEvent) -> u64 {
        match ev.kind {
            EventKind::Batch => {
                2000 + ev.class.map_or(0, |c| c.index() as u64)
            }
            EventKind::Infer | EventKind::Phase => {
                3000 + ev.shard.max(0) as u64
            }
            EventKind::Gauge => 0,
            _ => 1000 + ev.sensor_id as u64,
        }
    }

    fn track_name(ev: &TraceEvent) -> String {
        match ev.kind {
            EventKind::Batch => format!(
                "batcher-{}",
                ev.class.map_or("?", |c| c.as_str())
            ),
            EventKind::Infer | EventKind::Phase => {
                format!("shard-{}", ev.shard.max(0))
            }
            _ => format!("sensor-{}", ev.sensor_id),
        }
    }

    fn record(&mut self, ev: &TraceEvent) -> Result<()> {
        let tid = Self::tid(ev);
        if ev.kind != EventKind::Gauge && self.named_tids.insert(tid) {
            let name = Self::track_name(ev);
            self.emit_raw(&format!(
                "{{\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
                 \"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"{}\"}}}}",
                json::escape(&name)
            ))?;
        }
        let ts_us = ev.ts_ns as f64 / 1e3;
        let dur_us = ev.dur_ns as f64 / 1e3;
        let mut line = std::mem::take(&mut self.line);
        line.clear();
        line.push('{');
        match ev.kind {
            EventKind::Gauge => {
                let name = match ev.class {
                    Some(c) => format!("{}/{}", ev.label, c.as_str()),
                    None => ev.label.to_string(),
                };
                json::push_str_field(&mut line, "ph", "C");
                json::push_u64_field(&mut line, "pid", 1);
                json::push_str_field(&mut line, "name", &name);
                json::push_f64_field(&mut line, "ts", ts_us);
                line.push_str("\"args\":{\"value\":");
                json::push_f64(&mut line, ev.value);
                line.push_str("},");
            }
            EventKind::Queue | EventKind::Batch | EventKind::Infer
            | EventKind::Phase | EventKind::Complete => {
                let name = match ev.kind {
                    EventKind::Queue => "queue".to_string(),
                    EventKind::Batch => format!(
                        "batch/{}",
                        ev.label
                    ),
                    EventKind::Infer => format!(
                        "infer/{}",
                        ev.backend.map_or("?", |b| b.as_str())
                    ),
                    EventKind::Phase => ev.label.to_string(),
                    _ => format!(
                        "request/{}",
                        ev.class.map_or("?", |c| c.as_str())
                    ),
                };
                json::push_str_field(&mut line, "ph", "X");
                json::push_u64_field(&mut line, "pid", 1);
                json::push_u64_field(&mut line, "tid", tid);
                json::push_str_field(&mut line, "name", &name);
                json::push_f64_field(&mut line, "ts", ts_us);
                json::push_f64_field(&mut line, "dur", dur_us);
                line.push_str("\"args\":{");
                if ev.batch_id > 0 {
                    json::push_u64_field(&mut line, "batch_id", ev.batch_id);
                }
                if ev.kind == EventKind::Batch {
                    json::push_f64_field(&mut line, "size", ev.value);
                }
                if ev.kind == EventKind::Infer {
                    json::push_f64_field(&mut line, "sensor_pj",
                                         ev.sensor_pj);
                    json::push_f64_field(&mut line, "compute_pj",
                                         ev.compute_pj);
                    json::push_f64_field(&mut line, "dpu_pj", ev.dpu_pj);
                    json::push_f64_field(&mut line, "tx_pj", ev.tx_pj);
                    json::push_u64_field(&mut line, "modeled_ns",
                                         ev.modeled_ns);
                }
                if line.ends_with(',') {
                    line.pop();
                }
                line.push_str("},");
            }
            _ => {
                // instants: submit / reject / drop / expire / fail
                let name = if ev.label.is_empty() {
                    ev.kind.as_str().to_string()
                } else {
                    format!("{}:{}", ev.kind.as_str(), ev.label)
                };
                json::push_str_field(&mut line, "ph", "i");
                json::push_u64_field(&mut line, "pid", 1);
                json::push_u64_field(&mut line, "tid", tid);
                json::push_str_field(&mut line, "name", &name);
                json::push_f64_field(&mut line, "ts", ts_us);
                json::push_str_field(&mut line, "s", "t");
            }
        }
        line.pop(); // trailing comma
        line.push('}');
        let res = self.emit_raw(&line);
        self.line = line;
        res
    }

    fn emit_raw(&mut self, record: &str) -> Result<()> {
        if !self.first {
            self.out.write_all(b",\n").map_err(Error::Io)?;
        }
        self.first = false;
        self.out.write_all(record.as_bytes()).map_err(Error::Io)
    }

    fn finish(&mut self) -> Result<()> {
        self.out.write_all(b"\n]\n").map_err(Error::Io)?;
        self.out.flush().map_err(Error::Io)
    }
}

// ---------------------------------------------------------------------------
// Feed summary (`ns-lbp trace`)
// ---------------------------------------------------------------------------

/// Per-stage latency and energy summary of one JSONL trace feed.
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    /// Completed / rejected / dropped / expired / failed per class
    /// (indexed by `QosClass::index()`).
    pub completed: [u64; QosClass::COUNT],
    pub rejected: [u64; QosClass::COUNT],
    pub dropped: [u64; QosClass::COUNT],
    pub expired: [u64; QosClass::COUNT],
    pub failed: [u64; QosClass::COUNT],
    /// Queue-wait percentiles over all Queue spans, ns: (p50, p95, p99).
    pub queue_ns: (u64, u64, u64),
    /// Dispatch percentiles over all Infer spans, ns.
    pub infer_ns: (u64, u64, u64),
    /// End-to-end percentiles over all Complete spans, ns.
    pub e2e_ns: (u64, u64, u64),
    /// Per-class end-to-end percentiles, ns.
    pub e2e_ns_by_class: [(u64, u64, u64); QosClass::COUNT],
    /// Energy by pipeline stage summed over Infer spans, pJ:
    /// (sensing, in-SRAM compute, DPU, transmission).
    pub energy_pj: (f64, f64, f64, f64),
    /// Modeled (cost-model) time summed over Infer spans, ns.
    pub modeled_ns: u64,
    /// Drop/reject causes: (label, count), sorted by count desc.
    pub causes: Vec<(String, u64)>,
    /// Events the ring discarded (final `events_dropped` gauge).
    pub events_dropped: u64,
    /// Total feed lines parsed.
    pub lines: u64,
}

impl TraceSummary {
    fn tri_json(t: (u64, u64, u64)) -> String {
        format!("{{\"p50_ns\":{},\"p95_ns\":{},\"p99_ns\":{}}}",
                t.0, t.1, t.2)
    }

    /// Machine-readable form (used by CI's p99 cross-check).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{");
        json::push_u64_field(&mut s, "lines", self.lines);
        json::push_u64_field(&mut s, "events_dropped", self.events_dropped);
        s.push_str(&format!("\"queue\":{},", Self::tri_json(self.queue_ns)));
        s.push_str(&format!("\"infer\":{},", Self::tri_json(self.infer_ns)));
        s.push_str(&format!("\"e2e\":{},", Self::tri_json(self.e2e_ns)));
        s.push_str("\"classes\":{");
        for class in QosClass::ALL {
            let i = class.index();
            s.push('"');
            s.push_str(class.as_str());
            s.push_str("\":{");
            json::push_u64_field(&mut s, "completed", self.completed[i]);
            json::push_u64_field(&mut s, "rejected", self.rejected[i]);
            json::push_u64_field(&mut s, "dropped", self.dropped[i]);
            json::push_u64_field(&mut s, "expired", self.expired[i]);
            json::push_u64_field(&mut s, "failed", self.failed[i]);
            s.push_str(&format!("\"e2e\":{}",
                                Self::tri_json(self.e2e_ns_by_class[i])));
            s.push_str("},");
        }
        s.pop();
        s.push_str("},");
        s.push_str("\"energy_pj\":{");
        json::push_f64_field(&mut s, "sensor", self.energy_pj.0);
        json::push_f64_field(&mut s, "compute", self.energy_pj.1);
        json::push_f64_field(&mut s, "dpu", self.energy_pj.2);
        json::push_f64_field(&mut s, "transmission", self.energy_pj.3);
        s.pop();
        s.push_str("},");
        json::push_u64_field(&mut s, "modeled_ns", self.modeled_ns);
        s.push_str("\"causes\":{");
        for (label, n) in &self.causes {
            json::push_u64_field(&mut s, label, *n);
        }
        if s.ends_with(',') {
            s.pop();
        }
        s.push_str("}}");
        s
    }

    /// Human-readable rendering (the `ns-lbp trace` default output).
    pub fn render(&self) -> String {
        fn ms(t: (u64, u64, u64)) -> String {
            format!("p50 {:8.3} ms   p95 {:8.3} ms   p99 {:8.3} ms",
                    t.0 as f64 / 1e6, t.1 as f64 / 1e6, t.2 as f64 / 1e6)
        }
        let mut s = String::new();
        s.push_str(&format!("trace: {} events parsed, {} dropped by the \
                             ring\n\n", self.lines, self.events_dropped));
        s.push_str("per-stage latency\n");
        s.push_str(&format!("  queue    {}\n", ms(self.queue_ns)));
        s.push_str(&format!("  infer    {}\n", ms(self.infer_ns)));
        s.push_str(&format!("  e2e      {}\n\n", ms(self.e2e_ns)));
        s.push_str("per-class\n");
        for class in QosClass::ALL {
            let i = class.index();
            let total = self.completed[i] + self.rejected[i]
                + self.dropped[i] + self.expired[i] + self.failed[i];
            if total == 0 {
                continue;
            }
            s.push_str(&format!(
                "  {:<11} {:>6} ok  {:>4} rej  {:>4} drop  {:>4} exp  \
                 {:>4} fail   e2e {}\n",
                class.as_str(), self.completed[i], self.rejected[i],
                self.dropped[i], self.expired[i], self.failed[i],
                ms(self.e2e_ns_by_class[i])
            ));
        }
        let (sn, cp, dp, tx) = self.energy_pj;
        let total = sn + cp + dp + tx;
        s.push_str("\nenergy by stage (modeled)\n");
        if total > 0.0 {
            s.push_str(&format!(
                "  sensing      {:>14.1} pJ  ({:4.1}%)\n  in-SRAM      \
                 {:>14.1} pJ  ({:4.1}%)\n  DPU          {:>14.1} pJ  \
                 ({:4.1}%)\n  transmission {:>14.1} pJ  ({:4.1}%)\n",
                sn, 100.0 * sn / total, cp, 100.0 * cp / total,
                dp, 100.0 * dp / total, tx, 100.0 * tx / total
            ));
            s.push_str(&format!("  modeled dispatch time {:>11.3} ms\n",
                                self.modeled_ns as f64 / 1e6));
        } else {
            s.push_str("  (no dispatch spans in feed)\n");
        }
        if !self.causes.is_empty() {
            s.push_str("\ndrop/reject causes\n");
            for (label, n) in &self.causes {
                s.push_str(&format!("  {label:<28} {n:>6}\n"));
            }
        }
        s
    }
}

/// Parse a JSONL trace feed and summarize it (per-stage percentiles,
/// energy by stage, drop causes).  Unparseable lines are an error —
/// the feed is machine-written, so corruption should be loud.
pub fn summarize(feed: &str) -> Result<TraceSummary> {
    summarize_feeds(&[("feed", feed)])
}

/// Merge several JSONL trace feeds (e.g. one per fleet node) into a
/// single summary.  Percentiles and per-class counters pool every
/// feed's events; `events_dropped` is *summed* across feeds — each
/// feed's final gauge describes its own ring, so the merged figure is
/// the total the fleet discarded, not whichever feed was parsed last.
pub fn summarize_feeds(feeds: &[(&str, &str)]) -> Result<TraceSummary> {
    use crate::serve::percentile_ns;

    let mut sm = TraceSummary::default();
    let mut queue: Vec<u64> = Vec::new();
    let mut infer: Vec<u64> = Vec::new();
    let mut e2e: Vec<u64> = Vec::new();
    let mut e2e_class: [Vec<u64>; QosClass::COUNT] = Default::default();
    let mut causes: std::collections::HashMap<String, u64> =
        std::collections::HashMap::new();
    for &(feed_name, feed) in feeds {
        // Per-feed: the ring's `events_dropped` gauge is cumulative
        // within one feed, so only its final value counts.
        let mut feed_dropped = 0u64;
        for (lineno, line) in feed.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let at = |what: &str| {
                if feeds.len() == 1 {
                    Error::Config(format!(
                        "trace feed line {}: {what}", lineno + 1))
                } else {
                    Error::Config(format!(
                        "trace feed {feed_name} line {}: {what}", lineno + 1))
                }
            };
            let fields = json::parse_flat_object(line)
                .map_err(|e| at(&e.to_string()))?;
            let get = |k: &str| {
                fields.iter().find(|(key, _)| key == k).map(|(_, v)| v)
            };
            let kind = get("kind")
                .and_then(|v| v.as_str())
                .ok_or_else(|| at("no kind"))?;
            let class = get("class")
                .and_then(|v| v.as_str())
                .and_then(|s| s.parse::<QosClass>().ok());
            let dur = get("dur_ns").and_then(|v| v.as_u64()).unwrap_or(0);
            let label = get("label").and_then(|v| v.as_str()).unwrap_or("");
            sm.lines += 1;
            match kind {
                "queue" => queue.push(dur),
                "infer" => {
                    infer.push(dur);
                    let f = |k: &str| {
                        get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
                    };
                    sm.energy_pj.0 += f("sensor_pj");
                    sm.energy_pj.1 += f("compute_pj");
                    sm.energy_pj.2 += f("dpu_pj");
                    sm.energy_pj.3 += f("tx_pj");
                    sm.modeled_ns += get("modeled_ns")
                        .and_then(|v| v.as_u64())
                        .unwrap_or(0);
                }
                "complete" => {
                    e2e.push(dur);
                    if let Some(c) = class {
                        sm.completed[c.index()] += 1;
                        e2e_class[c.index()].push(dur);
                    }
                }
                "reject" => {
                    if let Some(c) = class {
                        sm.rejected[c.index()] += 1;
                    }
                    *causes.entry(format!("reject:{label}")).or_insert(0) += 1;
                }
                "drop" => {
                    if let Some(c) = class {
                        sm.dropped[c.index()] += 1;
                    }
                    *causes.entry(format!("drop:{label}")).or_insert(0) += 1;
                }
                "expire" => {
                    if let Some(c) = class {
                        sm.expired[c.index()] += 1;
                    }
                    *causes.entry(format!("expire:{label}")).or_insert(0) += 1;
                }
                "fail" => {
                    if let Some(c) = class {
                        sm.failed[c.index()] += 1;
                    }
                    *causes.entry(format!("fail:{label}")).or_insert(0) += 1;
                }
                "gauge" if label == "events_dropped" => {
                    feed_dropped =
                        get("value").and_then(|v| v.as_u64()).unwrap_or(0);
                }
                _ => {}
            }
        }
        sm.events_dropped += feed_dropped;
    }
    let tri = |v: &mut Vec<u64>| {
        v.sort_unstable();
        (percentile_ns(v, 0.50), percentile_ns(v, 0.95),
         percentile_ns(v, 0.99))
    };
    sm.queue_ns = tri(&mut queue);
    sm.infer_ns = tri(&mut infer);
    sm.e2e_ns = tri(&mut e2e);
    for (i, v) in e2e_class.iter_mut().enumerate() {
        sm.e2e_ns_by_class[i] = tri(v);
    }
    sm.causes = {
        let mut v: Vec<(String, u64)> = causes.into_iter().collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        v
    };
    Ok(sm)
}

// ---------------------------------------------------------------------------
// Multi-feed Chrome merge (`ns-lbp trace F1 F2 … --chrome OUT`)
// ---------------------------------------------------------------------------

/// Merge several JSONL feeds into one Chrome-trace JSON file, one
/// *process* per feed (pid = position + 1, named after the feed) so a
/// fleet's nodes land side by side on the same timeline.  Unlike the
/// live [`ChromeWriter`] this re-derives every record from the parsed
/// feed, so it works on any feeds `ns-lbp trace` can summarize.
/// Returns the number of event records written (metadata excluded).
pub fn merge_chrome_trace(feeds: &[(&str, &str)], path: &str) -> Result<u64> {
    fn emit(out: &mut std::io::BufWriter<std::fs::File>, first: &mut bool,
            record: &str) -> Result<()> {
        if !*first {
            out.write_all(b",\n").map_err(Error::Io)?;
        }
        *first = false;
        out.write_all(record.as_bytes()).map_err(Error::Io)
    }

    let mut out = std::io::BufWriter::new(
        std::fs::File::create(path).map_err(Error::Io)?,
    );
    out.write_all(b"[\n").map_err(Error::Io)?;
    let mut first = true;
    let mut events = 0u64;
    for (fi, &(feed_name, feed)) in feeds.iter().enumerate() {
        let pid = fi as u64 + 1;
        emit(&mut out, &mut first, &format!(
            "{{\"ph\":\"M\",\"pid\":{pid},\"name\":\"process_name\",\
             \"args\":{{\"name\":\"{}\"}}}}",
            json::escape(feed_name)
        ))?;
        let mut named_tids: HashSet<u64> = HashSet::new();
        for (lineno, line) in feed.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let fields = json::parse_flat_object(line).map_err(|e| {
                Error::Config(format!(
                    "trace feed {feed_name} line {}: {e}", lineno + 1))
            })?;
            let get = |k: &str| {
                fields.iter().find(|(key, _)| key == k).map(|(_, v)| v)
            };
            let kind = get("kind").and_then(|v| v.as_str()).ok_or_else(|| {
                Error::Config(format!(
                    "trace feed {feed_name} line {}: no kind", lineno + 1))
            })?;
            let class = get("class")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string();
            let label = get("label")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string();
            let u = |k: &str| get(k).and_then(|v| v.as_u64()).unwrap_or(0);
            let f = |k: &str| get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
            let ts_us = u("ts_ns") as f64 / 1e3;
            let dur_us = u("dur_ns") as f64 / 1e3;
            let (tid, track) = match kind {
                "batch" => (
                    2000 + class.parse::<QosClass>()
                        .map_or(0, |c| c.index() as u64),
                    format!("batcher-{}",
                            if class.is_empty() { "?" } else { &class }),
                ),
                "infer" | "phase" => {
                    let shard = u("shard");
                    (3000 + shard, format!("shard-{shard}"))
                }
                "gauge" => (0, String::new()),
                _ => {
                    let sensor = u("sensor_id");
                    (1000 + sensor, format!("sensor-{sensor}"))
                }
            };
            if kind != "gauge" && named_tids.insert(tid) {
                emit(&mut out, &mut first, &format!(
                    "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
                     \"name\":\"thread_name\",\
                     \"args\":{{\"name\":\"{}\"}}}}",
                    json::escape(&track)
                ))?;
            }
            let mut rec = String::with_capacity(192);
            rec.push('{');
            match kind {
                "gauge" => {
                    let name = if class.is_empty() {
                        label.clone()
                    } else {
                        format!("{label}/{class}")
                    };
                    json::push_str_field(&mut rec, "ph", "C");
                    json::push_u64_field(&mut rec, "pid", pid);
                    json::push_str_field(&mut rec, "name", &name);
                    json::push_f64_field(&mut rec, "ts", ts_us);
                    rec.push_str("\"args\":{\"value\":");
                    json::push_f64(&mut rec, f("value"));
                    rec.push_str("},");
                }
                "queue" | "batch" | "infer" | "phase" | "complete" => {
                    let name = match kind {
                        "queue" => "queue".to_string(),
                        "batch" => format!("batch/{label}"),
                        "infer" => format!(
                            "infer/{}",
                            get("backend")
                                .and_then(|v| v.as_str())
                                .unwrap_or("?")
                        ),
                        "phase" => label.clone(),
                        _ => format!(
                            "request/{}",
                            if class.is_empty() { "?" } else { &class }
                        ),
                    };
                    json::push_str_field(&mut rec, "ph", "X");
                    json::push_u64_field(&mut rec, "pid", pid);
                    json::push_u64_field(&mut rec, "tid", tid);
                    json::push_str_field(&mut rec, "name", &name);
                    json::push_f64_field(&mut rec, "ts", ts_us);
                    json::push_f64_field(&mut rec, "dur", dur_us);
                    rec.push_str("\"args\":{");
                    if u("batch_id") > 0 {
                        json::push_u64_field(&mut rec, "batch_id",
                                             u("batch_id"));
                    }
                    if kind == "batch" {
                        json::push_f64_field(&mut rec, "size", f("value"));
                    }
                    if kind == "infer" {
                        json::push_f64_field(&mut rec, "sensor_pj",
                                             f("sensor_pj"));
                        json::push_f64_field(&mut rec, "compute_pj",
                                             f("compute_pj"));
                        json::push_f64_field(&mut rec, "dpu_pj", f("dpu_pj"));
                        json::push_f64_field(&mut rec, "tx_pj", f("tx_pj"));
                        json::push_u64_field(&mut rec, "modeled_ns",
                                             u("modeled_ns"));
                    }
                    if rec.ends_with(',') {
                        rec.pop();
                    }
                    rec.push_str("},");
                }
                _ => {
                    let name = if label.is_empty() {
                        kind.to_string()
                    } else {
                        format!("{kind}:{label}")
                    };
                    json::push_str_field(&mut rec, "ph", "i");
                    json::push_u64_field(&mut rec, "pid", pid);
                    json::push_u64_field(&mut rec, "tid", tid);
                    json::push_str_field(&mut rec, "name", &name);
                    json::push_f64_field(&mut rec, "ts", ts_us);
                    json::push_str_field(&mut rec, "s", "t");
                }
            }
            rec.pop(); // trailing comma
            rec.push('}');
            emit(&mut out, &mut first, &rec)?;
            events += 1;
        }
    }
    out.write_all(b"\n]\n").map_err(Error::Io)?;
    out.flush().map_err(Error::Io)?;
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_is_inert() {
        let t = Tracer::disabled();
        assert!(!t.enabled());
        assert_eq!(t.now(), 0);
        assert_eq!(t.next_batch_id(), 0);
        t.emit(TraceEvent::default());
        assert_eq!(t.events_dropped(), 0);
        let mut out = Vec::new();
        t.drain_into(&mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn ring_overflow_drops_and_counts_without_corruption() {
        let t = Tracer::new(16);
        for i in 0..40u64 {
            t.emit(TraceEvent {
                kind: EventKind::Submit,
                ts_ns: i,
                seq: i,
                ..TraceEvent::default()
            });
        }
        assert_eq!(t.events_dropped(), 40 - 16);
        let mut out = Vec::new();
        t.drain_into(&mut out);
        // the *oldest* 16 survive (drop-newest overflow): the feed stays
        // a clean prefix, and every surviving line still parses
        assert_eq!(out.len(), 16);
        for (i, ev) in out.iter().enumerate() {
            assert_eq!(ev.seq, i as u64);
            assert!(json::parse_flat_object(&ev.to_jsonl()).is_ok());
        }
        // the ring is reusable after a drain
        t.emit(TraceEvent::default());
        out.clear();
        t.drain_into(&mut out);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn batch_ids_are_unique_and_start_at_one() {
        let t = Tracer::new(16);
        assert_eq!(t.next_batch_id(), 1);
        assert_eq!(t.next_batch_id(), 2);
        let t2 = t.clone();
        assert_eq!(t2.next_batch_id(), 3);
    }

    #[test]
    fn timestamps_are_monotonic_and_saturating() {
        let before = Instant::now();
        let t = Tracer::new(16);
        assert_eq!(t.ts(before), 0); // pre-epoch saturates
        let a = t.now();
        let b = t.now();
        assert!(b >= a);
    }

    #[test]
    fn jsonl_roundtrips_through_flat_parser() {
        let ev = TraceEvent {
            kind: EventKind::Infer,
            ts_ns: 1_000,
            dur_ns: 500,
            class: Some(QosClass::Billed),
            batch_id: 7,
            shard: 2,
            backend: Some(BackendKind::Architectural),
            sensor_pj: 12.5,
            compute_pj: 100.0,
            dpu_pj: 3.25,
            tx_pj: 8.0,
            modeled_ns: 42,
            ..TraceEvent::default()
        };
        let fields = json::parse_flat_object(&ev.to_jsonl()).unwrap();
        let get = |k: &str| {
            fields.iter().find(|(key, _)| key == k).map(|(_, v)| v.clone())
        };
        assert_eq!(get("kind").unwrap().as_str(), Some("infer"));
        assert_eq!(get("class").unwrap().as_str(), Some("billed"));
        assert_eq!(get("batch_id").unwrap().as_u64(), Some(7));
        assert_eq!(get("shard").unwrap().as_u64(), Some(2));
        assert_eq!(get("compute_pj").unwrap().as_f64(), Some(100.0));
        assert_eq!(get("modeled_ns").unwrap().as_u64(), Some(42));
        // per-request identity is omitted for non-request kinds
        assert!(get("sensor_id").is_none());
        // model 0 (the default) is omitted so single-model feeds are
        // byte-for-byte what they were before multi-model serving
        assert!(get("model_id").is_none());
        let tagged =
            TraceEvent { model_id: 3, ..ev }.to_jsonl();
        let fields = json::parse_flat_object(&tagged).unwrap();
        let model = fields
            .iter()
            .find(|(key, _)| key == "model_id")
            .map(|(_, v)| v.clone());
        assert_eq!(model.unwrap().as_u64(), Some(3));
    }

    #[test]
    fn summarize_computes_counts_and_percentiles() {
        let mut feed = String::new();
        for i in 1..=100u64 {
            let ev = TraceEvent {
                kind: EventKind::Complete,
                ts_ns: i,
                dur_ns: i * 1_000,
                class: Some(QosClass::Standard),
                sensor_id: 1,
                seq: i,
                ..TraceEvent::default()
            };
            feed.push_str(&ev.to_jsonl());
            feed.push('\n');
        }
        let ev = TraceEvent {
            kind: EventKind::Reject,
            ts_ns: 1,
            class: Some(QosClass::BestEffort),
            label: "full",
            ..TraceEvent::default()
        };
        feed.push_str(&ev.to_jsonl());
        feed.push('\n');
        let sm = summarize(&feed).unwrap();
        assert_eq!(sm.completed[QosClass::Standard.index()], 100);
        assert_eq!(sm.rejected[QosClass::BestEffort.index()], 1);
        assert_eq!(sm.e2e_ns.0, 50_000); // nearest-rank p50 of 1..=100 k
        assert_eq!(sm.e2e_ns.2, 99_000);
        assert_eq!(sm.causes, vec![("reject:full".to_string(), 1)]);
        assert!(sm.to_json().contains("\"completed\":100"));
        let rendered = sm.render();
        assert!(rendered.contains("standard"));
        assert!(rendered.contains("per-stage latency"));
    }

    #[test]
    fn summarize_rejects_corrupt_lines() {
        assert!(summarize("not json\n").is_err());
        assert!(summarize("{\"ts_ns\":1}\n").is_err()); // no kind
    }

    #[test]
    fn multi_feed_merge_pools_events_and_sums_ring_drops() {
        let mut feeds: Vec<String> = Vec::new();
        for node in 0..2u64 {
            let mut feed = String::new();
            for i in 1..=10u64 {
                let ev = TraceEvent {
                    kind: EventKind::Complete,
                    ts_ns: i,
                    dur_ns: i * 1_000,
                    class: Some(QosClass::Billed),
                    sensor_id: node as u32,
                    seq: i,
                    ..TraceEvent::default()
                };
                feed.push_str(&ev.to_jsonl());
                feed.push('\n');
            }
            // Two gauges per feed: only the final one counts, and the
            // merged figure sums the two feeds (3 + 5, not "last wins").
            for value in [1.0, (node as f64 + 1.0) * 2.0 + 1.0] {
                let ev = TraceEvent {
                    kind: EventKind::Gauge,
                    label: "events_dropped",
                    value,
                    ..TraceEvent::default()
                };
                feed.push_str(&ev.to_jsonl());
                feed.push('\n');
            }
            feeds.push(feed);
        }
        let named: Vec<(&str, &str)> = vec![
            ("feed-node0.jsonl", &feeds[0]),
            ("feed-node1.jsonl", &feeds[1]),
        ];
        let sm = summarize_feeds(&named).unwrap();
        assert_eq!(sm.completed[QosClass::Billed.index()], 20);
        assert_eq!(sm.events_dropped, 3 + 5);
        // A corrupt line in a named feed reports which feed.
        let bad = vec![("a.jsonl", feeds[0].as_str()), ("b.jsonl", "junk")];
        let err = summarize_feeds(&bad).unwrap_err().to_string();
        assert!(err.contains("b.jsonl"), "{err}");

        // Chrome merge: one process per feed, both named.
        let dir = std::env::temp_dir().join(format!(
            "nslbp-obs-merge-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("merged.trace.json");
        let n = merge_chrome_trace(&named, out.to_str().unwrap()).unwrap();
        assert_eq!(n, 24); // 2 × (10 completes + 2 gauges)
        let chrome = std::fs::read_to_string(&out).unwrap();
        let trimmed = chrome.trim();
        assert!(trimmed.starts_with('[') && trimmed.ends_with(']'));
        assert!(chrome.contains("feed-node0.jsonl"));
        assert!(chrome.contains("feed-node1.jsonl"));
        assert!(chrome.contains("\"pid\":2"));
        assert!(chrome.contains("request/billed"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn session_writes_feed_and_chrome_trace() {
        let dir = std::env::temp_dir().join(format!(
            "nslbp-obs-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let jsonl = dir.join("t.jsonl");
        let cfg = ObsConfig {
            enabled: true,
            ring_capacity: 1024,
            sample_period_us: 1_000,
            jsonl_path: jsonl.to_str().unwrap().to_string(),
        };
        let session = TraceSession::start(&cfg, |t| {
            t.emit(TraceEvent {
                kind: EventKind::Gauge,
                ts_ns: t.now(),
                label: "queue_depth",
                class: Some(QosClass::Standard),
                value: 3.0,
                ..TraceEvent::default()
            });
        })
        .unwrap();
        let tracer = session.tracer();
        assert!(tracer.enabled());
        let t0 = tracer.now();
        tracer.emit(TraceEvent {
            kind: EventKind::Submit,
            ts_ns: t0,
            class: Some(QosClass::Standard),
            sensor_id: 4,
            seq: 1,
            ..TraceEvent::default()
        });
        tracer.emit(TraceEvent {
            kind: EventKind::Complete,
            ts_ns: t0,
            dur_ns: 2_000,
            class: Some(QosClass::Standard),
            sensor_id: 4,
            seq: 1,
            batch_id: 1,
            ..TraceEvent::default()
        });
        session.finish().unwrap();

        let feed = std::fs::read_to_string(&cfg.jsonl_path).unwrap();
        let sm = summarize(&feed).unwrap();
        assert_eq!(sm.completed[QosClass::Standard.index()], 1);
        assert_eq!(sm.events_dropped, 0);
        // chrome file is a well-formed JSON array with the core keys
        let chrome = std::fs::read_to_string(cfg.chrome_path()).unwrap();
        let trimmed = chrome.trim();
        assert!(trimmed.starts_with('[') && trimmed.ends_with(']'));
        assert!(chrome.contains("\"ph\":\"X\""));
        assert!(chrome.contains("\"ph\":\"C\""));
        assert!(chrome.contains("sensor-4"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disabled_session_writes_nothing() {
        let cfg = ObsConfig {
            enabled: false,
            jsonl_path: "/nonexistent-dir/never-created.jsonl".into(),
            ..ObsConfig::default()
        };
        let session = TraceSession::start(&cfg, |_| {}).unwrap();
        assert!(!session.tracer().enabled());
        session.finish().unwrap();
        assert!(!std::path::Path::new("/nonexistent-dir").exists());
    }
}
