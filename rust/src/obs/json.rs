//! Minimal hand-rolled JSON emission and flat-object parsing shared by
//! every JSON producer in the crate (metrics reports, bench harness,
//! trace feed) — serde is unavailable offline.
//!
//! Two guarantees the ad-hoc `format!`-based emitters did not make:
//!
//! * **Strings are always escaped.**  [`escape_into`] handles `"`,
//!   `\\`, the common control escapes, and everything else below
//!   `0x20` as `\uXXXX`, so user-supplied text (an `[hw] profile`
//!   path, a bench case name) can never break the document.
//! * **Numbers are always valid JSON.**  [`push_f64`] never emits
//!   `NaN` or `inf` (both illegal in JSON): non-finite values are
//!   written as `0` — a sentinel the consumers treat as "absent" —
//!   and finite values round-trip via Rust's shortest-representation
//!   float formatting.

/// Append `s` to `out` with JSON string escaping (no surrounding
/// quotes).
pub fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// JSON-escaped copy of `s` (no surrounding quotes).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    escape_into(&mut out, s);
    out
}

/// Append `v` as a JSON number: finite values verbatim, non-finite
/// values as `0` (JSON has no `NaN`/`inf`).
pub fn push_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        // Rust's float Display is shortest-roundtrip and never
        // produces forms JSON would reject.
        out.push_str(&format!("{v}"));
    } else {
        out.push('0');
    }
}

/// A `"key":"escaped value",` pair (trailing comma included).
pub fn push_str_field(out: &mut String, key: &str, v: &str) {
    out.push('"');
    escape_into(out, key);
    out.push_str("\":\"");
    escape_into(out, v);
    out.push_str("\",");
}

/// A `"key":number,` pair (trailing comma included, non-finite → 0).
pub fn push_f64_field(out: &mut String, key: &str, v: f64) {
    out.push('"');
    escape_into(out, key);
    out.push_str("\":");
    push_f64(out, v);
    out.push(',');
}

/// A `"key":integer,` pair (trailing comma included).
pub fn push_u64_field(out: &mut String, key: &str, v: u64) {
    out.push('"');
    escape_into(out, key);
    out.push_str("\":");
    out.push_str(&v.to_string());
    out.push(',');
}

/// One field of a flat JSON object: every value is either a string or
/// a number (the only two types the trace feed emits).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            Value::Num(_) => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(v) => Some(*v),
            Value::Str(_) => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|v| *v >= 0.0).map(|v| v as u64)
    }
}

/// Parse one *flat* JSON object — string or number values only, no
/// nesting, no arrays, no booleans — the exact shape every trace-feed
/// line has.  Returns key → value pairs; errors carry a short reason.
pub fn parse_flat_object(line: &str)
                         -> std::result::Result<Vec<(String, Value)>, String> {
    let mut chars = line.trim().chars().peekable();
    let mut out = Vec::new();
    if chars.next() != Some('{') {
        return Err("expected '{'".into());
    }
    loop {
        skip_ws(&mut chars);
        match chars.peek() {
            Some('}') => {
                chars.next();
                break;
            }
            Some('"') => {}
            _ => return Err("expected '\"' or '}'".into()),
        }
        let key = parse_string(&mut chars)?;
        skip_ws(&mut chars);
        if chars.next() != Some(':') {
            return Err(format!("expected ':' after key {key:?}"));
        }
        skip_ws(&mut chars);
        let value = match chars.peek() {
            Some('"') => Value::Str(parse_string(&mut chars)?),
            Some(c) if c.is_ascii_digit() || *c == '-' => {
                let mut num = String::new();
                while let Some(&c) = chars.peek() {
                    if c.is_ascii_digit()
                        || matches!(c, '-' | '+' | '.' | 'e' | 'E')
                    {
                        num.push(c);
                        chars.next();
                    } else {
                        break;
                    }
                }
                Value::Num(
                    num.parse::<f64>()
                        .map_err(|_| format!("bad number {num:?}"))?,
                )
            }
            _ => return Err(format!("unsupported value for key {key:?}")),
        };
        out.push((key, value));
        skip_ws(&mut chars);
        match chars.next() {
            Some(',') => continue,
            Some('}') => break,
            _ => return Err("expected ',' or '}'".into()),
        }
    }
    skip_ws(&mut chars);
    if chars.next().is_some() {
        return Err("trailing garbage after object".into());
    }
    Ok(out)
}

fn skip_ws(chars: &mut std::iter::Peekable<std::str::Chars<'_>>) {
    while chars.peek().is_some_and(|c| c.is_whitespace()) {
        chars.next();
    }
}

fn parse_string(chars: &mut std::iter::Peekable<std::str::Chars<'_>>)
                -> std::result::Result<String, String> {
    if chars.next() != Some('"') {
        return Err("expected '\"'".into());
    }
    let mut s = String::new();
    loop {
        match chars.next() {
            None => return Err("unterminated string".into()),
            Some('"') => return Ok(s),
            Some('\\') => match chars.next() {
                Some('"') => s.push('"'),
                Some('\\') => s.push('\\'),
                Some('/') => s.push('/'),
                Some('n') => s.push('\n'),
                Some('r') => s.push('\r'),
                Some('t') => s.push('\t'),
                Some('b') => s.push('\u{8}'),
                Some('f') => s.push('\u{c}'),
                Some('u') => {
                    let hex: String = (0..4)
                        .filter_map(|_| chars.next())
                        .collect();
                    let code = u32::from_str_radix(&hex, 16)
                        .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                    s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                }
                other => return Err(format!("bad escape {other:?}")),
            },
            Some(c) => s.push(c),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_quotes_backslashes_and_controls() {
        assert_eq!(escape(r#"a"b\c"#), r#"a\"b\\c"#);
        assert_eq!(escape("line\nfeed\ttab"), "line\\nfeed\\ttab");
        assert_eq!(escape("\u{1}"), "\\u0001");
        assert_eq!(escape("plain/path.toml"), "plain/path.toml");
    }

    #[test]
    fn non_finite_floats_become_zero() {
        let mut s = String::new();
        push_f64(&mut s, f64::NAN);
        push_f64(&mut s, f64::INFINITY);
        push_f64(&mut s, f64::NEG_INFINITY);
        assert_eq!(s, "000");
        s.clear();
        push_f64(&mut s, 1.5);
        assert_eq!(s, "1.5");
    }

    #[test]
    fn flat_object_roundtrip() {
        let mut line = String::from("{");
        push_str_field(&mut line, "kind", "infer");
        push_str_field(&mut line, "path", "a\"b\\c");
        push_u64_field(&mut line, "ts_ns", 12345);
        push_f64_field(&mut line, "value", -2.5);
        line.pop();
        line.push('}');
        let fields = parse_flat_object(&line).unwrap();
        let get = |k: &str| {
            fields.iter().find(|(key, _)| key == k).map(|(_, v)| v.clone())
        };
        assert_eq!(get("kind"), Some(Value::Str("infer".into())));
        assert_eq!(get("path"), Some(Value::Str("a\"b\\c".into())));
        assert_eq!(get("ts_ns").unwrap().as_u64(), Some(12345));
        assert_eq!(get("value").unwrap().as_f64(), Some(-2.5));
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        assert!(parse_flat_object("not json").is_err());
        assert!(parse_flat_object("{\"a\":").is_err());
        assert!(parse_flat_object("{\"a\":1} extra").is_err());
        assert!(parse_flat_object("{\"a\":[1]}").is_err());
        assert!(parse_flat_object("{}").is_ok());
    }
}
