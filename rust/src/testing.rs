//! Minimal property-testing framework (proptest is unavailable offline).
//!
//! Provides value generators over [`crate::rng::Xoshiro256`], a case runner
//! with failure reporting (seed + iteration, so any failure is replayable),
//! greedy input shrinking for the common container/scalar cases, and the
//! shared synthetic-frame replay ([`synth_frames`]) used by `serve-bench`,
//! the serving benches, and the serve tests.
//!
//! ```no_run
//! // (no_run: doctest binaries bypass the crate's rpath to libxla_extension)
//! use ns_lbp::testing::{Config, Gen, check};
//!
//! check(Config::default().cases(64), "addition commutes", |g| {
//!     let a = g.u32_below(1000);
//!     let b = g.u32_below(1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::rng::Xoshiro256;

/// Digitize `n` random synthetic scenes through the sensor model for the
/// given network shape — the deterministic frame workload behind
/// `serve-bench`, `benches/serve_throughput.rs`, and the serve tests.
pub fn synth_frames(params: &crate::params::NetParams, n: usize, seed: u64)
                    -> crate::error::Result<Vec<crate::sensor::Frame>> {
    use crate::sensor::{FrameSource, ReplaySensor, SensorConfig};
    let cfg = params.config;
    let sensor_cfg = SensorConfig {
        rows: cfg.height,
        cols: cfg.width,
        channels: cfg.in_channels,
        skip_lsbs: cfg.apx_pixel,
        ..Default::default()
    };
    let mut rng = Xoshiro256::new(seed);
    let scenes: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..sensor_cfg.pixels()).map(|_| rng.next_f64()).collect())
        .collect();
    let mut sensor = ReplaySensor::new(sensor_cfg, scenes, seed)?;
    let mut frames = Vec::with_capacity(n);
    while let Some(f) = sensor.next_frame() {
        frames.push(f);
    }
    Ok(frames)
}

/// Load `artifacts/<dataset>.params.bin` (honoring the `NSLBP_ARTIFACTS`
/// env var), or `None` with a skip message when the artifact is absent —
/// the gating helper the artifact-dependent test suites share so
/// `cargo test` stays green from a bare checkout.
pub fn artifact_params(dataset: &str) -> Option<crate::params::NetParams> {
    let dir = std::env::var("NSLBP_ARTIFACTS")
        .unwrap_or_else(|_| crate::ARTIFACTS_DIR.into());
    let path = format!("{dir}/{dataset}.params.bin");
    if !std::path::Path::new(&path).exists() {
        eprintln!("skipping: artifact {path} missing — run `make artifacts`");
        return None;
    }
    Some(crate::params::load(path).expect("corrupt params artifact"))
}

/// Runner configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: u32,
    /// Base seed; case `i` runs with seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // NSLBP_PROPTEST_SEED overrides for replay; NSLBP_PROPTEST_CASES for
        // deeper local runs.
        let base_seed = std::env::var("NSLBP_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xA5A5_5A5A);
        let cases = std::env::var("NSLBP_PROPTEST_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(128);
        Self { cases, base_seed }
    }
}

impl Config {
    pub fn cases(mut self, n: u32) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.base_seed = s;
        self
    }
}

/// Per-case generator handle passed to the property closure.
pub struct Gen {
    rng: Xoshiro256,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256::new(seed) }
    }

    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn u8(&mut self) -> u8 {
        self.rng.next_u64() as u8
    }

    pub fn u32_below(&mut self, n: u32) -> u32 {
        self.rng.below(n as u64) as u32
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_i64(lo as i64, hi as i64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Vector of length `[min_len, max_len]` filled by `f`.
    pub fn vec<T>(&mut self, min_len: usize, max_len: usize,
                  mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| f(self)).collect()
    }

    /// Vector of `n` bytes.
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| self.u8()).collect()
    }
}

/// Run `property` over `config.cases` random cases; panic with the seed of
/// the first failing case.  The property signals failure by panicking
/// (e.g. via `assert!`), matching std test ergonomics.
pub fn check<F>(config: Config, name: &str, mut property: F)
where
    F: FnMut(&mut Gen),
{
    for i in 0..config.cases {
        let seed = config.base_seed.wrapping_add(i as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            property(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {i} (replay with \
                 NSLBP_PROPTEST_SEED={seed} NSLBP_PROPTEST_CASES=1): {msg}"
            );
        }
    }
}

/// Greedy shrink of a failing `Vec` input: repeatedly tries dropping chunks
/// while the predicate still fails; returns a locally minimal failing input.
pub fn shrink_vec<T: Clone>(input: &[T], fails: impl Fn(&[T]) -> bool) -> Vec<T> {
    let mut cur: Vec<T> = input.to_vec();
    debug_assert!(fails(&cur), "shrink_vec called with a passing input");
    let mut chunk = cur.len().max(1) / 2;
    while chunk > 0 {
        let mut i = 0;
        while i + chunk <= cur.len() {
            let mut candidate = cur.clone();
            candidate.drain(i..i + chunk);
            if fails(&candidate) {
                cur = candidate; // keep the smaller failing input
            } else {
                i += chunk;
            }
        }
        chunk /= 2;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(Config::default().cases(16), "trivial", |g| {
            let v = g.vec(0, 10, |g| g.u8());
            assert!(v.len() <= 10);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failures_with_seed() {
        check(Config::default().cases(4), "always fails", |_| {
            panic!("boom");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let x = g.i64_in(-5, 5);
            assert!((-5..=5).contains(&x));
            let u = g.u32_below(3);
            assert!(u < 3);
            let f = g.f64_in(1.0, 2.0);
            assert!((1.0..2.0).contains(&f));
        }
    }

    #[test]
    fn shrink_finds_minimal_counterexample() {
        // property: "no element equals 42" — minimal failing input is [42]
        let input: Vec<u32> = (0..100).collect();
        let failing: Vec<u32> = input.iter().cloned().chain([42]).collect();
        let shrunk = shrink_vec(&failing, |v| v.contains(&42));
        assert_eq!(shrunk, vec![42]);
    }

    #[test]
    fn deterministic_cases() {
        let mut first = Vec::new();
        check(Config::default().cases(8).seed(99), "record", |g| {
            first.push(g.u32_below(1_000_000));
        });
        let mut second = Vec::new();
        check(Config::default().cases(8).seed(99), "record", |g| {
            second.push(g.u32_below(1_000_000));
        });
        assert_eq!(first, second);
    }
}
