//! The async serve plane: the whole request pipeline — admission,
//! per-class batch formation, shard dispatch — run as cooperative
//! [`Task`](crate::exec::Task) state machines on one small
//! [`Executor`] worker pool, instead of a dedicated OS thread per
//! batcher and per shard.
//!
//! Why: the thread-per-stage plane scales with *pipeline stages*; the
//! paper's always-on edge deployment scales with *sensors*.  100 000
//! concurrent sensor sessions cannot each afford a thread, but they can
//! each afford a queue lane and a few hundred bytes of scheduler state.
//! The executor multiplexes everything onto `[serve.async] workers`
//! threads (default: one per core, capped at 8).
//!
//! Three task kinds cooperate:
//!
//! * **Class schedulers** (one per [`QosClass`]) own the class's
//!   per-sensor lanes and drain them with deficit-round-robin fairness
//!   ([`super::fairness::DrrScheduler`]): a hot camera can saturate
//!   *idle* capacity but never starve a backlogged classmate.  Batches
//!   seal on the class's `max_batch`, on its `deadline_us` (armed on
//!   the executor's timer wheel), or on drain-close — the same triggers
//!   and the same trace spans as the threaded batcher.
//! * **Dispatch tasks** (one per *potential* shard, `0..max_shards`)
//!   pull sealed batches and run `ShardWorker::dispatch`
//!   — bit-identical logits to the threaded shard pool, since both
//!   drive the same worker over the same disjoint
//!   [`ShardSlice`](crate::engine::ShardSlice)s (`count = max_shards`
//!   regardless of how many are active).  A task whose index is at or
//!   beyond the active count parks and *releases its engines*; on
//!   scale-up it rebuilds them from the model's prepacked planes
//!   (table wiring, not packing).
//! * **The autoscaler** samples the batch-queue depth every
//!   `scale_interval_us`: sustained depth grows the active shard count
//!   toward `max_shards`, sustained idleness shrinks it toward
//!   `min_shards`.  Scale changes never drop frames — a dispatch task
//!   checks its activation *before* popping, and a batch once popped is
//!   always dispatched to completion.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::{AsyncServeConfig, ClassKnobs, ServeConfig};
use crate::engine::{BackendKind, EngineConfig, RoutingPolicy, ShardSlice};
use crate::error::{Error, Result};
use crate::exec::{Context, EventSource, ExecQueue, Executor, Notify, Poll,
                  PollPop, Task};
use crate::obs::{EventKind, TraceEvent, Tracer};

use super::batcher::FlushReason;
use super::fairness::DrrScheduler;
use super::metrics::Metrics;
use super::shard::{Batch, ShardWorker};
use super::{ModelEntry, QosClass, QueuedRequest};

// ---------------------------------------------------------------------------
// Admission state: per-class DRR lanes
// ---------------------------------------------------------------------------

struct LaneState {
    sched: DrrScheduler<QueuedRequest>,
    closed: bool,
}

/// One QoS class's admission state: per-sensor DRR lanes bounded (in
/// total) by the class's `queue_depth` — the same depth the threaded
/// plane's [`super::queue::BoundedQueue`] enforces, just spread across
/// lanes instead of one FIFO.
pub(crate) struct ClassLanes {
    state: Mutex<LaneState>,
    /// Wakes the class scheduler task; registrations happen under
    /// `state`'s lock, so an admit can never slip between the
    /// scheduler's emptiness check and its parking.
    notify: Notify,
    depth: usize,
}

impl ClassLanes {
    fn new(quantum: u32, depth: usize) -> Self {
        Self {
            state: Mutex::new(LaneState {
                sched: DrrScheduler::new(quantum),
                closed: false,
            }),
            notify: Notify::new(),
            depth,
        }
    }

    /// Queued frames across every lane of this class (gauge view).
    pub(crate) fn len(&self) -> usize {
        self.state.lock().unwrap().sched.len()
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.notify.notify();
    }
}

/// Admission verdict for one request (the caller owns metrics/tracing,
/// so threaded and async admission stay observably identical).
pub(crate) enum Admit {
    Accepted,
    /// Accepted by displacing this queued request (drop-oldest class at
    /// depth); the displaced ticket must be failed by the caller.
    AcceptedDisplacing(QueuedRequest),
    /// Reject-newest class at depth.
    Full,
    /// The plane is draining.
    Closed,
}

// ---------------------------------------------------------------------------
// Autoscale state
// ---------------------------------------------------------------------------

struct ScaleState {
    /// Dispatch tasks with `index < active` pull batches; the rest park.
    active: AtomicUsize,
    /// Wakes parked dispatch tasks on scale-up (and on drain cascade).
    notify: Notify,
    high_water: AtomicUsize,
    up_events: AtomicU64,
    down_events: AtomicU64,
}

impl ScaleState {
    fn new(initial: usize) -> Self {
        Self {
            active: AtomicUsize::new(initial),
            notify: Notify::new(),
            high_water: AtomicUsize::new(initial),
            up_events: AtomicU64::new(0),
            down_events: AtomicU64::new(0),
        }
    }

    fn set_active(&self, n: usize) {
        self.active.store(n, Ordering::Release);
        self.high_water.fetch_max(n, Ordering::Relaxed);
        self.notify.notify();
    }
}

/// One autoscaler sampling step, as a pure function so the policy is
/// unit-testable without timers: returns the new active count and the
/// new consecutive-idle counter.
fn autoscale_decision(depth: usize, active: usize, idle: u32, min: usize,
                      max: usize, up_depth: usize, down_idle: u32)
                      -> (usize, u32) {
    // backlog proportional to the active pool means every active shard
    // already has work queued behind it: grow
    if active < max && depth >= up_depth.saturating_mul(active).max(1) {
        return (active + 1, 0);
    }
    if depth == 0 {
        let idle = idle.saturating_add(1);
        if idle >= down_idle && active > min {
            return (active - 1, 0);
        }
        return (active, idle);
    }
    (active, 0)
}

/// A point-in-time view of the async plane (serve-bench JSON, tests).
#[derive(Clone, Copy, Debug)]
pub struct AsyncStats {
    pub workers: usize,
    pub min_shards: usize,
    pub max_shards: usize,
    pub active_shards: usize,
    pub shards_high_water: usize,
    pub scale_up_events: u64,
    pub scale_down_events: u64,
}

// ---------------------------------------------------------------------------
// Shared handles (built before the trace session so gauges can sample)
// ---------------------------------------------------------------------------

/// The plane's shared state, split out so [`super::Server::start`] can
/// wire the trace sampler's gauges to it before any task runs.
#[derive(Clone)]
pub(crate) struct AsyncShared {
    pub(crate) lanes: [Arc<ClassLanes>; QosClass::COUNT],
    batches: Arc<ExecQueue<Batch>>,
    scale: Arc<ScaleState>,
}

impl AsyncShared {
    pub(crate) fn new(serve: &ServeConfig) -> Self {
        let a = serve.async_plane;
        let max = a.max_shards_or(serve.shards);
        Self {
            lanes: std::array::from_fn(|i| {
                let knobs = serve.class_knobs(QosClass::ALL[i]);
                Arc::new(ClassLanes::new(a.quantum, knobs.queue_depth))
            }),
            batches: Arc::new(ExecQueue::new()),
            scale: Arc::new(ScaleState::new(a.min_shards.min(max))),
        }
    }

    /// Sealed batches awaiting dispatch (gauge view).
    pub(crate) fn batch_depth(&self) -> usize {
        self.batches.len()
    }

    /// Currently active dispatch shards (gauge view).
    pub(crate) fn active_shards(&self) -> usize {
        self.scale.active.load(Ordering::Acquire)
    }
}

// ---------------------------------------------------------------------------
// Tasks
// ---------------------------------------------------------------------------

/// Per-class scheduler: drains the class's DRR lanes into batches.
struct ClassTask {
    class: QosClass,
    lanes: Arc<ClassLanes>,
    max_batch: usize,
    max_delay: Duration,
    forming: Vec<QueuedRequest>,
    /// Enqueue instant of the forming batch's first member — the
    /// deadline anchor, exactly like the threaded batcher's.
    anchor: Instant,
    /// The deadline currently armed on the timer wheel (dedup: a poll
    /// re-run by an arrival does not re-arm the same flush).
    armed: Option<Instant>,
    batches: Arc<ExecQueue<Batch>>,
    routing: RoutingPolicy,
    default_backend: BackendKind,
    tracer: Tracer,
    /// Class tasks still running; the last one out closes `batches`.
    remaining: Arc<AtomicUsize>,
}

enum ClassStep {
    Seal(FlushReason),
    Wait(Option<Instant>),
    Finish,
}

impl ClassTask {
    /// Seal the forming batch: split by (model id, pinned version)
    /// preserving order, emit the batch-formation and queue-wait spans,
    /// and hand each group to the dispatch queue — the async twin of
    /// the threaded batcher loop in [`super::Server::start`].
    fn seal(&mut self, reason: FlushReason) {
        let reqs = std::mem::take(&mut self.forming);
        let mut groups: Vec<(u32, u64, Vec<QueuedRequest>)> = Vec::new();
        for r in reqs {
            let key = (r.model_id, r.model.version);
            match groups.iter_mut().find(|(m, v, _)| (*m, *v) == key) {
                Some((_, _, g)) => g.push(r),
                None => groups.push((key.0, key.1, vec![r])),
            }
        }
        for (model_id, _version, reqs) in groups {
            let backend = self
                .routing
                .resolve_model(self.class, model_id, self.default_backend);
            let batch_id = self.tracer.next_batch_id();
            if self.tracer.enabled() {
                let sealed = Instant::now();
                let oldest = reqs
                    .iter()
                    .map(|r| r.enqueued_at)
                    .min()
                    .unwrap_or(sealed);
                self.tracer.emit(TraceEvent {
                    kind: EventKind::Batch,
                    ts_ns: self.tracer.ts(oldest),
                    dur_ns: sealed
                        .saturating_duration_since(oldest)
                        .as_nanos() as u64,
                    class: Some(self.class),
                    model_id,
                    batch_id,
                    label: reason.as_str(),
                    value: reqs.len() as f64,
                    ..TraceEvent::default()
                });
                for r in &reqs {
                    self.tracer.emit(TraceEvent {
                        kind: EventKind::Queue,
                        ts_ns: self.tracer.ts(r.enqueued_at),
                        dur_ns: sealed
                            .saturating_duration_since(r.enqueued_at)
                            .as_nanos() as u64,
                        class: Some(self.class),
                        sensor_id: r.sensor_id,
                        seq: r.frame.seq,
                        model_id,
                        batch_id,
                        ..TraceEvent::default()
                    });
                }
            }
            let model = Arc::clone(&reqs[0].model);
            let batch = Batch {
                class: self.class,
                backend,
                model_id,
                model,
                batch_id,
                requests: reqs,
            };
            if let Err(batch) = self.batches.push(batch) {
                // force-closed under us (abandoned drain): resolve the
                // members instead of leaving their tickets dangling
                for req in batch.requests {
                    req.slot.fulfill(Err(Error::Serve(
                        "server is draining".into(),
                    )));
                }
            }
        }
    }
}

impl Task for ClassTask {
    fn poll(&mut self, cx: &mut Context<'_>) -> Poll {
        loop {
            let step = {
                let mut st = self.lanes.state.lock().unwrap();
                while self.forming.len() < self.max_batch {
                    match st.sched.pop() {
                        Some((_sid, r)) => {
                            if self.forming.is_empty() {
                                self.anchor = r.enqueued_at;
                                self.armed = None;
                            }
                            self.forming.push(r);
                        }
                        None => break,
                    }
                }
                if self.forming.len() >= self.max_batch {
                    ClassStep::Seal(FlushReason::Size)
                } else if st.closed && st.sched.is_empty() {
                    if self.forming.is_empty() {
                        ClassStep::Finish
                    } else {
                        ClassStep::Seal(FlushReason::Closed)
                    }
                } else if !self.forming.is_empty() {
                    let deadline = self.anchor + self.max_delay;
                    if Instant::now() >= deadline {
                        ClassStep::Seal(FlushReason::Deadline)
                    } else {
                        // park for arrivals under the state lock (an
                        // admit serializes after this registration)
                        self.lanes.notify.register(&cx.waker());
                        ClassStep::Wait(Some(deadline))
                    }
                } else {
                    self.lanes.notify.register(&cx.waker());
                    ClassStep::Wait(None)
                }
            };
            match step {
                ClassStep::Seal(reason) => {
                    self.seal(reason);
                    // loop: more lanes may already be poppable
                }
                ClassStep::Wait(deadline) => {
                    if let Some(d) = deadline {
                        if self.armed != Some(d) {
                            self.armed = Some(d);
                            cx.wake_at(d);
                        }
                    }
                    return Poll::Pending;
                }
                ClassStep::Finish => {
                    if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        self.batches.close();
                    }
                    return Poll::Ready;
                }
            }
        }
    }
}

/// One potential shard: dispatches batches while `index < active`,
/// parks (and releases its engines) otherwise.
struct DispatchTask {
    index: usize,
    /// Built on activation, dropped on deactivation — the engine pool
    /// genuinely grows and shrinks.  Slices always use
    /// `count = max_shards`, so they stay disjoint at any active count
    /// and logits never depend on the autoscaler's history.
    worker: Option<ShardWorker>,
    max_shards: usize,
    default_model: Arc<ModelEntry>,
    config: EngineConfig,
    backends: Arc<Vec<BackendKind>>,
    batches: Arc<ExecQueue<Batch>>,
    scale: Arc<ScaleState>,
    metrics: Arc<Metrics>,
    tracer: Tracer,
}

impl DispatchTask {
    /// Fan an engine-build failure out to every member of `batch`
    /// (mirrors the threaded shard's `engine_build` failure path).
    fn fail_batch(&self, batch: Batch, msg: &str) {
        let Batch { class, model_id, batch_id, requests, .. } = batch;
        for req in requests {
            self.metrics.record_failure(class, model_id);
            if self.tracer.enabled() {
                self.tracer.emit(TraceEvent {
                    kind: EventKind::Fail,
                    ts_ns: self.tracer.now(),
                    class: Some(class),
                    sensor_id: req.sensor_id,
                    seq: req.frame.seq,
                    model_id,
                    batch_id,
                    shard: self.index as i32,
                    label: "engine_build",
                    ..TraceEvent::default()
                });
            }
            req.slot.fulfill(Err(Error::Serve(format!(
                "engine build for model {model_id} failed: {msg}"
            ))));
        }
    }
}

impl Task for DispatchTask {
    fn poll(&mut self, cx: &mut Context<'_>) -> Poll {
        loop {
            if self.index >= self.scale.active.load(Ordering::Acquire) {
                // deactivated: release the engines so the pool shrinks
                self.worker = None;
                // park on scale-up *and* on queue closure; register
                // first, then re-check, so a concurrent scale-up (or
                // close) between check and park is never missed
                self.scale.notify.register(&cx.waker());
                self.batches.register(&cx.waker());
                if self.batches.is_closed() {
                    // remaining items (if any) are drained by the
                    // always-active shards below min_shards
                    return Poll::Ready;
                }
                if self.index < self.scale.active.load(Ordering::Acquire) {
                    continue;
                }
                return Poll::Pending;
            }
            match self.batches.poll_pop(&cx.waker()) {
                PollPop::Item(batch) => {
                    if self.worker.is_none() {
                        match ShardWorker::build(
                            &self.default_model,
                            &self.config,
                            ShardSlice {
                                index: self.index,
                                count: self.max_shards,
                            },
                            &self.backends,
                            &self.tracer,
                        ) {
                            Ok(w) => self.worker = Some(w),
                            Err(e) => {
                                self.fail_batch(batch, &e.to_string());
                                continue;
                            }
                        }
                    }
                    let worker =
                        self.worker.as_mut().expect("worker built above");
                    // panic isolation (mirrors the thread pool): a
                    // panicking dispatch fails its batch's slots instead
                    // of killing the executor worker under this task
                    let caught = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            worker.dispatch(batch, &self.metrics,
                                            &self.tracer);
                        }),
                    );
                    if caught.is_err() {
                        worker.fail_pending(&self.metrics);
                    }
                    // yield between batches: self-wake requeues this
                    // task at the back of the ready queue, so dispatch
                    // work round-robins across the worker pool instead
                    // of one hot shard monopolizing a worker thread
                    cx.waker().wake();
                    return Poll::Pending;
                }
                PollPop::Empty => return Poll::Pending,
                PollPop::Closed => {
                    // cascade so parked peers observe the closure too
                    self.scale.notify.notify();
                    return Poll::Ready;
                }
            }
        }
    }
}

/// Periodic load sampler driving [`ScaleState`].
struct Autoscaler {
    batches: Arc<ExecQueue<Batch>>,
    scale: Arc<ScaleState>,
    cfg: AsyncServeConfig,
    max_shards: usize,
    idle: u32,
    /// The armed sample deadline: spurious wakes before it neither
    /// sample nor arm a duplicate timer.
    next_due: Option<Instant>,
    tracer: Tracer,
}

impl Task for Autoscaler {
    fn poll(&mut self, cx: &mut Context<'_>) -> Poll {
        if self.batches.is_closed() {
            return Poll::Ready;
        }
        let now = Instant::now();
        if let Some(due) = self.next_due {
            if now < due {
                // woken early (drain broadcast): the timer for `due`
                // is still armed, just go back to sleep
                return Poll::Pending;
            }
        }
        let active = self.scale.active.load(Ordering::Acquire);
        let (next, idle) = autoscale_decision(
            self.batches.len(),
            active,
            self.idle,
            self.cfg.min_shards.min(self.max_shards),
            self.max_shards,
            self.cfg.scale_up_depth,
            self.cfg.scale_down_idle,
        );
        self.idle = idle;
        if next != active {
            if next > active {
                self.scale.up_events.fetch_add(1, Ordering::Relaxed);
            } else {
                self.scale.down_events.fetch_add(1, Ordering::Relaxed);
            }
            self.scale.set_active(next);
            if self.tracer.enabled() {
                self.tracer.emit(TraceEvent {
                    kind: EventKind::Gauge,
                    ts_ns: self.tracer.now(),
                    label: "active_shards",
                    value: next as f64,
                    ..TraceEvent::default()
                });
            }
        }
        let due = now + self.cfg.scale_interval();
        self.next_due = Some(due);
        cx.wake_at(due);
        Poll::Pending
    }
}

// ---------------------------------------------------------------------------
// The plane
// ---------------------------------------------------------------------------

/// The running async serve plane owned by a [`super::Server`] when
/// `[serve.async] enabled = true`.
pub(crate) struct AsyncPlane {
    shared: AsyncShared,
    knobs: [ClassKnobs; QosClass::COUNT],
    executor: Option<Executor>,
    workers: usize,
    min_shards: usize,
    max_shards: usize,
}

impl AsyncPlane {
    /// Build the engine for shard 0 eagerly (validating the bank split
    /// and every routed backend before any task runs), then spawn the
    /// executor with the class schedulers, `max_shards` dispatch tasks,
    /// and the autoscaler.
    pub(crate) fn start(shared: AsyncShared, default_model: &Arc<ModelEntry>,
                        config: &EngineConfig, backends: &[BackendKind],
                        metrics: &Arc<Metrics>, tracer: &Tracer)
                        -> Result<Self> {
        let serve = config.system.serve;
        let a = serve.async_plane;
        let max_shards = a.max_shards_or(serve.shards);
        let min_shards = a.min_shards.min(max_shards);
        // shard 0 is never parked (min_shards >= 1): building it now
        // surfaces geometry/backend errors at start, like ShardPool does
        let worker0 = ShardWorker::build(
            default_model,
            config,
            ShardSlice { index: 0, count: max_shards },
            backends,
            tracer,
        )?;

        let workers = if a.workers > 0 {
            a.workers
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(2, 8)
        };
        let executor =
            Executor::new(workers, "nslbp-async").map_err(Error::Io)?;

        let routing = config.system.engine.routing.clone();
        let default_backend = config.system.engine.backend;
        let remaining = Arc::new(AtomicUsize::new(QosClass::COUNT));
        for class in QosClass::ALL {
            let knobs = serve.class_knobs(class);
            executor.spawn(Box::new(ClassTask {
                class,
                lanes: Arc::clone(&shared.lanes[class.index()]),
                max_batch: knobs.max_batch,
                max_delay: knobs.deadline(),
                forming: Vec::new(),
                anchor: Instant::now(),
                armed: None,
                batches: Arc::clone(&shared.batches),
                routing: routing.clone(),
                default_backend,
                tracer: tracer.clone(),
                remaining: Arc::clone(&remaining),
            }));
        }

        let backends: Arc<Vec<BackendKind>> = Arc::new(backends.to_vec());
        let mut prebuilt = Some(worker0);
        for index in 0..max_shards {
            executor.spawn(Box::new(DispatchTask {
                index,
                worker: if index == 0 { prebuilt.take() } else { None },
                max_shards,
                default_model: Arc::clone(default_model),
                config: config.clone(),
                backends: Arc::clone(&backends),
                batches: Arc::clone(&shared.batches),
                scale: Arc::clone(&shared.scale),
                metrics: Arc::clone(metrics),
                tracer: tracer.clone(),
            }));
        }

        executor.spawn(Box::new(Autoscaler {
            batches: Arc::clone(&shared.batches),
            scale: Arc::clone(&shared.scale),
            cfg: a,
            max_shards,
            idle: 0,
            next_due: None,
            tracer: tracer.clone(),
        }));

        let knobs =
            std::array::from_fn(|i| serve.class_knobs(QosClass::ALL[i]));
        Ok(Self {
            shared,
            knobs,
            executor: Some(executor),
            workers,
            min_shards,
            max_shards,
        })
    }

    /// Admit one validated request into its class's DRR lanes.  The
    /// caller (the server's submit path) translates the verdict into
    /// metrics, trace events, and ticket resolution, so both planes
    /// report admission identically.
    pub(crate) fn admit(&self, class: QosClass, queued: QueuedRequest)
                        -> Admit {
        let lanes = &self.shared.lanes[class.index()];
        let drop_oldest = self.knobs[class.index()].drop_oldest;
        let displaced = {
            let mut st = lanes.state.lock().unwrap();
            if st.closed {
                return Admit::Closed;
            }
            let mut displaced = None;
            if st.sched.len() >= lanes.depth {
                if drop_oldest {
                    displaced =
                        st.sched.displace(queued.sensor_id).map(|(_, r)| r);
                    if displaced.is_none() {
                        return Admit::Full; // depth 0 lanes (can't happen)
                    }
                } else {
                    return Admit::Full;
                }
            }
            st.sched.push(queued.sensor_id, queued);
            displaced
        };
        lanes.notify.notify();
        match displaced {
            Some(r) => Admit::AcceptedDisplacing(r),
            None => Admit::Accepted,
        }
    }

    /// The class's admission depth (for the rejection message — same
    /// number the threaded queue reports as its capacity).
    pub(crate) fn depth(&self, class: QosClass) -> usize {
        self.shared.lanes[class.index()].depth
    }

    pub(crate) fn stats(&self) -> AsyncStats {
        AsyncStats {
            workers: self.workers,
            min_shards: self.min_shards,
            max_shards: self.max_shards,
            active_shards: self.shared.scale.active.load(Ordering::Acquire),
            shards_high_water: self
                .shared
                .scale
                .high_water
                .load(Ordering::Relaxed),
            scale_up_events: self
                .shared
                .scale
                .up_events
                .load(Ordering::Relaxed),
            scale_down_events: self
                .shared
                .scale
                .down_events
                .load(Ordering::Relaxed),
        }
    }

    /// Test hook: force the active shard count (counted as a scale
    /// event, like an autoscaler decision).
    #[cfg(test)]
    fn force_scale(&self, n: usize) {
        let n = n.clamp(self.min_shards, self.max_shards);
        let active = self.shared.scale.active.load(Ordering::Acquire);
        if n > active {
            self.shared.scale.up_events.fetch_add(1, Ordering::Relaxed);
        } else if n < active {
            self.shared.scale.down_events.fetch_add(1, Ordering::Relaxed);
        }
        self.shared.scale.set_active(n);
    }

    /// Graceful drain: close every class's lanes, then wait for the
    /// task cascade (schedulers flush and finish → the last one closes
    /// the batch queue → dispatch tasks drain it and finish → the
    /// autoscaler observes the closure).  A panicked task is reported
    /// instead of deadlocking the join.
    pub(crate) fn drain(&mut self) -> Result<()> {
        for l in &self.shared.lanes {
            l.close();
        }
        let Some(exec) = self.executor.take() else { return Ok(()) };
        while exec.live() > 0 {
            if exec.panicked() > 0 {
                // dropping force-stops the worker threads
                return Err(Error::Serve(
                    "async serve task panicked".into(),
                ));
            }
            // broadcast wake: tasks parked on long timers (the
            // autoscaler between samples) re-poll and observe their
            // sources' closed state instead of sleeping the tick out
            exec.wake_all();
            std::thread::sleep(Duration::from_micros(200));
        }
        let panicked = exec.panicked();
        exec.join();
        if panicked > 0 {
            return Err(Error::Serve("async serve task panicked".into()));
        }
        Ok(())
    }
}

impl Drop for AsyncPlane {
    /// Dropping without drain still closes the lanes (pending tickets
    /// may stay unresolved, same contract as the threaded plane);
    /// dropping the executor force-stops its threads.
    fn drop(&mut self) {
        for l in &self.shared.lanes {
            l.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ArchSim;
    use crate::params::synth::synth_params;
    use crate::sensor::Frame;
    use crate::serve::{InferResponse, Request, Server, Ticket};

    fn async_config(min: usize, max: usize) -> EngineConfig {
        let mut config = EngineConfig {
            arch: ArchSim { lbp: false, mlp: false, early_exit: false },
            ..Default::default()
        };
        config.system.serve.max_batch = 4;
        config.system.serve.batch_deadline_us = 500;
        config.system.serve.async_plane.enabled = true;
        config.system.serve.async_plane.workers = 2;
        config.system.serve.async_plane.min_shards = min;
        config.system.serve.async_plane.max_shards = max;
        // dormant sampler: tests drive scale changes explicitly via
        // force_scale, so organic autoscaling cannot race assertions
        // (drain's wake_all broadcast still retires the task promptly)
        config.system.serve.async_plane.scale_interval_us = 3_600_000_000;
        config
    }

    fn frames(n: usize, seed: u64) -> (crate::params::NetParams, Vec<Frame>) {
        let (_, params) = synth_params(5);
        let frames = crate::testing::synth_frames(&params, n, seed).unwrap();
        (params, frames)
    }

    #[test]
    fn async_round_trip_and_drain() {
        let (params, fs) = frames(10, 3);
        let server = Server::start(params, async_config(1, 2)).unwrap();
        let tickets: Vec<Ticket> = fs
            .into_iter()
            .map(|f| server.submit(Request::from_frame(f)).unwrap())
            .collect();
        let mut responses: Vec<InferResponse> =
            tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        responses.sort_by_key(|r| r.seq());
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.seq(), i as u64);
            assert!(r.predicted() < 10);
            assert!(r.shard < 2);
        }
        let stats = server.async_stats().expect("async plane active");
        assert_eq!(stats.min_shards, 1);
        assert_eq!(stats.max_shards, 2);
        let report = server.drain().unwrap();
        assert_eq!(report.accepted, 10);
        assert_eq!(report.completed, 10);
        assert_eq!(report.failed, 0);
        assert_eq!(report.dropped, 0);
    }

    #[test]
    fn autoscale_up_then_down_loses_no_frames() {
        let (params, fs) = frames(24, 7);
        let server = Server::start(params, async_config(1, 3)).unwrap();
        let plane = server.async_plane.as_ref().unwrap();
        assert_eq!(plane.stats().active_shards, 1);

        let mut tickets = Vec::new();
        // wave 1 on one shard
        for f in &fs[..8] {
            tickets.push(server.submit(Request::from_frame(f.clone()))
                .unwrap());
        }
        // grow mid-traffic, then submit into the wider pool
        plane.force_scale(3);
        for f in &fs[8..16] {
            tickets.push(server.submit(Request::from_frame(f.clone()))
                .unwrap());
        }
        // shrink mid-traffic, then submit into the narrower pool
        plane.force_scale(1);
        for f in &fs[16..] {
            tickets.push(server.submit(Request::from_frame(f.clone()))
                .unwrap());
        }
        for t in tickets {
            assert!(t.wait().is_ok(), "no frame may be lost across scaling");
        }
        let stats = plane.stats();
        assert!(stats.scale_up_events >= 1);
        assert!(stats.scale_down_events >= 1);
        assert_eq!(stats.shards_high_water, 3);
        assert_eq!(stats.active_shards, 1);
        let report = server.drain().unwrap();
        assert_eq!(report.completed, 24);
        assert_eq!(report.dropped, 0);
        assert_eq!(report.failed, 0);
    }

    #[test]
    fn autoscale_decision_grows_under_load_and_shrinks_with_hysteresis() {
        // depth >= up_depth * active grows (clamped at max)
        assert_eq!(autoscale_decision(2, 1, 0, 1, 4, 2, 3), (2, 0));
        assert_eq!(autoscale_decision(8, 4, 0, 1, 4, 2, 3), (4, 0));
        // shallow backlog holds steady and clears the idle streak
        assert_eq!(autoscale_decision(1, 2, 2, 1, 4, 2, 3), (2, 0));
        // idle samples accumulate; only the down_idle-th shrinks
        assert_eq!(autoscale_decision(0, 2, 0, 1, 4, 2, 3), (2, 1));
        assert_eq!(autoscale_decision(0, 2, 1, 1, 4, 2, 3), (2, 2));
        assert_eq!(autoscale_decision(0, 2, 2, 1, 4, 2, 3), (1, 0));
        // never below min
        assert_eq!(autoscale_decision(0, 1, 9, 1, 4, 2, 3), (1, 10));
        // empty-queue growth edge: active 1 with any backlog >= 1 * up
        assert_eq!(autoscale_decision(0, 1, 0, 1, 4, 1, 3), (1, 1));
    }

    #[test]
    fn admission_depth_rejects_or_displaces_per_class_policy() {
        let (params, fs) = frames(6, 9);
        let mut config = async_config(1, 1);
        // tiny per-class depths to hit both admission policies fast
        config.system.serve.classes
            [QosClass::Billed.index()].queue_depth = Some(1);
        config.system.serve.classes
            [QosClass::BestEffort.index()].queue_depth = Some(1);
        let server = Server::start(params, config).unwrap();

        // billed rejects-newest at depth: submit a burst and count both
        // outcomes (dispatch may drain between submits, so rejection is
        // possible, not guaranteed — but accounting must balance)
        let mut accepted = 0u64;
        let mut rejected = 0u64;
        for f in &fs {
            match server.submit(
                Request::builder(f.clone()).class(QosClass::Billed).build(),
            ) {
                Ok(t) => {
                    accepted += 1;
                    drop(t);
                }
                Err(e) => {
                    rejected += 1;
                    assert!(e.to_string().contains("depth 1"), "{e}");
                }
            }
        }
        // best-effort displaces its own oldest instead of rejecting
        let mut tickets = Vec::new();
        for f in &fs {
            tickets.push(
                server
                    .submit(Request::builder(f.clone())
                        .class(QosClass::BestEffort)
                        .build())
                    .expect("drop-oldest admission never rejects"),
            );
        }
        let mut displaced = 0u64;
        let mut done = 0u64;
        for t in tickets {
            match t.wait() {
                Ok(_) => done += 1,
                Err(Error::Dropped(msg)) => {
                    displaced += 1;
                    assert!(msg.contains("displaced"), "{msg}");
                }
                Err(e) => panic!("unexpected best-effort failure: {e}"),
            }
        }
        assert_eq!(done + displaced, fs.len() as u64);
        let report = server.drain().unwrap();
        assert_eq!(report.accepted,
                   accepted + fs.len() as u64);
        assert_eq!(report.rejected, rejected);
        assert_eq!(report.dropped, displaced);
        assert_eq!(report.failed, 0);
    }
}
