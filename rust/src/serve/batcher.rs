//! Dynamic batch formation: size- and deadline-triggered.
//!
//! The paper's cache earns its throughput by spreading one batch across
//! many compute sub-arrays, so the serving layer wants batches as large
//! as possible — but an always-on sensor pipeline cannot hold a lone
//! frame hostage waiting for peers.  [`Batcher::next_batch`] therefore
//! ships a batch when either trigger fires:
//!
//! * **size** — `max_batch` requests have accumulated, or
//! * **deadline** — `max_delay` has elapsed since the *first* request of
//!   the forming batch arrived (partial batches ship at the deadline).

use std::time::Instant;

use super::queue::{BoundedQueue, PopResult};

/// Why [`Batcher::next_batch_tagged`] sealed a batch — the "size vs
/// deadline" distinction the trace feed records per batch, so a queue
/// that only ever deadline-flushes undersized batches is visible.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// `max_batch` requests accumulated.
    Size,
    /// `max_delay` elapsed since the first request's anchor.
    Deadline,
    /// The request queue closed (drain): the partial batch ships.
    Closed,
}

impl FlushReason {
    pub fn as_str(self) -> &'static str {
        match self {
            FlushReason::Size => "size",
            FlushReason::Deadline => "deadline",
            FlushReason::Closed => "closed",
        }
    }
}

/// When a forming batch must ship.  The server builds one per QoS class
/// from the class's resolved knobs ([`crate::config::ServeConfig::class_knobs`]),
/// so there is deliberately no constructor from the class-independent
/// `[serve]` defaults.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_delay: std::time::Duration,
}

/// Default deadline anchor: the moment the batcher popped the item.
fn pop_time_anchor<T>(_: &T) -> Instant {
    Instant::now()
}

/// Pulls items off a request queue and groups them into batches.  The
/// deadline anchor is any `Fn(&T) -> Instant` (not just a fn pointer), so
/// request-carrying types can anchor on an embedded enqueue timestamp and
/// callers can capture state in the closure.
pub struct Batcher<'q, T, A = fn(&T) -> Instant>
where
    A: Fn(&T) -> Instant,
{
    queue: &'q BoundedQueue<T>,
    policy: BatchPolicy,
    anchor: A,
}

impl<'q, T> Batcher<'q, T> {
    pub fn new(queue: &'q BoundedQueue<T>, policy: BatchPolicy) -> Self {
        Self { queue, policy, anchor: pop_time_anchor::<T> }
    }
}

impl<'q, T, A> Batcher<'q, T, A>
where
    A: Fn(&T) -> Instant,
{
    /// Anchor the deadline to a timestamp carried by the item (its
    /// enqueue time) instead of the pop time, so `max_delay` bounds the
    /// item's *total* staleness: a request that already sat in the queue
    /// past its deadline ships immediately with whatever backlog is on
    /// hand, rather than waiting another full `max_delay`.
    pub fn with_anchor<B>(self, anchor: B) -> Batcher<'q, T, B>
    where
        B: Fn(&T) -> Instant,
    {
        Batcher { queue: self.queue, policy: self.policy, anchor }
    }

    /// Block for the next batch; `None` once the queue is closed and
    /// drained.  Never returns an empty batch.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        self.next_batch_tagged().map(|(batch, _)| batch)
    }

    /// [`Batcher::next_batch`] plus the [`FlushReason`] that sealed the
    /// batch (the trace feed's size-vs-deadline attribution).
    pub fn next_batch_tagged(&self) -> Option<(Vec<T>, FlushReason)> {
        let first = self.queue.pop()?;
        let deadline = (self.anchor)(&first) + self.policy.max_delay;
        let mut batch = Vec::with_capacity(self.policy.max_batch);
        batch.push(first);
        let mut reason = FlushReason::Size;
        while batch.len() < self.policy.max_batch {
            // past the deadline this is a zero-wait poll: it drains the
            // already-queued backlog into the batch but never waits
            let wait = deadline.saturating_duration_since(Instant::now());
            match self.queue.pop_timeout(wait) {
                PopResult::Item(item) => batch.push(item),
                // deadline flush: ship what we have
                PopResult::TimedOut => {
                    reason = FlushReason::Deadline;
                    break;
                }
                // drain: ship the partial batch; the next call returns None
                PopResult::Closed => {
                    reason = FlushReason::Closed;
                    break;
                }
            }
        }
        Some((batch, reason))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn size_trigger_ships_full_batches() {
        let q = BoundedQueue::new(16);
        for i in 0..7u32 {
            q.try_push(i).unwrap();
        }
        let b = Batcher::new(&q, BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_secs(10),
        });
        // full batch ships immediately — the long deadline never engages
        let t0 = Instant::now();
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn deadline_trigger_ships_partial_batch() {
        let q = BoundedQueue::new(16);
        q.try_push(42u32).unwrap();
        let delay = Duration::from_millis(25);
        let b = Batcher::new(&q, BatchPolicy { max_batch: 8, max_delay: delay });
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch, vec![42]);
        // shipped at (not far past, not before) the deadline
        assert!(waited >= Duration::from_millis(20), "waited {waited:?}");
        assert!(waited < Duration::from_millis(500), "waited {waited:?}");
    }

    #[test]
    fn enqueue_anchor_ships_stale_backlog_without_waiting() {
        // items carry their own enqueue timestamps, already past deadline
        let q: BoundedQueue<Instant> = BoundedQueue::new(16);
        let stale = Instant::now() - Duration::from_millis(50);
        q.try_push(stale).unwrap();
        q.try_push(stale).unwrap();
        q.try_push(stale).unwrap();
        let b = Batcher::new(&q, BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(10),
        })
        .with_anchor(|t: &Instant| *t);
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        // the whole backlog ships at once, with zero additional delay
        assert_eq!(batch.len(), 3);
        assert!(t0.elapsed() < Duration::from_millis(10),
                "waited a fresh deadline for already-stale items");
    }

    #[test]
    fn capturing_closure_anchor_is_accepted() {
        // items carry an *offset* from a base instant captured by the
        // closure — impossible with a plain fn pointer anchor
        let q: BoundedQueue<u64> = BoundedQueue::new(16);
        let base = Instant::now() - Duration::from_millis(100);
        q.try_push(50).unwrap(); // enqueued 50 ms after base: stale
        q.try_push(60).unwrap();
        let b = Batcher::new(&q, BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(10),
        })
        .with_anchor(move |offset_ms: &u64| {
            base + Duration::from_millis(*offset_ms)
        });
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![50, 60]);
        assert!(t0.elapsed() < Duration::from_millis(10),
                "stale items must ship without a fresh deadline");
    }

    #[test]
    fn close_flushes_partial_batch_then_ends() {
        let q = Arc::new(BoundedQueue::new(16));
        q.try_push(1u32).unwrap();
        q.try_push(2).unwrap();
        q.close();
        let b = Batcher::new(&q, BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_secs(10),
        });
        assert_eq!(b.next_batch().unwrap(), vec![1, 2]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn flush_reasons_distinguish_size_deadline_and_close() {
        let q = BoundedQueue::new(16);
        for i in 0..4u32 {
            q.try_push(i).unwrap();
        }
        let b = Batcher::new(&q, BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_millis(20),
        });
        let (batch, reason) = b.next_batch_tagged().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(reason, FlushReason::Size);
        // one straggler: deadline flush
        q.try_push(9).unwrap();
        let (batch, reason) = b.next_batch_tagged().unwrap();
        assert_eq!(batch, vec![9]);
        assert_eq!(reason, FlushReason::Deadline);
        // close mid-formation: partial batch tagged Closed
        q.try_push(10).unwrap();
        q.close();
        let (batch, reason) = b.next_batch_tagged().unwrap();
        assert_eq!(batch, vec![10]);
        assert_eq!(reason, FlushReason::Closed);
        assert!(b.next_batch_tagged().is_none());
    }

    #[test]
    fn late_arrivals_join_the_forming_batch() {
        let q = Arc::new(BoundedQueue::new(16));
        q.try_push(1u32).unwrap();
        let q2 = Arc::clone(&q);
        let feeder = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            q2.try_push(2).unwrap();
        });
        let b = Batcher::new(&q, BatchPolicy {
            max_batch: 2,
            max_delay: Duration::from_millis(250),
        });
        assert_eq!(b.next_batch().unwrap(), vec![1, 2]);
        feeder.join().unwrap();
    }
}
