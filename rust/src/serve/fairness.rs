//! Deficit-round-robin (DRR) fairness across sensors within one QoS
//! class.
//!
//! The threaded serve plane admits FIFO within a class, so one hot
//! camera that submits faster than its classmates monopolizes every
//! batch.  The async plane keeps a *per-sensor lane* instead and drains
//! lanes deficit-round-robin: each backlogged lane earns `quantum`
//! frames of credit when the ring cursor reaches it and is served until
//! the credit runs out, so over any backlog window every backlogged
//! sensor completes within `quantum` frames of every other — a hot
//! sensor only ever eats its classmates' *idle* capacity, never their
//! turn.
//!
//! The scheduler is deliberately payload-generic (`DrrScheduler<T>`)
//! so the fairness property is provable on plain integers in the
//! property tests below; the serve plane instantiates it with its
//! queued requests.  Frames within one lane stay strictly FIFO — DRR
//! reorders *across* sensors only, never within a stream.

use std::collections::{BTreeMap, VecDeque};

/// One sensor's lane: its FIFO backlog plus its DRR credit state.
struct Lane<T> {
    queue: VecDeque<T>,
    /// Frames this lane may still pop in the current ring visit.
    deficit: u32,
    /// Whether the current visit's quantum was already granted.
    granted: bool,
    /// Whether the lane currently occupies a ring slot (lazily cleared
    /// when the ring cursor finds it empty).
    in_ring: bool,
}

impl<T> Default for Lane<T> {
    fn default() -> Self {
        Self {
            queue: VecDeque::new(),
            deficit: 0,
            granted: false,
            in_ring: false,
        }
    }
}

/// Deficit-round-robin scheduler over per-sensor FIFO lanes.
pub struct DrrScheduler<T> {
    lanes: BTreeMap<u32, Lane<T>>,
    /// Ring of lane ids; the front is the lane being served.  May hold
    /// stale (emptied) entries, removed lazily by [`DrrScheduler::pop`].
    ring: VecDeque<u32>,
    quantum: u32,
    total: usize,
}

impl<T> DrrScheduler<T> {
    /// A scheduler granting `quantum` frames per lane visit (min 1).
    pub fn new(quantum: u32) -> Self {
        Self {
            lanes: BTreeMap::new(),
            ring: VecDeque::new(),
            quantum: quantum.max(1),
            total: 0,
        }
    }

    /// Total queued items across all lanes.
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Enqueue `item` at the tail of `sensor`'s lane; a newly backlogged
    /// lane joins the ring at the tail (it is served *after* everyone
    /// already waiting — arriving hot buys no priority).
    pub fn push(&mut self, sensor: u32, item: T) {
        let lane = self.lanes.entry(sensor).or_default();
        lane.queue.push_back(item);
        self.total += 1;
        if !lane.in_ring {
            lane.in_ring = true;
            self.ring.push_back(sensor);
        }
    }

    /// Dequeue the next item under DRR order, with the lane it came from.
    pub fn pop(&mut self) -> Option<(u32, T)> {
        loop {
            let sid = *self.ring.front()?;
            let lane = self.lanes.get_mut(&sid).expect("ring id without lane");
            if lane.queue.is_empty() {
                // stale ring slot (displaced empty, or emptied earlier)
                lane.in_ring = false;
                lane.deficit = 0;
                lane.granted = false;
                self.ring.pop_front();
                continue;
            }
            if !lane.granted {
                lane.granted = true;
                lane.deficit = lane.deficit.saturating_add(self.quantum);
            }
            if lane.deficit == 0 {
                // visit's credit spent while still backlogged: move to
                // the ring tail and let the next lane have its turn
                lane.granted = false;
                self.ring.rotate_left(1);
                continue;
            }
            lane.deficit -= 1;
            self.total -= 1;
            let item = lane.queue.pop_front().expect("non-empty lane");
            if lane.queue.is_empty() {
                // idle lanes bank no credit (classic DRR reset)
                lane.in_ring = false;
                lane.deficit = 0;
                lane.granted = false;
                self.ring.pop_front();
            }
            return Some((sid, item));
        }
    }

    /// Drop-oldest admission support: remove and return the item a fresh
    /// frame should displace — the submitting sensor's own oldest frame
    /// when it has one (a hot sensor sheds *its own* stale pixels), else
    /// the oldest frame of the lane at the ring cursor.
    pub fn displace(&mut self, sensor: u32) -> Option<(u32, T)> {
        if let Some(item) = self.displace_from(sensor) {
            return Some((sensor, item));
        }
        loop {
            let sid = *self.ring.front()?;
            match self.displace_from(sid) {
                Some(item) => return Some((sid, item)),
                None => {
                    // stale slot: clear and keep looking
                    if let Some(lane) = self.lanes.get_mut(&sid) {
                        lane.in_ring = false;
                        lane.deficit = 0;
                        lane.granted = false;
                    }
                    self.ring.pop_front();
                }
            }
        }
    }

    fn displace_from(&mut self, sensor: u32) -> Option<T> {
        let lane = self.lanes.get_mut(&sensor)?;
        let item = lane.queue.pop_front()?;
        self.total -= 1;
        if lane.queue.is_empty() {
            // leave the ring slot for lazy removal; credit resets now
            lane.deficit = 0;
            lane.granted = false;
        }
        Some(item)
    }

    /// Lanes currently holding at least one frame.
    pub fn backlogged(&self) -> usize {
        self.lanes.values().filter(|l| !l.queue.is_empty()).count()
    }

    /// Visit each queued item in lane order (oldest first within a
    /// lane) — the drain path when a class shuts down.
    pub fn drain(&mut self) -> Vec<(u32, T)> {
        let mut out = Vec::with_capacity(self.total);
        while let Some(pair) = self.pop() {
            out.push(pair);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Config};
    use std::collections::BTreeMap;

    #[test]
    fn single_lane_is_plain_fifo() {
        let mut s = DrrScheduler::new(2);
        for i in 0..10 {
            s.push(7, i);
        }
        let popped: Vec<i32> = std::iter::from_fn(|| s.pop())
            .map(|(sid, v)| {
                assert_eq!(sid, 7);
                v
            })
            .collect();
        assert_eq!(popped, (0..10).collect::<Vec<_>>());
        assert!(s.is_empty());
    }

    #[test]
    fn quantum_interleaves_backlogged_lanes() {
        let mut s = DrrScheduler::new(2);
        for i in 0..6 {
            s.push(0, ("a", i));
            s.push(1, ("b", i));
        }
        let order: Vec<&str> =
            std::iter::from_fn(|| s.pop()).map(|(_, (t, _))| t).collect();
        // quantum 2: a a b b a a b b ...
        assert_eq!(order, vec!["a", "a", "b", "b", "a", "a", "b", "b",
                               "a", "a", "b", "b"]);
    }

    #[test]
    fn displace_prefers_own_lane_then_ring_cursor() {
        let mut s = DrrScheduler::new(1);
        s.push(0, "old0");
        s.push(1, "old1");
        // sensor 0 has a frame: its own oldest is displaced
        assert_eq!(s.displace(0), Some((0, "old0")));
        // sensor 0's lane is now empty: displacement falls to the ring
        // cursor (sensor 0's stale slot is skipped)
        assert_eq!(s.displace(0), Some((1, "old1")));
        assert_eq!(s.displace(0), None);
        assert!(s.is_empty());
        // the scheduler still works after displacement emptied it
        s.push(2, "fresh");
        assert_eq!(s.pop(), Some((2, "fresh")));
    }

    /// DRR's defining property: among lanes that are all still
    /// backlogged, served counts never spread further than one quantum —
    /// regardless of how skewed the per-lane backlogs are.
    #[test]
    fn prop_backlogged_spread_is_bounded_by_quantum() {
        check(Config::default().cases(64),
              "DRR spread <= quantum under skewed backlogs", |g| {
            let quantum = g.usize_in(1, 5) as u32;
            let sensors = g.usize_in(2, 8) as u32;
            // skewed arrival totals: lane i gets 1..=80 frames, with one
            // deliberately hot lane an order of magnitude above the rest
            let hot = g.u32_below(sensors);
            let mut s = DrrScheduler::new(quantum);
            let mut pushed: BTreeMap<u32, u64> = BTreeMap::new();
            for sid in 0..sensors {
                let n = if sid == hot {
                    g.usize_in(200, 400)
                } else {
                    g.usize_in(1, 80)
                };
                for i in 0..n {
                    s.push(sid, (sid, i));
                }
                pushed.insert(sid, n as u64);
            }
            let mut served: BTreeMap<u32, u64> = BTreeMap::new();
            let mut next_expected: BTreeMap<u32, usize> = BTreeMap::new();
            while let Some((sid, (from, idx))) = s.pop() {
                assert_eq!(sid, from, "lane tag mismatch");
                // per-lane FIFO: items surface in push order
                let want = next_expected.entry(sid).or_insert(0);
                assert_eq!(idx, *want, "lane {sid} reordered");
                *want += 1;
                *served.entry(sid).or_insert(0) += 1;
                // fairness: any two lanes still backlogged after this
                // pop have served counts within one quantum
                let backlogged: Vec<u64> = (0..sensors)
                    .filter(|sid| {
                        served.get(sid).copied().unwrap_or(0)
                            < pushed[sid]
                    })
                    .map(|sid| served.get(&sid).copied().unwrap_or(0))
                    .collect();
                if let (Some(&min), Some(&max)) =
                    (backlogged.iter().min(), backlogged.iter().max())
                {
                    assert!(
                        max - min <= quantum as u64,
                        "spread {} > quantum {quantum} \
                         (served {served:?}, pushed {pushed:?})",
                        max - min
                    );
                }
            }
            // conservation: everything pushed was popped exactly once
            assert_eq!(served, pushed);
            assert!(s.is_empty());
        });
    }

    /// No starvation under live skewed arrivals: while a slow lane has a
    /// frame queued, it waits at most one full ring revolution
    /// (`lanes * quantum` pops) before one of its frames surfaces.
    #[test]
    fn prop_no_starvation_under_skewed_arrival_rates() {
        check(Config::default().cases(48),
              "DRR bounds a backlogged lane's wait to one revolution",
              |g| {
            let quantum = g.usize_in(1, 4) as u32;
            let sensors = g.usize_in(2, 6) as u32;
            let hot = g.u32_below(sensors);
            let mut s: DrrScheduler<u32> = DrrScheduler::new(quantum);
            let mut pops_since: BTreeMap<u32, u64> = BTreeMap::new();
            let bound = (sensors * quantum) as u64;
            for step in 0..600u32 {
                // skewed arrivals: the hot lane pushes every step, the
                // others roughly once per `sensors` steps
                s.push(hot, step);
                let slow = step % sensors;
                if slow != hot {
                    s.push(slow, step);
                }
                // drain slower than the hot lane offers, so a backlog
                // actually forms and fairness is exercised
                if let Some((sid, _)) = s.pop() {
                    for (other, waited) in pops_since.iter_mut() {
                        if *other != sid {
                            *waited += 1;
                            assert!(
                                *waited <= bound,
                                "lane {other} starved for {waited} pops \
                                 (bound {bound})"
                            );
                        }
                    }
                    pops_since.insert(sid, 0);
                }
                // only lanes that are actually backlogged are held to
                // the bound: forget lanes that drained
                pops_since.retain(|sid, _| {
                    s.lanes
                        .get(sid)
                        .map(|l| !l.queue.is_empty())
                        .unwrap_or(false)
                });
                for sid in 0..sensors {
                    if s.lanes
                        .get(&sid)
                        .map(|l| !l.queue.is_empty())
                        .unwrap_or(false)
                    {
                        pops_since.entry(sid).or_insert(0);
                    }
                }
            }
        });
    }
}
