//! `serve` — the sharded, batching, QoS-aware request-serving layer on
//! top of the NS-LBP inference engine.
//!
//! The seed coordinator is a one-shot, run-to-completion loop; the paper
//! (and the PISA/LBPNet line of work it extends) frames the accelerator
//! as an *always-on* edge inference engine fed by continuous sensor
//! streams — streams whose pixels do not all deserve the same treatment.
//! The unit of work here is therefore a typed [`Request`] (frame +
//! sensor id + [`QosClass`] + optional deadline), not a bare frame.
//!
//! # Request lifecycle
//!
//! ```text
//!  Session::submit / Server::submit            (build: RequestBuilder)
//!        │  1. SUBMIT — stamp the per-sensor seq, pick the class
//!        ▼
//!  per-class BoundedQueue                      (admission control)
//!        │  2. ADMIT — reject past queue_depth, or displace the oldest
//!        │     queued frame for drop-oldest classes (fresh sensor data
//!        │     beats stale); rejected/dropped tickets resolve to Err
//!        ▼
//!  per-class Batcher thread                    (batch formation)
//!        │  3. BATCH — ship at the class's max_batch, or at the class's
//!        │     deadline_us measured from the oldest request's enqueue
//!        │     time; a batch never mixes classes
//!        ▼
//!  BoundedQueue<Batch> ──► ShardPool           (routing + dispatch)
//!        │  4. ROUTE — the batch carries the backend its class resolves
//!        │     to (engine::RoutingPolicy, `[engine.routing]`/--route);
//!        │     every shard hosts one engine per routed backend, pinned
//!        │     to the shard's disjoint bank slice
//!        │  5. INFER — one Engine::infer_batch call per batch (the
//!        │     batch-aware backends amortize compute across it);
//!        │     requests whose per-request deadline lapsed in the queue
//!        │     are shed, not inferred
//!        ▼
//!  Ticket                                      (completion)
//!           6. TICKET — wait() / wait_timeout() / try_take() resolve to
//!              an InferResponse carrying the frame's output, sensor id,
//!              class, backend, shard, and queue→response latency;
//!              Metrics records it all per class (p50/p95/p99,
//!              drop/reject counts) for the final MetricsReport
//! ```
//!
//! * [`queue`] — bounded MPMC queue; full ⇒ reject-with-error (or
//!   displace-oldest), closed ⇒ drain semantics.
//! * [`batcher`] — dynamic batching, size- or deadline-triggered, with a
//!   pluggable `Fn(&T) -> Instant` deadline anchor.
//! * [`shard`] — worker pool; whole-batch dispatch to per-shard,
//!   per-backend [`crate::engine::Engine`]s over disjoint bank slices
//!   ([`crate::engine::ShardSlice`]).  Sharding never changes logits —
//!   only which banks (and therefore whose modeled time budget) do the
//!   work; `rust/tests/serve.rs` proves 1-shard vs 4-shard equivalence.
//! * [`metrics`] — per-class accepted/rejected/dropped counters,
//!   p50/p95/p99 latency, throughput, and the energy-per-frame account.
//!
//! Shutdown is a graceful drain: [`Server::drain`] stops admission,
//! flushes every class queue through its batcher, lets every shard
//! finish its in-flight batches, then returns the final
//! [`MetricsReport`].  Knobs live in `[serve]` (global) and
//! `[serve.best_effort]` / `[serve.standard]` / `[serve.billed]`
//! (per class) of the system config ([`crate::config::ServeConfig`]);
//! `ns-lbp serve-bench` exercises the whole stack from the CLI.
//!
//! # The async plane
//!
//! With `[serve.async] enabled = true` the same lifecycle runs on the
//! event-driven plane instead of dedicated threads: admission lands in
//! per-sensor deficit-round-robin lanes ([`fairness`]), batch formation
//! and shard dispatch are cooperative tasks on a small
//! [`crate::exec::Executor`] pool ([`async_plane`]), and the active
//! shard count follows offered load between `min_shards` and
//! `max_shards`.  Admission errors, trace spans, metrics, and — because
//! sharding never changes logits — the outputs themselves are identical
//! across the two planes; only the concurrency substrate differs.

pub mod async_plane;
pub mod batcher;
pub mod fairness;
pub mod metrics;
pub mod queue;
pub mod shard;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::engine::{BackendKind, EngineConfig, FrameOutput, Prepacked};
use crate::error::{Error, Result};
use crate::obs::{EventKind, TraceEvent, TraceSession, Tracer};
use crate::params::NetParams;
use crate::sensor::Frame;

pub use crate::engine::QosClass;
pub use async_plane::AsyncStats;
pub use batcher::{BatchPolicy, Batcher, FlushReason};
pub use fairness::DrrScheduler;
pub use metrics::{percentile_ns, ClassReport, Metrics, MetricsReport,
                  ModelReport};
pub use queue::{BoundedQueue, PopResult, PushError};
pub use shard::{Batch, ShardPool};

/// One servable model held by a [`Server`]: the parsed parameters every
/// submission is validated against, plus — for models registered from a
/// compiled artifact ([`Server::push_model`]) — the prepacked tables
/// engines build from.  Entries are shared via `Arc`: every admitted
/// request pins the entry it validated against, so replacing a model
/// mid-stream never drops in-flight frames.
pub struct ModelEntry {
    /// The artifact's content-hash version (0 = the from-params default
    /// model the server started with).
    pub(crate) version: u64,
    pub(crate) params: Arc<NetParams>,
    pub(crate) prepacked: Option<Arc<Prepacked>>,
}

impl ModelEntry {
    /// Artifact content-hash version (0 for the from-params default).
    pub fn version(&self) -> u64 {
        self.version
    }
}

/// A typed, routable inference request — the serving layer's unit of
/// work.  Build one with [`Request::builder`] (or [`Request::from_frame`]
/// for the all-defaults shim), or let a [`Session`] stamp the sensor id
/// and per-sensor sequence number for you.
#[derive(Clone, Debug)]
pub struct Request {
    /// The digitized frame payload.
    pub frame: Frame,
    /// Which sensor stream this frame belongs to.
    pub sensor_id: u32,
    /// Service class: routing key, batching key, admission policy.
    pub class: QosClass,
    /// Which registered model should serve the frame (0 = the server's
    /// from-params default; others come from [`Server::push_model`]).
    pub model_id: u32,
    /// Optional freshness bound: if the request is still queued this
    /// long after submission, it is shed instead of inferred.
    pub deadline: Option<Duration>,
}

impl Request {
    /// Start building a request around `frame`.
    pub fn builder(frame: Frame) -> RequestBuilder {
        RequestBuilder { request: Request::from_frame(frame) }
    }

    /// All-defaults request: sensor 0, [`QosClass::Standard`], no
    /// deadline — the thin shim over the old frame-only submit path.
    pub fn from_frame(frame: Frame) -> Request {
        Request {
            frame,
            sensor_id: 0,
            class: QosClass::default(),
            model_id: 0,
            deadline: None,
        }
    }
}

/// Builder for [`Request`].
#[derive(Clone, Debug)]
pub struct RequestBuilder {
    request: Request,
}

impl RequestBuilder {
    pub fn sensor_id(mut self, sensor_id: u32) -> Self {
        self.request.sensor_id = sensor_id;
        self
    }

    pub fn class(mut self, class: QosClass) -> Self {
        self.request.class = class;
        self
    }

    pub fn model(mut self, model_id: u32) -> Self {
        self.request.model_id = model_id;
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.request.deadline = Some(deadline);
        self
    }

    pub fn build(self) -> Request {
        self.request
    }
}

/// One admitted request flowing through the pipeline (internal form:
/// payload + admission timestamp + completion slot).
pub(crate) struct QueuedRequest {
    pub(crate) frame: Frame,
    pub(crate) sensor_id: u32,
    pub(crate) model_id: u32,
    /// The entry the frame was validated against at admission, pinned
    /// for the request's whole lifetime (see [`ModelEntry`]).
    pub(crate) model: Arc<ModelEntry>,
    pub(crate) deadline: Option<Duration>,
    pub(crate) enqueued_at: Instant,
    pub(crate) slot: ResponseSlot,
}

/// A completed inference plus its serving metadata.
#[derive(Clone, Debug)]
pub struct InferResponse {
    /// The engine's full per-frame output (logits, telemetry).
    pub report: FrameOutput,
    /// Which sensor stream the frame came from.
    pub sensor_id: u32,
    /// The request's QoS class.
    pub class: QosClass,
    /// Which registered model served the frame.
    pub model_id: u32,
    /// The backend its (class, model) routed to.
    pub backend: BackendKind,
    /// Which shard processed the frame.
    pub shard: usize,
    /// Size of the dispatch batch this frame rode in.
    pub batch_size: usize,
    /// Queue-entry to completion latency.
    pub latency: Duration,
}

impl InferResponse {
    /// Sequence number within the frame's sensor stream.
    pub fn seq(&self) -> u64 {
        self.report.seq
    }

    pub fn predicted(&self) -> usize {
        self.report.predicted
    }
}

/// One-shot completion slot shared between a [`Ticket`] and the shard
/// that fulfills it.
pub(crate) struct SlotState {
    result: Mutex<Option<Result<InferResponse>>>,
    ready: Condvar,
}

pub(crate) type ResponseSlot = Arc<SlotState>;

impl SlotState {
    fn new() -> Self {
        Self { result: Mutex::new(None), ready: Condvar::new() }
    }

    pub(crate) fn fulfill(&self, r: Result<InferResponse>) {
        *self.result.lock().unwrap() = Some(r);
        self.ready.notify_all();
    }
}

/// Claim check for an admitted request.
pub struct Ticket {
    slot: ResponseSlot,
}

impl Ticket {
    /// Block until the shard pool delivers the response.
    pub fn wait(self) -> Result<InferResponse> {
        let mut g = self.slot.result.lock().unwrap();
        loop {
            if let Some(r) = g.take() {
                return r;
            }
            g = self.slot.ready.wait(g).unwrap();
        }
    }

    /// Block for at most `timeout`; `None` if no response arrived in
    /// time.  The ticket stays usable, so a caller facing a drained or
    /// wedged shard (or a server that was dropped without
    /// [`Server::drain`]) can bound its wait and retry or give up
    /// instead of blocking forever.
    pub fn wait_timeout(&self, timeout: Duration)
                        -> Option<Result<InferResponse>> {
        let deadline = Instant::now() + timeout;
        let g = self.slot.result.lock().unwrap();
        let (_g, r) = queue::wait_deadline(&self.slot.ready, g, deadline,
                                           |res| res.take());
        r
    }

    /// Non-blocking poll; `None` while the frame is still in flight.
    pub fn try_take(&self) -> Option<Result<InferResponse>> {
        self.slot.result.lock().unwrap().take()
    }
}

/// A per-sensor submission handle: owns (a reference into) the sensor's
/// sequence space, so multiple [`crate::sensor::FrameSource`] streams can
/// fan into one [`Server`] without seq collisions — every submitted frame
/// is re-stamped with the next sequence number of *its* sensor.  Two
/// sessions for the same `sensor_id` share one sequence space.
pub struct Session<'s> {
    server: &'s Server,
    sensor_id: u32,
    seq: Arc<AtomicU64>,
    class: QosClass,
    model_id: u32,
    deadline: Option<Duration>,
}

impl<'s> Session<'s> {
    /// Default QoS class for frames submitted through this session.
    pub fn with_class(mut self, class: QosClass) -> Self {
        self.class = class;
        self
    }

    /// Target model for frames submitted through this session
    /// (0 = the server's from-params default).
    pub fn with_model(mut self, model_id: u32) -> Self {
        self.model_id = model_id;
        self
    }

    /// Default per-request deadline for frames submitted through this
    /// session.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn sensor_id(&self) -> u32 {
        self.sensor_id
    }

    /// Submit one frame: stamps the sensor id and the next per-sensor
    /// sequence number, then admits it under the session's class.
    /// (A rejected submission still consumes a sequence number.)
    pub fn submit(&self, frame: Frame) -> Result<Ticket> {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut builder = Request::builder(frame.with_seq(seq))
            .sensor_id(self.sensor_id)
            .class(self.class)
            .model(self.model_id);
        if let Some(d) = self.deadline {
            builder = builder.deadline(d);
        }
        self.server.submit(builder.build())
    }
}

/// The serving front-end: per-class admission queues + per-class batcher
/// threads + a routed shard pool.
pub struct Server {
    class_queues: [Arc<BoundedQueue<QueuedRequest>>; QosClass::COUNT],
    batches: Arc<BoundedQueue<Batch>>,
    metrics: Arc<Metrics>,
    batchers: Vec<std::thread::JoinHandle<()>>,
    pool: Option<ShardPool>,
    /// The event-driven plane, when `[serve.async] enabled = true`; the
    /// thread-per-stage fields above stay idle in that mode.
    async_plane: Option<async_plane::AsyncPlane>,
    started: Instant,
    shards: usize,
    serve: ServeConfig,
    /// The model registry: id → pinned entry.  Id 0 is the from-params
    /// default installed at [`Server::start`]; [`Server::push_model`]
    /// adds or replaces entries while traffic flows.
    models: RwLock<BTreeMap<u32, Arc<ModelEntry>>>,
    sensors: Mutex<BTreeMap<u32, Arc<AtomicU64>>>,
    tracer: Tracer,
    trace: Option<TraceSession>,
}

impl Server {
    /// Spin up the pipeline: `config.system.serve` supplies the knobs,
    /// `config.system.engine` the backend selection and per-class
    /// routing, and the rest of `config` (cache geometry, arch-sim
    /// switches) is inherited by every shard's engines.
    pub fn start(params: NetParams, config: EngineConfig) -> Result<Self> {
        let serve: ServeConfig = config.system.serve;
        serve.validate()?;
        // model 0: the from-params default every server hosts
        let default_model = Arc::new(ModelEntry {
            version: 0,
            params: Arc::new(params),
            prepacked: None,
        });
        let routing = config.system.engine.routing.clone();
        let default_backend = config.system.engine.backend;
        // the distinct backends any class can land on — each shard
        // hosts one engine per entry
        let backends = routing.backend_set(default_backend);

        let class_queues: [Arc<BoundedQueue<QueuedRequest>>;
                           QosClass::COUNT] = std::array::from_fn(|i| {
            Arc::new(BoundedQueue::new(
                serve.class_knobs(QosClass::ALL[i]).queue_depth,
            ))
        });
        // a couple of in-flight batches per shard keeps workers fed
        // without hiding queueing latency inside the dispatch stage
        let batches = Arc::new(BoundedQueue::new(serve.shards * 2));
        let metrics = Arc::new(Metrics::default());

        // the async plane's shared state exists before the trace session
        // so the gauge sampler below can observe its lanes
        let shared = serve
            .async_plane
            .enabled
            .then(|| async_plane::AsyncShared::new(&serve));

        // tracing (off by default): the exporter session owns the ring
        // and the sink files; its sampler observes the live queues
        let trace = if let Some(sh) = &shared {
            let sh = sh.clone();
            let gauge_metrics = Arc::clone(&metrics);
            TraceSession::start(&config.system.obs, move |t| {
                let ts = t.now();
                for class in QosClass::ALL {
                    t.emit(TraceEvent {
                        kind: EventKind::Gauge,
                        ts_ns: ts,
                        class: Some(class),
                        label: "queue_depth",
                        value: sh.lanes[class.index()].len() as f64,
                        ..TraceEvent::default()
                    });
                    t.emit(TraceEvent {
                        kind: EventKind::Gauge,
                        ts_ns: ts,
                        class: Some(class),
                        label: "in_flight",
                        value: gauge_metrics.in_flight(class) as f64,
                        ..TraceEvent::default()
                    });
                }
                t.emit(TraceEvent {
                    kind: EventKind::Gauge,
                    ts_ns: ts,
                    label: "batch_queue_depth",
                    value: sh.batch_depth() as f64,
                    ..TraceEvent::default()
                });
                t.emit(TraceEvent {
                    kind: EventKind::Gauge,
                    ts_ns: ts,
                    label: "active_shards",
                    value: sh.active_shards() as f64,
                    ..TraceEvent::default()
                });
            })?
        } else {
            let queues: Vec<Arc<BoundedQueue<QueuedRequest>>> =
                class_queues.iter().map(Arc::clone).collect();
            let batches_q = Arc::clone(&batches);
            let gauge_metrics = Arc::clone(&metrics);
            TraceSession::start(&config.system.obs, move |t| {
                let ts = t.now();
                for class in QosClass::ALL {
                    t.emit(TraceEvent {
                        kind: EventKind::Gauge,
                        ts_ns: ts,
                        class: Some(class),
                        label: "queue_depth",
                        value: queues[class.index()].len() as f64,
                        ..TraceEvent::default()
                    });
                    t.emit(TraceEvent {
                        kind: EventKind::Gauge,
                        ts_ns: ts,
                        class: Some(class),
                        label: "in_flight",
                        value: gauge_metrics.in_flight(class) as f64,
                        ..TraceEvent::default()
                    });
                }
                t.emit(TraceEvent {
                    kind: EventKind::Gauge,
                    ts_ns: ts,
                    label: "batch_queue_depth",
                    value: batches_q.len() as f64,
                    ..TraceEvent::default()
                });
            })?
        };
        let tracer = trace.tracer();

        if let Some(sh) = shared {
            // event-driven plane: class schedulers, dispatch tasks, and
            // the autoscaler replace the batcher threads and shard pool
            let plane = async_plane::AsyncPlane::start(
                sh, &default_model, &config, &backends, &metrics, &tracer)?;
            return Ok(Self {
                class_queues,
                batches,
                metrics,
                batchers: Vec::new(),
                pool: None,
                async_plane: Some(plane),
                started: Instant::now(),
                shards: serve.shards,
                serve,
                models: RwLock::new(BTreeMap::from([(0u32, default_model)])),
                sensors: Mutex::new(BTreeMap::new()),
                tracer,
                trace: Some(trace),
            });
        }

        // spawn() validates the shard slicing against the cache geometry
        // (and every routed backend's availability) before any batcher
        // thread starts
        let pool = ShardPool::spawn(&default_model, &config, serve.shards,
                                    &backends, &batches, &metrics, &tracer)?;

        // one batcher per class; the last one out closes the batch queue
        let remaining = Arc::new(AtomicUsize::new(QosClass::COUNT));
        let mut batchers = Vec::with_capacity(QosClass::COUNT);
        let mut spawn_err = None;
        for class in QosClass::ALL {
            let knobs = serve.class_knobs(class);
            let policy = BatchPolicy {
                max_batch: knobs.max_batch,
                max_delay: knobs.deadline(),
            };
            let requests = Arc::clone(&class_queues[class.index()]);
            let batches_q = Arc::clone(&batches);
            let remaining = Arc::clone(&remaining);
            let routing = routing.clone();
            let tracer = tracer.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("nslbp-batcher-{class}"))
                .spawn(move || {
                    // deadline anchored to enqueue time: the class
                    // deadline bounds a frame's total queue staleness,
                    // not time-since-pop
                    let b = Batcher::new(&requests, policy)
                        .with_anchor(|r: &QueuedRequest| r.enqueued_at);
                    'form: while let Some((reqs, reason)) =
                        b.next_batch_tagged()
                    {
                        // a dispatch batch must be homogeneous in model
                        // as well as class: engines are per-(model,
                        // backend), so split the formed batch by
                        // (model id, pinned version) preserving order —
                        // single-model traffic stays one batch
                        let mut groups: Vec<(u32, u64, Vec<QueuedRequest>)> =
                            Vec::new();
                        for r in reqs {
                            let key = (r.model_id, r.model.version);
                            match groups
                                .iter_mut()
                                .find(|(m, v, _)| (*m, *v) == key)
                            {
                                Some((_, _, g)) => g.push(r),
                                None => groups.push((key.0, key.1, vec![r])),
                            }
                        }
                        for (model_id, _version, reqs) in groups {
                            let backend = routing.resolve_model(
                                class, model_id, default_backend);
                            let batch_id = tracer.next_batch_id();
                            if tracer.enabled() {
                                // batch seal: close every member's
                                // queue-wait span and record the
                                // formation window with its flush reason
                                let sealed = Instant::now();
                                let oldest = reqs
                                    .iter()
                                    .map(|r| r.enqueued_at)
                                    .min()
                                    .unwrap_or(sealed);
                                tracer.emit(TraceEvent {
                                    kind: EventKind::Batch,
                                    ts_ns: tracer.ts(oldest),
                                    dur_ns: sealed
                                        .saturating_duration_since(oldest)
                                        .as_nanos()
                                        as u64,
                                    class: Some(class),
                                    model_id,
                                    batch_id,
                                    label: reason.as_str(),
                                    value: reqs.len() as f64,
                                    ..TraceEvent::default()
                                });
                                for r in &reqs {
                                    tracer.emit(TraceEvent {
                                        kind: EventKind::Queue,
                                        ts_ns: tracer.ts(r.enqueued_at),
                                        dur_ns: sealed
                                            .saturating_duration_since(
                                                r.enqueued_at,
                                            )
                                            .as_nanos()
                                            as u64,
                                        class: Some(class),
                                        sensor_id: r.sensor_id,
                                        seq: r.frame.seq,
                                        model_id,
                                        batch_id,
                                        ..TraceEvent::default()
                                    });
                                }
                            }
                            let model = Arc::clone(&reqs[0].model);
                            let batch = Batch { class, backend, model_id,
                                                model, batch_id,
                                                requests: reqs };
                            if batches_q.push(batch).is_err() {
                                break 'form; // batch queue force-closed
                            }
                        }
                    }
                    if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        batches_q.close();
                    }
                });
            match spawned {
                Ok(handle) => batchers.push(handle),
                Err(e) => {
                    spawn_err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = spawn_err {
            // unwind cleanly: release the already-running threads
            for q in &class_queues {
                q.close();
            }
            batches.close();
            for h in batchers {
                let _ = h.join();
            }
            let _ = pool.join();
            return Err(Error::Io(e));
        }

        Ok(Self {
            class_queues,
            batches,
            metrics,
            batchers,
            pool: Some(pool),
            async_plane: None,
            started: Instant::now(),
            shards: serve.shards,
            serve,
            models: RwLock::new(BTreeMap::from([(0u32, default_model)])),
            sensors: Mutex::new(BTreeMap::new()),
            tracer,
            trace: Some(trace),
        })
    }

    /// Register (or replace) model `model_id` from a compiled artifact.
    /// New submissions for the id validate against — and are served by —
    /// the new version immediately; requests already in flight keep the
    /// entry they pinned at admission, so a push never drops frames.
    pub fn push_model(&self, model_id: u32,
                      model: &crate::compile::CompiledModel) -> Result<()> {
        if model.version == 0 {
            // version 0 is reserved for the from-params default: shards
            // key their engine caches on it
            return Err(Error::Serve(
                "artifact version 0 is reserved (unstamped artifact?)"
                    .into(),
            ));
        }
        let entry = Arc::new(ModelEntry {
            version: model.version,
            params: Arc::new(model.params.clone()),
            prepacked: Some(Arc::new(model.prepacked())),
        });
        self.models.write().unwrap().insert(model_id, entry);
        Ok(())
    }

    /// The registered (model id, version) pairs, in id order.
    pub fn models(&self) -> Vec<(u32, u64)> {
        self.models
            .read()
            .unwrap()
            .iter()
            .map(|(&id, e)| (id, e.version))
            .collect()
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// A submission handle bound to `sensor_id`'s sequence space (shared
    /// with any other session for the same sensor).
    pub fn session(&self, sensor_id: u32) -> Session<'_> {
        let seq = Arc::clone(
            self.sensors
                .lock()
                .unwrap()
                .entry(sensor_id)
                .or_insert_with(|| Arc::new(AtomicU64::new(0))),
        );
        Session {
            server: self,
            sensor_id,
            seq,
            class: QosClass::default(),
            model_id: 0,
            deadline: None,
        }
    }

    /// Admit one typed request into its class's queue.  Backpressure is
    /// never a wait: past the class's `queue_depth` the request is
    /// rejected immediately (reject-newest), or — for drop-oldest
    /// classes — the *oldest* queued request is displaced (its ticket
    /// resolves to an error) and the fresh one admitted.  Frames whose
    /// shape does not match the network are rejected here, so one
    /// malformed frame can never fail a whole dispatched batch.
    pub fn submit(&self, request: Request) -> Result<Ticket> {
        let class = request.class;
        let sensor_id = request.sensor_id;
        let model_id = request.model_id;
        let seq = request.frame.seq;
        // resolve and pin the target model: the Arc rides the queued
        // request, so a concurrent push_model replaces the registry
        // entry without touching this frame's params or engines
        let model = self.models.read().unwrap().get(&model_id).cloned();
        let Some(model) = model else {
            self.metrics.record_rejected(class);
            self.trace_admission(EventKind::Reject, class, sensor_id, seq,
                                 model_id, "unknown_model");
            return Err(Error::Serve(format!(
                "admission rejected: unknown model {model_id}"
            )));
        };
        if let Err(e) = crate::engine::validate_frame(&request.frame,
                                                      &model.params.config) {
            self.metrics.record_rejected(class);
            self.trace_admission(EventKind::Reject, class, sensor_id, seq,
                                 model_id, "bad_frame");
            return Err(Error::Serve(format!("admission rejected: {e}")));
        }

        let knobs = self.serve.class_knobs(class);
        let slot = Arc::new(SlotState::new());
        let enqueued_at = Instant::now();
        let queued = QueuedRequest {
            frame: request.frame,
            sensor_id: request.sensor_id,
            model_id,
            model,
            deadline: request.deadline,
            enqueued_at,
            slot: Arc::clone(&slot),
        };
        if let Some(plane) = &self.async_plane {
            // same verdicts, metrics, spans, and error text as the
            // threaded path below — only the queue structure differs
            // (per-sensor DRR lanes instead of one FIFO per class)
            return match plane.admit(class, queued) {
                async_plane::Admit::Accepted => {
                    self.metrics.record_accepted(class);
                    self.trace_admission(EventKind::Submit, class,
                                         sensor_id, seq, model_id, "");
                    Ok(Ticket { slot })
                }
                async_plane::Admit::AcceptedDisplacing(old) => {
                    self.metrics.record_accepted(class);
                    self.trace_admission(EventKind::Submit, class,
                                         sensor_id, seq, model_id, "");
                    self.metrics.record_dropped(class, old.model_id);
                    self.trace_admission(EventKind::Drop, class,
                                         old.sensor_id, old.frame.seq,
                                         old.model_id, "displaced");
                    old.slot.fulfill(Err(Error::Dropped(
                        "displaced by a fresher frame (drop-oldest \
                         admission)"
                            .into(),
                    )));
                    Ok(Ticket { slot })
                }
                async_plane::Admit::Full => {
                    self.metrics.record_rejected(class);
                    self.trace_admission(EventKind::Reject, class,
                                         sensor_id, seq, model_id,
                                         "queue_full");
                    Err(Error::Serve(format!(
                        "admission rejected: {class} queue at configured \
                         depth {}",
                        plane.depth(class)
                    )))
                }
                async_plane::Admit::Closed => {
                    Err(Error::Serve("server is draining".into()))
                }
            };
        }

        let queue = &self.class_queues[class.index()];
        if knobs.drop_oldest {
            match queue.push_dropping_oldest(queued) {
                Ok(displaced) => {
                    self.metrics.record_accepted(class);
                    self.trace_admission(EventKind::Submit, class,
                                         sensor_id, seq, model_id, "");
                    if let Some(old) = displaced {
                        self.metrics.record_dropped(class, old.model_id);
                        self.trace_admission(EventKind::Drop, class,
                                             old.sensor_id, old.frame.seq,
                                             old.model_id, "displaced");
                        old.slot.fulfill(Err(Error::Dropped(
                            "displaced by a fresher frame (drop-oldest \
                             admission)"
                                .into(),
                        )));
                    }
                    Ok(Ticket { slot })
                }
                Err(_) => Err(Error::Serve("server is draining".into())),
            }
        } else {
            match queue.try_push(queued) {
                Ok(()) => {
                    self.metrics.record_accepted(class);
                    self.trace_admission(EventKind::Submit, class,
                                         sensor_id, seq, model_id, "");
                    Ok(Ticket { slot })
                }
                Err((PushError::Full, _)) => {
                    self.metrics.record_rejected(class);
                    self.trace_admission(EventKind::Reject, class,
                                         sensor_id, seq, model_id,
                                         "queue_full");
                    Err(Error::Serve(format!(
                        "admission rejected: {class} queue at configured \
                         depth {}",
                        queue.capacity()
                    )))
                }
                Err((PushError::Closed, _)) => {
                    Err(Error::Serve("server is draining".into()))
                }
            }
        }
    }

    /// Emit one admission-stage instant (submit / reject / displaced
    /// drop).  A single branch when tracing is disabled.
    fn trace_admission(&self, kind: EventKind, class: QosClass,
                       sensor_id: u32, seq: u64, model_id: u32,
                       label: &'static str) {
        if self.tracer.enabled() {
            self.tracer.emit(TraceEvent {
                kind,
                ts_ns: self.tracer.now(),
                class: Some(class),
                sensor_id,
                seq,
                model_id,
                label,
                ..TraceEvent::default()
            });
        }
    }

    /// Live view of the metrics sink.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Autoscale/worker counters of the async plane, or `None` when the
    /// server runs the thread-per-stage plane.
    pub fn async_stats(&self) -> Option<AsyncStats> {
        self.async_plane.as_ref().map(|p| p.stats())
    }

    /// Graceful drain: stop admission, flush every queued request through
    /// the per-class batchers and shards, join all threads, and return
    /// the final report.
    pub fn drain(mut self) -> Result<MetricsReport> {
        for q in &self.class_queues {
            q.close();
        }
        if let Some(mut plane) = self.async_plane.take() {
            // closing the lanes cascades: schedulers flush and retire,
            // the last one closes the batch queue, dispatch tasks drain
            // it, the autoscaler observes the closure
            plane.drain()?;
        }
        for b in std::mem::take(&mut self.batchers) {
            b.join()
                .map_err(|_| Error::Serve("batcher thread panicked".into()))?;
        }
        // the last batcher closed `batches` on exit; shards drain it and
        // stop
        if let Some(pool) = self.pool.take() {
            pool.join()?;
        }
        // every producer is gone: flush the trace tail and close the sinks
        if let Some(trace) = self.trace.take() {
            trace.finish()?;
        }
        Ok(self.metrics.snapshot(self.started.elapsed()))
    }
}

impl Drop for Server {
    /// Dropping without [`Server::drain`] still releases the worker
    /// threads (close every queue); in-flight tickets may stay pending —
    /// use [`Ticket::wait_timeout`] to avoid blocking on them forever.
    fn drop(&mut self) {
        for q in &self.class_queues {
            q.close();
        }
        self.batches.close();
    }
}

/// Parse a `--mix A:B:C` weight spec (best_effort:standard:billed) into
/// the repeating class pattern submitted frames cycle through.  Rejects
/// specs with the wrong arity, non-numeric weights, and the all-zero
/// mix (which would describe no traffic at all).
pub fn parse_mix(spec: &str) -> Result<Vec<QosClass>> {
    let parts: Vec<&str> = spec.split(':').collect();
    if parts.len() != QosClass::COUNT {
        return Err(Error::Usage(format!(
            "--mix expects {} ':'-separated weights \
             (best_effort:standard:billed), got {spec:?}",
            QosClass::COUNT
        )));
    }
    let mut weights = [0usize; QosClass::COUNT];
    for (w, part) in weights.iter_mut().zip(&parts) {
        *w = part.trim().parse().map_err(|_| {
            Error::Usage(format!("--mix: bad weight {part:?}"))
        })?;
    }
    let max = weights.iter().copied().max().unwrap_or(0);
    if max == 0 {
        return Err(Error::Usage(
            "--mix needs at least one non-zero weight".into(),
        ));
    }
    // round-robin interleave so classes blend rather than run in blocks
    let mut pattern = Vec::new();
    for i in 0..max {
        for (ci, &w) in weights.iter().enumerate() {
            if i < w {
                pattern.push(QosClass::ALL[ci]);
            }
        }
    }
    Ok(pattern)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ArchSim;
    use crate::params::synth::synth_params;

    fn synth_frames(n: usize, seed: u64) -> (NetParams, Vec<Frame>) {
        let (_, params) = synth_params(5);
        let frames = crate::testing::synth_frames(&params, n, seed).unwrap();
        (params, frames)
    }

    fn test_config(shards: usize) -> EngineConfig {
        let mut config = EngineConfig {
            arch: ArchSim { lbp: false, mlp: false, early_exit: false },
            ..Default::default()
        };
        config.system.serve.shards = shards;
        config.system.serve.max_batch = 4;
        config.system.serve.batch_deadline_us = 500;
        config
    }

    #[test]
    fn server_round_trip_and_drain() {
        let (params, frames) = synth_frames(10, 3);
        let server = Server::start(params, test_config(2)).unwrap();
        let tickets: Vec<Ticket> = frames
            .into_iter()
            .map(|f| server.submit(Request::from_frame(f)).unwrap())
            .collect();
        let mut responses: Vec<InferResponse> =
            tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        responses.sort_by_key(|r| r.seq());
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.seq(), i as u64);
            assert!(r.predicted() < 10);
            assert!(r.shard < 2);
            assert!(r.batch_size >= 1);
            assert_eq!(r.sensor_id, 0);
            assert_eq!(r.class, QosClass::Standard);
        }
        let report = server.drain().unwrap();
        assert_eq!(report.accepted, 10);
        assert_eq!(report.completed, 10);
        assert_eq!(report.failed, 0);
        assert_eq!(report.dropped, 0);
        assert_eq!(report.arch_mismatches, 0);
        assert!(report.batches >= 3, "10 frames / max_batch 4");
        assert!(report.p50_ms <= report.p95_ms);
        assert!(report.p95_ms <= report.p99_ms);
        assert!(report.throughput_fps > 0.0);
        let std_class = report.class(QosClass::Standard).unwrap();
        assert_eq!(std_class.completed, 10);
    }

    #[test]
    fn bad_frame_shape_is_rejected_at_admission() {
        let (params, frames) = synth_frames(1, 4);
        let server = Server::start(params, test_config(1)).unwrap();
        let good =
            server.submit(Request::from_frame(frames[0].clone())).unwrap();
        let err = server
            .submit(Request::from_frame(Frame {
                rows: 1, cols: 1, channels: 1, pixels: vec![0], seq: 99,
            }))
            .unwrap_err();
        assert!(err.to_string().contains("admission rejected"), "{err}");
        assert!(good.wait().is_ok());
        let report = server.drain().unwrap();
        assert_eq!(report.rejected, 1);
        assert_eq!(report.completed, 1);
        assert_eq!(report.failed, 0);
    }

    #[test]
    fn submit_after_drop_semantics_and_shard_validation() {
        let (params, _) = synth_frames(1, 5);
        // more shards than banks must fail fast at start()
        let mut config = test_config(81);
        config.system.serve.shards = 81;
        assert!(Server::start(params, config).is_err());
    }

    #[test]
    fn sessions_own_disjoint_sequence_spaces() {
        let (params, frames) = synth_frames(6, 6);
        let server = Server::start(params, test_config(1)).unwrap();
        let cam0 = server.session(0);
        let cam1 = server.session(1);
        let mut tickets = Vec::new();
        // interleave two sensors; every source frame carries seq 0..6,
        // which would collide without per-sensor re-stamping
        for f in &frames[..3] {
            tickets.push((0u32, cam0.submit(f.clone()).unwrap()));
            tickets.push((1u32, cam1.submit(f.clone()).unwrap()));
        }
        let mut seqs: BTreeMap<u32, Vec<u64>> = BTreeMap::new();
        for (sensor, t) in tickets {
            let r = t.wait().unwrap();
            assert_eq!(r.sensor_id, sensor);
            seqs.entry(sensor).or_default().push(r.seq());
        }
        assert_eq!(seqs[&0], vec![0, 1, 2]);
        assert_eq!(seqs[&1], vec![0, 1, 2]);
        // a second session for sensor 0 continues its sequence space
        let cam0_again = server.session(0);
        let t = cam0_again.submit(frames[3].clone()).unwrap();
        assert_eq!(t.wait().unwrap().seq(), 3);
        drop(cam0);
        drop(cam1);
        drop(cam0_again);
        server.drain().unwrap();
    }

    /// Compile a default-geometry artifact model in memory (different
    /// seed → different weights, same frame shape as the test frames).
    fn artifact_model(seed: u64) -> crate::compile::CompiledModel {
        let spec = crate::compile::ModelSpec::parse(
            &format!("[model]\nname = \"alt\"\nseed = {seed}\n"),
            std::path::Path::new("."),
        )
        .unwrap();
        crate::compile::build_model(&spec, &test_config(1).system).unwrap()
    }

    #[test]
    fn two_model_traffic_splits_metrics_and_responses() {
        let (params, frames) = synth_frames(8, 7);
        let server = Server::start(params, test_config(2)).unwrap();
        let alt = artifact_model(9);
        let alt_version = alt.version;
        server.push_model(1, &alt).unwrap();
        assert_eq!(server.models(), vec![(0, 0), (1, alt_version)]);

        // unknown model ids are rejected at admission
        let err = server
            .submit(Request::builder(frames[0].clone()).model(42).build())
            .unwrap_err();
        assert!(err.to_string().contains("unknown model 42"), "{err}");

        let cam0 = server.session(0);
        let cam1 = server.session(1).with_model(1);
        let mut tickets = Vec::new();
        for f in &frames[..4] {
            tickets.push((0u32, cam0.submit(f.clone()).unwrap()));
            tickets.push((1u32, cam1.submit(f.clone()).unwrap()));
        }
        for (model_id, t) in tickets {
            let r = t.wait().unwrap();
            assert_eq!(r.model_id, model_id);
            assert!(r.predicted() < 10);
        }
        let report = server.drain().unwrap();
        assert_eq!(report.completed, 8);
        assert_eq!(report.failed, 0);
        assert_eq!(report.rejected, 1);
        let m0 = report.model(QosClass::Standard, 0).unwrap();
        assert_eq!((m0.completed, m0.failed, m0.dropped), (4, 0, 0));
        let m1 = report.model(QosClass::Standard, 1).unwrap();
        assert_eq!((m1.completed, m1.failed, m1.dropped), (4, 0, 0));
        assert!(report.to_json().contains("\"model_id\":1"));
    }

    #[test]
    fn push_model_rolls_over_without_dropping_inflight_frames() {
        let (params, frames) = synth_frames(12, 8);
        let server = Server::start(params, test_config(1)).unwrap();
        server.push_model(1, &artifact_model(9)).unwrap();
        let cam = server.session(0).with_model(1);
        let mut tickets: Vec<Ticket> =
            frames[..6].iter().map(|f| cam.submit(f.clone()).unwrap())
                .collect();
        // replace model 1 while the first wave may still be in flight:
        // admitted requests pinned the old entry, so nothing drops
        let v1 = server.models()[1].1;
        server.push_model(1, &artifact_model(10)).unwrap();
        let v2 = server.models()[1].1;
        assert_ne!(v1, v2, "different seeds must hash differently");
        tickets.extend(
            frames[6..].iter().map(|f| cam.submit(f.clone()).unwrap()));
        for t in tickets {
            assert_eq!(t.wait().unwrap().model_id, 1);
        }
        let report = server.drain().unwrap();
        assert_eq!(report.completed, 12);
        assert_eq!(report.dropped, 0);
        assert_eq!(report.failed, 0);
        let m1 = report.model(QosClass::Standard, 1).unwrap();
        assert_eq!(m1.completed, 12);
    }

    #[test]
    fn parse_mix_validates_and_interleaves() {
        // weights round-robin so classes blend rather than run in blocks
        assert_eq!(
            parse_mix("1:2:1").unwrap(),
            vec![QosClass::BestEffort, QosClass::Standard, QosClass::Billed,
                 QosClass::Standard]
        );
        assert_eq!(parse_mix("0:1:0").unwrap(), vec![QosClass::Standard]);
        assert_eq!(parse_mix(" 2 : 0 : 0 ").unwrap().len(), 2);
        // wrong arity names the expected form
        let err = parse_mix("1:2").unwrap_err();
        assert!(err.to_string().contains("best_effort:standard:billed"),
                "{err}");
        // junk weights, the all-zero mix, and empty specs are usage
        // errors, never panics or silently empty patterns
        for bad in ["1:2:3:4", "a:1:0", "1: :0", "", "0:0:0", "-1:1:0",
                    "1:2:"] {
            let err = parse_mix(bad).unwrap_err();
            assert!(matches!(err, Error::Usage(_)), "{bad:?} -> {err}");
        }
    }

    #[test]
    fn wait_timeout_on_unfulfilled_slot_returns_none() {
        let ticket = Ticket { slot: Arc::new(SlotState::new()) };
        let t0 = Instant::now();
        assert!(ticket.wait_timeout(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(15));
        // fulfilled afterwards, the same ticket resolves
        ticket.slot.fulfill(Err(Error::Serve("late".into())));
        assert!(ticket.wait_timeout(Duration::from_millis(1)).is_some());
    }
}
