//! `serve` — the sharded, batching frame-serving layer on top of the
//! NS-LBP inference engine.
//!
//! The seed coordinator is a one-shot, run-to-completion loop; the paper
//! (and the PISA/LBPNet line of work it extends) frames the accelerator
//! as an *always-on* edge inference engine fed by continuous sensor
//! streams.  This module supplies that missing layer:
//!
//! ```text
//!  submit() ──► BoundedQueue ──► Batcher ──► BoundedQueue ──► ShardPool
//!  (admission    (backpressure:   (size/      (of batches)    shard 0: banks 0..19
//!   control)      reject past      deadline                   shard 1: banks 20..39
//!                 queue_depth)     triggers)                  ...      ──► Ticket
//! ```
//!
//! * [`queue`] — bounded MPMC queue; full ⇒ reject-with-error, closed ⇒
//!   drain semantics.
//! * [`batcher`] — dynamic batching, shipped at `max_batch` or at the
//!   `batch_deadline_us` of the oldest queued frame.
//! * [`shard`] — worker pool; each shard owns an [`crate::engine::Engine`]
//!   whose backend is pinned to a disjoint bank slice
//!   ([`crate::engine::ShardSlice`]), so shards model disjoint compute
//!   sub-arrays.  Which execution path runs (functional, architectural,
//!   PJRT) is the engine's backend selection (`system.engine.backend`,
//!   or `ns-lbp serve-bench --backend ...`).  Sharding never changes
//!   logits — only which banks (and therefore whose modeled time budget)
//!   do the work; `rust/tests/serve.rs` proves 1-shard vs 4-shard
//!   equivalence.
//! * [`metrics`] — accepted/rejected/completed counters, p50/p95/p99
//!   latency, throughput, and the energy-per-frame account.
//!
//! Shutdown is a graceful drain: [`Server::drain`] stops admission,
//! flushes the request queue through the batcher, lets every shard
//! finish its in-flight batches, then returns the final
//! [`MetricsReport`].  Knobs live in `[serve]` of the system config
//! ([`crate::config::ServeConfig`]); `ns-lbp serve-bench` exercises the
//! whole stack from the CLI.

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod shard;

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::engine::{EngineConfig, FrameOutput};
use crate::error::{Error, Result};
use crate::params::NetParams;
use crate::sensor::Frame;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::{Metrics, MetricsReport};
pub use queue::{BoundedQueue, PopResult, PushError};
pub use shard::{Batch, ShardPool};

/// One admitted inference request flowing through the pipeline.
pub struct Request {
    pub frame: Frame,
    pub(crate) enqueued_at: Instant,
    pub(crate) slot: ResponseSlot,
}

/// A completed inference plus its serving metadata.
#[derive(Clone, Debug)]
pub struct InferResponse {
    /// The engine's full per-frame output (logits, telemetry).
    pub report: FrameOutput,
    /// Which shard processed the frame.
    pub shard: usize,
    /// Size of the dispatch batch this frame rode in.
    pub batch_size: usize,
    /// Queue-entry to completion latency.
    pub latency: Duration,
}

impl InferResponse {
    pub fn seq(&self) -> u64 {
        self.report.seq
    }

    pub fn predicted(&self) -> usize {
        self.report.predicted
    }
}

/// One-shot completion slot shared between a [`Ticket`] and the shard
/// that fulfills it.
pub(crate) struct SlotState {
    result: Mutex<Option<Result<InferResponse>>>,
    ready: Condvar,
}

pub(crate) type ResponseSlot = Arc<SlotState>;

impl SlotState {
    fn new() -> Self {
        Self { result: Mutex::new(None), ready: Condvar::new() }
    }

    pub(crate) fn fulfill(&self, r: Result<InferResponse>) {
        *self.result.lock().unwrap() = Some(r);
        self.ready.notify_all();
    }
}

/// Claim check for an admitted request.
pub struct Ticket {
    slot: ResponseSlot,
}

impl Ticket {
    /// Block until the shard pool delivers the response.
    pub fn wait(self) -> Result<InferResponse> {
        let mut g = self.slot.result.lock().unwrap();
        loop {
            if let Some(r) = g.take() {
                return r;
            }
            g = self.slot.ready.wait(g).unwrap();
        }
    }

    /// Non-blocking poll; `None` while the frame is still in flight.
    pub fn try_take(&self) -> Option<Result<InferResponse>> {
        self.slot.result.lock().unwrap().take()
    }
}

/// The serving front-end: admission queue + batcher thread + shard pool.
pub struct Server {
    requests: Arc<BoundedQueue<Request>>,
    batches: Arc<BoundedQueue<Batch>>,
    metrics: Arc<Metrics>,
    batcher: Option<std::thread::JoinHandle<()>>,
    pool: Option<ShardPool>,
    started: Instant,
    shards: usize,
}

impl Server {
    /// Spin up the pipeline: `config.system.serve` supplies the knobs,
    /// the rest of `config` (cache geometry, arch-sim switches, backend
    /// selection in `config.system.engine`) is inherited by every
    /// shard's engine.
    pub fn start(params: NetParams, config: EngineConfig) -> Result<Self> {
        let serve: ServeConfig = config.system.serve;
        serve.validate()?;
        let requests = Arc::new(BoundedQueue::new(serve.queue_depth));
        // a couple of in-flight batches per shard keeps workers fed
        // without hiding queueing latency inside the dispatch stage
        let batches = Arc::new(BoundedQueue::new(serve.shards * 2));
        let metrics = Arc::new(Metrics::default());

        // spawn() validates the shard slicing against the cache geometry
        // and errors before any worker thread starts
        let pool = ShardPool::spawn(&params, &config, serve.shards, &batches,
                                    &metrics)?;

        let policy = BatchPolicy::from_serve(&serve);
        let spawned = {
            let requests = Arc::clone(&requests);
            let batches = Arc::clone(&batches);
            std::thread::Builder::new()
                .name("nslbp-batcher".into())
                .spawn(move || {
                    // deadline anchored to enqueue time: max_delay bounds a
                    // frame's total queue staleness, not time-since-pop
                    let b = Batcher::new(&requests, policy)
                        .with_anchor(|r: &Request| r.enqueued_at);
                    while let Some(batch) = b.next_batch() {
                        if batches.push(batch).is_err() {
                            break; // batch queue force-closed
                        }
                    }
                    batches.close();
                })
        };
        let batcher = match spawned {
            Ok(handle) => handle,
            Err(e) => {
                // unwind cleanly: release the already-running shard pool
                requests.close();
                batches.close();
                let _ = pool.join();
                return Err(Error::Io(e));
            }
        };

        Ok(Self {
            requests,
            batches,
            metrics,
            batcher: Some(batcher),
            pool: Some(pool),
            started: Instant::now(),
            shards: serve.shards,
        })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Admit one frame.  Backpressure is an error, not a wait: past
    /// `serve.queue_depth` the frame is rejected immediately.
    pub fn submit(&self, frame: Frame) -> Result<Ticket> {
        let slot = Arc::new(SlotState::new());
        let req = Request {
            frame,
            enqueued_at: Instant::now(),
            slot: Arc::clone(&slot),
        };
        match self.requests.try_push(req) {
            Ok(()) => {
                self.metrics.record_accepted();
                Ok(Ticket { slot })
            }
            Err((PushError::Full, _)) => {
                self.metrics.record_rejected();
                Err(Error::Serve(format!(
                    "admission rejected: queue at configured depth {}",
                    self.requests.capacity()
                )))
            }
            Err((PushError::Closed, _)) => {
                Err(Error::Serve("server is draining".into()))
            }
        }
    }

    /// Live view of the metrics sink.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Graceful drain: stop admission, flush every queued request through
    /// batcher and shards, join all threads, and return the final report.
    pub fn drain(mut self) -> Result<MetricsReport> {
        self.requests.close();
        if let Some(b) = self.batcher.take() {
            b.join()
                .map_err(|_| Error::Serve("batcher thread panicked".into()))?;
        }
        // the batcher closed `batches` on exit; shards drain it and stop
        if let Some(pool) = self.pool.take() {
            pool.join()?;
        }
        Ok(self.metrics.snapshot(self.started.elapsed()))
    }
}

impl Drop for Server {
    /// Dropping without [`Server::drain`] still releases the worker
    /// threads (close both queues); in-flight tickets may stay pending.
    fn drop(&mut self) {
        self.requests.close();
        self.batches.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ArchSim;
    use crate::params::synth::synth_params;

    fn synth_frames(n: usize, seed: u64) -> (NetParams, Vec<Frame>) {
        let (_, params) = synth_params(5);
        let frames = crate::testing::synth_frames(&params, n, seed).unwrap();
        (params, frames)
    }

    fn test_config(shards: usize) -> EngineConfig {
        let mut config = EngineConfig {
            arch: ArchSim { lbp: false, mlp: false, early_exit: false },
            ..Default::default()
        };
        config.system.serve.shards = shards;
        config.system.serve.max_batch = 4;
        config.system.serve.batch_deadline_us = 500;
        config
    }

    #[test]
    fn server_round_trip_and_drain() {
        let (params, frames) = synth_frames(10, 3);
        let server = Server::start(params, test_config(2)).unwrap();
        let tickets: Vec<Ticket> = frames
            .into_iter()
            .map(|f| server.submit(f).unwrap())
            .collect();
        let mut responses: Vec<InferResponse> =
            tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        responses.sort_by_key(|r| r.seq());
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.seq(), i as u64);
            assert!(r.predicted() < 10);
            assert!(r.shard < 2);
            assert!(r.batch_size >= 1);
        }
        let report = server.drain().unwrap();
        assert_eq!(report.accepted, 10);
        assert_eq!(report.completed, 10);
        assert_eq!(report.failed, 0);
        assert_eq!(report.arch_mismatches, 0);
        assert!(report.batches >= 3, "10 frames / max_batch 4");
        assert!(report.p50_ms <= report.p95_ms);
        assert!(report.p95_ms <= report.p99_ms);
        assert!(report.throughput_fps > 0.0);
    }

    #[test]
    fn bad_frame_shape_fails_just_that_ticket() {
        let (params, frames) = synth_frames(2, 4);
        let server = Server::start(params, test_config(1)).unwrap();
        let good = server.submit(frames[0].clone()).unwrap();
        let bad = server
            .submit(Frame { rows: 1, cols: 1, channels: 1, pixels: vec![0],
                            seq: 99 })
            .unwrap();
        assert!(good.wait().is_ok());
        assert!(bad.wait().is_err());
        let report = server.drain().unwrap();
        assert_eq!(report.failed, 1);
        assert_eq!(report.completed, 1);
    }

    #[test]
    fn submit_after_drop_semantics_and_shard_validation() {
        let (params, _) = synth_frames(1, 5);
        // more shards than banks must fail fast at start()
        let mut config = test_config(81);
        config.system.serve.shards = 81;
        assert!(Server::start(params, config).is_err());
    }
}
