//! Shard worker pool: each shard owns an [`Engine`] whose backend is
//! pinned to a disjoint slice of the cache's banks
//! ([`crate::engine::ShardSlice`]), mirroring the paper's parallelism
//! model — different frames proceed on different bank groups, so one hot
//! request cannot monopolize the whole 2.5 MB slice.  Workers pull
//! *batches* (not single frames) so a shard keeps its sub-arrays busy
//! across a whole dispatch.

use std::sync::Arc;
use std::thread::JoinHandle;

use crate::engine::{Engine, EngineConfig, ShardSlice};
use crate::error::{Error, Result};
use crate::params::NetParams;

use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::{InferResponse, Request};

/// A dispatched batch of admitted requests.
pub type Batch = Vec<Request>;

/// Fixed pool of shard worker threads consuming from a shared batch queue.
pub struct ShardPool {
    workers: Vec<JoinHandle<()>>,
}

impl ShardPool {
    /// Build `count` sharded engines (erroring early on an invalid slice
    /// or an unavailable backend) and spawn one worker thread per shard.
    pub fn spawn(params: &NetParams, base: &EngineConfig, count: usize,
                 batches: &Arc<BoundedQueue<Batch>>, metrics: &Arc<Metrics>)
                 -> Result<Self> {
        let mut engines = Vec::with_capacity(count);
        for index in 0..count {
            let config = EngineConfig {
                shard: Some(ShardSlice { index, count }),
                ..base.clone()
            };
            engines.push(
                Engine::builder()
                    .config(config)
                    .params(params.clone())
                    .build()?,
            );
        }
        let workers = engines
            .into_iter()
            .enumerate()
            .map(|(index, engine)| {
                let batches = Arc::clone(batches);
                let metrics = Arc::clone(metrics);
                std::thread::Builder::new()
                    .name(format!("nslbp-shard-{index}"))
                    .spawn(move || shard_main(index, engine, &batches, &metrics))
                    .map_err(Error::Io)
            })
            .collect::<Result<Vec<_>>>()
            .map_err(|e| {
                // release any workers that did start before the failure
                batches.close();
                e
            })?;
        Ok(Self { workers })
    }

    /// Wait for every worker to finish (the batch queue must be closed
    /// first, or this blocks forever).
    pub fn join(self) -> Result<()> {
        for w in self.workers {
            w.join().map_err(|_| {
                Error::Serve("shard worker panicked".into())
            })?;
        }
        Ok(())
    }
}

fn shard_main(index: usize, mut engine: Engine,
              batches: &BoundedQueue<Batch>, metrics: &Metrics) {
    while let Some(batch) = batches.pop() {
        metrics.record_batch();
        let batch_size = batch.len();
        for req in batch {
            match engine.infer_frame(&req.frame) {
                Ok(report) => {
                    let latency = req.enqueued_at.elapsed();
                    metrics.record_completion(latency, &report);
                    req.slot.fulfill(Ok(InferResponse {
                        report,
                        shard: index,
                        batch_size,
                        latency,
                    }));
                }
                Err(e) => {
                    metrics.record_failure();
                    req.slot.fulfill(Err(e));
                }
            }
        }
    }
}
