//! Shard worker pool: each shard owns one [`Engine`] per *routed
//! backend*, every engine pinned to the shard's disjoint slice of the
//! cache banks ([`crate::engine::ShardSlice`]), mirroring the paper's
//! parallelism model — different frames proceed on different bank
//! groups, so one hot request cannot monopolize the whole 2.5 MB slice.
//!
//! Workers pull *batches* and dispatch each one to the batch's routed
//! backend in a single [`Engine::infer_batch`] call — no per-frame
//! loop — so the batch-aware backends (weight-stationary functional
//! MLP, architectural multi-frame sub-array packing) actually amortize
//! compute across the dispatch, instead of batching buying queueing
//! only.
//!
//! With multi-model serving (`Server::push_model`) each shard also
//! keeps a small LRU cache of engines for artifact models, keyed by
//! (artifact version, backend); engines for the default from-params
//! model stay prebuilt and pinned.  A cache miss builds the engine from
//! the batch's pinned [`ModelEntry`] — all packing already done at
//! compile time, so a build is table wiring, not bit-plane transposes.
//!
//! The per-batch dispatch logic lives in `ShardWorker`, shared by two
//! drivers: the thread-per-shard pool below (one dedicated OS thread
//! blocking on the batch queue) and the async plane's dispatch tasks
//! ([`crate::serve::async_plane`]), where the same worker is polled by
//! the executor and the shard count autoscales.  Both produce
//! bit-identical logits — the worker is the single source of truth for
//! what "dispatch a batch" means.

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::engine::{BackendKind, Engine, EngineConfig, QosClass, ShardSlice};
use crate::error::{Error, Result};
use crate::faults::{ShardFault, ShardFaults};
use crate::obs::{EventKind, TraceEvent, Tracer};
use crate::sensor::Frame;

use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::{InferResponse, ModelEntry, QueuedRequest};

/// A dispatched batch: admitted requests of one QoS class and one model,
/// bound for one backend.  Classes (or models) routed to different
/// engines never share a batch.
pub struct Batch {
    pub class: QosClass,
    pub backend: BackendKind,
    /// Which registered model the batch's frames target (0 = default).
    pub model_id: u32,
    /// The model entry every member was validated against at admission —
    /// pinned here so a concurrent `push_model` can never drop the
    /// params/prepacked tables out from under an in-flight batch.
    pub(crate) model: Arc<ModelEntry>,
    /// Trace correlation id allocated at batch seal (0 when tracing is
    /// off): joins the batcher's formation span to the shard's dispatch
    /// span and every member request's completion.
    pub(crate) batch_id: u64,
    pub(crate) requests: Vec<QueuedRequest>,
}

/// Fixed pool of shard worker threads consuming from a shared batch queue.
pub struct ShardPool {
    workers: Vec<JoinHandle<()>>,
}

impl ShardPool {
    /// Build `count` sharded engine sets for the default model — one
    /// engine per backend in `backends` per shard, erroring early on an
    /// invalid slice or an unavailable backend — and spawn one worker
    /// thread per shard.  Engines for artifact models are built lazily
    /// inside the worker, bounded by `serve.model_cache`.
    pub fn spawn(default_model: &Arc<ModelEntry>, base: &EngineConfig,
                 count: usize, backends: &[BackendKind],
                 batches: &Arc<BoundedQueue<Batch>>, metrics: &Arc<Metrics>,
                 tracer: &Tracer)
                 -> Result<Self> {
        let mut shard_workers = Vec::with_capacity(count);
        for index in 0..count {
            shard_workers.push(ShardWorker::build(
                default_model, base, ShardSlice { index, count }, backends,
                tracer,
            )?);
        }
        let workers = shard_workers
            .into_iter()
            .enumerate()
            .map(|(index, mut worker)| {
                let batches = Arc::clone(batches);
                let metrics = Arc::clone(metrics);
                let tracer = tracer.clone();
                std::thread::Builder::new()
                    .name(format!("nslbp-shard-{index}"))
                    .spawn(move || {
                        while let Some(batch) = batches.pop() {
                            // Panic isolation: a panicking dispatch (an
                            // injected chaos fault, or a genuine backend
                            // bug) must not wedge the pool — fail every
                            // slot the batch still owed and keep serving.
                            let caught = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| {
                                    worker.dispatch(batch, &metrics,
                                                    &tracer);
                                }),
                            );
                            if caught.is_err() {
                                worker.fail_pending(&metrics);
                            }
                        }
                    })
                    .map_err(Error::Io)
            })
            .collect::<Result<Vec<_>>>()
            .map_err(|e| {
                // release any workers that did start before the failure
                batches.close();
                e
            })?;
        Ok(Self { workers })
    }

    /// Wait for every worker to finish (the batch queue must be closed
    /// first, or this blocks forever).
    pub fn join(self) -> Result<()> {
        for w in self.workers {
            w.join().map_err(|_| {
                Error::Serve("shard worker panicked".into())
            })?;
        }
        Ok(())
    }
}

/// Build one engine for `model` on `kind`.  Artifact models carry
/// prepacked plans/planes, so the build wires tables instead of redoing
/// compile-time packing work.
fn build_model_engine(model: &ModelEntry, config: &EngineConfig,
                      kind: BackendKind) -> Result<Engine> {
    let mut builder = Engine::builder()
        .config(config.clone())
        .params((*model.params).clone())
        .backend(kind);
    if let Some(p) = &model.prepacked {
        builder = builder.prepacked(Arc::clone(p));
    }
    builder.build()
}

/// One artifact-model engine held by a shard.  (The default model's
/// engines live in the prebuilt per-backend set and are never evicted.)
struct CachedEngine {
    version: u64,
    kind: BackendKind,
    last_used: u64,
    engine: Engine,
}

/// Find-or-build the engine for an artifact batch; past `cap` entries
/// the least-recently-used cached engine is evicted first.
fn cached_engine<'c>(cache: &'c mut Vec<CachedEngine>,
                     model: &Arc<ModelEntry>, backend: BackendKind,
                     config: &EngineConfig, cap: usize, tick: u64,
                     tracer: &Tracer) -> Result<&'c mut Engine> {
    if let Some(pos) = cache
        .iter()
        .position(|c| c.version == model.version && c.kind == backend)
    {
        cache[pos].last_used = tick;
        return Ok(&mut cache[pos].engine);
    }
    let mut engine = build_model_engine(model, config, backend)?;
    engine.set_tracer(tracer.clone());
    if cache.len() >= cap {
        if let Some(pos) = (0..cache.len()).min_by_key(|&i| cache[i].last_used)
        {
            cache.swap_remove(pos);
        }
    }
    cache.push(CachedEngine {
        version: model.version,
        kind: backend,
        last_used: tick,
        engine,
    });
    let last = cache.len() - 1;
    Ok(&mut cache[last].engine)
}

/// One shard's dispatch state: its pinned default-model engines, its
/// artifact-engine LRU, and the persistent scratch buffers the
/// steady-state loop reuses instead of reallocating per batch.
///
/// The worker is *driver-agnostic*: [`ShardWorker::dispatch`] is one
/// synchronous batch → fulfilled-slots step, equally at home on a
/// dedicated thread (blocking queue pop around it) or inside an
/// executor task's poll.
pub(crate) struct ShardWorker {
    index: usize,
    engines: Vec<(BackendKind, Engine)>,
    config: EngineConfig,
    model_cache: usize,
    frames: Vec<Frame>,
    shells: Vec<(u32, u64, Instant, super::ResponseSlot)>,
    cache: Vec<CachedEngine>,
    tick: u64,
    /// Seeded chaos injector for this shard (`None` unless `[faults]`
    /// arms stalls or panics).
    faults: Option<ShardFaults>,
    /// Class/model of the batch currently being dispatched, so
    /// [`ShardWorker::fail_pending`] can attribute failures after a
    /// mid-dispatch panic unwound the `dispatch` frame.
    batch_class: QosClass,
    batch_model: u32,
}

impl ShardWorker {
    /// Build the pinned engine set for `slice` — one engine per routed
    /// backend, each seeing only its disjoint bank slice.  The async
    /// plane passes `slice.count = max_shards` for every worker so the
    /// slices stay disjoint (and logits stay identical) no matter how
    /// many shards are currently active.
    pub(crate) fn build(default_model: &Arc<ModelEntry>,
                        base: &EngineConfig, slice: ShardSlice,
                        backends: &[BackendKind], tracer: &Tracer)
                        -> Result<Self> {
        let config = EngineConfig { shard: Some(slice), ..base.clone() };
        let mut engines = Vec::with_capacity(backends.len());
        for &kind in backends {
            let mut engine = build_model_engine(default_model, &config, kind)?;
            engine.set_tracer(tracer.clone());
            engines.push((kind, engine));
        }
        Ok(Self {
            index: slice.index,
            model_cache: base.system.serve.model_cache.max(1),
            engines,
            config,
            frames: Vec::new(),
            shells: Vec::new(),
            cache: Vec::new(),
            tick: 0,
            faults: ShardFaults::new(&base.system.faults, slice.index),
            batch_class: QosClass::default(),
            batch_model: 0,
        })
    }

    /// Fail every response slot the in-flight batch still owes — called
    /// by the dispatch driver after a panic unwound `dispatch` (the
    /// shells survive in `self`, so no caller is left waiting forever).
    pub(crate) fn fail_pending(&mut self, metrics: &Metrics) {
        for (_sensor_id, _seq, _enqueued_at, slot) in self.shells.drain(..) {
            metrics.record_failure(self.batch_class, self.batch_model);
            slot.fulfill(Err(Error::Serve(
                "shard worker panicked mid-dispatch".into(),
            )));
        }
        self.frames.clear();
    }

    /// Dispatch one batch: shed expired members, resolve the engine,
    /// run one whole-batch `infer_batch`, and fulfill every member's
    /// response slot (success or failure — no slot is ever left
    /// dangling).
    pub(crate) fn dispatch(&mut self, batch: Batch, metrics: &Metrics,
                           tracer: &Tracer) {
        let Batch { class, backend, model_id, model, batch_id, requests } =
            batch;
        let index = self.index;
        self.batch_class = class;
        self.batch_model = model_id;

        // shed requests whose per-request deadline expired while queued:
        // the caller asked for freshness, not a stale answer
        let now = Instant::now();
        self.frames.clear();
        self.shells.clear();
        for req in requests {
            let expired = req
                .deadline
                .map_or(false, |d| now.duration_since(req.enqueued_at) > d);
            if expired {
                metrics.record_dropped(class, model_id);
                if tracer.enabled() {
                    tracer.emit(TraceEvent {
                        kind: EventKind::Expire,
                        ts_ns: tracer.now(),
                        class: Some(class),
                        sensor_id: req.sensor_id,
                        seq: req.frame.seq,
                        model_id,
                        batch_id,
                        shard: index as i32,
                        label: "deadline",
                        ..TraceEvent::default()
                    });
                }
                req.slot.fulfill(Err(Error::Dropped(format!(
                    "deadline expired after {:.1} ms in queue",
                    req.enqueued_at.elapsed().as_secs_f64() * 1e3
                ))));
            } else {
                let seq = req.frame.seq;
                self.frames.push(req.frame);
                self.shells
                    .push((req.sensor_id, seq, req.enqueued_at, req.slot));
            }
        }
        if self.frames.is_empty() {
            return; // fully-expired batch: nothing was dispatched
        }

        // chaos injection point: after the shells are populated (so a
        // panic here exercises the driver's fail-over path) and before
        // any lock is held (so a panic can never poison the metrics)
        if let Some(f) = self.faults.as_mut() {
            match f.next() {
                Some(ShardFault::Stall(d)) => {
                    metrics.record_fault();
                    std::thread::sleep(d);
                }
                Some(ShardFault::Panic) => {
                    metrics.record_fault();
                    panic!("injected shard fault: chaos panic");
                }
                None => {}
            }
        }
        metrics.record_batch();
        let batch_size = self.frames.len();

        // resolve the engine: default-model batches hit the prebuilt,
        // pinned per-backend set; artifact batches go through the
        // bounded LRU, building from the pinned entry on a miss
        self.tick += 1;
        let engine = if model.version == 0 {
            self.engines
                .iter_mut()
                .find(|(kind, _)| *kind == backend)
                .map(|(_, engine)| engine)
                .expect("batch routed to a backend this shard does not host")
        } else {
            match cached_engine(&mut self.cache, &model, backend,
                                &self.config, self.model_cache, self.tick,
                                tracer) {
                Ok(engine) => engine,
                Err(e) => {
                    let msg = e.to_string();
                    for (sensor_id, seq, _, slot) in self.shells.drain(..) {
                        metrics.record_failure(class, model_id);
                        if tracer.enabled() {
                            tracer.emit(TraceEvent {
                                kind: EventKind::Fail,
                                ts_ns: tracer.now(),
                                class: Some(class),
                                sensor_id,
                                seq,
                                model_id,
                                batch_id,
                                shard: index as i32,
                                label: "engine_build",
                                ..TraceEvent::default()
                            });
                        }
                        slot.fulfill(Err(Error::Serve(format!(
                            "engine build for model {model_id} failed: {msg}"
                        ))));
                    }
                    return;
                }
            }
        };

        // one whole-batch dispatch — the engine (and its cross-check)
        // sees the entire batch at once
        let dispatch_start = Instant::now();
        match engine.infer_batch(&self.frames) {
            Ok(out) if out.frames.len() == self.shells.len() => {
                if tracer.enabled() {
                    // dispatch span with the batch's telemetry energy
                    // rolled up into the paper's stage decomposition
                    let tel = out.telemetry();
                    let e = &tel.cost.energy;
                    tracer.emit(TraceEvent {
                        kind: EventKind::Infer,
                        ts_ns: tracer.ts(dispatch_start),
                        dur_ns: dispatch_start.elapsed().as_nanos() as u64,
                        class: Some(class),
                        model_id,
                        batch_id,
                        shard: index as i32,
                        backend: Some(backend),
                        sensor_pj: e.sensor_pj,
                        compute_pj: e.compute_pj + e.read_pj + e.write_pj
                            + e.ctrl_pj,
                        dpu_pj: e.dpu_pj,
                        tx_pj: e.transmission_pj,
                        modeled_ns: tel.cost.time_ns.max(0.0) as u64,
                        ..TraceEvent::default()
                    });
                }
                for (report, (sensor_id, seq, enqueued_at, slot)) in
                    out.frames.into_iter().zip(self.shells.drain(..))
                {
                    let latency = enqueued_at.elapsed();
                    metrics.record_completion(class, model_id, latency,
                                              &report);
                    if tracer.enabled() {
                        // dur is the *same* latency the metrics
                        // reservoir records, so span-derived
                        // percentiles reproduce the report's
                        tracer.emit(TraceEvent {
                            kind: EventKind::Complete,
                            ts_ns: tracer.ts(enqueued_at),
                            dur_ns: latency.as_nanos() as u64,
                            class: Some(class),
                            sensor_id,
                            seq,
                            model_id,
                            batch_id,
                            shard: index as i32,
                            backend: Some(backend),
                            ..TraceEvent::default()
                        });
                    }
                    slot.fulfill(Ok(InferResponse {
                        report,
                        sensor_id,
                        class,
                        model_id,
                        backend,
                        shard: index,
                        batch_size,
                        latency,
                    }));
                }
            }
            Ok(out) => {
                let msg = format!(
                    "backend returned {} outputs for a {}-frame batch",
                    out.frames.len(),
                    self.shells.len()
                );
                for (sensor_id, seq, _, slot) in self.shells.drain(..) {
                    metrics.record_failure(class, model_id);
                    if tracer.enabled() {
                        tracer.emit(TraceEvent {
                            kind: EventKind::Fail,
                            ts_ns: tracer.now(),
                            class: Some(class),
                            sensor_id,
                            seq,
                            model_id,
                            batch_id,
                            shard: index as i32,
                            label: "output_count_mismatch",
                            ..TraceEvent::default()
                        });
                    }
                    slot.fulfill(Err(Error::Serve(msg.clone())));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for (sensor_id, seq, _, slot) in self.shells.drain(..) {
                    metrics.record_failure(class, model_id);
                    if tracer.enabled() {
                        tracer.emit(TraceEvent {
                            kind: EventKind::Fail,
                            ts_ns: tracer.now(),
                            class: Some(class),
                            sensor_id,
                            seq,
                            model_id,
                            batch_id,
                            shard: index as i32,
                            label: "backend_error",
                            ..TraceEvent::default()
                        });
                    }
                    slot.fulfill(Err(Error::Serve(format!(
                        "batch inference failed: {msg}"
                    ))));
                }
            }
        }
    }
}
