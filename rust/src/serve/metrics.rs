//! Serving metrics: request counters, per-request latency percentiles,
//! throughput, and the accelerator's energy/time account aggregated
//! across shards — all broken down per [`QosClass`] as well as in
//! aggregate, so a routed two-class run shows each class's own
//! p50/p95/p99, drop/reject counts, and energy under the active
//! hardware profile (`MetricsReport::hw_profile`).
//!
//! Counters are atomics (touched on every request); the latency
//! reservoirs and energy accumulators sit behind one mutex that is taken
//! once per *completed* frame — far off the admission hot path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::energy::EnergyBreakdown;
use crate::engine::{FrameOutput, QosClass};
use crate::rng::Xoshiro256;

/// Latency samples kept per reservoir for percentile estimation.  Beyond
/// this the sink switches to uniform reservoir sampling (Vitter's
/// Algorithm R), so an always-on server holds O(1) memory no matter how
/// many frames it has served.
pub const LATENCY_RESERVOIR: usize = 1 << 16;

/// Per-class admission/completion counters.
#[derive(Default)]
struct ClassCounters {
    accepted: AtomicU64,
    rejected: AtomicU64,
    /// Displaced by drop-oldest admission or expired past a per-request
    /// deadline before dispatch.
    dropped: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
}

/// Bounded uniform latency sample (Algorithm R past the cap).
#[derive(Default)]
struct Reservoir {
    samples: Vec<u64>,
    seen: u64,
}

impl Reservoir {
    fn offer(&mut self, ns: u64, rng: &mut Xoshiro256) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR {
            self.samples.push(ns);
        } else {
            let j = rng.below(self.seen);
            if (j as usize) < LATENCY_RESERVOIR {
                self.samples[j as usize] = ns;
            }
        }
    }

    fn sorted(&self) -> Vec<u64> {
        let mut lat = self.samples.clone();
        lat.sort_unstable();
        lat
    }
}

/// Shared metrics sink for one server instance.
pub struct Metrics {
    arch_mismatches: AtomicU64,
    cross_checked: AtomicU64,
    cross_check_mismatches: AtomicU64,
    batches: AtomicU64,
    /// Chaos-plane injections executed by this node's shards (stalls +
    /// panics); wire faults live in the router's [`crate::fleet`] stats
    /// and bitflips in [`crate::faults::bitflips_injected`].
    faults_injected: AtomicU64,
    /// Recovery-plane retries spent on this node's traffic.
    retries: AtomicU64,
    /// Frames re-homed onto this node after another node died.
    rehomed: AtomicU64,
    /// Frames degraded to best-effort under sustained fault pressure.
    degraded: AtomicU64,
    classes: [ClassCounters; QosClass::COUNT],
    inner: Mutex<Aggregates>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            arch_mismatches: AtomicU64::new(0),
            cross_checked: AtomicU64::new(0),
            cross_check_mismatches: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            faults_injected: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            rehomed: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            classes: Default::default(),
            inner: Mutex::new(Aggregates {
                all: Reservoir::default(),
                per_class: Default::default(),
                per_model: BTreeMap::new(),
                rng: Xoshiro256::new(0x6c62_7031),
                energy: EnergyBreakdown::default(),
                per_class_energy: Default::default(),
                arch_time_ns: 0.0,
                hw_profile: String::new(),
            }),
        }
    }
}

/// Per-(class, model) slice of the mutex-guarded aggregates: which
/// model a frame was served by matters to capacity planning the moment
/// a server hosts more than one (`Server::push_model`).
#[derive(Default)]
struct ModelAgg {
    completed: u64,
    failed: u64,
    dropped: u64,
    latency: Reservoir,
    energy: EnergyBreakdown,
}

struct Aggregates {
    /// Uniform latency sample across every class.
    all: Reservoir,
    /// Per-class latency samples, indexed by [`QosClass::index`].
    per_class: [Reservoir; QosClass::COUNT],
    /// Per-(class index, model id) accounts, populated lazily as
    /// traffic for each pair arrives.
    per_model: BTreeMap<(usize, u32), ModelAgg>,
    rng: Xoshiro256,
    energy: EnergyBreakdown,
    /// Per-class energy accounts, indexed by [`QosClass::index`].
    per_class_energy: [EnergyBreakdown; QosClass::COUNT],
    arch_time_ns: f64,
    /// Hardware profile stamped on completed frames' telemetry ("" until
    /// the first modeled completion, "mixed" if profiles disagree).
    hw_profile: String,
}

impl Metrics {
    pub fn record_accepted(&self, class: QosClass) {
        self.classes[class.index()]
            .accepted
            .fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self, class: QosClass) {
        self.classes[class.index()]
            .rejected
            .fetch_add(1, Ordering::Relaxed);
    }

    /// A request was shed: displaced by drop-oldest admission, or its
    /// per-request deadline expired before dispatch.
    pub fn record_dropped(&self, class: QosClass, model_id: u32) {
        self.classes[class.index()]
            .dropped
            .fetch_add(1, Ordering::Relaxed);
        let mut agg = self.inner.lock().unwrap();
        agg.per_model
            .entry((class.index(), model_id))
            .or_default()
            .dropped += 1;
    }

    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    /// A fault-plan injection fired on this node (shard stall or panic).
    pub fn record_fault(&self) {
        self.faults_injected.fetch_add(1, Ordering::Relaxed);
    }

    /// A recovery retry was spent on behalf of this node's traffic.
    pub fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// A frame was re-homed onto this node after a peer died.
    pub fn record_rehomed(&self) {
        self.rehomed.fetch_add(1, Ordering::Relaxed);
    }

    /// A frame was degraded to best-effort under fault pressure.
    pub fn record_degraded(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_failure(&self, class: QosClass, model_id: u32) {
        self.classes[class.index()]
            .failed
            .fetch_add(1, Ordering::Relaxed);
        let mut agg = self.inner.lock().unwrap();
        agg.per_model
            .entry((class.index(), model_id))
            .or_default()
            .failed += 1;
    }

    /// One frame finished: queue→response latency plus its engine output.
    pub fn record_completion(&self, class: QosClass, model_id: u32,
                             latency: Duration, report: &FrameOutput) {
        self.classes[class.index()]
            .completed
            .fetch_add(1, Ordering::Relaxed);
        self.arch_mismatches
            .fetch_add(report.telemetry.arch_mismatches, Ordering::Relaxed);
        self.cross_checked
            .fetch_add(report.telemetry.cross_check_frames, Ordering::Relaxed);
        self.cross_check_mismatches.fetch_add(
            report.telemetry.cross_check_mismatches,
            Ordering::Relaxed,
        );
        let mut agg = self.inner.lock().unwrap();
        let ns = latency.as_nanos() as u64;
        let agg = &mut *agg;
        agg.all.offer(ns, &mut agg.rng);
        agg.per_class[class.index()].offer(ns, &mut agg.rng);
        let model = agg.per_model
            .entry((class.index(), model_id))
            .or_default();
        model.completed += 1;
        model.latency.offer(ns, &mut agg.rng);
        model.energy.add(&report.telemetry.cost.energy);
        agg.energy.add(&report.telemetry.cost.energy);
        agg.per_class_energy[class.index()]
            .add(&report.telemetry.cost.energy);
        agg.arch_time_ns += report.telemetry.cost.time_ns;
        crate::engine::Telemetry::merge_profile_label(
            &mut agg.hw_profile,
            &report.telemetry.profile,
        );
    }

    pub fn completed(&self) -> u64 {
        self.classes
            .iter()
            .map(|c| c.completed.load(Ordering::Relaxed))
            .sum()
    }

    pub fn rejected(&self) -> u64 {
        self.classes
            .iter()
            .map(|c| c.rejected.load(Ordering::Relaxed))
            .sum()
    }

    pub fn dropped(&self) -> u64 {
        self.classes
            .iter()
            .map(|c| c.dropped.load(Ordering::Relaxed))
            .sum()
    }

    /// Admitted requests not yet resolved (completed/dropped/failed) —
    /// the tracing sampler's per-class in-flight gauge.  Counters are
    /// updated independently, so a momentarily-torn read can undercount;
    /// the subtraction saturates instead of wrapping.
    pub fn in_flight(&self, class: QosClass) -> u64 {
        let c = &self.classes[class.index()];
        let resolved = c.completed.load(Ordering::Relaxed)
            + c.dropped.load(Ordering::Relaxed)
            + c.failed.load(Ordering::Relaxed);
        c.accepted.load(Ordering::Relaxed).saturating_sub(resolved)
    }

    fn accepted_total(&self) -> u64 {
        self.classes
            .iter()
            .map(|c| c.accepted.load(Ordering::Relaxed))
            .sum()
    }

    fn failed_total(&self) -> u64 {
        self.classes
            .iter()
            .map(|c| c.failed.load(Ordering::Relaxed))
            .sum()
    }

    /// Freeze a report over everything recorded so far.
    pub fn snapshot(&self, wall: Duration) -> MetricsReport {
        let agg = self.inner.lock().unwrap();
        let lat = agg.all.sorted();
        let completed = self.completed();
        let batches = self.batches.load(Ordering::Relaxed);
        let wall_seconds = wall.as_secs_f64();
        let per_class = QosClass::ALL
            .iter()
            .map(|&class| {
                let c = &self.classes[class.index()];
                let lat = agg.per_class[class.index()].sorted();
                let completed = c.completed.load(Ordering::Relaxed);
                let energy_pj = agg.per_class_energy[class.index()].total_pj();
                ClassReport {
                    class,
                    accepted: c.accepted.load(Ordering::Relaxed),
                    rejected: c.rejected.load(Ordering::Relaxed),
                    dropped: c.dropped.load(Ordering::Relaxed),
                    completed,
                    failed: c.failed.load(Ordering::Relaxed),
                    p50_ms: percentile_ns(&lat, 0.50) as f64 / 1e6,
                    p95_ms: percentile_ns(&lat, 0.95) as f64 / 1e6,
                    p99_ms: percentile_ns(&lat, 0.99) as f64 / 1e6,
                    max_ms: lat.last().copied().unwrap_or(0) as f64 / 1e6,
                    energy_uj: energy_pj / 1e6,
                    energy_per_frame_uj: if completed == 0 {
                        0.0
                    } else {
                        energy_pj / 1e6 / completed as f64
                    },
                }
            })
            .collect();
        let per_model = agg.per_model
            .iter()
            .map(|(&(class_idx, model_id), m)| {
                let lat = m.latency.sorted();
                let energy_pj = m.energy.total_pj();
                ModelReport {
                    model_id,
                    class: QosClass::ALL[class_idx],
                    completed: m.completed,
                    failed: m.failed,
                    dropped: m.dropped,
                    p50_ms: percentile_ns(&lat, 0.50) as f64 / 1e6,
                    p99_ms: percentile_ns(&lat, 0.99) as f64 / 1e6,
                    energy_uj: energy_pj / 1e6,
                    energy_per_frame_uj: if m.completed == 0 {
                        0.0
                    } else {
                        energy_pj / 1e6 / m.completed as f64
                    },
                }
            })
            .collect();
        MetricsReport {
            hw_profile: agg.hw_profile.clone(),
            accepted: self.accepted_total(),
            rejected: self.rejected(),
            dropped: self.dropped(),
            completed,
            failed: self.failed_total(),
            arch_mismatches: self.arch_mismatches.load(Ordering::Relaxed),
            cross_checked: self.cross_checked.load(Ordering::Relaxed),
            cross_check_mismatches: self
                .cross_check_mismatches
                .load(Ordering::Relaxed),
            faults_injected: self.faults_injected.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            rehomed: self.rehomed.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 {
                0.0
            } else {
                completed as f64 / batches as f64
            },
            p50_ms: percentile_ns(&lat, 0.50) as f64 / 1e6,
            p95_ms: percentile_ns(&lat, 0.95) as f64 / 1e6,
            p99_ms: percentile_ns(&lat, 0.99) as f64 / 1e6,
            max_ms: lat.last().copied().unwrap_or(0) as f64 / 1e6,
            wall_seconds,
            throughput_fps: if wall_seconds > 0.0 {
                completed as f64 / wall_seconds
            } else {
                0.0
            },
            energy_per_frame_uj: if completed == 0 {
                0.0
            } else {
                agg.energy.total_pj() / 1e6 / completed as f64
            },
            total_arch_time_ns: agg.arch_time_ns,
            per_class,
            per_model,
        }
    }
}

/// Nearest-rank percentile over an ascending-sorted slice (0 on empty).
pub fn percentile_ns(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize)
        .clamp(1, sorted.len())
        - 1;
    sorted[idx]
}

/// One QoS class's slice of a [`MetricsReport`].
#[derive(Clone, Debug)]
pub struct ClassReport {
    pub class: QosClass,
    pub accepted: u64,
    pub rejected: u64,
    /// Drop-oldest displacements plus per-request-deadline expiries.
    pub dropped: u64,
    pub completed: u64,
    pub failed: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    /// Total energy this class's completed frames cost under the active
    /// hardware profile [µJ].
    pub energy_uj: f64,
    /// `energy_uj / completed` (0 with no completions).
    pub energy_per_frame_uj: f64,
}

impl ClassReport {
    /// Any traffic at all in this class?
    pub fn active(&self) -> bool {
        self.accepted + self.rejected + self.dropped + self.failed > 0
    }
}

/// One (class, model) pair's slice of a [`MetricsReport`] — present only
/// for pairs that saw traffic (model 0 is the server's from-params
/// default; higher ids are artifacts registered via
/// `Server::push_model`).
#[derive(Clone, Debug)]
pub struct ModelReport {
    pub model_id: u32,
    pub class: QosClass,
    pub completed: u64,
    pub failed: u64,
    /// Drop-oldest displacements plus deadline expiries.
    pub dropped: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Total energy this pair's completed frames cost [µJ].
    pub energy_uj: f64,
    /// `energy_uj / completed` (0 with no completions).
    pub energy_per_frame_uj: f64,
}

/// Frozen metrics for one serving run.
#[derive(Clone, Debug, Default)]
pub struct MetricsReport {
    /// Hardware profile that priced the energy numbers ("" when nothing
    /// was modeled, "mixed" when completions disagree).
    pub hw_profile: String,
    pub accepted: u64,
    pub rejected: u64,
    /// Requests shed after admission (drop-oldest / deadline expiry).
    pub dropped: u64,
    pub completed: u64,
    pub failed: u64,
    pub arch_mismatches: u64,
    /// Frames cross-checked against the engine's reference backend.
    pub cross_checked: u64,
    /// Frames whose logits diverged from the reference backend (must be 0).
    pub cross_check_mismatches: u64,
    /// Chaos-plane injections this node's shards executed (stalls +
    /// panics); 0 whenever `[faults]` is disabled.
    pub faults_injected: u64,
    /// Recovery retries spent on this node's traffic.
    pub retries: u64,
    /// Frames re-homed onto this node after a peer died.
    pub rehomed: u64,
    /// Frames degraded to best-effort under sustained fault pressure.
    pub degraded: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub wall_seconds: f64,
    /// Host throughput: completed frames / wall clock.
    pub throughput_fps: f64,
    pub energy_per_frame_uj: f64,
    /// Summed modeled accelerator busy time across shards [ns].
    pub total_arch_time_ns: f64,
    /// Per-class breakdown, one entry per [`QosClass`] in `ALL` order
    /// (empty only on a `Default`-constructed report).
    pub per_class: Vec<ClassReport>,
    /// Per-(class, model) breakdown, one entry per pair that saw
    /// traffic, ordered by (class index, model id).
    pub per_model: Vec<ModelReport>,
}

impl MetricsReport {
    /// This class's slice of the report, if the report carries one.
    pub fn class(&self, class: QosClass) -> Option<&ClassReport> {
        self.per_class.iter().find(|r| r.class == class)
    }

    /// This (class, model) pair's slice, if it saw any traffic.
    pub fn model(&self, class: QosClass, model_id: u32)
                 -> Option<&ModelReport> {
        self.per_model
            .iter()
            .find(|r| r.class == class && r.model_id == model_id)
    }

    /// Modeled accelerator throughput with `shards` slices running
    /// concurrently (busy time is summed, so divide it back out).
    pub fn modeled_fps(&self, shards: usize) -> f64 {
        if self.total_arch_time_ns <= 0.0 || self.completed == 0 {
            return 0.0;
        }
        let per_shard_ns = self.total_arch_time_ns / shards.max(1) as f64;
        self.completed as f64 / (per_shard_ns * 1e-9)
    }

    pub fn print(&self, label: &str) {
        println!("== serve report: {label} ==");
        println!(
            "  requests  : {} accepted, {} rejected, {} dropped, \
             {} completed, {} failed",
            self.accepted, self.rejected, self.dropped, self.completed,
            self.failed
        );
        println!(
            "  batches   : {} dispatched, {:.1} frames/batch mean",
            self.batches, self.mean_batch
        );
        println!(
            "  latency   : p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms | \
             max {:.2} ms",
            self.p50_ms, self.p95_ms, self.p99_ms, self.max_ms
        );
        for c in self.per_class.iter().filter(|c| c.active()) {
            println!(
                "  {:<10}: {} ok / {} rej / {} drop | p50 {:.2} ms | \
                 p95 {:.2} ms | p99 {:.2} ms | {:.3} µJ/frame",
                c.class.as_str(), c.completed, c.rejected, c.dropped,
                c.p50_ms, c.p95_ms, c.p99_ms, c.energy_per_frame_uj
            );
        }
        if self.per_model.iter().any(|m| m.model_id != 0) {
            // only worth a breakdown once a non-default model served
            for m in &self.per_model {
                println!(
                    "  model {:>4} @ {:<11}: {} ok / {} fail / {} drop | \
                     p50 {:.2} ms | p99 {:.2} ms | {:.3} µJ/frame",
                    m.model_id, m.class.as_str(), m.completed, m.failed,
                    m.dropped, m.p50_ms, m.p99_ms, m.energy_per_frame_uj
                );
            }
        }
        println!(
            "  throughput: {:.1} frames/s over {:.2} s wall",
            self.throughput_fps, self.wall_seconds
        );
        println!(
            "  energy    : {:.3} µJ/frame under profile {:?} | \
             arch mismatches {}",
            self.energy_per_frame_uj,
            if self.hw_profile.is_empty() { "unmodeled" }
            else { &self.hw_profile },
            self.arch_mismatches
        );
        if self.cross_checked > 0 {
            println!(
                "  cross-chk : {} frames checked, {} mismatches",
                self.cross_checked, self.cross_check_mismatches
            );
        }
        if self.faults_injected + self.retries + self.rehomed
            + self.degraded > 0
        {
            println!(
                "  chaos     : {} faults injected | {} retries | \
                 {} rehomed | {} degraded",
                self.faults_injected, self.retries, self.rehomed,
                self.degraded
            );
        }
    }

    /// Machine-readable report (`serve-bench --json`): counters, global
    /// and per-class latency percentiles, throughput, and energy, so CI
    /// can track a serve trajectory across PRs.  Emission goes through
    /// [`crate::obs::json`], so strings are escaped (`hw_profile` is
    /// user-suppliable via `[hw] profile = path`) and numbers are never
    /// `NaN`/`inf` — the output is always valid JSON.
    pub fn to_json(&self) -> String {
        use crate::obs::json as j;

        let mut s = String::from("{");
        j::push_str_field(&mut s, "hw_profile", &self.hw_profile);
        j::push_u64_field(&mut s, "accepted", self.accepted);
        j::push_u64_field(&mut s, "rejected", self.rejected);
        j::push_u64_field(&mut s, "dropped", self.dropped);
        j::push_u64_field(&mut s, "completed", self.completed);
        j::push_u64_field(&mut s, "failed", self.failed);
        j::push_u64_field(&mut s, "batches", self.batches);
        j::push_f64_field(&mut s, "mean_batch", self.mean_batch);
        s.push_str("\"latency_ms\":{");
        j::push_f64_field(&mut s, "p50", self.p50_ms);
        j::push_f64_field(&mut s, "p95", self.p95_ms);
        j::push_f64_field(&mut s, "p99", self.p99_ms);
        j::push_f64_field(&mut s, "max", self.max_ms);
        s.pop();
        s.push_str("},");
        j::push_f64_field(&mut s, "wall_seconds", self.wall_seconds);
        j::push_f64_field(&mut s, "throughput_fps", self.throughput_fps);
        j::push_f64_field(&mut s, "energy_per_frame_uj",
                          self.energy_per_frame_uj);
        j::push_f64_field(&mut s, "total_arch_time_ns",
                          self.total_arch_time_ns);
        j::push_u64_field(&mut s, "arch_mismatches", self.arch_mismatches);
        j::push_u64_field(&mut s, "cross_checked", self.cross_checked);
        j::push_u64_field(&mut s, "cross_check_mismatches",
                          self.cross_check_mismatches);
        j::push_u64_field(&mut s, "faults_injected", self.faults_injected);
        j::push_u64_field(&mut s, "retries", self.retries);
        j::push_u64_field(&mut s, "rehomed", self.rehomed);
        j::push_u64_field(&mut s, "degraded", self.degraded);
        s.push_str("\"per_class\":[");
        for (i, c) in self.per_class.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push('{');
            j::push_str_field(&mut s, "class", c.class.as_str());
            j::push_u64_field(&mut s, "accepted", c.accepted);
            j::push_u64_field(&mut s, "rejected", c.rejected);
            j::push_u64_field(&mut s, "dropped", c.dropped);
            j::push_u64_field(&mut s, "completed", c.completed);
            j::push_u64_field(&mut s, "failed", c.failed);
            j::push_f64_field(&mut s, "p50_ms", c.p50_ms);
            j::push_f64_field(&mut s, "p95_ms", c.p95_ms);
            j::push_f64_field(&mut s, "p99_ms", c.p99_ms);
            j::push_f64_field(&mut s, "max_ms", c.max_ms);
            j::push_f64_field(&mut s, "energy_uj", c.energy_uj);
            j::push_f64_field(&mut s, "energy_per_frame_uj",
                              c.energy_per_frame_uj);
            s.pop();
            s.push('}');
        }
        s.push_str("],\"per_model\":[");
        for (i, m) in self.per_model.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push('{');
            j::push_u64_field(&mut s, "model_id", m.model_id as u64);
            j::push_str_field(&mut s, "class", m.class.as_str());
            j::push_u64_field(&mut s, "completed", m.completed);
            j::push_u64_field(&mut s, "failed", m.failed);
            j::push_u64_field(&mut s, "dropped", m.dropped);
            j::push_f64_field(&mut s, "p50_ms", m.p50_ms);
            j::push_f64_field(&mut s, "p99_ms", m.p99_ms);
            j::push_f64_field(&mut s, "energy_uj", m.energy_uj);
            j::push_f64_field(&mut s, "energy_per_frame_uj",
                              m.energy_per_frame_uj);
            s.pop();
            s.push('}');
        }
        s.push_str("]}");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_ns(&v, 0.50), 50);
        assert_eq!(percentile_ns(&v, 0.95), 95);
        assert_eq!(percentile_ns(&v, 0.99), 99);
        assert_eq!(percentile_ns(&v, 1.0), 100);
        assert_eq!(percentile_ns(&[7], 0.99), 7);
        assert_eq!(percentile_ns(&[], 0.5), 0);
    }

    fn report(arch_time_ns: f64) -> FrameOutput {
        FrameOutput {
            seq: 0,
            predicted: 0,
            logits: vec![],
            features: None,
            telemetry: crate::engine::Telemetry {
                profile: "ns_lbp_65nm".into(),
                cost: crate::hw::Cost {
                    energy: EnergyBreakdown {
                        compute_pj: 2e6, // 2 µJ
                        ..Default::default()
                    },
                    time_ns: arch_time_ns,
                },
                ..Default::default()
            },
        }
    }

    #[test]
    fn latency_reservoir_stays_bounded() {
        let m = Metrics::default();
        let report = report(0.0);
        let n = LATENCY_RESERVOIR as u64 + 5000;
        for i in 0..n {
            m.record_completion(QosClass::Standard, 0,
                                Duration::from_nanos(i + 1), &report);
        }
        let agg = m.inner.lock().unwrap();
        assert_eq!(agg.all.samples.len(), LATENCY_RESERVOIR);
        assert_eq!(agg.all.seen, n);
        let cls = &agg.per_class[QosClass::Standard.index()];
        assert_eq!(cls.samples.len(), LATENCY_RESERVOIR);
        // every retained sample is a real observation
        assert!(agg.all.samples.iter().all(|&v| v >= 1 && v <= n));
        assert!(cls.samples.iter().all(|&v| v >= 1 && v <= n));
    }

    #[test]
    fn counters_and_snapshot_split_per_class() {
        let m = Metrics::default();
        m.record_accepted(QosClass::Standard);
        m.record_accepted(QosClass::Standard);
        m.record_accepted(QosClass::Billed);
        m.record_rejected(QosClass::Standard);
        m.record_dropped(QosClass::BestEffort, 0);
        m.record_batch();
        let report = report(1000.0);
        m.record_completion(QosClass::Standard, 0, Duration::from_millis(2),
                            &report);
        m.record_completion(QosClass::Billed, 0, Duration::from_millis(4),
                            &report);
        let s = m.snapshot(Duration::from_secs(1));
        assert_eq!(s.accepted, 3);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.dropped, 1);
        assert_eq!(s.completed, 2);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch - 2.0).abs() < 1e-12);
        assert!((s.p50_ms - 2.0).abs() < 0.5);
        assert!((s.max_ms - 4.0).abs() < 0.5);
        assert!((s.throughput_fps - 2.0).abs() < 1e-9);
        assert!((s.total_arch_time_ns - 2000.0).abs() < 1e-9);
        assert!(s.modeled_fps(2) > s.modeled_fps(1) * 1.99);
        assert_eq!(s.hw_profile, "ns_lbp_65nm");
        // per-class slices
        assert_eq!(s.per_class.len(), QosClass::COUNT);
        let std_c = s.class(QosClass::Standard).unwrap();
        assert_eq!(std_c.accepted, 2);
        assert_eq!(std_c.rejected, 1);
        assert_eq!(std_c.completed, 1);
        assert!((std_c.p50_ms - 2.0).abs() < 0.5);
        // per-class energy under the active profile
        assert!((std_c.energy_uj - 2.0).abs() < 1e-9);
        assert!((std_c.energy_per_frame_uj - 2.0).abs() < 1e-9);
        let billed = s.class(QosClass::Billed).unwrap();
        assert_eq!(billed.completed, 1);
        assert!((billed.p50_ms - 4.0).abs() < 0.5);
        assert!((billed.energy_uj - 2.0).abs() < 1e-9);
        assert!((s.energy_per_frame_uj - 2.0).abs() < 1e-9);
        let be = s.class(QosClass::BestEffort).unwrap();
        assert_eq!(be.dropped, 1);
        assert_eq!(be.completed, 0);
        assert!(be.active());
    }

    #[test]
    fn reservoir_percentiles_match_exact_below_cap() {
        // parity: on runs with <= LATENCY_RESERVOIR completions the
        // reservoir retains *every* sample, so the report's p50/p95/p99
        // must equal the exact nearest-rank percentiles of the full
        // latency sequence — no sampling error at all below the cap
        let m = Metrics::default();
        let rep = report(0.0);
        // a deliberately lumpy (non-uniform, unsorted) latency sequence
        let mut exact: Vec<u64> = Vec::new();
        let mut x: u64 = 0x2545_f491_4f6c_dd1d;
        for _ in 0..10_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let ns = 1_000 + (x % 5_000_000);
            exact.push(ns);
            m.record_completion(QosClass::Standard, 0,
                                Duration::from_nanos(ns), &rep);
        }
        exact.sort_unstable();
        let s = m.snapshot(Duration::from_secs(1));
        for (q, got_ms) in [(0.50, s.p50_ms), (0.95, s.p95_ms),
                            (0.99, s.p99_ms)] {
            let want_ms = percentile_ns(&exact, q) as f64 / 1e6;
            assert!((got_ms - want_ms).abs() < 1e-12,
                    "p{q}: report {got_ms} vs exact {want_ms}");
        }
        assert!((s.max_ms - *exact.last().unwrap() as f64 / 1e6).abs()
                    < 1e-12);
    }

    #[test]
    fn json_escapes_hostile_hw_profile() {
        // hw_profile is user-suppliable ([hw] profile = path): quotes
        // and backslashes in it must not break the JSON document
        let mut s = MetricsReport {
            hw_profile: "evil\"profile\\with\ncontrols".into(),
            ..MetricsReport::default()
        };
        s.mean_batch = f64::NAN; // non-finite must not leak either
        let json = s.to_json();
        assert!(json.contains(
            "\"hw_profile\":\"evil\\\"profile\\\\with\\ncontrols\""
        ));
        assert!(json.contains("\"mean_batch\":0"));
        assert!(!json.contains("NaN"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn in_flight_tracks_unresolved_admissions() {
        let m = Metrics::default();
        assert_eq!(m.in_flight(QosClass::Standard), 0);
        m.record_accepted(QosClass::Standard);
        m.record_accepted(QosClass::Standard);
        m.record_accepted(QosClass::Standard);
        assert_eq!(m.in_flight(QosClass::Standard), 3);
        m.record_completion(QosClass::Standard, 0, Duration::from_millis(1),
                            &report(0.0));
        m.record_dropped(QosClass::Standard, 0);
        assert_eq!(m.in_flight(QosClass::Standard), 1);
        m.record_failure(QosClass::Standard, 0);
        assert_eq!(m.in_flight(QosClass::Standard), 0);
        // other classes unaffected
        assert_eq!(m.in_flight(QosClass::Billed), 0);
    }

    #[test]
    fn json_report_is_well_formed_and_carries_classes() {
        let m = Metrics::default();
        m.record_accepted(QosClass::Billed);
        m.record_batch();
        m.record_completion(QosClass::Billed, 2, Duration::from_millis(3),
                            &report(500.0));
        let s = m.snapshot(Duration::from_secs(1));
        let json = s.to_json();
        // structural sanity without a JSON parser: balanced braces and
        // the expected keys present
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        for key in ["\"accepted\":", "\"latency_ms\":", "\"per_class\":",
                    "\"throughput_fps\":", "\"energy_per_frame_uj\":",
                    "\"class\":\"billed\"", "\"energy_uj\":",
                    "\"hw_profile\":\"ns_lbp_65nm\"", "\"per_model\":",
                    "\"model_id\":2"] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(json.starts_with('{') && json.ends_with('}'));
    }

    #[test]
    fn per_model_breakdown_splits_traffic() {
        let m = Metrics::default();
        let rep = report(100.0);
        // two models under one class, plus one model under another class
        m.record_completion(QosClass::Standard, 0,
                            Duration::from_millis(2), &rep);
        m.record_completion(QosClass::Standard, 0,
                            Duration::from_millis(2), &rep);
        m.record_completion(QosClass::Standard, 7,
                            Duration::from_millis(8), &rep);
        m.record_completion(QosClass::Billed, 7,
                            Duration::from_millis(1), &rep);
        m.record_failure(QosClass::Standard, 7);
        m.record_dropped(QosClass::Standard, 7);
        let s = m.snapshot(Duration::from_secs(1));
        assert_eq!(s.per_model.len(), 3);
        let d = s.model(QosClass::Standard, 0).unwrap();
        assert_eq!((d.completed, d.failed, d.dropped), (2, 0, 0));
        assert!((d.p50_ms - 2.0).abs() < 0.5);
        // each completion carried 2 µJ of compute energy
        assert!((d.energy_uj - 4.0).abs() < 1e-9);
        assert!((d.energy_per_frame_uj - 2.0).abs() < 1e-9);
        let m7 = s.model(QosClass::Standard, 7).unwrap();
        assert_eq!((m7.completed, m7.failed, m7.dropped), (1, 1, 1));
        assert!((m7.p50_ms - 8.0).abs() < 0.5);
        let b7 = s.model(QosClass::Billed, 7).unwrap();
        assert_eq!(b7.completed, 1);
        assert!(s.model(QosClass::Billed, 0).is_none());
        // pair ordering is (class index, model id)
        let order: Vec<(QosClass, u32)> =
            s.per_model.iter().map(|m| (m.class, m.model_id)).collect();
        let mut sorted = order.clone();
        sorted.sort_by_key(|&(c, id)| (c.index(), id));
        assert_eq!(order, sorted);
        // the aggregate view is untouched by the split
        assert_eq!(s.completed, 4);
        let json = s.to_json();
        assert!(json.contains("\"model_id\":7"), "{json}");
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }
}
