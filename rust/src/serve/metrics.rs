//! Serving metrics: request counters, per-request latency percentiles,
//! throughput, and the accelerator's energy/time account aggregated
//! across shards.
//!
//! Counters are atomics (touched on every request); the latency
//! reservoir and energy accumulators sit behind one mutex that is taken
//! once per *completed* frame — far off the admission hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::energy::EnergyBreakdown;
use crate::engine::FrameOutput;
use crate::rng::Xoshiro256;

/// Latency samples kept for percentile estimation.  Beyond this the
/// sink switches to uniform reservoir sampling (Vitter's Algorithm R),
/// so an always-on server holds O(1) memory no matter how many frames
/// it has served.
pub const LATENCY_RESERVOIR: usize = 1 << 16;

/// Shared metrics sink for one server instance.
pub struct Metrics {
    accepted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    arch_mismatches: AtomicU64,
    cross_checked: AtomicU64,
    cross_check_mismatches: AtomicU64,
    batches: AtomicU64,
    inner: Mutex<Aggregates>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            arch_mismatches: AtomicU64::new(0),
            cross_checked: AtomicU64::new(0),
            cross_check_mismatches: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            inner: Mutex::new(Aggregates {
                latencies_ns: Vec::new(),
                samples_seen: 0,
                rng: Xoshiro256::new(0x6c62_7031),
                energy: EnergyBreakdown::default(),
                arch_time_ns: 0.0,
            }),
        }
    }
}

struct Aggregates {
    /// Uniform sample of per-request latencies (≤ [`LATENCY_RESERVOIR`]).
    latencies_ns: Vec<u64>,
    /// Completions offered to the reservoir so far.
    samples_seen: u64,
    rng: Xoshiro256,
    energy: EnergyBreakdown,
    arch_time_ns: f64,
}

impl Metrics {
    pub fn record_accepted(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_failure(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// One frame finished: queue→response latency plus its engine output.
    pub fn record_completion(&self, latency: Duration, report: &FrameOutput) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.arch_mismatches
            .fetch_add(report.telemetry.arch_mismatches, Ordering::Relaxed);
        self.cross_checked
            .fetch_add(report.telemetry.cross_check_frames, Ordering::Relaxed);
        self.cross_check_mismatches.fetch_add(
            report.telemetry.cross_check_mismatches,
            Ordering::Relaxed,
        );
        let mut agg = self.inner.lock().unwrap();
        let ns = latency.as_nanos() as u64;
        agg.samples_seen += 1;
        if agg.latencies_ns.len() < LATENCY_RESERVOIR {
            agg.latencies_ns.push(ns);
        } else {
            // Algorithm R: keep each of the n samples with prob. cap/n
            let j = agg.rng.below(agg.samples_seen);
            if (j as usize) < LATENCY_RESERVOIR {
                agg.latencies_ns[j as usize] = ns;
            }
        }
        agg.energy.add(&report.telemetry.energy);
        agg.arch_time_ns += report.telemetry.arch_time_ns;
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Freeze a report over everything recorded so far.
    pub fn snapshot(&self, wall: Duration) -> MetricsReport {
        let agg = self.inner.lock().unwrap();
        let mut lat = agg.latencies_ns.clone();
        lat.sort_unstable();
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let wall_seconds = wall.as_secs_f64();
        MetricsReport {
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed,
            failed: self.failed.load(Ordering::Relaxed),
            arch_mismatches: self.arch_mismatches.load(Ordering::Relaxed),
            cross_checked: self.cross_checked.load(Ordering::Relaxed),
            cross_check_mismatches: self
                .cross_check_mismatches
                .load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 {
                0.0
            } else {
                completed as f64 / batches as f64
            },
            p50_ms: percentile_ns(&lat, 0.50) as f64 / 1e6,
            p95_ms: percentile_ns(&lat, 0.95) as f64 / 1e6,
            p99_ms: percentile_ns(&lat, 0.99) as f64 / 1e6,
            max_ms: lat.last().copied().unwrap_or(0) as f64 / 1e6,
            wall_seconds,
            throughput_fps: if wall_seconds > 0.0 {
                completed as f64 / wall_seconds
            } else {
                0.0
            },
            energy_per_frame_uj: if completed == 0 {
                0.0
            } else {
                agg.energy.total_pj() / 1e6 / completed as f64
            },
            total_arch_time_ns: agg.arch_time_ns,
        }
    }
}

/// Nearest-rank percentile over an ascending-sorted slice (0 on empty).
pub fn percentile_ns(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize)
        .clamp(1, sorted.len())
        - 1;
    sorted[idx]
}

/// Frozen metrics for one serving run.
#[derive(Clone, Debug, Default)]
pub struct MetricsReport {
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub arch_mismatches: u64,
    /// Frames cross-checked against the engine's reference backend.
    pub cross_checked: u64,
    /// Frames whose logits diverged from the reference backend (must be 0).
    pub cross_check_mismatches: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub wall_seconds: f64,
    /// Host throughput: completed frames / wall clock.
    pub throughput_fps: f64,
    pub energy_per_frame_uj: f64,
    /// Summed modeled accelerator busy time across shards [ns].
    pub total_arch_time_ns: f64,
}

impl MetricsReport {
    /// Modeled accelerator throughput with `shards` slices running
    /// concurrently (busy time is summed, so divide it back out).
    pub fn modeled_fps(&self, shards: usize) -> f64 {
        if self.total_arch_time_ns <= 0.0 || self.completed == 0 {
            return 0.0;
        }
        let per_shard_ns = self.total_arch_time_ns / shards.max(1) as f64;
        self.completed as f64 / (per_shard_ns * 1e-9)
    }

    pub fn print(&self, label: &str) {
        println!("== serve report: {label} ==");
        println!(
            "  requests  : {} accepted, {} rejected, {} completed, {} failed",
            self.accepted, self.rejected, self.completed, self.failed
        );
        println!(
            "  batches   : {} dispatched, {:.1} frames/batch mean",
            self.batches, self.mean_batch
        );
        println!(
            "  latency   : p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms | \
             max {:.2} ms",
            self.p50_ms, self.p95_ms, self.p99_ms, self.max_ms
        );
        println!(
            "  throughput: {:.1} frames/s over {:.2} s wall",
            self.throughput_fps, self.wall_seconds
        );
        println!(
            "  energy    : {:.3} µJ/frame | arch mismatches {}",
            self.energy_per_frame_uj, self.arch_mismatches
        );
        if self.cross_checked > 0 {
            println!(
                "  cross-chk : {} frames checked, {} mismatches",
                self.cross_checked, self.cross_check_mismatches
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_ns(&v, 0.50), 50);
        assert_eq!(percentile_ns(&v, 0.95), 95);
        assert_eq!(percentile_ns(&v, 0.99), 99);
        assert_eq!(percentile_ns(&v, 1.0), 100);
        assert_eq!(percentile_ns(&[7], 0.99), 7);
        assert_eq!(percentile_ns(&[], 0.5), 0);
    }

    fn report(arch_time_ns: f64) -> FrameOutput {
        FrameOutput {
            seq: 0,
            predicted: 0,
            logits: vec![],
            features: None,
            telemetry: crate::engine::Telemetry {
                arch_time_ns,
                ..Default::default()
            },
        }
    }

    #[test]
    fn latency_reservoir_stays_bounded() {
        let m = Metrics::default();
        let report = report(0.0);
        let n = LATENCY_RESERVOIR as u64 + 5000;
        for i in 0..n {
            m.record_completion(Duration::from_nanos(i + 1), &report);
        }
        let agg = m.inner.lock().unwrap();
        assert_eq!(agg.latencies_ns.len(), LATENCY_RESERVOIR);
        assert_eq!(agg.samples_seen, n);
        // every retained sample is a real observation
        assert!(agg.latencies_ns.iter().all(|&v| v >= 1 && v <= n));
    }

    #[test]
    fn counters_and_snapshot() {
        let m = Metrics::default();
        m.record_accepted();
        m.record_accepted();
        m.record_rejected();
        m.record_batch();
        let report = report(1000.0);
        m.record_completion(Duration::from_millis(2), &report);
        m.record_completion(Duration::from_millis(4), &report);
        let s = m.snapshot(Duration::from_secs(1));
        assert_eq!(s.accepted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 2);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch - 2.0).abs() < 1e-12);
        assert!((s.p50_ms - 2.0).abs() < 0.5);
        assert!((s.max_ms - 4.0).abs() < 0.5);
        assert!((s.throughput_fps - 2.0).abs() < 1e-9);
        assert!((s.total_arch_time_ns - 2000.0).abs() < 1e-9);
        assert!(s.modeled_fps(2) > s.modeled_fps(1) * 1.99);
    }
}
