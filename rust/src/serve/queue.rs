//! Bounded MPMC queue with admission control (crossbeam is unavailable
//! offline; this is a Mutex + Condvar ring shared by producers and
//! consumers).
//!
//! Two properties matter to the serving layer:
//!
//! * **Backpressure is explicit.** [`BoundedQueue::try_push`] never
//!   blocks: past the configured depth it hands the item back with
//!   [`PushError::Full`] so the caller can reject the request instead of
//!   letting an unbounded backlog destroy tail latency.  Producers that
//!   *are* allowed to wait (the batcher feeding shard workers) use
//!   [`BoundedQueue::push`].
//! * **Shutdown is a drain, not a drop.** [`BoundedQueue::close`] stops
//!   new work; consumers keep popping until the queue is empty and only
//!   then observe the closed state, so every admitted item is processed.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Wait on `cv` until `take` yields a value or `deadline` passes,
/// re-checking after every (possibly spurious) wakeup.  `take` runs
/// *before* the first deadline check, so a result that is already
/// available wins even when the deadline has already passed — the shared
/// contract of every bounded wait in the crate ([`BoundedQueue::
/// pop_timeout`], `Ticket::wait_timeout`, the fleet's ticket and control
/// slots).  Returns the guard so the caller can drop it before notifying
/// its own condvars.
pub(crate) fn wait_deadline<'a, T, R>(
    cv: &Condvar,
    mut g: MutexGuard<'a, T>,
    deadline: Instant,
    mut take: impl FnMut(&mut T) -> Option<R>,
) -> (MutexGuard<'a, T>, Option<R>) {
    loop {
        if let Some(r) = take(&mut g) {
            return (g, Some(r));
        }
        let now = Instant::now();
        if now >= deadline {
            return (g, None);
        }
        let (guard, _) = cv.wait_timeout(g, deadline - now).unwrap();
        g = guard;
    }
}

/// Why a non-blocking push was refused (the item is handed back).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushError {
    /// Queue at capacity — admission control rejected the item.
    Full,
    /// Queue closed for new work (draining / shut down).
    Closed,
}

/// Outcome of a bounded-wait pop.
#[derive(Debug)]
pub enum PopResult<T> {
    Item(T),
    /// Nothing arrived within the timeout; the queue is still open.
    TimedOut,
    /// Queue closed and fully drained.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer / multi-consumer queue.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    capacity: usize,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "queue capacity must be >= 1");
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            capacity,
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Non-blocking admission-controlled push; hands the item back when
    /// the queue is full or closed.
    pub fn try_push(&self, item: T) -> Result<(), (PushError, T)> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err((PushError::Closed, item));
        }
        if g.items.len() >= self.capacity {
            return Err((PushError::Full, item));
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push that never rejects for fullness: at capacity the
    /// *oldest* queued item is displaced and handed back (`Ok(Some(old))`)
    /// to make room — the admission policy of sensor classes that prefer
    /// fresh frames over queue completeness.  Only a closed queue refuses
    /// the item.
    pub fn push_dropping_oldest(&self, item: T)
                                -> Result<Option<T>, (PushError, T)> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err((PushError::Closed, item));
        }
        let displaced = if g.items.len() >= self.capacity {
            g.items.pop_front()
        } else {
            None
        };
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(displaced)
    }

    /// Blocking push: waits for space.  Returns the item back only if the
    /// queue is closed while waiting.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(item);
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop with a bounded wait (used by the batcher's deadline logic).
    /// An available item wins over the closed flag, and both win over an
    /// already-expired deadline (see [`wait_deadline`]).
    pub fn pop_timeout(&self, timeout: Duration) -> PopResult<T> {
        let deadline = Instant::now() + timeout;
        let g = self.inner.lock().unwrap();
        let (g, popped) =
            wait_deadline(&self.not_empty, g, deadline, |inner| {
                if let Some(item) = inner.items.pop_front() {
                    Some(PopResult::Item(item))
                } else if inner.closed {
                    Some(PopResult::Closed)
                } else {
                    None
                }
            });
        drop(g);
        match popped {
            Some(PopResult::Item(item)) => {
                self.not_full.notify_one();
                PopResult::Item(item)
            }
            Some(res) => res,
            None => PopResult::TimedOut,
        }
    }

    /// Close for new work; wakes every waiter so consumers can drain.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn full_queue_rejects_and_returns_item() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        let (err, item) = q.try_push(3).unwrap_err();
        assert_eq!(err, PushError::Full);
        assert_eq!(item, 3);
        assert_eq!(q.len(), 2);
        // space frees up after a pop
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).unwrap();
    }

    #[test]
    fn closed_queue_rejects_pushes_but_drains_pops() {
        let q = BoundedQueue::new(4);
        q.try_push("a").unwrap();
        q.close();
        assert!(q.is_closed());
        let (err, _) = q.try_push("b").unwrap_err();
        assert_eq!(err, PushError::Closed);
        assert_eq!(q.push("c"), Err("c"));
        // the admitted item still comes out, then Closed
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), None);
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)),
                         PopResult::Closed));
    }

    #[test]
    fn push_dropping_oldest_displaces_head_only_when_full() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.push_dropping_oldest(1).unwrap(), None);
        assert_eq!(q.push_dropping_oldest(2).unwrap(), None);
        // full: the oldest item comes back, the fresh one is queued
        assert_eq!(q.push_dropping_oldest(3).unwrap(), Some(1));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        q.close();
        let (err, item) = q.push_dropping_oldest(4).unwrap_err();
        assert_eq!(err, PushError::Closed);
        assert_eq!(item, 4);
    }

    #[test]
    fn wait_deadline_already_passed_still_takes_available_value() {
        let m = Mutex::new(Some(7u32));
        let cv = Condvar::new();
        let past = Instant::now() - Duration::from_millis(50);
        // value available: returned even though the deadline is long gone
        let (g, r) = wait_deadline(&cv, m.lock().unwrap(), past,
                                   |v: &mut Option<u32>| v.take());
        assert_eq!(r, Some(7));
        drop(g);
        // nothing available + deadline passed: immediate None, no wait
        let t0 = Instant::now();
        let (_g, r) = wait_deadline(&cv, m.lock().unwrap(), past,
                                    |v: &mut Option<u32>| v.take());
        assert_eq!(r, None);
        assert!(t0.elapsed() < Duration::from_millis(20));
    }

    #[test]
    fn pop_timeout_with_zero_timeout_still_pops_available_item() {
        let q = BoundedQueue::new(2);
        q.try_push(5u32).unwrap();
        assert!(matches!(q.pop_timeout(Duration::ZERO), PopResult::Item(5)));
        assert!(matches!(q.pop_timeout(Duration::ZERO), PopResult::TimedOut));
    }

    #[test]
    fn pop_timeout_expires_on_empty_open_queue() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        let t0 = Instant::now();
        assert!(matches!(q.pop_timeout(Duration::from_millis(20)),
                         PopResult::TimedOut));
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn blocking_push_waits_for_space() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(1u32).unwrap();
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || q2.push(2).is_ok());
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.pop(), Some(1));
        assert!(producer.join().unwrap());
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn mpmc_under_contention_delivers_everything_once() {
        let q = Arc::new(BoundedQueue::new(8));
        let n_items = 200u32;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..n_items / 4 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n_items as usize);
    }
}
