//! `faults::retry` — typed retry with jittered exponential backoff.
//!
//! Replaces the crate's hand-rolled sleep-and-retry admission loops with
//! one policy object: a retryable failure ([`Error::Serve`] — admission
//! backpressure by contract, see [`crate::error`]) backs off
//! exponentially with seeded jitter (so lockstep harness threads don't
//! re-collide) up to a hard attempt budget.  Every other error is
//! terminal and propagates untouched on the first occurrence.

use std::time::Duration;

use crate::error::{Error, Result};
use crate::rng::Xoshiro256;

/// Backoff shape plus attempt budget.  Durations are capped, jitter is a
/// symmetric fraction of the capped backoff.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// First backoff.
    pub base: Duration,
    /// Multiplier per subsequent retry.
    pub factor: f64,
    /// Ceiling on any single backoff.
    pub max_backoff: Duration,
    /// Jitter amplitude as a fraction of the backoff in [0, 1]: the
    /// slept time is `backoff * (1 ± jitter)`.
    pub jitter: f64,
    /// Maximum number of *retries* (the first attempt is free).
    pub budget: u32,
}

impl RetryPolicy {
    /// Admission loops: tight first backoff (the queue usually frees in
    /// microseconds), generous budget — replaces the harness loops that
    /// slept a flat 200 µs forever.
    pub fn admission() -> RetryPolicy {
        RetryPolicy {
            base: Duration::from_micros(200),
            factor: 2.0,
            max_backoff: Duration::from_millis(5),
            jitter: 0.5,
            budget: 20_000,
        }
    }

    /// Control-plane operations (model push, drain acks): slower cadence,
    /// small budget — failing fast matters more than persistence.
    pub fn control() -> RetryPolicy {
        RetryPolicy {
            base: Duration::from_millis(10),
            factor: 2.0,
            max_backoff: Duration::from_millis(200),
            jitter: 0.5,
            budget: 6,
        }
    }

    /// The backoff to sleep before retry number `attempt` (0-based),
    /// jittered by `rng`.
    pub fn backoff(&self, attempt: u32, rng: &mut Xoshiro256) -> Duration {
        let exp = self.factor.max(1.0).powi(attempt.min(30) as i32);
        let capped = (self.base.as_secs_f64() * exp)
            .min(self.max_backoff.as_secs_f64());
        let jitter = self.jitter.clamp(0.0, 1.0);
        let scale = 1.0 + jitter * (2.0 * rng.next_f64() - 1.0);
        Duration::from_secs_f64((capped * scale).max(0.0))
    }
}

/// A policy bound to a jitter stream, counting the retries it spends.
pub struct Retrier {
    policy: RetryPolicy,
    rng: Xoshiro256,
    /// Total retries across every `run` call on this retrier.
    pub retries: u64,
}

impl Retrier {
    pub fn new(policy: RetryPolicy, seed: u64) -> Retrier {
        Retrier { policy, rng: Xoshiro256::new(seed), retries: 0 }
    }

    /// Run `op` until it succeeds, fails terminally, or exhausts the
    /// retry budget (the last `Error::Serve` is then returned).
    pub fn run<T>(&mut self, mut op: impl FnMut() -> Result<T>) -> Result<T> {
        let mut attempt = 0u32;
        loop {
            match op() {
                Ok(v) => return Ok(v),
                Err(Error::Serve(msg)) => {
                    if attempt >= self.policy.budget {
                        return Err(Error::Serve(msg));
                    }
                    self.retries += 1;
                    let pause = self.policy.backoff(attempt, &mut self.rng);
                    std::thread::sleep(pause);
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_and_respects_cap() {
        let policy = RetryPolicy {
            base: Duration::from_millis(1),
            factor: 2.0,
            max_backoff: Duration::from_millis(8),
            jitter: 0.0,
            budget: 10,
        };
        let mut rng = Xoshiro256::new(1);
        assert_eq!(policy.backoff(0, &mut rng), Duration::from_millis(1));
        assert_eq!(policy.backoff(1, &mut rng), Duration::from_millis(2));
        assert_eq!(policy.backoff(2, &mut rng), Duration::from_millis(4));
        // attempts 3.. hit the cap
        assert_eq!(policy.backoff(3, &mut rng), Duration::from_millis(8));
        assert_eq!(policy.backoff(9, &mut rng), Duration::from_millis(8));
    }

    #[test]
    fn jitter_stays_inside_the_band() {
        let policy = RetryPolicy {
            base: Duration::from_millis(4),
            factor: 1.0,
            max_backoff: Duration::from_millis(4),
            jitter: 0.5,
            budget: 1,
        };
        let mut rng = Xoshiro256::new(7);
        for _ in 0..200 {
            let b = policy.backoff(0, &mut rng);
            assert!(b >= Duration::from_millis(2) && b <= Duration::from_millis(6),
                    "jittered backoff {b:?} outside [2ms, 6ms]");
        }
    }

    #[test]
    fn retries_serve_errors_until_success() {
        let mut retrier = Retrier::new(
            RetryPolicy { base: Duration::from_micros(10), ..RetryPolicy::admission() },
            3,
        );
        let mut failures_left = 3;
        let got = retrier.run(|| {
            if failures_left > 0 {
                failures_left -= 1;
                Err(Error::Serve("queue full".into()))
            } else {
                Ok(42)
            }
        });
        assert_eq!(got.unwrap(), 42);
        assert_eq!(retrier.retries, 3);
    }

    #[test]
    fn budget_exhaustion_returns_the_last_serve_error() {
        let policy = RetryPolicy {
            base: Duration::from_micros(1),
            factor: 1.0,
            max_backoff: Duration::from_micros(1),
            jitter: 0.0,
            budget: 4,
        };
        let mut retrier = Retrier::new(policy, 5);
        let mut calls = 0u32;
        let got: Result<()> = retrier.run(|| {
            calls += 1;
            Err(Error::Serve(format!("still full ({calls})")))
        });
        assert!(matches!(got, Err(Error::Serve(_))));
        assert_eq!(calls, 5, "first attempt + 4 retries");
        assert_eq!(retrier.retries, 4);
    }

    #[test]
    fn terminal_errors_are_not_retried() {
        let mut retrier = Retrier::new(RetryPolicy::control(), 9);
        let mut calls = 0u32;
        let got: Result<()> = retrier.run(|| {
            calls += 1;
            Err(Error::Runtime("backend exploded".into()))
        });
        assert!(matches!(got, Err(Error::Runtime(_))));
        assert_eq!(calls, 1);
        assert_eq!(retrier.retries, 0);
    }
}
