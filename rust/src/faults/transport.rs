//! `faults::transport` — a [`Transport`] decorator that executes the
//! [`FaultPlan`]'s wire schedule.
//!
//! Every link half handed out by the inner transport is wrapped in a
//! [`FaultyTx`] that counts its sends and consults
//! [`FaultPlan::wire_fault`] per message: drop and blackhole discard,
//! duplicate sends twice, delay holds the message in *count space* —
//! it is released after `Delay(n)` subsequent sends on the same link
//! direction (reordering it past them), not after a wall-clock timer, so
//! the executed schedule is a pure function of the message sequence.
//! Held messages are flushed in schedule order when the link closes or
//! the plan is disarmed: nothing is ever lost *by the harness itself*
//! once injection stops, which is what lets the chaos gates demand zero
//! billed loss.
//!
//! Only the sender side is wrapped; receivers are untouched.  Both
//! directions of every node link get an independent fault stream
//! ([`Dir::Request`] / [`Dir::Response`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::fleet::transport::{
    NodeId, NodeLink, RouterLink, Transport, WireRequest, WireResponse, WireTx,
};

use super::{Dir, FaultPlan, WireFault};

/// Sender wrapper executing the plan on one link direction.
struct FaultyTx<T> {
    inner: Arc<dyn WireTx<T>>,
    plan: Arc<FaultPlan>,
    node: NodeId,
    dir: Dir,
    /// Per-link message index; the key into the fault schedule.
    sent: AtomicU64,
    /// Delayed messages: `(release_at_index, msg)`, released once the
    /// link's send index passes `release_at_index` (or on close/disarm).
    held: Mutex<Vec<(u64, T)>>,
}

impl<T: Send + Clone> FaultyTx<T> {
    fn new(inner: Arc<dyn WireTx<T>>, plan: Arc<FaultPlan>, node: NodeId, dir: Dir) -> Self {
        FaultyTx { inner, plan, node, dir, sent: AtomicU64::new(0), held: Mutex::new(Vec::new()) }
    }

    /// Deliver held messages due at or before `now`, oldest release
    /// index first.
    fn release_due(&self, now: u64) {
        let due: Vec<(u64, T)> = {
            let mut held = self.held.lock().unwrap();
            if held.iter().all(|&(at, _)| at > now) {
                return;
            }
            let mut due = Vec::new();
            let mut i = 0;
            while i < held.len() {
                if held[i].0 <= now {
                    due.push(held.remove(i));
                } else {
                    i += 1;
                }
            }
            due.sort_by_key(|&(at, _)| at);
            due
        };
        for (_, msg) in due {
            let _ = self.inner.send(msg);
        }
    }

    /// Deliver everything held, regardless of release index.
    fn flush(&self) {
        let mut held: Vec<(u64, T)> = std::mem::take(&mut *self.held.lock().unwrap());
        held.sort_by_key(|&(at, _)| at);
        for (_, msg) in held {
            let _ = self.inner.send(msg);
        }
    }
}

impl<T: Send + Clone> WireTx<T> for FaultyTx<T> {
    fn send(&self, msg: T) -> std::result::Result<(), T> {
        if !self.plan.armed() {
            self.flush();
            return self.inner.send(msg);
        }
        let index = self.sent.fetch_add(1, Ordering::Relaxed);
        let ledger = &self.plan.ledger;
        let result = match self.plan.wire_fault(self.node, self.dir, index) {
            WireFault::Deliver => self.inner.send(msg),
            WireFault::Drop => {
                ledger.dropped.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            WireFault::Blackhole => {
                ledger.blackholed.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            WireFault::Duplicate => {
                ledger.duplicated.fetch_add(1, Ordering::Relaxed);
                let copy = msg.clone();
                match self.inner.send(msg) {
                    Ok(()) => {
                        // best-effort second copy; a full queue dropping
                        // it just makes the duplicate a no-op
                        let _ = self.inner.send(copy);
                        Ok(())
                    }
                    Err(back) => Err(back),
                }
            }
            WireFault::Delay(slots) => {
                ledger.delayed.fetch_add(1, Ordering::Relaxed);
                self.held.lock().unwrap().push((index + slots as u64, msg));
                Ok(())
            }
        };
        self.release_due(index);
        result
    }

    fn close(&self) {
        self.flush();
        self.inner.close();
    }
}

/// [`Transport`] decorator: wraps the sender half of every link handed
/// out by `inner` with the plan's wire schedule.
pub struct FaultyTransport {
    inner: Box<dyn Transport>,
    plan: Arc<FaultPlan>,
}

impl FaultyTransport {
    pub fn new(inner: Box<dyn Transport>, plan: Arc<FaultPlan>) -> FaultyTransport {
        FaultyTransport { inner, plan }
    }
}

impl Transport for FaultyTransport {
    fn connect(&mut self, node: NodeId) -> (RouterLink, NodeLink) {
        let (router, node_link) = self.inner.connect(node);
        let req_tx: Arc<dyn WireTx<WireRequest>> = Arc::new(FaultyTx::new(
            router.tx,
            Arc::clone(&self.plan),
            node,
            Dir::Request,
        ));
        let rsp_inner: Arc<dyn WireTx<WireResponse>> = Arc::from(node_link.tx);
        let rsp_tx: Box<dyn WireTx<WireResponse>> = Box::new(FaultyTx::new(
            rsp_inner,
            Arc::clone(&self.plan),
            node,
            Dir::Response,
        ));
        (
            RouterLink { tx: req_tx, rx: router.rx },
            NodeLink { rx: node_link.rx, tx: rsp_tx },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FaultsConfig;
    use crate::fleet::transport::ChannelTransport;
    use crate::fleet::transport::TryRecv;

    fn plan_with(f: impl FnOnce(&mut FaultsConfig)) -> Arc<FaultPlan> {
        let mut cfg = FaultsConfig::default();
        cfg.enabled = true;
        f(&mut cfg);
        FaultPlan::new(cfg)
    }

    fn connect(plan: &Arc<FaultPlan>) -> (RouterLink, NodeLink) {
        let mut t =
            FaultyTransport::new(Box::new(ChannelTransport::new(1024)), Arc::clone(plan));
        t.connect(0)
    }

    fn drain_req_ids(rx: &dyn crate::fleet::transport::WireRx<WireRequest>) -> Vec<u64> {
        let mut ids = Vec::new();
        loop {
            match rx.try_recv() {
                TryRecv::Msg(WireRequest::Ping { req_id }) => ids.push(req_id),
                TryRecv::Msg(_) => unreachable!("tests only send pings"),
                _ => return ids,
            }
        }
    }

    #[test]
    fn disarmed_plan_passes_everything_through() {
        let plan = plan_with(|c| c.drop_prob = 1.0);
        plan.disarm();
        let (router, node) = connect(&plan);
        for req_id in 0..32 {
            router.tx.send(WireRequest::Ping { req_id }).unwrap();
        }
        assert_eq!(drain_req_ids(node.rx.as_ref()).len(), 32);
        assert_eq!(plan.ledger.total(), 0);
    }

    #[test]
    fn drop_all_delivers_nothing_and_counts() {
        let plan = plan_with(|c| c.drop_prob = 1.0);
        let (router, node) = connect(&plan);
        for req_id in 0..16 {
            router.tx.send(WireRequest::Ping { req_id }).unwrap();
        }
        assert!(drain_req_ids(node.rx.as_ref()).is_empty());
        assert_eq!(plan.ledger.dropped.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn duplicate_all_doubles_delivery() {
        let plan = plan_with(|c| c.dup_prob = 1.0);
        let (router, node) = connect(&plan);
        for req_id in 0..8 {
            router.tx.send(WireRequest::Ping { req_id }).unwrap();
        }
        let ids = drain_req_ids(node.rx.as_ref());
        assert_eq!(ids.len(), 16);
        for req_id in 0..8 {
            assert_eq!(ids.iter().filter(|&&i| i == req_id).count(), 2);
        }
    }

    #[test]
    fn delay_reorders_but_loses_nothing() {
        let plan = plan_with(|c| {
            c.delay_prob = 0.5;
            c.delay_slots = 3;
        });
        let (router, node) = connect(&plan);
        let n = 64u64;
        for req_id in 0..n {
            router.tx.send(WireRequest::Ping { req_id }).unwrap();
        }
        // tail-held messages flush on close
        router.tx.close();
        let mut ids = drain_req_ids(node.rx.as_ref());
        assert_eq!(plan.ledger.delayed.load(Ordering::Relaxed) > 0, true);
        assert_ne!(ids, (0..n).collect::<Vec<_>>(), "some reordering expected");
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>(), "nothing lost or duplicated");
    }

    #[test]
    fn flap_window_blackholes_and_response_dir_is_independent() {
        let plan = plan_with(|c| {
            c.flap_node = 0;
            c.flap_after = 4;
            c.flap_len = 4;
        });
        let (router, node) = connect(&plan);
        for req_id in 0..12 {
            router.tx.send(WireRequest::Ping { req_id }).unwrap();
        }
        let ids = drain_req_ids(node.rx.as_ref());
        assert_eq!(ids, vec![0, 1, 2, 3, 8, 9, 10, 11]);
        assert_eq!(plan.ledger.blackholed.load(Ordering::Relaxed), 4);
        // the response direction counts its own index space but shares
        // the same flap window [4, 8): 12 sends -> 8 delivered
        for req_id in 0..12 {
            node.tx.send(WireResponse::Pong { req_id }).unwrap();
        }
        let mut got = 0;
        while let TryRecv::Msg(_) = router.rx.try_recv() {
            got += 1;
        }
        assert_eq!(got, 8);
        assert_eq!(plan.ledger.blackholed.load(Ordering::Relaxed), 8);
    }
}
