//! `faults` — the deterministic fault-injection plane (chaos plane).
//!
//! Serving hardware near the sensor fails in undramatic ways: a flaky
//! aggregation link drops or reorders frames, a shard wedges on a slow
//! DMA, a node browns out for half a second, comparator read margins
//! collapse under voltage droop.  This module injects exactly those
//! faults — and nothing nondeterministic — so the recovery machinery
//! (retransmit, health tracking, rejoin, dedup) can be exercised in CI
//! with byte-identical schedules run to run.
//!
//! **Determinism contract.**  Every injection decision is a pure
//! function of `(seed, site, index)` hashed through
//! [`crate::rng::splitmix64`]: the same seed always produces the same
//! fault *schedule* (which message slots drop, duplicate, delay; which
//! dispatch ticks stall).  What varies between runs is only *which real
//! message lands in which slot* — thread interleaving — which is exactly
//! the degree of freedom a recovery layer must tolerate anyway.  The
//! schedule itself ([`FaultPlan::schedule_digest`],
//! [`FaultPlan::schedule_events`]) is computed without executing
//! anything, so `ns-lbp chaos --seed S` emits an identical schedule
//! section every run.
//!
//! Sites covered:
//!
//! * **Wire** ([`transport::FaultyTransport`]): drop / duplicate /
//!   delay(reorder) / blackhole at the [`crate::fleet::transport`] seam,
//!   per link direction, indexed by a per-link message counter.  Delay
//!   is *count-space*: a held message is released after `delay_slots`
//!   subsequent sends (or on close/disarm), so no timers are involved.
//! * **Shard** ([`ShardFaults`]): stall or panic a shard worker
//!   mid-dispatch, proving the exec plane's panic isolation end to end.
//! * **Artifact** ([`artifact_corruption`]): flip one byte of a pushed
//!   `.nslbpc` image in transit; the node's checksum rejects it and the
//!   router retries.
//! * **Comparator** ([`BitFlips`]): flip architectural read bits at the
//!   Monte-Carlo decision-error rate of a sigma-scaled
//!   [`crate::circuit::CircuitParams`] — the paper's Fig. 10 variation
//!   model driving live-serving bit errors.
//!
//! Recovery primitives live alongside: [`retry::RetryPolicy`] (jittered
//! exponential backoff), [`health::HealthTracker`] (alive → suspect →
//! dead → rejoin), and [`SeqLedger`] (exactly-once completion under
//! duplicated / reordered wire responses).

pub mod health;
pub mod retry;
pub mod transport;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::config::FaultsConfig;
use crate::rng::splitmix64;

pub use health::{HealthTracker, NodeState};
pub use retry::{RetryPolicy, Retrier};
pub use transport::FaultyTransport;

// ---------------------------------------------------------------------------
// Deterministic draws
// ---------------------------------------------------------------------------

/// Domain tags keep the per-site draw streams independent: the same
/// (seed, index) pair must not correlate a wire drop with a shard stall.
const TAG_WIRE_REQ: u64 = 0x5749_5245_0000_0001;
const TAG_WIRE_RSP: u64 = 0x5749_5245_0000_0002;
const TAG_DELAY_LEN: u64 = 0x5749_5245_0000_0003;
const TAG_SHARD: u64 = 0x5348_4152_4400_0001;
const TAG_ARTIFACT: u64 = 0x4152_5446_0000_0001;
const TAG_BITFLIP: u64 = 0x4249_5446_0000_0001;

/// One 64-bit draw, pure in `(seed, tag, a, b)`.
fn raw_draw(seed: u64, tag: u64, a: u64, b: u64) -> u64 {
    let mut s = seed
        ^ tag.rotate_left(17)
        ^ a.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ b.wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
    splitmix64(&mut s)
}

/// Uniform in [0, 1), pure in `(seed, tag, a, b)`.
fn unit_draw(seed: u64, tag: u64, a: u64, b: u64) -> f64 {
    (raw_draw(seed, tag, a, b) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

// ---------------------------------------------------------------------------
// Wire fault schedule
// ---------------------------------------------------------------------------

/// Direction of a wire message; part of every wire draw's key so the
/// request and response streams of one link fault independently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// Router → node.
    Request,
    /// Node → router.
    Response,
}

impl Dir {
    fn tag(self) -> u64 {
        match self {
            Dir::Request => TAG_WIRE_REQ,
            Dir::Response => TAG_WIRE_RSP,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Dir::Request => "req",
            Dir::Response => "rsp",
        }
    }
}

/// The plan's decision for one wire-message slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFault {
    /// Pass through untouched.
    Deliver,
    /// Silently discard (the sender still sees `Ok`).
    Drop,
    /// Deliver twice.
    Duplicate,
    /// Hold for this many subsequent sends on the same link direction,
    /// then deliver (reordering past everything sent in between).
    Delay(u32),
    /// Inside the node-flap window: discard, modelling a node that has
    /// gone dark for a stretch of its message timeline.
    Blackhole,
}

impl WireFault {
    /// Stable code for digesting / naming the schedule.
    fn code(self) -> u64 {
        match self {
            WireFault::Deliver => 0,
            WireFault::Drop => 1,
            WireFault::Duplicate => 2,
            WireFault::Blackhole => 3,
            WireFault::Delay(slots) => 0x100 + slots as u64,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            WireFault::Deliver => "deliver",
            WireFault::Drop => "drop",
            WireFault::Duplicate => "duplicate",
            WireFault::Delay(_) => "delay",
            WireFault::Blackhole => "blackhole",
        }
    }
}

/// One non-`Deliver` slot of the schedule, for the chaos report.
#[derive(Clone, Debug)]
pub struct ScheduleEvent {
    pub node: usize,
    pub dir: Dir,
    pub index: u64,
    pub fault: WireFault,
}

// ---------------------------------------------------------------------------
// Executed-fault ledger
// ---------------------------------------------------------------------------

/// Counters for faults actually executed (the schedule says what *would*
/// happen at each slot; the ledger says what *did*, given how much
/// traffic really flowed).
#[derive(Debug, Default)]
pub struct FaultLedger {
    pub dropped: AtomicU64,
    pub duplicated: AtomicU64,
    pub delayed: AtomicU64,
    pub blackholed: AtomicU64,
    pub artifacts_corrupted: AtomicU64,
}

impl FaultLedger {
    pub fn total(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
            + self.duplicated.load(Ordering::Relaxed)
            + self.delayed.load(Ordering::Relaxed)
            + self.blackholed.load(Ordering::Relaxed)
            + self.artifacts_corrupted.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// FaultPlan
// ---------------------------------------------------------------------------

/// A seeded, armed/disarmed fault schedule shared by every injection
/// site that has a handle to it (the wire wrappers and the chaos
/// harness; shard and comparator sites rebuild the same decisions from
/// the [`FaultsConfig`] they carry).
pub struct FaultPlan {
    config: FaultsConfig,
    armed: AtomicBool,
    pub ledger: FaultLedger,
}

impl FaultPlan {
    pub fn new(config: FaultsConfig) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            armed: AtomicBool::new(config.enabled),
            config,
            ledger: FaultLedger::default(),
        })
    }

    pub fn config(&self) -> &FaultsConfig {
        &self.config
    }

    pub fn armed(&self) -> bool {
        self.armed.load(Ordering::Acquire)
    }

    /// Stop injecting.  The wire wrappers flush held messages on their
    /// next send and pass everything through untouched — call this
    /// before draining a fleet so control traffic cannot be eaten.
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::Release);
    }

    /// Pure schedule lookup: what happens to message `index` on the
    /// `(node, dir)` link.  Independent of execution history.
    pub fn wire_fault(&self, node: usize, dir: Dir, index: u64) -> WireFault {
        let c = &self.config;
        if c.flap_len > 0 && node == c.flap_node {
            let start = c.flap_after as u64;
            if index >= start && index < start + c.flap_len as u64 {
                return WireFault::Blackhole;
            }
        }
        let u = unit_draw(c.seed, dir.tag(), node as u64, index);
        let mut edge = c.drop_prob;
        if u < edge {
            return WireFault::Drop;
        }
        edge += c.dup_prob;
        if u < edge {
            return WireFault::Duplicate;
        }
        edge += c.delay_prob;
        if u < edge {
            let span = c.delay_slots.max(1) as u64;
            let slots =
                1 + (raw_draw(c.seed, TAG_DELAY_LEN, node as u64, index) % span) as u32;
            return WireFault::Delay(slots);
        }
        WireFault::Deliver
    }

    /// Flip one byte of an outbound artifact image?  Pure in
    /// `(seed, node, index)`; `index` is the per-node push attempt
    /// counter, so a retry redraws and (almost surely) goes clean.
    pub fn corrupt_artifact(&self, node: usize, index: u64, bytes: &mut [u8]) -> bool {
        if !self.armed() {
            return false;
        }
        match artifact_corruption(&self.config, node, index, bytes.len()) {
            Some(pos) => {
                bytes[pos] ^= 0x40;
                self.ledger.artifacts_corrupted.fetch_add(1, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// FNV-flavoured digest of the wire schedule over `nodes` links and
    /// the first `horizon` message slots per direction.  Two runs with
    /// the same seed and knobs produce the same digest by construction.
    pub fn schedule_digest(&self, nodes: usize, horizon: u64) -> u64 {
        let mut h: u64 = 0x9E37_79B9_7F4A_7C15 ^ self.config.seed;
        for node in 0..nodes {
            for dir in [Dir::Request, Dir::Response] {
                for index in 0..horizon {
                    let code = self.wire_fault(node, dir, index).code();
                    let mut s = h
                        ^ code
                        ^ ((node as u64) << 40)
                        ^ (dir.tag() << 1)
                        ^ index;
                    h = splitmix64(&mut s);
                }
            }
        }
        h
    }

    /// The first `max` non-`Deliver` slots of the schedule, in
    /// `(node, dir, index)` order — the human-readable half of the
    /// determinism proof in `BENCH_chaos.json`.
    pub fn schedule_events(
        &self,
        nodes: usize,
        horizon: u64,
        max: usize,
    ) -> Vec<ScheduleEvent> {
        let mut events = Vec::new();
        for node in 0..nodes {
            for dir in [Dir::Request, Dir::Response] {
                for index in 0..horizon {
                    let fault = self.wire_fault(node, dir, index);
                    if fault != WireFault::Deliver {
                        events.push(ScheduleEvent { node, dir, index, fault });
                        if events.len() >= max {
                            return events;
                        }
                    }
                }
            }
        }
        events
    }
}

/// Pure corruption schedule for model pushes, usable without a plan
/// handle (the fleet router rebuilds decisions from its
/// [`FaultsConfig`]): the byte to flip in a `len`-byte artifact for push
/// attempt `index` to `node`, or `None` for a clean push.
pub fn artifact_corruption(
    cfg: &FaultsConfig,
    node: usize,
    index: u64,
    len: usize,
) -> Option<usize> {
    if !cfg.enabled || cfg.artifact_corrupt_prob <= 0.0 || len == 0 {
        return None;
    }
    let u = unit_draw(cfg.seed, TAG_ARTIFACT, node as u64, index);
    if u >= cfg.artifact_corrupt_prob {
        return None;
    }
    Some((raw_draw(cfg.seed, TAG_ARTIFACT, node as u64, !index) % len as u64) as usize)
}

// ---------------------------------------------------------------------------
// Shard faults (stall / panic)
// ---------------------------------------------------------------------------

/// Process-wide panic token: at most one injected panic per process, so
/// a chaos run proves isolation without cascading every shard into the
/// recovery path at once.
static PANIC_TOKEN: AtomicBool = AtomicBool::new(false);

fn take_panic_token() -> bool {
    !PANIC_TOKEN.swap(true, Ordering::Relaxed)
}

/// Re-arm the panic token (tests only — each test binary gets one
/// injected panic unless it resets between scenarios).
pub fn reset_panic_token() {
    PANIC_TOKEN.store(false, Ordering::Relaxed);
}

/// What a shard dispatch was told to do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardFault {
    /// Sleep this long before serving the batch (a wedged DMA / slow
    /// memory lane); the batch still completes.
    Stall(Duration),
    /// Panic mid-dispatch.  The pool's isolation wrapper must fail the
    /// batch's tickets and keep the worker thread alive.
    Panic,
}

/// Per-shard dispatch fault stream, rebuilt from config inside the serve
/// plane (no shared plan handle crosses the serve boundary).  Decisions
/// are pure in `(seed, shard, tick)`.
pub struct ShardFaults {
    seed: u64,
    shard: u64,
    tick: u64,
    stall_prob: f64,
    stall: Duration,
    panic_prob: f64,
}

impl ShardFaults {
    /// `None` when the config injects nothing at this site.
    pub fn new(cfg: &FaultsConfig, shard: usize) -> Option<ShardFaults> {
        if !cfg.enabled || (cfg.stall_prob <= 0.0 && cfg.panic_prob <= 0.0) {
            return None;
        }
        Some(ShardFaults {
            seed: cfg.seed,
            shard: shard as u64,
            tick: 0,
            stall_prob: cfg.stall_prob,
            stall: Duration::from_micros(cfg.stall_us),
            panic_prob: cfg.panic_prob,
        })
    }

    /// Decide the fault (if any) for the next dispatch tick.
    pub fn next(&mut self) -> Option<ShardFault> {
        let t = self.tick;
        self.tick += 1;
        let u = unit_draw(self.seed, TAG_SHARD, self.shard, t);
        if u < self.panic_prob {
            if take_panic_token() {
                return Some(ShardFault::Panic);
            }
            // token spent: degrade the scheduled panic to a stall so the
            // tick still exercises the slow path deterministically
            return Some(ShardFault::Stall(self.stall));
        }
        if u < self.panic_prob + self.stall_prob {
            return Some(ShardFault::Stall(self.stall));
        }
        None
    }
}

// ---------------------------------------------------------------------------
// Comparator bit flips
// ---------------------------------------------------------------------------

/// Process-wide count of comparator bits actually flipped (the
/// architectural backend has no metrics handle; the chaos harness reads
/// the delta around a run).
static BITFLIPS: AtomicU64 = AtomicU64::new(0);

pub fn bitflips_injected() -> u64 {
    BITFLIPS.load(Ordering::Relaxed)
}

/// Comparator read-bit flip injector for the architectural backend.
///
/// The flip rate is not a free knob: it is the Monte-Carlo decision
/// error rate ([`crate::circuit::MonteCarlo`]) of the circuit's
/// variation model with both sigmas scaled by
/// `faults.bitflip_sigma_scale` — the paper's Fig. 10 methodology
/// projected onto live serving.  At nominal sigma (scale 1.0) the rate
/// is exactly zero, so enabling faults without touching the scale
/// leaves the architectural datapath bit-identical.
pub struct BitFlips {
    rate: f64,
    state: u64,
    pub flipped: u64,
}

impl BitFlips {
    /// `None` when the configured scale produces a zero error rate (or
    /// faults are disabled) — the hot loop then pays nothing.
    pub fn new(
        cfg: &FaultsConfig,
        circuit: &crate::circuit::CircuitParams,
        lane: usize,
    ) -> Option<BitFlips> {
        if !cfg.enabled || cfg.bitflip_sigma_scale <= 0.0 {
            return None;
        }
        let rate = Self::rate_for(cfg, circuit);
        if rate <= 0.0 {
            return None;
        }
        Some(BitFlips {
            rate,
            state: raw_draw(cfg.seed, TAG_BITFLIP, lane as u64, 0),
            flipped: 0,
        })
    }

    /// The Monte-Carlo decision-error rate at the scaled sigma.  Pure in
    /// `(cfg.seed, scale, circuit)`; monotone (statistically) in scale.
    pub fn rate_for(cfg: &FaultsConfig, circuit: &crate::circuit::CircuitParams) -> f64 {
        let mut params = circuit.clone();
        params.sigma_process *= cfg.bitflip_sigma_scale;
        params.sigma_mismatch *= cfg.bitflip_sigma_scale;
        let mc = crate::circuit::MonteCarlo { params, trials: 64, bitlines: 256 };
        mc.run(cfg.seed ^ TAG_BITFLIP).decision_error_rate
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Flip-flag for one comparator read; deterministic in construction
    /// order.
    #[inline]
    fn flip(&mut self) -> bool {
        let u = (splitmix64(&mut self.state) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < self.rate
    }

    /// Apply flips to a slice of comparator read bits; returns how many
    /// flipped.
    pub fn apply(&mut self, bits: &mut [bool]) -> u64 {
        let mut n = 0u64;
        for b in bits.iter_mut() {
            if self.flip() {
                *b = !*b;
                n += 1;
            }
        }
        if n > 0 {
            self.flipped += n;
            BITFLIPS.fetch_add(n, Ordering::Relaxed);
        }
        n
    }
}

// ---------------------------------------------------------------------------
// Exactly-once sequence ledger
// ---------------------------------------------------------------------------

/// Request ids that reached a terminal resolution (or were superseded by
/// a retransmit / re-home).  The fleet collector consults it before
/// counting an unmatched response as orphaned: a duplicated, reordered,
/// or late wire response for a resolved id is *deduplicated*, never
/// double-completed — the exactly-once half of the recovery contract.
#[derive(Debug, Default)]
pub struct SeqLedger {
    seen: std::collections::HashSet<u64>,
}

impl SeqLedger {
    pub fn new() -> SeqLedger {
        SeqLedger::default()
    }

    /// Record `id` as resolved; `false` if it already was.
    pub fn record(&mut self, id: u64) -> bool {
        self.seen.insert(id)
    }

    pub fn contains(&self, id: u64) -> bool {
        self.seen.contains(&id)
    }

    pub fn len(&self) -> usize {
        self.seen.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seen.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_with(f: impl FnOnce(&mut FaultsConfig)) -> Arc<FaultPlan> {
        let mut cfg = FaultsConfig::default();
        cfg.enabled = true;
        f(&mut cfg);
        FaultPlan::new(cfg)
    }

    #[test]
    fn schedule_is_pure_in_seed() {
        let a = plan_with(|c| {
            c.seed = 77;
            c.drop_prob = 0.1;
            c.dup_prob = 0.1;
            c.delay_prob = 0.1;
        });
        let b = plan_with(|c| {
            c.seed = 77;
            c.drop_prob = 0.1;
            c.dup_prob = 0.1;
            c.delay_prob = 0.1;
        });
        assert_eq!(a.schedule_digest(3, 256), b.schedule_digest(3, 256));
        for node in 0..3 {
            for index in 0..256 {
                assert_eq!(
                    a.wire_fault(node, Dir::Request, index),
                    b.wire_fault(node, Dir::Request, index)
                );
            }
        }
        let c = plan_with(|c| {
            c.seed = 78;
            c.drop_prob = 0.1;
            c.dup_prob = 0.1;
            c.delay_prob = 0.1;
        });
        assert_ne!(a.schedule_digest(3, 256), c.schedule_digest(3, 256));
    }

    #[test]
    fn probabilities_partition_the_unit_interval() {
        // with all three probs at 1/3 every slot faults; with all zero
        // none do
        let hot = plan_with(|c| {
            c.drop_prob = 1.0 / 3.0;
            c.dup_prob = 1.0 / 3.0;
            c.delay_prob = 1.0 / 3.0;
        });
        let cold = plan_with(|_| {});
        let (mut drops, mut dups, mut delays) = (0u32, 0u32, 0u32);
        for index in 0..300 {
            match hot.wire_fault(0, Dir::Response, index) {
                WireFault::Drop => drops += 1,
                WireFault::Duplicate => dups += 1,
                WireFault::Delay(s) => {
                    assert!(s >= 1 && s as usize <= hot.config().delay_slots);
                    delays += 1;
                }
                other => panic!("unexpected {other:?} with saturated probs"),
            }
            assert_eq!(cold.wire_fault(0, Dir::Response, index), WireFault::Deliver);
        }
        // all three arms actually drawn
        assert!(drops > 0 && dups > 0 && delays > 0, "{drops}/{dups}/{delays}");
    }

    #[test]
    fn flap_window_blackholes_exactly_its_slots() {
        let plan = plan_with(|c| {
            c.flap_node = 1;
            c.flap_after = 10;
            c.flap_len = 5;
        });
        for index in 0..30 {
            let f = plan.wire_fault(1, Dir::Request, index);
            if (10..15).contains(&index) {
                assert_eq!(f, WireFault::Blackhole, "index {index}");
            } else {
                assert_eq!(f, WireFault::Deliver, "index {index}");
            }
            // the other node is untouched
            assert_eq!(plan.wire_fault(0, Dir::Request, index), WireFault::Deliver);
        }
    }

    #[test]
    fn disarm_stops_artifact_corruption() {
        let plan = plan_with(|c| c.artifact_corrupt_prob = 1.0);
        let mut bytes = vec![0u8; 64];
        assert!(plan.corrupt_artifact(0, 0, &mut bytes));
        assert!(bytes.iter().any(|&b| b != 0));
        plan.disarm();
        let mut clean = vec![0u8; 64];
        assert!(!plan.corrupt_artifact(0, 1, &mut clean));
        assert!(clean.iter().all(|&b| b == 0));
        assert_eq!(plan.ledger.artifacts_corrupted.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn shard_faults_draw_stalls_and_one_panic() {
        reset_panic_token();
        let mut cfg = FaultsConfig::default();
        cfg.enabled = true;
        cfg.panic_prob = 1.0;
        let mut a = ShardFaults::new(&cfg, 0).expect("armed");
        assert_eq!(a.next(), Some(ShardFault::Panic));
        // token spent: the next scheduled panic degrades to a stall
        assert!(matches!(a.next(), Some(ShardFault::Stall(_))));
        let mut b = ShardFaults::new(&cfg, 1).expect("armed");
        assert!(matches!(b.next(), Some(ShardFault::Stall(_))));
        reset_panic_token();
        // disabled or zero-prob configs opt out entirely
        assert!(ShardFaults::new(&FaultsConfig::default(), 0).is_none());
    }

    #[test]
    fn bitflip_rate_zero_at_nominal_sigma_and_grows_with_scale() {
        let circuit = crate::circuit::CircuitParams::default();
        let mut cfg = FaultsConfig::default();
        cfg.enabled = true;
        // nominal sigma: the Fig. 10 reproduction has zero decision
        // errors, so no flips are injected at all
        assert!(BitFlips::new(&cfg, &circuit, 0).is_none());
        cfg.bitflip_sigma_scale = 8.0;
        let hot = BitFlips::new(&cfg, &circuit, 0).expect("8x sigma must err");
        assert!(hot.rate() > 0.0);
        cfg.bitflip_sigma_scale = 16.0;
        let hotter_rate = BitFlips::rate_for(&cfg, &circuit);
        assert!(hotter_rate >= hot.rate(), "{hotter_rate} < {}", hot.rate());
        // apply() flips roughly rate * n bits, deterministically
        cfg.bitflip_sigma_scale = 8.0;
        let mut x = BitFlips::new(&cfg, &circuit, 3).unwrap();
        let mut y = BitFlips::new(&cfg, &circuit, 3).unwrap();
        let mut bx = vec![false; 4096];
        let mut by = vec![false; 4096];
        let nx = x.apply(&mut bx);
        let ny = y.apply(&mut by);
        assert_eq!(nx, ny);
        assert_eq!(bx, by);
        assert!(nx > 0);
    }

    #[test]
    fn seq_ledger_records_once() {
        let mut l = SeqLedger::new();
        assert!(l.is_empty());
        assert!(l.record(9));
        assert!(!l.record(9));
        assert!(l.contains(9));
        assert!(!l.contains(10));
        assert_eq!(l.len(), 1);
    }
}
