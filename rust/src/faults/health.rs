//! `faults::health` — the node liveness state machine.
//!
//! The fleet router used to learn about node death two ways only: an
//! explicit `kill_node` or the link closing under the collector.  A
//! *transiently* dark node (flapping link, long GC-style stall) showed
//! up as neither — frames just aged.  The tracker closes that gap with
//! the classic three-state machine:
//!
//! ```text
//!            silent > suspect_ms          silent > dead_ms
//!   Alive ───────────────────────▶ Suspect ────────────────▶ Dead
//!     ▲                              │                        │
//!     └───── any message ────────────┘     any message        │
//!     └───────────────────────── (rejoin) ◀───────────────────┘
//! ```
//!
//! "Any message" includes [`crate::fleet::transport::WireResponse::Pong`]
//! answers to the monitor's health probes, so liveness never depends on
//! the node owing frames.  A node the operator killed explicitly is
//! pinned `Dead` and cannot rejoin.  Transition counters feed the fleet
//! report (`health.suspect` / `health.dead` / `health.rejoined`) — the
//! chaos gate asserts a node-flap scenario actually walked the machine.

use std::time::{Duration, Instant};

/// Liveness verdict for one node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeState {
    Alive,
    /// Silent past `suspect_ms`; still routed to, but on notice.
    Suspect,
    /// Silent past `dead_ms` (or explicitly killed): out of rotation,
    /// its frames re-homed.
    Dead,
}

/// Per-node last-seen bookkeeping plus transition counters.
#[derive(Debug)]
pub struct HealthTracker {
    states: Vec<NodeState>,
    killed: Vec<bool>,
    last_seen: Vec<Instant>,
    suspect_after: Duration,
    dead_after: Duration,
    /// Alive → Suspect transitions observed.
    pub to_suspect: u64,
    /// (Alive|Suspect) → Dead transitions observed.
    pub to_dead: u64,
    /// Dead → Alive rejoins observed.
    pub rejoined: u64,
}

impl HealthTracker {
    pub fn new(nodes: usize, suspect_after: Duration, dead_after: Duration) -> Self {
        let now = Instant::now();
        HealthTracker {
            states: vec![NodeState::Alive; nodes],
            killed: vec![false; nodes],
            last_seen: vec![now; nodes],
            suspect_after,
            dead_after: dead_after.max(suspect_after),
            to_suspect: 0,
            to_dead: 0,
            rejoined: 0,
        }
    }

    pub fn state(&self, node: usize) -> NodeState {
        self.states.get(node).copied().unwrap_or(NodeState::Dead)
    }

    /// A message arrived from `node`.  Refreshes last-seen and walks the
    /// machine back to `Alive`; returns `true` when this was a rejoin
    /// (the caller puts the node back into routing rotation).
    pub fn mark_seen(&mut self, node: usize) -> bool {
        if node >= self.states.len() || self.killed[node] {
            return false;
        }
        self.last_seen[node] = Instant::now();
        match self.states[node] {
            NodeState::Dead => {
                self.states[node] = NodeState::Alive;
                self.rejoined += 1;
                true
            }
            NodeState::Suspect => {
                self.states[node] = NodeState::Alive;
                false
            }
            NodeState::Alive => false,
        }
    }

    /// Pin `node` dead forever (operator kill / permanent link loss).
    pub fn mark_killed(&mut self, node: usize) {
        if node < self.states.len() {
            self.killed[node] = true;
            self.states[node] = NodeState::Dead;
        }
    }

    /// Advance every node's machine against `now`; returns the nodes
    /// that transitioned to `Dead` this sweep (the caller re-homes their
    /// frames).
    pub fn sweep(&mut self, now: Instant) -> Vec<usize> {
        let mut died = Vec::new();
        for node in 0..self.states.len() {
            if self.killed[node] {
                continue;
            }
            let silent = now.saturating_duration_since(self.last_seen[node]);
            match self.states[node] {
                NodeState::Alive if silent >= self.suspect_after => {
                    self.states[node] = NodeState::Suspect;
                    self.to_suspect += 1;
                    if silent >= self.dead_after {
                        self.states[node] = NodeState::Dead;
                        self.to_dead += 1;
                        died.push(node);
                    }
                }
                NodeState::Suspect if silent >= self.dead_after => {
                    self.states[node] = NodeState::Dead;
                    self.to_dead += 1;
                    died.push(node);
                }
                _ => {}
            }
        }
        died
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracker() -> HealthTracker {
        // wide windows: the sweeps below pass synthetic `now` values, so
        // the only real-clock sensitivity is the gap between `new()` and
        // the test's `t0` — keep it far below suspect_after
        HealthTracker::new(2, Duration::from_millis(50), Duration::from_millis(200))
    }

    #[test]
    fn walks_alive_suspect_dead_and_rejoins() {
        let mut h = tracker();
        let t0 = Instant::now();
        assert_eq!(h.state(0), NodeState::Alive);
        assert!(h.sweep(t0).is_empty());
        // past suspect_after but not dead_after: Suspect
        assert!(h.sweep(t0 + Duration::from_millis(80)).is_empty());
        assert_eq!(h.state(0), NodeState::Suspect);
        assert_eq!(h.to_suspect, 2, "both nodes went suspect");
        // past dead_after: Dead, reported once
        let died = h.sweep(t0 + Duration::from_millis(300));
        assert_eq!(died, vec![0, 1]);
        assert_eq!(h.state(1), NodeState::Dead);
        // a second sweep does not re-report the death
        assert!(h.sweep(t0 + Duration::from_millis(400)).is_empty());
        assert_eq!(h.to_dead, 2);
        // a message brings node 0 back
        assert!(h.mark_seen(0));
        assert_eq!(h.state(0), NodeState::Alive);
        assert_eq!(h.rejoined, 1);
        // fresh last-seen: an immediate sweep keeps it alive
        assert!(h.sweep(Instant::now()).is_empty());
        assert_eq!(h.state(0), NodeState::Alive);
    }

    #[test]
    fn suspect_recovers_without_counting_a_rejoin() {
        let mut h = tracker();
        let t0 = Instant::now();
        h.sweep(t0 + Duration::from_millis(80));
        assert_eq!(h.state(0), NodeState::Suspect);
        assert!(!h.mark_seen(0), "suspect -> alive is not a rejoin");
        assert_eq!(h.state(0), NodeState::Alive);
        assert_eq!(h.rejoined, 0);
    }

    #[test]
    fn killed_nodes_are_pinned_dead() {
        let mut h = tracker();
        h.mark_killed(1);
        assert_eq!(h.state(1), NodeState::Dead);
        assert!(!h.mark_seen(1), "a killed node cannot rejoin");
        assert_eq!(h.state(1), NodeState::Dead);
        // sweeps skip it (no double-counted death)
        let died = h.sweep(Instant::now() + Duration::from_millis(500));
        assert_eq!(died, vec![0]);
        assert_eq!(h.to_dead, 1);
        // out-of-range nodes read as dead, harmlessly
        assert_eq!(h.state(99), NodeState::Dead);
        assert!(!h.mark_seen(99));
    }
}
