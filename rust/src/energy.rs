//! Timing / energy / area arithmetic — the in-house "optimizer tool" of
//! the paper's evaluation framework (§6.1, Fig. 8).
//!
//! Role of Cacti + the post-layout numbers: convert event counts from the
//! architectural simulation ([`crate::isa::ExecStats`],
//! [`crate::dpu::DpuStats`], sensor conversions) into ns / pJ / mm².
//!
//! Since the `hw` redesign this module holds the raw per-event tables
//! ([`EnergyParams`], [`AreaModel`]) and the 65 nm reference arithmetic
//! ([`EnergyModel`]); consumers price telemetry through
//! [`crate::hw::CostModel`] / [`crate::hw::HwProfile`], which wrap these
//! tables, add the per-opcode cycle dimension and platform scaling, and
//! make the whole bundle a named, serializable profile.  The constants
//! below are exactly the `ns_lbp_65nm` built-in (asserted cost-identical
//! by `hw`'s parity tests).
//!
//! Calibration (TSMC 65 nm GP, 1.1 V, 1.25 GHz — DESIGN.md §Substitutions):
//! the compute-op energy is anchored to the paper's 37.4 TOPS/W headline:
//! one three-row activation performs 256 parallel bit-line ops, so
//! `E_compute = 256 ops / 37.4 TOPS/W = 6.84 pJ`; read/write energies use
//! typical 8 KB 65 nm SRAM access costs; the DPU/ADC constants are standard
//! 65 nm figures.  Area follows Table 3: the reconfigurable SA costs 3.4×
//! a standard SA.

use crate::dpu::DpuStats;
use crate::isa::ExecStats;
use crate::sram::CacheGeometry;

/// Per-event energy/time constants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyParams {
    /// Clock frequency [GHz] (paper: 1.25 GHz at 1.1 V).
    pub freq_ghz: f64,
    /// Three-row compute activation incl. SA + result latch [pJ/row-op].
    pub compute_op_pj: f64,
    /// Single-row decoupled read [pJ].
    pub row_read_pj: f64,
    /// Row write [pJ].
    pub row_write_pj: f64,
    /// Controller/decoder overhead per cycle [pJ].
    pub ctrl_cycle_pj: f64,
    /// DPU events [pJ].
    pub bitcount_pj: f64,
    pub shift_pj: f64,
    pub add_pj: f64,
    pub activation_pj: f64,
    pub quantize_pj: f64,
    pub shifted_relu_pj: f64,
    /// SAR ADC energy per resolved bit [pJ].
    pub adc_bit_pj: f64,
    /// Pixel readout (CDS, column amp) [pJ/pixel].
    pub pixel_read_pj: f64,
    /// Off-chip transmission [pJ/bit] (baselines without near-sensor
    /// processing pay this for every raw pixel bit).
    pub offchip_bit_pj: f64,
    /// 8-bit MAC on a conventional digital datapath [pJ] (CNN baselines).
    pub mac8_pj: f64,
    /// Floating-point op [pJ] (LBCNN's batch-norm / 1x1 float path).
    pub flop_pj: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self {
            freq_ghz: 1.25,
            // 256 bit-ops per activation / 37.4 TOPS/W
            compute_op_pj: 256.0 / 37.4,
            row_read_pj: 4.8,
            row_write_pj: 5.5,
            ctrl_cycle_pj: 0.40,
            bitcount_pj: 1.2,
            shift_pj: 0.30,
            add_pj: 0.35,
            activation_pj: 1.5,
            quantize_pj: 0.9,
            shifted_relu_pj: 0.5,
            adc_bit_pj: 0.60,
            pixel_read_pj: 0.20,
            offchip_bit_pj: 12.0,
            mac8_pj: 2.8,
            flop_pj: 7.0,
        }
    }
}

/// Itemized energy account [pJ].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub compute_pj: f64,
    pub read_pj: f64,
    pub write_pj: f64,
    pub ctrl_pj: f64,
    pub dpu_pj: f64,
    pub sensor_pj: f64,
    pub transmission_pj: f64,
}

impl EnergyBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.read_pj + self.write_pj + self.ctrl_pj
            + self.dpu_pj + self.sensor_pj + self.transmission_pj
    }

    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.compute_pj += o.compute_pj;
        self.read_pj += o.read_pj;
        self.write_pj += o.write_pj;
        self.ctrl_pj += o.ctrl_pj;
        self.dpu_pj += o.dpu_pj;
        self.sensor_pj += o.sensor_pj;
        self.transmission_pj += o.transmission_pj;
    }
}

/// The model.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyModel {
    pub params: EnergyParams,
}

impl EnergyModel {
    pub fn new(params: EnergyParams) -> Self {
        Self { params }
    }

    /// Cycle time [ns].
    pub fn cycle_ns(&self) -> f64 {
        1.0 / self.params.freq_ghz
    }

    /// Energy of an ISA execution trace.
    pub fn exec_energy(&self, stats: &ExecStats) -> EnergyBreakdown {
        EnergyBreakdown {
            compute_pj: stats.compute_ops as f64 * self.params.compute_op_pj,
            read_pj: stats.row_reads as f64 * self.params.row_read_pj,
            write_pj: stats.row_writes as f64 * self.params.row_write_pj,
            ctrl_pj: stats.cycles as f64 * self.params.ctrl_cycle_pj,
            ..Default::default()
        }
    }

    /// Wall-clock of an ISA trace on one sub-array [ns].
    pub fn exec_time_ns(&self, stats: &ExecStats) -> f64 {
        stats.cycles as f64 * self.cycle_ns()
    }

    /// Energy of the DPU activity.
    pub fn dpu_energy(&self, stats: &DpuStats) -> EnergyBreakdown {
        let p = &self.params;
        EnergyBreakdown {
            dpu_pj: stats.bitcounts as f64 * p.bitcount_pj
                + stats.shifts as f64 * p.shift_pj
                + stats.adds as f64 * p.add_pj
                + stats.activations as f64 * p.activation_pj
                + stats.quantize_ops as f64 * p.quantize_pj
                + stats.shifted_relus as f64 * p.shifted_relu_pj,
            ..Default::default()
        }
    }

    /// Sensor-side energy: CDS readout + per-bit ADC (the Ap-LBP LSB skip
    /// reduces `effective_bits`).
    pub fn sensor_energy(&self, pixels: u64, effective_bits: u64) -> EnergyBreakdown {
        EnergyBreakdown {
            sensor_pj: pixels as f64
                * (self.params.pixel_read_pj
                    + effective_bits as f64 * self.params.adc_bit_pj),
            ..Default::default()
        }
    }

    /// Off-chip transmission cost of shipping `bits` out of the node.
    pub fn transmission_energy(&self, bits: u64) -> EnergyBreakdown {
        EnergyBreakdown {
            transmission_pj: bits as f64 * self.params.offchip_bit_pj,
            ..Default::default()
        }
    }

    /// Peak compute efficiency [TOPS/W]: bit-ops per compute activation
    /// over its energy.  Reproduces the paper's 37.4 at defaults.
    pub fn tops_per_watt(&self, lanes_per_op: u64) -> f64 {
        // ops / (pJ) == TOPS/W  (1 op/pJ = 1 TOPS/W)
        lanes_per_op as f64 / self.params.compute_op_pj
    }

    /// Peak throughput of a whole cache slice [Tera-ops/s]: every compute
    /// sub-array issues one row-op per cycle.
    pub fn peak_tops(&self, geometry: &CacheGeometry) -> f64 {
        geometry.total_subarrays() as f64
            * geometry.cols as f64
            * self.params.freq_ghz
            * 1e9
            / 1e12
    }
}

// ---------------------------------------------------------------------------
// Area model (Table 3)
// ---------------------------------------------------------------------------

/// Area accounting at 65 nm (Table 3 comparisons).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AreaModel {
    /// 8T bit-cell area [µm²] (65 nm GP).
    pub bitcell_um2: f64,
    /// Standard sense amplifier area [µm²/column].
    pub sa_um2: f64,
    /// Compute-SA overhead factor over a standard SA (paper: 3.4×).
    pub sa_overhead: f64,
    /// Row decoder + ctrl area per sub-array [µm²].
    pub periphery_um2: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        Self {
            bitcell_um2: 0.98,   // 8T cell, 65 nm GP
            sa_um2: 95.0,        // standard latch SA per column
            sa_overhead: 3.4,    // paper Table 3
            periphery_um2: 9_000.0,
        }
    }
}

impl AreaModel {
    /// One compute sub-array [mm²].
    pub fn subarray_mm2(&self, rows: usize, cols: usize) -> f64 {
        let cells = rows as f64 * cols as f64 * self.bitcell_um2;
        let sas = cols as f64 * self.sa_um2 * self.sa_overhead;
        (cells + sas + self.periphery_um2) / 1e6
    }

    /// Memory-only sub-array (standard SA) [mm²] — the overhead baseline.
    pub fn subarray_memory_only_mm2(&self, rows: usize, cols: usize) -> f64 {
        let cells = rows as f64 * cols as f64 * self.bitcell_um2;
        let sas = cols as f64 * self.sa_um2;
        (cells + sas + self.periphery_um2) / 1e6
    }

    /// Whole cache slice [mm²].
    pub fn slice_mm2(&self, g: &CacheGeometry) -> f64 {
        g.total_subarrays() as f64 * self.subarray_mm2(g.rows, g.cols)
    }

    /// Fractional area cost of making the cache computational.
    pub fn compute_overhead_fraction(&self, g: &CacheGeometry) -> f64 {
        let mem = self.subarray_memory_only_mm2(g.rows, g.cols);
        let cmp = self.subarray_mm2(g.rows, g.cols);
        (cmp - mem) / mem
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Opcode;

    #[test]
    fn headline_tops_per_watt() {
        let m = EnergyModel::default();
        let v = m.tops_per_watt(256);
        assert!((v - 37.4).abs() < 1e-9, "{v}");
    }

    #[test]
    fn cycle_time_matches_1_25_ghz() {
        let m = EnergyModel::default();
        assert!((m.cycle_ns() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn exec_energy_itemization() {
        let mut stats = ExecStats::default();
        stats.compute_ops = 10;
        stats.row_reads = 4;
        stats.row_writes = 14;
        stats.cycles = 20;
        let m = EnergyModel::default();
        let e = m.exec_energy(&stats);
        let p = m.params;
        assert!((e.compute_pj - 10.0 * p.compute_op_pj).abs() < 1e-9);
        assert!((e.read_pj - 4.0 * p.row_read_pj).abs() < 1e-9);
        assert!((e.write_pj - 14.0 * p.row_write_pj).abs() < 1e-9);
        assert!((e.total_pj()
            - (e.compute_pj + e.read_pj + e.write_pj + e.ctrl_pj))
            .abs()
            < 1e-9);
        assert!((m.exec_time_ns(&stats) - 16.0).abs() < 1e-12);
        stats.by_opcode.add(Opcode::Cmp, 1);
        assert_eq!(stats.by_opcode.get(Opcode::Cmp), 1);
    }

    #[test]
    fn sensor_lsb_skip_saves_energy() {
        let m = EnergyModel::default();
        let full = m.sensor_energy(784, 8).total_pj();
        let apx2 = m.sensor_energy(784, 6).total_pj();
        assert!(apx2 < full);
        // saving is exactly 2 ADC bits per pixel
        let want = 784.0 * 2.0 * m.params.adc_bit_pj;
        assert!(((full - apx2) - want).abs() < 1e-9);
    }

    #[test]
    fn offchip_transmission_dominates_local_compute() {
        // the paper's premise: shipping raw pixels off-chip costs far more
        // than computing locally.
        let m = EnergyModel::default();
        let raw_bits = 784 * 8;
        let tx = m.transmission_energy(raw_bits).total_pj();
        let mut stats = ExecStats::default();
        stats.compute_ops = 784; // a full LBP pass is ~1 op/pixel-ish
        stats.cycles = 784;
        let local = m.exec_energy(&stats).total_pj();
        assert!(tx > 5.0 * local, "tx {tx} vs local {local}");
    }

    #[test]
    fn peak_tops_of_paper_slice() {
        let m = EnergyModel::default();
        let g = CacheGeometry::default();
        // 320 sub-arrays × 256 lanes × 1.25 GHz = 102.4 TOPS
        assert!((m.peak_tops(&g) - 102.4).abs() < 1e-9);
    }

    #[test]
    fn area_overhead_in_table3_band() {
        let a = AreaModel::default();
        let g = CacheGeometry::default();
        let f = a.compute_overhead_fraction(&g);
        // SA overhead 3.4× on ~10% SA share ⇒ array-level overhead well
        // under 2× (the paper's "light modification" claim)
        assert!(f > 0.0 && f < 1.0, "overhead fraction {f}");
        assert!(a.slice_mm2(&g) > 0.0);
        assert!(a.subarray_mm2(256, 256) > a.subarray_memory_only_mm2(256, 256));
    }

    #[test]
    fn breakdown_add_merges() {
        let mut a = EnergyBreakdown { compute_pj: 1.0, ..Default::default() };
        a.add(&EnergyBreakdown { compute_pj: 2.0, dpu_pj: 3.0, ..Default::default() });
        assert!((a.compute_pj - 3.0).abs() < 1e-12);
        assert!((a.dpu_pj - 3.0).abs() < 1e-12);
    }
}
