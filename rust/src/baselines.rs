//! Comparison-design cost models for Fig. 11 (paper §6.3).
//!
//! Fig. 11 compares four designs on SVHN:
//!
//! * **NS-LBP running Ap-LBP** — this work: 65 nm, 1.25 GHz, PAC skip
//!   (fewer samples compared, fewer mapping-table accesses, fewer RBL
//!   bit-planes processed) + the sensor-side ADC LSB skip.
//! * **LBPNet** on the prior-generation compute-SRAM platform of [38]
//!   (28 nm transposable 8T, bit-serial, 475 MHz) — exact LBP, no skips.
//! * **8-bit quantized CNN** on [38] — bit-serial MACs.
//! * **LBCNN** on [38] — binary ancestor convolutions + float 1×1 fusion
//!   and batch-norm (the float path is its energy Achilles heel).
//!
//! Every model is an *analytic* cost over the same op-count substrate
//! ([`crate::lbp::opcount`]); each design is a thin [`HwProfile`]
//! selection ([`Design::profile`] — `ns_lbp_65nm`, `sram38_28nm`,
//! `cnn8_digital`, `lbcnn`) over that substrate, so platform differences
//! live in the shared `hw` subsystem rather than in local constants.
//! The reproduction target is the *shape* of the paper's result (who
//! wins and by roughly what factor — Ap-LBP ~2.2×/4× over LBPNet,
//! ~5.2×/6.2× over CNN, ~4×/2.3× over LBCNN in energy/time), not the
//! absolute joules of the authors' testbed.

use crate::energy::EnergyBreakdown;
use crate::hw::{CostModel, HwProfile};
use crate::lbp::opcount::ApLbpOps;
use crate::sram::CacheGeometry;

/// The four Fig.-11 designs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Design {
    /// NS-LBP + Ap-LBP with `apx` approximated bits (paper optimum: 2).
    NsLbpApLbp { apx: u64 },
    /// Exact LBPNet on the [38]-style platform.
    LbpNet,
    /// 8-bit quantized CNN on the [38]-style platform.
    Cnn8bit,
    /// LBCNN on the [38]-style platform.
    Lbcnn,
}

impl Design {
    pub fn name(&self) -> String {
        match self {
            Design::NsLbpApLbp { apx } => format!("NS-LBP (Ap-LBP, apx={apx})"),
            Design::LbpNet => "LBPNet [44] on [38]".into(),
            Design::Cnn8bit => "CNN 8-bit on [38]".into(),
            Design::Lbcnn => "LBCNN [15] on [38]".into(),
        }
    }

    /// The hardware profile this design runs on — the Fig.-11 platform
    /// constants now live as named [`HwProfile`] built-ins.
    pub fn profile(&self) -> HwProfile {
        match self {
            Design::NsLbpApLbp { .. } => HwProfile::ns_lbp_65nm(),
            Design::LbpNet => HwProfile::sram38_28nm(),
            Design::Cnn8bit => HwProfile::cnn8_digital(),
            Design::Lbcnn => HwProfile::lbcnn(),
        }
    }
}

/// Cost of one inference.
#[derive(Clone, Debug)]
pub struct CostReport {
    pub design: String,
    pub energy: EnergyBreakdown,
    pub time_ns: f64,
    /// Parameter storage [bytes].
    pub memory_bytes: u64,
}

impl CostReport {
    pub fn energy_uj(&self) -> f64 {
        self.energy.total_pj() / 1e6
    }

    pub fn time_us(&self) -> f64 {
        self.time_ns / 1e3
    }
}

/// Per-image cost of `design` on `dataset` ("mnist" | "svhn"), priced
/// under the design's own built-in profile ([`Design::profile`]).
pub fn cost(design: Design, dataset: &str, geometry: &CacheGeometry)
            -> Option<CostReport> {
    cost_with_profile(design, dataset, &design.profile(), geometry)
}

/// Per-image cost of `design` under an explicit [`HwProfile`] — the
/// swap-in point for alternative hardware comparisons.  Returns `None`
/// for an unknown dataset, and for a MAC-based design (CNN / LBCNN)
/// priced under a profile without the required datapath
/// (`mac_cycles`/`mac_lanes`/`flop_lanes` of 0) — a zero-lane datapath
/// would otherwise report nonsense (zero or lane-starved time).
pub fn cost_with_profile(design: Design, dataset: &str, profile: &HwProfile,
                         geometry: &CacheGeometry) -> Option<CostReport> {
    match design {
        Design::NsLbpApLbp { apx } => {
            let net = ApLbpOps::for_dataset(dataset, apx)?;
            Some(lbp_cost(design, &net, profile, geometry,
                          /*planes=*/ 8 - apx, /*adc_bits=*/ 8 - apx))
        }
        Design::LbpNet => {
            let net = ApLbpOps::for_dataset(dataset, 0)?;
            Some(lbp_cost(design, &net, profile, geometry, 8, 8))
        }
        Design::Cnn8bit => cnn_cost(dataset, profile),
        Design::Lbcnn => lbcnn_cost(dataset, profile),
    }
}

/// Shared LBP-network cost (Ap-LBP on NS-LBP, or exact LBPNet on [38]).
fn lbp_cost(design: Design, net: &ApLbpOps, profile: &HwProfile,
            geometry: &CacheGeometry, planes: u64,
            adc_bits: u64) -> CostReport {
    let ops = match design {
        Design::NsLbpApLbp { .. } => net.total_aplbp(),
        _ => net.total_lbpnet(),
    };
    let p = &profile.energy;
    let lanes = geometry.cols as f64;

    // --- LBP layers: row-parallel in-memory compares --------------------
    // each scalar comparison occupies one lane for `planes` bit-plane
    // passes of the 7-instruction Algorithm-1 loop
    let batches = (ops.comparisons as f64 / lanes).ceil();
    let cycles_per_batch = 4.0 + 7.0 * planes as f64 + 2.0
        + 2.0 * 8.0 /* lane load: 2×8 transposed row writes */;
    let lbp_cycles = batches * cycles_per_batch;
    let mut e = EnergyBreakdown {
        compute_pj: batches * (7.0 * planes as f64) * p.compute_op_pj,
        read_pj: ops.reads as f64 / lanes * p.row_read_pj,
        write_pj: ops.writes as f64 / lanes * p.row_write_pj
            + batches * 16.0 * p.row_write_pj,
        ctrl_pj: lbp_cycles * p.ctrl_cycle_pj,
        ..Default::default()
    };

    // --- MLP (both networks share the quantized 2-layer head) -----------
    let (d1, hid, ncls) = mlp_dims(net);
    let and_ops = (d1 * hid + hid * ncls) as f64 * 16.0 / lanes; // 4×4 planes
    e.compute_pj += and_ops * p.compute_op_pj;
    e.dpu_pj += and_ops * (p.bitcount_pj + p.shift_pj + p.add_pj)
        + (hid + ncls) as f64 * p.activation_pj;
    let mlp_cycles = and_ops * 2.0; // AND + ctrl read per plane pair

    // --- sensor ----------------------------------------------------------
    let pixels = net.height * net.width * net.in_channels;
    e.add(&profile.sensor_cost(pixels, adc_bits).energy);

    // --- platform scaling -------------------------------------------------
    scale_energy(&mut e, profile.energy_scale);
    let subarrays = geometry.total_subarrays() as f64;
    let total_cycles = (lbp_cycles + mlp_cycles) / subarrays.max(1.0);
    let time_ns = total_cycles / profile.energy.freq_ghz;

    CostReport {
        design: design.name(),
        energy: e,
        time_ns,
        memory_bytes: lbp_net_memory(net),
    }
}

/// 8-bit CNN with the Table-1-equivalent layer budget, bit-serial on [38].
fn cnn_cost(dataset: &str, profile: &HwProfile) -> Option<CostReport> {
    if profile.mac_cycles == 0 || profile.mac_lanes == 0 {
        return None; // no MAC datapath on this profile
    }
    let net = ApLbpOps::for_dataset(dataset, 0)?;
    let p = &profile.energy;
    // Table 1: the CNN equivalent of each LBP layer costs p·q·ch·r·s MACs
    let pixels = net.height * net.width;
    let mut macs = 0u64;
    for l in 0..net.n_lbp_layers {
        macs += pixels * net.channels_into(l) * 9 * net.kernels_per_layer;
    }
    let (d1, hid, ncls) = mlp_dims(&net);
    macs += (d1 * hid + hid * ncls) as u64;

    let mut e = EnergyBreakdown {
        compute_pj: macs as f64 * p.mac8_pj,
        // every MAC reads an 8-bit weight + activation from the array
        read_pj: macs as f64 * 2.0 * 8.0 / 256.0 * p.row_read_pj,
        ..Default::default()
    };
    e.add(&profile.sensor_cost(pixels * net.in_channels, 8).energy);
    scale_energy(&mut e, profile.energy_scale);

    let cycles = macs as f64 * profile.mac_cycles as f64
        / profile.mac_lanes as f64;
    let time_ns = cycles / profile.energy.freq_ghz;

    // conv weights (8-bit) + FC weights (8-bit)
    let conv_w: u64 = (0..net.n_lbp_layers)
        .map(|l| net.channels_into(l) * 9 * net.kernels_per_layer)
        .sum();
    let memory = conv_w + (d1 * hid + hid * ncls) as u64;
    Some(CostReport {
        design: Design::Cnn8bit.name(),
        energy: e,
        time_ns,
        memory_bytes: memory,
    })
}

/// LBCNN: sparse binary ancestor convs (cheap, XNOR-ish) + float 1×1
/// fusion and 2-D batch-norm (the expensive part, per §2.2).
fn lbcnn_cost(dataset: &str, profile: &HwProfile) -> Option<CostReport> {
    if profile.mac_lanes == 0 || profile.flop_lanes == 0 {
        return None; // needs both the binary-conv array and a float path
    }
    let net = ApLbpOps::for_dataset(dataset, 0)?;
    let p = &profile.energy;
    let pixels = net.height * net.width;
    let n_anchor = 4 * net.kernels_per_layer; // LBCNN needs more ancestors
    let mut bin_ops = 0u64; // binary conv adds/subs
    let mut flops = 0u64; // float 1×1 + batch-norm
    for l in 0..net.n_lbp_layers {
        bin_ops += pixels * net.channels_into(l) * 9 * n_anchor;
        // 1×1 fusion: n_anchor→K float MACs/pixel; 2D batch-norm: linear in
        // feature-map size (the paper's model-complexity complaint)
        flops += pixels * n_anchor * net.kernels_per_layer
            + 2 * pixels * net.kernels_per_layer;
    }
    let (d1, hid, ncls) = mlp_dims(&net);
    flops += (d1 * hid + hid * ncls) as u64;

    let mut e = EnergyBreakdown {
        // binary add/sub ≈ 1/8 of an 8-bit MAC
        compute_pj: bin_ops as f64 * (p.mac8_pj / 8.0) + flops as f64 * p.flop_pj,
        read_pj: bin_ops as f64 / 256.0 * p.row_read_pj
            + flops as f64 * 2.0 * 32.0 / 256.0 / 8.0 * p.row_read_pj,
        ..Default::default()
    };
    e.add(&profile.sensor_cost(pixels * net.in_channels, 8).energy);
    scale_energy(&mut e, profile.energy_scale);

    // binary convs run fully bit-parallel over the array; floats on the
    // platform's SIMD float datapath
    let cycles = bin_ops as f64 / (profile.mac_lanes * 8) as f64
        + flops as f64 / profile.flop_lanes as f64;
    let time_ns = cycles / profile.energy.freq_ghz;

    // ancestors (1 bit, sparse) + float 1×1 weights + bn params (f32)
    let anchor_bits: u64 = (0..net.n_lbp_layers)
        .map(|l| net.channels_into(l) * 9 * n_anchor)
        .sum();
    let small_float_params: u64 = (0..net.n_lbp_layers)
        .map(|_| n_anchor * net.kernels_per_layer + 2 * net.kernels_per_layer)
        .sum::<u64>();
    let fc_params = (d1 * hid + hid * ncls) as u64;
    // 1×1/bn in f32, FC stored in half precision for inference
    let memory = anchor_bits / 8 + small_float_params * 4 + fc_params * 2;
    Some(CostReport {
        design: Design::Lbcnn.name(),
        energy: e,
        time_ns,
        memory_bytes: memory,
    })
}

/// MLP dimensions shared by all designs (512 hidden, 10 classes).
fn mlp_dims(net: &ApLbpOps) -> (usize, usize, usize) {
    let ch_final = net.channels_into(net.n_lbp_layers) as usize;
    let d1 = (net.height as usize / 4) * (net.width as usize / 4) * ch_final;
    (d1, 512, 10)
}

/// Parameter storage of the LBP nets: sampling patterns (byte-packed
/// dy/dx/ch per point) + 4-bit MLP weights + f32 affines.
fn lbp_net_memory(net: &ApLbpOps) -> u64 {
    let patterns: u64 = (0..net.n_lbp_layers)
        .map(|_| net.kernels_per_layer * net.e * 2) // 2 B per sample point
        .sum::<u64>();
    let (d1, hid, ncls) = mlp_dims(net);
    patterns + ((d1 * hid + hid * ncls) / 2) as u64 + ((hid + ncls) * 8) as u64
}

fn scale_energy(e: &mut EnergyBreakdown, k: f64) {
    e.compute_pj *= k;
    e.read_pj *= k;
    e.write_pj *= k;
    e.ctrl_pj *= k;
    e.dpu_pj *= k;
    // sensor + transmission are node-independent
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reports() -> Vec<CostReport> {
        let g = CacheGeometry::default();
        [
            Design::NsLbpApLbp { apx: 2 },
            Design::LbpNet,
            Design::Cnn8bit,
            Design::Lbcnn,
        ]
        .iter()
        .map(|&d| cost(d, "svhn", &g).unwrap())
        .collect()
    }

    #[test]
    fn fig11a_energy_ordering_and_factors() {
        let r = reports();
        let (ap, lbp, cnn, lbcnn) =
            (r[0].energy_uj(), r[1].energy_uj(), r[2].energy_uj(), r[3].energy_uj());
        // who wins
        assert!(ap < lbp && ap < cnn && ap < lbcnn);
        // rough factors (paper: 2.2×, 5.2×, 4×)
        let f_lbp = lbp / ap;
        let f_cnn = cnn / ap;
        let f_lbcnn = lbcnn / ap;
        assert!((1.5..3.5).contains(&f_lbp), "vs LBPNet: {f_lbp}");
        assert!((3.5..8.0).contains(&f_cnn), "vs CNN: {f_cnn}");
        assert!((2.5..6.5).contains(&f_lbcnn), "vs LBCNN: {f_lbcnn}");
        // and the CNN must be the most expensive overall (MAC-dominated)
        assert!(cnn > lbp);
    }

    #[test]
    fn fig11b_time_ordering_and_factors() {
        let r = reports();
        let (ap, lbp, cnn, lbcnn) =
            (r[0].time_us(), r[1].time_us(), r[2].time_us(), r[3].time_us());
        assert!(ap < lbp && ap < cnn && ap < lbcnn);
        let f_lbp = lbp / ap;
        let f_cnn = cnn / ap;
        let f_lbcnn = lbcnn / ap;
        // paper: 4×, 6.2×, 2.3×
        assert!((2.5..6.0).contains(&f_lbp), "vs LBPNet: {f_lbp}");
        assert!((4.0..9.0).contains(&f_cnn), "vs CNN: {f_cnn}");
        assert!((1.5..4.0).contains(&f_lbcnn), "vs LBCNN: {f_lbcnn}");
        // crossover shape: LBCNN is faster than LBPNet (binary convs are
        // row-parallel) but burns more energy (float path) — Fig. 11a/b
        assert!(lbcnn < lbp, "LBCNN time {lbcnn} vs LBPNet {lbp}");
    }

    #[test]
    fn fig11c_memory_shape() {
        let r = reports();
        let (ap, lbp, cnn, lbcnn) = (r[0].memory_bytes, r[1].memory_bytes,
                                     r[2].memory_bytes, r[3].memory_bytes);
        // Ap-LBP ≈ LBPNet (paper: "doesn't remarkably reduce memory")
        assert_eq!(ap, lbp);
        // ~3.4× smaller than LBCNN
        let f = lbcnn as f64 / ap as f64;
        assert!((2.0..5.0).contains(&f), "LBCNN/ApLBP memory {f}");
        // CNN (8-bit) sits between the LBP nets and LBCNN
        assert!(cnn > ap && cnn < lbcnn);
    }

    #[test]
    fn apx_monotone_in_energy_and_time() {
        let g = CacheGeometry::default();
        let mut prev_e = f64::INFINITY;
        let mut prev_t = f64::INFINITY;
        for apx in 0..=4 {
            let r = cost(Design::NsLbpApLbp { apx }, "mnist", &g).unwrap();
            assert!(r.energy_uj() < prev_e, "apx={apx}");
            assert!(r.time_us() <= prev_t, "apx={apx}");
            prev_e = r.energy_uj();
            prev_t = r.time_us();
        }
    }

    #[test]
    fn unknown_dataset_is_none() {
        let g = CacheGeometry::default();
        assert!(cost(Design::LbpNet, "imagenet", &g).is_none());
    }

    #[test]
    fn mnist_cheaper_than_svhn() {
        let g = CacheGeometry::default();
        let m = cost(Design::NsLbpApLbp { apx: 2 }, "mnist", &g).unwrap();
        let s = cost(Design::NsLbpApLbp { apx: 2 }, "svhn", &g).unwrap();
        assert!(m.energy_uj() < s.energy_uj());
        assert!(m.time_us() < s.time_us());
    }

    #[test]
    fn designs_select_their_builtin_profiles_and_swap_cleanly() {
        assert_eq!(Design::NsLbpApLbp { apx: 2 }.profile().name,
                   "ns_lbp_65nm");
        assert_eq!(Design::LbpNet.profile().name, "sram38_28nm");
        assert_eq!(Design::Cnn8bit.profile().name, "cnn8_digital");
        assert_eq!(Design::Lbcnn.profile().name, "lbcnn");
        // swapping Ap-LBP onto the prior platform must cost more than on
        // its native 65 nm point — the A/B premise at the analytic level
        let g = CacheGeometry::default();
        let native = cost(Design::NsLbpApLbp { apx: 2 }, "svhn", &g).unwrap();
        let ported = cost_with_profile(Design::NsLbpApLbp { apx: 2 }, "svhn",
                                       &HwProfile::sram38_28nm(), &g)
            .unwrap();
        assert!(ported.energy_uj() > native.energy_uj());
        assert!(ported.time_us() > native.time_us());
        // MAC-based designs refuse profiles with no MAC/float datapath
        // instead of reporting zero time
        assert!(cost_with_profile(Design::Cnn8bit, "svhn",
                                  &HwProfile::ns_lbp_65nm(), &g)
            .is_none());
        assert!(cost_with_profile(Design::Lbcnn, "svhn",
                                  &HwProfile::ns_lbp_65nm(), &g)
            .is_none());
    }
}
