//! The architectural backend: the near-sensor in-SRAM simulation.
//!
//! Each frame flows through two redundant paths:
//!
//! * the **functional path** (`crate::model`) — fast bit-exact integer
//!   inference used for the logits, and
//! * the **architectural path** — the same LBP comparisons executed as
//!   Algorithm 1 over simulated compute sub-arrays
//!   (`crate::lbp::parallel_compare`) and, optionally, the MLP as
//!   in-memory AND/bitcount (`crate::mlp`), producing cycle/energy
//!   statistics *and* a per-frame equivalence check (any divergence is
//!   counted in `Telemetry::arch_mismatches` — it must be 0).
//!
//! Which pieces are simulated is controlled by `EngineConfig::arch`
//! ([`super::ArchSim`]); the modeled accelerator time assumes the
//! configured shard's sub-array budget (`EngineConfig::subarray_budget`).
//!
//! The batch path is where the paper's parallelism pays off: all frames
//! of one `infer_batch` call gather their LBP comparison lanes into a
//! *shared* lane list, so one Algorithm-1 pass over the sub-array fleet
//! packs lanes (and, at the tail of each frame's lane list, whole
//! chunks) from multiple frames.  The modeled time counts
//! `ceil(total_chunks / subarray_budget)` fleet passes for the whole
//! batch instead of per frame — batching a near-empty fleet amortizes to
//! a fraction of the per-frame cost, while logits stay bit-identical to
//! the per-frame path (chunk boundaries never change lane results).

use crate::dpu::Dpu;
use crate::energy::EnergyModel;
use crate::error::Result;
use crate::isa::{ExecStats, Executor};
use crate::lbp::parallel_compare;
use crate::mapping::LbpSubarrayMap;
use crate::mlp::MlpSubarrayMap;
use crate::model::{self, TensorU8};
use crate::params::{LbpLayer, NetParams};
use crate::sensor::Frame;
use crate::sram::{Region, SubArray};

use super::{BackendKind, BackendOutput, Capabilities, EngineConfig,
            FrameOutput, InferenceBackend, Telemetry};

/// The in-SRAM simulation backend.  Owns its scratch compute sub-array,
/// so one backend instance serves one worker/shard thread.
pub struct ArchitecturalBackend {
    params: NetParams,
    config: EngineConfig,
    energy_model: EnergyModel,
    scratch: SubArray,
}

impl ArchitecturalBackend {
    pub fn new(params: NetParams, config: EngineConfig) -> Result<Self> {
        config.validate()?;
        let mut energy_model = EnergyModel::default();
        energy_model.params.freq_ghz = config.system.circuit.freq_ghz;
        let g = &config.system.cache;
        let scratch = SubArray::new(g.rows, g.cols);
        Ok(Self { params, config, energy_model, scratch })
    }

    /// Compute sub-arrays available to this backend instance — the whole
    /// cache, or just the configured shard's bank slice.
    pub fn subarray_budget(&self) -> usize {
        self.config.subarray_budget()
    }

    /// Single-frame convenience wrapper around the batch path (a batch
    /// of one chunks and times exactly like the historical per-frame
    /// loop).
    pub fn infer_frame(&mut self, frame: &Frame) -> Result<FrameOutput> {
        let out = self.infer_batch(std::slice::from_ref(frame))?;
        out.frames.into_iter().next().ok_or_else(|| {
            crate::error::Error::Engine(
                "architectural backend returned no output for the frame"
                    .into(),
            )
        })
    }
}

impl InferenceBackend for ArchitecturalBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Architectural
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            available: true,
            produces_features: true,
            modeled_telemetry: true,
            detail: "in-SRAM architectural simulation (cycles/energy modeled)"
                .into(),
        }
    }

    fn infer_batch(&mut self, frames: &[Frame]) -> Result<BackendOutput> {
        let core = ArchCore {
            params: &self.params,
            config: &self.config,
            energy_model: &self.energy_model,
        };
        Ok(BackendOutput { frames: core.process_batch(frames,
                                                      &mut self.scratch)? })
    }
}

/// Per-frame accumulator threaded through the batched layers: ISA
/// activity, DPU counters, bit-level divergences, and this frame's share
/// of the modeled fleet time.
#[derive(Default)]
struct FrameAcc {
    exec: ExecStats,
    dpu: Dpu,
    mismatches: u64,
    arch_time_ns: f64,
}

/// Shared-state view used while the scratch sub-array is mutably borrowed.
struct ArchCore<'a> {
    params: &'a NetParams,
    config: &'a EngineConfig,
    energy_model: &'a EnergyModel,
}

impl ArchCore<'_> {
    fn subarray_budget(&self) -> usize {
        self.config.subarray_budget()
    }

    /// Lane order for one LBP layer: (y, x, kernel, sample≥apx).
    fn gather_pairs(&self, x: &TensorU8, layer: &LbpLayer) -> Vec<(u8, u8)> {
        let apx = self.params.config.apx_code;
        let mut pairs = Vec::with_capacity(
            x.h * x.w * layer.offsets.len() * (self.params.config.e - apx),
        );
        for y in 0..x.h {
            for xx in 0..x.w {
                for (k, pts) in layer.offsets.iter().enumerate() {
                    let pivot = x.get(y, xx, layer.pivot_ch[k] as usize);
                    for pt in pts.iter().skip(apx) {
                        let v = x.get_padded(
                            y as i64 + pt.dy as i64,
                            xx as i64 + pt.dx as i64,
                            pt.ch as usize,
                        );
                        pairs.push((v, pivot));
                    }
                }
            }
        }
        pairs
    }

    /// One LBP layer on the architectural path, over *every* frame of the
    /// batch at once.  All frames' comparison lanes concatenate into one
    /// shared lane list before chunking, so a single ≤`cols`-lane
    /// sub-array pass can pack lanes from more than one frame, and the
    /// fleet-pass count (the modeled-time unit) is amortized batch-wide.
    /// Returns every frame's joint output tensor; ISA activity is
    /// attributed to the frame owning each chunk's first lane, modeled
    /// time is split evenly (frames are shape-identical, so their lane
    /// counts are equal).
    ///
    /// Attribution granularity: when a frame's lane count is not a
    /// multiple of `cols`, a straddling chunk's stats (and therefore a
    /// sliver of per-frame energy) land on its first-lane owner — batch
    /// *totals* are exact, per-frame splits are chunk-granular.  Callers
    /// needing exact per-frame accounting should submit frames
    /// individually (`infer_frame` is bit- and stat-identical to the
    /// historical per-frame path).
    fn lbp_layer_arch_batch(&self, xs: &[TensorU8], layer: &LbpLayer,
                            scratch: &mut SubArray, map: &LbpSubarrayMap,
                            accs: &mut [FrameAcc]) -> Result<Vec<TensorU8>> {
        let cfg = &self.params.config;
        let apx = cfg.apx_code;
        let samples = cfg.e - apx;
        let cols = scratch.cols();
        if xs.is_empty() {
            return Ok(Vec::new());
        }

        // one shared lane list for the whole batch
        let mut pairs: Vec<(u8, u8)> = Vec::new();
        let mut frame_ends = Vec::with_capacity(xs.len());
        for x in xs {
            pairs.extend(self.gather_pairs(x, layer));
            frame_ends.push(pairs.len());
        }

        // run Algorithm 1 per ≤cols-lane chunk on the scratch sub-array;
        // chunks are cut from the shared list, not per frame
        let mut bits = Vec::with_capacity(pairs.len());
        let mut chunks = 0u64;
        let mut lane_base = 0usize;
        let mut owner = 0usize;
        for chunk in pairs.chunks(cols) {
            while lane_base >= frame_ends[owner] {
                owner += 1;
            }
            let acc = &mut accs[owner];
            map.load_lanes(scratch, 0, chunk)?;
            acc.exec.row_writes += 2 * map.bits as u64; // transposed load
            acc.exec.cycles += 2 * map.bits as u64;
            let mut ex = Executor::new(scratch);
            let out = parallel_compare(&mut ex, map, 0, chunk.len(),
                                       cfg.apx_pixel,
                                       self.config.arch.early_exit)?;
            acc.exec.merge(&ex.stats);
            bits.extend(out.bits);
            chunks += 1;
            lane_base += chunk.len();
        }

        // modeled time: the whole batch shares ceil(chunks / budget)
        // fleet passes — the parallel-LBP amortization
        let subarrays = self.subarray_budget() as f64;
        let cycles_per_batch = (2.0 * map.bits as f64)
            + 4.0 + 7.0 * (map.bits - cfg.apx_pixel) as f64 + 3.0;
        let layer_time_ns = (chunks as f64 / subarrays).ceil()
            * cycles_per_batch * self.energy_model.cycle_ns();
        let share_ns = layer_time_ns / xs.len() as f64;
        for acc in accs.iter_mut() {
            acc.arch_time_ns += share_ns;
        }

        // split the bit stream back per frame; assemble codes in the
        // same lane order and cross-check against the functional math
        let k_n = layer.offsets.len();
        let mut outs = Vec::with_capacity(xs.len());
        let mut lane = 0usize;
        for (x, acc) in xs.iter().zip(accs.iter_mut()) {
            let mut out = TensorU8::zeros(x.h, x.w, x.c + k_n);
            for y in 0..x.h {
                for xx in 0..x.w {
                    for ch in 0..x.c {
                        out.set(y, xx, ch, x.get(y, xx, ch));
                    }
                    for k in 0..k_n {
                        let mut code = 0u32;
                        for s in 0..samples {
                            if bits[lane + s] {
                                code |= 1 << (s + apx);
                            }
                        }
                        lane += samples;
                        let want = model::lbp_code(x, layer, k, y, xx, apx);
                        if code != want {
                            acc.mismatches += 1;
                        }
                        out.set(y, xx, x.c + k,
                                acc.dpu.shifted_relu_u8(code, cfg.e as u32));
                    }
                }
            }
            outs.push(out);
        }
        Ok(outs)
    }

    /// In-memory MLP layer (architectural); returns raw integer accums and
    /// mismatch count vs the functional matmul.
    fn mlp_layer_arch(&self, feats: &[u8], mlp: &crate::params::MlpLayer,
                      scratch: &mut SubArray, mmap: &MlpSubarrayMap,
                      exec: &mut ExecStats, dpu: &mut Dpu)
                      -> Result<(Vec<i64>, u64, f64)> {
        let cols = scratch.cols();
        let half = 1u8 << (self.params.config.w_bits - 1);
        let chunks: Vec<&[u8]> = feats.chunks(cols).collect();
        let mut accs = vec![0i64; mlp.o];
        let mut and_batches = 0u64;

        for (ci, chunk) in chunks.iter().enumerate() {
            let mut ex = Executor::new(scratch);
            mmap.load_vector(&mut ex, Region::Input, 0, chunk)?;
            let rowsum: i64 = chunk.iter().map(|&v| v as i64).sum();
            for o in 0..mlp.o {
                // weight column chunk, offset-stored unsigned
                let w_col: Vec<u8> = (0..chunk.len())
                    .map(|di| {
                        (mlp.weight(ci * cols + di, o) as i16 + half as i16)
                            as u8
                    })
                    .collect();
                mmap.load_vector(&mut ex, Region::Weight, 0, &w_col)?;
                accs[o] += mmap.dot_signed(&mut ex, dpu, 0, 0, chunk.len(),
                                           rowsum)?;
                and_batches += (mmap.act_bits * mmap.w_bits) as u64;
            }
            exec.merge(&ex.stats);
        }

        // cross-check against the functional integer matmul
        let want = model::int_matmul(feats, mlp);
        let mismatches =
            accs.iter().zip(&want).filter(|(a, w)| a != w).count() as u64;
        let subarrays = self.subarray_budget() as f64;
        let time_ns = (and_batches as f64 * 2.0 / subarrays).ceil()
            * self.energy_model.cycle_ns();
        Ok((accs, mismatches, time_ns))
    }

    /// Process a whole batch of digitized frames, sharing sub-array
    /// passes across frames in the LBP stage.
    fn process_batch(&self, frames: &[Frame], scratch: &mut SubArray)
                     -> Result<Vec<FrameOutput>> {
        let cfg = &self.params.config;
        let mut xs = Vec::with_capacity(frames.len());
        for frame in frames {
            xs.push(super::digitize(frame, cfg)?);
        }
        let map = LbpSubarrayMap::new(self.config.system.cache.region, 8)?;
        let mut accs: Vec<FrameAcc> =
            (0..frames.len()).map(|_| FrameAcc::default()).collect();

        // --- LBP layers (batched across frames) ------------------------------
        for layer in &self.params.lbp_layers {
            if self.config.arch.lbp {
                xs = self.lbp_layer_arch_batch(&xs, layer, scratch, &map,
                                               &mut accs)?;
            } else {
                for (x, acc) in xs.iter_mut().zip(accs.iter_mut()) {
                    *x = model::lbp_layer_forward(x, layer, cfg.e,
                                                  cfg.apx_code, &mut acc.dpu);
                }
            }
        }

        // the MLP map consumes the LBP map; build it once per batch
        let mmap = if self.config.arch.mlp {
            Some(MlpSubarrayMap::new(map, cfg.act_bits, cfg.w_bits)?)
        } else {
            None
        };

        let mut outputs = Vec::with_capacity(frames.len());
        for ((frame, x), acc) in
            frames.iter().zip(&xs).zip(accs.iter_mut())
        {
            // --- pooling + quantization (DPU) --------------------------------
            let s = cfg.pool;
            let vmax = (255 * s * s) as u32;
            let (ph, pw) = (x.h / s, x.w / s);
            let mut feats = Vec::with_capacity(ph * pw * x.c);
            for py in 0..ph {
                for px in 0..pw {
                    for ch in 0..x.c {
                        let mut sum = 0u32;
                        for dy in 0..s {
                            for dx in 0..s {
                                sum += x.get(py * s + dy, px * s + dx, ch)
                                    as u32;
                            }
                        }
                        feats.push(acc.dpu.quantize_pooled(
                            sum, vmax, cfg.act_bits as u32)?);
                    }
                }
            }

            // --- MLP ---------------------------------------------------------
            let logits = if let Some(mmap) = mmap.as_ref() {
                let (acc1, mm1, t1) =
                    self.mlp_layer_arch(&feats, &self.params.mlp1, scratch,
                                        mmap, &mut acc.exec, &mut acc.dpu)?;
                acc.mismatches += mm1;
                acc.arch_time_ns += t1;
                let hidden: Vec<u8> = acc1.iter().enumerate()
                    .map(|(o, &h)| acc.dpu.activation(
                        h, self.params.mlp1.scale[o],
                        self.params.mlp1.bias[o], cfg.act_bits as u32))
                    .collect();
                let (acc2, mm2, t2) =
                    self.mlp_layer_arch(&hidden, &self.params.mlp2, scratch,
                                        mmap, &mut acc.exec, &mut acc.dpu)?;
                acc.mismatches += mm2;
                acc.arch_time_ns += t2;
                acc2.iter().enumerate()
                    .map(|(o, &h)| acc.dpu.affine(
                        h, self.params.mlp2.scale[o],
                        self.params.mlp2.bias[o]))
                    .collect()
            } else {
                model::mlp_forward(self.params, &feats, &mut acc.dpu)?
            };

            // --- energy ------------------------------------------------------
            let mut energy = self.energy_model.exec_energy(&acc.exec);
            energy.add(&self.energy_model.dpu_energy(&acc.dpu.stats));
            let pixels = (cfg.height * cfg.width * cfg.in_channels) as u64;
            energy.add(&self.energy_model.sensor_energy(
                pixels,
                (8 - cfg.apx_pixel) as u64,
            ));

            outputs.push(FrameOutput {
                seq: frame.seq,
                predicted: model::argmax(&logits),
                logits,
                features: Some(feats),
                telemetry: Telemetry {
                    exec: std::mem::take(&mut acc.exec),
                    dpu: acc.dpu.stats,
                    energy,
                    arch_time_ns: acc.arch_time_ns,
                    arch_mismatches: acc.mismatches,
                    ..Default::default()
                },
            });
        }
        Ok(outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ArchSim, ShardSlice};
    use crate::params::synth::synth_params;
    use crate::testing::synth_frames;

    fn backend(arch: ArchSim, shard: Option<ShardSlice>)
               -> ArchitecturalBackend {
        let (_, params) = synth_params(5);
        let config = EngineConfig { arch, shard, ..Default::default() };
        ArchitecturalBackend::new(params, config).unwrap()
    }

    #[test]
    fn arch_lbp_matches_functional_bits() {
        let (_, params) = synth_params(5);
        let frames = synth_frames(&params, 2, 31).unwrap();
        let mut b = backend(
            ArchSim { lbp: true, mlp: true, early_exit: false }, None);
        let out = b.infer_batch(&frames).unwrap();
        let t = out.telemetry();
        assert_eq!(t.arch_mismatches, 0, "arch != functional");
        assert!(t.exec.compute_ops > 0);
        assert!(t.energy.total_pj() > 0.0);
        assert!(t.arch_time_ns > 0.0);
    }

    #[test]
    fn shard_slice_stretches_modeled_time_only() {
        let (_, params) = synth_params(5);
        let frames = synth_frames(&params, 1, 31).unwrap();
        let arch = ArchSim { lbp: true, mlp: false, early_exit: false };
        let mut full = backend(arch, None);
        let mut quarter = backend(arch, Some(ShardSlice { index: 0, count: 4 }));
        assert_eq!(full.subarray_budget(), 320);
        assert_eq!(quarter.subarray_budget(), 80);
        let rf = full.infer_frame(&frames[0]).unwrap();
        let rq = quarter.infer_frame(&frames[0]).unwrap();
        assert_eq!(rf.logits, rq.logits);
        assert_eq!(rf.telemetry.arch_mismatches, 0);
        assert_eq!(rq.telemetry.arch_mismatches, 0);
        assert!(rq.telemetry.arch_time_ns >= rf.telemetry.arch_time_ns);
    }

    #[test]
    fn rejects_wrong_frame_shape() {
        let mut b = backend(ArchSim::default(), None);
        let bad = Frame { rows: 5, cols: 5, channels: 1, pixels: vec![0; 25],
                          seq: 0 };
        assert!(b.infer_frame(&bad).is_err());
    }

    #[test]
    fn batched_frames_share_fleet_passes_with_identical_logits() {
        let (_, params) = synth_params(5);
        let frames = synth_frames(&params, 4, 37).unwrap();
        let arch = ArchSim { lbp: true, mlp: false, early_exit: false };
        let mut b = backend(arch, None);
        let singles: Vec<FrameOutput> = frames
            .iter()
            .map(|f| b.infer_frame(f).unwrap())
            .collect();
        let batched = b.infer_batch(&frames).unwrap();
        assert_eq!(batched.frames.len(), frames.len());
        for (s, f) in singles.iter().zip(&batched.frames) {
            assert_eq!(s.seq, f.seq);
            assert_eq!(s.logits, f.logits, "frame {}", f.seq);
            assert_eq!(f.telemetry.arch_mismatches, 0);
        }
        // the whole batch shares fleet passes: its modeled time must be
        // well under the sum of the per-frame runs (4x18 chunks/layer all
        // fit a single 320-sub-array pass under the default geometry)
        let sum_single: f64 =
            singles.iter().map(|r| r.telemetry.arch_time_ns).sum();
        let batched_total = batched.telemetry().arch_time_ns;
        assert!(batched_total > 0.0);
        assert!(
            batched_total < 0.5 * sum_single,
            "no amortization: batched {batched_total} vs {sum_single}"
        );
    }
}
