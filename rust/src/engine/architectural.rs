//! The architectural backend: the near-sensor in-SRAM simulation.
//!
//! Each frame flows through two redundant paths:
//!
//! * the **functional path** (`crate::model`) — fast bit-exact integer
//!   inference used for the logits, and
//! * the **architectural path** — the same LBP comparisons executed as
//!   Algorithm 1 over simulated compute sub-arrays
//!   (`crate::lbp::parallel_compare_into`) and, optionally, the MLP as
//!   in-memory AND/bitcount (`crate::mlp`), producing cycle/energy
//!   statistics *and* a per-frame equivalence check (any divergence is
//!   counted in `Telemetry::arch_mismatches` — it must be 0).
//!
//! Which pieces are simulated is controlled by `EngineConfig::arch`
//! ([`super::ArchSim`]); the modeled accelerator time assumes the
//! configured shard's sub-array budget (`EngineConfig::subarray_budget`).
//!
//! The batch path is where the paper's parallelism pays off: all frames
//! of one `infer_batch` call gather their LBP comparison lanes into a
//! *shared* lane list, so one Algorithm-1 pass over the sub-array fleet
//! packs lanes (and, at the tail of each frame's lane list, whole
//! chunks) from multiple frames.  The modeled time counts
//! `ceil(total_chunks / subarray_budget)` fleet passes for the whole
//! batch instead of per frame — batching a near-empty fleet amortizes to
//! a fraction of the per-frame cost, while logits stay bit-identical to
//! the per-frame path (chunk boundaries never change lane results).
//! The in-memory MLP packs the same way: every frame's AND/bitcount
//! batches pool into one per-layer fleet-pass count before dividing by
//! the sub-array budget.
//!
//! **Hot path (§Perf, see EXPERIMENTS.md):** everything static is
//! precomputed at build, everything transient lives in a persistent
//! arena.  The MLP weight bit-planes are transposed *once* into
//! [`WeightPlanes`] (the paper's weights-stationary premise — the seed
//! re-packed every weight column per output neuron per chunk per frame);
//! the sub-array maps and the functional fallback's gather tables
//! ([`crate::model::LbpLayerPlan`]) are built once; and the per-batch
//! lane lists, bit streams, plane staging rows, layer tensors and
//! accumulators live in an `ArchScratch` arena reused across batches, so the
//! steady-state compute loops perform no heap allocation (only the
//! returned logits/features, which escape into the output, are
//! allocated).  A serve shard keeps one backend per routed class, so the
//! arena persists across the whole traffic stream.
//!
//! All telemetry is priced through the configured hardware profile
//! (`SystemConfig::hw_profile()` → [`crate::hw::CostModel`]); swapping
//! `[hw] profile` re-prices energy and modeled time without touching the
//! simulated math.

use crate::dpu::Dpu;
use crate::error::Result;
use crate::hw::{Cost, CostModel, HwProfile};
use crate::isa::{ExecStats, Executor};
use crate::lbp::parallel_compare_into;
use crate::mapping::LbpSubarrayMap;
use crate::mlp::{MlpSubarrayMap, WeightPlanes};
use crate::model::{self, LbpLayerPlan, TensorU8};
use crate::obs::{EventKind, TraceEvent, Tracer};
use crate::params::{LbpLayer, MlpLayer, NetParams};
use crate::sensor::Frame;
use crate::sram::{Region, SubArray};

use super::{BackendKind, BackendOutput, Capabilities, EngineConfig,
            FrameOutput, InferenceBackend, Telemetry};

/// Persistent scratch arena: every transient the batch path needs, owned
/// by the backend and reused across `infer_batch` calls.  Buffers grow
/// to the steady-state size once and then stay warm — a shard serving a
/// fixed network shape stops allocating after its first batch.
#[derive(Default)]
struct ArchScratch {
    /// Shared (neighbor, pivot) lane list of the whole batch.
    pairs: Vec<(u8, u8)>,
    /// Cumulative per-frame end offsets into `pairs`.
    frame_ends: Vec<usize>,
    /// Comparator bits of every chunk, batch-wide.
    bits: Vec<bool>,
    /// Bit-plane staging rows for the transposed lane load.
    planes: Vec<u64>,
    /// Current layer inputs, one tensor per frame (ping half).
    xs: Vec<TensorU8>,
    /// Next layer outputs (pong half, swapped each layer).
    ys: Vec<TensorU8>,
    /// Per-frame statistic accumulators.
    accs: Vec<FrameAcc>,
    /// In-memory MLP layer accumulator (per frame, reused).
    mlp_acc: Vec<i64>,
    /// Functional cross-check accumulator for the same layer.
    mlp_want: Vec<i64>,
    /// Quantized hidden activations, one vector per frame.
    hidden: Vec<Vec<u8>>,
}

/// The in-SRAM simulation backend.  Owns its scratch compute sub-array
/// and arena, so one backend instance serves one worker/shard thread.
pub struct ArchitecturalBackend {
    params: NetParams,
    config: EngineConfig,
    cost_model: HwProfile,
    scratch: SubArray,
    /// Sub-array row map for the LBP lanes (built once).
    map: LbpSubarrayMap,
    /// W/I-region map, present when the in-memory MLP is simulated.
    mmap: Option<MlpSubarrayMap>,
    /// Prepacked weight bit-planes for (mlp1, mlp2); `Some` iff `mmap`.
    weight_planes: Option<(WeightPlanes, WeightPlanes)>,
    /// Per-layer gather tables for the functional LBP fallback.
    plans: Vec<LbpLayerPlan>,
    arena: ArchScratch,
    /// Stage-phase span source (disabled by default — zero cost).
    tracer: Tracer,
    /// Chaos comparator-variation injector: flips read bits at the
    /// Monte-Carlo decision-error rate of the `[faults]`-scaled sigma
    /// (`None` — zero cost — unless that rate is nonzero).
    flips: Option<crate::faults::BitFlips>,
}

impl ArchitecturalBackend {
    pub fn new(params: NetParams, config: EngineConfig) -> Result<Self> {
        Self::with_prepacked(params, config, None)
    }

    /// Build, reusing compiled tables from an artifact when given: the
    /// gather plans always, the weight bit-planes when the in-memory MLP
    /// is simulated.  Tables are validated against the params and cache
    /// geometry — a mismatch errors instead of silently repacking.
    pub fn with_prepacked(params: NetParams, config: EngineConfig,
                          prepacked: Option<&crate::engine::Prepacked>)
        -> Result<Self>
    {
        config.validate()?;
        let cost_model = config.system.hw_profile();
        let g = &config.system.cache;
        let scratch = SubArray::new(g.rows, g.cols);
        let map = LbpSubarrayMap::new(g.region, 8)?;
        let cfg = &params.config;
        // everything static packs once at build: the MLP map consumes
        // the LBP map, and the weight columns transpose into
        // chunk-aligned, offset-stored bit-plane buffers (or come
        // prepacked from a compiled artifact)
        let (mmap, weight_planes) = if config.arch.mlp {
            let mmap = MlpSubarrayMap::new(map, cfg.act_bits, cfg.w_bits)?;
            let planes = match prepacked {
                Some(p) => p.planes_for(&params, g.cols)?,
                None => (
                    WeightPlanes::pack(&params.mlp1, cfg.w_bits, g.cols)?,
                    WeightPlanes::pack(&params.mlp2, cfg.w_bits, g.cols)?,
                ),
            };
            (Some(mmap), Some(planes))
        } else {
            (None, None)
        };
        let plans = match prepacked {
            Some(p) => p.plans_for(&params)?,
            None => model::plan_layers(&params),
        };
        let flips = crate::faults::BitFlips::new(
            &config.system.faults,
            &config.system.circuit,
            config.shard.map_or(0, |s| s.index),
        );
        Ok(Self {
            params,
            config,
            cost_model,
            scratch,
            map,
            mmap,
            weight_planes,
            plans,
            arena: ArchScratch::default(),
            tracer: Tracer::disabled(),
            flips,
        })
    }

    /// Compute sub-arrays available to this backend instance — the whole
    /// cache, or just the configured shard's bank slice.
    pub fn subarray_budget(&self) -> usize {
        self.config.subarray_budget()
    }

    /// Single-frame convenience wrapper around the batch path (a batch
    /// of one chunks and times exactly like the historical per-frame
    /// loop).
    pub fn infer_frame(&mut self, frame: &Frame) -> Result<FrameOutput> {
        let out = self.infer_batch(std::slice::from_ref(frame))?;
        out.frames.into_iter().next().ok_or_else(|| {
            crate::error::Error::Engine(
                "architectural backend returned no output for the frame"
                    .into(),
            )
        })
    }
}

impl InferenceBackend for ArchitecturalBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Architectural
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            available: true,
            produces_features: true,
            modeled_telemetry: true,
            detail: "in-SRAM architectural simulation (cycles/energy modeled)"
                .into(),
        }
    }

    fn infer_batch(&mut self, frames: &[Frame]) -> Result<BackendOutput> {
        let core = ArchCore {
            params: &self.params,
            config: &self.config,
            cost_model: &self.cost_model,
            map: &self.map,
            mmap: self.mmap.as_ref(),
            weight_planes: self.weight_planes.as_ref(),
            plans: &self.plans,
            tracer: &self.tracer,
        };
        Ok(BackendOutput {
            frames: core.process_batch(frames, &mut self.scratch,
                                       &mut self.arena,
                                       self.flips.as_mut())?,
        })
    }

    fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }
}

/// Per-frame accumulator threaded through the batched layers: ISA
/// activity, DPU counters, bit-level divergences, and this frame's share
/// of the modeled fleet time.
#[derive(Default)]
struct FrameAcc {
    exec: ExecStats,
    dpu: Dpu,
    mismatches: u64,
    arch_time_ns: f64,
}

/// Shared-state view used while the scratch sub-array and arena are
/// mutably borrowed.
struct ArchCore<'a> {
    params: &'a NetParams,
    config: &'a EngineConfig,
    cost_model: &'a HwProfile,
    map: &'a LbpSubarrayMap,
    mmap: Option<&'a MlpSubarrayMap>,
    weight_planes: Option<&'a (WeightPlanes, WeightPlanes)>,
    plans: &'a [LbpLayerPlan],
    tracer: &'a Tracer,
}

impl ArchCore<'_> {
    fn subarray_budget(&self) -> usize {
        self.config.subarray_budget()
    }

    /// Close a stage phase span opened with
    /// `tracer.enabled().then(Instant::now)`.
    fn phase_span(&self, label: &'static str,
                  start: Option<std::time::Instant>) {
        if let Some(t0) = start {
            self.tracer.emit(TraceEvent {
                kind: EventKind::Phase,
                ts_ns: self.tracer.ts(t0),
                dur_ns: t0.elapsed().as_nanos() as u64,
                shard: self.config.shard.map_or(-1, |s| s.index as i32),
                backend: Some(BackendKind::Architectural),
                label,
                ..TraceEvent::default()
            });
        }
    }

    /// Lane order for one LBP layer: (y, x, kernel, sample≥apx),
    /// appended to the arena's shared lane list.
    fn gather_pairs_into(&self, x: &TensorU8, layer: &LbpLayer,
                         pairs: &mut Vec<(u8, u8)>) {
        let apx = self.params.config.apx_code;
        pairs.reserve(
            x.h * x.w * layer.offsets.len() * (self.params.config.e - apx),
        );
        for y in 0..x.h {
            for xx in 0..x.w {
                for (k, pts) in layer.offsets.iter().enumerate() {
                    let pivot = x.get(y, xx, layer.pivot_ch[k] as usize);
                    for pt in pts.iter().skip(apx) {
                        let v = x.get_padded(
                            y as i64 + pt.dy as i64,
                            xx as i64 + pt.dx as i64,
                            pt.ch as usize,
                        );
                        pairs.push((v, pivot));
                    }
                }
            }
        }
    }

    /// One LBP layer on the architectural path, over *every* frame of the
    /// batch at once.  All frames' comparison lanes concatenate into one
    /// shared lane list before chunking, so a single ≤`cols`-lane
    /// sub-array pass can pack lanes from more than one frame, and the
    /// fleet-pass count (the modeled-time unit) is amortized batch-wide.
    /// Writes every frame's joint output tensor into `ys` (reused arena
    /// tensors — the caller swaps the ping/pong halves); ISA activity is
    /// attributed to the frame owning each chunk's first lane, modeled
    /// time is split evenly (frames are shape-identical, so their lane
    /// counts are equal).
    ///
    /// Attribution granularity: when a frame's lane count is not a
    /// multiple of `cols`, a straddling chunk's stats (and therefore a
    /// sliver of per-frame energy) land on its first-lane owner — batch
    /// *totals* are exact, per-frame splits are chunk-granular.  Callers
    /// needing exact per-frame accounting should submit frames
    /// individually (`infer_frame` is bit- and stat-identical to the
    /// historical per-frame path).
    #[allow(clippy::too_many_arguments)]
    fn lbp_layer_arch_batch(&self, layer: &LbpLayer, scratch: &mut SubArray,
                            xs: &[TensorU8], ys: &mut Vec<TensorU8>,
                            pairs: &mut Vec<(u8, u8)>,
                            frame_ends: &mut Vec<usize>,
                            bits: &mut Vec<bool>, planes: &mut Vec<u64>,
                            flips: Option<&mut crate::faults::BitFlips>,
                            accs: &mut [FrameAcc]) -> Result<()> {
        let cfg = &self.params.config;
        let apx = cfg.apx_code;
        let samples = cfg.e - apx;
        let cols = scratch.cols();
        let map = self.map;
        if xs.is_empty() {
            ys.clear();
            return Ok(());
        }

        // one shared lane list for the whole batch (arena-resident)
        pairs.clear();
        frame_ends.clear();
        for x in xs {
            self.gather_pairs_into(x, layer, pairs);
            frame_ends.push(pairs.len());
        }

        // run Algorithm 1 per ≤cols-lane chunk on the scratch sub-array;
        // chunks are cut from the shared list, not per frame
        bits.clear();
        let mut chunks = 0u64;
        let mut lane_base = 0usize;
        let mut owner = 0usize;
        for chunk in pairs.chunks(cols) {
            while lane_base >= frame_ends[owner] {
                owner += 1;
            }
            let acc = &mut accs[owner];
            map.load_lanes_with(scratch, 0, chunk, planes)?;
            acc.exec.row_writes += 2 * map.bits as u64; // transposed load
            acc.exec.cycles += 2 * map.bits as u64;
            let mut ex = Executor::new(scratch);
            parallel_compare_into(&mut ex, map, 0, chunk.len(),
                                  cfg.apx_pixel, self.config.arch.early_exit,
                                  bits)?;
            acc.exec.merge(&ex.stats);
            chunks += 1;
            lane_base += chunk.len();
        }

        // modeled time: the whole batch shares ceil(chunks / budget)
        // fleet passes — the parallel-LBP amortization
        let subarrays = self.subarray_budget() as f64;
        let cycles_per_batch = (2.0 * map.bits as f64)
            + 4.0 + 7.0 * (map.bits - cfg.apx_pixel) as f64 + 3.0;
        let layer_time_ns = (chunks as f64 / subarrays).ceil()
            * cycles_per_batch * self.cost_model.cycle_ns();
        let share_ns = layer_time_ns / xs.len() as f64;
        for acc in accs.iter_mut() {
            acc.arch_time_ns += share_ns;
        }

        // chaos comparator variation: flip sensed bits at the scaled
        // Monte-Carlo decision-error rate *before* code assembly, so the
        // divergence flows through the functional cross-check below and
        // surfaces as arch mismatches in the frame telemetry
        if let Some(f) = flips {
            f.apply(bits);
        }

        // split the bit stream back per frame; assemble codes in the
        // same lane order and cross-check against the functional math
        let k_n = layer.offsets.len();
        ys.resize_with(xs.len(), TensorU8::default);
        let mut lane = 0usize;
        for ((x, out), acc) in xs.iter().zip(ys.iter_mut())
            .zip(accs.iter_mut())
        {
            out.reset(x.h, x.w, x.c + k_n);
            for y in 0..x.h {
                for xx in 0..x.w {
                    for ch in 0..x.c {
                        out.set(y, xx, ch, x.get(y, xx, ch));
                    }
                    for k in 0..k_n {
                        let mut code = 0u32;
                        for s in 0..samples {
                            if bits[lane + s] {
                                code |= 1 << (s + apx);
                            }
                        }
                        lane += samples;
                        let want = model::lbp_code(x, layer, k, y, xx, apx);
                        if code != want {
                            acc.mismatches += 1;
                        }
                        out.set(y, xx, x.c + k,
                                acc.dpu.shifted_relu_u8(code, cfg.e as u32));
                    }
                }
            }
        }
        Ok(())
    }

    /// In-memory MLP layer (architectural) for one frame; fills `accs`
    /// with the raw integer accums (arena buffer) and returns the
    /// mismatch count vs the functional matmul plus the AND-batch count
    /// (the fleet-pass unit the batch-level time model amortizes across
    /// frames).  The W region loads from the prepacked bit-planes — no
    /// per-neuron column collection or transposition (§Perf).
    #[allow(clippy::too_many_arguments)]
    fn mlp_layer_arch(&self, feats: &[u8], mlp: &MlpLayer,
                      planes: &WeightPlanes, scratch: &mut SubArray,
                      mmap: &MlpSubarrayMap, exec: &mut ExecStats,
                      dpu: &mut Dpu, accs: &mut Vec<i64>,
                      want: &mut Vec<i64>) -> Result<(u64, u64)> {
        let cols = scratch.cols();
        accs.clear();
        accs.resize(mlp.o, 0);
        let mut and_batches = 0u64;

        for (ci, chunk) in feats.chunks(cols).enumerate() {
            let mut ex = Executor::new(scratch);
            mmap.load_vector(&mut ex, Region::Input, 0, chunk)?;
            let rowsum: i64 = chunk.iter().map(|&v| v as i64).sum();
            for o in 0..mlp.o {
                mmap.load_weight_planes(&mut ex, 0, planes, ci, o)?;
                accs[o] += mmap.dot_signed(&mut ex, dpu, 0, 0, chunk.len(),
                                           rowsum)?;
                and_batches += (mmap.act_bits * mmap.w_bits) as u64;
            }
            exec.merge(&ex.stats);
        }

        // cross-check against the functional integer matmul
        model::int_matmul_into(feats, mlp, want);
        let mismatches =
            accs.iter().zip(want.iter()).filter(|(a, w)| a != w).count()
                as u64;
        Ok((mismatches, and_batches))
    }

    /// Modeled time of one MLP layer's AND/bitcount batches spread over
    /// the sub-array fleet.  `and_batches` is summed across every frame
    /// of the dispatch before the ceiling, so — exactly like the LBP
    /// lanes — a batch shares fleet passes instead of paying
    /// `ceil(per-frame / budget)` once per frame.  For a single frame
    /// this reduces to the historical per-frame formula.
    fn mlp_layer_time_ns(&self, and_batches: u64) -> f64 {
        let subarrays = self.subarray_budget() as f64;
        (and_batches as f64 * 2.0 / subarrays).ceil()
            * self.cost_model.cycle_ns()
    }

    /// Process a whole batch of digitized frames, sharing sub-array
    /// passes across frames in the LBP *and* in-memory-MLP stages.  All
    /// transients live in `arena`; only the per-frame outputs allocate.
    fn process_batch(&self, frames: &[Frame], scratch: &mut SubArray,
                     arena: &mut ArchScratch,
                     mut flips: Option<&mut crate::faults::BitFlips>)
                     -> Result<Vec<FrameOutput>> {
        if frames.is_empty() {
            return Ok(Vec::new());
        }
        let cfg = &self.params.config;
        let ArchScratch { pairs, frame_ends, bits, planes, xs, ys, accs,
                          mlp_acc, mlp_want, hidden } = arena;
        xs.resize_with(frames.len(), TensorU8::default);
        for (frame, x) in frames.iter().zip(xs.iter_mut()) {
            super::digitize_into(frame, cfg, x)?;
        }
        accs.clear();
        accs.resize_with(frames.len(), FrameAcc::default);

        // --- LBP layers (batched across frames) ------------------------------
        let lbp_start = self.tracer.enabled()
            .then(std::time::Instant::now);
        for (layer, plan) in self.params.lbp_layers.iter().zip(self.plans) {
            if self.config.arch.lbp {
                self.lbp_layer_arch_batch(layer, scratch, xs, ys, pairs,
                                          frame_ends, bits, planes,
                                          flips.as_deref_mut(), accs)?;
            } else {
                ys.resize_with(xs.len(), TensorU8::default);
                for ((x, y), acc) in
                    xs.iter().zip(ys.iter_mut()).zip(accs.iter_mut())
                {
                    model::lbp_layer_forward_into(x, layer, plan, cfg.e,
                                                  cfg.apx_code, &mut acc.dpu,
                                                  y);
                }
            }
            std::mem::swap(xs, ys);
        }
        self.phase_span("lbp", lbp_start);

        // --- pooling + quantization (DPU, per frame) ------------------------
        let mut feats_batch: Vec<Vec<u8>> = Vec::with_capacity(frames.len());
        for (x, acc) in xs.iter().zip(accs.iter_mut()) {
            feats_batch.push(model::pool_quantize(x, cfg.pool, cfg.act_bits,
                                                  &mut acc.dpu)?);
        }

        // --- MLP (AND/bitcount batches packed across frames) ----------------
        // Each frame's dots still run on the scratch sub-array, but the
        // fleet-pass accounting pools every frame's AND batches per layer
        // before dividing by the sub-array budget — the same amortization
        // the LBP lanes get, with bit-identical logits (packing only
        // changes which sub-array a batch is modeled on, never the math).
        let n = frames.len() as f64;
        let mlp_start = self.tracer.enabled()
            .then(std::time::Instant::now);
        let logits_batch: Vec<Vec<f32>> = if let (Some(mmap), Some((p1, p2))) =
            (self.mmap, self.weight_planes)
        {
            let m1 = &self.params.mlp1;
            let mut and1 = 0u64;
            hidden.resize_with(frames.len(), Vec::new);
            for ((feats, h), acc) in feats_batch.iter().zip(hidden.iter_mut())
                .zip(accs.iter_mut())
            {
                let (mm1, ab) =
                    self.mlp_layer_arch(feats, m1, p1, scratch, mmap,
                                        &mut acc.exec, &mut acc.dpu,
                                        mlp_acc, mlp_want)?;
                acc.mismatches += mm1;
                and1 += ab;
                h.clear();
                h.extend(mlp_acc.iter().enumerate().map(|(o, &v)| {
                    acc.dpu.activation(v, m1.scale[o], m1.bias[o],
                                       cfg.act_bits as u32)
                }));
            }
            let m2 = &self.params.mlp2;
            let mut and2 = 0u64;
            let mut logits_batch = Vec::with_capacity(frames.len());
            for (h, acc) in hidden.iter().zip(accs.iter_mut()) {
                let (mm2, ab) =
                    self.mlp_layer_arch(h, m2, p2, scratch, mmap,
                                        &mut acc.exec, &mut acc.dpu,
                                        mlp_acc, mlp_want)?;
                acc.mismatches += mm2;
                and2 += ab;
                logits_batch.push(mlp_acc.iter().enumerate()
                    .map(|(o, &v)| acc.dpu.affine(v, m2.scale[o],
                                                  m2.bias[o]))
                    .collect());
            }
            // whole-batch fleet passes, split evenly (frames are
            // shape-identical, so their AND-batch counts are equal)
            let share_ns = (self.mlp_layer_time_ns(and1)
                + self.mlp_layer_time_ns(and2)) / n;
            for acc in accs.iter_mut() {
                acc.arch_time_ns += share_ns;
            }
            logits_batch
        } else {
            feats_batch.iter().zip(accs.iter_mut())
                .map(|(feats, acc)| {
                    model::mlp_forward(self.params, feats, &mut acc.dpu)
                })
                .collect::<Result<Vec<_>>>()?
        };
        self.phase_span("mlp", mlp_start);

        // --- cost under the active profile ----------------------------------
        let pixels = (cfg.height * cfg.width * cfg.in_channels) as u64;
        let mut outputs = Vec::with_capacity(frames.len());
        for ((frame, feats), (logits, acc)) in frames
            .iter()
            .zip(feats_batch)
            .zip(logits_batch.into_iter().zip(accs.iter_mut()))
        {
            let mut energy = self.cost_model.exec_cost(&acc.exec).energy;
            energy.add(&self.cost_model.dpu_cost(&acc.dpu.stats).energy);
            energy.add(&self.cost_model.sensor_cost(
                pixels,
                (8 - cfg.apx_pixel) as u64,
            ).energy);

            outputs.push(FrameOutput {
                seq: frame.seq,
                predicted: model::argmax(&logits),
                logits,
                features: Some(feats),
                telemetry: Telemetry {
                    profile: self.cost_model.name.clone(),
                    exec: std::mem::take(&mut acc.exec),
                    dpu: acc.dpu.stats,
                    cost: Cost { energy, time_ns: acc.arch_time_ns },
                    arch_mismatches: acc.mismatches,
                    ..Default::default()
                },
            });
        }
        Ok(outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ArchSim, ShardSlice};
    use crate::params::synth::synth_params;
    use crate::testing::synth_frames;

    fn backend(arch: ArchSim, shard: Option<ShardSlice>)
               -> ArchitecturalBackend {
        let (_, params) = synth_params(5);
        let config = EngineConfig { arch, shard, ..Default::default() };
        ArchitecturalBackend::new(params, config).unwrap()
    }

    #[test]
    fn arch_lbp_matches_functional_bits() {
        let (_, params) = synth_params(5);
        let frames = synth_frames(&params, 2, 31).unwrap();
        let mut b = backend(
            ArchSim { lbp: true, mlp: true, early_exit: false }, None);
        let out = b.infer_batch(&frames).unwrap();
        let t = out.telemetry();
        assert_eq!(t.arch_mismatches, 0, "arch != functional");
        assert!(t.exec.compute_ops > 0);
        assert!(t.cost.energy.total_pj() > 0.0);
        assert!(t.cost.time_ns > 0.0);
        assert_eq!(t.profile, "ns_lbp_65nm");
    }

    #[test]
    fn shard_slice_stretches_modeled_time_only() {
        let (_, params) = synth_params(5);
        let frames = synth_frames(&params, 1, 31).unwrap();
        let arch = ArchSim { lbp: true, mlp: false, early_exit: false };
        let mut full = backend(arch, None);
        let mut quarter = backend(arch, Some(ShardSlice { index: 0, count: 4 }));
        assert_eq!(full.subarray_budget(), 320);
        assert_eq!(quarter.subarray_budget(), 80);
        let rf = full.infer_frame(&frames[0]).unwrap();
        let rq = quarter.infer_frame(&frames[0]).unwrap();
        assert_eq!(rf.logits, rq.logits);
        assert_eq!(rf.telemetry.arch_mismatches, 0);
        assert_eq!(rq.telemetry.arch_mismatches, 0);
        assert!(rq.telemetry.cost.time_ns >= rf.telemetry.cost.time_ns);
    }

    #[test]
    fn rejects_wrong_frame_shape() {
        let mut b = backend(ArchSim::default(), None);
        let bad = Frame { rows: 5, cols: 5, channels: 1, pixels: vec![0; 25],
                          seq: 0 };
        assert!(b.infer_frame(&bad).is_err());
    }

    #[test]
    fn batched_frames_share_fleet_passes_with_identical_logits() {
        let (_, params) = synth_params(5);
        let frames = synth_frames(&params, 4, 37).unwrap();
        let arch = ArchSim { lbp: true, mlp: false, early_exit: false };
        let mut b = backend(arch, None);
        let singles: Vec<FrameOutput> = frames
            .iter()
            .map(|f| b.infer_frame(f).unwrap())
            .collect();
        let batched = b.infer_batch(&frames).unwrap();
        assert_eq!(batched.frames.len(), frames.len());
        for (s, f) in singles.iter().zip(&batched.frames) {
            assert_eq!(s.seq, f.seq);
            assert_eq!(s.logits, f.logits, "frame {}", f.seq);
            assert_eq!(f.telemetry.arch_mismatches, 0);
        }
        // the whole batch shares fleet passes: its modeled time must be
        // well under the sum of the per-frame runs (4x18 chunks/layer all
        // fit a single 320-sub-array pass under the default geometry)
        let sum_single: f64 =
            singles.iter().map(|r| r.telemetry.cost.time_ns).sum();
        let batched_total = batched.telemetry().cost.time_ns;
        assert!(batched_total > 0.0);
        assert!(
            batched_total < 0.5 * sum_single,
            "no amortization: batched {batched_total} vs {sum_single}"
        );
    }

    #[test]
    fn batched_inmemory_mlp_parity_and_amortization() {
        // the in-memory MLP packs its AND/bitcount batches across frames
        // the same way the LBP lanes pack: bit-identical logits, fewer
        // modeled fleet passes than the per-frame sum
        let (_, params) = synth_params(5);
        let frames = synth_frames(&params, 4, 41).unwrap();
        let arch = ArchSim { lbp: true, mlp: true, early_exit: false };
        let mut b = backend(arch, None);
        let singles: Vec<FrameOutput> = frames
            .iter()
            .map(|f| b.infer_frame(f).unwrap())
            .collect();
        let batched = b.infer_batch(&frames).unwrap();
        for (s, f) in singles.iter().zip(&batched.frames) {
            assert_eq!(s.logits, f.logits, "frame {}", f.seq);
            assert_eq!(s.features, f.features, "frame {}", f.seq);
            assert_eq!(f.telemetry.arch_mismatches, 0);
            // the simulated work per frame is unchanged — only the
            // fleet-pass time model amortizes
            assert_eq!(s.telemetry.exec, f.telemetry.exec, "frame {}",
                       f.seq);
            assert_eq!(s.telemetry.dpu, f.telemetry.dpu, "frame {}", f.seq);
        }
        let sum_single: f64 =
            singles.iter().map(|r| r.telemetry.cost.time_ns).sum();
        let batched_total = batched.telemetry().cost.time_ns;
        assert!(batched_total > 0.0);
        assert!(
            batched_total < 0.5 * sum_single,
            "no MLP amortization: batched {batched_total} vs {sum_single}"
        );
    }

    #[test]
    fn warm_arena_reuse_is_bit_identical_to_cold() {
        // a backend that has already served batches (warm arena, sized
        // buffers, stale sub-array contents) must answer exactly like a
        // freshly built one — logits, features, stats, modeled cost
        let (_, params) = synth_params(5);
        let arch = ArchSim { lbp: true, mlp: true, early_exit: false };
        let mut warm = backend(arch, None);
        // warm it up on different batch shapes
        for n in [3usize, 1, 4] {
            let f = synth_frames(&params, n, 91).unwrap();
            warm.infer_batch(&f).unwrap();
        }
        let frames = synth_frames(&params, 2, 97).unwrap();
        let got = warm.infer_batch(&frames).unwrap();
        let mut cold = backend(arch, None);
        let want = cold.infer_batch(&frames).unwrap();
        assert_eq!(got.frames.len(), want.frames.len());
        for (g, w) in got.frames.iter().zip(&want.frames) {
            assert_eq!(g.logits, w.logits, "frame {}", g.seq);
            assert_eq!(g.features, w.features, "frame {}", g.seq);
            assert_eq!(g.telemetry.exec, w.telemetry.exec);
            assert_eq!(g.telemetry.dpu, w.telemetry.dpu);
            assert_eq!(g.telemetry.arch_mismatches, 0);
            assert!((g.telemetry.cost.time_ns - w.telemetry.cost.time_ns)
                        .abs() < 1e-9);
        }
    }
}
