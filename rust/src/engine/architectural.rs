//! The architectural backend: the near-sensor in-SRAM simulation.
//!
//! Each frame flows through two redundant paths:
//!
//! * the **functional path** (`crate::model`) — fast bit-exact integer
//!   inference used for the logits, and
//! * the **architectural path** — the same LBP comparisons executed as
//!   Algorithm 1 over simulated compute sub-arrays
//!   (`crate::lbp::parallel_compare`) and, optionally, the MLP as
//!   in-memory AND/bitcount (`crate::mlp`), producing cycle/energy
//!   statistics *and* a per-frame equivalence check (any divergence is
//!   counted in `Telemetry::arch_mismatches` — it must be 0).
//!
//! Which pieces are simulated is controlled by `EngineConfig::arch`
//! ([`super::ArchSim`]); the modeled accelerator time assumes the
//! configured shard's sub-array budget (`EngineConfig::subarray_budget`).

use crate::dpu::Dpu;
use crate::energy::EnergyModel;
use crate::error::Result;
use crate::isa::{ExecStats, Executor};
use crate::lbp::parallel_compare;
use crate::mapping::LbpSubarrayMap;
use crate::mlp::MlpSubarrayMap;
use crate::model::{self, TensorU8};
use crate::params::{LbpLayer, NetParams};
use crate::sensor::Frame;
use crate::sram::{Region, SubArray};

use super::{BackendKind, BackendOutput, Capabilities, EngineConfig,
            FrameOutput, InferenceBackend, Telemetry};

/// The in-SRAM simulation backend.  Owns its scratch compute sub-array,
/// so one backend instance serves one worker/shard thread.
pub struct ArchitecturalBackend {
    params: NetParams,
    config: EngineConfig,
    energy_model: EnergyModel,
    scratch: SubArray,
}

impl ArchitecturalBackend {
    pub fn new(params: NetParams, config: EngineConfig) -> Result<Self> {
        config.validate()?;
        let mut energy_model = EnergyModel::default();
        energy_model.params.freq_ghz = config.system.circuit.freq_ghz;
        let g = &config.system.cache;
        let scratch = SubArray::new(g.rows, g.cols);
        Ok(Self { params, config, energy_model, scratch })
    }

    /// Compute sub-arrays available to this backend instance — the whole
    /// cache, or just the configured shard's bank slice.
    pub fn subarray_budget(&self) -> usize {
        self.config.subarray_budget()
    }

    /// Run one frame (borrow-splitting wrapper around the core logic).
    pub fn infer_frame(&mut self, frame: &Frame) -> Result<FrameOutput> {
        let core = ArchCore {
            params: &self.params,
            config: &self.config,
            energy_model: &self.energy_model,
        };
        core.process(frame, &mut self.scratch)
    }
}

impl InferenceBackend for ArchitecturalBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Architectural
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            available: true,
            produces_features: true,
            modeled_telemetry: true,
            detail: "in-SRAM architectural simulation (cycles/energy modeled)"
                .into(),
        }
    }

    fn infer_batch(&mut self, frames: &[Frame]) -> Result<BackendOutput> {
        let mut out = Vec::with_capacity(frames.len());
        for frame in frames {
            out.push(self.infer_frame(frame)?);
        }
        Ok(BackendOutput { frames: out })
    }
}

/// Shared-state view used while the scratch sub-array is mutably borrowed.
struct ArchCore<'a> {
    params: &'a NetParams,
    config: &'a EngineConfig,
    energy_model: &'a EnergyModel,
}

impl ArchCore<'_> {
    fn subarray_budget(&self) -> usize {
        self.config.subarray_budget()
    }

    /// Lane order for one LBP layer: (y, x, kernel, sample≥apx).
    fn gather_pairs(&self, x: &TensorU8, layer: &LbpLayer) -> Vec<(u8, u8)> {
        let apx = self.params.config.apx_code;
        let mut pairs = Vec::with_capacity(
            x.h * x.w * layer.offsets.len() * (self.params.config.e - apx),
        );
        for y in 0..x.h {
            for xx in 0..x.w {
                for (k, pts) in layer.offsets.iter().enumerate() {
                    let pivot = x.get(y, xx, layer.pivot_ch[k] as usize);
                    for pt in pts.iter().skip(apx) {
                        let v = x.get_padded(
                            y as i64 + pt.dy as i64,
                            xx as i64 + pt.dx as i64,
                            pt.ch as usize,
                        );
                        pairs.push((v, pivot));
                    }
                }
            }
        }
        pairs
    }

    /// One LBP layer on the architectural path; returns the joint output
    /// and the number of bit mismatches against the functional path.
    fn lbp_layer_arch(&self, x: &TensorU8, layer: &LbpLayer,
                      scratch: &mut SubArray, map: &LbpSubarrayMap,
                      exec: &mut ExecStats, dpu: &mut Dpu)
                      -> Result<(TensorU8, u64, f64)> {
        let cfg = &self.params.config;
        let apx = cfg.apx_code;
        let samples = cfg.e - apx;
        let pairs = self.gather_pairs(x, layer);
        let cols = scratch.cols();

        // run Algorithm 1 per ≤cols-lane batch on the scratch sub-array
        let mut bits = Vec::with_capacity(pairs.len());
        let mut batches = 0u64;
        for chunk in pairs.chunks(cols) {
            map.load_lanes(scratch, 0, chunk)?;
            exec.row_writes += 2 * map.bits as u64; // transposed lane load
            exec.cycles += 2 * map.bits as u64;
            let mut ex = Executor::new(scratch);
            let out = parallel_compare(&mut ex, map, 0, chunk.len(),
                                       cfg.apx_pixel,
                                       self.config.arch.early_exit)?;
            exec.merge(&ex.stats);
            bits.extend(out.bits);
            batches += 1;
        }

        // assemble codes in the same lane order and cross-check
        let k_n = layer.offsets.len();
        let mut out = TensorU8::zeros(x.h, x.w, x.c + k_n);
        let mut mismatches = 0u64;
        let mut lane = 0usize;
        for y in 0..x.h {
            for xx in 0..x.w {
                for ch in 0..x.c {
                    out.set(y, xx, ch, x.get(y, xx, ch));
                }
                for k in 0..k_n {
                    let mut code = 0u32;
                    for n in 0..samples {
                        if bits[lane + n] {
                            code |= 1 << (n + apx);
                        }
                    }
                    lane += samples;
                    let want = model::lbp_code(x, layer, k, y, xx, apx);
                    if code != want {
                        mismatches += 1;
                    }
                    out.set(y, xx, x.c + k,
                            dpu.shifted_relu_u8(code, cfg.e as u32));
                }
            }
        }

        // modeled time: batches spread across this shard's sub-arrays
        let subarrays = self.subarray_budget() as f64;
        let cycles_per_batch = (2.0 * map.bits as f64)
            + 4.0 + 7.0 * (map.bits - cfg.apx_pixel) as f64 + 3.0;
        let time_ns = (batches as f64 / subarrays).ceil() * cycles_per_batch
            * self.energy_model.cycle_ns();
        Ok((out, mismatches, time_ns))
    }

    /// In-memory MLP layer (architectural); returns raw integer accums and
    /// mismatch count vs the functional matmul.
    fn mlp_layer_arch(&self, feats: &[u8], mlp: &crate::params::MlpLayer,
                      scratch: &mut SubArray, mmap: &MlpSubarrayMap,
                      exec: &mut ExecStats, dpu: &mut Dpu)
                      -> Result<(Vec<i64>, u64, f64)> {
        let cols = scratch.cols();
        let half = 1u8 << (self.params.config.w_bits - 1);
        let chunks: Vec<&[u8]> = feats.chunks(cols).collect();
        let mut accs = vec![0i64; mlp.o];
        let mut and_batches = 0u64;

        for (ci, chunk) in chunks.iter().enumerate() {
            let mut ex = Executor::new(scratch);
            mmap.load_vector(&mut ex, Region::Input, 0, chunk)?;
            let rowsum: i64 = chunk.iter().map(|&v| v as i64).sum();
            for o in 0..mlp.o {
                // weight column chunk, offset-stored unsigned
                let w_col: Vec<u8> = (0..chunk.len())
                    .map(|di| {
                        (mlp.weight(ci * cols + di, o) as i16 + half as i16)
                            as u8
                    })
                    .collect();
                mmap.load_vector(&mut ex, Region::Weight, 0, &w_col)?;
                accs[o] += mmap.dot_signed(&mut ex, dpu, 0, 0, chunk.len(),
                                           rowsum)?;
                and_batches += (mmap.act_bits * mmap.w_bits) as u64;
            }
            exec.merge(&ex.stats);
        }

        // cross-check against the functional integer matmul
        let want = model::int_matmul(feats, mlp);
        let mismatches =
            accs.iter().zip(&want).filter(|(a, w)| a != w).count() as u64;
        let subarrays = self.subarray_budget() as f64;
        let time_ns = (and_batches as f64 * 2.0 / subarrays).ceil()
            * self.energy_model.cycle_ns();
        Ok((accs, mismatches, time_ns))
    }

    /// Process one digitized frame.
    fn process(&self, frame: &Frame, scratch: &mut SubArray)
               -> Result<FrameOutput> {
        let cfg = &self.params.config;
        let mut x = super::digitize(frame, cfg)?;
        let map = LbpSubarrayMap::new(self.config.system.cache.region, 8)?;
        let mut exec = ExecStats::default();
        let mut dpu = Dpu::default();
        let mut mismatches = 0u64;
        let mut arch_time_ns = 0.0;

        // --- LBP layers -----------------------------------------------------
        for layer in &self.params.lbp_layers {
            if self.config.arch.lbp {
                let (nx, mm, t) =
                    self.lbp_layer_arch(&x, layer, scratch, &map, &mut exec,
                                        &mut dpu)?;
                mismatches += mm;
                arch_time_ns += t;
                x = nx;
            } else {
                x = model::lbp_layer_forward(&x, layer, cfg.e, cfg.apx_code,
                                             &mut dpu);
            }
        }

        // --- pooling + quantization (DPU) ------------------------------------
        let s = cfg.pool;
        let vmax = (255 * s * s) as u32;
        let (ph, pw) = (x.h / s, x.w / s);
        let mut feats = Vec::with_capacity(ph * pw * x.c);
        for py in 0..ph {
            for px in 0..pw {
                for ch in 0..x.c {
                    let mut sum = 0u32;
                    for dy in 0..s {
                        for dx in 0..s {
                            sum += x.get(py * s + dy, px * s + dx, ch) as u32;
                        }
                    }
                    feats.push(dpu.quantize_pooled(sum, vmax,
                                                   cfg.act_bits as u32)?);
                }
            }
        }

        // --- MLP --------------------------------------------------------------
        let logits = if self.config.arch.mlp {
            let mmap = MlpSubarrayMap::new(map, cfg.act_bits, cfg.w_bits)?;
            let (acc1, mm1, t1) =
                self.mlp_layer_arch(&feats, &self.params.mlp1, scratch, &mmap,
                                    &mut exec, &mut dpu)?;
            mismatches += mm1;
            arch_time_ns += t1;
            let hidden: Vec<u8> = acc1.iter().enumerate()
                .map(|(o, &h)| dpu.activation(h, self.params.mlp1.scale[o],
                                              self.params.mlp1.bias[o],
                                              cfg.act_bits as u32))
                .collect();
            let (acc2, mm2, t2) =
                self.mlp_layer_arch(&hidden, &self.params.mlp2, scratch, &mmap,
                                    &mut exec, &mut dpu)?;
            mismatches += mm2;
            arch_time_ns += t2;
            acc2.iter().enumerate()
                .map(|(o, &h)| dpu.affine(h, self.params.mlp2.scale[o],
                                          self.params.mlp2.bias[o]))
                .collect()
        } else {
            model::mlp_forward(self.params, &feats, &mut dpu)?
        };

        // --- energy ------------------------------------------------------------
        let mut energy = self.energy_model.exec_energy(&exec);
        energy.add(&self.energy_model.dpu_energy(&dpu.stats));
        let pixels = (cfg.height * cfg.width * cfg.in_channels) as u64;
        energy.add(&self.energy_model.sensor_energy(
            pixels,
            (8 - cfg.apx_pixel) as u64,
        ));

        Ok(FrameOutput {
            seq: frame.seq,
            predicted: model::argmax(&logits),
            logits,
            features: Some(feats),
            telemetry: Telemetry {
                exec,
                dpu: dpu.stats,
                energy,
                arch_time_ns,
                arch_mismatches: mismatches,
                ..Default::default()
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ArchSim, ShardSlice};
    use crate::params::synth::synth_params;
    use crate::testing::synth_frames;

    fn backend(arch: ArchSim, shard: Option<ShardSlice>)
               -> ArchitecturalBackend {
        let (_, params) = synth_params(5);
        let config = EngineConfig { arch, shard, ..Default::default() };
        ArchitecturalBackend::new(params, config).unwrap()
    }

    #[test]
    fn arch_lbp_matches_functional_bits() {
        let (_, params) = synth_params(5);
        let frames = synth_frames(&params, 2, 31).unwrap();
        let mut b = backend(
            ArchSim { lbp: true, mlp: true, early_exit: false }, None);
        let out = b.infer_batch(&frames).unwrap();
        let t = out.telemetry();
        assert_eq!(t.arch_mismatches, 0, "arch != functional");
        assert!(t.exec.compute_ops > 0);
        assert!(t.energy.total_pj() > 0.0);
        assert!(t.arch_time_ns > 0.0);
    }

    #[test]
    fn shard_slice_stretches_modeled_time_only() {
        let (_, params) = synth_params(5);
        let frames = synth_frames(&params, 1, 31).unwrap();
        let arch = ArchSim { lbp: true, mlp: false, early_exit: false };
        let mut full = backend(arch, None);
        let mut quarter = backend(arch, Some(ShardSlice { index: 0, count: 4 }));
        assert_eq!(full.subarray_budget(), 320);
        assert_eq!(quarter.subarray_budget(), 80);
        let rf = full.infer_frame(&frames[0]).unwrap();
        let rq = quarter.infer_frame(&frames[0]).unwrap();
        assert_eq!(rf.logits, rq.logits);
        assert_eq!(rf.telemetry.arch_mismatches, 0);
        assert_eq!(rq.telemetry.arch_mismatches, 0);
        assert!(rq.telemetry.arch_time_ns >= rf.telemetry.arch_time_ns);
    }

    #[test]
    fn rejects_wrong_frame_shape() {
        let mut b = backend(ArchSim::default(), None);
        let bad = Frame { rows: 5, cols: 5, channels: 1, pixels: vec![0; 25],
                          seq: 0 };
        assert!(b.infer_frame(&bad).is_err());
    }
}
