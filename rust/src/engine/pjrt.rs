//! The PJRT backend: the AOT-lowered JAX/Pallas HLO graph executed
//! through `crate::runtime::Runtime`.
//!
//! Only compiled into a real executor when the `pjrt` cargo feature is
//! enabled; otherwise the backend's `capabilities()` probe reports it
//! unavailable, and `Engine::builder()` refuses to select it — an early,
//! explicit error instead of a failure on the first frame.

use crate::error::{Error, Result};
use crate::model;
use crate::params::NetParams;
use crate::runtime::{pjrt_available, Runtime};
use crate::sensor::Frame;

use super::{BackendKind, BackendOutput, Capabilities, EngineConfig,
            FrameOutput, InferenceBackend, Telemetry};

/// The artifacts' static batch size (set at AOT-lowering time).
pub const ARTIFACT_BATCH: usize = 4;

/// Wraps the PJRT runtime over one `aplbp_*` HLO artifact.  Frames are
/// fed as f32 images in [0,1]; since the artifact re-applies the sensor
/// quantization, feeding back `pixels/255` reproduces the digitized
/// values bit-exactly.  No hardware statistics are modeled.
///
/// `infer_batch` slices the input into [`ARTIFACT_BATCH`]-sized chunks
/// and pads only the final one — so now that the serve shards dispatch
/// whole batches (instead of looping `infer_frame`), a PJRT shard fills
/// the artifact's static batch with real frames rather than padding
/// every single frame to it.
pub struct PjrtBackend {
    params: NetParams,
    runtime: Runtime,
    artifact: String,
    loaded: bool,
}

impl PjrtBackend {
    pub fn new(params: NetParams, config: &EngineConfig,
               artifact: String) -> Result<Self> {
        config.validate()?;
        let runtime = Runtime::new(config.system.artifacts_dir.clone())?;
        // Surface a missing artifact at construction time (the engine's
        // early-error contract) — but only when the backend is otherwise
        // available; feature absence is reported through capabilities().
        if pjrt_available() {
            let path = std::path::Path::new(&config.system.artifacts_dir)
                .join(format!("{artifact}.hlo.txt"));
            if !path.exists() {
                return Err(Error::Engine(format!(
                    "artifact {} not found — run `make artifacts`",
                    path.display()
                )));
            }
        }
        Ok(Self { params, runtime, artifact, loaded: false })
    }

    fn ensure_loaded(&mut self) -> Result<()> {
        if !self.loaded {
            self.runtime.load(&self.artifact)?;
            self.loaded = true;
        }
        Ok(())
    }
}

impl InferenceBackend for PjrtBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn capabilities(&self) -> Capabilities {
        if pjrt_available() {
            Capabilities {
                available: true,
                produces_features: false,
                modeled_telemetry: false,
                detail: format!(
                    "PJRT ({}) on artifact {:?}",
                    self.runtime.platform(),
                    self.artifact
                ),
            }
        } else {
            Capabilities {
                available: false,
                produces_features: false,
                modeled_telemetry: false,
                detail: "PJRT backend not compiled into this build \
                         (rebuild with `--features pjrt` and a vendored \
                         xla crate)"
                    .into(),
            }
        }
    }

    fn infer_batch(&mut self, frames: &[Frame]) -> Result<BackendOutput> {
        let caps = self.capabilities();
        if !caps.available {
            return Err(Error::Engine(caps.detail));
        }
        self.ensure_loaded()?;
        let cfg = self.params.config;
        let npix = cfg.height * cfg.width * cfg.in_channels;
        for frame in frames {
            super::validate_frame(frame, &cfg)?;
        }
        let mut out = Vec::with_capacity(frames.len());
        for chunk in frames.chunks(ARTIFACT_BATCH) {
            // pad the final chunk to the artifact's static batch
            let mut flat = Vec::with_capacity(ARTIFACT_BATCH * npix);
            for frame in chunk {
                flat.extend(frame.pixels.iter().map(|&p| p as f32 / 255.0));
            }
            flat.resize(ARTIFACT_BATCH * npix, 0.0);
            let logits = self.runtime.run_aplbp(&self.artifact, &self.params,
                                                &flat, ARTIFACT_BATCH)?;
            for (frame, l) in chunk.iter().zip(logits) {
                out.push(FrameOutput {
                    seq: frame.seq,
                    predicted: model::argmax(&l),
                    logits: l,
                    features: None,
                    telemetry: Telemetry::default(),
                });
            }
        }
        Ok(BackendOutput { frames: out })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::synth::synth_params;

    #[test]
    fn reports_unavailability_without_the_feature() {
        if pjrt_available() {
            return;
        }
        let (_, params) = synth_params(1);
        let mut b = PjrtBackend::new(params, &EngineConfig::default(),
                                     "aplbp_mnist".into())
            .unwrap();
        let caps = b.capabilities();
        assert!(!caps.available);
        assert!(caps.detail.contains("pjrt"), "{}", caps.detail);
        let frame = Frame { rows: 1, cols: 1, channels: 1, pixels: vec![0],
                            seq: 0 };
        assert!(b.infer_batch(&[frame]).is_err());
    }
}
