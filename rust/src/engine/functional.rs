//! The functional backend: plain-Rust bit-exact integer inference
//! (`crate::model`), the fast path with no modeled hardware statistics.

use crate::dpu::Dpu;
use crate::error::Result;
use crate::hw::{CostModel, HwProfile};
use crate::model::{self, LbpLayerPlan, TensorU8};
use crate::obs::{EventKind, TraceEvent, Tracer};
use crate::params::NetParams;
use crate::sensor::Frame;

use super::{BackendKind, BackendOutput, Capabilities, EngineConfig,
            FrameOutput, InferenceBackend, Telemetry};

/// Reusable per-backend working set: the digitized/LBP ping-pong
/// tensors and the per-frame DPUs.  Warm buffers never reallocate
/// (§Perf, EXPERIMENTS.md) — a serve shard keeps one backend per routed
/// class, so the scratch persists across the whole traffic stream.
#[derive(Default)]
struct FuncScratch {
    /// Current layer input (holds the digitized frame, then each
    /// layer's output after the swap).
    cur: TensorU8,
    /// Next layer output (pong half).
    nxt: TensorU8,
    /// One DPU per frame of the current batch.
    dpus: Vec<Dpu>,
}

/// Wraps the functional model: LBP layers, pooling/quantization, and the
/// integer MLP, exactly as `python/compile/model.py` specifies them.
/// DPU activity and sensor readout are priced through the configured
/// [`HwProfile`]; there is no cycle model (`Telemetry::cost.time_ns`
/// stays 0).
///
/// The batch path is vectorized: LBP feature extraction runs per frame,
/// then both MLP layers run weight-stationary over the whole batch
/// ([`model::mlp_forward_batch`]) — the weight matrices stream through
/// the cache once per batch instead of once per frame, with bit-identical
/// logits and per-frame DPU counters.  The per-layer gather tables
/// ([`LbpLayerPlan`]) are precomputed at build and the LBP stage runs
/// through reusable ping-pong tensors, so the steady-state hot path
/// allocates only the outputs (features/logits) that escape the call.
pub struct FunctionalBackend {
    params: NetParams,
    cost_model: HwProfile,
    plans: Vec<LbpLayerPlan>,
    scratch: FuncScratch,
    /// Stage-phase span source (disabled by default — zero cost).
    tracer: Tracer,
    /// Shard index for span attribution (-1 when unsharded).
    shard: i32,
}

impl FunctionalBackend {
    pub fn new(params: NetParams, config: &EngineConfig) -> Result<Self> {
        Self::with_prepacked(params, config, None)
    }

    /// Build, reusing compiled gather plans from an artifact when given
    /// (validated against the params — a mismatch is an error).
    pub fn with_prepacked(params: NetParams, config: &EngineConfig,
                          prepacked: Option<&crate::engine::Prepacked>)
        -> Result<Self>
    {
        config.validate()?;
        let plans = match prepacked {
            Some(p) => p.plans_for(&params)?,
            None => model::plan_layers(&params),
        };
        Ok(Self {
            params,
            cost_model: config.system.hw_profile(),
            plans,
            scratch: FuncScratch::default(),
            tracer: Tracer::disabled(),
            shard: config.shard.map_or(-1, |s| s.index as i32),
        })
    }
}

/// Close a stage phase span opened with
/// `tracer.enabled().then(Instant::now)`.  Free function over the
/// tracer/shard fields only, so it composes with the mutably borrowed
/// scratch arena inside `infer_batch`.
fn phase_span(tracer: &Tracer, shard: i32, label: &'static str,
              start: Option<std::time::Instant>) {
    if let Some(t0) = start {
        tracer.emit(TraceEvent {
            kind: EventKind::Phase,
            ts_ns: tracer.ts(t0),
            dur_ns: t0.elapsed().as_nanos() as u64,
            shard,
            backend: Some(BackendKind::Functional),
            label,
            ..TraceEvent::default()
        });
    }
}

impl InferenceBackend for FunctionalBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Functional
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            available: true,
            produces_features: true,
            modeled_telemetry: false,
            detail: "bit-exact integer functional model (no cycle model)"
                .into(),
        }
    }

    fn infer_batch(&mut self, frames: &[Frame]) -> Result<BackendOutput> {
        let cfg = self.params.config;

        // stage 1 (per frame): digitize + LBP layers + pooled features,
        // through the reusable ping-pong tensors and prebuilt plans
        let lbp_start = self.tracer.enabled()
            .then(std::time::Instant::now);
        let FuncScratch { cur, nxt, dpus } = &mut self.scratch;
        dpus.clear();
        dpus.resize_with(frames.len(), Dpu::default);
        let mut feats_batch: Vec<Vec<u8>> = Vec::with_capacity(frames.len());
        for (frame, dpu) in frames.iter().zip(dpus.iter_mut()) {
            super::digitize_into(frame, &cfg, cur)?;
            for (layer, plan) in
                self.params.lbp_layers.iter().zip(&self.plans)
            {
                model::lbp_layer_forward_into(cur, layer, plan, cfg.e,
                                              cfg.apx_code, dpu, nxt);
                std::mem::swap(cur, nxt);
            }
            feats_batch.push(model::pool_quantize(cur, cfg.pool,
                                                  cfg.act_bits, dpu)?);
        }

        phase_span(&self.tracer, self.shard, "lbp", lbp_start);

        // stage 2 (whole batch): weight-stationary MLP over all frames
        let mlp_start = self.tracer.enabled()
            .then(std::time::Instant::now);
        let logits_batch =
            model::mlp_forward_batch(&self.params, &feats_batch, dpus)?;
        phase_span(&self.tracer, self.shard, "mlp", mlp_start);

        // stage 3 (per frame): assemble outputs and the energy account
        let pixels = (cfg.height * cfg.width * cfg.in_channels) as u64;
        let out = frames
            .iter()
            .zip(feats_batch)
            .zip(logits_batch)
            .zip(dpus.iter())
            .map(|(((frame, feats), logits), dpu)| {
                let mut cost = self.cost_model.dpu_cost(&dpu.stats);
                cost.add(&self.cost_model.sensor_cost(
                    pixels,
                    (8 - cfg.apx_pixel) as u64,
                ));
                FrameOutput {
                    seq: frame.seq,
                    predicted: model::argmax(&logits),
                    logits,
                    features: Some(feats),
                    telemetry: Telemetry {
                        profile: self.cost_model.name.clone(),
                        dpu: dpu.stats,
                        cost,
                        ..Default::default()
                    },
                }
            })
            .collect();
        Ok(BackendOutput { frames: out })
    }

    fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpu::Dpu;
    use crate::model::TensorU8;
    use crate::params::synth::synth_params;
    use crate::testing::synth_frames;

    #[test]
    fn matches_direct_model_apply_on_digitized_frames() {
        let (_, params) = synth_params(3);
        let frames = synth_frames(&params, 2, 9).unwrap();
        let mut backend =
            FunctionalBackend::new(params.clone(), &EngineConfig::default())
                .unwrap();
        let out = backend.infer_batch(&frames).unwrap();
        for (frame, got) in frames.iter().zip(&out.frames) {
            // direct functional reference on the same digitized pixels
            let cfg = params.config;
            let image = TensorU8 { h: cfg.height, w: cfg.width,
                                   c: cfg.in_channels,
                                   data: frame.pixels.clone() };
            let mut dpu = Dpu::default();
            let feats =
                model::forward_lbp(&params, &image, &mut dpu).unwrap();
            let logits =
                model::mlp_forward(&params, &feats, &mut dpu).unwrap();
            assert_eq!(got.logits, logits);
            assert_eq!(got.features.as_deref(), Some(feats.as_slice()));
            assert_eq!(got.predicted, model::argmax(&logits));
            assert!(got.telemetry.cost.energy.total_pj() > 0.0);
            assert_eq!(got.telemetry.cost.time_ns, 0.0);
            assert_eq!(got.telemetry.profile, "ns_lbp_65nm");
        }
    }

    #[test]
    fn rejects_wrong_frame_shape() {
        let (_, params) = synth_params(3);
        let mut backend =
            FunctionalBackend::new(params, &EngineConfig::default()).unwrap();
        let bad = Frame { rows: 2, cols: 2, channels: 1, pixels: vec![0; 4],
                          seq: 0 };
        assert!(backend.infer_batch(&[bad]).is_err());
    }
}
