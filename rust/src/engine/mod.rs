//! `engine` — the unified inference API over the three Ap-LBP execution
//! paths.
//!
//! The paper's contribution is *one* network executed three ways: a
//! bit-exact functional golden model, an in-SRAM architectural simulation
//! with cycle/energy accounting, and the AOT-lowered JAX/Pallas graph on
//! PJRT.  This module makes that triplet a first-class abstraction:
//!
//! * [`InferenceBackend`] — the trait every execution path implements:
//!   `infer_batch(&[Frame]) -> BackendOutput` carrying logits, optional
//!   pooled features, and a unified [`Telemetry`] of cycle/energy/DPU
//!   statistics.  Backends advertise what they can do through
//!   [`Capabilities`] (probed at build time, so an unavailable backend is
//!   an early, explicit error instead of a late one).
//! * [`FunctionalBackend`] — wraps the plain-Rust integer model
//!   (`crate::model`); fast, no modeled hardware statistics.
//! * [`ArchitecturalBackend`] — wraps the Algorithm-1 / in-memory-MLP
//!   simulation over compute sub-arrays, producing cycle/energy telemetry
//!   and an internal bit-level cross-check against the functional math
//!   (`Telemetry::arch_mismatches`).
//! * [`PjrtBackend`] — wraps `crate::runtime::Runtime` (the `pjrt` cargo
//!   feature); without the feature it reports itself unavailable through
//!   `capabilities()`.
//! * [`Engine`] — owns backend selection, optional pluggable
//!   cross-checking against a reference backend (logit divergences are
//!   counted in `Telemetry::cross_check_mismatches`), and telemetry
//!   accumulation across batches.  Built through [`EngineBuilder`]:
//!
//! ```no_run
//! use ns_lbp::engine::{BackendKind, Engine};
//! use ns_lbp::params::synth::synth_params;
//!
//! let (_, params) = synth_params(1);
//! let mut engine = Engine::builder()
//!     .params(params)
//!     .backend(BackendKind::Architectural)
//!     .cross_check(BackendKind::Functional)
//!     .build()
//!     .unwrap();
//! # let frames: Vec<ns_lbp::sensor::Frame> = Vec::new();
//! let out = engine.infer_batch(&frames).unwrap();
//! assert_eq!(engine.telemetry().cross_check_mismatches, 0);
//! ```
//!
//! The coordinator, the serving layer, the CLI, and the benches all
//! construct backends exclusively through this module.  Per-request-class
//! backend selection is a first-class policy here too: [`QosClass`] names
//! the service classes (best-effort / standard / billed) and
//! [`RoutingPolicy`] maps each class to a [`BackendKind`]
//! (`[engine.routing]` config section, `--route class=backend` CLI) —
//! the serving layer batches per class and dispatches every batch to the
//! routed backend in one `infer_batch` call.  New workloads (A/B energy
//! comparisons, future execution paths) are an `InferenceBackend` impl,
//! not another fork of the pipeline.

pub mod architectural;
pub mod functional;
pub mod pjrt;

use crate::config::SystemConfig;
use crate::dpu::DpuStats;
use crate::error::{Error, Result};
use crate::hw::{Cost, HwProfile};
use crate::isa::ExecStats;
use crate::model::TensorU8;
use crate::params::{NetConfig, NetParams};
use crate::sensor::Frame;

pub use architectural::ArchitecturalBackend;
pub use functional::FunctionalBackend;
pub use pjrt::PjrtBackend;

// ---------------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------------

/// The execution paths a frame can take through the system.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// Plain-Rust bit-exact integer model (`crate::model`).
    Functional,
    /// In-SRAM simulation: Algorithm-1 LBP comparisons and (optionally)
    /// the bit-serial in-memory MLP, with cycle/energy accounting.
    #[default]
    Architectural,
    /// AOT JAX/Pallas HLO executed through PJRT (`pjrt` cargo feature).
    Pjrt,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Functional => "functional",
            BackendKind::Architectural => "architectural",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Parse an optional backend: `"none"` / `"off"` mean "no backend"
    /// (used for the cross-check selector).
    pub fn parse_optional(s: &str) -> Result<Option<BackendKind>> {
        match s.trim().to_ascii_lowercase().as_str() {
            "none" | "off" | "" => Ok(None),
            other => other.parse().map(Some),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for BackendKind {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "functional" | "func" => Ok(BackendKind::Functional),
            "architectural" | "arch" => Ok(BackendKind::Architectural),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            other => Err(Error::Config(format!(
                "unknown backend {other:?} (functional|architectural|pjrt)"
            ))),
        }
    }
}

// ---------------------------------------------------------------------------
// QoS classes and routing
// ---------------------------------------------------------------------------

/// Quality-of-service class of a serve request.  The near-sensor premise
/// (PISA; Lee et al. 2017) is that not every pixel deserves the same
/// treatment: always-on sensor streams want the cheapest approximate
/// path and fresh frames, while billed output wants the exact, fully
/// accounted path.  Classes are the routing key ([`RoutingPolicy`]) and
/// the batching key (`[serve]` per-class knobs) — a batch never mixes
/// classes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum QosClass {
    /// Always-on sensor traffic: cheapest path, prefers fresh frames
    /// (drop-oldest admission by default).
    BestEffort,
    /// The default class: reject-past-depth admission, default backend.
    #[default]
    Standard,
    /// Billed/exact traffic: typically routed to the fully accounted
    /// architectural path.
    Billed,
}

impl QosClass {
    /// Every class, in `index()` order.
    pub const ALL: [QosClass; 3] =
        [QosClass::BestEffort, QosClass::Standard, QosClass::Billed];

    /// Number of classes (array-table dimension).
    pub const COUNT: usize = 3;

    /// Dense index into per-class tables.
    pub fn index(self) -> usize {
        match self {
            QosClass::BestEffort => 0,
            QosClass::Standard => 1,
            QosClass::Billed => 2,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            QosClass::BestEffort => "best_effort",
            QosClass::Standard => "standard",
            QosClass::Billed => "billed",
        }
    }
}

impl std::fmt::Display for QosClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for QosClass {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "best_effort" | "best-effort" | "be" => Ok(QosClass::BestEffort),
            "standard" | "std" => Ok(QosClass::Standard),
            "billed" | "bill" => Ok(QosClass::Billed),
            other => Err(Error::Config(format!(
                "unknown QoS class {other:?} (best_effort|standard|billed)"
            ))),
        }
    }
}

/// Backend selection keyed by `(QosClass, model_id)`: which
/// [`BackendKind`] serves each class, optionally refined per served
/// model.  Resolution order is model route → class route → the engine's
/// default backend.  Class routes come from the `[engine.routing]`
/// config section (`best_effort = "functional"` …) or repeated
/// `--route class=backend` CLI options; model routes from
/// `--route class@model=backend`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RoutingPolicy {
    routes: [Option<BackendKind>; QosClass::COUNT],
    model_routes: std::collections::BTreeMap<(usize, u32), BackendKind>,
}

impl RoutingPolicy {
    /// Route `class` to `kind`.
    pub fn set(&mut self, class: QosClass, kind: BackendKind) {
        self.routes[class.index()] = Some(kind);
    }

    /// Route `(class, model_id)` to `kind`, shadowing the class route.
    pub fn set_model(&mut self, class: QosClass, model_id: u32,
                     kind: BackendKind) {
        self.model_routes.insert((class.index(), model_id), kind);
    }

    /// The explicit route for `class`, if one is configured.
    pub fn route(&self, class: QosClass) -> Option<BackendKind> {
        self.routes[class.index()]
    }

    /// The explicit route for `(class, model_id)`, if one is configured.
    pub fn model_route(&self, class: QosClass, model_id: u32)
        -> Option<BackendKind>
    {
        self.model_routes.get(&(class.index(), model_id)).copied()
    }

    /// The backend `class` resolves to under `default`.
    pub fn resolve(&self, class: QosClass, default: BackendKind) -> BackendKind {
        self.routes[class.index()].unwrap_or(default)
    }

    /// The backend `(class, model_id)` resolves to under `default`:
    /// model route first, then the class route, then `default`.
    pub fn resolve_model(&self, class: QosClass, model_id: u32,
                         default: BackendKind) -> BackendKind {
        self.model_route(class, model_id)
            .unwrap_or_else(|| self.resolve(class, default))
    }

    /// True when neither a class nor a model has an explicit route.
    pub fn is_empty(&self) -> bool {
        self.routes.iter().all(|r| r.is_none()) && self.model_routes.is_empty()
    }

    /// Distinct backends the classes (and any model routes) actually
    /// resolve to — the set of engines every serve shard must be able to
    /// instantiate.  A default backend no class resolves to is *not*
    /// included: if all three classes are routed elsewhere, no shard
    /// needs to build (or be able to build) the default.
    pub fn backend_set(&self, default: BackendKind) -> Vec<BackendKind> {
        let mut kinds = Vec::new();
        for class in QosClass::ALL {
            let k = self.resolve(class, default);
            if !kinds.contains(&k) {
                kinds.push(k);
            }
        }
        for &k in self.model_routes.values() {
            if !kinds.contains(&k) {
                kinds.push(k);
            }
        }
        kinds
    }

    /// Apply a CLI `--route class=backend` or `class@model=backend` spec.
    pub fn apply_spec(&mut self, spec: &str) -> Result<()> {
        let (target, backend) = spec.split_once('=').ok_or_else(|| {
            Error::Config(format!(
                "--route expects class=backend or class@model=backend, \
                 got {spec:?}"
            ))
        })?;
        match target.split_once('@') {
            Some((class, model)) => {
                let model_id: u32 = model.parse().map_err(|_| {
                    Error::Config(format!(
                        "--route model id {model:?} is not a u32"
                    ))
                })?;
                self.set_model(class.parse()?, model_id, backend.parse()?);
            }
            None => self.set(target.parse()?, backend.parse()?),
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Configuration (shared with the coordinator)
// ---------------------------------------------------------------------------

/// What the architectural path simulates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArchSim {
    /// Run every LBP comparison through the ISA-level Algorithm 1.
    pub lbp: bool,
    /// Run the MLP through the in-memory AND/bitcount path.
    pub mlp: bool,
    /// Let the Ctrl early-exit Algorithm 1 once all lanes are decided.
    pub early_exit: bool,
}

impl Default for ArchSim {
    fn default() -> Self {
        Self { lbp: true, mlp: false, early_exit: false }
    }
}

/// A shard's slice of the cache: shard `index` of `count` owns a disjoint
/// group of banks (the paper's parallelism unit), so concurrent shards
/// model concurrent traffic over *disjoint* compute sub-arrays instead of
/// all of them claiming the whole 2.5 MB slice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSlice {
    pub index: usize,
    pub count: usize,
}

impl ShardSlice {
    /// Banks owned by this shard out of `banks` total (remainder banks go
    /// to the lowest-indexed shards).
    pub fn banks(&self, banks: usize) -> usize {
        banks / self.count + usize::from(self.index < banks % self.count)
    }
}

/// Engine configuration: the system setup, the architectural-simulation
/// switches, and an optional shard slice.  (The coordinator re-exports
/// this as `CoordinatorConfig`.)  Backend selection itself lives in
/// `SystemConfig::engine` so it is settable from the config file and
/// `--set engine.backend=...`; [`EngineBuilder::backend`] overrides it.
#[derive(Clone, Debug, Default)]
pub struct EngineConfig {
    pub system: SystemConfig,
    pub arch: ArchSim,
    /// When set, the modeled accelerator time assumes only this shard's
    /// bank slice is available (functional results are unaffected).
    pub shard: Option<ShardSlice>,
}

impl EngineConfig {
    pub fn validate(&self) -> Result<()> {
        self.system.cache.validate()?;
        if let Some(s) = self.shard {
            if s.count == 0 || s.index >= s.count {
                return Err(Error::Engine(format!(
                    "shard slice {}/{} invalid",
                    s.index, s.count
                )));
            }
            if s.count > self.system.cache.banks {
                return Err(Error::Engine(format!(
                    "{} shards cannot split {} banks",
                    s.count, self.system.cache.banks
                )));
            }
        }
        Ok(())
    }

    /// Compute sub-arrays available under this configuration — the whole
    /// cache, or just the configured shard's bank slice.
    pub fn subarray_budget(&self) -> usize {
        let g = &self.system.cache;
        match self.shard {
            None => g.total_subarrays(),
            Some(s) => s.banks(g.banks) * g.mats_per_bank * g.subarrays_per_mat,
        }
    }
}

// ---------------------------------------------------------------------------
// Outputs
// ---------------------------------------------------------------------------

/// Unified per-frame (or per-run, once merged) execution statistics.
/// Backends without a hardware model leave the modeled fields at zero
/// (see `Capabilities::modeled_telemetry`).
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    /// Name of the [`crate::hw::HwProfile`] that priced `cost` (empty
    /// when nothing is modeled, [`Telemetry::MIXED_PROFILES`] after
    /// merging telemetry priced under different profiles).
    pub profile: String,
    /// ISA-level execution statistics (cycles, row accesses, opcodes).
    pub exec: ExecStats,
    /// Digital-processing-unit activity counters.
    pub dpu: DpuStats,
    /// What this frame cost under `profile`: itemized energy (compute,
    /// DPU, sensor, ...) plus modeled accelerator time.
    pub cost: Cost,
    /// Cost of the cross-check reference backend's redundant run, kept
    /// strictly apart from `cost` so enabling cross-checking never
    /// inflates the primary profile's numbers.
    pub cross_check_cost: Cost,
    /// In-backend bit-level divergences of the architectural path against
    /// the functional math (must be 0).
    pub arch_mismatches: u64,
    /// Frames compared against the engine's cross-check reference backend.
    pub cross_check_frames: u64,
    /// Frames whose logits diverged from the reference backend (must be 0).
    pub cross_check_mismatches: u64,
}

impl Telemetry {
    /// `profile` value after merging telemetry from different profiles.
    /// Reserved: [`crate::hw::HwProfile::validate`] rejects a profile
    /// actually named this, so the sentinel is unambiguous in reports.
    pub const MIXED_PROFILES: &'static str = "mixed";

    /// Fold another profile label into `current`: empty adopts, a
    /// disagreement becomes [`Telemetry::MIXED_PROFILES`].  The single
    /// rule every aggregation path (telemetry merge, serve metrics, run
    /// summaries) shares.
    pub fn merge_profile_label(current: &mut String, other: &str) {
        if current.is_empty() {
            current.push_str(other);
        } else if !other.is_empty() && current.as_str() != other {
            *current = Self::MIXED_PROFILES.into();
        }
    }

    pub fn merge(&mut self, o: &Telemetry) {
        Self::merge_profile_label(&mut self.profile, &o.profile);
        self.exec.merge(&o.exec);
        self.dpu.merge(&o.dpu);
        self.cost.add(&o.cost);
        self.cross_check_cost.add(&o.cross_check_cost);
        self.arch_mismatches += o.arch_mismatches;
        self.cross_check_frames += o.cross_check_frames;
        self.cross_check_mismatches += o.cross_check_mismatches;
    }
}

/// One frame's inference result.  (The coordinator re-exports this as
/// `FrameReport`.)
#[derive(Clone, Debug)]
pub struct FrameOutput {
    pub seq: u64,
    /// Argmax class of `logits`.
    pub predicted: usize,
    pub logits: Vec<f32>,
    /// Pooled `act_bits` features, when the backend produces them.
    pub features: Option<Vec<u8>>,
    pub telemetry: Telemetry,
}

/// A batch of inference results, in the order of the submitted frames.
#[derive(Clone, Debug, Default)]
pub struct BackendOutput {
    pub frames: Vec<FrameOutput>,
}

impl BackendOutput {
    /// Merge of every frame's telemetry.
    pub fn telemetry(&self) -> Telemetry {
        let mut t = Telemetry::default();
        for f in &self.frames {
            t.merge(&f.telemetry);
        }
        t
    }
}

/// What a backend can do, probed before any frame is submitted.
#[derive(Clone, Debug)]
pub struct Capabilities {
    /// Whether the backend can execute at all in this build/environment.
    pub available: bool,
    /// Whether `FrameOutput::features` is populated.
    pub produces_features: bool,
    /// Whether cycle/energy telemetry is modeled (vs left at zero).
    pub modeled_telemetry: bool,
    /// Human-readable description (or the reason it is unavailable).
    pub detail: String,
}

// ---------------------------------------------------------------------------
// The trait
// ---------------------------------------------------------------------------

/// One execution path for the Ap-LBP network.
///
/// Implementations consume digitized sensor frames (`u8` pixels with the
/// ADC's LSB skip already applied) and return per-frame logits plus
/// telemetry.  A failed batch returns `Err`; per-frame granularity is
/// available through `Engine::infer_frame`.
pub trait InferenceBackend {
    /// Which execution path this is.
    fn kind(&self) -> BackendKind;

    /// Probe availability and feature support without running anything.
    fn capabilities(&self) -> Capabilities;

    /// Run inference over a batch of frames.
    fn infer_batch(&mut self, frames: &[Frame]) -> Result<BackendOutput>;

    /// Install a trace handle: backends that implement this emit
    /// per-phase spans (`lbp` / `mlp`) from inside `infer_batch`.  The
    /// default keeps phase-blind backends valid — they simply
    /// contribute no phase spans to the feed.
    fn set_tracer(&mut self, _tracer: crate::obs::Tracer) {}
}

/// Shape-check a digitized frame against the network geometry (shared by
/// every backend and the serve admission path so the error reads the
/// same everywhere).  The pixel-count check matters: a frame whose
/// declared dims are right but whose `pixels` vec is short would
/// otherwise index out of bounds deep inside the LBP layers.
pub(crate) fn validate_frame(frame: &Frame, cfg: &NetConfig) -> Result<()> {
    let pixels = cfg.height * cfg.width * cfg.in_channels;
    if frame.rows != cfg.height || frame.cols != cfg.width
        || frame.channels != cfg.in_channels
        || frame.pixels.len() != pixels
    {
        return Err(Error::Engine(format!(
            "frame shape mismatch: expected {}x{}x{} ({} px), \
             got {}x{}x{} ({} px)",
            cfg.height, cfg.width, cfg.in_channels, pixels,
            frame.rows, frame.cols, frame.channels, frame.pixels.len()
        )));
    }
    Ok(())
}

/// Validate a frame and lift it into an HWC tensor, writing into a
/// reusable tensor (the backends' scratch arenas) re-shaped in place —
/// a warm buffer never reallocates.  The ADC already applied the
/// pixel-LSB skip; the mask is re-applied defensively.
pub(crate) fn digitize_into(frame: &Frame, cfg: &NetConfig,
                            out: &mut TensorU8) -> Result<()> {
    validate_frame(frame, cfg)?;
    let mask = 0xFFu8 ^ ((1u8 << cfg.apx_pixel).wrapping_sub(1));
    out.h = cfg.height;
    out.w = cfg.width;
    out.c = cfg.in_channels;
    out.data.clear();
    out.data.extend(frame.pixels.iter().map(|&p| p & mask));
    Ok(())
}

/// Compiled, ready-to-use engine tables: the per-layer LBP gather plans
/// and (optionally) both MLP weight bit-plane sets, as carried by a
/// `compile::CompiledModel` artifact.  Backends handed one of these skip
/// `model::plan_layers` / `WeightPlanes::pack` at construction — the
/// whole point of compiling ahead of time — after validating that the
/// tables actually belong to the params and cache geometry in use.
#[derive(Clone, Debug)]
pub struct Prepacked {
    /// One gather plan per LBP layer (`model::plan_layers` output).
    pub plans: Vec<crate::model::LbpLayerPlan>,
    /// `(mlp1, mlp2)` weight bit-planes, packed at the compiling cache
    /// geometry.  `None` when the artifact was compiled without them.
    pub planes: Option<(crate::mlp::WeightPlanes, crate::mlp::WeightPlanes)>,
}

impl Prepacked {
    /// The gather plans, validated against `params` (layer count and
    /// per-layer channel growth must match).
    pub fn plans_for(&self, params: &NetParams)
        -> Result<Vec<crate::model::LbpLayerPlan>>
    {
        let chs = params.config.channels_after();
        if self.plans.len() != params.lbp_layers.len() {
            return Err(Error::Engine(format!(
                "prepacked plans cover {} LBP layers, params have {}",
                self.plans.len(), params.lbp_layers.len()
            )));
        }
        for (i, (plan, &c)) in self.plans.iter().zip(&chs).enumerate() {
            if plan.width != params.config.width || plan.channels != c {
                return Err(Error::Engine(format!(
                    "prepacked plan {i} linearized for {}x{} channels, \
                     params need {}x{}",
                    plan.width, plan.channels, params.config.width, c
                )));
            }
        }
        Ok(self.plans.clone())
    }

    /// The weight bit-planes, validated against `params` and the engine's
    /// cache geometry (`cols` lanes, `w_bits` planes).  Errors rather
    /// than silently repacking: an artifact compiled for a different
    /// geometry must be recompiled, not patched up at load.
    pub fn planes_for(&self, params: &NetParams, cols: usize)
        -> Result<(crate::mlp::WeightPlanes, crate::mlp::WeightPlanes)>
    {
        let (p1, p2) = self.planes.as_ref().ok_or_else(|| {
            Error::Engine(
                "artifact carries no weight planes; recompile with the \
                 architectural MLP path enabled".into(),
            )
        })?;
        let cfg = &params.config;
        for (name, p, d, o) in [
            ("mlp1", p1, params.mlp1.d, params.mlp1.o),
            ("mlp2", p2, params.mlp2.d, params.mlp2.o),
        ] {
            if p.cols != cols || p.w_bits != cfg.w_bits {
                return Err(Error::Engine(format!(
                    "prepacked {name} planes built for cols={} w_bits={}, \
                     engine needs cols={cols} w_bits={}; recompile the \
                     artifact for this cache geometry",
                    p.cols, p.w_bits, cfg.w_bits
                )));
            }
            if p.d != d || p.o != o {
                return Err(Error::Engine(format!(
                    "prepacked {name} planes shaped {}x{}, params need \
                     {d}x{o}",
                    p.d, p.o
                )));
            }
        }
        Ok((p1.clone(), p2.clone()))
    }
}

fn make_backend(kind: BackendKind, params: &NetParams, config: &EngineConfig,
                artifact: &str, prepacked: Option<&Prepacked>)
    -> Result<Box<dyn InferenceBackend + Send>>
{
    let backend: Box<dyn InferenceBackend + Send> = match kind {
        BackendKind::Functional => {
            Box::new(FunctionalBackend::with_prepacked(
                params.clone(), config, prepacked)?)
        }
        BackendKind::Architectural => {
            Box::new(ArchitecturalBackend::with_prepacked(
                params.clone(), config.clone(), prepacked)?)
        }
        BackendKind::Pjrt => {
            Box::new(PjrtBackend::new(params.clone(), config,
                                      artifact.to_string())?)
        }
    };
    let caps = backend.capabilities();
    if !caps.available {
        return Err(Error::Engine(format!(
            "backend {kind} unavailable: {}",
            caps.detail
        )));
    }
    Ok(backend)
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

/// The engine: a selected primary backend, an optional cross-check
/// reference backend, and accumulated telemetry.
pub struct Engine {
    params: NetParams,
    config: EngineConfig,
    primary: Box<dyn InferenceBackend + Send>,
    reference: Option<Box<dyn InferenceBackend + Send>>,
    telemetry: Telemetry,
    tracer: crate::obs::Tracer,
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Run a batch through the primary backend and, when configured,
    /// through the reference backend; logit divergences are counted per
    /// frame in `Telemetry::cross_check_mismatches`.  The reference run's
    /// cost lands in `Telemetry::cross_check_cost`, never in the
    /// primary's `cost` — cross-checking is an observability feature and
    /// must not inflate the primary profile's energy/time numbers.
    pub fn infer_batch(&mut self, frames: &[Frame]) -> Result<BackendOutput> {
        let mut out = self.primary.infer_batch(frames)?;
        if let Some(reference) = self.reference.as_mut() {
            let check_start = self.tracer.enabled().then(std::time::Instant::now);
            let ref_out = reference.infer_batch(frames)?;
            if let Some(t0) = check_start {
                self.tracer.emit(crate::obs::TraceEvent {
                    kind: crate::obs::EventKind::Phase,
                    ts_ns: self.tracer.ts(t0),
                    dur_ns: t0.elapsed().as_nanos() as u64,
                    shard: self.config.shard.map_or(-1, |s| s.index as i32),
                    backend: Some(reference.kind()),
                    label: "cross_check",
                    ..crate::obs::TraceEvent::default()
                });
            }
            if ref_out.frames.len() != out.frames.len() {
                return Err(Error::Engine(format!(
                    "cross-check returned {} outputs for {} frames",
                    ref_out.frames.len(),
                    out.frames.len()
                )));
            }
            for (f, r) in out.frames.iter_mut().zip(&ref_out.frames) {
                f.telemetry.cross_check_frames += 1;
                f.telemetry.cross_check_cost.add(&r.telemetry.cost);
                if !logits_match(&f.logits, &r.logits) {
                    f.telemetry.cross_check_mismatches += 1;
                }
            }
        }
        for f in &out.frames {
            self.telemetry.merge(&f.telemetry);
        }
        Ok(out)
    }

    /// Single-frame convenience wrapper around [`Engine::infer_batch`].
    pub fn infer_frame(&mut self, frame: &Frame) -> Result<FrameOutput> {
        let out = self.infer_batch(std::slice::from_ref(frame))?;
        out.frames.into_iter().next().ok_or_else(|| {
            Error::Engine("backend returned no output for the frame".into())
        })
    }

    /// Primary backend kind.
    pub fn kind(&self) -> BackendKind {
        self.primary.kind()
    }

    /// Primary backend capabilities.
    pub fn capabilities(&self) -> Capabilities {
        self.primary.capabilities()
    }

    /// Reference backend kind, when cross-checking is enabled.
    pub fn cross_check_kind(&self) -> Option<BackendKind> {
        self.reference.as_ref().map(|r| r.kind())
    }

    /// Install a trace handle on the engine and both its backends: the
    /// engine emits a `cross_check` phase span per reference run, the
    /// backends their own `lbp`/`mlp` phase spans.  With the default
    /// (disabled) tracer all of it is a branch per batch.
    pub fn set_tracer(&mut self, tracer: crate::obs::Tracer) {
        self.primary.set_tracer(tracer.clone());
        if let Some(reference) = self.reference.as_mut() {
            reference.set_tracer(tracer.clone());
        }
        self.tracer = tracer;
    }

    /// Telemetry accumulated over every batch this engine has run.
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    pub fn params(&self) -> &NetParams {
        &self.params
    }
}

/// Logit equivalence: exact for the integer paths, within the golden-model
/// tolerance (1e-4 relative) so the PJRT float path can be a reference.
fn logits_match(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| (x - y).abs() <= 1e-4 * y.abs().max(1.0))
}

/// Builder for [`Engine`].  Backend and cross-check selection default to
/// `config.system.engine` (file / `--set` controlled); explicit calls to
/// [`EngineBuilder::backend`] / [`EngineBuilder::cross_check`] win.
#[derive(Default)]
pub struct EngineBuilder {
    config: EngineConfig,
    params: Option<NetParams>,
    backend: Option<BackendKind>,
    cross_check: Option<Option<BackendKind>>,
    artifact: Option<String>,
    hw_profile: Option<HwProfile>,
    prepacked: Option<std::sync::Arc<Prepacked>>,
}

impl EngineBuilder {
    pub fn config(mut self, config: EngineConfig) -> Self {
        self.config = config;
        self
    }

    pub fn params(mut self, params: NetParams) -> Self {
        self.params = Some(params);
        self
    }

    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.backend = Some(kind);
        self
    }

    pub fn cross_check(mut self, kind: BackendKind) -> Self {
        self.cross_check = Some(Some(kind));
        self
    }

    /// Disable cross-checking even if the config requests it.
    pub fn no_cross_check(mut self) -> Self {
        self.cross_check = Some(None);
        self
    }

    /// HLO artifact name for the PJRT backend (default:
    /// `engine.pjrt_artifact` from the config, `aplbp_mnist` out of the
    /// box).
    pub fn artifact(mut self, name: impl Into<String>) -> Self {
        self.artifact = Some(name.into());
        self
    }

    /// Hardware profile the backends price telemetry with, overriding
    /// the config's `[hw]` selection (`--hw-profile` on the CLI).
    pub fn hw_profile(mut self, profile: HwProfile) -> Self {
        self.hw_profile = Some(profile);
        self
    }

    /// Compiled tables from a `CompiledModel` artifact: backends reuse
    /// the gather plans and weight bit-planes instead of rebuilding
    /// them.  The tables are validated against the params and cache
    /// geometry at build — a mismatching artifact is an error, never a
    /// silent repack.
    pub fn prepacked(mut self, prepacked: std::sync::Arc<Prepacked>) -> Self {
        self.prepacked = Some(prepacked);
        self
    }

    pub fn build(mut self) -> Result<Engine> {
        let params = self.params.ok_or_else(|| {
            Error::Engine("EngineBuilder: params not set".into())
        })?;
        if let Some(profile) = self.hw_profile.take() {
            profile.validate()?;
            self.config.system.hw.profile = profile;
        }
        self.config.validate()?;
        let kind = self.backend.unwrap_or(self.config.system.engine.backend);
        let cross = self
            .cross_check
            .unwrap_or(self.config.system.engine.cross_check);
        let artifact = self
            .artifact
            .unwrap_or_else(|| self.config.system.engine.pjrt_artifact.clone());
        let prepacked = self.prepacked.as_deref();
        let primary =
            make_backend(kind, &params, &self.config, &artifact, prepacked)?;
        let reference = match cross {
            Some(k) => Some(make_backend(k, &params, &self.config, &artifact,
                                         prepacked)?),
            None => None,
        };
        Ok(Engine {
            params,
            config: self.config,
            primary,
            reference,
            telemetry: Telemetry::default(),
            tracer: crate::obs::Tracer::disabled(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::synth::synth_params;
    use crate::testing::synth_frames;

    fn setup(n: usize) -> (NetParams, Vec<Frame>) {
        let (_, params) = synth_params(5);
        let frames = synth_frames(&params, n, 17).unwrap();
        (params, frames)
    }

    #[test]
    fn backend_kind_parses_and_displays() {
        assert_eq!("functional".parse::<BackendKind>().unwrap(),
                   BackendKind::Functional);
        assert_eq!("ARCH".parse::<BackendKind>().unwrap(),
                   BackendKind::Architectural);
        assert_eq!("pjrt".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert!("frobnicate".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::Architectural.to_string(), "architectural");
        assert_eq!(BackendKind::parse_optional("none").unwrap(), None);
        assert_eq!(BackendKind::parse_optional("functional").unwrap(),
                   Some(BackendKind::Functional));
        assert!(BackendKind::parse_optional("nope").is_err());
    }

    #[test]
    fn builder_requires_params() {
        assert!(Engine::builder().build().is_err());
    }

    #[test]
    fn validate_frame_reports_expected_then_actual_dims() {
        let (params, frames) = setup(1);
        let cfg = &params.config;
        assert!(validate_frame(&frames[0], cfg).is_ok());
        let mut frame = frames[0].clone();
        frame.rows += 1;
        frame.pixels.truncate(3);
        let msg = validate_frame(&frame, cfg).unwrap_err().to_string();
        let want = format!(
            "expected {}x{}x{} ({} px), got {}x{}x{} (3 px)",
            cfg.height, cfg.width, cfg.in_channels,
            cfg.height * cfg.width * cfg.in_channels,
            cfg.height + 1, cfg.width, cfg.in_channels
        );
        assert!(msg.contains(&want),
                "message should carry expected-then-actual dims: {msg}");
    }

    #[test]
    fn engine_runs_functional_backend() {
        let (params, frames) = setup(3);
        let mut engine = Engine::builder()
            .params(params)
            .backend(BackendKind::Functional)
            .build()
            .unwrap();
        assert_eq!(engine.kind(), BackendKind::Functional);
        assert!(engine.capabilities().available);
        let out = engine.infer_batch(&frames).unwrap();
        assert_eq!(out.frames.len(), 3);
        for (i, f) in out.frames.iter().enumerate() {
            assert_eq!(f.seq, i as u64);
            assert_eq!(f.logits.len(), 10);
            assert!(f.predicted < 10);
            assert!(f.features.is_some());
        }
        assert_eq!(engine.telemetry().arch_mismatches, 0);
    }

    #[test]
    fn cross_check_counts_frames_and_agrees() {
        let (params, frames) = setup(2);
        let mut engine = Engine::builder()
            .params(params)
            .backend(BackendKind::Architectural)
            .cross_check(BackendKind::Functional)
            .build()
            .unwrap();
        assert_eq!(engine.cross_check_kind(), Some(BackendKind::Functional));
        let out = engine.infer_batch(&frames).unwrap();
        let t = out.telemetry();
        assert_eq!(t.cross_check_frames, 2);
        assert_eq!(t.cross_check_mismatches, 0);
        assert_eq!(engine.telemetry().cross_check_frames, 2);
        assert_eq!(engine.telemetry().cross_check_mismatches, 0);
    }

    #[test]
    fn pjrt_backend_unavailable_is_an_early_error() {
        if crate::runtime::pjrt_available() {
            return; // pjrt-featured builds construct a real client instead
        }
        let (params, _) = setup(1);
        let err = Engine::builder()
            .params(params)
            .backend(BackendKind::Pjrt)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("unavailable"), "{err}");
    }

    #[test]
    fn shard_slice_banks_partition_exactly() {
        for count in [1, 3, 4, 7, 80] {
            let total: usize = (0..count)
                .map(|index| ShardSlice { index, count }.banks(80))
                .sum();
            assert_eq!(total, 80, "count {count}");
        }
    }

    #[test]
    fn engine_config_validates_shard_slices() {
        let bad = EngineConfig {
            shard: Some(ShardSlice { index: 2, count: 2 }),
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let too_many = EngineConfig {
            shard: Some(ShardSlice { index: 0, count: 81 }),
            ..Default::default()
        };
        assert!(too_many.validate().is_err());
        assert!(EngineConfig::default().validate().is_ok());
    }

    #[test]
    fn qos_class_parses_indexes_and_displays() {
        assert_eq!("best_effort".parse::<QosClass>().unwrap(),
                   QosClass::BestEffort);
        assert_eq!("BILLED".parse::<QosClass>().unwrap(), QosClass::Billed);
        assert_eq!("std".parse::<QosClass>().unwrap(), QosClass::Standard);
        assert!("platinum".parse::<QosClass>().is_err());
        assert_eq!(QosClass::BestEffort.to_string(), "best_effort");
        for (i, class) in QosClass::ALL.iter().enumerate() {
            assert_eq!(class.index(), i);
            assert_eq!(class.as_str().parse::<QosClass>().unwrap(), *class);
        }
        assert_eq!(QosClass::default(), QosClass::Standard);
    }

    #[test]
    fn routing_policy_resolves_and_collects_backends() {
        let mut routing = RoutingPolicy::default();
        assert!(routing.is_empty());
        assert_eq!(routing.resolve(QosClass::Billed, BackendKind::Functional),
                   BackendKind::Functional);
        assert_eq!(routing.backend_set(BackendKind::Functional),
                   vec![BackendKind::Functional]);

        routing.apply_spec("best_effort=functional").unwrap();
        routing.apply_spec("billed=architectural").unwrap();
        assert!(!routing.is_empty());
        assert_eq!(routing.route(QosClass::BestEffort),
                   Some(BackendKind::Functional));
        assert_eq!(routing.route(QosClass::Standard), None);
        assert_eq!(routing.resolve(QosClass::Billed, BackendKind::Functional),
                   BackendKind::Architectural);
        // the distinct resolved backends, in class order
        assert_eq!(routing.backend_set(BackendKind::Functional),
                   vec![BackendKind::Functional, BackendKind::Architectural]);
        // a default no class resolves to is not instantiated
        routing.apply_spec("standard=functional").unwrap();
        assert_eq!(routing.backend_set(BackendKind::Pjrt),
                   vec![BackendKind::Functional, BackendKind::Architectural]);

        assert!(routing.apply_spec("billed").is_err());
        assert!(routing.apply_spec("gold=functional").is_err());
        assert!(routing.apply_spec("billed=warp").is_err());
    }

    #[test]
    fn telemetry_merges_additively() {
        let mut a = Telemetry {
            profile: "ns_lbp_65nm".into(),
            cost: Cost { time_ns: 1.5, ..Default::default() },
            arch_mismatches: 1,
            ..Default::default()
        };
        let b = Telemetry {
            profile: "ns_lbp_65nm".into(),
            cost: Cost { time_ns: 2.5, ..Default::default() },
            cross_check_frames: 3,
            cross_check_mismatches: 1,
            ..Default::default()
        };
        a.merge(&b);
        assert!((a.cost.time_ns - 4.0).abs() < 1e-12);
        assert_eq!(a.profile, "ns_lbp_65nm");
        assert_eq!(a.arch_mismatches, 1);
        assert_eq!(a.cross_check_frames, 3);
        assert_eq!(a.cross_check_mismatches, 1);
        // merging telemetry priced under another profile marks it mixed
        let c = Telemetry { profile: "sram38_28nm".into(),
                            ..Default::default() };
        a.merge(&c);
        assert_eq!(a.profile, Telemetry::MIXED_PROFILES);
        // an unmodeled (empty-profile) merge does not
        let mut d = Telemetry::default();
        d.merge(&b);
        assert_eq!(d.profile, "ns_lbp_65nm");
        d.merge(&Telemetry::default());
        assert_eq!(d.profile, "ns_lbp_65nm");
    }
}
