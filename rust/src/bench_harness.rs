//! Micro/macro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iteration with robust statistics (median, MAD,
//! p05/p95, throughput), a `black_box` to defeat const-folding, and a
//! tabular reporter used by every `benches/*.rs` target (all built with
//! `harness = false`).
//!
//! ```no_run
//! use ns_lbp::bench_harness::{Bench, black_box};
//! let mut b = Bench::new("sum");
//! let r = b.run("1..1000", || black_box((0u64..1000).sum::<u64>()));
//! r.print();
//! ```

use std::hint;
use std::time::{Duration, Instant};

/// Opaque value sink; prevents the optimizer from deleting the benched code.
#[inline]
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub group: String,
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mean: Duration,
    pub p05: Duration,
    pub p95: Duration,
    /// Median absolute deviation — robust spread estimate.
    pub mad: Duration,
}

// String escaping and float formatting come from the crate-wide JSON
// writer (`obs::json`), shared with the metrics report and the trace
// exporter so every JSON surface escapes identically.
use crate::obs::json::escape as json_escape;

impl CaseResult {
    /// items/second given `items` work items per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.median.as_secs_f64()
    }

    /// One JSON object per case — the `BENCH_*.json` trajectory record
    /// CI diffs across runs (all durations in nanoseconds; see
    /// EXPERIMENTS.md for the field glossary).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"group\":\"{}\",\"name\":\"{}\",\"iters\":{},\
             \"median_ns\":{},\"mean_ns\":{},\"p05_ns\":{},\"p95_ns\":{},\
             \"mad_ns\":{}}}",
            json_escape(&self.group),
            json_escape(&self.name),
            self.iters,
            self.median.as_secs_f64() * 1e9,
            self.mean.as_secs_f64() * 1e9,
            self.p05.as_secs_f64() * 1e9,
            self.p95.as_secs_f64() * 1e9,
            self.mad.as_secs_f64() * 1e9,
        )
    }

    pub fn print(&self) {
        println!(
            "{:<40} median {:>12?} mean {:>12?} p05 {:>12?} p95 {:>12?} ({} iters)",
            format!("{}/{}", self.group, self.name),
            self.median,
            self.mean,
            self.p05,
            self.p95,
            self.iters
        );
    }
}

/// Benchmark group runner.
pub struct Bench {
    group: String,
    /// Target measurement time per case.
    pub measure_time: Duration,
    /// Warmup time per case.
    pub warmup_time: Duration,
    /// Upper bound on timed samples.
    pub max_samples: usize,
    results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // NSLBP_BENCH_FAST=1 shrinks times for CI smoke runs.
        let fast = std::env::var("NSLBP_BENCH_FAST").is_ok();
        Self {
            group: group.to_string(),
            measure_time: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(2)
            },
            warmup_time: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(500)
            },
            max_samples: 10_000,
            results: Vec::new(),
        }
    }

    /// Time `f` (called repeatedly); returns robust statistics.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> CaseResult {
        // Warmup and initial calibration of per-iteration cost.
        let warm_start = Instant::now();
        let mut calib_iters = 0u64;
        while warm_start.elapsed() < self.warmup_time || calib_iters == 0 {
            black_box(f());
            calib_iters += 1;
            if calib_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / calib_iters as f64;

        // Choose a batch size so one sample costs ≥ ~50 µs (timer noise floor).
        let batch = ((50e-6 / per_iter.max(1e-12)).ceil() as u64).max(1);
        let target_samples = ((self.measure_time.as_secs_f64()
            / (per_iter * batch as f64).max(1e-9)) as usize)
            .clamp(10, self.max_samples);

        let mut samples = Vec::with_capacity(target_samples);
        let mut total_iters = 0u64;
        for _ in 0..target_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |q: f64| -> Duration {
            let idx = ((samples.len() - 1) as f64 * q).round() as usize;
            Duration::from_secs_f64(samples[idx])
        };
        let median = pick(0.5);
        let mean = Duration::from_secs_f64(
            samples.iter().sum::<f64>() / samples.len() as f64,
        );
        let med = median.as_secs_f64();
        let mut devs: Vec<f64> = samples.iter().map(|s| (s - med).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = Duration::from_secs_f64(devs[devs.len() / 2]);

        let result = CaseResult {
            group: self.group.clone(),
            name: name.to_string(),
            iters: total_iters,
            median,
            mean,
            p05: pick(0.05),
            p95: pick(0.95),
            mad,
        };
        result.print();
        self.results.push(result.clone());
        result
    }

    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }

    /// Look up a finished case by name (bench mains use this to print
    /// before/after speedups without re-running anything).
    pub fn result(&self, name: &str) -> Option<&CaseResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// The whole group as one JSON document:
    /// `{"group": ..., "cases": [...]}`.
    pub fn to_json(&self) -> String {
        let cases: Vec<String> =
            self.results.iter().map(|r| r.to_json()).collect();
        format!(
            "{{\"group\":\"{}\",\"cases\":[{}]}}",
            json_escape(&self.group),
            cases.join(",")
        )
    }

    /// Write the group's JSON to `path` (the `BENCH_<group>.json`
    /// artifact CI uploads and diffs against the previous run).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json() + "\n")
    }
}

/// Steady-state allocation accounting for hot-path regression gates.
///
/// Behind the `alloc-count` feature a bench binary installs
/// [`alloc_count::CountingAlloc`] as its `#[global_allocator]` and
/// brackets the measured closure with [`alloc_count::count`]; the gate
/// then asserts the warm path performs exactly its known baseline of
/// allocations (e.g. the unavoidable output clone) and nothing more.
/// The counter is a relaxed atomic: the hot paths under the gate are
/// single-threaded, and a data race would only ever overcount — which
/// fails the gate loudly rather than hiding a regression.
#[cfg(feature = "alloc-count")]
pub mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    static ALLOCS: AtomicU64 = AtomicU64::new(0);

    /// `System` allocator wrapper that counts every `alloc`/`realloc`.
    /// Install with `#[global_allocator]` in the bench binary.
    pub struct CountingAlloc;

    // SAFETY: defers entirely to `System`; the counter has no effect
    // on the returned pointers or layouts.
    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        unsafe fn realloc(
            &self,
            ptr: *mut u8,
            layout: Layout,
            new_size: usize,
        ) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }
    }

    /// Total allocations since process start (monotone).
    pub fn total() -> u64 {
        ALLOCS.load(Ordering::Relaxed)
    }

    /// Allocations performed while running `f`.
    pub fn count<T>(f: impl FnOnce() -> T) -> (T, u64) {
        let before = total();
        let out = f();
        (out, total() - before)
    }
}

/// Simple fixed-width table printer for paper-figure outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:<w$} ", c, w = widths[i]));
            }
            s.push('|');
            s
        };
        let sep: String = widths
            .iter()
            .map(|w| format!("|{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "|";
        println!("{}", line(&self.headers));
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    /// Write the table as TSV (for EXPERIMENTS.md ingestion).
    pub fn write_tsv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join("\t"))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join("\t"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("NSLBP_BENCH_FAST", "1");
        let mut b = Bench::new("test");
        let r = b.run("noop-ish", || black_box(1u64 + 1));
        assert!(r.median.as_nanos() < 1_000_000); // well under 1 ms
        assert!(r.p05 <= r.median && r.median <= r.p95);
        assert!(r.iters > 0);
    }

    #[test]
    fn throughput_math() {
        let r = CaseResult {
            group: "g".into(),
            name: "n".into(),
            iters: 1,
            median: Duration::from_millis(10),
            mean: Duration::from_millis(10),
            p05: Duration::from_millis(9),
            p95: Duration::from_millis(11),
            mad: Duration::from_millis(1),
        };
        assert!((r.throughput(100.0) - 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn json_output_is_well_formed() {
        std::env::set_var("NSLBP_BENCH_FAST", "1");
        let mut b = Bench::new("jsongroup");
        b.run("case_a", || black_box(3u64 * 7));
        b.run("case \"b\"", || black_box(1u64));
        let json = b.to_json();
        assert!(json.starts_with("{\"group\":\"jsongroup\",\"cases\":["));
        assert!(json.contains("\"name\":\"case_a\""));
        assert!(json.contains("\"median_ns\":"));
        // quotes in names are escaped, so the document stays parseable
        assert!(json.contains("case \\\"b\\\""));
        assert_eq!(json.matches("\"iters\":").count(), 2);
        assert!(b.result("case_a").is_some());
        assert!(b.result("nope").is_none());
    }

    #[test]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()]);
        }));
        assert!(result.is_err());
    }
}
