//! `fleet::router` — rendezvous-hash placement and the per-node,
//! per-class admission ledger.
//!
//! Placement is rendezvous (highest-random-weight) hashing: for sensor
//! `s`, every node `n` gets a score `fnv1a(s ‖ n)` and the sensor is
//! owned by the highest-scoring *live* node.  The property that makes
//! this the right tool for a fleet of near-sensor caches is minimal
//! disruption: when a node leaves, only the sensors it owned move (each
//! to its second-ranked node); every other sensor's owner is untouched —
//! no ring to rebalance, no table to replicate.  When a node joins, the
//! only sensors that move are the ones the new node now wins.
//!
//! Admission is capacity-bounded per `(node, QosClass)`: the
//! [`RoutingTable`] tracks in-flight counts and [`RoutingTable::admit`]
//! walks the sensor's rendezvous ranking, placing the frame on the first
//! live node with headroom (a *spill* when that isn't the top choice).
//! The ledger is deliberately pure — no channels, no clocks — so the
//! proptests can drive millions of random admit/release mixes against
//! the exact code the fleet runs.

use crate::compile::fnv1a;
use crate::engine::QosClass;

use super::transport::NodeId;

// ---------------------------------------------------------------------------
// Rendezvous hashing (pure)
// ---------------------------------------------------------------------------

/// Rendezvous score of `node` for `sensor_id`.
pub fn rendezvous_score(sensor_id: u32, node: NodeId) -> u64 {
    let mut key = [0u8; 12];
    key[..4].copy_from_slice(&sensor_id.to_le_bytes());
    key[4..].copy_from_slice(&(node as u64).to_le_bytes());
    fnv1a(&key)
}

/// All of `nodes` ranked for `sensor_id`, best first.  Ties (which FNV
/// makes vanishingly rare) break toward the lower node id so the
/// ranking is a total order.
pub fn rendezvous_rank(sensor_id: u32, nodes: &[NodeId]) -> Vec<NodeId> {
    let mut ranked: Vec<NodeId> = nodes.to_vec();
    ranked.sort_by_key(|&n| (std::cmp::Reverse(rendezvous_score(sensor_id, n)), n));
    ranked
}

/// The owner (top-ranked member of `nodes`) for `sensor_id`.
pub fn rendezvous_owner(sensor_id: u32, nodes: &[NodeId]) -> Option<NodeId> {
    nodes
        .iter()
        .copied()
        .max_by_key(|&n| (rendezvous_score(sensor_id, n), std::cmp::Reverse(n)))
}

// ---------------------------------------------------------------------------
// Admission ledger
// ---------------------------------------------------------------------------

/// Where one admission landed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub node: NodeId,
    /// True when capacity pushed the frame past its rendezvous owner.
    pub spilled: bool,
}

/// Live-set plus per-node, per-class in-flight accounting.  All methods
/// are synchronous and allocation-light; the fleet wraps one of these in
/// a mutex.
#[derive(Clone, Debug)]
pub struct RoutingTable {
    live: Vec<bool>,
    in_flight: Vec<[usize; QosClass::COUNT]>,
    capacity: [usize; QosClass::COUNT],
}

impl RoutingTable {
    /// `capacity` is the per-node in-flight bound for each class (index
    /// by [`QosClass::index`]).
    pub fn new(nodes: usize, capacity: [usize; QosClass::COUNT]) -> Self {
        Self {
            live: vec![true; nodes],
            in_flight: vec![[0; QosClass::COUNT]; nodes],
            capacity,
        }
    }

    pub fn nodes(&self) -> usize {
        self.live.len()
    }

    pub fn capacity(&self, class: QosClass) -> usize {
        self.capacity[class.index()]
    }

    pub fn is_live(&self, node: NodeId) -> bool {
        self.live.get(node).copied().unwrap_or(false)
    }

    /// Nodes currently accepting traffic, ascending id.
    pub fn live_nodes(&self) -> Vec<NodeId> {
        (0..self.live.len()).filter(|&n| self.live[n]).collect()
    }

    /// Take `node` out of rotation (crash or administrative kill).  Its
    /// in-flight counts are zeroed — the fleet re-homes those frames as
    /// fresh admissions.
    pub fn mark_dead(&mut self, node: NodeId) {
        if node < self.live.len() {
            self.live[node] = false;
            self.in_flight[node] = [0; QosClass::COUNT];
        }
    }

    /// Put `node` back into rotation after a transient fault (health
    /// monitor rejoin).  Its in-flight ledger restarts from zero: every
    /// frame it owed was re-homed when it was marked dead, so the node
    /// comes back empty.  No-op for a node that is already live.
    pub fn mark_live(&mut self, node: NodeId) {
        if node < self.live.len() && !self.live[node] {
            self.live[node] = true;
            self.in_flight[node] = [0; QosClass::COUNT];
        }
    }

    pub fn in_flight(&self, node: NodeId, class: QosClass) -> usize {
        self.in_flight[node][class.index()]
    }

    /// Admit one `class` frame from `sensor_id`: walk the sensor's
    /// rendezvous ranking and place it on the first live node with
    /// class headroom, charging that node's ledger.  `None` when every
    /// live node is at capacity (or none is live) — the caller surfaces
    /// that as a retryable rejection.
    pub fn admit(&mut self, sensor_id: u32, class: QosClass) -> Option<Placement> {
        let live = self.live_nodes();
        let ranked = rendezvous_rank(sensor_id, &live);
        for (rank, &node) in ranked.iter().enumerate() {
            if self.in_flight[node][class.index()] < self.capacity[class.index()] {
                self.in_flight[node][class.index()] += 1;
                return Some(Placement { node, spilled: rank > 0 });
            }
        }
        None
    }

    /// Release one in-flight slot after the frame resolved (completed,
    /// rejected downstream, dropped, or failed).  No-op for a node
    /// already marked dead — its ledger was zeroed at death.
    pub fn release(&mut self, node: NodeId, class: QosClass) {
        if self.is_live(node) {
            let slot = &mut self.in_flight[node][class.index()];
            *slot = slot.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_is_total_and_stable() {
        let nodes: Vec<NodeId> = (0..5).collect();
        for sensor in 0..64u32 {
            let r1 = rendezvous_rank(sensor, &nodes);
            let r2 = rendezvous_rank(sensor, &nodes);
            assert_eq!(r1, r2);
            let mut sorted = r1.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, nodes);
            assert_eq!(rendezvous_owner(sensor, &nodes), Some(r1[0]));
        }
    }

    #[test]
    fn owner_spread_is_not_degenerate() {
        // 3 nodes, 300 sensors: every node should own a healthy share.
        let nodes: Vec<NodeId> = (0..3).collect();
        let mut owned = [0usize; 3];
        for sensor in 0..300u32 {
            owned[rendezvous_owner(sensor, &nodes).unwrap()] += 1;
        }
        for (node, &n) in owned.iter().enumerate() {
            assert!(n > 50, "node {node} owns only {n}/300 sensors: {owned:?}");
        }
    }

    #[test]
    fn admit_respects_capacity_and_spills() {
        let mut table = RoutingTable::new(2, [1, 1, 1]);
        let sensor = 7;
        let owner = rendezvous_owner(sensor, &[0, 1]).unwrap();
        let first = table.admit(sensor, QosClass::Billed).unwrap();
        assert_eq!(first, Placement { node: owner, spilled: false });
        let second = table.admit(sensor, QosClass::Billed).unwrap();
        assert_eq!(second.node, 1 - owner);
        assert!(second.spilled);
        // Both nodes full for billed; a third admission is refused but
        // other classes still fit.
        assert!(table.admit(sensor, QosClass::Billed).is_none());
        assert!(table.admit(sensor, QosClass::Standard).is_some());
        table.release(first.node, QosClass::Billed);
        assert!(table.admit(sensor, QosClass::Billed).is_some());
    }

    #[test]
    fn dead_node_leaves_rotation() {
        let mut table = RoutingTable::new(3, [4, 4, 4]);
        table.mark_dead(1);
        for sensor in 0..32u32 {
            let p = table.admit(sensor, QosClass::Standard).unwrap();
            assert_ne!(p.node, 1);
        }
        // Releasing against a dead node is a no-op, not an underflow.
        table.release(1, QosClass::Standard);
        assert_eq!(table.in_flight(1, QosClass::Standard), 0);
    }

    #[test]
    fn rejoin_restores_rotation_with_a_clean_ledger() {
        let mut table = RoutingTable::new(2, [1, 1, 1]);
        table.admit(7, QosClass::Standard).unwrap();
        table.admit(7, QosClass::Standard).unwrap();
        table.mark_dead(0);
        assert!(!table.is_live(0));
        // rejoin: live again, in-flight zeroed (its frames were re-homed)
        table.mark_live(0);
        assert!(table.is_live(0));
        assert_eq!(table.in_flight(0, QosClass::Standard), 0);
        // mark_live on an already-live node must not zero a real ledger
        let p = table.admit(9, QosClass::Standard).unwrap();
        let before = table.in_flight(p.node, QosClass::Standard);
        table.mark_live(p.node);
        assert_eq!(table.in_flight(p.node, QosClass::Standard), before);
        assert_eq!(table.live_nodes(), vec![0, 1]);
    }
}
