//! `fleet::transport` — the socket-shaped message link between the
//! fleet router and its serve nodes.
//!
//! Every router↔node interaction goes through [`WireRequest`] /
//! [`WireResponse`] messages correlated by `req_id`: plain-data payloads
//! with no shared state, so a real wire (TCP, RDMA, whatever the
//! deployment uses) can slot in behind [`Transport`] by serializing the
//! same messages.  The in-tree implementation, [`ChannelTransport`],
//! rides the serving layer's [`BoundedQueue`] — same close/drain
//! semantics a socket gives you: a closed link still yields messages
//! already in flight, then reports down.
//!
//! Link-down is a first-class signal, not an error path: the router's
//! per-node collector treats `recv() == None` as the node being gone and
//! re-homes that node's in-flight frames (see [`crate::fleet`]).

use std::sync::Arc;
use std::time::Duration;

use crate::engine::QosClass;
use crate::sensor::Frame;
use crate::serve::queue::{BoundedQueue, PopResult};
use crate::serve::{InferResponse, MetricsReport};

/// Fleet-wide node identifier (dense, assigned at [`crate::fleet::Fleet`]
/// start).
pub type NodeId = usize;

// ---------------------------------------------------------------------------
// Wire messages
// ---------------------------------------------------------------------------

/// Router → node messages.  `req_id` correlates the eventual
/// [`WireResponse`]; ids are unique across the fleet's lifetime, so a
/// re-homed frame is a *new* request, never a replay of an old id.
#[derive(Clone, Debug)]
pub enum WireRequest {
    /// Serve one frame.  `frame.seq` is stamped by the router (the fleet
    /// owns the per-sensor sequence space — a re-homed frame keeps its
    /// seq, so fleet output is comparable to a single-node run).
    Submit {
        req_id: u64,
        sensor_id: u32,
        class: QosClass,
        model_id: u32,
        frame: Frame,
    },
    /// Install (or roll) a compiled model from its serialized `.nslbpc`
    /// artifact bytes.  The bytes are broadcast once: the router
    /// serializes a model a single time and every node's message shares
    /// the same buffer (a real wire would put the same bytes on N
    /// sockets).
    PushModel {
        req_id: u64,
        model_id: u32,
        artifact: Arc<Vec<u8>>,
    },
    /// Graceful shutdown: finish in-flight frames, then report.
    Drain { req_id: u64 },
    /// Health probe (see [`crate::faults::health`]): the node answers
    /// with [`WireResponse::Pong`] immediately.  Any response refreshes a
    /// node's last-seen time; pings guarantee one exists even when the
    /// node owes no frames.
    Ping { req_id: u64 },
}

/// Node → router messages.
#[derive(Clone, Debug)]
pub enum WireResponse {
    /// A submitted frame completed inference.
    Completed { req_id: u64, response: InferResponse },
    /// Admission rejected the frame (node queue at depth); retryable.
    Rejected { req_id: u64, error: String },
    /// The frame was shed (drop-oldest admission or deadline); terminal.
    Dropped { req_id: u64, error: String },
    /// The frame failed inside the node's pipeline; terminal.
    Failed { req_id: u64, error: String },
    /// `PushModel` landed; `version` is the artifact content-hash the
    /// node now serves for `model_id`.
    ModelPushed { req_id: u64, model_id: u32, version: u64 },
    /// `PushModel` could not be applied.
    PushFailed { req_id: u64, error: String },
    /// `Drain` finished; the node's frozen serving metrics.
    Drained { req_id: u64, report: Box<MetricsReport> },
    /// Answer to a [`WireRequest::Ping`] health probe.
    Pong { req_id: u64 },
}

impl WireResponse {
    /// The correlation id this response answers.
    pub fn req_id(&self) -> u64 {
        match self {
            WireResponse::Completed { req_id, .. }
            | WireResponse::Rejected { req_id, .. }
            | WireResponse::Dropped { req_id, .. }
            | WireResponse::Failed { req_id, .. }
            | WireResponse::ModelPushed { req_id, .. }
            | WireResponse::PushFailed { req_id, .. }
            | WireResponse::Drained { req_id, .. }
            | WireResponse::Pong { req_id } => *req_id,
        }
    }
}

// ---------------------------------------------------------------------------
// Link halves
// ---------------------------------------------------------------------------

/// Sender half of one direction of a link.
pub trait WireTx<T>: Send + Sync {
    /// Queue `msg` for delivery; `Err(msg)` means the link is down.
    fn send(&self, msg: T) -> std::result::Result<(), T>;
    /// Close the link.  Messages already queued still deliver (drain
    /// semantics); subsequent sends fail.
    fn close(&self);
}

/// Receiver half of one direction of a link.
pub trait WireRx<T>: Send {
    /// Block until the next message; `None` means the link closed and
    /// every queued message was already delivered.
    fn recv(&self) -> Option<T>;
    /// Non-blocking poll.
    fn try_recv(&self) -> TryRecv<T>;
}

/// Outcome of a [`WireRx::try_recv`].
#[derive(Debug)]
pub enum TryRecv<T> {
    Msg(T),
    /// Nothing queued; link still up.
    Empty,
    /// Link closed and drained.
    Closed,
}

/// The router's end of one node link.
pub struct RouterLink {
    pub tx: Arc<dyn WireTx<WireRequest>>,
    pub rx: Box<dyn WireRx<WireResponse>>,
}

/// The node's end of its link (consumed by the node service loop).
pub struct NodeLink {
    pub rx: Box<dyn WireRx<WireRequest>>,
    pub tx: Box<dyn WireTx<WireResponse>>,
}

/// Connection factory: one bidirectional link per node.  Implementations
/// decide what a "link" is — in-memory queues today, sockets later; the
/// router and node loops only ever see the [`RouterLink`] / [`NodeLink`]
/// halves.
pub trait Transport: Send {
    fn connect(&mut self, node: NodeId) -> (RouterLink, NodeLink);
}

// ---------------------------------------------------------------------------
// In-memory channel transport
// ---------------------------------------------------------------------------

/// In-process [`Transport`]: a pair of [`BoundedQueue`]s per node.
/// `depth` bounds each direction; the fleet sizes it past the router's
/// total admission capacity so a healthy link never blocks the router.
pub struct ChannelTransport {
    depth: usize,
}

impl ChannelTransport {
    pub fn new(depth: usize) -> Self {
        Self { depth: depth.max(1) }
    }
}

struct QueueTx<T>(Arc<BoundedQueue<T>>);
struct QueueRx<T>(Arc<BoundedQueue<T>>);

impl<T: Send> WireTx<T> for QueueTx<T> {
    fn send(&self, msg: T) -> std::result::Result<(), T> {
        self.0.push(msg)
    }

    fn close(&self) {
        self.0.close();
    }
}

impl<T: Send> WireRx<T> for QueueRx<T> {
    fn recv(&self) -> Option<T> {
        self.0.pop()
    }

    fn try_recv(&self) -> TryRecv<T> {
        match self.0.pop_timeout(Duration::ZERO) {
            PopResult::Item(msg) => TryRecv::Msg(msg),
            PopResult::TimedOut => TryRecv::Empty,
            PopResult::Closed => TryRecv::Closed,
        }
    }
}

impl Transport for ChannelTransport {
    fn connect(&mut self, _node: NodeId) -> (RouterLink, NodeLink) {
        let to_node = Arc::new(BoundedQueue::<WireRequest>::new(self.depth));
        let to_router = Arc::new(BoundedQueue::<WireResponse>::new(self.depth));
        (
            RouterLink {
                tx: Arc::new(QueueTx(Arc::clone(&to_node))),
                rx: Box::new(QueueRx(Arc::clone(&to_router))),
            },
            NodeLink {
                rx: Box::new(QueueRx(to_node)),
                tx: Box::new(QueueTx(to_router)),
            },
        )
    }
}
