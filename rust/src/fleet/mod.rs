//! `fleet` — multi-node serving: sensor-hash routing, versioned weight
//! replication, and failure drills.
//!
//! One [`crate::serve::Server`] simulates one near-sensor cache.  A real
//! deployment of the paper's accelerator is a *fleet* of such caches
//! behind an aggregation point, so this module runs N serve nodes (in
//! process, to stay offline) behind the socket-shaped
//! [`transport::Transport`] and fronts them with a router:
//!
//! * **Placement** — sessions spread across nodes by rendezvous hash of
//!   `sensor_id` ([`router::rendezvous_rank`]), with per-node,
//!   per-[`QosClass`] admission capacity and spill to the next-ranked
//!   node when the owner is full.
//! * **Weight replication** — [`Fleet::push_model`] serializes a
//!   content-hash-versioned compiled artifact *once* and rolls it
//!   node-by-node over the wire, awaiting each node's version ack;
//!   `serve::Server::push_model` pins in-flight frames to the entry they
//!   were admitted against, so a rollover never drops frames.
//! * **Failure drills** — [`Fleet::kill_node`] drops a node without
//!   drain.  The router detects link-down, re-homes the dead node's
//!   in-flight frames to their next-ranked live nodes (same `seq`, new
//!   request id), and keeps billed-frame loss at zero: frames are only
//!   *lost* when no live node remains.
//! * **Fleet observability** — [`Fleet::drain`] folds every node's
//!   [`MetricsReport`] plus router-side counters (re-homes, spills,
//!   per-node completions, end-to-end percentiles) into one
//!   [`FleetReport`]; with tracing on, each node writes its own JSONL
//!   feed (`feed-node<i>.jsonl`) that `ns-lbp trace` can merge.
//!
//! Engines are deterministic, so a fleet's logits are bit-identical to a
//! single node serving the same stamped frames — re-homing and spilling
//! move *where* a frame runs, never *what* it computes.  `ns-lbp
//! fleet-bench` drives the whole stack, drills included.

pub mod node;
pub mod router;
pub mod transport;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::compile::CompiledModel;
use crate::config::{FaultsConfig, FleetConfig};
use crate::engine::{EngineConfig, QosClass};
use crate::error::{Error, Result};
use crate::faults::{HealthTracker, SeqLedger};
use crate::obs::json as j;
use crate::params::NetParams;
use crate::sensor::Frame;
use crate::serve::queue::wait_deadline;
use crate::serve::{percentile_ns, InferResponse, MetricsReport};

pub use router::{rendezvous_owner, rendezvous_rank, rendezvous_score, Placement,
                 RoutingTable};
pub use transport::{ChannelTransport, NodeId, Transport, WireRequest, WireResponse};

// ---------------------------------------------------------------------------
// Completion plumbing
// ---------------------------------------------------------------------------

/// A completed fleet inference: the serving node's response plus the
/// router's view of the frame's journey.
#[derive(Clone, Debug)]
pub struct FleetResponse {
    /// Node that completed the frame (after any re-homing).
    pub node: NodeId,
    /// Times the frame was re-homed after a node death.
    pub rerouted: u32,
    /// Router-observed submit→completion latency (spans re-homes).
    pub latency: Duration,
    /// The node's full serving response (logits, telemetry, shard…).
    pub inner: InferResponse,
}

impl FleetResponse {
    pub fn seq(&self) -> u64 {
        self.inner.seq()
    }

    pub fn predicted(&self) -> usize {
        self.inner.predicted()
    }
}

struct FleetSlot {
    result: Mutex<Option<Result<FleetResponse>>>,
    ready: Condvar,
}

impl FleetSlot {
    fn new() -> Self {
        Self { result: Mutex::new(None), ready: Condvar::new() }
    }

    fn fulfill(&self, r: Result<FleetResponse>) {
        let mut g = self.result.lock().unwrap();
        if g.is_none() {
            *g = Some(r);
            self.ready.notify_all();
        }
    }
}

/// Claim on one in-flight fleet frame (mirrors [`crate::serve::Ticket`]).
pub struct FleetTicket {
    slot: Arc<FleetSlot>,
}

impl FleetTicket {
    /// Block until the frame resolves.
    pub fn wait(self) -> Result<FleetResponse> {
        let mut g = self.slot.result.lock().unwrap();
        loop {
            if let Some(r) = g.take() {
                return r;
            }
            g = self.slot.ready.wait(g).unwrap();
        }
    }

    /// Bounded wait; `None` on timeout (claim stays valid).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<FleetResponse>> {
        let deadline = Instant::now() + timeout;
        let g = self.slot.result.lock().unwrap();
        let (_g, r) =
            wait_deadline(&self.slot.ready, g, deadline, |res| res.take());
        r
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<Result<FleetResponse>> {
        self.slot.result.lock().unwrap().take()
    }
}

/// Ack payload for control operations (model push, drain).
enum ControlAck {
    Pushed { version: u64 },
    Drained,
}

struct ControlSlot {
    node: NodeId,
    result: Mutex<Option<Result<ControlAck>>>,
    ready: Condvar,
}

impl ControlSlot {
    fn new(node: NodeId) -> Self {
        Self { node, result: Mutex::new(None), ready: Condvar::new() }
    }

    fn fulfill(&self, r: Result<ControlAck>) {
        let mut g = self.result.lock().unwrap();
        if g.is_none() {
            *g = Some(r);
            self.ready.notify_all();
        }
    }

    fn wait(&self, timeout: Duration) -> Option<Result<ControlAck>> {
        let deadline = Instant::now() + timeout;
        let g = self.result.lock().unwrap();
        let (_g, r) = wait_deadline(&self.ready, g, deadline, |res| res.take());
        r
    }
}

// ---------------------------------------------------------------------------
// Router core (shared with collector threads)
// ---------------------------------------------------------------------------

struct PendingEntry {
    sensor_id: u32,
    class: QosClass,
    model_id: u32,
    frame: Frame,
    node: NodeId,
    attempts: u32,
    submitted: Instant,
    /// When the frame last went on the wire (refreshed per placement);
    /// the retransmit sweep ages on this, not on `submitted`.
    last_sent: Instant,
    slot: Arc<FleetSlot>,
}

/// "Owner" of a parked frame (no placement available right now) — never
/// a real node id, so link-down re-homing skips it and only the
/// retransmit sweep picks it back up.
const NO_NODE: NodeId = usize::MAX;

#[derive(Clone, Debug, Default)]
struct FleetStats {
    submitted: u64,
    completed: u64,
    completed_by_class: [u64; QosClass::COUNT],
    completed_by_node: Vec<u64>,
    rejected: u64,
    dropped: u64,
    failed: u64,
    rerouted: u64,
    spilled: u64,
    lost: [u64; QosClass::COUNT],
    /// Responses with no pending entry *and* no resolved-ledger record —
    /// a genuine protocol bug, tracked so it can't hide.
    orphaned: u64,
    /// Responses for an already-resolved request id (late duplicates of
    /// completed frames, stragglers from superseded placements) that the
    /// ledger absorbed — the exactly-once counter.
    deduped: u64,
    /// Frames retransmitted by the monitor after `retransmit_ms` of
    /// silence.
    retries: u64,
    /// Standard-class frames shed to best-effort routing under sustained
    /// placement failure.
    degraded: u64,
}

struct RouterState {
    table: RoutingTable,
    pending: HashMap<u64, PendingEntry>,
    control: HashMap<u64, Arc<ControlSlot>>,
    reports: Vec<Option<MetricsReport>>,
    stats: FleetStats,
    latencies_ns: Vec<u64>,
    /// Terminally-resolved (or superseded) request ids; see
    /// [`crate::faults::SeqLedger`].
    resolved: SeqLedger,
    /// Node liveness machine — present only when `[faults]` is enabled
    /// (the monitor thread owns the sweep cadence).
    health: Option<HealthTracker>,
}

struct RouterCore {
    state: Mutex<RouterState>,
    txs: Vec<Arc<dyn transport::WireTx<WireRequest>>>,
    next_req: AtomicU64,
}

impl RouterCore {
    fn req_id(&self) -> u64 {
        self.next_req.fetch_add(1, Ordering::Relaxed)
    }
}

/// Place `entry` on the first live node with capacity and put it on the
/// wire.  On a send failure (link just died) the target is marked dead
/// and the walk continues.  `Err` hands the entry back: no live node had
/// headroom for its class.
fn route_and_send(core: &RouterCore, mut entry: PendingEntry)
                  -> std::result::Result<NodeId, (Error, PendingEntry)> {
    loop {
        let req_id = core.req_id();
        let node = {
            let mut st = core.state.lock().unwrap();
            let placement = match st.table.admit(entry.sensor_id, entry.class) {
                Some(p) => p,
                None => {
                    let live = st.table.live_nodes().len();
                    return Err((
                        Error::Serve(format!(
                            "fleet admission: no capacity for class {} on any \
                             of {live} live node(s)",
                            entry.class.as_str()
                        )),
                        entry,
                    ));
                }
            };
            if placement.spilled {
                st.stats.spilled += 1;
            }
            entry.node = placement.node;
            entry.last_sent = Instant::now();
            let msg_parts = (entry.sensor_id, entry.class, entry.model_id,
                             entry.frame.clone());
            st.pending.insert(req_id, entry);
            drop(st);
            (placement.node, msg_parts)
        };
        let (node, (sensor_id, class, model_id, frame)) = node;
        let msg = WireRequest::Submit { req_id, sensor_id, class, model_id, frame };
        match core.txs[node].send(msg) {
            Ok(()) => return Ok(node),
            Err(_) => {
                // Link down between admit and send: undo, mark dead, walk on.
                let mut st = core.state.lock().unwrap();
                st.table.release(node, class);
                st.table.mark_dead(node);
                match st.pending.remove(&req_id) {
                    Some(e) => entry = e,
                    // The node's collector already re-homed it.
                    None => return Ok(node),
                }
            }
        }
    }
}

/// Any message from `node` proves liveness; a rejoin (first sign of
/// life from a health-dead node) puts it back into routing rotation.
fn note_alive(core: &RouterCore, node: NodeId) {
    let mut st = core.state.lock().unwrap();
    let st = &mut *st;
    if let Some(h) = st.health.as_mut() {
        if h.mark_seen(node) {
            st.table.mark_live(node);
        }
    }
}

/// One node's response collector: runs until the node's link closes,
/// then re-homes whatever the dead node still owed.
fn collect(core: &Arc<RouterCore>, node: NodeId,
           rx: Box<dyn transport::WireRx<WireResponse>>) {
    while let Some(msg) = rx.recv() {
        note_alive(core, node);
        match msg {
            WireResponse::Completed { req_id, response } => {
                let entry = {
                    let mut st = core.state.lock().unwrap();
                    match st.pending.remove(&req_id) {
                        Some(e) => {
                            st.resolved.record(req_id);
                            st.table.release(node, e.class);
                            let ns = e.submitted.elapsed().as_nanos() as u64;
                            st.latencies_ns.push(ns);
                            st.stats.completed += 1;
                            st.stats.completed_by_class[e.class.index()] += 1;
                            st.stats.completed_by_node[node] += 1;
                            Some(e)
                        }
                        None => {
                            if st.resolved.contains(req_id) {
                                st.stats.deduped += 1;
                            } else {
                                st.stats.orphaned += 1;
                            }
                            None
                        }
                    }
                };
                if let Some(e) = entry {
                    e.slot.fulfill(Ok(FleetResponse {
                        node,
                        rerouted: e.attempts,
                        latency: e.submitted.elapsed(),
                        inner: response,
                    }));
                }
            }
            WireResponse::Rejected { req_id, error } => {
                resolve_error(core, node, req_id, Error::Serve(error), Term::Rejected);
            }
            WireResponse::Dropped { req_id, error } => {
                resolve_error(core, node, req_id, Error::Dropped(error), Term::Dropped);
            }
            WireResponse::Failed { req_id, error } => {
                // Either a frame failure or a failed drain report.
                let control = core.state.lock().unwrap().control.remove(&req_id);
                match control {
                    Some(slot) => slot.fulfill(Err(Error::Serve(error))),
                    None => resolve_error(core, node, req_id,
                                          Error::Runtime(error), Term::Failed),
                }
            }
            WireResponse::ModelPushed { req_id, version, .. } => {
                if let Some(slot) = core.state.lock().unwrap().control.remove(&req_id) {
                    slot.fulfill(Ok(ControlAck::Pushed { version }));
                }
            }
            WireResponse::PushFailed { req_id, error } => {
                if let Some(slot) = core.state.lock().unwrap().control.remove(&req_id) {
                    slot.fulfill(Err(Error::Serve(error)));
                }
            }
            WireResponse::Drained { req_id, report } => {
                let slot = {
                    let mut st = core.state.lock().unwrap();
                    st.reports[node] = Some(*report);
                    st.control.remove(&req_id)
                };
                if let Some(slot) = slot {
                    slot.fulfill(Ok(ControlAck::Drained));
                }
            }
            // Liveness was already noted above; a pong carries nothing
            // else.
            WireResponse::Pong { .. } => {}
        }
    }
    node_down(core, node);
}

enum Term {
    Rejected,
    Dropped,
    Failed,
}

fn resolve_error(core: &RouterCore, node: NodeId, req_id: u64, err: Error, term: Term) {
    let entry = {
        let mut st = core.state.lock().unwrap();
        match st.pending.remove(&req_id) {
            Some(e) => {
                st.resolved.record(req_id);
                st.table.release(node, e.class);
                match term {
                    Term::Rejected => st.stats.rejected += 1,
                    Term::Dropped => st.stats.dropped += 1,
                    Term::Failed => st.stats.failed += 1,
                }
                Some(e)
            }
            None => {
                if st.resolved.contains(req_id) {
                    st.stats.deduped += 1;
                } else {
                    st.stats.orphaned += 1;
                }
                None
            }
        }
    };
    if let Some(e) = entry {
        e.slot.fulfill(Err(err));
    }
}

/// Link-down handling: mark the node dead, fail its control waiters,
/// and re-home every frame it still owed.  Re-homed frames keep their
/// stamped `seq` and original submit time, so fleet output and latency
/// accounting stay comparable to an undisturbed run.
fn node_down(core: &Arc<RouterCore>, node: NodeId) {
    let (rehome, controls) = {
        let mut st = core.state.lock().unwrap();
        st.table.mark_dead(node);
        let ids: Vec<u64> = st
            .pending
            .iter()
            .filter(|(_, e)| e.node == node)
            .map(|(&id, _)| id)
            .collect();
        let rehome: Vec<PendingEntry> = ids
            .iter()
            .map(|id| {
                // The re-home supersedes this placement: a straggler
                // response under the old id dedups instead of orphaning.
                st.resolved.record(*id);
                st.pending.remove(id).unwrap()
            })
            .collect();
        let cids: Vec<u64> = st
            .control
            .iter()
            .filter(|(_, c)| c.node == node)
            .map(|(&id, _)| id)
            .collect();
        let controls: Vec<Arc<ControlSlot>> =
            cids.iter().map(|id| st.control.remove(id).unwrap()).collect();
        (rehome, controls)
    };
    for slot in controls {
        slot.fulfill(Err(Error::Serve(format!("fleet node {node} went down"))));
    }
    for mut entry in rehome {
        entry.attempts += 1;
        core.state.lock().unwrap().stats.rerouted += 1;
        if let Err((err, entry)) = route_and_send(core, entry) {
            dispose_unplaceable(core, entry, err);
        }
    }
}

/// A frame that could not be placed on any live node right now.  With
/// the recovery plane on (health tracker present) it is parked for the
/// next retransmit sweep — capacity frees up or a node rejoins, and
/// nothing is lost while the fleet lives.  Without it the legacy drill
/// semantics apply: the frame is lost and its ticket fails.
fn dispose_unplaceable(core: &RouterCore, entry: PendingEntry, err: Error) {
    let recovering = core.state.lock().unwrap().health.is_some();
    if recovering {
        park(core, entry);
    } else {
        let mut st = core.state.lock().unwrap();
        st.stats.lost[entry.class.index()] += 1;
        drop(st);
        entry.slot.fulfill(Err(err));
    }
}

/// Park a frame with no placement: it re-enters `pending` under a fresh
/// id with no owning node, so the next retransmit sweep re-routes it.
fn park(core: &RouterCore, mut entry: PendingEntry) {
    entry.node = NO_NODE;
    let req_id = core.req_id();
    core.state.lock().unwrap().pending.insert(req_id, entry);
}

/// The recovery pulse (runs only while `[faults]` is enabled): every
/// `probe_ms` it (1) pings every node — health-dead ones included, so a
/// pong is what proves a rejoin — (2) advances the health machine,
/// re-homing the frames of nodes that just went dead, and (3)
/// retransmits pending frames older than `retransmit_ms`.
fn monitor_loop(core: &Arc<RouterCore>, stop: &AtomicBool, cfg: &FaultsConfig) {
    let probe = Duration::from_millis(cfg.probe_ms.max(1));
    let retransmit_after = Duration::from_millis(cfg.retransmit_ms.max(1));
    while !stop.load(Ordering::Acquire) {
        for tx in &core.txs {
            // A closed link (killed node) just errors; ignored.
            let _ = tx.send(WireRequest::Ping { req_id: core.req_id() });
        }
        let died = {
            let mut st = core.state.lock().unwrap();
            match st.health.as_mut() {
                Some(h) => h.sweep(Instant::now()),
                None => Vec::new(),
            }
        };
        for node in died {
            node_down(core, node);
        }
        retransmit_stale(core, retransmit_after, cfg.degrade_after);
        std::thread::sleep(probe);
    }
}

/// Retransmit every pending frame silent past `after`: release the old
/// placement, record its request id as superseded (late responses dedup
/// instead of double-completing), and re-route under a fresh id.  A
/// Standard frame that keeps failing placement sheds to best-effort
/// after `degrade_after` attempts (graceful degradation); frames that
/// still cannot be placed are parked and swept again — never lost.
fn retransmit_stale(core: &Arc<RouterCore>, after: Duration, degrade_after: u64) {
    let now = Instant::now();
    let stale: Vec<PendingEntry> = {
        let mut st = core.state.lock().unwrap();
        let ids: Vec<u64> = st
            .pending
            .iter()
            .filter(|(_, e)| now.saturating_duration_since(e.last_sent) >= after)
            .map(|(&id, _)| id)
            .collect();
        let mut stale = Vec::with_capacity(ids.len());
        for id in ids {
            let e = st.pending.remove(&id).unwrap();
            st.resolved.record(id);
            st.table.release(e.node, e.class);
            st.stats.retries += 1;
            stale.push(e);
        }
        stale
    };
    for mut entry in stale {
        entry.attempts += 1;
        if let Err((_, mut entry)) = route_and_send(core, entry) {
            if entry.class == QosClass::Standard
                && degrade_after > 0
                && entry.attempts as u64 >= degrade_after
            {
                entry.class = QosClass::BestEffort;
                core.state.lock().unwrap().stats.degraded += 1;
                match route_and_send(core, entry) {
                    Ok(_) => continue,
                    Err((_, e)) => entry = e,
                }
            }
            park(core, entry);
        }
    }
}

// ---------------------------------------------------------------------------
// Fleet
// ---------------------------------------------------------------------------

struct NodeHandle {
    kill: Arc<AtomicBool>,
    service: Option<JoinHandle<()>>,
    collector: Option<JoinHandle<()>>,
}

/// N in-process serve nodes behind a rendezvous-hash router.  See the
/// module docs for the design; knobs live in `[fleet]`
/// ([`crate::config::FleetConfig`]).
pub struct Fleet {
    core: Arc<RouterCore>,
    handles: Vec<NodeHandle>,
    killed: Mutex<Vec<NodeId>>,
    seqs: Mutex<HashMap<u32, u64>>,
    config: FleetConfig,
    faults: FaultsConfig,
    /// Health/retransmit monitor; present only with `[faults]` enabled.
    monitor: Option<JoinHandle<()>>,
    monitor_stop: Arc<AtomicBool>,
}

impl Fleet {
    /// Start `config.system.fleet.nodes` serve nodes over the in-memory
    /// channel transport.
    pub fn start(params: NetParams, config: EngineConfig) -> Result<Fleet> {
        let depth: usize =
            config.system.fleet.capacity.iter().sum::<usize>() + 16;
        Fleet::start_with_transport(params, config,
                                    Box::new(ChannelTransport::new(depth)))
    }

    /// Start over a caller-supplied [`Transport`] — the seam where a
    /// real wire slots in.
    pub fn start_with_transport(params: NetParams, config: EngineConfig,
                                mut transport: Box<dyn Transport>)
                                -> Result<Fleet> {
        let fleet_cfg = config.system.fleet.clone();
        fleet_cfg.validate()?;
        let n = fleet_cfg.nodes;

        let mut links = Vec::with_capacity(n);
        let mut txs = Vec::with_capacity(n);
        for node in 0..n {
            let (router_link, node_link) = transport.connect(node);
            txs.push(Arc::clone(&router_link.tx));
            links.push((router_link.rx, node_link));
        }

        let faults_cfg = config.system.faults;
        let core = Arc::new(RouterCore {
            state: Mutex::new(RouterState {
                table: RoutingTable::new(n, fleet_cfg.capacity),
                pending: HashMap::new(),
                control: HashMap::new(),
                reports: vec![None; n],
                stats: FleetStats {
                    completed_by_node: vec![0; n],
                    ..FleetStats::default()
                },
                latencies_ns: Vec::new(),
                resolved: SeqLedger::new(),
                health: if faults_cfg.enabled {
                    Some(HealthTracker::new(
                        n,
                        Duration::from_millis(faults_cfg.suspect_ms),
                        Duration::from_millis(faults_cfg.dead_ms),
                    ))
                } else {
                    None
                },
            }),
            txs,
            next_req: AtomicU64::new(1),
        });

        let mut handles = Vec::with_capacity(n);
        for (node, (router_rx, node_link)) in links.into_iter().enumerate() {
            let mut node_config = config.clone();
            // Each node gets its own trace feed: feed.jsonl ->
            // feed-node<i>.jsonl (merged back by `ns-lbp trace A B C`).
            if node_config.system.obs.enabled {
                node_config.system.obs.jsonl_path =
                    node_feed_path(&config.system.obs.jsonl_path, node);
            }
            let server = crate::serve::Server::start(params.clone(), node_config)?;
            let kill = Arc::new(AtomicBool::new(false));
            let service = {
                let kill = Arc::clone(&kill);
                std::thread::Builder::new()
                    .name(format!("fleet-node-{node}"))
                    .spawn(move || node::run(node, server, node_link, kill))
                    .map_err(|e| Error::Serve(format!("spawn node {node}: {e}")))?
            };
            let collector = {
                let core = Arc::clone(&core);
                std::thread::Builder::new()
                    .name(format!("fleet-collect-{node}"))
                    .spawn(move || collect(&core, node, router_rx))
                    .map_err(|e| Error::Serve(format!("spawn collector {node}: {e}")))?
            };
            handles.push(NodeHandle {
                kill,
                service: Some(service),
                collector: Some(collector),
            });
        }

        let monitor_stop = Arc::new(AtomicBool::new(false));
        let monitor = if faults_cfg.enabled {
            let core = Arc::clone(&core);
            let stop = Arc::clone(&monitor_stop);
            Some(
                std::thread::Builder::new()
                    .name("fleet-monitor".into())
                    .spawn(move || monitor_loop(&core, &stop, &faults_cfg))
                    .map_err(|e| Error::Serve(format!("spawn fleet monitor: {e}")))?,
            )
        } else {
            None
        };

        Ok(Fleet {
            core,
            handles,
            killed: Mutex::new(Vec::new()),
            seqs: Mutex::new(HashMap::new()),
            config: fleet_cfg,
            faults: faults_cfg,
            monitor,
            monitor_stop,
        })
    }

    pub fn nodes(&self) -> usize {
        self.handles.len()
    }

    pub fn config(&self) -> &FleetConfig {
        &self.config
    }

    /// Nodes currently accepting traffic.
    pub fn live_nodes(&self) -> Vec<NodeId> {
        self.core.state.lock().unwrap().table.live_nodes()
    }

    /// The live node that owns `sensor_id` under rendezvous hashing.
    pub fn owner_of(&self, sensor_id: u32) -> Option<NodeId> {
        let live = self.live_nodes();
        rendezvous_owner(sensor_id, &live)
    }

    /// Open a session for one sensor stream: stamps the per-sensor
    /// sequence number on each submitted frame (the fleet owns the seq
    /// space so re-homed frames keep their place in the stream).
    pub fn session(&self, sensor_id: u32) -> FleetSession<'_> {
        FleetSession {
            fleet: self,
            sensor_id,
            class: QosClass::default(),
            model_id: 0,
        }
    }

    /// Submit a frame whose `seq` the caller already stamped.  Admission
    /// walks the sensor's rendezvous ranking; `Err(Error::Serve)` means
    /// every live node is at capacity for `class` (retryable).
    pub fn submit_stamped(&self, sensor_id: u32, class: QosClass, model_id: u32,
                          frame: Frame) -> Result<FleetTicket> {
        let slot = Arc::new(FleetSlot::new());
        let now = Instant::now();
        let entry = PendingEntry {
            sensor_id,
            class,
            model_id,
            frame,
            node: 0,
            attempts: 0,
            submitted: now,
            last_sent: now,
            slot: Arc::clone(&slot),
        };
        match route_and_send(&self.core, entry) {
            Ok(_) => {
                self.core.state.lock().unwrap().stats.submitted += 1;
                Ok(FleetTicket { slot })
            }
            Err((err, _entry)) => {
                self.core.state.lock().unwrap().stats.rejected += 1;
                Err(err)
            }
        }
    }

    /// Kill `node` without drain (failure drill): the node drops its
    /// server on the spot and severs its link; the router re-homes its
    /// in-flight frames to the next-ranked live nodes.
    pub fn kill_node(&self, node: NodeId) -> Result<()> {
        if node >= self.handles.len() {
            return Err(Error::Usage(format!(
                "fleet kill: node {node} out of range (fleet has {})",
                self.handles.len()
            )));
        }
        self.handles[node].kill.store(true, Ordering::Release);
        // Stop feeding it; in-flight responses still drain off the link.
        self.core.txs[node].close();
        {
            let mut st = self.core.state.lock().unwrap();
            let st = &mut *st;
            st.table.mark_dead(node);
            // An operator kill is permanent — no health rejoin.
            if let Some(h) = st.health.as_mut() {
                h.mark_killed(node);
            }
        }
        let mut killed = self.killed.lock().unwrap();
        if !killed.contains(&node) {
            killed.push(node);
        }
        Ok(())
    }

    /// Roll `model` (as `model_id`) through the fleet node-by-node:
    /// serialize the artifact once, push it to each live node over the
    /// wire, and wait for that node's version ack before moving on.
    /// Returns the per-node acks `(node, version)`; every version is the
    /// artifact's content hash, so convergence means all acks agree.
    /// Nodes that die mid-roll are skipped (the drill path).
    pub fn push_model(&self, model_id: u32, model: &CompiledModel)
                      -> Result<Vec<(NodeId, u64)>> {
        let mut stamped = model.clone();
        let artifact = Arc::new(stamped.to_bytes());
        let version = stamped.version;
        let live = self.live_nodes();
        let mut acks = Vec::with_capacity(live.len());
        let policy = crate::faults::RetryPolicy::control();
        let mut rng = crate::rng::Xoshiro256::new(self.faults.seed ^ 0x9b75);
        'nodes: for node in live {
            for attempt in 0..=policy.budget {
                // Chaos artifact fault: the plan may flip one byte of
                // *this attempt's* copy in transit; the node's checksum
                // rejects it and the retry redraws (fresh attempt index).
                let payload = match crate::faults::artifact_corruption(
                    &self.faults, node, attempt as u64, artifact.len(),
                ) {
                    Some(pos) => {
                        let mut bytes = (*artifact).clone();
                        bytes[pos] ^= 0x40;
                        Arc::new(bytes)
                    }
                    None => Arc::clone(&artifact),
                };
                let req_id = self.core.req_id();
                let slot = Arc::new(ControlSlot::new(node));
                self.core
                    .state
                    .lock()
                    .unwrap()
                    .control
                    .insert(req_id, Arc::clone(&slot));
                let msg = WireRequest::PushModel { req_id, model_id, artifact: payload };
                if self.core.txs[node].send(msg).is_err() {
                    self.core.state.lock().unwrap().control.remove(&req_id);
                    continue 'nodes;
                }
                match slot.wait(CONTROL_TIMEOUT) {
                    Some(Ok(ControlAck::Pushed { version: acked })) => {
                        if acked != version {
                            return Err(Error::Serve(format!(
                                "fleet push_model: node {node} acked version \
                                 {acked:016x}, expected {version:016x}"
                            )));
                        }
                        acks.push((node, acked));
                        continue 'nodes;
                    }
                    Some(Ok(ControlAck::Drained)) => unreachable!("push acked as drain"),
                    Some(Err(Error::Serve(e))) if e.contains("went down") => {
                        continue 'nodes;
                    }
                    Some(Err(Error::Serve(e))) => {
                        // PushFailed (bad bytes, checksum): retryable.
                        if attempt >= policy.budget {
                            return Err(Error::Serve(format!(
                                "fleet push_model: node {node} refused the \
                                 artifact after {attempt} retries: {e}"
                            )));
                        }
                        self.core.state.lock().unwrap().stats.retries += 1;
                        std::thread::sleep(policy.backoff(attempt, &mut rng));
                    }
                    Some(Err(e)) => return Err(e),
                    None => {
                        return Err(Error::Serve(format!(
                            "fleet push_model: node {node} ack timed out"
                        )))
                    }
                }
            }
        }
        if acks.is_empty() {
            return Err(Error::Serve(
                "fleet push_model: no live node acked the artifact".into(),
            ));
        }
        Ok(acks)
    }

    /// Graceful shutdown: drain every live node (each finishes its
    /// in-flight frames, then reports), join the node threads, and fold
    /// everything into a [`FleetReport`].
    pub fn drain(mut self) -> Result<FleetReport> {
        // Stop the recovery pulse first: no health death or retransmit
        // may race the drain handshake.
        self.monitor_stop.store(true, Ordering::Release);
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
        let live = self.live_nodes();
        let mut waits = Vec::with_capacity(live.len());
        for &node in &live {
            let req_id = self.core.req_id();
            let slot = Arc::new(ControlSlot::new(node));
            self.core
                .state
                .lock()
                .unwrap()
                .control
                .insert(req_id, Arc::clone(&slot));
            if self.core.txs[node].send(WireRequest::Drain { req_id }).is_err() {
                self.core.state.lock().unwrap().control.remove(&req_id);
                continue;
            }
            waits.push(slot);
        }
        for slot in waits {
            // A node dying mid-drain surfaces as Err here; the report
            // simply lacks its MetricsReport.
            let _ = slot.wait(CONTROL_TIMEOUT);
        }
        for (node, handle) in self.handles.iter_mut().enumerate() {
            self.core.txs[node].close();
            if let Some(h) = handle.service.take() {
                let _ = h.join();
            }
            if let Some(h) = handle.collector.take() {
                let _ = h.join();
            }
        }

        let killed = std::mem::take(&mut *self.killed.lock().unwrap());
        let mut st = self.core.state.lock().unwrap();
        let stats = st.stats.clone();
        let reports = std::mem::take(&mut st.reports);
        let mut lat = std::mem::take(&mut st.latencies_ns);
        let (health_suspect, health_dead, health_rejoined) = st
            .health
            .as_ref()
            .map(|h| (h.to_suspect, h.to_dead, h.rejoined))
            .unwrap_or((0, 0, 0));
        drop(st);
        lat.sort_unstable();
        let ms = |q: f64| percentile_ns(&lat, q) as f64 / 1e6;
        Ok(FleetReport {
            nodes: self.handles.len(),
            killed,
            live,
            submitted: stats.submitted,
            completed: stats.completed,
            completed_by_class: stats.completed_by_class,
            completed_by_node: stats.completed_by_node,
            rejected: stats.rejected,
            dropped: stats.dropped,
            failed: stats.failed,
            rerouted: stats.rerouted,
            spilled: stats.spilled,
            lost: stats.lost,
            orphaned: stats.orphaned,
            deduped: stats.deduped,
            retries: stats.retries,
            degraded: stats.degraded,
            health_suspect,
            health_dead,
            health_rejoined,
            p50_ms: ms(0.50),
            p95_ms: ms(0.95),
            p99_ms: ms(0.99),
            max_ms: lat.last().copied().unwrap_or(0) as f64 / 1e6,
            node_reports: reports,
        })
    }

    fn next_seq(&self, sensor_id: u32) -> u64 {
        let mut seqs = self.seqs.lock().unwrap();
        let seq = seqs.entry(sensor_id).or_insert(0);
        let s = *seq;
        *seq += 1;
        s
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        // Ungraceful teardown (e.g. a test bailed): sever every link so
        // node loops and collectors exit instead of leaking.
        self.monitor_stop.store(true, Ordering::Release);
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
        for (node, handle) in self.handles.iter_mut().enumerate() {
            handle.kill.store(true, Ordering::Release);
            self.core.txs[node].close();
            if let Some(h) = handle.service.take() {
                let _ = h.join();
            }
            if let Some(h) = handle.collector.take() {
                let _ = h.join();
            }
        }
    }
}

const CONTROL_TIMEOUT: Duration = Duration::from_secs(60);

/// Per-node trace feed path: `feed.jsonl` → `feed-node<i>.jsonl`.
pub fn node_feed_path(base: &str, node: NodeId) -> String {
    match base.strip_suffix(".jsonl") {
        Some(stem) => format!("{stem}-node{node}.jsonl"),
        None => format!("{base}-node{node}"),
    }
}

/// Per-sensor submission handle (mirrors [`crate::serve::Session`]).
pub struct FleetSession<'f> {
    fleet: &'f Fleet,
    sensor_id: u32,
    class: QosClass,
    model_id: u32,
}

impl FleetSession<'_> {
    pub fn with_class(mut self, class: QosClass) -> Self {
        self.class = class;
        self
    }

    pub fn with_model(mut self, model_id: u32) -> Self {
        self.model_id = model_id;
        self
    }

    pub fn sensor_id(&self) -> u32 {
        self.sensor_id
    }

    /// Stamp the next per-sensor `seq` and submit.
    pub fn submit(&self, frame: Frame) -> Result<FleetTicket> {
        let seq = self.fleet.next_seq(self.sensor_id);
        self.fleet
            .submit_stamped(self.sensor_id, self.class, self.model_id,
                            frame.with_seq(seq))
    }
}

// ---------------------------------------------------------------------------
// Fleet report
// ---------------------------------------------------------------------------

/// The fleet-level rollup: router-side counters + per-node
/// [`MetricsReport`]s (`None` for killed nodes — they died without
/// drain).
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub nodes: usize,
    /// Nodes killed by drills, in kill order.
    pub killed: Vec<NodeId>,
    /// Nodes that were alive at drain.
    pub live: Vec<NodeId>,
    pub submitted: u64,
    pub completed: u64,
    pub completed_by_class: [u64; QosClass::COUNT],
    /// Completions credited to the node that served them (a re-homed
    /// frame credits its final node).
    pub completed_by_node: Vec<u64>,
    pub rejected: u64,
    pub dropped: u64,
    pub failed: u64,
    /// Frames re-homed after a node death.
    pub rerouted: u64,
    /// Admissions that spilled past the sensor's rendezvous owner.
    pub spilled: u64,
    /// Frames lost per class (no live node left to serve them).
    pub lost: [u64; QosClass::COUNT],
    pub orphaned: u64,
    /// Late/duplicate responses absorbed by the resolved ledger
    /// (exactly-once under wire duplication and retransmits).
    pub deduped: u64,
    /// Monitor retransmits of silent frames.
    pub retries: u64,
    /// Standard frames shed to best-effort routing under fault pressure.
    pub degraded: u64,
    /// Health machine transitions observed (alive→suspect, →dead,
    /// dead→alive).
    pub health_suspect: u64,
    pub health_dead: u64,
    pub health_rejoined: u64,
    /// Router-observed end-to-end latency percentiles (spanning
    /// re-homes).
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub node_reports: Vec<Option<MetricsReport>>,
}

impl FleetReport {
    /// Billed frames lost — the drill invariant that must stay zero.
    pub fn billed_lost(&self) -> u64 {
        self.lost[QosClass::Billed.index()]
    }

    pub fn completed_for(&self, class: QosClass) -> u64 {
        self.completed_by_class[class.index()]
    }

    /// Single-document JSON (same spirit as
    /// [`MetricsReport::to_json`], with a per-node breakdown).
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(2048);
        out.push('{');
        j::push_u64_field(&mut out, "nodes", self.nodes as u64);
        out.push_str("\"killed\":[");
        for (i, n) in self.killed.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&n.to_string());
        }
        out.push_str("],");
        j::push_u64_field(&mut out, "submitted", self.submitted);
        j::push_u64_field(&mut out, "completed", self.completed);
        j::push_u64_field(&mut out, "rejected", self.rejected);
        j::push_u64_field(&mut out, "dropped", self.dropped);
        j::push_u64_field(&mut out, "failed", self.failed);
        j::push_u64_field(&mut out, "rerouted", self.rerouted);
        j::push_u64_field(&mut out, "spilled", self.spilled);
        j::push_u64_field(&mut out, "orphaned", self.orphaned);
        j::push_u64_field(&mut out, "deduped", self.deduped);
        j::push_u64_field(&mut out, "retries", self.retries);
        j::push_u64_field(&mut out, "degraded", self.degraded);
        out.push_str("\"health\":{");
        j::push_u64_field(&mut out, "suspect", self.health_suspect);
        j::push_u64_field(&mut out, "dead", self.health_dead);
        j::push_u64_field(&mut out, "rejoined", self.health_rejoined);
        out.pop();
        out.push_str("},");
        j::push_u64_field(&mut out, "billed_lost", self.billed_lost());
        out.push_str("\"completed_by_class\":{");
        for class in QosClass::ALL {
            j::push_u64_field(&mut out, class.as_str(),
                              self.completed_by_class[class.index()]);
        }
        out.pop();
        out.push_str("},");
        out.push_str("\"lost_by_class\":{");
        for class in QosClass::ALL {
            j::push_u64_field(&mut out, class.as_str(), self.lost[class.index()]);
        }
        out.pop();
        out.push_str("},");
        out.push_str("\"latency_ms\":{");
        j::push_f64_field(&mut out, "p50", self.p50_ms);
        j::push_f64_field(&mut out, "p95", self.p95_ms);
        j::push_f64_field(&mut out, "p99", self.p99_ms);
        j::push_f64_field(&mut out, "max", self.max_ms);
        out.pop();
        out.push_str("},");
        out.push_str("\"per_node\":[");
        for node in 0..self.nodes {
            if node > 0 {
                out.push(',');
            }
            out.push('{');
            j::push_u64_field(&mut out, "node", node as u64);
            out.push_str("\"killed\":");
            out.push_str(if self.killed.contains(&node) { "true," } else { "false," });
            j::push_u64_field(&mut out, "completed_routed",
                              self.completed_by_node[node]);
            out.push_str("\"report\":");
            match &self.node_reports[node] {
                Some(r) => out.push_str(&r.to_json()),
                None => out.push_str("null"),
            }
            out.push('}');
        }
        out.push_str("]}");
        out
    }

    /// Human-readable rollup.
    pub fn print(&self, label: &str) {
        println!("== fleet report: {label} ==");
        println!(
            "  nodes {} (killed {:?})  submitted {}  completed {}  \
             rejected {}  dropped {}  failed {}",
            self.nodes, self.killed, self.submitted, self.completed,
            self.rejected, self.dropped, self.failed
        );
        println!(
            "  rerouted {}  spilled {}  billed lost {}  \
             e2e p50/p95/p99/max {:.3}/{:.3}/{:.3}/{:.3} ms",
            self.rerouted, self.spilled, self.billed_lost(),
            self.p50_ms, self.p95_ms, self.p99_ms, self.max_ms
        );
        if self.retries + self.deduped + self.degraded + self.health_dead
            + self.health_rejoined
            > 0
        {
            println!(
                "  recovery: retries {}  deduped {}  degraded {}  \
                 health suspect/dead/rejoined {}/{}/{}",
                self.retries, self.deduped, self.degraded,
                self.health_suspect, self.health_dead, self.health_rejoined
            );
        }
        for node in 0..self.nodes {
            match &self.node_reports[node] {
                Some(r) => println!(
                    "  node {node}: routed {}  accepted {}  completed {}  \
                     p99 {:.3} ms",
                    self.completed_by_node[node], r.accepted, r.completed,
                    r.p99_ms
                ),
                None => println!(
                    "  node {node}: routed {}  (killed — no drain report)",
                    self.completed_by_node[node]
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ArchSim;
    use crate::params::synth::synth_params;
    use crate::serve::{Request, Server};

    fn test_config(nodes: usize) -> EngineConfig {
        let mut config = EngineConfig {
            arch: ArchSim { lbp: false, mlp: false, early_exit: false },
            ..Default::default()
        };
        config.system.serve.shards = 1;
        config.system.serve.max_batch = 4;
        config.system.serve.batch_deadline_us = 500;
        config.system.fleet.nodes = nodes;
        config
    }

    fn synth(n: usize, seed: u64) -> (NetParams, Vec<Frame>) {
        let (_, params) = synth_params(5);
        let frames = crate::testing::synth_frames(&params, n, seed).unwrap();
        (params, frames)
    }

    #[test]
    fn fleet_round_trip_matches_single_server() {
        let (params, frames) = synth(12, 9);
        let fleet = Fleet::start(params.clone(), test_config(3)).unwrap();
        let sensors: Vec<u32> = (0..4).collect();
        let mut tickets = Vec::new();
        for (i, frame) in frames.iter().enumerate() {
            let sensor = sensors[i % sensors.len()];
            let session = fleet.session(sensor).with_class(QosClass::Billed);
            tickets.push((sensor, session.submit(frame.clone()).unwrap()));
        }
        let mut fleet_logits: HashMap<(u32, u64), Vec<f32>> = HashMap::new();
        for (sensor, ticket) in tickets {
            let resp = ticket.wait().unwrap();
            fleet_logits.insert((sensor, resp.seq()), resp.inner.report.logits);
        }
        let report = fleet.drain().unwrap();
        assert_eq!(report.completed, 12);
        assert_eq!(report.billed_lost(), 0);
        assert_eq!(report.orphaned, 0);
        assert_eq!(
            report.completed_by_node.iter().sum::<u64>(),
            report.completed
        );

        // Same frames through one serve::Server: logits must be
        // bit-identical (placement never changes the math).
        let server = Server::start(params, test_config(1)).unwrap();
        let mut seqs: HashMap<u32, u64> = HashMap::new();
        let mut single = Vec::new();
        for (i, frame) in frames.iter().enumerate() {
            let sensor = sensors[i % sensors.len()];
            let seq = seqs.entry(sensor).or_insert(0);
            let request = Request::builder(frame.clone().with_seq(*seq))
                .sensor_id(sensor)
                .class(QosClass::Billed)
                .build();
            *seq += 1;
            single.push((sensor, server.submit(request).unwrap()));
        }
        for (sensor, ticket) in single {
            let resp = ticket.wait().unwrap();
            let fleet_l = &fleet_logits[&(sensor, resp.seq())];
            assert_eq!(fleet_l, &resp.report.logits,
                       "sensor {sensor} seq {} diverged", resp.seq());
        }
        server.drain().unwrap();
    }

    #[test]
    fn push_model_rolls_all_nodes_to_same_version() {
        let (params, frames) = synth(4, 11);
        let config = test_config(2);
        let fleet = Fleet::start(params, config.clone()).unwrap();
        let spec = crate::compile::ModelSpec::parse(
            "[model]\nname = \"alt\"\nseed = 7\n",
            std::path::Path::new("."),
        )
        .unwrap();
        let model = crate::compile::build_model(&spec, &config.system).unwrap();
        let acks = fleet.push_model(1, &model).unwrap();
        assert_eq!(acks.len(), 2);
        assert!(acks.iter().all(|&(_, v)| v == acks[0].1 && v != 0), "{acks:?}");
        // The rolled model serves traffic on every node.
        let mut tickets = Vec::new();
        for (i, frame) in frames.iter().enumerate() {
            let session = fleet.session(i as u32).with_model(1);
            tickets.push(session.submit(frame.clone()).unwrap());
        }
        for ticket in tickets {
            let resp = ticket.wait().unwrap();
            assert_eq!(resp.inner.model_id, 1);
        }
        let report = fleet.drain().unwrap();
        assert_eq!(report.completed, 4);
    }

    #[test]
    fn capacity_rejection_is_retryable_serve_error() {
        let (params, frames) = synth(1, 13);
        let mut config = test_config(1);
        config.system.fleet.capacity = [1, 1, 1];
        // A slow batcher keeps the first frame in flight while we probe.
        config.system.serve.max_batch = 8;
        config.system.serve.batch_deadline_us = 50_000;
        let fleet = Fleet::start(params, config).unwrap();
        let session = fleet.session(3);
        let first = session.submit(frames[0].clone()).unwrap();
        let second = session.submit(frames[0].clone());
        assert!(matches!(second, Err(Error::Serve(_))), "{second:?}");
        first.wait().unwrap();
        let report = fleet.drain().unwrap();
        assert_eq!(report.completed, 1);
        assert_eq!(report.rejected, 1);
    }

    #[test]
    fn node_feed_paths_are_distinct() {
        assert_eq!(node_feed_path("feed.jsonl", 0), "feed-node0.jsonl");
        assert_eq!(node_feed_path("feed.jsonl", 2), "feed-node2.jsonl");
        assert_eq!(node_feed_path("feed", 1), "feed-node1");
    }
}
