//! `fleet::node` — the per-node service loop.
//!
//! Each fleet node owns a full [`serve::Server`] (admission queues,
//! batchers, bank-sliced shard pool, metrics, trace feed) and speaks to
//! the router exclusively through its [`NodeLink`].  The node is
//! serve-plane-agnostic: with `[serve.async] enabled = true` in the
//! fleet's system config, every node hosts the event-driven plane
//! ([`crate::serve::async_plane`]) — DRR sensor fairness and shard
//! autoscaling per node — behind the same `Server` submit/ticket/drain
//! surface, so nothing in this loop or the router changes.  The loop is
//! single-threaded and never blocks indefinitely: it alternates between
//! polling completion tickets (forwarding each as a
//! [`WireResponse::Completed`]) and polling the request link, sleeping
//! briefly when both are idle.
//!
//! Shutdown paths:
//! * **Drain** (graceful): stop consuming requests, resolve every
//!   pending ticket, then `Server::drain` and report
//!   [`WireResponse::Drained`].
//! * **Kill** (drill / crash): the kill flag drops the server on the
//!   spot — no drain, pending tickets abandoned — and closes the
//!   response link.  The router sees link-down and re-homes whatever
//!   this node still owed (see [`crate::fleet`]).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::Error;
use crate::serve::{Request, Server, Ticket};

use super::transport::{NodeId, NodeLink, TryRecv, WireRequest, WireResponse};

/// How long the loop sleeps when no ticket resolved and no request
/// arrived.  Low enough to keep node-local latency well under a batch
/// deadline, high enough not to spin.
const IDLE_POLL: Duration = Duration::from_micros(100);

/// Upper bound on waiting for in-flight tickets during a drain.  A
/// ticket can dangle forever if its shard died mid-dispatch (an injected
/// panic whose batch was already claimed); past this bound the node
/// fails the stragglers and drains anyway, instead of wedging the fleet
/// against the router's much larger control timeout.
const DRAIN_DEADLINE: Duration = Duration::from_secs(5);

/// Run one node until drain, kill, or router disconnect.  `kill` is the
/// drill switch: once set, the server is dropped without drain.
pub(crate) fn run(id: NodeId, server: Server, link: NodeLink, kill: Arc<AtomicBool>) {
    let mut server = Some(server);
    let mut pending: Vec<(u64, Ticket)> = Vec::new();
    let mut draining: Option<(u64, Instant)> = None;

    loop {
        if kill.load(Ordering::Acquire) {
            // Simulated crash: abandon in-flight work, sever the link.
            drop(server.take());
            link.tx.close();
            return;
        }

        let mut progressed = poll_tickets(&mut pending, &link);

        if let Some((drain_req, since)) = draining {
            if pending.is_empty() {
                finish_drain(id, drain_req, server.take(), &link);
                return;
            }
            if since.elapsed() >= DRAIN_DEADLINE {
                for (req_id, _) in pending.drain(..) {
                    let _ = link.tx.send(WireResponse::Failed {
                        req_id,
                        error: "node drain deadline: ticket never resolved".into(),
                    });
                }
                finish_drain(id, drain_req, server.take(), &link);
                return;
            }
            if !progressed {
                std::thread::sleep(IDLE_POLL);
            }
            continue;
        }

        match link.rx.try_recv() {
            TryRecv::Msg(msg) => {
                progressed = true;
                match msg {
                    WireRequest::Submit { req_id, sensor_id, class, model_id, frame } => {
                        let request = Request::builder(frame)
                            .sensor_id(sensor_id)
                            .class(class)
                            .model(model_id)
                            .build();
                        match server.as_ref().expect("server live").submit(request) {
                            Ok(ticket) => pending.push((req_id, ticket)),
                            Err(e) => {
                                let _ = link.tx.send(WireResponse::Rejected {
                                    req_id,
                                    error: e.to_string(),
                                });
                            }
                        }
                    }
                    WireRequest::PushModel { req_id, model_id, artifact } => {
                        let resp = push_model(server.as_ref().expect("server live"),
                                              model_id, &artifact, req_id);
                        let _ = link.tx.send(resp);
                    }
                    WireRequest::Drain { req_id } => {
                        draining = Some((req_id, Instant::now()));
                    }
                    WireRequest::Ping { req_id } => {
                        let _ = link.tx.send(WireResponse::Pong { req_id });
                    }
                }
            }
            TryRecv::Empty => {}
            TryRecv::Closed => {
                // Router went away without a drain: resolve what we owe,
                // then fall down without a report.
                if pending.is_empty() {
                    drop(server.take());
                    link.tx.close();
                    return;
                }
            }
        }

        if !progressed {
            std::thread::sleep(IDLE_POLL);
        }
    }
}

/// Forward every resolved ticket; returns whether anything resolved.
fn poll_tickets(pending: &mut Vec<(u64, Ticket)>, link: &NodeLink) -> bool {
    let before = pending.len();
    pending.retain(|(req_id, ticket)| match ticket.try_take() {
        None => true,
        Some(result) => {
            let resp = match result {
                Ok(response) => WireResponse::Completed { req_id: *req_id, response },
                Err(Error::Dropped(e)) => {
                    WireResponse::Dropped { req_id: *req_id, error: e }
                }
                Err(e) => WireResponse::Failed { req_id: *req_id, error: e.to_string() },
            };
            let _ = link.tx.send(resp);
            false
        }
    });
    pending.len() != before
}

fn push_model(server: &Server, model_id: u32, artifact: &[u8], req_id: u64)
              -> WireResponse {
    match crate::compile::CompiledModel::from_bytes(artifact) {
        Ok(model) => match server.push_model(model_id, &model) {
            Ok(()) => WireResponse::ModelPushed {
                req_id,
                model_id,
                version: model.version,
            },
            Err(e) => WireResponse::PushFailed { req_id, error: e.to_string() },
        },
        Err(e) => WireResponse::PushFailed { req_id, error: e.to_string() },
    }
}

fn finish_drain(_id: NodeId, drain_req: u64, server: Option<Server>, link: &NodeLink) {
    match server.expect("server live").drain() {
        Ok(report) => {
            let _ = link.tx.send(WireResponse::Drained {
                req_id: drain_req,
                report: Box::new(report),
            });
        }
        Err(e) => {
            let _ = link.tx.send(WireResponse::Failed {
                req_id: drain_req,
                error: e.to_string(),
            });
        }
    }
    link.tx.close();
}
