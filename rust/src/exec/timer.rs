//! Hashed timer wheel — the executor's deadline primitive.
//!
//! Timers drive two things in the serve plane: per-class batch
//! *deadline flushes* ("ship the forming batch once the oldest member
//! is `deadline_us` stale") and the autoscaler's periodic load
//! sampling.  Both want many cheap, coarse timers, which is exactly the
//! hashed-wheel trade-off: O(1) insert into `slot = tick mod wheel_len`
//! and amortized O(1) expiry by walking only the slots the clock
//! actually crossed, at the cost of `tick` granularity (timers never
//! fire *early*, but may fire up to one tick late — fine against
//! millisecond-scale batching deadlines).
//!
//! The wheel is a passive data structure (no thread of its own); the
//! executor's timer thread drives it via [`TimerWheel::collect_due`] /
//! [`TimerWheel::next_deadline`] under the executor's timer lock.

use std::time::{Duration, Instant};

/// One armed timer: fire the task `id` at (or just after) `at`.
#[derive(Clone, Copy, Debug)]
struct Entry {
    at: Instant,
    id: usize,
}

/// Fixed-size hashed timer wheel over absolute [`Instant`] deadlines.
///
/// Entries hash into `wheel_len` slots by their deadline's tick index;
/// entries more than one wheel revolution out simply stay in their slot
/// across scans (they are retained by timestamp, not position), so the
/// wheel never needs cascading levels for the serve plane's deadline
/// range.
#[derive(Debug)]
pub struct TimerWheel {
    slots: Vec<Vec<Entry>>,
    tick: Duration,
    origin: Instant,
    /// Last tick fully scanned by [`TimerWheel::collect_due`].
    cursor: u64,
    len: usize,
}

impl TimerWheel {
    /// A wheel of `slots` buckets at `tick` granularity (both clamped to
    /// sane minimums: a zero tick would divide by zero, a single slot
    /// still works but degrades to a scan).
    pub fn new(tick: Duration, slots: usize) -> Self {
        let tick = tick.max(Duration::from_micros(1));
        Self {
            slots: (0..slots.max(1)).map(|_| Vec::new()).collect(),
            tick,
            origin: Instant::now(),
            cursor: 0,
            len: 0,
        }
    }

    /// Armed timer count.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn tick_of(&self, at: Instant) -> u64 {
        let ns = at.saturating_duration_since(self.origin).as_nanos();
        (ns / self.tick.as_nanos().max(1)) as u64
    }

    /// Arm a timer for task `id` at `at`.  Returns `true` when this
    /// deadline is now the wheel's earliest — the caller's cue to kick
    /// the timer thread out of its current (longer) sleep.
    pub fn insert(&mut self, at: Instant, id: usize) -> bool {
        let earliest = self.next_deadline().map_or(true, |d| at < d);
        // overdue (or current-tick) deadlines land in the cursor slot,
        // which every collect_due scan covers — nothing can be missed
        let tick = self.tick_of(at).max(self.cursor);
        let slot = (tick % self.slots.len() as u64) as usize;
        self.slots[slot].push(Entry { at, id });
        self.len += 1;
        earliest
    }

    /// Drain every timer with `at <= now` into `out`, advancing the
    /// cursor.  Only the slots between the previous cursor and `now`'s
    /// tick are touched (all of them at most once per call).
    pub fn collect_due(&mut self, now: Instant, out: &mut Vec<usize>) {
        let now_tick = self.tick_of(now).max(self.cursor);
        if self.len == 0 {
            self.cursor = now_tick;
            return;
        }
        let n = self.slots.len() as u64;
        // inclusive scan of [cursor, now_tick]: the cursor slot is
        // rescanned because overdue inserts are clamped into it
        let span = (now_tick - self.cursor).min(n - 1);
        for t in self.cursor..=self.cursor + span {
            let slot = (t % n) as usize;
            let bucket = &mut self.slots[slot];
            let mut i = 0;
            while i < bucket.len() {
                if bucket[i].at <= now {
                    out.push(bucket.swap_remove(i).id);
                    self.len -= 1;
                } else {
                    i += 1;
                }
            }
        }
        self.cursor = now_tick;
    }

    /// The earliest armed deadline (a full scan — the serve plane keeps
    /// at most a handful of timers armed, so this stays cheap).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.slots
            .iter()
            .flat_map(|s| s.iter().map(|e| e.at))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn due_timers_fire_in_any_order_but_completely() {
        let mut w = TimerWheel::new(Duration::from_micros(100), 8);
        let t0 = Instant::now();
        for id in 0..20 {
            w.insert(t0 + Duration::from_micros(50 * id as u64), id);
        }
        assert_eq!(w.len(), 20);
        let mut due = Vec::new();
        w.collect_due(t0 + Duration::from_millis(2), &mut due);
        due.sort_unstable();
        assert_eq!(due, (0..20).collect::<Vec<_>>());
        assert!(w.is_empty());
    }

    #[test]
    fn future_timers_survive_scans_and_never_fire_early() {
        let mut w = TimerWheel::new(Duration::from_micros(100), 8);
        let t0 = Instant::now();
        let late = t0 + Duration::from_secs(3600);
        w.insert(late, 7);
        // a deadline many revolutions out shares a slot with near ones
        w.insert(t0 + Duration::from_micros(150), 1);
        let mut due = Vec::new();
        w.collect_due(t0 + Duration::from_millis(1), &mut due);
        assert_eq!(due, vec![1]);
        assert_eq!(w.len(), 1);
        assert_eq!(w.next_deadline(), Some(late));
        // repeated scans walk past it without firing
        for ms in 2..50 {
            due.clear();
            w.collect_due(t0 + Duration::from_millis(ms), &mut due);
            assert!(due.is_empty(), "fired {due:?} early at +{ms} ms");
        }
        assert_eq!(w.len(), 1);
    }

    #[test]
    fn overdue_insert_fires_on_next_scan() {
        let mut w = TimerWheel::new(Duration::from_micros(100), 16);
        let t0 = Instant::now();
        let mut due = Vec::new();
        // advance the cursor well past the origin first
        w.collect_due(t0 + Duration::from_millis(10), &mut due);
        // an already-expired deadline must still be collected
        assert!(w.insert(t0, 3));
        due.clear();
        w.collect_due(t0 + Duration::from_millis(10), &mut due);
        assert_eq!(due, vec![3]);
    }

    #[test]
    fn insert_reports_new_earliest_deadline() {
        let mut w = TimerWheel::new(Duration::from_micros(100), 8);
        let t0 = Instant::now();
        assert!(w.insert(t0 + Duration::from_millis(10), 0));
        assert!(!w.insert(t0 + Duration::from_millis(20), 1));
        assert!(w.insert(t0 + Duration::from_millis(5), 2));
    }
}
