//! Event sources: what a pending task waits *on*.
//!
//! The executor itself only knows "poll tasks that were woken"; these
//! primitives are the other half of the contract — a place to park a
//! [`Waker`] and a producer-side call that trips it.  [`Notify`] is the
//! bare readiness cell; [`ExecQueue`] is the channel-shaped source the
//! serve plane multiplexes on.  Both implement [`EventSource`], the
//! seam an epoll-backed reactor can later slot into: an fd source would
//! `register` the same way and wake from the reactor thread instead of
//! from a producer.

use std::collections::VecDeque;
use std::sync::Mutex;

use super::Waker;

/// Anything a task can register wait-interest on.  Implementors must
/// wake every registered waker when they become ready, and must tolerate
/// duplicate registrations from re-polled tasks (wake-ups are permitted
/// to be spurious; tasks re-check state after every poll).
pub trait EventSource {
    /// Park `waker` until the source's next readiness edge.
    fn register(&self, waker: &Waker);
}

/// A readiness cell: tasks park wakers, producers trip them all.
///
/// Registration is level-meaningless — [`Notify::notify`] wakes and
/// *forgets* the current waiter set, so a task that still isn't
/// satisfied simply re-registers on its next poll.  Wakers are deduped
/// by task id, so a task polled several times between notifies parks
/// only one entry.
#[derive(Default)]
pub struct Notify {
    waiters: Mutex<Vec<Waker>>,
}

impl Notify {
    pub fn new() -> Self {
        Self::default()
    }

    /// Wake every parked task and clear the waiter set.
    pub fn notify(&self) {
        let drained: Vec<Waker> =
            std::mem::take(&mut *self.waiters.lock().unwrap());
        for w in drained {
            w.wake();
        }
    }

    /// Parked-waiter count (test/diagnostic view).
    pub fn waiters(&self) -> usize {
        self.waiters.lock().unwrap().len()
    }
}

impl EventSource for Notify {
    fn register(&self, waker: &Waker) {
        let mut ws = self.waiters.lock().unwrap();
        if !ws.iter().any(|w| w.task_id() == waker.task_id()) {
            ws.push(waker.clone());
        }
    }
}

/// Result of a non-blocking [`ExecQueue::poll_pop`].
pub enum PollPop<T> {
    /// An item was dequeued.
    Item(T),
    /// Queue open but empty; the caller's waker is parked and will fire
    /// on the next push (or close) — return `Pending`.
    Empty,
    /// Closed and fully drained; no more items will ever arrive.
    Closed,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Unbounded MPMC channel with *poll* semantics — the executor-native
/// sibling of [`crate::serve::queue::BoundedQueue`].  Consumers never
/// block: an empty poll parks the task's waker (registered while the
/// queue lock is held, so a racing push cannot slip between the
/// emptiness check and the registration).  Producers are plain method
/// calls from any thread — submit paths, scheduler tasks, or a future
/// reactor.
///
/// Unbounded is deliberate: every producer feeding one of these is
/// already bounded upstream (per-class admission depth), so pushing can
/// never be asked to wait, and a task-context producer must never block.
pub struct ExecQueue<T> {
    state: Mutex<QueueState<T>>,
    notify: Notify,
}

impl<T> Default for ExecQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> ExecQueue<T> {
    pub fn new() -> Self {
        Self {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            notify: Notify::new(),
        }
    }

    /// Enqueue an item; `Err(item)` hands it back if the queue closed.
    pub fn push(&self, item: T) -> std::result::Result<(), T> {
        {
            let mut s = self.state.lock().unwrap();
            if s.closed {
                return Err(item);
            }
            s.items.push_back(item);
        }
        self.notify.notify();
        Ok(())
    }

    /// Non-blocking dequeue with waker parking (see [`PollPop`]).
    pub fn poll_pop(&self, waker: &Waker) -> PollPop<T> {
        let mut s = self.state.lock().unwrap();
        if let Some(item) = s.items.pop_front() {
            return PollPop::Item(item);
        }
        if s.closed {
            return PollPop::Closed;
        }
        // park under the state lock: a push serializes after this
        // registration and is guaranteed to see the waker
        self.notify.register(waker);
        PollPop::Empty
    }

    /// Close the queue: pushes fail from now on, consumers drain what is
    /// left and then observe [`PollPop::Closed`].
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.notify.notify();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Queued item count.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> EventSource for ExecQueue<T> {
    fn register(&self, waker: &Waker) {
        // registration outside poll_pop still holds the state lock so
        // the push path cannot race past it
        let _s = self.state.lock().unwrap();
        self.notify.register(waker);
    }
}
