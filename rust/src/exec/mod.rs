//! `exec` — a zero-dependency event-driven executor: the reactor core
//! under the async serve plane.
//!
//! The thread-per-stage serve pipeline tops out at tens of concurrent
//! sensors; the paper's premise is *massively* parallel near-sensor
//! streams.  This module is the substrate that closes the gap: many
//! thousands of cooperative state machines ([`Task`]s) multiplexed onto
//! a small fixed worker pool, with deadlines served by a hashed
//! [`TimerWheel`] and readiness delivered through [`Waker`]s parked on
//! [`EventSource`]s.
//!
//! # Model
//!
//! * A [`Task`] is a resumable state machine: `poll` runs it until it
//!   either finishes ([`Poll::Ready`] — the task is retired) or cannot
//!   make progress ([`Poll::Pending`] — it parked its [`Waker`] on some
//!   event source first, or armed a timer via [`Context::wake_at`]).
//! * The [`Executor`] owns a ready queue of woken task ids, `workers`
//!   threads that drain it, and one timer thread driving the wheel.
//!   Wake-ups coalesce: waking a queued task is a no-op, waking a task
//!   *currently being polled* re-queues it once after the poll returns
//!   (so no readiness edge is ever lost to the poll/park race).
//! * Event sources ([`Notify`], [`ExecQueue`]) wake parked tasks from
//!   any thread — producer code, other tasks, or (later) an epoll
//!   reactor thread; the executor is indifferent to where edges come
//!   from.
//!
//! Spurious wake-ups are allowed by contract; tasks re-examine their
//! state on every poll.  There are no futures and no `unsafe`: a task
//! id plus a state machine is all the serve plane needs, and the whole
//! scheduler stays inspectable with a debugger.

pub mod source;
pub mod timer;

pub use source::{EventSource, ExecQueue, Notify, PollPop};
pub use timer::TimerWheel;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

/// Outcome of one [`Task::poll`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Poll {
    /// The task is finished and is retired from the executor.
    Ready,
    /// The task parked a waker (or armed a timer) and yields the worker.
    Pending,
}

/// A cooperative state machine run by the [`Executor`].
///
/// `poll` must not block the worker on anything another *task* is
/// responsible for producing (that is what parking is for); blocking on
/// CPU-bound work — an `infer_batch` call — is fine and expected, that
/// is exactly what the worker pool is sized around.
pub trait Task: Send {
    fn poll(&mut self, cx: &mut Context<'_>) -> Poll;
}

/// Per-poll task context: the identity needed to park and to arm timers.
pub struct Context<'a> {
    inner: &'a Arc<Inner>,
    id: usize,
}

impl Context<'_> {
    /// A waker for this task — clone it onto any [`EventSource`].
    pub fn waker(&self) -> Waker {
        Waker { inner: Arc::downgrade(self.inner), id: self.id }
    }

    /// This task's executor-assigned id.
    pub fn task_id(&self) -> usize {
        self.id
    }

    /// Arm a one-shot timer: the task is woken at (or one wheel tick
    /// after) `deadline`.  Arming several timers is fine — each fires a
    /// (possibly coalesced) wake.
    pub fn wake_at(&self, deadline: Instant) {
        self.inner.schedule_timer(deadline, self.id);
    }
}

/// Handle that re-queues one task.  Holds only a weak reference, so
/// wakers parked on long-lived sources never keep a drained executor
/// (or its retired tasks) alive; waking after shutdown is a no-op.
#[derive(Clone)]
pub struct Waker {
    inner: Weak<Inner>,
    id: usize,
}

impl Waker {
    pub fn wake(&self) {
        if let Some(inner) = self.inner.upgrade() {
            inner.wake(self.id);
        }
    }

    /// The woken task's id (used by sources to dedup registrations).
    pub fn task_id(&self) -> usize {
        self.id
    }
}

/// Scheduling state of one task slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TaskState {
    /// Parked; a wake moves it to `Queued`.
    Idle,
    /// In the ready queue awaiting a worker.
    Queued,
    /// A worker is polling it right now.
    Running,
    /// Woken *while* running: re-queue as soon as the poll returns.
    Rearm,
    /// Returned [`Poll::Ready`] (or panicked); permanently retired.
    Done,
}

struct Slot {
    /// The task body; `None` while a worker holds it (Running) and
    /// forever after retirement (Done).
    task: Option<Box<dyn Task>>,
    state: TaskState,
}

struct Sched {
    slots: Vec<Slot>,
    ready: VecDeque<usize>,
    /// Tasks not yet `Done` — `join` waits for this to hit zero.
    live: usize,
}

struct Inner {
    sched: Mutex<Sched>,
    ready_cv: Condvar,
    /// Signalled when `live` reaches zero (join) and on shutdown.
    idle_cv: Condvar,
    timers: Mutex<TimerWheel>,
    timer_cv: Condvar,
    shutdown: AtomicBool,
    panicked: AtomicUsize,
}

impl Inner {
    fn wake(&self, id: usize) {
        let mut s = self.sched.lock().unwrap();
        let Some(slot) = s.slots.get_mut(id) else { return };
        match slot.state {
            TaskState::Idle => {
                slot.state = TaskState::Queued;
                s.ready.push_back(id);
                self.ready_cv.notify_one();
            }
            TaskState::Running => slot.state = TaskState::Rearm,
            TaskState::Queued | TaskState::Rearm | TaskState::Done => {}
        }
    }

    fn schedule_timer(&self, at: Instant, id: usize) {
        let new_earliest = self.timers.lock().unwrap().insert(at, id);
        if new_earliest {
            // the timer thread may be sleeping toward a later deadline
            self.timer_cv.notify_one();
        }
    }
}

fn worker_main(inner: Arc<Inner>) {
    loop {
        let (id, mut task) = {
            let mut s = inner.sched.lock().unwrap();
            loop {
                if let Some(id) = s.ready.pop_front() {
                    s.slots[id].state = TaskState::Running;
                    let task = s.slots[id]
                        .task
                        .take()
                        .expect("queued task slot without a body");
                    break (id, task);
                }
                if inner.shutdown.load(Ordering::Acquire) {
                    return;
                }
                s = inner.ready_cv.wait(s).unwrap();
            }
        };
        // a panicking task is retired, not fatal: the worker survives
        // and `join` still terminates (live is decremented)
        let polled =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut cx = Context { inner: &inner, id };
                task.poll(&mut cx)
            }));
        let mut s = inner.sched.lock().unwrap();
        match polled {
            Ok(Poll::Pending) => {
                let rearm = s.slots[id].state == TaskState::Rearm;
                s.slots[id].task = Some(task);
                if rearm {
                    s.slots[id].state = TaskState::Queued;
                    s.ready.push_back(id);
                    inner.ready_cv.notify_one();
                } else {
                    s.slots[id].state = TaskState::Idle;
                }
            }
            Ok(Poll::Ready) | Err(_) => {
                if polled.is_err() {
                    inner.panicked.fetch_add(1, Ordering::Relaxed);
                }
                s.slots[id].state = TaskState::Done;
                s.live -= 1;
                if s.live == 0 {
                    inner.idle_cv.notify_all();
                }
            }
        }
    }
}

fn timer_main(inner: Arc<Inner>) {
    let mut due: Vec<usize> = Vec::new();
    loop {
        {
            let mut t = inner.timers.lock().unwrap();
            loop {
                if inner.shutdown.load(Ordering::Acquire) {
                    return;
                }
                t.collect_due(Instant::now(), &mut due);
                if !due.is_empty() {
                    break;
                }
                match t.next_deadline() {
                    Some(at) => {
                        let now = Instant::now();
                        if at <= now {
                            continue;
                        }
                        let (guard, _) = inner
                            .timer_cv
                            .wait_timeout(t, at - now)
                            .unwrap();
                        t = guard;
                    }
                    None => t = inner.timer_cv.wait(t).unwrap(),
                }
            }
        }
        for id in due.drain(..) {
            inner.wake(id);
        }
    }
}

/// Fixed worker pool + timer thread over a shared ready queue.
pub struct Executor {
    inner: Arc<Inner>,
    workers: Vec<std::thread::JoinHandle<()>>,
    timer: Option<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn `workers` poll threads (min 1) named `{name}-w{i}` plus the
    /// `{name}-timer` thread.  `tick` is the timer-wheel granularity.
    pub fn with_tick(workers: usize, name: &str, tick: Duration)
                     -> std::io::Result<Self> {
        let inner = Arc::new(Inner {
            sched: Mutex::new(Sched {
                slots: Vec::new(),
                ready: VecDeque::new(),
                live: 0,
            }),
            ready_cv: Condvar::new(),
            idle_cv: Condvar::new(),
            timers: Mutex::new(TimerWheel::new(tick, 256)),
            timer_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            panicked: AtomicUsize::new(0),
        });
        let mut handles = Vec::with_capacity(workers.max(1));
        for i in 0..workers.max(1) {
            let inner = Arc::clone(&inner);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("{name}-w{i}"))
                    .spawn(move || worker_main(inner))?,
            );
        }
        let timer = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name(format!("{name}-timer"))
                .spawn(move || timer_main(inner))?
        };
        Ok(Self { inner, workers: handles, timer: Some(timer) })
    }

    /// [`Executor::with_tick`] at the default 100 µs wheel granularity.
    pub fn new(workers: usize, name: &str) -> std::io::Result<Self> {
        Self::with_tick(workers, name, Duration::from_micros(100))
    }

    /// Register `task` and queue it for an initial poll.  Returns the
    /// task id (stable for the executor's lifetime).
    pub fn spawn(&self, task: Box<dyn Task>) -> usize {
        let mut s = self.inner.sched.lock().unwrap();
        let id = s.slots.len();
        s.slots.push(Slot { task: Some(task), state: TaskState::Queued });
        s.live += 1;
        s.ready.push_back(id);
        self.inner.ready_cv.notify_one();
        id
    }

    /// A waker for task `id`, usable from any thread (submit paths park
    /// none of their own state — they just kick the consuming task).
    pub fn waker(&self, id: usize) -> Waker {
        Waker { inner: Arc::downgrade(&self.inner), id }
    }

    /// Wake one task by id.
    pub fn wake(&self, id: usize) {
        self.inner.wake(id);
    }

    /// Wake every non-retired task — the shutdown broadcast that lets
    /// parked tasks observe their sources' closed state and finish.
    pub fn wake_all(&self) {
        let n = self.inner.sched.lock().unwrap().slots.len();
        for id in 0..n {
            self.inner.wake(id);
        }
    }

    /// Tasks not yet finished.
    pub fn live(&self) -> usize {
        self.inner.sched.lock().unwrap().live
    }

    /// Tasks retired by panic instead of [`Poll::Ready`].
    pub fn panicked(&self) -> usize {
        self.inner.panicked.load(Ordering::Relaxed)
    }

    /// Wait until every spawned task has finished, then stop the worker
    /// and timer threads.  The caller must have arranged termination
    /// (closed the queues the tasks consume) — a task that never returns
    /// `Ready` blocks this forever, exactly like joining a wedged thread.
    pub fn join(mut self) {
        {
            let mut s = self.inner.sched.lock().unwrap();
            while s.live > 0 {
                s = self.inner.idle_cv.wait(s).unwrap();
            }
        }
        self.stop_threads();
    }

    fn stop_threads(&mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        self.inner.ready_cv.notify_all();
        self.inner.timer_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(t) = self.timer.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Executor {
    /// Dropping without [`Executor::join`] force-stops the threads;
    /// unfinished tasks are abandoned in place (their wakers go dead via
    /// the weak reference).
    fn drop(&mut self) {
        self.stop_threads();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    /// Counts down through `Pending` polls, parking on a Notify.
    struct CountDown {
        left: u32,
        polls: Arc<AtomicU64>,
        notify: Arc<Notify>,
    }

    impl Task for CountDown {
        fn poll(&mut self, cx: &mut Context<'_>) -> Poll {
            self.polls.fetch_add(1, Ordering::Relaxed);
            if self.left == 0 {
                return Poll::Ready;
            }
            self.left -= 1;
            self.notify.register(&cx.waker());
            Poll::Pending
        }
    }

    #[test]
    fn tasks_run_to_ready_across_wakes() {
        let exec = Executor::new(2, "test-exec").unwrap();
        let polls = Arc::new(AtomicU64::new(0));
        let notify = Arc::new(Notify::new());
        for _ in 0..8 {
            exec.spawn(Box::new(CountDown {
                left: 3,
                polls: Arc::clone(&polls),
                notify: Arc::clone(&notify),
            }));
        }
        // notify until everything retires (wakes may be spurious or
        // coalesced; the loop just keeps edges coming)
        while exec.live() > 0 {
            notify.notify();
            std::thread::sleep(Duration::from_micros(200));
        }
        exec.join();
        // each task: 3 Pending polls + 1 Ready poll minimum
        assert!(polls.load(Ordering::Relaxed) >= 8 * 4);
    }

    /// Parks forever until its queue closes.
    struct Drainer {
        queue: Arc<ExecQueue<u64>>,
        sum: Arc<AtomicU64>,
    }

    impl Task for Drainer {
        fn poll(&mut self, cx: &mut Context<'_>) -> Poll {
            loop {
                match self.queue.poll_pop(&cx.waker()) {
                    PollPop::Item(v) => {
                        self.sum.fetch_add(v, Ordering::Relaxed);
                    }
                    PollPop::Empty => return Poll::Pending,
                    PollPop::Closed => return Poll::Ready,
                }
            }
        }
    }

    #[test]
    fn queue_readiness_drives_consumers_to_completion() {
        let exec = Executor::new(3, "test-exec").unwrap();
        let queue = Arc::new(ExecQueue::new());
        let sum = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            exec.spawn(Box::new(Drainer {
                queue: Arc::clone(&queue),
                sum: Arc::clone(&sum),
            }));
        }
        let want: u64 = (1..=1000).sum();
        for v in 1..=1000u64 {
            queue.push(v).unwrap();
        }
        queue.close();
        exec.join();
        assert_eq!(sum.load(Ordering::Relaxed), want);
        assert!(queue.push(7).is_err(), "closed queue must refuse pushes");
    }

    /// Arms a timer once, then completes when it fires.
    struct Alarm {
        armed: Option<Instant>,
        fired_after: Arc<Mutex<Option<Duration>>>,
        delay: Duration,
    }

    impl Task for Alarm {
        fn poll(&mut self, cx: &mut Context<'_>) -> Poll {
            match self.armed {
                None => {
                    let at = Instant::now() + self.delay;
                    self.armed = Some(at);
                    cx.wake_at(at);
                    Poll::Pending
                }
                Some(at) => {
                    let now = Instant::now();
                    if now < at {
                        // spurious wake: re-arm and keep waiting
                        cx.wake_at(at);
                        return Poll::Pending;
                    }
                    *self.fired_after.lock().unwrap() =
                        Some(now.saturating_duration_since(at));
                    Poll::Ready
                }
            }
        }
    }

    #[test]
    fn timer_wheel_wakes_tasks_no_earlier_than_their_deadline() {
        let exec = Executor::new(1, "test-exec").unwrap();
        let lateness = Arc::new(Mutex::new(None));
        exec.spawn(Box::new(Alarm {
            armed: None,
            fired_after: Arc::clone(&lateness),
            delay: Duration::from_millis(5),
        }));
        exec.join();
        let late = lateness.lock().unwrap().expect("alarm never fired");
        // never early (poll re-arms if woken early); a loose upper bound
        // guards against a wedged wheel, not scheduler jitter
        assert!(late < Duration::from_secs(5), "alarm {late:?} late");
    }

    #[test]
    fn wake_during_poll_rearms_instead_of_getting_lost() {
        // a task that parks *after* the edge has already fired: the
        // Running->Rearm transition must re-queue it
        struct ParkLate {
            notify: Arc<Notify>,
            first: bool,
            done: Arc<AtomicBool>,
        }
        impl Task for ParkLate {
            fn poll(&mut self, cx: &mut Context<'_>) -> Poll {
                if self.first {
                    self.first = false;
                    self.notify.register(&cx.waker());
                    // edge fires while we are still inside poll
                    self.notify.notify();
                    std::thread::sleep(Duration::from_millis(2));
                    return Poll::Pending;
                }
                self.done.store(true, Ordering::Release);
                Poll::Ready
            }
        }
        let exec = Executor::new(1, "test-exec").unwrap();
        let done = Arc::new(AtomicBool::new(false));
        exec.spawn(Box::new(ParkLate {
            notify: Arc::new(Notify::new()),
            first: true,
            done: Arc::clone(&done),
        }));
        exec.join();
        assert!(done.load(Ordering::Acquire));
    }

    #[test]
    fn panicking_task_is_retired_and_counted() {
        struct Boom;
        impl Task for Boom {
            fn poll(&mut self, _cx: &mut Context<'_>) -> Poll {
                panic!("task panic");
            }
        }
        let exec = Executor::new(1, "test-exec").unwrap();
        exec.spawn(Box::new(Boom));
        // join must still terminate; the panic is accounted, not fatal
        let panicked = {
            let e = exec;
            // give the worker a moment, then join
            while e.live() > 0 {
                std::thread::sleep(Duration::from_micros(100));
            }
            let n = e.panicked();
            e.join();
            n
        };
        assert_eq!(panicked, 1);
    }
}
