//! Algorithm 1: parallel bit-wise in-memory LBP comparison.
//!
//! Converts the software-sequential `pixel >= pivot` comparison into
//! bit-plane-parallel XOR passes over the mapped sub-array: starting from
//! the MSB plane, `NS-LBP_cmp` XORs the pixel plane with the pivot plane
//! for all 256 lanes at once; lanes whose XOR is 1 are *decided* at this
//! plane (the pixel bit itself tells the order: pivot bit 0 ⇒ pixel >
//! pivot ⇒ comparator output 1), remaining lanes continue to the next
//! plane; lanes equal through all planes output 1 (`>=` convention).
//!
//! The controller bookkeeping (`decided` mask, LBP update) is itself done
//! with in-memory row ops, composing 2-input AND/OR/NOT from the Table-2
//! primitives and the constant rows:
//! `AND2(a,b) = MAJ3(a,b,0)`, `OR2(a,b) = MAJ3(a,b,1)`, `NOT(a) = a ⊕ 1`.
//!
//! Cost: 7 instructions per bit-plane + 2 finalization ops + the optional
//! early-exit Ctrl read per plane — constant-time in the bit width, which
//! is the paper's headline property ("constant search time determined by
//! the bit length").

use crate::error::Result;
use crate::isa::{Executor, IniValue, Instruction};
use crate::mapping::{LbpSubarrayMap, ResvRow};

/// Result of one in-memory comparison pass.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompareOutcome {
    /// Comparator bits per lane: `pixel >= pivot`.
    pub bits: Vec<bool>,
    /// Bit-planes actually processed (early exit can cut this short).
    pub planes_processed: usize,
}

/// Scalar oracle: what Algorithm 1 must compute per lane.
pub fn compare_ref(pairs: &[(u8, u8)]) -> Vec<bool> {
    pairs.iter().map(|&(p, c)| p >= c).collect()
}

/// Run Algorithm 1 on lanes previously loaded into `slot` (see
/// [`LbpSubarrayMap::load_lanes`]).
///
/// * `lanes` — number of valid lanes in the slot.
/// * `skip_lsb_planes` — the sensor-side Ap-LBP approximation: planes the
///   ADC never converted are all-zero on both operands and are skipped
///   outright (no compare issued).
/// * `early_exit` — let the Ctrl stop once every lane is decided (costs
///   one Ctrl read per plane, saves the remaining planes).
pub fn parallel_compare(ex: &mut Executor<'_>, map: &LbpSubarrayMap,
                        slot: usize, lanes: usize, skip_lsb_planes: usize,
                        early_exit: bool) -> Result<CompareOutcome> {
    let mut bits = Vec::with_capacity(lanes);
    let planes_processed = parallel_compare_into(ex, map, slot, lanes,
                                                 skip_lsb_planes, early_exit,
                                                 &mut bits)?;
    Ok(CompareOutcome { bits, planes_processed })
}

/// Allocation-free [`parallel_compare`]: the comparator bits are
/// *appended* to a caller-owned buffer (the architectural batch path
/// accumulates every chunk of a whole batch into one arena vector) and
/// the processed-plane count is returned.  Identical instruction stream
/// and statistics.
pub fn parallel_compare_into(ex: &mut Executor<'_>, map: &LbpSubarrayMap,
                             slot: usize, lanes: usize,
                             skip_lsb_planes: usize, early_exit: bool,
                             out: &mut Vec<bool>) -> Result<usize> {
    let result = map.resv(ResvRow::Result);
    let lbp = map.resv(ResvRow::Lbp);
    let zero = map.resv(ResvRow::Zero);
    let one = map.resv(ResvRow::One);
    let decided = map.resv(ResvRow::Decided);
    let scratch = map.resv(ResvRow::Scratch);
    let scratch2 = map.resv(ResvRow::Scratch2);

    // constants + bookkeeping init
    ex.exec(Instruction::Ini { dest: zero, value: IniValue::Zeros })?;
    ex.exec(Instruction::Ini { dest: one, value: IniValue::Ones })?;
    ex.exec(Instruction::Ini { dest: lbp, value: IniValue::Zeros })?;
    ex.exec(Instruction::Ini { dest: decided, value: IniValue::Zeros })?;

    let mut planes = 0;
    for bit in (skip_lsb_planes..map.bits).rev() {
        let p_row = map.pixel_bit_row(slot, bit)?;
        let c_row = map.pivot_bit_row(slot, bit)?;
        // 1. Result_array <- P_i XOR C_i  (the NS-LBP_cmp hot op)
        ex.exec(Instruction::Cmp { src1: p_row, src2: c_row, dest: result })?;
        // 2. scratch <- NOT decided
        ex.exec(Instruction::Cmp { src1: decided, src2: one, dest: scratch })?;
        // 3. scratch2 <- Result AND NOT-decided   (newly decided lanes)
        ex.exec(Instruction::Carry { src1: result, src2: scratch, src3: zero,
                                     dest: scratch2 })?;
        // 4. scratch <- NOT C_i   (pivot bit 0 ⇒ pixel wins ⇒ LBP bit 1)
        ex.exec(Instruction::Cmp { src1: c_row, src2: one, dest: scratch })?;
        // 5. scratch <- newly AND NOT-C_i
        ex.exec(Instruction::Carry { src1: scratch2, src2: scratch, src3: zero,
                                     dest: scratch })?;
        // 6. LBP_array |= scratch
        ex.exec(Instruction::Carry { src1: lbp, src2: scratch, src3: one,
                                     dest: lbp })?;
        // 7. decided |= newly
        ex.exec(Instruction::Carry { src1: decided, src2: scratch2, src3: one,
                                     dest: decided })?;
        planes += 1;

        if early_exit {
            // Ctrl reads the decided mask (NS-LBP_Mem) and breaks when all
            // valid lanes are resolved.
            ex.stats.record_ctrl_read();
            let words = ex.array.row_words(decided)?; // no-copy borrow
            let all_decided = (0..lanes)
                .all(|l| words[l / 64] >> (l % 64) & 1 == 1);
            if all_decided {
                break;
            }
        }
    }

    // equality lanes (never decided) output 1: LBP |= NOT decided
    ex.exec(Instruction::Cmp { src1: decided, src2: one, dest: scratch })?;
    ex.exec(Instruction::Carry { src1: lbp, src2: scratch, src3: one,
                                 dest: lbp })?;

    map.read_resv_bits_into(ex.array, ResvRow::Lbp, lanes, out)?;
    ex.stats.record_ctrl_read();
    Ok(planes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Executor;
    use crate::sram::{RegionLayout, SubArray};

    fn map() -> LbpSubarrayMap {
        LbpSubarrayMap::new(RegionLayout::default(), 8).unwrap()
    }

    fn run_pairs(pairs: &[(u8, u8)], skip: usize, early: bool) -> CompareOutcome {
        let m = map();
        let mut sa = SubArray::new(256, 256);
        m.load_lanes(&mut sa, 0, pairs).unwrap();
        let mut ex = Executor::new(&mut sa);
        parallel_compare(&mut ex, &m, 0, pairs.len(), skip, early).unwrap()
    }

    #[test]
    fn matches_scalar_oracle_exhaustive_edges() {
        let pairs: Vec<(u8, u8)> = vec![
            (0, 0), (0, 255), (255, 0), (255, 255), (128, 127), (127, 128),
            (1, 0), (0, 1), (200, 200), (73, 74),
        ];
        let got = run_pairs(&pairs, 0, false);
        assert_eq!(got.bits, compare_ref(&pairs));
        assert_eq!(got.planes_processed, 8);
    }

    #[test]
    fn matches_oracle_randomized_full_width() {
        let mut rng = crate::rng::Xoshiro256::new(0xC0FFEE);
        for _ in 0..20 {
            let n = rng.range_i64(1, 256) as usize;
            let pairs: Vec<(u8, u8)> = (0..n)
                .map(|_| (rng.next_u64() as u8, rng.next_u64() as u8))
                .collect();
            for early in [false, true] {
                let got = run_pairs(&pairs, 0, early);
                assert_eq!(got.bits, compare_ref(&pairs));
            }
        }
    }

    #[test]
    fn early_exit_cuts_planes_when_msb_decides() {
        // all lanes differ at the MSB -> one plane suffices
        let pairs: Vec<(u8, u8)> = (0..256).map(|_| (0x80u8, 0x00u8)).collect();
        let got = run_pairs(&pairs, 0, true);
        assert_eq!(got.planes_processed, 1);
        assert!(got.bits.iter().all(|&b| b));
        // without early exit all 8 planes run
        let got = run_pairs(&pairs, 0, false);
        assert_eq!(got.planes_processed, 8);
    }

    #[test]
    fn skip_lsb_planes_matches_masked_compare() {
        // with the bottom 2 ADC bits never converted, both operands arrive
        // masked — the in-memory result equals comparing masked values.
        let mut rng = crate::rng::Xoshiro256::new(42);
        let pairs: Vec<(u8, u8)> = (0..256)
            .map(|_| ((rng.next_u64() as u8) & 0xFC, (rng.next_u64() as u8) & 0xFC))
            .collect();
        let got = run_pairs(&pairs, 2, false);
        assert_eq!(got.bits, compare_ref(&pairs));
        assert_eq!(got.planes_processed, 6);
    }

    #[test]
    fn constant_time_in_bit_width() {
        // instruction count must not depend on data (no early exit)
        let all_equal = vec![(7u8, 7u8); 64];
        let all_diff = vec![(255u8, 0u8); 64];
        let m = map();
        let mut counts = Vec::new();
        for pairs in [&all_equal, &all_diff] {
            let mut sa = SubArray::new(256, 256);
            m.load_lanes(&mut sa, 0, pairs).unwrap();
            let mut ex = Executor::new(&mut sa);
            parallel_compare(&mut ex, &m, 0, pairs.len(), 0, false).unwrap();
            counts.push(ex.stats.instructions);
        }
        assert_eq!(counts[0], counts[1]);
    }

    #[test]
    fn instruction_budget_per_plane() {
        // 4 init + 7 per plane + 2 finalize (no early exit)
        let pairs = vec![(1u8, 2u8); 16];
        let m = map();
        let mut sa = SubArray::new(256, 256);
        m.load_lanes(&mut sa, 0, &pairs).unwrap();
        let mut ex = Executor::new(&mut sa);
        parallel_compare(&mut ex, &m, 0, pairs.len(), 0, false).unwrap();
        assert_eq!(ex.stats.instructions, 4 + 7 * 8 + 2);
    }

    #[test]
    fn multiple_slots_independent() {
        let m = map();
        let mut sa = SubArray::new(256, 256);
        let a: Vec<(u8, u8)> = (0..100).map(|i| (i as u8, 50)).collect();
        let b: Vec<(u8, u8)> = (0..100).map(|i| (200, i as u8)).collect();
        m.load_lanes(&mut sa, 0, &a).unwrap();
        m.load_lanes(&mut sa, 5, &b).unwrap();
        let mut ex = Executor::new(&mut sa);
        let ra = parallel_compare(&mut ex, &m, 0, a.len(), 0, false).unwrap();
        assert_eq!(ra.bits, compare_ref(&a));
        let rb = parallel_compare(&mut ex, &m, 5, b.len(), 0, false).unwrap();
        assert_eq!(rb.bits, compare_ref(&b));
    }
}
